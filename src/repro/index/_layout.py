"""Shared row-layout constants and helpers for the index modules.

``build.py``, ``compress.py``, and ``merge.py`` all agree on one physical row
layout -- sentinel-padded sorted rows, a bucketed first-term fanout grid, and
128-row capacity quanta -- so the constants live here once instead of drifting
apart in three copies.
"""
from __future__ import annotations

import numpy as np

MAX_FANOUT = 4096   # fanout table columns per length section (memory/probe trade)
SENTINEL = np.uint32(0xFFFFFFFF)   # pad rows: sort after every real row
PAD_QUANTUM = 128   # row capacities round up to this (shards/segments stack)


def fanout_layout(vocab_size: int) -> tuple[int, int]:
    """(shift, n_buckets): lead term t maps to bucket t >> shift, monotonically."""
    shift = 0
    while ((vocab_size + 1) >> shift) > MAX_FANOUT:
        shift += 1
    n_buckets = ((vocab_size + 1) >> shift) + 1
    return shift, n_buckets


def round_capacity(n_rows: int) -> int:
    """Default padded capacity for ``n_rows`` real rows (+1 sentinel guard)."""
    return max(PAD_QUANTUM, -(-(n_rows + 1) // PAD_QUANTUM) * PAD_QUANTUM)


def pad_rows(a: np.ndarray, size: int, fill) -> np.ndarray:
    """Pad axis 0 of ``a`` to ``size`` rows with ``fill``."""
    pad = [(0, size - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad, constant_values=fill)


def row_bytes_view(keys: np.ndarray) -> np.ndarray:
    """[N] void view of uint32 key rows whose byte order == numeric lex order.

    Big-endian bytes make per-row ``memcmp`` equal ascending lexicographic
    comparison of the uint32 columns, so sorts/merges of index rows can run
    on a single flat column instead of one pass per key lane.
    """
    n_cols = keys.shape[1]
    return np.ascontiguousarray(keys.astype(">u4")).view(
        np.dtype((np.void, 4 * n_cols)))[:, 0]


def row_offsets(sorted_key: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Lower-bound offsets of ``queries`` in a sorted key column, int32."""
    return np.searchsorted(sorted_key, queries, side="left").astype(np.int32)


def row_lengths(section_start: np.ndarray, size: int) -> np.ndarray:
    """Row length 1..sigma (sentinels: sigma+1) from the section start table."""
    return np.searchsorted(section_start, np.arange(size), side="right") \
        .astype(np.int32)
