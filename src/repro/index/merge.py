"""Incremental index maintenance: k-way segment merge + generational (LSM) index.

The job side emits one frozen artifact per run; before this module, refreshing
the served index under a growing corpus meant re-sorting *everything*.  The
sorted immutable :class:`~repro.index.build.IndexSegment` is the unit of
composition (Pibiri & Venturini's layout observation), so freshness becomes the
classic log-structured-merge discipline instead:

  * :func:`merge_segments` -- k-way merge of sorted segments into one new
    segment with duplicate grams' counts *summed*.  Three routes produce the
    sorted run: ``"kway"`` (the default fold of the wave engine) exploits the
    inputs' sortedness on the host -- a stable sort of the concatenated
    big-endian row bytes is a galloping k-way merge (timsort detects the k
    presorted runs), an order of magnitude cheaper than re-sorting blind --
    and folds duplicate counts exactly in int64 via ``np.add.reduceat``;
    ``"merge"`` runs the jitted pairwise merge-path (``kernels/merge_path.py``
    Pallas kernel, or its jnp ref) over a balanced pairing tree;
    ``"device"`` is the same merge-path tree with an automatic host-kway
    fallback above ``DEVICE_MERGE_MAX_ROWS`` total rows (oversized tau=1
    gram sets would thrash device memory); ``"sort"`` re-sorts the
    concatenation through ``mapreduce.sort``.  On the device routes, run
    boundaries come
    from ``mapreduce.segment``'s lcp primitive and the dedup-summed count
    fold runs through the reducer's segmented-sum path in two uint32 limbs
    (exact below ``_MAX_DEVICE_RUN`` duplicates per gram; longer runs replay
    on the host in int64).  Every route refuses loudly if a merged cf
    overflows the uint32 device lanes (mirroring the continuation-mass guard
    in ``build.py``), and all three produce bit-identical segments: the
    output order is ascending (length | packed lanes), a pure function of
    the row set.
  * :func:`merge_indexes` -- segments in, finished artifact out:
    ``index_from_segment`` rebuilds fanout/continuation/cumsum structures from
    the merged rows *without re-running the job*, and re-compresses when the
    inputs were compressed.  Because the structure build is shared with
    ``build_index`` and the continuation order is a pure function of the row
    set, ``merge(build(A), build(B))`` is bit-identical to ``build(A ∪ B)``.
  * :class:`GenerationalIndex` -- L0..Ln immutable segments under a size-ratio
    compaction policy: each ingest freezes a new L0 from a (small) job delta,
    and merges cascade only when a newer run grows to within ``size_ratio`` of
    its elder, so a 10% corpus delta costs a 10% job + occasional merges rather
    than a full rebuild.  Point lookups sum cf across live segments; top-k
    completion fetches per-segment candidates and merges them exactly
    (``query.py``/``serve.py`` route both layouts, single-device and sharded).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stats import NGramStats
from repro.mapreduce import pack as packing
from repro.mapreduce import segment as mr_segment
from repro.mapreduce import sort as mr_sort
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from ._layout import SENTINEL, pad_rows, round_capacity, row_bytes_view
from .build import IndexSegment, NGramIndex, build_index, index_from_segment
from .compress import CompressedNGramIndex, compress_index, decode_segment

DEFAULT_SIZE_RATIO = 4
_U32_MAX = np.iinfo(np.uint32).max

AnyIndex = "NGramIndex | CompressedNGramIndex"


def _merged_run(segs: list[IndexSegment], *, route: str,
                use_kernels: bool) -> tuple[jax.Array, jax.Array]:
    """One sorted run (duplicates kept, sentinels at the tail) over all rows."""
    if route == "sort":
        # fallback: re-sort the concatenation (mapreduce.sort, the job's own
        # multi-key lexicographic sort; sentinel rows sort to the tail)
        keys = jnp.concatenate([s.keys for s in segs], axis=0)
        counts = jnp.concatenate([s.counts for s in segs], axis=0)
        keys, (counts,) = mr_sort.sort_with_payload(keys, [counts])
    elif route in ("merge", "device"):
        if use_kernels:
            from repro.kernels import ops as kops
            merge2 = kops.merge_path
        else:
            from repro.kernels import ref as kref
            merge2 = kref.merge_path_ref
        # balanced pairing tree in segment-index order: every row rides
        # O(log k) pairwise merges instead of the linear chain's O(k), and
        # adjacent pairing + the merge-path's A-first tie rule keep global
        # duplicate order (moot anyway: the dedup fold sums duplicates, and
        # output order is a pure function of the row set)
        # wave-fold segments arrive host-resident; the merge tree's traced
        # binary search needs device operands, so lift once up front
        runs = [(jnp.asarray(s.keys, jnp.uint32),
                 jnp.asarray(s.counts, jnp.uint32)) for s in segs]
        while len(runs) > 1:
            paired = [merge2(runs[i][0], runs[i + 1][0],
                             runs[i][1], runs[i + 1][1])
                      for i in range(0, len(runs) - 1, 2)]
            if len(runs) % 2:
                paired.append(runs[-1])
            runs = paired
        keys, counts = runs[0]
    else:
        raise ValueError(f"unknown merge route {route!r}")
    return jnp.asarray(keys, jnp.uint32), jnp.asarray(counts, jnp.uint32)


# Two-limb uint32 segment sums stay exact while every run is shorter than
# this; a merge of k segments with distinct rows each has runs of length <= k,
# so the device fold covers everything but adversarial duplicate floods.
_MAX_DEVICE_RUN = 1 << 16

# the "device" route's size ceiling: above this many total input rows
# (sentinel pads included -- that is what the merge tree actually moves) the
# fold falls back to the host k-way path, which streams in numpy instead of
# holding every intermediate merge run in device memory.  Oversized tau=1
# gram sets (huge corpora at tiny tau) are exactly the shape that trips this.
DEVICE_MERGE_MAX_ROWS = 1 << 22


def _run_starts(sorted_bytes: np.ndarray) -> np.ndarray:
    """Start offsets of the duplicate runs of a sorted byte-row column."""
    n = sorted_bytes.shape[0]
    new_run = np.empty((n,), bool)
    if n:
        new_run[0] = True
        new_run[1:] = sorted_bytes[1:] != sorted_bytes[:-1]
    return np.flatnonzero(new_run)


def _check_u32(totals: np.ndarray) -> np.ndarray:
    """uint32 view of int64 merged counts, refusing loudly on overflow."""
    if totals.size and int(totals.max()) > _U32_MAX:
        bad = int(np.argmax(totals))
        raise ValueError(
            f"merged count {int(totals[bad])} of gram row {bad} overflows the "
            "uint32 device count lane; raise tau or shard the corpus before "
            "merging")
    return totals.astype(np.uint32)


def _sorted_unique(segs: list[IndexSegment]):
    """Merge + dedup-fold segments' real rows -> sorted (keys, totals int64).

    Sentinel tails are stripped up front (``n_rows``), so only real rows ride
    the sort.  Viewing each row as its big-endian bytes makes byte order
    equal numeric lexicographic order, so a *stable* sort of the
    concatenation is a galloping k-way merge (numpy's timsort detects the k
    presorted runs) -- measured ~5-8x cheaper than a blind lexsort at the
    wave engine's row counts.  Duplicate counts fold exactly in int64 via
    ``reduceat``.
    """
    keys = np.concatenate(
        [np.asarray(s.keys, np.uint32)[:s.n_rows] for s in segs], axis=0)
    counts = np.concatenate(
        [np.asarray(s.counts, np.uint32)[:s.n_rows] for s in segs], axis=0)
    row_bytes = row_bytes_view(keys)
    order = np.argsort(row_bytes, kind="stable")
    starts = _run_starts(row_bytes[order])
    if not starts.size:
        return (np.zeros((0, keys.shape[1]), np.uint32),
                np.zeros((0,), np.int64), np.zeros((0,), row_bytes.dtype))
    picked = order[starts]
    totals = np.add.reduceat(counts[order].astype(np.int64), starts)
    return keys[picked], totals, row_bytes[picked]


def _kway_fold_host(segs: list[IndexSegment], *,
                    sigma: int) -> tuple[np.ndarray, np.ndarray]:
    """Host k-way dedup fold that exploits the inputs' sortedness.

    Balanced inputs take one galloping merge-by-stable-sort over every real
    row (see :func:`_sorted_unique`).  *Skewed* inputs -- one segment at
    least as large as all others combined, the shape of every LSM compaction
    (a fresh delta folding into a grown elder run) -- skip sorting the large
    segment entirely: only the small side is merged and deduped, then spliced
    into the base by binary search (``searchsorted``), so a compaction costs
    O(delta log delta + delta log base + total move) instead of
    O(total log total).  Both paths produce the identical sorted unique row
    set with exact int64 count folds and the uint32 overflow guard.
    """
    sizes = [s.n_rows for s in segs]
    b = int(np.argmax(sizes))
    nb, nd = sizes[b], sum(sizes) - sizes[b]
    if nd == 0:
        # one live input (plus empties): its rows are already sorted+unique
        base = segs[b]
        return (np.asarray(base.keys, np.uint32)[:nb],
                np.asarray(base.counts, np.uint32)[:nb])
    if nb < nd:
        keys, totals, _ = _sorted_unique(segs)
        return keys, _check_u32(totals)

    # skewed fast path: sort/dedup only the small side ...
    d_keys, d_tot, d_bytes = _sorted_unique(segs[:b] + segs[b + 1:])
    base = segs[b]
    b_keys = np.asarray(base.keys, np.uint32)[:nb]
    b_tot = np.asarray(base.counts, np.uint32)[:nb].astype(np.int64)
    b_bytes = row_bytes_view(b_keys)
    # ... then splice: delta rows already in the base fold their counts in
    # place (unique x unique -- no index collides), the rest interleave at
    # their searchsorted insertion points via one shift-and-scatter
    pos = np.searchsorted(b_bytes, d_bytes, side="left")
    dup = np.zeros(d_bytes.shape[0], bool)
    in_range = pos < nb
    dup[in_range] = b_bytes[pos[in_range]] == d_bytes[in_range]
    b_tot[pos[dup]] += d_tot[dup]
    ins = pos[~dup]                      # sorted: delta is
    n_new = int(ins.shape[0])
    out_keys = np.empty((nb + n_new, b_keys.shape[1]), np.uint32)
    out_tot = np.empty((nb + n_new,), np.int64)
    new_at = ins + np.arange(n_new)
    base_at = np.arange(nb) + np.cumsum(
        np.bincount(ins, minlength=nb + 1))[:nb]
    out_keys[base_at] = b_keys
    out_tot[base_at] = b_tot
    out_keys[new_at] = d_keys[~dup]
    out_tot[new_at] = d_tot[~dup]
    return out_keys, _check_u32(out_tot)


@partial(jax.jit, static_argnames=("sigma",))
def _fold_runs_device(keys: jax.Array, counts: jax.Array, *, sigma: int):
    """Dedup-fold a sorted run on device: the reducer's segmented-sum path.

    Device count lanes are uint32 and x64 may be off, so the fold runs in two
    uint32 limbs (lo/hi 16 bits of each count, segment-summed separately and
    recombined) -- exact while runs stay under ``_MAX_DEVICE_RUN`` rows, with
    the recombine carry doubling as the loud cf-overflow guard.  Run starts
    are compacted to the front with a stable argsort (order preserved), the
    tail refilled with sentinels.  Returns
    (keys [N, C], totals [N], n_runs, overflow?, max_run_len).
    """
    n, n_cols = keys.shape
    lcp = mr_segment.lcp_lengths(keys.astype(jnp.int32))
    new_run = lcp < n_cols                     # row 0 has lcp 0 -> always True
    seg = jnp.maximum(jnp.cumsum(new_run.astype(jnp.int32)) - 1, 0)
    run_len = jax.ops.segment_sum(jnp.ones((n,), jnp.uint32), seg,
                                  num_segments=n)
    slo = jax.ops.segment_sum(counts & jnp.uint32(0xFFFF), seg, num_segments=n)
    shi = jax.ops.segment_sum(counts >> 16, seg, num_segments=n)
    hi = shi + (slo >> 16)                     # carry; > 0xFFFF == cf overflow
    totals = (hi << 16) | (slo & jnp.uint32(0xFFFF))
    real = new_run & (keys[:, 0] <= jnp.uint32(sigma))  # sentinels sort last
    order = jnp.argsort(~real, stable=True)    # real run starts first, in order
    n_runs = jnp.sum(real.astype(jnp.int32))
    in_range = jnp.arange(n) < n_runs
    out_keys = jnp.where(in_range[:, None], keys[order], SENTINEL)
    out_counts = jnp.where(in_range, totals[seg][order], 0)
    overflow = jnp.any(in_range & ((hi[seg][order] >> 16) != 0))
    return out_keys, out_counts, n_runs, overflow, jnp.max(run_len)


def _fold_runs_host(keys: np.ndarray, counts: np.ndarray, *,
                    sigma: int) -> tuple[np.ndarray, np.ndarray]:
    """Host int64 fold -- fallback for runs too long for the two-limb device
    path, and the bearer of the detailed overflow diagnostic."""
    lcp = np.asarray(mr_segment.lcp_lengths(
        jnp.asarray(keys).astype(jnp.int32)))
    new_run = lcp < keys.shape[1]
    starts = np.flatnonzero(new_run)
    cs = np.concatenate([[0], np.cumsum(counts, dtype=np.int64)])
    ends = np.append(starts[1:], keys.shape[0])
    totals = cs[ends] - cs[starts]                      # int64: exact fold
    run_keys = keys[starts]
    real = run_keys[:, 0] <= np.uint32(sigma)           # sentinel length sorts last
    r_keys = run_keys[real]
    r_tot = totals[real]
    # mirror of build.py's continuation-mass guard: a silently wrapped cf would
    # serve plausible-looking garbage, so refuse loudly instead (raise tau, or
    # shard the corpus so per-shard counts stay in range)
    if r_tot.size and int(r_tot.max()) > _U32_MAX:
        bad = int(np.argmax(r_tot))
        raise ValueError(
            f"merged count {int(r_tot[bad])} of gram row {bad} overflows the "
            "uint32 device count lane; raise tau or shard the corpus before "
            "merging")
    return r_keys, r_tot.astype(np.uint32)


def merge_segments(segments, *, route: str = "merge", use_kernels: bool = False,
                   pad_to: int | None = None,
                   n_compressed: int | None = None) -> IndexSegment:
    """Merge sorted segments into one, summing counts of duplicate grams.

    ``route="kway"`` folds on the host exploiting the inputs' sortedness
    (stable sort of concatenated big-endian row bytes == galloping k-way
    merge; int64 ``reduceat`` count fold); ``route="merge"`` runs the jitted
    pairwise merge-path (Pallas kernel when ``use_kernels``, jnp ref
    otherwise) over a balanced pairing tree; ``route="device"`` is the
    merge-path tree as the wave fold's on-device k-way sort, falling back to
    the host kway fold when the inputs exceed ``DEVICE_MERGE_MAX_ROWS``
    total rows; ``route="sort"`` re-sorts the concatenation (the
    ``mapreduce.sort`` fallback).  All routes are bit-identical.  Raises
    ``ValueError`` if any merged count overflows the uint32 device lanes.

    ``n_compressed`` is purely observational: callers that decoded some
    inputs from the compressed layout record the flat/compressed mix on the
    ``merge.segments`` span.
    """
    segs = list(segments)
    if not segs:
        raise ValueError("cannot merge zero segments")
    sigma, vocab = segs[0].sigma, segs[0].vocab_size
    for s in segs[1:]:
        if (s.sigma, s.vocab_size) != (sigma, vocab):
            raise ValueError(
                f"segment meta mismatch: ({s.sigma}, {s.vocab_size}) vs "
                f"({sigma}, {vocab})")
    sp = obs_trace.span("merge.segments")
    if sp:
        sp.set(n_segments=len(segs),
               rows_in=sum(int(s.keys.shape[0]) for s in segs))
        if n_compressed is not None:
            sp.set(n_compressed=n_compressed,
                   n_flat=len(segs) - n_compressed)
    sp.__enter__()
    try:
        return _merge_segments_body(segs, sigma, vocab, route=route,
                                    use_kernels=use_kernels, pad_to=pad_to)
    finally:
        sp.__exit__(None, None, None)


def _merge_segments_body(segs, sigma, vocab, *, route, use_kernels, pad_to):
    host = route == "kway"
    if route == "device" and sum(
            int(s.keys.shape[0]) for s in segs) > DEVICE_MERGE_MAX_ROWS:
        # oversized tau=1 gram set: the device tree would hold O(total) rows
        # per merge level -- take the streaming host fold instead
        host = True
    if host:
        r_keys, r_tot = _kway_fold_host(segs, sigma=sigma)
    else:
        keys, counts = _merged_run(segs, route=route, use_kernels=use_kernels)

        # run boundaries (a row starts a run iff it differs from its
        # predecessor, via mapreduce.segment's lcp primitive) and the
        # dedup-summed totals all fold on device through the reducer's
        # segmented-sum path; the host only learns (n_runs, overflow?,
        # max_run) to size and validate the result
        out_keys, out_counts, n_runs, overflow, max_run = _fold_runs_device(
            keys, counts, sigma=sigma)
        n_runs, overflow, max_run = int(n_runs), bool(overflow), int(max_run)
        if overflow or max_run >= _MAX_DEVICE_RUN:
            # rare: replay on host for the int64 fold / detailed diagnostic
            r_keys, r_tot = _fold_runs_host(np.asarray(keys, np.uint32),
                                            np.asarray(counts, np.uint32),
                                            sigma=sigma)
        else:
            r_keys = np.asarray(out_keys[:n_runs], np.uint32)
            r_tot = np.asarray(out_counts[:n_runs], np.uint32)
    r = int(r_keys.shape[0])
    size = pad_to if pad_to is not None else round_capacity(r)
    if size < r + 1:
        raise ValueError(f"pad_to={size} < n_rows+1={r + 1}")
    keys_p = pad_rows(r_keys, size, SENTINEL)
    cnts_p = pad_rows(r_tot, size, 0)
    if not host:
        # device routes hand device arrays back; the host folds stay
        # host-resident end to end -- an LSM cascade of kway merges would
        # otherwise pay an h2d/d2h round trip per compaction for data the
        # next merge reads right back on the host
        keys_p, cnts_p = jnp.asarray(keys_p), jnp.asarray(cnts_p)
    return IndexSegment(keys=keys_p, counts=cnts_p, sigma=sigma,
                        vocab_size=vocab)


def _merge_input_segment(entry, *, route: str) -> IndexSegment:
    """Segment view of one merge input, compressed-native when needed.

    Flat entries pass through (``to_segment`` on an :class:`NGramIndex` is a
    field read); compressed entries stream-decode block chunks through
    :func:`~repro.index.compress.decode_segment` -- O(chunk) peak decoded
    working set, never a whole decoded table.  The host ``"kway"`` route (the
    LSM default) takes the unpadded host segment straight in; device routes
    get the capacity-padded device form their search kernels expect.
    """
    if isinstance(entry, CompressedNGramIndex):
        return decode_segment(entry) if route == "kway" else entry.to_segment()
    return entry if isinstance(entry, IndexSegment) else entry.to_segment()


def merge_indexes(indexes, *, route: str = "merge", use_kernels: bool = False,
                  pad_to: int | None = None):
    """Merge finished indexes into one of the same layout, job-free.

    All inputs must share (sigma, vocab_size) and layout; compressed inputs must
    agree on ``block_size`` and yield a compressed result.  Compressed inputs
    merge natively: their rows stream through the chunked block decode rather
    than a full-table ``to_segment`` round trip.
    """
    ixs = list(indexes)
    if not ixs:
        raise ValueError("cannot merge zero indexes")
    compressed = isinstance(ixs[0], CompressedNGramIndex)
    for ix in ixs[1:]:
        if isinstance(ix, CompressedNGramIndex) != compressed:
            raise ValueError("cannot merge mixed flat/compressed layouts")
    seg = merge_segments([_merge_input_segment(ix, route=route) for ix in ixs],
                         route=route, use_kernels=use_kernels,
                         n_compressed=sum(
                             isinstance(ix, CompressedNGramIndex)
                             for ix in ixs))
    idx = index_from_segment(seg, pad_to=pad_to)
    if compressed:
        bs = {ix.block_size for ix in ixs}
        if len(bs) != 1:
            raise ValueError(f"mixed block_size across inputs: {sorted(bs)}")
        return compress_index(idx, block_size=bs.pop())
    return idx


def segment_to_stats(seg: IndexSegment, *,
                     min_count: int | None = None) -> NGramStats:
    """Host-side ``NGramStats`` view of a segment (sharded rebuilds, tests).

    ``min_count`` filters rows *before* the term unpack -- the wave
    finalizer's global tau, applied while the row set is still packed, so
    only surviving rows (the monolithic-sized output) pay the unpack.
    Filtering commutes with unpacking, so the result equals filtering the
    full view after the fact.
    """
    r = seg.n_rows
    keys = np.asarray(seg.keys)[:r]
    counts = np.asarray(seg.counts)[:r].astype(np.int64)
    if min_count is not None and min_count > 1:
        keep = counts >= min_count
        keys = keys[keep]
        counts = counts[keep]
        r = int(keys.shape[0])
    lengths = keys[:, 0].astype(np.int32)
    grams = np.asarray(packing.unpack_terms(
        jnp.asarray(keys[:, 1:]), vocab_size=seg.vocab_size,
        sigma=seg.sigma)) if r else np.zeros((0, seg.sigma), np.int32)
    return NGramStats(grams.astype(np.int32), lengths, counts)


def stats_union(*stats: NGramStats) -> NGramStats:
    """Dedup-summed union of job outputs -- the from-scratch merge oracle."""
    acc: dict[tuple[int, ...], int] = {}
    sigma = max((int(s.grams.shape[1]) for s in stats), default=0)
    for s in stats:
        for g, v in s.to_dict().items():
            acc[g] = acc.get(g, 0) + v
    grams = np.zeros((len(acc), sigma), np.int32)
    lengths = np.zeros((len(acc),), np.int32)
    counts = np.zeros((len(acc),), np.int64)
    for i, (g, v) in enumerate(acc.items()):
        grams[i, :len(g)] = g
        lengths[i] = len(g)
        counts[i] = v
    return NGramStats(grams, lengths, counts)


def merge_continuation_results(per_seg, *, k: int):
    """Exact cross-segment fold of per-segment continuation answers.

    per_seg: list of (n_distinct [Q], total [Q], terms [Q, m], counts [Q, m])
    numpy-compatible tuples, each holding a segment's *complete* continuation
    set (certified upstream: every n_distinct <= m).  Returns the standard
    (nd [Q], total [Q], terms [Q, k], counts [Q, k]) with per-term counts
    summed across segments, ranked (cf desc, term asc) -- the same tie order
    the continuation view stores, so the fold is bit-compatible with a
    from-scratch merged index.
    """
    nd0, tot0, t0, c0 = [np.asarray(x) for x in per_seg[0]]
    q = nd0.shape[0]
    total = np.zeros((q,), np.int64)
    terms_all, counts_all, qid_all = [], [], []
    for nd_i, tot_i, t_i, c_i in per_seg:
        total += np.asarray(tot_i, np.int64)
        t_i = np.asarray(t_i)
        c_i = np.asarray(c_i, np.int64)
        live = c_i > 0
        qid = np.broadcast_to(np.arange(q)[:, None], t_i.shape)
        terms_all.append(t_i[live].astype(np.int64))
        counts_all.append(c_i[live])
        qid_all.append(qid[live])
    terms = np.concatenate(terms_all) if terms_all else np.zeros(0, np.int64)
    cfs = np.concatenate(counts_all) if counts_all else np.zeros(0, np.int64)
    qid = np.concatenate(qid_all) if qid_all else np.zeros(0, np.int64)
    span = int(terms.max()) + 2 if terms.size else 2
    key = qid * span + terms
    uniq, inv = np.unique(key, return_inverse=True)
    sums = np.bincount(inv, weights=cfs.astype(np.float64)).astype(np.int64)
    # query-time mirror of the merge fold's guard: summed-across-segment
    # masses/counts must fit the uint32 result lanes or refuse loudly
    worst = max(int(sums.max()) if sums.size else 0,
                int(total.max()) if total.size else 0)
    if worst > _U32_MAX:
        raise ValueError(
            f"summed continuation mass {worst} across live segments overflows "
            "uint32; compact the index or raise tau")
    u_q = (uniq // span).astype(np.int64)
    u_t = (uniq % span).astype(np.int64)
    nd = np.bincount(u_q, minlength=q).astype(np.uint32)
    # rank within each query: cf desc, term asc (the continuation tie order)
    order = np.lexsort((u_t, -sums, u_q))
    rank = np.arange(order.size) - np.concatenate(
        [[0], np.cumsum(np.bincount(u_q, minlength=q))])[u_q[order]]
    topk_t = np.zeros((q, k), np.uint32)
    topk_c = np.zeros((q, k), np.uint32)
    keep = rank < k
    topk_t[u_q[order][keep], rank[keep]] = u_t[order][keep]
    topk_c[u_q[order][keep], rank[keep]] = sums[order][keep]
    return nd, total.astype(np.uint32), topk_t, topk_c


class TieredSegmentAccumulator:
    """Size-tiered fold of a stream of sorted segments (the wave accumulator).

    The wave engine's naive fold -- ``acc = merge_segments([acc, seg])`` per
    wave -- re-merges the whole running segment every wave: O(waves x total)
    rows through the merge path.  This accumulator applies the same LSM
    discipline as :class:`GenerationalIndex` to raw segments: ``push`` stacks
    the new segment as the newest rung and merges only while the newest rung
    has grown to within ``size_ratio`` of its elder, so equal-sized waves
    amortize to O(total log waves) merge rows; ``result`` folds the surviving
    rungs once.  Because dedup-summed segment merges are associative and the
    output order is a pure function of the row set, the final segment is
    bit-identical to the pairwise fold's.

    ``fold_rows`` counts every input row fed through :func:`merge_segments`
    -- the measured merge work the benchmarks compare across strategies.
    """

    def __init__(self, *, size_ratio: int = DEFAULT_SIZE_RATIO,
                 route: str = "sort", use_kernels: bool = False):
        if size_ratio < 1:
            raise ValueError("size_ratio must be >= 1")
        self.size_ratio = size_ratio
        self.route = route
        self.use_kernels = use_kernels
        self.rungs: list[tuple[IndexSegment, int]] = []   # newest first
        self.fold_rows = 0

    def _merge_front(self, n: int) -> None:
        segs = [s for s, _ in reversed(self.rungs[:n])]   # elder first
        self.fold_rows += sum(r for _, r in self.rungs[:n])
        merged = merge_segments(segs, route=self.route,
                                use_kernels=self.use_kernels)
        self.rungs[:n] = [(merged, merged.n_rows)]

    def push(self, seg: IndexSegment, *, n_rows: int | None = None) -> None:
        """Stack one segment, then compact rungs under the size-ratio policy.

        ``n_rows`` (when the caller already knows it, e.g. from the stats the
        segment was frozen from) skips the segment's own host-side row count.
        """
        self.rungs.insert(0, (seg, seg.n_rows if n_rows is None else n_rows))
        while (len(self.rungs) >= 2 and
               self.rungs[0][1] * self.size_ratio >= self.rungs[1][1]):
            self._merge_front(2)

    def result(self) -> IndexSegment:
        """Fold the remaining rungs into the one final sorted segment."""
        if not self.rungs:
            raise ValueError("no segments accumulated")
        if len(self.rungs) > 1:
            self._merge_front(len(self.rungs))
        return self.rungs[0][0]


class DeferredSegmentAccumulator:
    """Stack every wave segment; fold once, k-way, at :meth:`result`.

    The wave engine's default fold.  Incremental compaction (tiered or
    pairwise) re-merges rows it has merged before -- O(total log waves) and
    O(waves x total) rows respectively -- but a :meth:`run` fold does not
    need intermediate merged state at all: only ``result`` is ever read.
    Deferring makes the total fold work exactly *one* k-way merge over the
    raw wave partials (O(total) rows through :func:`merge_segments`, which
    the ``"kway"`` route turns into a single galloping host merge).

    Memory: all wave partials stay live until ``result`` -- O(total tau=1
    rows), the same order as the merged segment every accumulator must
    produce anyway.  When waves must release their partials eagerly (truly
    bounded-memory streaming), use :class:`TieredSegmentAccumulator`
    (log-many live rungs) or :class:`PairwiseSegmentAccumulator` (one).
    Same interface, bit-identical result: dedup-summed merges are
    associative and the output order is a pure function of the row set.
    """

    def __init__(self, *, route: str = "kway", use_kernels: bool = False,
                 **_ignored):
        self.route = route
        self.use_kernels = use_kernels
        self.segs: list[IndexSegment] = []
        self._rows: list[int] = []
        self.fold_rows = 0

    def push(self, seg: IndexSegment, *, n_rows: int | None = None) -> None:
        self.segs.append(seg)
        self._rows.append(seg.n_rows if n_rows is None else n_rows)

    def result(self) -> IndexSegment:
        if not self.segs:
            raise ValueError("no segments accumulated")
        if len(self.segs) == 1:
            return self.segs[0]
        self.fold_rows += sum(self._rows)
        merged = merge_segments(self.segs, route=self.route,
                                use_kernels=self.use_kernels)
        self.segs = [merged]
        self._rows = [merged.n_rows]
        return merged


class PairwiseSegmentAccumulator:
    """The legacy fold-every-wave-into-one-segment baseline (O(waves x total)).

    Same interface and bit-identical result as
    :class:`TieredSegmentAccumulator`; kept for the benchmark comparison and
    as the degenerate-memory option (exactly one live segment at all times).
    """

    def __init__(self, *, route: str = "sort", use_kernels: bool = False,
                 **_ignored):
        self.route = route
        self.use_kernels = use_kernels
        self._seg: IndexSegment | None = None
        self._rows = 0
        self.fold_rows = 0

    def push(self, seg: IndexSegment, *, n_rows: int | None = None) -> None:
        rows = seg.n_rows if n_rows is None else n_rows
        if self._seg is None:
            self._seg, self._rows = seg, rows
            return
        self.fold_rows += self._rows + rows
        self._seg = merge_segments([self._seg, seg], route=self.route,
                                   use_kernels=self.use_kernels)
        self._rows = self._seg.n_rows

    def result(self) -> IndexSegment:
        if self._seg is None:
            raise ValueError("no segments accumulated")
        return self._seg


class GenerationalIndex:
    """L0..Ln immutable sorted segments + size-ratio compaction (an LSM tree).

    ``ingest`` freezes a job delta into a new L0 (newest-first list) and then
    compacts: while the newest run has grown to within ``size_ratio`` of its
    elder (``rows(L0) * size_ratio >= rows(L1)``), the two merge -- so equal
    ingests amortize into log-many segments and a small delta over a big base
    costs no merge at all.

    Writes are segment-first: a level lives as a bare :class:`IndexSegment`
    until a reader touches it, at which point :attr:`segments` materializes
    the full :class:`NGramIndex` / :class:`CompressedNGramIndex` artifact in
    place (cached until the level is compacted away).  Ingest therefore
    costs one sorted-segment freeze plus the galloping segment merge --
    the acceleration structures are built once per *surviving* level
    instead of once per wave, the classic write-optimized LSM trade.
    Because ``build_index == index_from_segment . segment_from_stats``, a
    lazily materialized level is bit-identical to an eagerly frozen one.
    Queries go through ``query.py`` / ``serve.py``, which sum point counts
    and exactly fold top-k candidates across live segments.  ``generation``
    bumps on every mutation -- the serving cache's invalidation key.

    **Compressed-at-rest tier policy** (``compress=True``): hot L0 deltas
    materialize *flat* -- they are small, short-lived, and merge away soon --
    while any rung produced by a compaction merge freezes to the
    :class:`CompressedNGramIndex` at-rest layout.  Provenance, not position,
    decides: a rung that has been through a merge is the cold, grown run.
    Mixed flat/compressed stacks answer bit-identically (the compressed
    layout's parity contract), and compaction decodes compressed inputs
    chunk-by-chunk via :func:`~repro.index.compress.decode_segment` -- never
    a whole decoded table.
    """

    def __init__(self, *, sigma: int, vocab_size: int, compress: bool = False,
                 block_size: int = 4, size_ratio: int = DEFAULT_SIZE_RATIO,
                 route: str = "kway", use_kernels: bool = False):
        if size_ratio < 1:
            raise ValueError("size_ratio must be >= 1")
        self.sigma = sigma
        self.vocab_size = vocab_size
        self.compress = compress
        self.block_size = block_size
        self.size_ratio = size_ratio
        self.route = route
        self.use_kernels = use_kernels
        self._next_id = 0
        # newest (L0) first; an entry is a bare IndexSegment until a reader
        # materializes it (in place) into a built index artifact
        self.levels = []
        self.generation = 0
        # lifetime compaction accounting, surfaced through the metrics
        # registry on every mutation (see _publish_metrics)
        self.compaction_stats = {"ingests": 0, "merges": 0, "rows_merged": 0}

    # --- structure ----------------------------------------------------------- #

    @property
    def levels(self) -> list:
        """Live level entries, newest first.  Assign a full list to replace
        the stack (tests/benchmarks bootstrap with pre-built artifacts);
        in-place mutation is reserved for the index itself, which keeps the
        per-level provenance and identity books in sync."""
        return self._levels

    @levels.setter
    def levels(self, entries) -> None:
        # externally handed entries carry no merge provenance: bare segments
        # among them materialize flat, matching a fresh-ingest L0
        self._levels = list(entries)
        self._from_merge = [False] * len(self._levels)
        self._level_ids = [self._take_id() for _ in self._levels]

    @property
    def level_ids(self) -> tuple:
        """Stable per-level identity tokens (newest first): a level keeps its
        id as long as its content is untouched, and every ingest/merge mints
        a fresh id -- the incremental re-shard reuse key (``serve.py``)."""
        return tuple(self._level_ids)

    def _take_id(self) -> int:
        self._next_id += 1
        return self._next_id

    def _materialize(self, i: int):
        """Build (and cache, replacing in place) level ``i``'s query artifact."""
        entry = self._levels[i]
        if isinstance(entry, IndexSegment):
            with obs_trace.span("gen.materialize") as sp:
                idx = index_from_segment(entry)
                # tier policy: only merged (cold, grown) rungs freeze to the
                # compressed at-rest layout; fresh L0 deltas stay flat
                compressed = self.compress and self._from_merge[i]
                if compressed:
                    idx = compress_index(idx, block_size=self.block_size)
                if sp:
                    sp.set(level=i, rows=idx.n_rows,
                           compressed=int(compressed))
            self._levels[i] = entry = idx
        return entry

    @property
    def segments(self) -> tuple:
        return tuple(self._materialize(i) for i in range(len(self._levels)))

    @property
    def n_segments(self) -> int:
        return len(self.levels)

    @property
    def n_rows(self) -> int:
        return sum(ix.n_rows for ix in self.levels)

    @property
    def nbytes(self) -> int:
        return sum(ix.nbytes for ix in self.levels)

    def __repr__(self) -> str:
        rows = "+".join(str(ix.n_rows) for ix in self.levels) or "0"
        return (f"GenerationalIndex(gen={self.generation}, "
                f"segments={self.n_segments}, rows={rows})")

    # --- mutation ------------------------------------------------------------ #

    def _freeze(self, stats: NGramStats) -> IndexSegment:
        # segment only -- the query artifact (and compression) materializes
        # lazily on first read, so ingest stays O(delta sort)
        from .build import segment_from_stats
        return segment_from_stats(stats, vocab_size=self.vocab_size)

    def ingest(self, stats: NGramStats) -> dict:
        """Freeze a job delta into L0, then compact.  Returns a report dict
        (rows ingested, merges performed, live segment row counts)."""
        if int(stats.grams.shape[1]) != self.sigma:
            raise ValueError(
                f"delta sigma {int(stats.grams.shape[1])} != index sigma "
                f"{self.sigma}")
        with obs_trace.span("gen.ingest") as sp:
            seg = None
            if len(stats):
                with obs_trace.span("gen.freeze"):
                    seg = self._freeze(stats)
            return self._ingest_body(seg, len(stats), sp)

    def ingest_segment(self, seg: IndexSegment | None, *,
                       n_rows: int | None = None) -> dict:
        """Ingest an already-frozen sorted segment as the new L0, then compact.

        The wave engine's streaming entry: the fold thread freezes each
        wave's partial on the host (``build.segment_from_wave_stats``) and
        hands the bare segment straight in -- no per-wave index build; the
        query artifact materializes lazily on first read.
        """
        if seg is not None and (seg.sigma, seg.vocab_size) != (
                self.sigma, self.vocab_size):
            raise ValueError(
                f"segment meta ({seg.sigma}, {seg.vocab_size}) != index "
                f"({self.sigma}, {self.vocab_size})")
        with obs_trace.span("gen.ingest") as sp:
            rows = 0 if seg is None else \
                (seg.n_rows if n_rows is None else n_rows)
            return self._ingest_body(seg, rows, sp)

    def _ingest_body(self, seg, rows: int, sp) -> dict:
        """Shared L0 insert + compaction + accounting of both ingest entries.

        An *empty* delta (e.g. an all-PAD wave of the streaming ingest path)
        bumps the generation -- readers must still observe the swap -- but
        inserts no segment: an all-sentinel L0 would cost every future query
        a full per-segment dispatch for nothing.
        """
        merges = 0
        if rows:
            self._levels.insert(0, seg)
            self._from_merge.insert(0, False)       # fresh delta: hot, flat
            self._level_ids.insert(0, self._take_id())
            merges = self._compact()
        self.generation += 1
        self.compaction_stats["ingests"] += 1
        self._publish_metrics()
        if sp:
            sp.set(rows=rows, merges=merges, segments=len(self.levels))
        return {"ingested_rows": rows, "merges": merges,
                "segment_rows": [ix.n_rows for ix in self.levels]}

    def _merge_front(self, n: int) -> None:
        # elder segments first: merge-path ties keep generation order stable;
        # compaction works on segment views (any cached artifact of a merged
        # level dies with it -- the merged level rebuilds lazily if read);
        # compressed rungs stream-decode chunk by chunk, never a full table
        with obs_trace.span("gen.compact") as sp:
            rows_in = sum(ix.n_rows for ix in self._levels[:n])
            merged = merge_segments(
                [_merge_input_segment(e, route=self.route)
                 for e in reversed(self._levels[:n])],
                route=self.route, use_kernels=self.use_kernels,
                n_compressed=sum(isinstance(e, CompressedNGramIndex)
                                 for e in self._levels[:n]))
            self._levels[:n] = [merged]
            self._from_merge[:n] = [True]           # merged: cold at rest
            self._level_ids[:n] = [self._take_id()]
            self.compaction_stats["merges"] += 1
            self.compaction_stats["rows_merged"] += rows_in
            if sp:
                sp.set(rows_in=rows_in, rows_out=merged.n_rows)

    def _compact(self) -> int:
        merges = 0
        while (len(self.levels) >= 2 and
               self.levels[0].n_rows * self.size_ratio >= self.levels[1].n_rows):
            self._merge_front(2)
            merges += 1
        return merges

    def _publish_metrics(self) -> None:
        """Push live structure + lifetime compaction stats to the registry.

        A no-op (shared null singleton) when metrics are disabled; gauges
        carry the current shape (rung sizes newest-first), counters mirror
        the monotonic ``compaction_stats``.
        """
        reg = obs_metrics.get_registry()
        if not reg:
            return
        reg.gauge("gen.generation").set(self.generation)
        reg.gauge("gen.segments").set(self.n_segments)
        reg.gauge("gen.rows").set(self.n_rows)
        # rung sizes newest-first; bounded set of gauges (log-many rungs).
        # bytes_at_rest reads the entry as-is: a bare (not yet materialized)
        # rung reports its flat segment bytes and shrinks at the first
        # publish after its lazy compression; compressed rungs report their
        # persisted stream bytes (nbytes_at_rest), not the resident total
        # with decoded query caches
        n_comp, total_bytes = 0, 0
        for i, ix in enumerate(self._levels):
            reg.gauge(f"gen.rung{i}_rows").set(ix.n_rows)
            b = getattr(ix, "nbytes_at_rest", None) or ix.nbytes
            total_bytes += b
            reg.gauge(f"gen.rung{i}_bytes_at_rest").set(b)
            n_comp += isinstance(ix, CompressedNGramIndex)
        reg.gauge("gen.bytes_at_rest").set(total_bytes)
        reg.gauge("gen.compressed_segments").set(n_comp)
        for k, v in self.compaction_stats.items():
            c = reg.counter(f"gen.{k}")
            c.add(v - c.value)          # counters mirror the lifetime totals

    def compact_all(self) -> None:
        """Force-merge every live segment into one (maintenance/benchmarks)."""
        if len(self.levels) >= 2:
            self._merge_front(len(self.levels))
            self.generation += 1
            self._publish_metrics()


def generational_from_stats(stats: NGramStats, *, vocab_size: int,
                            compress: bool = False,
                            **kw) -> GenerationalIndex:
    """Bootstrap a generational index from one finished job's output."""
    gen = GenerationalIndex(sigma=int(stats.grams.shape[1]),
                            vocab_size=vocab_size, compress=compress, **kw)
    gen.ingest(stats)
    return gen
