"""Batched queries against a frozen :class:`~repro.index.build.NGramIndex`.

All entry points are jitted, operate on whole query batches, and are branchless
inside (fixed-iteration binary searches; misses and invalid queries resolve to
count 0 / empty completion lists through masks, never through control flow), so
one compiled program serves any traffic mix.

Query plan, uncompressed :class:`NGramIndex` (both views):

  1. length + lead-term bucket -> [lo, hi) bracket from the fanout table (O(1));
  2. lexicographic lower/upper bound on the packed lanes inside the bracket --
     ``use_kernels=True`` routes the search through the Pallas ``bsearch`` kernel
     (``repro.kernels.ops``), else the pure-jnp ``ref`` path (same contract);
  3. gather counts / top-k continuation rows at the found positions.

Compressed :class:`~repro.index.compress.CompressedNGramIndex` (same public
entry points; dispatch is on the index type, which is static under jit):

  1. bracket as above, but the fanout cell boundaries come from Elias-Fano
     ``select`` instead of a dense table;
  2. the same bsearch (kernel or ref) runs over the per-block *head* rows --
     heads carry an explicit length column, so one search spans all sections;
  3. the candidate block is decoded and ranked in one pass (``block_decode``
     kernel or its ref oracle): global position = block * block_size + in-block
     rank, clipped into [lo, hi);
  4. counts / continuation rows are gathered from the fixed-width bit streams.

Because rank counting is global (out-of-bracket rows still compare consistently
under the (length, terms) order) the clip step makes bracketed and global
answers identical -- the parity suite leans on this.

Validity rules: a query gram must have 1 <= len <= sigma, all terms in 1..vocab
before the PAD tail, and nothing after it.  Continuation prefixes allow len 0
(top-k unigrams) on a single-device index; the sharded server requires len >= 1
(shards partition by lead term -- see ``serve.py``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels.bitpack import extract_bits
from repro.mapreduce import pack as packing
from .build import NGramIndex, search_steps
from .compress import CompressedNGramIndex
from .merge import GenerationalIndex, merge_continuation_results


def _bsearch(view: jax.Array, q_lanes: jax.Array, lo: jax.Array,
             hi: jax.Array, *, upper: bool, use_kernels: bool,
             steps: int | None = None) -> jax.Array:
    if steps is None:
        steps = search_steps(view.shape[0])
    if use_kernels:
        from repro.kernels import ops as kops
        return kops.bsearch(view, q_lanes, lo, hi, upper=upper, steps=steps)
    from repro.kernels import ref as kref
    return kref.bsearch_ref(view, q_lanes, lo, hi, upper=upper, steps=steps)


def _search(idx: NGramIndex, view: jax.Array, q_lanes: jax.Array, lo: jax.Array,
            hi: jax.Array, *, upper: bool, use_kernels: bool) -> jax.Array:
    return _bsearch(view, q_lanes, lo, hi, upper=upper, use_kernels=use_kernels)


def _bracket(idx: NGramIndex, table: jax.Array, length: jax.Array,
             lead: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[lo, hi) rows of the (length, lead-term bucket) fanout cell."""
    sec = jnp.clip(length - 1, 0, idx.sigma - 1)
    b = jnp.clip((lead >> jnp.uint32(idx.fanout_shift)).astype(jnp.int32),
                 0, idx.n_fanout - 1)
    return table[sec, b], table[sec, b + 1]


def _clean(idx: NGramIndex, grams: jax.Array, lengths: jax.Array,
           lo_len: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(masked grams, lengths, valid): zero the PAD tail, validate term ranges."""
    grams = grams.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    in_len = jnp.arange(idx.sigma, dtype=jnp.int32)[None, :] < lengths[:, None]
    grams = grams * in_len
    ok_terms = jnp.all(jnp.where(in_len, (grams >= 1) & (grams <= idx.vocab_size),
                                 True), axis=1)
    valid = (lengths >= lo_len) & (lengths <= idx.sigma) & ok_terms
    return grams, lengths, valid


# --------------------------------------------------------------------------- #
# compressed-index plan: EF bracket -> head bsearch -> block decode -> gather
# --------------------------------------------------------------------------- #

def _dense_qkey(cidx: CompressedNGramIndex, length: jax.Array,
                terms: jax.Array) -> jax.Array:
    """[Q, HL] uint32 query keys in the dense head layout.

    Traced mirror of ``compress._pack_head_keys`` over the same
    ``head_key_layout``: (length, t0..t_{sigma-1}) MSB-first with no slack,
    so one lane fewer to gather and compare per bsearch step than the old
    (len | packed lanes) keys.  Garbage terms on invalid queries stay
    in-width (masked), deterministic, and are discarded downstream."""
    from .compress import head_key_layout
    fields, hl = head_key_layout(cidx.sigma, cidx.term_bits)
    cols = [length] + [terms[:, j] for j in range(cidx.sigma)]
    out = [jnp.zeros(length.shape, jnp.uint32) for _ in range(hl)]
    for (o, w), v in zip(fields, cols):
        v = v.astype(jnp.uint32) & jnp.uint32((1 << w) - 1)
        r = o + w
        j0 = o // 32
        e0 = 32 * (j0 + 1)
        if r <= e0:
            out[j0] = out[j0] | (v << (e0 - r))
        else:                       # field straddles a lane boundary
            out[j0] = out[j0] | (v >> (r - e0))
            e1 = 32 * ((r - 1) // 32 + 1)
            out[(r - 1) // 32] = out[(r - 1) // 32] | (v << (e1 - r))
    return jnp.stack(out, axis=1)


def _c_head_bracket(cidx: CompressedNGramIndex, table: jax.Array,
                    length: jax.Array, lead: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """[lo_h, hi_h) *block* bracket of the (length, lead-term bucket) cell.

    One gather off the decoded fanout cache (``fan_cache`` /
    ``cont_fan_cache``, already in blocks) fetches the cell's start; the
    static ``head_span`` (the widest cell measured at build time, in blocks)
    bounds its width, which both seeds the head bsearch and caps its trip
    count (``head_steps``) -- without the bracket every head probe would pay
    log2(n_blocks) steps, and before the cache the fetch itself cost a
    per-batch EF select/decode.  The cell end itself is never needed: ranks
    count against the *global* (length, terms) order, under which rows
    outside the cell still compare consistently, so cell-clipping the result
    would be a no-op for any valid query (invalid ones are masked upstream).
    """
    sec = jnp.clip(length - 1, 0, cidx.sigma - 1)
    b = jnp.clip((lead >> jnp.uint32(cidx.fanout_shift)).astype(jnp.int32),
                 0, cidx.n_fanout - 1)
    flat = sec * (cidx.n_fanout + 1) + b
    lo_h = jnp.take(table, flat).astype(jnp.int32)
    return lo_h, jnp.minimum(lo_h + cidx.head_span, cidx.n_blocks)


def _c_rank(cidx: CompressedNGramIndex, blk: jax.Array, q_terms: jax.Array,
            q_len: jax.Array, sec: jax.Array, *, cont: bool,
            use_kernels: bool, qblock: int = 256
            ) -> tuple[jax.Array, jax.Array]:
    """(cnt_lt, cnt_eq) of each query inside its candidate block."""
    if cont:
        args = (cidx.cont_lcps, cidx.cont_payload, cidx.cont_block_base)
    else:
        args = (cidx.lcps, cidx.payload, cidx.block_base)
    kw = dict(term_bits=cidx.term_bits, lcp_width=cidx.lcp_width,
              block_size=cidx.block_size, len_off=1 if cont else 0)
    if use_kernels:
        from repro.kernels import ops as kops
        return kops.block_decode(*args, sec, blk, q_terms, q_len, **kw,
                                 qblock=qblock)
    # the jnp ref path processes the whole batch at once; qblock only tiles
    # the Pallas grid
    from repro.kernels import ref as kref
    return kref.block_decode_ref(*args, sec, blk, q_terms, q_len, **kw)


def _c_lookup_packed(cidx: CompressedNGramIndex, q_lanes: jax.Array,
                     q_len: jax.Array, valid: jax.Array, *,
                     use_kernels: bool, qblock: int = 256,
                     q_terms: jax.Array | None = None) -> jax.Array:
    b, nb = cidx.block_size, cidx.n_blocks
    sec = cidx.section_starts()
    if q_terms is None:
        # pre-packed callers (the sharded server ships lanes only): recover
        # the terms; the cleaned-gram entry points pass them through instead
        q_terms = packing.unpack_terms(q_lanes, vocab_size=cidx.vocab_size,
                                       sigma=cidx.sigma).astype(jnp.int32)
    qkey = _dense_qkey(cidx, q_len, q_terms)
    # point rows are unique, so the block holding q (if any) is the last one
    # whose head <= q: upper bound over heads, minus one.  The fanout-cache
    # bracket caps the search at head_steps trips (log2 of the widest cell)
    # instead of log2(n_blocks) -- heads outside the cell compare
    # consistently under the global order, so the bracketed result is
    # bit-identical to a full-range search
    lead = packing.lead_term(q_lanes[:, 0], vocab_size=cidx.vocab_size)
    lo_h, hi_h = _c_head_bracket(cidx, cidx.fan_cache, q_len, lead)
    pos_h = _bsearch(cidx.heads, qkey, lo_h, hi_h, upper=True,
                     use_kernels=use_kernels, steps=cidx.head_steps)
    blk = jnp.clip(pos_h - 1, 0, nb - 1)
    cnt_lt, cnt_eq = _c_rank(cidx, blk, q_terms, q_len, sec, cont=False,
                             use_kernels=use_kernels, qblock=qblock)
    pos = jnp.clip(blk * b + cnt_lt, 0, cidx.size - 1)
    hit = valid & (cnt_eq > 0)       # uniqueness makes equality self-validating
    cf = extract_bits(cidx.counts_packed, pos, cidx.count_width)
    return jnp.where(hit, cf, 0).astype(jnp.uint32)


def _c_continuations_packed(cidx: CompressedNGramIndex, p_lanes: jax.Array,
                            p_len: jax.Array, valid: jax.Array, *, k: int,
                            use_kernels: bool, qblock: int = 256,
                            p_terms: jax.Array | None = None):
    b, nb = cidx.block_size, cidx.n_blocks
    sec = cidx.section_starts()
    lead = packing.lead_term(p_lanes[:, 0], vocab_size=cidx.vocab_size)
    target = p_len + 1
    lo_h, hi_h = _c_head_bracket(cidx, cidx.cont_fan_cache, target, lead)
    if p_terms is None:
        p_terms = packing.unpack_terms(p_lanes, vocab_size=cidx.vocab_size,
                                       sigma=cidx.sigma).astype(jnp.int32)
    qkey = _dense_qkey(cidx, target, p_terms)
    # duplicate prefixes can straddle blocks, so the lower bound needs the
    # block *before* the first head >= q, the upper bound the block of the
    # last head <= q (see compress.py docstring for the run/head argument)
    m_lb = _bsearch(cidx.cont_heads, qkey, lo_h, hi_h, upper=False,
                    use_kernels=use_kernels, steps=cidx.head_steps)
    blk_lb = jnp.clip(m_lb - 1, 0, nb - 1)
    m_ub = _bsearch(cidx.cont_heads, qkey, lo_h, hi_h, upper=True,
                    use_kernels=use_kernels, steps=cidx.head_steps)
    blk_ub = jnp.clip(m_ub - 1, 0, nb - 1)
    # one fused rank call for both bounds (same decode program, doubled batch)
    nq = blk_lb.shape[0]
    lt2, eq2 = _c_rank(cidx, jnp.concatenate([blk_lb, blk_ub]),
                       jnp.concatenate([p_terms, p_terms]),
                       jnp.concatenate([target, target]), sec, cont=True,
                       use_kernels=use_kernels, qblock=qblock)
    lb = jnp.where(valid, blk_lb * b + lt2[:nq], 0)
    ub = jnp.where(valid, blk_ub * b + lt2[nq:] + eq2[nq:], 0)
    n_distinct = (ub - lb).astype(jnp.uint32)
    # one gather off the decoded cumsum cache -- the resident EF structure
    # stays the at-rest format, but the hot path never pays per-batch EF
    # select/decode work (this select_many was the top-k latency gap)
    mass = jnp.take(cidx.cumsum_cache, jnp.concatenate([ub, lb]))
    total = mass[:nq] - mass[nq:]
    offs = lb[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    in_group = offs < ub[:, None]
    safe = jnp.minimum(offs, cidx.size - 1)
    terms = jnp.where(in_group,
                      extract_bits(cidx.cont_last_packed, safe, cidx.term_bits),
                      0)
    counts = jnp.where(in_group,
                       extract_bits(cidx.cont_counts_packed, safe,
                                    cidx.count_width), 0)
    return n_distinct, total, terms, counts


@partial(jax.jit, static_argnames=("use_kernels", "qblock"))
def lookup_packed(idx: NGramIndex, q_lanes: jax.Array, q_len: jax.Array,
                  valid: jax.Array, *, use_kernels: bool = False,
                  qblock: int = 256,
                  q_terms: jax.Array | None = None) -> jax.Array:
    """Point counts [Q] uint32 for pre-packed queries (the serving hot path).

    ``qblock`` tiles the compressed block-decode kernel's query grid (a TPU
    tuning knob; the jnp ref path ignores it).  ``q_terms`` lets callers that
    already hold the cleaned term matrix skip the lane unpack on the
    compressed path -- for valid rows ``unpack(pack(g)) == g`` exactly and
    invalid rows are masked, so answers are bit-identical either way.
    """
    if isinstance(idx, CompressedNGramIndex):
        return _c_lookup_packed(idx, q_lanes, q_len, valid,
                                use_kernels=use_kernels, qblock=qblock,
                                q_terms=q_terms)
    lead = packing.lead_term(q_lanes[:, 0], vocab_size=idx.vocab_size)
    lo, hi = _bracket(idx, idx.fanout, q_len, lead)
    pos = _search(idx, idx.lanes, q_lanes, lo, hi, upper=False,
                  use_kernels=use_kernels)
    safe = jnp.minimum(pos, idx.size - 1)
    hit = (pos < hi) & jnp.all(idx.lanes[safe] == q_lanes, axis=1) & valid
    return jnp.where(hit, idx.counts[safe], 0)


@partial(jax.jit, static_argnames=("use_kernels", "qblock"))
def _lookup_single(idx: NGramIndex, grams: jax.Array, lengths: jax.Array,
                   *, use_kernels: bool = False,
                   qblock: int = 256) -> jax.Array:
    """One-segment :func:`lookup` (jitted; the pre-generational entry point)."""
    grams, lengths, valid = _clean(idx, grams, lengths, lo_len=1)
    q_lanes = packing.pack_terms(grams, vocab_size=idx.vocab_size)
    return lookup_packed(idx, q_lanes, lengths, valid, use_kernels=use_kernels,
                         qblock=qblock, q_terms=grams)


_U32_MAX = np.iinfo(np.uint32).max


def lookup_deferred(idx, grams, lengths, *, use_kernels: bool = False) -> list:
    """Dispatch :func:`lookup` without materializing: per-segment device arrays.

    The async serving half-pair: submit a batch now, fold it with
    :func:`collect_lookup` one batch later, and jax's async dispatch overlaps
    the device work of every live segment with the host's handling of the
    previous batch -- no ``block_until_ready`` anywhere.
    """
    if isinstance(idx, GenerationalIndex):
        return [_lookup_single(ix, grams, lengths, use_kernels=use_kernels)
                for ix in idx.segments]
    return [_lookup_single(idx, grams, lengths, use_kernels=use_kernels)]


def collect_lookup(parts: list, n: int) -> np.ndarray:
    """Materialize + fold deferred per-segment lookups -> [n] uint32.

    The cross-segment sum runs in int64 and refuses loudly if a total
    overflows the uint32 result lane -- the query-time mirror of the merge
    fold's guard (``index/merge.py``), so a gram whose evidence is split
    across segments can never serve a silently wrapped count.
    """
    acc = np.zeros((n,), np.int64)
    for p in parts:
        acc += np.asarray(p).astype(np.int64, copy=False)
    if acc.size and int(acc.max()) > _U32_MAX:
        raise ValueError(
            f"summed cf {int(acc.max())} across live segments overflows "
            "uint32; compact the index or raise tau")
    return acc.astype(np.uint32)


def lookup(idx, grams, lengths, *, use_kernels: bool = False):
    """Collection frequencies [Q] uint32 of raw query grams [Q, sigma].

    Misses (gram absent / below tau / malformed) return 0 -- exactly the oracle's
    ``counts.get(gram, 0)`` for frequent-gram stores.  ``idx`` may be a single
    frozen index (either layout) or a :class:`GenerationalIndex`, whose answer
    is the sum of cf over live segments (a gram ingested twice has its evidence
    split across segments until compaction folds it).
    """
    if not isinstance(idx, GenerationalIndex):
        return _lookup_single(idx, grams, lengths, use_kernels=use_kernels)
    segs = idx.segments
    if len(segs) == 1:
        return _lookup_single(segs[0], grams, lengths, use_kernels=use_kernels)
    return collect_lookup(lookup_deferred(idx, grams, lengths,
                                          use_kernels=use_kernels),
                          np.asarray(grams).shape[0])


@partial(jax.jit, static_argnames=("k", "use_kernels", "qblock"))
def continuations_packed(idx: NGramIndex, p_lanes: jax.Array, p_len: jax.Array,
                         valid: jax.Array, *, k: int,
                         use_kernels: bool = False, qblock: int = 256,
                         p_terms: jax.Array | None = None):
    """Top-k completions for pre-packed prefixes (see :func:`continuations`).

    ``qblock``/``p_terms`` as in :func:`lookup_packed`."""
    if isinstance(idx, CompressedNGramIndex):
        return _c_continuations_packed(idx, p_lanes, p_len, valid, k=k,
                                       use_kernels=use_kernels, qblock=qblock,
                                       p_terms=p_terms)
    lead = packing.lead_term(p_lanes[:, 0], vocab_size=idx.vocab_size)
    target_len = p_len + 1
    lo, hi = _bracket(idx, idx.cont_fanout, target_len, lead)
    lb = _search(idx, idx.cont_prefix, p_lanes, lo, hi, upper=False,
                 use_kernels=use_kernels)
    ub = _search(idx, idx.cont_prefix, p_lanes, lo, hi, upper=True,
                 use_kernels=use_kernels)
    lb = jnp.where(valid, lb, 0)
    ub = jnp.where(valid, ub, 0)
    n_distinct = (ub - lb).astype(jnp.uint32)
    total = idx.cont_cumsum[ub] - idx.cont_cumsum[lb]
    offs = lb[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    in_group = offs < ub[:, None]
    safe = jnp.minimum(offs, idx.size - 1)
    terms = jnp.where(in_group, idx.cont_last[safe], 0)
    counts = jnp.where(in_group, idx.cont_counts[safe], 0)
    return n_distinct, total, terms, counts


@partial(jax.jit, static_argnames=("k", "use_kernels", "qblock"))
def _continuations_single(idx: NGramIndex, prefixes: jax.Array,
                          p_len: jax.Array, *, k: int,
                          use_kernels: bool = False, qblock: int = 256):
    """One-segment :func:`continuations` (jitted)."""
    prefixes, p_len, valid = _clean(idx, prefixes, p_len, lo_len=0)
    valid = valid & (p_len <= idx.sigma - 1)
    p_lanes = packing.pack_terms(prefixes, vocab_size=idx.vocab_size)
    return continuations_packed(idx, p_lanes, p_len, valid, k=k,
                                use_kernels=use_kernels, qblock=qblock,
                                p_terms=prefixes)


def generational_continuation_sets(segments, fetch, *, k: int):
    """Certified-complete per-segment continuation answers + the fetch width.

    The cross-segment fold is only exact if every segment's *entire*
    continuation set of every queried prefix was fetched, so the driver ladders
    the fetch width: ask for top-m, check the returned (exact) n_distinct
    against m, and double on any miss -- the retry-with-more-headroom idiom the
    shuffle capacity already uses.  ``fetch(segment, m)`` returns the standard
    (nd, total, terms, counts) tuple; this helper is shared by the local path
    here and the sharded path in ``serve.py``.
    """
    m = max(int(k), 1)
    while True:
        per = [tuple(np.asarray(x) for x in fetch(ix, m)) for ix in segments]
        max_nd = max((int(p[0].max()) if p[0].size else 0 for p in per),
                     default=0)
        if max_nd <= m:
            return per, m
        m = max(m * 2, 1 << (max_nd - 1).bit_length())


def continuations(idx, prefixes, p_len, *, k: int, use_kernels: bool = False):
    """Top-k next-token completions of each prefix [Q, sigma] (len in 0..sigma-1).

    Returns (n_distinct [Q], total [Q], terms [Q, k], counts [Q, k]): the number
    of distinct frequent continuations, their total mass (sum of cf over ALL
    continuations, not just the top k), and the k highest-cf (next_term, cf)
    pairs, count-descending, zero-padded.  Both are over the index's frequent
    grams (cf >= tau), i.e. the continuation statistics a backoff LM or
    completion ranker reads.

    ``idx`` may be a :class:`GenerationalIndex`: per-segment candidate sets are
    fetched complete (see :func:`generational_continuation_sets`) and folded
    exactly -- per-term counts summed across segments, ranked (cf desc, term
    asc), the same tie order the continuation view stores.
    """
    if not isinstance(idx, GenerationalIndex):
        return _continuations_single(idx, prefixes, p_len, k=k,
                                     use_kernels=use_kernels)
    segs = idx.segments
    qn = np.asarray(prefixes).shape[0]
    if not segs:
        return (np.zeros((qn,), np.uint32), np.zeros((qn,), np.uint32),
                np.zeros((qn, k), np.uint32), np.zeros((qn, k), np.uint32))
    if len(segs) == 1:
        return _continuations_single(segs[0], prefixes, p_len, k=k,
                                     use_kernels=use_kernels)
    per, _ = generational_continuation_sets(
        segs, lambda ix, m: _continuations_single(ix, prefixes, p_len, k=m,
                                                  use_kernels=use_kernels),
        k=k)
    return merge_continuation_results(per, k=k)
