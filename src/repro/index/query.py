"""Batched queries against a frozen :class:`~repro.index.build.NGramIndex`.

All entry points are jitted, operate on whole query batches, and are branchless
inside (fixed-iteration binary searches; misses and invalid queries resolve to
count 0 / empty completion lists through masks, never through control flow), so
one compiled program serves any traffic mix.

Query plan (both views):

  1. length + lead-term bucket -> [lo, hi) bracket from the fanout table (O(1));
  2. lexicographic lower/upper bound on the packed lanes inside the bracket --
     ``use_kernels=True`` routes the search through the Pallas ``bsearch`` kernel
     (``repro.kernels.ops``), else the pure-jnp ``ref`` path (same contract);
  3. gather counts / top-k continuation rows at the found positions.

Validity rules: a query gram must have 1 <= len <= sigma, all terms in 1..vocab
before the PAD tail, and nothing after it.  Continuation prefixes allow len 0
(top-k unigrams) on a single-device index; the sharded server requires len >= 1
(shards partition by lead term -- see ``serve.py``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.mapreduce import pack as packing
from .build import NGramIndex, search_steps


def _search(idx: NGramIndex, view: jax.Array, q_lanes: jax.Array, lo: jax.Array,
            hi: jax.Array, *, upper: bool, use_kernels: bool) -> jax.Array:
    steps = search_steps(idx.size)
    if use_kernels:
        from repro.kernels import ops as kops
        return kops.bsearch(view, q_lanes, lo, hi, upper=upper, steps=steps)
    from repro.kernels import ref as kref
    return kref.bsearch_ref(view, q_lanes, lo, hi, upper=upper, steps=steps)


def _bracket(idx: NGramIndex, table: jax.Array, length: jax.Array,
             lead: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[lo, hi) rows of the (length, lead-term bucket) fanout cell."""
    sec = jnp.clip(length - 1, 0, idx.sigma - 1)
    b = jnp.clip((lead >> jnp.uint32(idx.fanout_shift)).astype(jnp.int32),
                 0, idx.n_fanout - 1)
    return table[sec, b], table[sec, b + 1]


def _clean(idx: NGramIndex, grams: jax.Array, lengths: jax.Array,
           lo_len: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(masked grams, lengths, valid): zero the PAD tail, validate term ranges."""
    grams = grams.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    in_len = jnp.arange(idx.sigma, dtype=jnp.int32)[None, :] < lengths[:, None]
    grams = grams * in_len
    ok_terms = jnp.all(jnp.where(in_len, (grams >= 1) & (grams <= idx.vocab_size),
                                 True), axis=1)
    valid = (lengths >= lo_len) & (lengths <= idx.sigma) & ok_terms
    return grams, lengths, valid


@partial(jax.jit, static_argnames=("use_kernels",))
def lookup_packed(idx: NGramIndex, q_lanes: jax.Array, q_len: jax.Array,
                  valid: jax.Array, *, use_kernels: bool = False) -> jax.Array:
    """Point counts [Q] uint32 for pre-packed queries (the serving hot path)."""
    lead = packing.lead_term(q_lanes[:, 0], vocab_size=idx.vocab_size)
    lo, hi = _bracket(idx, idx.fanout, q_len, lead)
    pos = _search(idx, idx.lanes, q_lanes, lo, hi, upper=False,
                  use_kernels=use_kernels)
    safe = jnp.minimum(pos, idx.size - 1)
    hit = (pos < hi) & jnp.all(idx.lanes[safe] == q_lanes, axis=1) & valid
    return jnp.where(hit, idx.counts[safe], 0)


@partial(jax.jit, static_argnames=("use_kernels",))
def lookup(idx: NGramIndex, grams: jax.Array, lengths: jax.Array,
           *, use_kernels: bool = False) -> jax.Array:
    """Collection frequencies [Q] uint32 of raw query grams [Q, sigma].

    Misses (gram absent / below tau / malformed) return 0 -- exactly the oracle's
    ``counts.get(gram, 0)`` for frequent-gram stores.
    """
    grams, lengths, valid = _clean(idx, grams, lengths, lo_len=1)
    q_lanes = packing.pack_terms(grams, vocab_size=idx.vocab_size)
    return lookup_packed(idx, q_lanes, lengths, valid, use_kernels=use_kernels)


@partial(jax.jit, static_argnames=("k", "use_kernels"))
def continuations_packed(idx: NGramIndex, p_lanes: jax.Array, p_len: jax.Array,
                         valid: jax.Array, *, k: int,
                         use_kernels: bool = False):
    """Top-k completions for pre-packed prefixes (see :func:`continuations`)."""
    lead = packing.lead_term(p_lanes[:, 0], vocab_size=idx.vocab_size)
    target_len = p_len + 1
    lo, hi = _bracket(idx, idx.cont_fanout, target_len, lead)
    lb = _search(idx, idx.cont_prefix, p_lanes, lo, hi, upper=False,
                 use_kernels=use_kernels)
    ub = _search(idx, idx.cont_prefix, p_lanes, lo, hi, upper=True,
                 use_kernels=use_kernels)
    lb = jnp.where(valid, lb, 0)
    ub = jnp.where(valid, ub, 0)
    n_distinct = (ub - lb).astype(jnp.uint32)
    total = idx.cont_cumsum[ub] - idx.cont_cumsum[lb]
    offs = lb[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    in_group = offs < ub[:, None]
    safe = jnp.minimum(offs, idx.size - 1)
    terms = jnp.where(in_group, idx.cont_last[safe], 0)
    counts = jnp.where(in_group, idx.cont_counts[safe], 0)
    return n_distinct, total, terms, counts


@partial(jax.jit, static_argnames=("k", "use_kernels"))
def continuations(idx: NGramIndex, prefixes: jax.Array, p_len: jax.Array,
                  *, k: int, use_kernels: bool = False):
    """Top-k next-token completions of each prefix [Q, sigma] (len in 0..sigma-1).

    Returns (n_distinct [Q], total [Q], terms [Q, k], counts [Q, k]): the number
    of distinct frequent continuations, their total mass (sum of cf over ALL
    continuations, not just the top k), and the k highest-cf (next_term, cf)
    pairs, count-descending, zero-padded.  Both are over the index's frequent
    grams (cf >= tau), i.e. the continuation statistics a backoff LM or
    completion ranker reads.
    """
    prefixes, p_len, valid = _clean(idx, prefixes, p_len, lo_len=0)
    valid = valid & (p_len <= idx.sigma - 1)
    p_lanes = packing.pack_terms(prefixes, vocab_size=idx.vocab_size)
    return continuations_packed(idx, p_lanes, p_len, valid, k=k,
                                use_kernels=use_kernels)
