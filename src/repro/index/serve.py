"""Sharded query serving over a mesh -- the MapReduce shuffle run in reverse.

The job-side shuffle routes *records* to reducers by hash(lead term)
(``mapreduce.shuffle``, the paper's Algorithm-4 partitioner).  Serving routes
*queries* the same way: ``build_sharded_index`` partitions the frozen index rows
with the identical hash, so shard p of the index holds exactly the grams reducer
p would have emitted, every query's answer lives on one known shard, and -- since
all continuations of a prefix share its lead term -- top-k completion queries
route identically to point lookups.

One serving step inside ``shard_map`` is the dispatch pattern inverted:

  partition  queries by hash(lead term)          (shuffle.partition_ids)
  bucketize  into the [P, capacity, W] buffer    (shuffle.bucketize)
  all_to_all queries to their owning shard       (shuffle.exchange)
  answer     locally (index/query.py, optionally the Pallas bsearch kernel)
  all_to_all results back along the same route
  scatter    results to each query's original slot (carried as a meta lane)

Capacity is the same head-room knob as the job shuffle: overflow is counted,
never dropped, and the driver retries with doubled capacity.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.stats import NGramStats
from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from .build import NGramIndex, build_index
from .compress import compress_index
from .merge import (GenerationalIndex, merge_continuation_results,
                    segment_to_stats)
from . import query as q


@dataclasses.dataclass(frozen=True)
class ShardedNGramIndex:
    """An :class:`NGramIndex` per mesh slice, stacked on a sharded leading axis."""

    index: NGramIndex          # every array leaf is [P, ...], sharded on dim 0
    mesh: jax.sharding.Mesh
    axis_name: str
    # compiled serving steps keyed by (mode, k, capacity, use_kernels), plus
    # the cached empty-prefix merge vector keyed by ("empty_prefix", k,
    # use_kernels); lives on the instance so it dies with the index (no stale
    # cross-index hits)
    _servers: dict = dataclasses.field(default_factory=dict, repr=False,
                                       compare=False)

    @property
    def n_parts(self) -> int:
        return self.mesh.shape[self.axis_name]

    @property
    def sigma(self) -> int:
        return self.index.sigma


def shard_of_rows(first_terms: np.ndarray, n_parts: int) -> np.ndarray:
    """Owning shard per gram row -- identical to the job shuffle's partitioner."""
    h = shuffle.hash_u32(jnp.asarray(first_terms, jnp.uint32))
    return np.asarray(h % jnp.uint32(n_parts), np.int64)


def build_sharded_index(stats: NGramStats, *, vocab_size: int, mesh,
                        axis_name: str = "data", compress: bool = False,
                        block_size: int = 4) -> ShardedNGramIndex:
    """Partition ``stats`` rows by hash(lead term) and freeze one index per shard.

    Shards are padded to a common capacity so they stack into single [P, ...]
    arrays that ``device_put`` lays out along the mesh axis.  ``compress=True``
    re-encodes every shard into the front-coded + Elias-Fano layout
    (``repro.index.compress``); a first pass measures each shard's stream sizes
    and bit widths, then all shards are re-encoded against the maxima so the
    compressed pytrees share one treedef (static meta) and stack like the
    uncompressed ones.
    """
    n_parts = mesh.shape[axis_name]
    part = shard_of_rows(np.asarray(stats.grams)[:, 0] if len(stats) else
                         np.zeros((0,), np.int64), n_parts)
    shard_stats = []
    for p in range(n_parts):
        m = part == p
        shard_stats.append(NGramStats(stats.grams[m], stats.lengths[m],
                                      stats.counts[m]))
    cap = max(128, -(-(max(len(s) for s in shard_stats) + 1) // 128) * 128)
    shards = [build_index(s, vocab_size=vocab_size, pad_to=cap)
              for s in shard_stats]
    if compress:
        probe = [compress_index(s, block_size=block_size) for s in shards]
        shards = [compress_index(
            s, block_size=block_size,
            count_width=max(c.count_width for c in probe),
            payload_words=max(c.payload.shape[0] for c in probe),
            cont_payload_words=max(c.cont_payload.shape[0] for c in probe),
            cumsum_universe=max(c.ef_cumsum.universe for c in probe),
            head_span=max(c.head_span for c in probe),
        ) for s in shards]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *shards)
    stacked = jax.device_put(stacked, NamedSharding(mesh, P(axis_name)))
    return ShardedNGramIndex(stacked, mesh, axis_name)


@dataclasses.dataclass(frozen=True)
class ShardedGenerationalIndex:
    """One :class:`ShardedNGramIndex` per live generational segment.

    The PR-2 stacking trick (probe pass forcing common static meta so shard
    pytrees stack) applies per segment; across segments sizes differ wildly
    (that is the point of generations), so the segment axis stays a host-side
    tuple and the cross-segment fold runs on the host -- same split as the
    single-device generational path in ``query.py``.
    """

    shards: tuple          # one ShardedNGramIndex per segment, newest first
    generation: int
    mesh: jax.sharding.Mesh
    axis_name: str
    # identity tokens of the generational levels each shard stack was built
    # from (GenerationalIndex.level_ids), plus the (compress, block_size)
    # layout the build used -- together the reuse key for incremental
    # re-sharding (see shard_generational's ``prev``)
    level_ids: tuple = ()
    layout: tuple = ()

    @property
    def n_segments(self) -> int:
        return len(self.shards)

    @property
    def sigma(self) -> int:
        return self.shards[0].sigma

    @property
    def nbytes(self) -> int:
        return sum(s.index.nbytes for s in self.shards)


def shard_generational(gen: GenerationalIndex, *, mesh, axis_name: str = "data",
                       compress: bool | None = None,
                       block_size: int | None = None,
                       prev: ShardedGenerationalIndex | None = None,
                       ) -> ShardedGenerationalIndex:
    """Partition every live segment of ``gen`` over the mesh.

    Layout defaults follow the generational index's own (``compress`` /
    ``block_size``); each segment gets its own probe-passed sharded build, so
    per-segment shard stacks keep a common treedef while segments of different
    generations keep their own capacities.  Empty segments (a generation
    bootstrapped from an empty job, or indexes built before
    ``GenerationalIndex.ingest`` started dropping empty deltas) are skipped
    when a non-empty one exists: an all-sentinel shard stack would cost every
    query batch a full hash-routed round trip to add zeros.

    ``prev`` (a ShardedGenerationalIndex built from an earlier generation of
    the *same* index) makes the re-shard incremental: levels are immutable and
    carry stable identity tokens (``gen.level_ids``), so any level whose id
    appears in ``prev`` reuses its already-built shard stack verbatim --
    including its compiled server cache -- and only new/merged levels pay the
    partition + build + (optional) compress pass.  A small delta over a big
    base then re-shards at O(delta) instead of O(total).  Reuse is skipped
    when the mesh, axis, or layout differ.
    """
    if not gen.segments:
        raise ValueError("cannot shard an empty GenerationalIndex")
    compress = gen.compress if compress is None else compress
    block_size = gen.block_size if block_size is None else block_size
    layout = (bool(compress), int(block_size))
    cache: dict = {}
    if (prev is not None and prev.mesh is mesh
            and prev.axis_name == axis_name and prev.layout == layout):
        cache = dict(zip(prev.level_ids, prev.shards))
    ids = gen.level_ids
    pairs = [(lid, ix) for lid, ix in zip(ids, gen.segments) if ix.n_rows] or \
        [(ids[0], gen.segments[0])]
    reused = sum(lid in cache for lid, _ in pairs)
    with obs_trace.span("serve.shard_generational") as sp:
        if sp:
            sp.set(segments=len(pairs), reused=reused)
        shards = tuple(
            cache[lid] if lid in cache else
            build_sharded_index(segment_to_stats(ix.to_segment()),
                                vocab_size=gen.vocab_size, mesh=mesh,
                                axis_name=axis_name, compress=compress,
                                block_size=block_size)
            for lid, ix in pairs)
    reg = obs_metrics.get_registry()
    if reg:
        reg.counter("serve.shard_builds").add(len(pairs) - reused)
        reg.counter("serve.shard_reuses").add(reused)
    return ShardedGenerationalIndex(shards=shards, generation=gen.generation,
                                    mesh=mesh, axis_name=axis_name,
                                    level_ids=tuple(lid for lid, _ in pairs),
                                    layout=layout)


def describe_topology(index_like) -> dict:
    """JSON-able shard/segment map -- the frontend's ``/v1/system/topology``.

    Accepts any serving-side index shape and reports how queries route to
    data: the generational segment stack (newest first, with stable level
    ids so clients can diff generations), and for sharded layouts the mesh
    partitioning -- every query's answer lives on shard
    ``hash_u32(lead_term) % n_parts``, the job shuffle's own partitioner, so
    publishing ``n_parts`` + the partitioner name is a complete routing
    contract for an external router.
    """
    if isinstance(index_like, ShardedGenerationalIndex):
        return {
            "kind": "sharded_generational",
            "generation": int(index_like.generation),
            "n_parts": int(index_like.n_parts),
            "axis": index_like.axis_name,
            "partitioner": "hash_u32(lead_term) % n_parts",
            "nbytes": int(index_like.nbytes),
            "segments": [{"level_id": int(lid),
                          "nbytes": int(sh.index.nbytes)}
                         for lid, sh in zip(index_like.level_ids,
                                            index_like.shards)],
        }
    if isinstance(index_like, ShardedNGramIndex):
        return {
            "kind": "sharded",
            "n_parts": int(index_like.n_parts),
            "axis": index_like.axis_name,
            "partitioner": "hash_u32(lead_term) % n_parts",
            "nbytes": int(index_like.index.nbytes),
        }
    if isinstance(index_like, GenerationalIndex):
        return {
            "kind": "generational",
            "generation": int(index_like.generation),
            "n_segments": int(index_like.n_segments),
            "n_rows": int(index_like.n_rows),
            "nbytes": int(index_like.nbytes),
            "compress": bool(index_like.compress),
            "segments": [{"level_id": int(lid), "rows": int(ix.n_rows),
                          "nbytes": int(ix.nbytes)}
                         for lid, ix in zip(index_like.level_ids,
                                            index_like.segments)],
        }
    # single frozen index (flat or compressed): one segment, no routing
    return {"kind": "index", "rows": int(index_like.n_rows),
            "nbytes": int(index_like.nbytes)}


def result_width(mode: str, k: int) -> int:
    """uint32 result lanes per query: cf, or n_distinct|total|terms[k]|counts[k]."""
    return 1 if mode == "lookup" else 2 + 2 * k


def make_server(sharded: ShardedNGramIndex, *, mode: str = "lookup", k: int = 8,
                capacity: int = 64, use_kernels: bool = False):
    """Compile one serving step: (grams [P, B_local, sigma], lengths [P, B_local])
    -> (results [P, B_local, R_out] uint32, global overflow count).

    ``mode``: "lookup" (point cf) or "continuations" (top-k completion); the
    compiled step needs length >= 1 either way (routing hashes the lead term).
    Length-0 prefixes are handled outside the step by :func:`serve` via the
    host-side cross-shard merge (:func:`empty_prefix_continuations`).
    """
    if mode not in ("lookup", "continuations"):
        raise ValueError(f"unknown serve mode {mode!r}")
    mesh, axis_name = sharded.mesh, sharded.axis_name
    n_parts = sharded.n_parts
    idx_meta = sharded.index
    n_l, sigma = idx_meta.n_lanes, idx_meta.sigma
    r_out = result_width(mode, k)

    def step(idx_tree, grams, lengths):
        idx = jax.tree_util.tree_map(lambda a: a[0], idx_tree)
        grams, lengths = grams[0], lengths[0]          # [B_local, sigma], [B_local]
        b_local = grams.shape[0]
        grams, lengths, valid = q._clean(idx, grams, lengths, lo_len=1)
        if mode == "continuations":
            valid = valid & (lengths <= sigma - 1)
        lanes = packing.pack_terms(grams, vocab_size=idx.vocab_size)
        lead = grams[:, 0].astype(jnp.uint32)
        slot = jnp.arange(b_local, dtype=jnp.uint32)
        records = jnp.concatenate(
            [lanes, lengths.astype(jnp.uint32)[:, None], slot[:, None],
             valid.astype(jnp.uint32)[:, None]], axis=1)
        part = shuffle.partition_ids(lead, valid, n_parts)
        buf, overflow = shuffle.bucketize(records, part, n_parts, capacity)
        slot_map = buf[:, :, n_l + 1].reshape(-1)       # local send-side bookkeeping
        sent = buf[:, :, n_l + 2].reshape(-1) > 0
        remote = shuffle.exchange(buf, axis_name)       # [P*cap, W] queries to answer
        r_lanes = remote[:, :n_l]
        r_len = remote[:, n_l].astype(jnp.int32)
        r_valid = remote[:, n_l + 2] > 0
        if mode == "lookup":
            cf = q.lookup_packed(idx, r_lanes, r_len, r_valid,
                                 use_kernels=use_kernels)
            res = cf[:, None]
        else:
            nd, tot, terms, counts = q.continuations_packed(
                idx, r_lanes, r_len, r_valid, k=k, use_kernels=use_kernels)
            res = jnp.concatenate([nd[:, None], tot[:, None], terms, counts],
                                  axis=1)
        res = res.astype(jnp.uint32).reshape(n_parts, capacity, r_out)
        back = jax.lax.all_to_all(res, axis_name, split_axis=0, concat_axis=0)
        back = back.reshape(-1, r_out)                  # aligned with sent buffer
        tgt = jnp.where(sent, slot_map, b_local).astype(jnp.int32)
        out = jnp.zeros((b_local, r_out), jnp.uint32).at[tgt].set(back,
                                                                  mode="drop")
        return out[None], jax.lax.psum(overflow, axis_name)

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(P(axis_name), P(axis_name, None, None), P(axis_name, None)),
        out_specs=(P(axis_name), P()), check_vma=False)
    return jax.jit(fn)


def empty_prefix_continuations(sharded: ShardedNGramIndex, *, k: int = 8,
                               use_kernels: bool = False) -> np.ndarray:
    """Merged empty-prefix (unigram top-k) answer [2+2k] uint32.

    The hash-routed serving step cannot answer length-0 prefixes (there is no
    lead term to route by), but every unigram lives on exactly one shard, so the
    cross-shard merge is exact: each shard reports its local top-k over the
    length-1 section, the host sums the disjoint distinct/mass totals and keeps
    the k best (term id breaks count ties, deterministically).  Any global top-k
    unigram is a fortiori in its own shard's top-k, so k rows per shard suffice.
    """
    sigma = sharded.sigma
    pg = np.zeros((1, sigma), np.int32)
    pl = np.zeros((1,), np.int32)
    n_distinct = 0
    total = 0
    pairs: list[tuple[int, int]] = []
    for p in range(sharded.n_parts):
        idx_p = jax.tree_util.tree_map(lambda a: a[p], sharded.index)
        nd, tot, terms, counts = q.continuations(idx_p, pg, pl, k=k,
                                                 use_kernels=use_kernels)
        n_distinct += int(np.asarray(nd)[0])
        total += int(np.asarray(tot)[0])
        for t, c in zip(np.asarray(terms)[0], np.asarray(counts)[0]):
            if c > 0:
                pairs.append((int(c), int(t)))
    pairs.sort(key=lambda tc: (-tc[0], tc[1]))
    out = np.zeros((2 + 2 * k,), np.uint32)
    out[0], out[1] = n_distinct, total
    for i, (c, t) in enumerate(pairs[:k]):
        out[2 + i] = t
        out[2 + k + i] = c
    return out


def _cached_server(sharded: ShardedNGramIndex, mode: str, k: int, capacity: int,
                   use_kernels: bool):
    """Compiled serving step for this index + static config (a micro-batching
    frontend calls serve() per batch; the program is reusable)."""
    key = (mode, k, capacity, use_kernels)
    if key not in sharded._servers:
        sharded._servers[key] = make_server(sharded, mode=mode, k=k,
                                            capacity=capacity,
                                            use_kernels=use_kernels)
    return sharded._servers[key]


def _serve_generational(sharded: ShardedGenerationalIndex, grams, lengths, *,
                        mode: str, k: int, **kw) -> np.ndarray:
    """Cross-segment fold of per-segment sharded answers (host side).

    Point lookups sum cf over segments; continuation queries fetch each
    segment's complete candidate set (the same certified ladder as the local
    generational path) and fold exactly.  Each per-segment answer still rides
    the full hash-routed all_to_all machinery of :func:`serve`.
    """
    from .query import generational_continuation_sets

    if mode == "lookup":
        acc = np.zeros((np.asarray(grams).shape[0],), np.int64)
        for sh in sharded.shards:
            acc += serve(sh, grams, lengths, mode="lookup", **kw) \
                .astype(np.int64)
        if acc.size and int(acc.max()) > np.iinfo(np.uint32).max:
            raise ValueError(
                f"summed cf {int(acc.max())} across live segments overflows "
                "uint32; compact the index or raise tau")
        return acc.astype(np.uint32)

    def fetch(sh, m):
        res = serve(sh, grams, lengths, mode="continuations", k=m, **kw)
        return res[:, 0], res[:, 1], res[:, 2:2 + m], res[:, 2 + m:]

    per, _ = generational_continuation_sets(sharded.shards, fetch, k=k)
    nd, total, terms, counts = merge_continuation_results(per, k=k)
    return np.concatenate([nd[:, None], total[:, None], terms, counts],
                          axis=1).astype(np.uint32)


def serve(sharded, grams, lengths, *, mode: str = "lookup",
          k: int = 8, capacity_factor: float = 2.0, use_kernels: bool = False,
          max_retries: int = 6) -> np.ndarray:
    """Answer one query batch on the mesh, retrying on shuffle overflow.

    grams [B, sigma], lengths [B] (host or device).  Returns uint32 [B] counts
    (mode "lookup") or [B, 2+2k] packed continuation results (see
    :func:`result_width`).  Hash routing balances Zipf-skewed lead terms the same
    way the job shuffle does; ``capacity_factor`` is the head-room knob.

    Length-0 continuation prefixes (unigram top-k) cannot be hash-routed; they
    are answered once via the host-side cross-shard merge
    (:func:`empty_prefix_continuations`, cached on the index -- the answer is a
    pure function of (index, k)) and broadcast into their slots, so the sharded
    path accepts the same query mix as the single-device one.

    ``sharded`` may also be a :class:`ShardedGenerationalIndex`: every live
    segment is served through this same path and the answers fold on the host
    (sum for lookups, exact candidate-set merge for continuations).
    """
    if isinstance(sharded, ShardedGenerationalIndex):
        return _serve_generational(sharded, grams, lengths, mode=mode, k=k,
                                   capacity_factor=capacity_factor,
                                   use_kernels=use_kernels,
                                   max_retries=max_retries)
    n_parts = sharded.n_parts
    grams = np.asarray(grams)
    lengths = np.asarray(lengths)
    empty = (np.asarray(lengths) == 0) if mode == "continuations" else \
        np.zeros(lengths.shape, bool)
    b = grams.shape[0]
    b_local = -(-b // n_parts)
    pad = b_local * n_parts - b
    g = np.pad(grams, ((0, pad), (0, 0))).reshape(n_parts, b_local, -1)
    ln = np.pad(lengths, (0, pad)).reshape(n_parts, b_local)
    # b_local rows per (src, dst) pair is always enough -- the clamp makes small
    # batches retry-free while big batches keep the factor*B/P head-room sizing
    capacity = min(b_local, max(8, int(capacity_factor * b_local / n_parts) + 1))
    reg = obs_metrics.get_registry()
    with obs_trace.span("serve.batch") as sp:
        if sp:
            sp.set(mode=mode, batch=b, parts=n_parts)
        t0 = time.perf_counter()
        for attempt in range(max_retries):
            server = _cached_server(sharded, mode, k, capacity, use_kernels)
            out, overflow = server(sharded.index, jnp.asarray(g, jnp.int32),
                                   jnp.asarray(ln, jnp.int32))
            if int(overflow) == 0:
                break
            capacity *= 2
        else:
            raise RuntimeError(
                f"query shuffle overflow persisted at {capacity}")
        if sp:
            sp.set(retries=attempt, capacity=capacity)
        if reg:
            reg.counter("serve.batches").add(1)
            reg.counter("serve.queries").add(b)
            reg.counter("serve.retries").add(attempt)
            reg.histogram("serve.batch_seconds").observe(
                time.perf_counter() - t0)
    # np.array (not asarray): the device buffer view is read-only and the
    # empty-prefix overlay below writes into rows
    out = np.array(out).reshape(n_parts * b_local, -1)[:b]
    if empty.any():
        key = ("empty_prefix", k, use_kernels)
        if key not in sharded._servers:
            sharded._servers[key] = empty_prefix_continuations(
                sharded, k=k, use_kernels=use_kernels)
        out[empty] = sharded._servers[key]
    return out[:, 0] if mode == "lookup" else out
