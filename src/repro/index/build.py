"""Freeze a finished SUFFIX-sigma job into a device-resident, queryable index.

The job leaves an ``NGramStats`` blob -- (gram, cf) rows in arbitrary order -- whose
only lookup path is a Python dict.  Following Pibiri & Venturini's observation that
the post-job win is a *sorted, compressed, immutable* layout, the build is split in
two along the line the generational (LSM-style) index composes over:

  * :func:`segment_from_stats` packs the rows into the shuffle/sort phases' own
    packed-lane record format (``mapreduce.pack``) and sorts them with the same
    multi-key lexicographic sort (``mapreduce.sort``) into an
    :class:`IndexSegment` -- the sorted immutable run of (length | lanes, cf)
    rows that is the unit of merge (``index/merge.py``);
  * :func:`index_from_segment` derives the acceleration structures from any
    sorted segment, whether it came from a job or from a k-way merge of older
    segments:

      - **per-length sections** -- ``section_start[l]`` delimits the length-(l+1)
        section, so a point query binary-searches only rows of its own length;
      - **first-term fanout table** -- within each section, rows of equal lead
        term are contiguous, so ``fanout[l-1, b] .. fanout[l-1, b+1]`` brackets
        the rows whose lead-term bucket is ``b`` (Lemire & Kaser's "one hash
        narrows the hot path", as a monotone table instead of a filter);
      - the **continuation view** -- the same rows re-ordered by (|gram|, packed
        *prefix* lanes, cf desc, next term asc), plus the running-mass
        ``cont_cumsum``.  The final next-term key makes the order a pure
        function of the row *set* (not of input order), which is what lets a
        merged segment rebuild bit-identical structures to a from-scratch build.

``build_index`` is their composition.  Everything is a flat jnp array
(registered dataclass pytrees), so artifacts can be ``device_put`` whole,
stacked along a leading shard axis (``serve.py``), and closed over by jitted
query functions.  Counts are stored as uint32 on device (cf <= total tokens;
the int64 path stays on the host-side ``NGramStats``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsearch import search_steps  # re-export: queries need it
from repro.mapreduce import pack as packing
from repro.mapreduce import sort
from repro.core.stats import NGramStats
from ._layout import (MAX_FANOUT, SENTINEL, fanout_layout, pad_rows,
                      round_capacity, row_bytes_view, row_offsets)

_SENTINEL = SENTINEL   # backwards-compat alias (pre-_layout name)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IndexSegment:
    """One sorted immutable run of n-gram rows -- the unit of merge.

    Rows are sorted by (length | packed lanes); rows 0..n_rows-1 are real, the
    tail is all-ones sentinels that sort after every real row.  Both
    :class:`NGramIndex` (which stores a segment verbatim plus derived
    structures) and :class:`~repro.index.compress.CompressedNGramIndex` (which
    re-encodes one, and decodes back via ``to_segment``) wrap this abstraction;
    ``index/merge.py`` consumes and produces it.
    """

    keys: jax.Array    # [size, 1+L] uint32: (row length | packed gram lanes)
    counts: jax.Array  # [size] uint32 collection frequencies (0 on sentinels)
    sigma: int = dataclasses.field(metadata=dict(static=True))
    vocab_size: int = dataclasses.field(metadata=dict(static=True))

    @property
    def size(self) -> int:
        return int(self.keys.shape[-2])

    @property
    def n_lanes(self) -> int:
        return int(self.keys.shape[-1]) - 1

    @property
    def lanes(self) -> jax.Array:
        """Packed gram lanes [..., size, L] (the length column stripped)."""
        return self.keys[..., 1:]

    @property
    def n_rows(self) -> int:
        """Real (non-sentinel) rows; the length column is the primary sort key,
        so one host-side searchsorted recovers the boundary.  Cached on first
        read (segments are immutable; compaction polls row counts per ingest,
        which would otherwise re-sync the device per poll)."""
        cached = self.__dict__.get("_n_rows")
        if cached is None:
            lens = np.asarray(self.keys[..., 0])
            cached = int(np.searchsorted(lens, self.sigma, side="right"))
            object.__setattr__(self, "_n_rows", cached)
        return cached

    @property
    def nbytes(self) -> int:
        return sum(int(np.asarray(f).nbytes) for f in (self.keys, self.counts))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NGramIndex:
    """Immutable device-resident n-gram index (see module docstring).

    Wraps the point-lookup :class:`IndexSegment` (rows sorted by (length, lex
    packed lanes); sentinel tail) plus the derived acceleration structures.
    """

    # --- point-lookup view: the sorted segment itself ----------------------------
    segment: IndexSegment
    section_start: jax.Array  # [sigma+1] int32: section l+1 = rows [s[l], s[l+1])
    fanout: jax.Array         # [sigma, n_fanout+1] int32 lead-term bucket offsets
    # --- continuation view: rows sorted by (length, prefix lanes, cf desc) -------
    cont_prefix: jax.Array    # [size, L] uint32 packed lanes of the length-1 prefix
    cont_last: jax.Array      # [size]    uint32 final term of each gram
    cont_counts: jax.Array    # [size]    uint32 cf, descending within prefix group
    cont_fanout: jax.Array    # [sigma, n_fanout+1] int32 prefix-lead bucket offsets
    cont_cumsum: jax.Array    # [size+1]  uint32 running sum of cont_counts
    # --- static meta (part of the treedef; identical across shards) --------------
    sigma: int = dataclasses.field(metadata=dict(static=True))
    vocab_size: int = dataclasses.field(metadata=dict(static=True))
    size: int = dataclasses.field(metadata=dict(static=True))
    fanout_shift: int = dataclasses.field(metadata=dict(static=True))
    n_fanout: int = dataclasses.field(metadata=dict(static=True))

    @property
    def lanes(self) -> jax.Array:
        """[..., size, L] uint32 packed gram lanes (the segment's, sans length)."""
        return self.segment.lanes

    @property
    def counts(self) -> jax.Array:
        """[..., size] uint32 collection frequencies."""
        return self.segment.counts

    @property
    def n_lanes(self) -> int:
        # last axis, so the property also holds for a [P, size, L] sharded stack
        return self.segment.n_lanes

    @property
    def n_rows(self) -> int:
        """Real (non-sentinel) rows; the last section end."""
        return int(self.section_start[-1])

    @property
    def nbytes(self) -> int:
        return self.segment.nbytes + sum(int(np.asarray(f).nbytes) for f in (
            self.section_start, self.fanout,
            self.cont_prefix, self.cont_last, self.cont_counts,
            self.cont_fanout, self.cont_cumsum))

    def to_segment(self) -> IndexSegment:
        """The point-view segment (shared arrays, no copy)."""
        return self.segment


def segment_from_stats(stats: NGramStats, *, vocab_size: int,
                       pad_to: int | None = None) -> IndexSegment:
    """Sort a finished job's rows into an :class:`IndexSegment`.

    Bucketed (time-series) counts are marginalized -- segments carry cf.
    ``pad_to`` fixes the padded capacity (default rounds R+1 up to 128).
    """
    grams = np.asarray(stats.grams, np.int32)
    lengths = np.asarray(stats.lengths, np.int32)
    counts = np.asarray(stats.counts)
    if counts.ndim == 2:
        counts = counts.sum(axis=1)
    counts = counts.astype(np.uint32)
    r, sigma = grams.shape
    size = pad_to if pad_to is not None else round_capacity(r)
    if size < r + 1:
        raise ValueError(f"pad_to={size} < n_rows+1={r + 1}")

    lanes = packing.pack_terms(jnp.asarray(grams), vocab_size=vocab_size)
    keys = jnp.concatenate([jnp.asarray(lengths, jnp.uint32)[:, None], lanes],
                           axis=1)
    keys_s, (counts_s,) = sort.sort_with_payload(keys, [jnp.asarray(counts)])
    return IndexSegment(
        keys=jnp.asarray(pad_rows(np.asarray(keys_s, np.uint32), size,
                                  SENTINEL)),
        counts=jnp.asarray(pad_rows(np.asarray(counts_s, np.uint32), size, 0)),
        sigma=sigma, vocab_size=vocab_size)


def segment_from_wave_stats(stats: NGramStats, *,
                            vocab_size: int) -> IndexSegment:
    """Freeze one wave's partial into a sorted segment without a device trip.

    The single-device wave collector emits rows in reducer order: for every
    gram length, ascending packed lanes (the reducer walks the sorted record
    block).  A *stable* argsort on the length column alone -- a sigma-way
    counting sort, not a general sort -- therefore recovers full
    (length | packed lanes) segment order, and the final stable byte-view
    argsort degenerates to a linear verification pass (timsort on sorted
    input).  Rows from collectors without the ordering guarantee (e.g.
    hash-partitioned mesh partials) are genuinely sorted by that same pass.
    Everything runs in numpy (``pack_terms_np``), so the per-wave freeze
    costs ~a millisecond instead of an eager device pack+sort+transfer
    chain.

    The result is host-resident and unpadded (no sentinel tail) -- exactly
    what the k-way fold consumes; ``IndexSegment.n_rows`` still answers
    correctly, and any route of :func:`~repro.index.merge.merge_segments`
    accepts it.
    """
    grams = np.asarray(stats.grams, np.int32)
    lengths = np.asarray(stats.lengths, np.uint32)
    counts = np.asarray(stats.counts)
    if counts.ndim == 2:
        counts = counts.sum(axis=1)
    counts = counts.astype(np.uint32)
    sigma = int(grams.shape[1])
    lanes = packing.pack_terms_np(grams, vocab_size=vocab_size)
    keys = np.concatenate([lengths[:, None], lanes], axis=1).astype(np.uint32)
    order = np.argsort(keys[:, 0], kind="stable")
    keys = keys[order]
    counts = counts[order]
    full = np.argsort(row_bytes_view(keys), kind="stable")
    return IndexSegment(keys=keys[full], counts=counts[full], sigma=sigma,
                        vocab_size=vocab_size)


def index_from_segment(seg: IndexSegment, *,
                       pad_to: int | None = None) -> NGramIndex:
    """Derive the acceleration structures of a sorted segment -- the shared back
    half of ``build_index`` and of every incremental merge (``index/merge.py``),
    which is what makes merged and from-scratch indexes bit-identical.
    """
    sigma, vocab_size = seg.sigma, seg.vocab_size
    r = seg.n_rows
    keys = np.asarray(seg.keys)[:r]
    counts_s = np.asarray(seg.counts)[:r]
    len_s = keys[:, 0].astype(np.int64)
    lanes_s = keys[:, 1:]
    shift, n_fanout = fanout_layout(vocab_size)
    size = pad_to if pad_to is not None else round_capacity(r)
    if size < r + 1:
        raise ValueError(f"pad_to={size} < n_rows+1={r + 1}")

    grams = np.asarray(packing.unpack_terms(
        jnp.asarray(lanes_s), vocab_size=vocab_size, sigma=sigma)) \
        if r else np.zeros((0, sigma), np.int32)
    lead_s = grams[:, 0].astype(np.uint32)
    # combined (length, bucket) key is monotone: length is the primary sort key
    # and the lead term sits in lane 0's most-significant bits
    combined = len_s * n_fanout + (lead_s.astype(np.int64) >> shift)
    section_start = row_offsets(len_s, np.arange(1, sigma + 2))
    grid = (np.arange(1, sigma + 1)[:, None] * n_fanout
            + np.arange(n_fanout + 1)[None, :])
    fanout = np.minimum(row_offsets(combined, grid.reshape(-1)).reshape(
        sigma, n_fanout + 1), section_start[1:][:, None]).astype(np.int32)

    # ---- continuation view: (length | prefix lanes | cf desc | next term) -------
    # the trailing next-term key breaks (prefix, cf) ties deterministically, so
    # the view depends only on the row *set* -- merge parity leans on this
    lengths = len_s.astype(np.int32)
    prefix = grams * (np.arange(sigma)[None, :] < (lengths - 1)[:, None])
    p_lanes = packing.pack_terms(jnp.asarray(prefix), vocab_size=vocab_size)
    last = grams[np.arange(r), np.maximum(lengths - 1, 0)].astype(np.uint32) \
        if r else np.zeros((0,), np.uint32)
    p_lead = prefix[:, 0].astype(np.uint32)
    ckeys = jnp.concatenate([jnp.asarray(lengths, jnp.uint32)[:, None],
                             p_lanes,
                             (~jnp.asarray(counts_s)).astype(jnp.uint32)[:, None],
                             jnp.asarray(last)[:, None]],
                            axis=1)
    n_l = seg.n_lanes
    ckeys_s, (c_counts_s, c_lead_s) = sort.sort_with_payload(
        ckeys, [jnp.asarray(counts_s), jnp.asarray(p_lead)])
    ckeys_s = np.asarray(ckeys_s)
    cp_lanes_s = ckeys_s[:, 1:1 + n_l]
    c_last_s = ckeys_s[:, 2 + n_l]
    c_combined = (ckeys_s[:, 0].astype(np.int64) * n_fanout
                  + (np.asarray(c_lead_s, np.int64) >> shift))
    cont_fanout = np.minimum(row_offsets(c_combined, grid.reshape(-1)).reshape(
        sigma, n_fanout + 1), section_start[1:][:, None]).astype(np.int32)
    # running mass in int64 first: the total over all rows is ~sigma x corpus
    # tokens and can exceed uint32 even when every individual cf fits.  A wrap
    # would silently corrupt continuation totals, so refuse loudly instead --
    # sharding the index (serve.py) divides the mass per shard.
    mass = np.cumsum(np.asarray(c_counts_s, np.int64))
    if r and mass[-1] > np.iinfo(np.uint32).max:
        raise ValueError(
            f"total continuation mass {int(mass[-1])} overflows the uint32 "
            "device cumsum; build the index sharded (build_sharded_index) or "
            "raise tau")
    cont_cumsum = np.zeros((size + 1,), np.uint32)
    cont_cumsum[1:r + 1] = mass.astype(np.uint32)
    if r:
        cont_cumsum[r + 1:] = cont_cumsum[r]

    return NGramIndex(
        segment=IndexSegment(
            keys=jnp.asarray(pad_rows(keys.astype(np.uint32), size, SENTINEL)),
            counts=jnp.asarray(pad_rows(counts_s.astype(np.uint32), size, 0)),
            sigma=sigma, vocab_size=vocab_size),
        section_start=jnp.asarray(section_start),
        fanout=jnp.asarray(fanout),
        cont_prefix=jnp.asarray(pad_rows(cp_lanes_s.astype(np.uint32), size,
                                         SENTINEL)),
        cont_last=jnp.asarray(pad_rows(c_last_s.astype(np.uint32), size, 0)),
        cont_counts=jnp.asarray(pad_rows(np.asarray(c_counts_s, np.uint32),
                                         size, 0)),
        cont_fanout=jnp.asarray(cont_fanout),
        cont_cumsum=jnp.asarray(cont_cumsum),
        sigma=sigma, vocab_size=vocab_size, size=size,
        fanout_shift=shift, n_fanout=n_fanout,
    )


def build_index(stats: NGramStats, *, vocab_size: int,
                pad_to: int | None = None) -> NGramIndex:
    """Freeze ``stats`` (a finished job's output) into an :class:`NGramIndex`.

    ``pad_to`` fixes the padded row capacity (sharded builds pass a common
    capacity so shards stack into one array).
    """
    return index_from_segment(
        segment_from_stats(stats, vocab_size=vocab_size, pad_to=pad_to),
        pad_to=pad_to)
