"""Freeze a finished SUFFIX-sigma job into a device-resident, queryable index.

The job leaves an ``NGramStats`` blob -- (gram, cf) rows in arbitrary order -- whose
only lookup path is a Python dict.  Following Pibiri & Venturini's observation that
the post-job win is a *sorted, compressed, immutable* layout, ``build_index``
re-packs the rows into the same packed-lane record format the shuffle/sort phases
use (``mapreduce.pack``), sorted with the same multi-key lexicographic sort
(``mapreduce.sort``), and adds two acceleration structures:

  * **per-length sections** -- rows ordered by (|gram|, lex); ``section_start[l]``
    delimits the length-(l+1) section, so a point query binary-searches only the
    rows of its own length;
  * **first-term fanout table** -- within each section, rows of equal lead term are
    contiguous (the lead term occupies the most-significant bits of lane 0), so
    ``fanout[l-1, b] .. fanout[l-1, b+1]`` brackets the rows whose lead-term bucket
    is ``b``.  This cuts the binary search from log2(R) to log2(rows-per-bucket)
    probes -- the "one-hash narrows the hot path" idea of Lemire & Kaser, realized
    as a monotone table instead of a probabilistic filter (exactness matters: the
    index must return cf, not membership).

A second view of the same rows -- the **continuation view** -- is ordered by
(|gram|, packed *prefix* lanes, cf desc).  Rows extending a common prefix are
contiguous AND sorted by count, so top-k next-token completion is two binary
searches plus a k-row gather; the per-section running sum (``cont_cumsum``) gives
the total continuation mass of a prefix in O(1).

Everything is a flat jnp array (registered dataclass pytree), so the artifact can
be ``device_put`` whole, stacked along a leading shard axis (``serve.py``), and
closed over by jitted query functions.  Counts are stored as uint32 on device
(cf <= total tokens; the int64 path stays on the host-side ``NGramStats``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsearch import search_steps  # re-export: queries need it
from repro.mapreduce import pack as packing
from repro.mapreduce import sort
from repro.core.stats import NGramStats

MAX_FANOUT = 4096   # fanout table columns per length section (memory/probe trade)
_SENTINEL = np.uint32(0xFFFFFFFF)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NGramIndex:
    """Immutable device-resident n-gram index (see module docstring).

    Rows 0..n_rows-1 are real; rows n_rows..size-1 are all-ones sentinels that sort
    after every real row (binary searches never land on them inside a section).
    """

    # --- point-lookup view: rows sorted by (length, lex packed lanes) ------------
    lanes: jax.Array          # [size, L] uint32 packed gram lanes
    counts: jax.Array         # [size]    uint32 collection frequencies
    section_start: jax.Array  # [sigma+1] int32: section l+1 = rows [s[l], s[l+1])
    fanout: jax.Array         # [sigma, n_fanout+1] int32 lead-term bucket offsets
    # --- continuation view: rows sorted by (length, prefix lanes, cf desc) -------
    cont_prefix: jax.Array    # [size, L] uint32 packed lanes of the length-1 prefix
    cont_last: jax.Array      # [size]    uint32 final term of each gram
    cont_counts: jax.Array    # [size]    uint32 cf, descending within prefix group
    cont_fanout: jax.Array    # [sigma, n_fanout+1] int32 prefix-lead bucket offsets
    cont_cumsum: jax.Array    # [size+1]  uint32 running sum of cont_counts
    # --- static meta (part of the treedef; identical across shards) --------------
    sigma: int = dataclasses.field(metadata=dict(static=True))
    vocab_size: int = dataclasses.field(metadata=dict(static=True))
    size: int = dataclasses.field(metadata=dict(static=True))
    fanout_shift: int = dataclasses.field(metadata=dict(static=True))
    n_fanout: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_lanes(self) -> int:
        # last axis, so the property also holds for a [P, size, L] sharded stack
        return int(self.lanes.shape[-1])

    @property
    def n_rows(self) -> int:
        """Real (non-sentinel) rows; the last section end."""
        return int(self.section_start[-1])

    @property
    def nbytes(self) -> int:
        return sum(int(np.asarray(f).nbytes) for f in (
            self.lanes, self.counts, self.section_start, self.fanout,
            self.cont_prefix, self.cont_last, self.cont_counts,
            self.cont_fanout, self.cont_cumsum))


def fanout_layout(vocab_size: int) -> tuple[int, int]:
    """(shift, n_buckets): lead term t maps to bucket t >> shift, monotonically."""
    shift = 0
    while ((vocab_size + 1) >> shift) > MAX_FANOUT:
        shift += 1
    n_buckets = ((vocab_size + 1) >> shift) + 1
    return shift, n_buckets


def _pad_rows(a: np.ndarray, size: int, fill) -> np.ndarray:
    pad = [(0, size - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad, constant_values=fill)


def _offsets(sorted_key: np.ndarray, queries: np.ndarray) -> np.ndarray:
    return np.searchsorted(sorted_key, queries, side="left").astype(np.int32)


def build_index(stats: NGramStats, *, vocab_size: int,
                pad_to: int | None = None) -> NGramIndex:
    """Freeze ``stats`` (a finished job's output) into an :class:`NGramIndex`.

    ``pad_to`` fixes the padded row capacity (sharded builds pass a common
    capacity so shards stack into one array); default rounds R+1 up to 128.
    Bucketed (time-series) counts are marginalized -- the index serves cf.
    """
    grams = np.asarray(stats.grams, np.int32)
    lengths = np.asarray(stats.lengths, np.int32)
    counts = np.asarray(stats.counts)
    if counts.ndim == 2:
        counts = counts.sum(axis=1)
    counts = counts.astype(np.uint32)
    r, sigma = grams.shape
    n_l = packing.n_lanes(sigma, vocab_size)
    shift, n_fanout = fanout_layout(vocab_size)
    size = pad_to if pad_to is not None else max(128, -(-(r + 1) // 128) * 128)
    if size < r + 1:
        raise ValueError(f"pad_to={size} < n_rows+1={r + 1}")

    lanes = np.asarray(packing.pack_terms(jnp.asarray(grams),
                                          vocab_size=vocab_size), np.uint32)
    lead = grams[:, 0].astype(np.uint32)

    # ---- point-lookup view: one lexicographic sort on (length | lanes) ----------
    keys = jnp.concatenate([jnp.asarray(lengths, jnp.uint32)[:, None],
                            jnp.asarray(lanes)], axis=1)
    keys_s, (counts_s, lead_s) = sort.sort_with_payload(
        keys, [jnp.asarray(counts), jnp.asarray(lead)])
    keys_s = np.asarray(keys_s)
    len_s = keys_s[:, 0].astype(np.int64)
    lanes_s = keys_s[:, 1:]
    # combined (length, bucket) key is monotone: length is the primary sort key and
    # the lead term sits in lane 0's most-significant bits
    combined = len_s * n_fanout + (np.asarray(lead_s, np.int64) >> shift)
    section_start = _offsets(len_s, np.arange(1, sigma + 2))
    grid = (np.arange(1, sigma + 1)[:, None] * n_fanout
            + np.arange(n_fanout + 1)[None, :])
    fanout = np.minimum(_offsets(combined, grid.reshape(-1)).reshape(
        sigma, n_fanout + 1), section_start[1:][:, None]).astype(np.int32)

    # ---- continuation view: (length | prefix lanes | cf desc) -------------------
    prefix = grams * (np.arange(sigma)[None, :] < (lengths - 1)[:, None])
    p_lanes = np.asarray(packing.pack_terms(jnp.asarray(prefix),
                                            vocab_size=vocab_size), np.uint32)
    last = grams[np.arange(r), np.maximum(lengths - 1, 0)].astype(np.uint32) \
        if r else np.zeros((0,), np.uint32)
    p_lead = prefix[:, 0].astype(np.uint32)
    ckeys = jnp.concatenate([jnp.asarray(lengths, jnp.uint32)[:, None],
                             jnp.asarray(p_lanes),
                             (~jnp.asarray(counts)).astype(jnp.uint32)[:, None]],
                            axis=1)
    ckeys_s, (c_last_s, c_counts_s, c_lead_s) = sort.sort_with_payload(
        ckeys, [jnp.asarray(last), jnp.asarray(counts), jnp.asarray(p_lead)])
    ckeys_s = np.asarray(ckeys_s)
    cp_lanes_s = ckeys_s[:, 1:1 + n_l]
    c_combined = (ckeys_s[:, 0].astype(np.int64) * n_fanout
                  + (np.asarray(c_lead_s, np.int64) >> shift))
    cont_fanout = np.minimum(_offsets(c_combined, grid.reshape(-1)).reshape(
        sigma, n_fanout + 1), section_start[1:][:, None]).astype(np.int32)
    # running mass in int64 first: the total over all rows is ~sigma x corpus
    # tokens and can exceed uint32 even when every individual cf fits.  A wrap
    # would silently corrupt continuation totals, so refuse loudly instead --
    # sharding the index (serve.py) divides the mass per shard.
    mass = np.cumsum(np.asarray(c_counts_s, np.int64))
    if r and mass[-1] > np.iinfo(np.uint32).max:
        raise ValueError(
            f"total continuation mass {int(mass[-1])} overflows the uint32 "
            "device cumsum; build the index sharded (build_sharded_index) or "
            "raise tau")
    cont_cumsum = np.zeros((size + 1,), np.uint32)
    cont_cumsum[1:r + 1] = mass.astype(np.uint32)
    if r:
        cont_cumsum[r + 1:] = cont_cumsum[r]

    return NGramIndex(
        lanes=jnp.asarray(_pad_rows(lanes_s, size, _SENTINEL)),
        counts=jnp.asarray(_pad_rows(np.asarray(counts_s, np.uint32), size, 0)),
        section_start=jnp.asarray(section_start),
        fanout=jnp.asarray(fanout),
        cont_prefix=jnp.asarray(_pad_rows(cp_lanes_s, size, _SENTINEL)),
        cont_last=jnp.asarray(_pad_rows(np.asarray(c_last_s, np.uint32), size, 0)),
        cont_counts=jnp.asarray(_pad_rows(np.asarray(c_counts_s, np.uint32),
                                          size, 0)),
        cont_fanout=jnp.asarray(cont_fanout),
        cont_cumsum=jnp.asarray(cont_cumsum),
        sigma=sigma, vocab_size=vocab_size, size=size,
        fanout_shift=shift, n_fanout=n_fanout,
    )
