"""Compressed index layout: front-coded blocks + Elias-Fano monotone structures.

The frozen :class:`~repro.index.build.NGramIndex` stores every row's packed
lanes verbatim; past VMEM-resident shard sizes that is the dominant cost.
Following Pibiri & Venturini (*Handling Massive N-Gram Datasets Efficiently*),
the sorted immutable layout admits two classic compressors, both implemented
here in device-decodable form:

**Front-coded blocks.**  Rows are cut into fixed ``block_size`` blocks.  Each
block stores its first row verbatim (the *head*, kept bit-packed in lane form so
the existing lexicographic binary search runs on heads unchanged) and every
other row as ``(lcp, suffix terms)`` against its predecessor: ``lcp`` values ride
in a nibble/byte stream, suffix terms in a ``bits_for_vocab``-wide stream, and a
per-block base offset (cumulative suffix-term count) replaces per-row pointers
-- in-block offsets are a prefix sum of ``store_len - lcp``, which the decoder
recomputes on the fly.  Prefix sharing is measured at build time with the same
``lcp_boundary`` kernel the SUFFIX-sigma reducer uses.

**Elias-Fano.**  Every monotone structure the query plan reads (section
starts, the continuation fanout table, ``cont_cumsum``) is split into
unary-coded high bits (uint32 words plus a per-word rank directory) and packed
low bits; ``select`` is a branchless
fixed-trip-count search over the rank directory plus an in-word popcount scan,
so bracket lookups and continuation-mass queries stay jittable and batched.

Row order, sentinel padding, and tie-breaks are inherited *exactly* from the
uncompressed index -- ``compress_index`` is a pure re-encoding, which is what
makes bit-exact differential testing against :class:`NGramIndex` possible (see
``tests/test_compress.py``; a silently corrupted count would otherwise hide
behind plausible-looking output).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitpack import extract_bits, pack_bits
from repro.mapreduce import pack as packing
from repro.core.stats import NGramStats
from repro.kernels.bsearch import search_steps
from ._layout import SENTINEL, pad_rows, row_lengths
from .build import IndexSegment, NGramIndex, build_index


# --------------------------------------------------------------------------- #
# Elias-Fano
# --------------------------------------------------------------------------- #

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EliasFano:
    """Monotone non-decreasing uint sequence in ~(2 + log2(U/n)) bits/value.

    ``high`` holds the unary upper parts (one i sits at bit ``i + (v_i >> l)``),
    ``word_rank`` the cumulative popcount per high word (the select directory),
    ``low`` the packed ``low_bits``-wide lower parts.
    """

    low: jax.Array        # [lw] uint32 packed low bits
    high: jax.Array       # [hw] uint32 unary high bits
    word_rank: jax.Array  # [hw+1] uint32 cumulative popcount of ``high``
    n: int = dataclasses.field(metadata=dict(static=True))
    low_bits: int = dataclasses.field(metadata=dict(static=True))
    universe: int = dataclasses.field(metadata=dict(static=True))

    @staticmethod
    def encode(values: np.ndarray, universe: int | None = None) -> "EliasFano":
        v = np.asarray(values, np.uint64)
        n = int(v.shape[0])
        if n == 0:
            raise ValueError("cannot Elias-Fano encode an empty sequence")
        if np.any(np.diff(v.astype(np.int64)) < 0):
            raise ValueError("sequence is not monotone non-decreasing")
        u = int(v.max()) if universe is None else int(universe)
        if u < int(v.max()):
            raise ValueError(f"universe {u} < max value {int(v.max())}")
        l = max(0, int(math.floor(math.log2(max(u, 1) / n))) if u > n else 0)
        l = min(l, 31)
        low = pack_bits((v & np.uint64((1 << l) - 1)).astype(np.uint32), l)
        ones = np.arange(n, dtype=np.uint64) + (v >> np.uint64(l))
        n_bits = n + (u >> l) + 1
        hw = max(1, -(-n_bits // 32))
        high = np.zeros((hw,), np.uint32)
        np.bitwise_or.at(high, (ones >> np.uint64(5)).astype(np.int64),
                         np.uint32(1) << (ones & np.uint64(31)).astype(np.uint32))
        pop = np.array([bin(int(w)).count("1") for w in high], np.uint32)
        word_rank = np.zeros((hw + 1,), np.uint32)
        word_rank[1:] = np.cumsum(pop, dtype=np.uint32)
        return EliasFano(jnp.asarray(low), jnp.asarray(high),
                         jnp.asarray(word_rank), n=n, low_bits=l, universe=u)

    def select(self, i: jax.Array) -> jax.Array:
        """Values [*i.shape] uint32 at positions ``i`` (0 <= i < n), jit-safe."""
        i = i.astype(jnp.uint32)
        # word holding the i-th one: last w with word_rank[w] <= i
        w = (jnp.searchsorted(self.word_rank, i, side="right") - 1).astype(jnp.int32)
        w = jnp.clip(w, 0, self.high.shape[0] - 1)
        rank_in = i - jnp.take(self.word_rank, w)
        word = jnp.take(self.high, w)
        bits = (word[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
        cum = jnp.cumsum(bits, axis=-1)
        bitpos = jnp.sum((cum <= rank_in[..., None]).astype(jnp.uint32), axis=-1)
        one_pos = w.astype(jnp.uint32) * 32 + bitpos
        high_val = one_pos - i
        low_val = extract_bits(self.low, i, self.low_bits)
        return (high_val << jnp.uint32(self.low_bits)) | low_val

    def decode_all(self) -> jax.Array:
        """All n values [n] uint32 in one pass over the high words.

        The batched-select fast path: a query batch issuing more selects than
        ~n/32 amortizes this whole-table decode (O(high words + n) work, and a
        *transient* buffer -- the resident layout stays compressed) and then
        reads answers with one plain gather each, instead of paying a
        rank-directory search per query.
        """
        hw = self.high.shape[0]
        j = jnp.arange(32, dtype=jnp.uint32)
        bits = (self.high[:, None] >> j[None, :]) & jnp.uint32(1)    # [hw, 32]
        pos = jnp.arange(hw, dtype=jnp.uint32)[:, None] * 32 + j
        # compact the one-positions by sorting (ones first, position order kept):
        # XLA lowers sort far better than the equivalent scatter on every
        # backend we serve from
        masked = jnp.where(bits > 0, pos, jnp.uint32(0xFFFFFFFF)).reshape(-1)
        one_pos = jax.lax.sort(masked)[:self.n]
        high_val = one_pos - jnp.arange(self.n, dtype=jnp.uint32)
        low_val = extract_bits(self.low, jnp.arange(self.n), self.low_bits)
        return (high_val << jnp.uint32(self.low_bits)) | low_val

    def select_many(self, i: jax.Array) -> jax.Array:
        """:meth:`select`, but batch-adaptive: whole-decode + gather when the
        (static) batch size amortizes it, per-query directory search when not."""
        if self.n <= 64 * int(np.prod(i.shape)):
            return jnp.take(self.decode_all(), jnp.clip(i, 0, self.n - 1))
        return self.select(i)

    @property
    def nbytes(self) -> int:
        return sum(int(np.asarray(a).nbytes)
                   for a in (self.low, self.high, self.word_rank))


# --------------------------------------------------------------------------- #
# Compressed index
# --------------------------------------------------------------------------- #

def lcp_width_for(sigma: int) -> int:
    """Nibble for sigma <= 14, byte beyond: lcp values never straddle a word."""
    if sigma <= 14:
        return 4
    if sigma <= 254:
        return 8
    raise ValueError(f"sigma {sigma} out of supported range")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressedNGramIndex:
    """Front-coded + Elias-Fano re-encoding of an :class:`NGramIndex`.

    Same logical rows in the same order (sentinels included); every query path
    must answer bit-identically to the uncompressed index.
    """

    # --- point-lookup view -------------------------------------------------- #
    heads: jax.Array         # [nb, 1+L] uint32 (row length | packed head lanes)
    lcps: jax.Array          # packed lcp stream, lcp_width bits/row
    payload: jax.Array       # packed suffix-term stream, term_bits bits/term
    block_base: jax.Array    # [nb+1] uint32 cumulative suffix terms per block
    counts_packed: jax.Array  # packed cf stream, count_width bits/row
    ef_section: EliasFano    # section_start  (sigma+1 values, universe=size)
    # (no point-view fanout: point lookups bsearch ALL heads -- with one search
    # per query a bracket fetch costs more than the steps it saves; the
    # continuation path runs two searches per query and keeps its bracket)
    # --- continuation view -------------------------------------------------- #
    cont_heads: jax.Array        # [nb, 1+L] uint32 (gram length | prefix lanes)
    cont_lcps: jax.Array
    cont_payload: jax.Array
    cont_block_base: jax.Array
    cont_last_packed: jax.Array   # packed next-term stream, term_bits bits/row
    cont_counts_packed: jax.Array  # packed cf stream, count_width bits/row
    ef_cont_fanout: EliasFano
    ef_cumsum: EliasFano          # cont_cumsum (size+1 values)
    # --- static meta -------------------------------------------------------- #
    sigma: int = dataclasses.field(metadata=dict(static=True))
    vocab_size: int = dataclasses.field(metadata=dict(static=True))
    size: int = dataclasses.field(metadata=dict(static=True))
    fanout_shift: int = dataclasses.field(metadata=dict(static=True))
    n_fanout: int = dataclasses.field(metadata=dict(static=True))
    block_size: int = dataclasses.field(metadata=dict(static=True))
    head_span: int = dataclasses.field(metadata=dict(static=True))
    head_steps: int = dataclasses.field(metadata=dict(static=True))
    term_bits: int = dataclasses.field(metadata=dict(static=True))
    count_width: int = dataclasses.field(metadata=dict(static=True))
    lcp_width: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_lanes(self) -> int:
        return packing.n_lanes(self.sigma, self.vocab_size)

    @property
    def n_blocks(self) -> int:
        return self.size // self.block_size

    @property
    def n_rows(self) -> int:
        """Real (non-sentinel) rows; the last section end."""
        return int(np.asarray(self.ef_section.select(
            jnp.asarray([self.ef_section.n - 1]))[0]))

    @property
    def nbytes(self) -> int:
        arrays = (self.heads, self.lcps, self.payload, self.block_base,
                  self.counts_packed, self.cont_heads, self.cont_lcps,
                  self.cont_payload, self.cont_block_base,
                  self.cont_last_packed, self.cont_counts_packed)
        efs = (self.ef_section, self.ef_cont_fanout, self.ef_cumsum)
        return (sum(int(np.asarray(a).nbytes) for a in arrays)
                + sum(e.nbytes for e in efs))

    def section_starts(self) -> jax.Array:
        """Decoded [sigma+1] int32 section starts (the in-block length key)."""
        return self.ef_section.decode_all().astype(jnp.int32)

    def to_segment(self) -> IndexSegment:
        """Decode the point view back into the sorted :class:`IndexSegment`.

        The inverse of ``compress_index`` restricted to the merge-relevant rows:
        front-coded blocks decode to the exact term matrix (``decode_view``),
        which re-packs to the exact lanes -- so segments extracted from the
        compressed layout merge bit-identically to ones from the flat layout.
        """
        r = self.n_rows
        terms = decode_view(self, "point")[:r].astype(np.int32)
        lanes = np.asarray(packing.pack_terms(jnp.asarray(terms),
                                              vocab_size=self.vocab_size),
                           np.uint32)
        sec = np.asarray(self.section_starts())
        lens = row_lengths(sec, self.size)[:r].astype(np.uint32)
        keys = np.concatenate([lens[:, None], lanes], axis=1)
        counts = np.asarray(extract_bits(self.counts_packed,
                                         jnp.arange(max(r, 1)),
                                         self.count_width), np.uint32)[:r]
        return IndexSegment(
            keys=jnp.asarray(pad_rows(keys, self.size, SENTINEL)),
            counts=jnp.asarray(pad_rows(counts, self.size, 0)),
            sigma=self.sigma, vocab_size=self.vocab_size)


# shared with build/merge via index/_layout (satellite: constants dedupe)
_row_lengths = row_lengths


def _front_code(terms: np.ndarray, lanes: np.ndarray, row_len: np.ndarray,
                *, len_off: int, block_size: int, term_bits: int,
                lcp_width: int, payload_words: int | None):
    """(heads, lcps, payload, block_base) for one view.

    terms  : [size, S] int32 decoded term rows (view order, sentinels included)
    lanes  : [size, L] uint32 packed rows (head storage, for the head bsearch)
    len_off: 0 for the point view, 1 for the continuation (prefix) view --
             stored terms per row = clip(row_len - len_off, 0, S); everything
             past that is PAD and reconstructed as 0.
    """
    from repro.kernels import ops as kops
    size, sigma = terms.shape
    b = block_size
    if size % b:
        raise ValueError(f"size {size} not a multiple of block_size {b}")
    store_len = np.clip(row_len - len_off, 0, sigma).astype(np.int32)
    lcp = np.asarray(kops.lcp_boundary(jnp.asarray(terms))[0])
    lcp = np.minimum(lcp, store_len)
    lcp[0::b] = 0                      # block heads restart the coding chain
    ns = store_len - lcp
    j = np.arange(sigma)[None, :]
    stored_mask = (j >= lcp[:, None]) & (j < store_len[:, None])
    suffix = terms[stored_mask].astype(np.uint32)   # C-order: row-major ✓
    cum = np.zeros(size + 1, np.int64)
    np.cumsum(ns, out=cum[1:])
    # size % b == 0, so the stride already ends on cum[size]: [nb+1] entries
    block_base = cum[0::b].astype(np.uint32)
    payload = pack_bits(suffix, term_bits, n_words=payload_words)
    lcps = pack_bits(lcp.astype(np.uint32), lcp_width)
    heads = np.concatenate(
        [row_len[0::b].astype(np.uint32)[:, None], lanes[0::b]], axis=1)
    return heads, lcps, payload, block_base


def compress_index(idx: NGramIndex, *, block_size: int = 4,
                   count_width: int | None = None,
                   payload_words: int | None = None,
                   cont_payload_words: int | None = None,
                   cumsum_universe: int | None = None,
                   head_span: int | None = None) -> CompressedNGramIndex:
    """Re-encode ``idx`` losslessly.  The capacity overrides exist so sharded
    builds can force identical array shapes / static meta across shards
    (stacked pytrees need a common treedef)."""
    sigma, vocab, size = idx.sigma, idx.vocab_size, idx.size
    tb = packing.bits_for_vocab(vocab)
    lw = lcp_width_for(sigma)
    section_start = np.asarray(idx.section_start)
    row_len = _row_lengths(section_start, size)
    counts = np.asarray(idx.counts)
    cw = count_width if count_width is not None else \
        max(1, int(counts.max()).bit_length() if counts.size else 1)

    lanes = np.asarray(idx.lanes)
    terms = np.asarray(packing.unpack_terms(
        jnp.asarray(lanes), vocab_size=vocab, sigma=sigma))
    heads, lcps, payload, block_base = _front_code(
        terms, lanes, row_len, len_off=0, block_size=block_size,
        term_bits=tb, lcp_width=lw, payload_words=payload_words)

    c_lanes = np.asarray(idx.cont_prefix)
    c_terms = np.asarray(packing.unpack_terms(
        jnp.asarray(c_lanes), vocab_size=vocab, sigma=sigma))
    c_heads, c_lcps, c_payload, c_block_base = _front_code(
        c_terms, c_lanes, row_len, len_off=1, block_size=block_size,
        term_bits=tb, lcp_width=lw, payload_words=cont_payload_words)

    fan = np.asarray(idx.fanout, np.int64).reshape(-1)
    c_fan = np.asarray(idx.cont_fanout, np.int64).reshape(-1)
    if head_span is None:
        # widest fanout cell measured in blocks: every head-search bracket is
        # [lo // B, lo // B + head_span), so the fixed-trip head bsearch stops
        # after log2(span) instead of log2(n_blocks) steps -- the compressed
        # layout's analogue of the fanout table shrinking the row search.  The
        # +1 covers a cell straddling one extra block boundary than its row
        # count suggests.
        head_span = 1
        for t in (np.asarray(idx.fanout), np.asarray(idx.cont_fanout)):
            if t.size:
                head_span = max(head_span, int(np.max(
                    -(-t[:, 1:] // block_size) - t[:, :-1] // block_size)) + 1)
        head_span = min(head_span, size // block_size)
    cumsum = np.asarray(idx.cont_cumsum, np.int64)
    for name, seq in (("fanout", fan), ("cont_fanout", c_fan)):
        if seq.size and np.any(np.diff(seq) < 0):
            raise AssertionError(f"{name} table is not monotone when flattened")

    return CompressedNGramIndex(
        heads=jnp.asarray(heads), lcps=jnp.asarray(lcps),
        payload=jnp.asarray(payload), block_base=jnp.asarray(block_base),
        counts_packed=jnp.asarray(pack_bits(counts.astype(np.uint32), cw)),
        ef_section=EliasFano.encode(section_start, universe=size),
        cont_heads=jnp.asarray(c_heads), cont_lcps=jnp.asarray(c_lcps),
        cont_payload=jnp.asarray(c_payload),
        cont_block_base=jnp.asarray(c_block_base),
        cont_last_packed=jnp.asarray(
            pack_bits(np.asarray(idx.cont_last, np.uint32), tb)),
        cont_counts_packed=jnp.asarray(
            pack_bits(np.asarray(idx.cont_counts, np.uint32), cw)),
        ef_cont_fanout=EliasFano.encode(c_fan, universe=size),
        ef_cumsum=EliasFano.encode(
            cumsum, universe=cumsum_universe if cumsum_universe is not None
            else int(cumsum[-1])),
        sigma=sigma, vocab_size=vocab, size=size,
        fanout_shift=idx.fanout_shift, n_fanout=idx.n_fanout,
        block_size=block_size, head_span=head_span,
        head_steps=search_steps(head_span),
        term_bits=tb, count_width=cw, lcp_width=lw,
    )


def build_compressed_index(stats: NGramStats, *, vocab_size: int,
                           pad_to: int | None = None,
                           block_size: int = 4) -> CompressedNGramIndex:
    """Job output -> compressed index (freeze uncompressed, then re-encode)."""
    return compress_index(build_index(stats, vocab_size=vocab_size,
                                      pad_to=pad_to), block_size=block_size)


def decode_view(cidx: CompressedNGramIndex, view: str = "point") -> np.ndarray:
    """Reconstruct the full [size, S] term matrix of one view (host, for tests).

    Exactness here is the structural half of the parity argument: if the decode
    round-trips every row, any query mismatch must be in the search plan.
    """
    if view == "point":
        lcps, payload, base, len_off = (cidx.lcps, cidx.payload,
                                        cidx.block_base, 0)
    elif view == "cont":
        lcps, payload, base, len_off = (cidx.cont_lcps, cidx.cont_payload,
                                        cidx.cont_block_base, 1)
    else:
        raise ValueError(view)
    size, sigma, b = cidx.size, cidx.sigma, cidx.block_size
    sec = np.asarray(cidx.section_starts())
    row_len = _row_lengths(sec, size)
    store_len = np.clip(row_len - len_off, 0, sigma)
    lcp = np.asarray(extract_bits(lcps, jnp.arange(size), cidx.lcp_width)) \
        .astype(np.int64)
    ns = store_len - lcp
    total = int(np.asarray(base)[-1])
    vals = np.asarray(extract_bits(payload, jnp.arange(max(total, 1)),
                                   cidx.term_bits)).astype(np.int64)[:total]
    cum = np.zeros(size + 1, np.int64)
    np.cumsum(ns, out=cum[1:])
    j = np.arange(sigma)[None, :]
    tpos = cum[:-1, None] + (j - lcp[:, None])
    stored_mask = (j >= lcp[:, None]) & (j < store_len[:, None])
    aligned = np.where(stored_mask, vals[np.clip(tpos, 0, max(total - 1, 0))], 0)
    lcp_b = lcp.reshape(-1, b)
    aligned_b = aligned.reshape(-1, b, sigma)
    slen_b = store_len.reshape(-1, b)
    cand = np.where(lcp_b[:, :, None] <= j[None], np.arange(b)[None, :, None], -1)
    prov = np.maximum.accumulate(cand, axis=1)
    taken = np.take_along_axis(aligned_b, prov, axis=1)
    slen_p = np.take_along_axis(
        np.broadcast_to(slen_b[:, :, None], aligned_b.shape), prov, axis=1)
    out = np.where(j[None] < slen_p, taken, 0).reshape(size, sigma)
    return out.astype(np.int64)
