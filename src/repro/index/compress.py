"""Compressed index layout: front-coded blocks + Elias-Fano monotone structures.

The frozen :class:`~repro.index.build.NGramIndex` stores every row's packed
lanes verbatim; past VMEM-resident shard sizes that is the dominant cost.
Following Pibiri & Venturini (*Handling Massive N-Gram Datasets Efficiently*),
the sorted immutable layout admits two classic compressors, both implemented
here in device-decodable form:

**Front-coded blocks.**  Rows are cut into fixed ``block_size`` blocks.  Each
block stores its first row verbatim (the *head*, kept bit-packed in lane form so
the existing lexicographic binary search runs on heads unchanged) and every
other row as ``(lcp, suffix terms)`` against its predecessor: ``lcp`` values ride
in a nibble/byte stream, suffix terms in a ``bits_for_vocab``-wide stream, and a
per-block base offset (cumulative suffix-term count) replaces per-row pointers
-- in-block offsets are a prefix sum of ``store_len - lcp``, which the decoder
recomputes on the fly.  Prefix sharing is measured at build time with the same
``lcp_boundary`` kernel the SUFFIX-sigma reducer uses.

**Elias-Fano.**  Every monotone structure the query plan reads (section
starts, the continuation fanout table, ``cont_cumsum``) is split into
unary-coded high bits (uint32 words plus a per-word rank directory) and packed
low bits; ``select`` is a branchless
fixed-trip-count search over the rank directory plus an in-word popcount scan,
so bracket lookups and continuation-mass queries stay jittable and batched.

Row order, sentinel padding, and tie-breaks are inherited *exactly* from the
uncompressed index -- ``compress_index`` is a pure re-encoding, which is what
makes bit-exact differential testing against :class:`NGramIndex` possible (see
``tests/test_compress.py``; a silently corrupted count would otherwise hide
behind plausible-looking output).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitpack import extract_bits, pack_bits
from repro.mapreduce import pack as packing
from repro.core.stats import NGramStats
from repro.kernels.bsearch import search_steps
from ._layout import SENTINEL, pad_rows, row_lengths
from .build import IndexSegment, NGramIndex, build_index


# --------------------------------------------------------------------------- #
# Elias-Fano
# --------------------------------------------------------------------------- #

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EliasFano:
    """Monotone non-decreasing uint sequence in ~(2 + log2(U/n)) bits/value.

    ``high`` holds the unary upper parts (one i sits at bit ``i + (v_i >> l)``),
    ``word_rank`` the cumulative popcount per high word (the select directory),
    ``low`` the packed ``low_bits``-wide lower parts.
    """

    low: jax.Array        # [lw] uint32 packed low bits
    high: jax.Array       # [hw] uint32 unary high bits
    word_rank: jax.Array  # [hw+1] uint32 cumulative popcount of ``high``
    n: int = dataclasses.field(metadata=dict(static=True))
    low_bits: int = dataclasses.field(metadata=dict(static=True))
    universe: int = dataclasses.field(metadata=dict(static=True))

    @staticmethod
    def encode(values: np.ndarray, universe: int | None = None) -> "EliasFano":
        v = np.asarray(values, np.uint64)
        n = int(v.shape[0])
        if n == 0:
            raise ValueError("cannot Elias-Fano encode an empty sequence")
        if np.any(np.diff(v.astype(np.int64)) < 0):
            raise ValueError("sequence is not monotone non-decreasing")
        u = int(v.max()) if universe is None else int(universe)
        if u < int(v.max()):
            raise ValueError(f"universe {u} < max value {int(v.max())}")
        l = max(0, int(math.floor(math.log2(max(u, 1) / n))) if u > n else 0)
        l = min(l, 31)
        low = pack_bits((v & np.uint64((1 << l) - 1)).astype(np.uint32), l)
        ones = np.arange(n, dtype=np.uint64) + (v >> np.uint64(l))
        n_bits = n + (u >> l) + 1
        hw = max(1, -(-n_bits // 32))
        high = np.zeros((hw,), np.uint32)
        np.bitwise_or.at(high, (ones >> np.uint64(5)).astype(np.int64),
                         np.uint32(1) << (ones & np.uint64(31)).astype(np.uint32))
        pop = np.array([bin(int(w)).count("1") for w in high], np.uint32)
        word_rank = np.zeros((hw + 1,), np.uint32)
        word_rank[1:] = np.cumsum(pop, dtype=np.uint32)
        return EliasFano(jnp.asarray(low), jnp.asarray(high),
                         jnp.asarray(word_rank), n=n, low_bits=l, universe=u)

    def select(self, i: jax.Array) -> jax.Array:
        """Values [*i.shape] uint32 at positions ``i`` (0 <= i < n), jit-safe."""
        i = i.astype(jnp.uint32)
        # word holding the i-th one: last w with word_rank[w] <= i
        w = (jnp.searchsorted(self.word_rank, i, side="right") - 1).astype(jnp.int32)
        w = jnp.clip(w, 0, self.high.shape[0] - 1)
        rank_in = i - jnp.take(self.word_rank, w)
        word = jnp.take(self.high, w)
        bits = (word[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
        cum = jnp.cumsum(bits, axis=-1)
        bitpos = jnp.sum((cum <= rank_in[..., None]).astype(jnp.uint32), axis=-1)
        one_pos = w.astype(jnp.uint32) * 32 + bitpos
        high_val = one_pos - i
        low_val = extract_bits(self.low, i, self.low_bits)
        return (high_val << jnp.uint32(self.low_bits)) | low_val

    def decode_all(self) -> jax.Array:
        """All n values [n] uint32 in one pass over the high words.

        The batched-select fast path: a query batch issuing more selects than
        ~n/32 amortizes this whole-table decode (O(high words + n) work, and a
        *transient* buffer -- the resident layout stays compressed) and then
        reads answers with one plain gather each, instead of paying a
        rank-directory search per query.
        """
        hw = self.high.shape[0]
        j = jnp.arange(32, dtype=jnp.uint32)
        bits = (self.high[:, None] >> j[None, :]) & jnp.uint32(1)    # [hw, 32]
        pos = jnp.arange(hw, dtype=jnp.uint32)[:, None] * 32 + j
        # compact the one-positions by sorting (ones first, position order kept):
        # XLA lowers sort far better than the equivalent scatter on every
        # backend we serve from
        masked = jnp.where(bits > 0, pos, jnp.uint32(0xFFFFFFFF)).reshape(-1)
        one_pos = jax.lax.sort(masked)[:self.n]
        high_val = one_pos - jnp.arange(self.n, dtype=jnp.uint32)
        low_val = extract_bits(self.low, jnp.arange(self.n), self.low_bits)
        return (high_val << jnp.uint32(self.low_bits)) | low_val

    def select_many(self, i: jax.Array) -> jax.Array:
        """:meth:`select`, but batch-adaptive: whole-decode + gather when the
        (static) batch size amortizes it, per-query directory search when not.

        The crossover is deliberately tight (4 selects per value, was 64):
        ``decode_all``'s whole-table sort dominated batch-4096 lookup latency,
        and past a few selects per value the per-query directory search wins
        on every backend we measured.  Hot paths should prefer the decoded
        caches on :class:`CompressedNGramIndex` and never reach this.
        """
        if self.n <= 4 * int(np.prod(i.shape)):
            return jnp.take(self.decode_all(), jnp.clip(i, 0, self.n - 1))
        return self.select(i)

    @property
    def nbytes(self) -> int:
        return sum(int(np.asarray(a).nbytes)
                   for a in (self.low, self.high, self.word_rank))


# --------------------------------------------------------------------------- #
# Compressed index
# --------------------------------------------------------------------------- #

def lcp_width_for(sigma: int) -> int:
    """Nibble for sigma <= 14, byte beyond: lcp values never straddle a word."""
    if sigma <= 14:
        return 4
    if sigma <= 254:
        return 8
    raise ValueError(f"sigma {sigma} out of supported range")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressedNGramIndex:
    """Front-coded + Elias-Fano re-encoding of an :class:`NGramIndex`.

    Same logical rows in the same order (sentinels included); every query path
    must answer bit-identically to the uncompressed index.
    """

    # --- point-lookup view -------------------------------------------------- #
    heads: jax.Array         # [nb, HL] uint32 dense (row_len|terms) head keys
    lcps: jax.Array          # packed lcp stream, lcp_width bits/row
    payload: jax.Array       # packed suffix-term stream, term_bits bits/term
    block_base: jax.Array    # [nb+1] uint32 cumulative suffix terms per block
    counts_packed: jax.Array  # packed cf stream, count_width bits/row
    ef_section: EliasFano    # section_start  (sigma+1 values, universe=size)
    # (both views bracket their head bsearch through the decoded fanout
    # caches below; the point view's bracket rows never need EF encoding --
    # they are the flat fanout table's, divided by block_size)
    # --- continuation view -------------------------------------------------- #
    cont_heads: jax.Array        # [nb, HL] uint32 dense (gram len|prefix) keys
    cont_lcps: jax.Array
    cont_payload: jax.Array
    cont_block_base: jax.Array
    cont_last_packed: jax.Array   # packed next-term stream, term_bits bits/row
    cont_counts_packed: jax.Array  # packed cf stream, count_width bits/row
    ef_cont_fanout: EliasFano
    ef_cumsum: EliasFano          # cont_cumsum (size+1 values)
    # --- cached select directories ------------------------------------------ #
    # Deterministic decodes of the EF structures, precomputed once at build so
    # the query hot path gathers instead of paying per-batch EF select work.
    # The EFs above stay the at-rest format (``nbytes_at_rest``); these are
    # resident-only acceleration state, pure functions of the streams, so
    # merged-vs-built bit parity holds.  The fanout caches store the
    # head-search bracket *lo block* per (section, lead bucket) -- uint16 when
    # the block count allows -- which turns both views' head bsearch into the
    # fixed-``head_steps`` bracketed form.
    sec_cache: jax.Array       # [sigma+1] int32 decoded section starts
    cumsum_cache: jax.Array    # [size+1] uint32 decoded cont_cumsum
    fan_cache: jax.Array       # [sigma*(n_fanout+1)] point-view bracket blocks
    cont_fan_cache: jax.Array  # [sigma*(n_fanout+1)] cont-view bracket blocks
    # --- static meta -------------------------------------------------------- #
    sigma: int = dataclasses.field(metadata=dict(static=True))
    vocab_size: int = dataclasses.field(metadata=dict(static=True))
    size: int = dataclasses.field(metadata=dict(static=True))
    fanout_shift: int = dataclasses.field(metadata=dict(static=True))
    n_fanout: int = dataclasses.field(metadata=dict(static=True))
    block_size: int = dataclasses.field(metadata=dict(static=True))
    head_span: int = dataclasses.field(metadata=dict(static=True))
    head_steps: int = dataclasses.field(metadata=dict(static=True))
    term_bits: int = dataclasses.field(metadata=dict(static=True))
    count_width: int = dataclasses.field(metadata=dict(static=True))
    lcp_width: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_lanes(self) -> int:
        return packing.n_lanes(self.sigma, self.vocab_size)

    @property
    def n_blocks(self) -> int:
        return self.size // self.block_size

    @property
    def n_rows(self) -> int:
        """Real (non-sentinel) rows; the last section end."""
        return int(np.asarray(self.sec_cache[-1]))

    @property
    def nbytes(self) -> int:
        """Total resident bytes: the at-rest streams plus the decoded caches."""
        caches = (self.sec_cache, self.cumsum_cache, self.fan_cache,
                  self.cont_fan_cache)
        return (self.nbytes_at_rest
                + sum(int(np.asarray(a).nbytes) for a in caches))

    @property
    def nbytes_at_rest(self) -> int:
        """Bytes of the persisted compressed artifact: the front-coded /
        bit-packed streams plus the EF directories.  Excludes the decoded
        query caches, which are derived resident-only state rebuilt from the
        streams -- the number the compression-ratio contract and the
        generational ``bytes_at_rest`` gauges report."""
        arrays = (self.heads, self.lcps, self.payload, self.block_base,
                  self.counts_packed, self.cont_heads, self.cont_lcps,
                  self.cont_payload, self.cont_block_base,
                  self.cont_last_packed, self.cont_counts_packed)
        efs = (self.ef_section, self.ef_cont_fanout, self.ef_cumsum)
        return (sum(int(np.asarray(a).nbytes) for a in arrays)
                + sum(e.nbytes for e in efs))

    def section_starts(self) -> jax.Array:
        """Decoded [sigma+1] int32 section starts (the in-block length key)."""
        return self.sec_cache

    def to_segment(self) -> IndexSegment:
        """Decode the point view back into the sorted :class:`IndexSegment`.

        The inverse of ``compress_index`` restricted to the merge-relevant
        rows: :func:`decode_segment` streams the front-coded blocks back to
        the exact term matrix chunk by chunk, which re-packs to the exact
        lanes -- so segments extracted from the compressed layout merge
        bit-identically to ones from the flat layout.  (The merge path calls
        ``decode_segment`` directly and never pads back to capacity.)
        """
        seg = decode_segment(self)
        return IndexSegment(
            keys=jnp.asarray(pad_rows(np.asarray(seg.keys), self.size,
                                      SENTINEL)),
            counts=jnp.asarray(pad_rows(np.asarray(seg.counts), self.size,
                                        0)),
            sigma=self.sigma, vocab_size=self.vocab_size)


# shared with build/merge via index/_layout (satellite: constants dedupe)
_row_lengths = row_lengths

# rows decoded per chunk by decode_segment; module-level so tests can shrink
# it and assert the working-set bound
_DECODE_CHUNK_ROWS = 4096
# peak rows any single decode chunk materialized (test hook for the
# "compaction never decodes a full table" contract)
_DECODE_WATERMARK = {"rows": 0}


@partial(jax.jit, static_argnames=("term_bits", "lcp_width", "block_size",
                                   "vocab_size", "use_kernels"))
def _decode_chunk(lcps, payload, block_base, sec, ids, *, term_bits: int,
                  lcp_width: int, block_size: int, vocab_size: int,
                  use_kernels: bool):
    """Packed lanes [len(ids)*block_size, L] of the requested point blocks."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    sigma = sec.shape[0] - 1
    if use_kernels:
        terms = kops.block_expand(lcps, payload, block_base, sec, ids,
                                  sigma=sigma, term_bits=term_bits,
                                  lcp_width=lcp_width, block_size=block_size,
                                  len_off=0)
    else:
        terms = kref.block_expand_ref(lcps, payload, block_base, sec, ids,
                                      term_bits=term_bits, lcp_width=lcp_width,
                                      block_size=block_size, len_off=0)
    return packing.pack_terms(terms.reshape(-1, sigma), vocab_size=vocab_size)


def decode_segment(cidx: CompressedNGramIndex, *, chunk_rows: int | None = None,
                   use_kernels: bool = False) -> IndexSegment:
    """Stream the point view back into an **unpadded host** :class:`IndexSegment`.

    The compressed-native merge entry point: blocks decode ``chunk_rows`` rows
    at a time through one fixed-shape jitted program (the tail chunk clips
    block ids instead of recompiling), so the peak decoded working set is
    O(chunk), never the whole table.  Decode work is attributed to the metrics
    registry (``merge.blocks_decoded`` / ``compress.rows_decoded``) so any
    remaining full-table decode shows up in traces.
    """
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    b = cidx.block_size
    r = cidx.n_rows
    nb_used = -(-r // b)                       # blocks holding real rows
    cb = max(1, (chunk_rows if chunk_rows is not None
                 else _DECODE_CHUNK_ROWS) // b)
    # never wider than the table: an oversized chunk would pad ids out to the
    # requested width and decode the clamp-filler blocks over and over
    cb = min(cb, max(nb_used, 1))
    n_lanes = cidx.n_lanes
    keys = np.empty((r, 1 + n_lanes), np.uint32)
    keys[:, 0] = _row_lengths(np.asarray(cidx.sec_cache),
                              cidx.size)[:r].astype(np.uint32)
    sp = obs_trace.span("compress.decode")
    if sp:
        sp.set(rows=r, blocks=nb_used, chunk_blocks=cb)
    sp.__enter__()
    try:
        for c0 in range(0, nb_used, cb):
            ids = jnp.minimum(jnp.arange(c0, c0 + cb, dtype=jnp.int32),
                              max(cidx.n_blocks - 1, 0))
            lanes = np.asarray(_decode_chunk(
                cidx.lcps, cidx.payload, cidx.block_base, cidx.sec_cache, ids,
                term_bits=cidx.term_bits, lcp_width=cidx.lcp_width,
                block_size=b, vocab_size=cidx.vocab_size,
                use_kernels=use_kernels), np.uint32)
            lo, hi = c0 * b, min((c0 + cb) * b, r)
            keys[lo:hi, 1:] = lanes[:hi - lo]
            _DECODE_WATERMARK["rows"] = max(_DECODE_WATERMARK["rows"], cb * b)
        counts = np.asarray(extract_bits(cidx.counts_packed,
                                         jnp.arange(max(r, 1)),
                                         cidx.count_width), np.uint32)[:r]
    finally:
        sp.__exit__(None, None, None)
    reg = obs_metrics.get_registry()
    reg.counter("merge.blocks_decoded").add(nb_used)
    reg.counter("compress.rows_decoded").add(r)
    return IndexSegment(keys=keys, counts=counts, sigma=cidx.sigma,
                        vocab_size=cidx.vocab_size)


def head_key_layout(sigma: int, term_bits: int):
    """((offset, width) per field, n_lanes) of the dense head search key.

    Head rows are pure search accelerators (decode restarts from the payload
    at every block head), so they use a denser layout than the row lanes:
    (row_len, t0..t_{sigma-1}) concatenated MSB-first with no per-lane slack,
    split into uint32 lanes.  Lex order over the lanes equals lex order over
    (row_len, terms) -- the same total order the flat index sorts by -- while
    usually saving a lane per head vs the old (len | packed lanes) form:
    fewer gathers and compares per bsearch step on the hot path, and a
    smaller at-rest heads array.
    """
    len_bits = (sigma + 1).bit_length()     # row_len <= sigma+1 (sentinels)
    widths = [len_bits] + [term_bits] * sigma
    offs, o = [], 0
    for w in widths:
        offs.append(o)
        o += w
    return tuple(zip(offs, widths)), -(-o // 32)


def _pack_head_keys(row_len: np.ndarray, terms: np.ndarray,
                    *, term_bits: int) -> np.ndarray:
    """[n, HL] uint32 dense head keys (host build side of
    :func:`head_key_layout`; :func:`repro.index.query._dense_qkey` is the
    traced query side -- the two must pack bit-identically)."""
    n, sigma = terms.shape
    fields, hl = head_key_layout(sigma, term_bits)
    lanes = np.zeros((n, hl), np.uint32)
    cols = [row_len.astype(np.uint64)] + \
        [terms[:, j].astype(np.uint64) for j in range(sigma)]
    for (o, w), v in zip(fields, cols):
        v = v & np.uint64((1 << w) - 1)
        r = o + w
        j0 = o // 32
        e0 = 32 * (j0 + 1)
        if r <= e0:
            lanes[:, j0] |= (v << np.uint64(e0 - r)).astype(np.uint32)
        else:                       # field straddles a lane boundary
            lanes[:, j0] |= (v >> np.uint64(r - e0)).astype(np.uint32)
            e1 = 32 * ((r - 1) // 32 + 1)
            lanes[:, (r - 1) // 32] |= (
                (v << np.uint64(e1 - r)) & np.uint64(0xFFFFFFFF)
            ).astype(np.uint32)
    return lanes


def _unpack_terms_host(lanes: np.ndarray, *, vocab_size: int,
                       sigma: int) -> np.ndarray:
    """Host-side :func:`packing.unpack_terms` -- the build path stays on the
    host end to end instead of paying two device round-trips per view."""
    bits = packing.bits_for_vocab(vocab_size)
    per = packing.terms_per_lane(vocab_size)
    shifts = np.arange(per - 1, -1, -1, dtype=np.uint32) * np.uint32(bits)
    mask = np.uint32((1 << bits) - 1) if bits < 32 else np.uint32(0xFFFFFFFF)
    t = (lanes[..., None] >> shifts) & mask
    t = t.reshape(t.shape[:-2] + (-1,))
    return t[..., :sigma].astype(np.int32)


def _lcp_host(terms: np.ndarray) -> np.ndarray:
    """lcp[i] = common prefix length of sorted rows i and i-1 (lcp[0] = 0)."""
    lcp = np.zeros(terms.shape[0], np.int32)
    if terms.shape[0] > 1:
        eq = (terms[1:] == terms[:-1]).astype(np.int32)
        lcp[1:] = np.cumprod(eq, axis=1).sum(axis=1)
    return lcp


def _front_code(terms: np.ndarray, row_len: np.ndarray,
                *, len_off: int, block_size: int, term_bits: int,
                lcp_width: int, payload_words: int | None):
    """(heads, lcps, payload, block_base) for one view.

    terms  : [size, S] int32 decoded term rows (view order, sentinels included)
    len_off: 0 for the point view, 1 for the continuation (prefix) view --
             stored terms per row = clip(row_len - len_off, 0, S); everything
             past that is PAD and reconstructed as 0.
    """
    size, sigma = terms.shape
    b = block_size
    if size % b:
        raise ValueError(f"size {size} not a multiple of block_size {b}")
    store_len = np.clip(row_len - len_off, 0, sigma).astype(np.int32)
    lcp = np.minimum(_lcp_host(terms), store_len)
    lcp[0::b] = 0                      # block heads restart the coding chain
    ns = store_len - lcp
    j = np.arange(sigma)[None, :]
    stored_mask = (j >= lcp[:, None]) & (j < store_len[:, None])
    suffix = terms[stored_mask].astype(np.uint32)   # C-order: row-major ✓
    cum = np.zeros(size + 1, np.int64)
    np.cumsum(ns, out=cum[1:])
    # size % b == 0, so the stride already ends on cum[size]: [nb+1] entries
    block_base = cum[0::b].astype(np.uint32)
    payload = pack_bits(suffix, term_bits, n_words=payload_words)
    lcps = pack_bits(lcp.astype(np.uint32), lcp_width)
    heads = _pack_head_keys(row_len[0::b], terms[0::b], term_bits=term_bits)
    return heads, lcps, payload, block_base


def _fan_lo_blocks(fan_rows: np.ndarray, block_size: int,
                   size: int) -> np.ndarray:
    """Per-(section, bucket) head-search bracket start, in *blocks*.

    The decoded fanout cache: one gather replaces the per-batch EF
    select/decode work that used to seed the head bsearch, and storing block
    ids (not rows) keeps it uint16 for every index under 64Ki blocks."""
    lo = fan_rows // block_size
    nb = size // block_size
    dt = np.uint16 if nb <= np.iinfo(np.uint16).max else np.int32
    return lo.astype(dt)


def compress_index(idx: NGramIndex, *, block_size: int = 4,
                   count_width: int | None = None,
                   payload_words: int | None = None,
                   cont_payload_words: int | None = None,
                   cumsum_universe: int | None = None,
                   head_span: int | None = None) -> CompressedNGramIndex:
    """Re-encode ``idx`` losslessly.  The capacity overrides exist so sharded
    builds can force identical array shapes / static meta across shards
    (stacked pytrees need a common treedef)."""
    sigma, vocab, size = idx.sigma, idx.vocab_size, idx.size
    tb = packing.bits_for_vocab(vocab)
    lw = lcp_width_for(sigma)
    section_start = np.asarray(idx.section_start)
    row_len = _row_lengths(section_start, size)
    counts = np.asarray(idx.counts)
    cw = count_width if count_width is not None else \
        max(1, int(counts.max()).bit_length() if counts.size else 1)

    terms = _unpack_terms_host(np.asarray(idx.lanes), vocab_size=vocab,
                               sigma=sigma)
    heads, lcps, payload, block_base = _front_code(
        terms, row_len, len_off=0, block_size=block_size,
        term_bits=tb, lcp_width=lw, payload_words=payload_words)

    c_terms = _unpack_terms_host(np.asarray(idx.cont_prefix),
                                 vocab_size=vocab, sigma=sigma)
    c_heads, c_lcps, c_payload, c_block_base = _front_code(
        c_terms, row_len, len_off=1, block_size=block_size,
        term_bits=tb, lcp_width=lw, payload_words=cont_payload_words)

    fan = np.asarray(idx.fanout, np.int64).reshape(-1)
    c_fan = np.asarray(idx.cont_fanout, np.int64).reshape(-1)
    if head_span is None:
        # widest fanout cell measured in blocks: every head-search bracket is
        # [lo // B, lo // B + head_span), so the fixed-trip head bsearch stops
        # after log2(span) instead of log2(n_blocks) steps -- the compressed
        # layout's analogue of the fanout table shrinking the row search.  The
        # +1 covers a cell straddling one extra block boundary than its row
        # count suggests.
        head_span = 1
        for t in (np.asarray(idx.fanout), np.asarray(idx.cont_fanout)):
            if t.size:
                head_span = max(head_span, int(np.max(
                    -(-t[:, 1:] // block_size) - t[:, :-1] // block_size)) + 1)
        head_span = min(head_span, size // block_size)
    cumsum = np.asarray(idx.cont_cumsum, np.int64)
    for name, seq in (("fanout", fan), ("cont_fanout", c_fan)):
        if seq.size and np.any(np.diff(seq) < 0):
            raise AssertionError(f"{name} table is not monotone when flattened")

    return CompressedNGramIndex(
        heads=jnp.asarray(heads), lcps=jnp.asarray(lcps),
        payload=jnp.asarray(payload), block_base=jnp.asarray(block_base),
        counts_packed=jnp.asarray(pack_bits(counts.astype(np.uint32), cw)),
        ef_section=EliasFano.encode(section_start, universe=size),
        cont_heads=jnp.asarray(c_heads), cont_lcps=jnp.asarray(c_lcps),
        cont_payload=jnp.asarray(c_payload),
        cont_block_base=jnp.asarray(c_block_base),
        cont_last_packed=jnp.asarray(
            pack_bits(np.asarray(idx.cont_last, np.uint32), tb)),
        cont_counts_packed=jnp.asarray(
            pack_bits(np.asarray(idx.cont_counts, np.uint32), cw)),
        ef_cont_fanout=EliasFano.encode(c_fan, universe=size),
        ef_cumsum=EliasFano.encode(
            cumsum, universe=cumsum_universe if cumsum_universe is not None
            else int(cumsum[-1])),
        sec_cache=jnp.asarray(section_start.astype(np.int32)),
        cumsum_cache=jnp.asarray(cumsum.astype(np.uint32)),
        fan_cache=jnp.asarray(_fan_lo_blocks(fan, block_size, size)),
        cont_fan_cache=jnp.asarray(_fan_lo_blocks(c_fan, block_size, size)),
        sigma=sigma, vocab_size=vocab, size=size,
        fanout_shift=idx.fanout_shift, n_fanout=idx.n_fanout,
        block_size=block_size, head_span=head_span,
        head_steps=search_steps(head_span),
        term_bits=tb, count_width=cw, lcp_width=lw,
    )


def build_compressed_index(stats: NGramStats, *, vocab_size: int,
                           pad_to: int | None = None,
                           block_size: int = 4) -> CompressedNGramIndex:
    """Job output -> compressed index (freeze uncompressed, then re-encode)."""
    return compress_index(build_index(stats, vocab_size=vocab_size,
                                      pad_to=pad_to), block_size=block_size)


def decode_view(cidx: CompressedNGramIndex, view: str = "point") -> np.ndarray:
    """Reconstruct the full [size, S] term matrix of one view (host, for tests).

    Exactness here is the structural half of the parity argument: if the decode
    round-trips every row, any query mismatch must be in the search plan.
    """
    if view == "point":
        lcps, payload, base, len_off = (cidx.lcps, cidx.payload,
                                        cidx.block_base, 0)
    elif view == "cont":
        lcps, payload, base, len_off = (cidx.cont_lcps, cidx.cont_payload,
                                        cidx.cont_block_base, 1)
    else:
        raise ValueError(view)
    size, sigma, b = cidx.size, cidx.sigma, cidx.block_size
    sec = np.asarray(cidx.section_starts())
    row_len = _row_lengths(sec, size)
    store_len = np.clip(row_len - len_off, 0, sigma)
    lcp = np.asarray(extract_bits(lcps, jnp.arange(size), cidx.lcp_width)) \
        .astype(np.int64)
    ns = store_len - lcp
    total = int(np.asarray(base)[-1])
    vals = np.asarray(extract_bits(payload, jnp.arange(max(total, 1)),
                                   cidx.term_bits)).astype(np.int64)[:total]
    cum = np.zeros(size + 1, np.int64)
    np.cumsum(ns, out=cum[1:])
    j = np.arange(sigma)[None, :]
    tpos = cum[:-1, None] + (j - lcp[:, None])
    stored_mask = (j >= lcp[:, None]) & (j < store_len[:, None])
    aligned = np.where(stored_mask, vals[np.clip(tpos, 0, max(total - 1, 0))], 0)
    lcp_b = lcp.reshape(-1, b)
    aligned_b = aligned.reshape(-1, b, sigma)
    slen_b = store_len.reshape(-1, b)
    cand = np.where(lcp_b[:, :, None] <= j[None], np.arange(b)[None, :, None], -1)
    prov = np.maximum.accumulate(cand, axis=1)
    taken = np.take_along_axis(aligned_b, prov, axis=1)
    slen_p = np.take_along_axis(
        np.broadcast_to(slen_b[:, :, None], aligned_b.shape), prov, axis=1)
    out = np.where(j[None] < slen_p, taken, 0).reshape(size, sigma)
    return out.astype(np.int64)
