"""Device-resident n-gram index + batched query serving.

The read side of the system: ``build`` freezes a finished job's ``NGramStats``
into a sorted packed-lane artifact (``IndexSegment`` -- the immutable unit of
composition), ``compress`` re-encodes it losslessly (front-coded blocks +
Elias-Fano monotone structures, ~3x smaller), ``merge`` composes sorted
segments without re-running the job and keeps generations of them fresh under
streaming ingest (``GenerationalIndex``, LSM-style size-tiered compaction),
``query`` answers batched point-count and top-k-continuation queries against
any layout or a whole generation stack, and ``serve`` shards everything over a
mesh with the job shuffle's own hash partitioner (shards align with reducer
outputs; cross-shard and cross-segment folds run on the host).
"""
from . import build, compress, merge, query, serve
from .build import (IndexSegment, NGramIndex, build_index, index_from_segment,
                    segment_from_stats)
from .compress import (CompressedNGramIndex, EliasFano, build_compressed_index,
                       compress_index, decode_segment)
from .merge import (GenerationalIndex, PairwiseSegmentAccumulator,
                    TieredSegmentAccumulator, generational_from_stats,
                    merge_indexes, merge_segments, segment_to_stats,
                    stats_union)
from .query import continuations, lookup
from .serve import (ShardedGenerationalIndex, ShardedNGramIndex,
                    build_sharded_index, empty_prefix_continuations,
                    make_server, shard_generational)
from .serve import serve as serve_queries

__all__ = ["build", "compress", "merge", "query", "serve",
           "IndexSegment", "NGramIndex", "build_index", "index_from_segment",
           "segment_from_stats",
           "CompressedNGramIndex", "EliasFano", "build_compressed_index",
           "compress_index", "decode_segment",
           "GenerationalIndex", "TieredSegmentAccumulator",
           "PairwiseSegmentAccumulator", "generational_from_stats",
           "merge_indexes", "merge_segments", "segment_to_stats",
           "stats_union",
           "lookup", "continuations",
           "ShardedGenerationalIndex", "ShardedNGramIndex",
           "build_sharded_index", "empty_prefix_continuations", "make_server",
           "shard_generational", "serve_queries"]
