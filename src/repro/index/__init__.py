"""Device-resident n-gram index + batched query serving.

The read side of the system: ``build`` freezes a finished job's ``NGramStats``
into a sorted packed-lane artifact, ``compress`` re-encodes it losslessly
(front-coded blocks + Elias-Fano monotone structures, ~3x smaller), ``query``
answers batched point-count and top-k-continuation queries against either
layout, and ``serve`` shards both over a mesh with the job shuffle's own hash
partitioner (shards align with reducer outputs; empty-prefix top-k merges
across shards on the host).
"""
from . import build, compress, query, serve
from .build import NGramIndex, build_index
from .compress import (CompressedNGramIndex, EliasFano, build_compressed_index,
                       compress_index)
from .query import continuations, lookup
from .serve import (ShardedNGramIndex, build_sharded_index,
                    empty_prefix_continuations, make_server)
from .serve import serve as serve_queries

__all__ = ["build", "compress", "query", "serve", "NGramIndex", "build_index",
           "CompressedNGramIndex", "EliasFano", "build_compressed_index",
           "compress_index", "lookup", "continuations", "ShardedNGramIndex",
           "build_sharded_index", "empty_prefix_continuations", "make_server",
           "serve_queries"]
