"""Device-resident n-gram index + batched query serving.

The read side of the system: ``build`` freezes a finished job's ``NGramStats``
into a sorted packed-lane artifact, ``query`` answers batched point-count and
top-k-continuation queries against it, and ``serve`` shards both over a mesh
with the job shuffle's own hash partitioner (shards align with reducer outputs).
"""
from . import build, query, serve
from .build import NGramIndex, build_index
from .query import continuations, lookup
from .serve import ShardedNGramIndex, build_sharded_index, make_server
from .serve import serve as serve_queries

__all__ = ["build", "query", "serve", "NGramIndex", "build_index", "lookup",
           "continuations", "ShardedNGramIndex", "build_sharded_index",
           "make_server", "serve_queries"]
