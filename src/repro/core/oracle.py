"""Pure-Python reference implementation -- the test oracle.

Directly implements the problem statement of SSIII: every n-gram s with
cf(s) >= tau and |s| <= sigma, where cf is the number of (possibly overlapping)
occurrences across all documents.  Token streams use PAD(0) as the document /
sentence separator, matching the array encoding used by the JAX pipelines.
"""
from __future__ import annotations

from collections import Counter, defaultdict

import numpy as np


def documents_from_stream(tokens) -> list[list[int]]:
    docs: list[list[int]] = []
    cur: list[int] = []
    for t in np.asarray(tokens).tolist():
        if t == 0:
            if cur:
                docs.append(cur)
            cur = []
        else:
            cur.append(int(t))
    if cur:
        docs.append(cur)
    return docs


def ngram_counts(tokens, sigma: int, tau: int) -> dict[tuple[int, ...], int]:
    cnt: Counter = Counter()
    for doc in documents_from_stream(tokens):
        n = len(doc)
        for b in range(n):
            for e in range(b, min(b + sigma, n)):
                cnt[tuple(doc[b:e + 1])] += 1
    return {g: c for g, c in cnt.items() if c >= tau}


def ngram_series(tokens, bucket_ids, sigma: int, tau: int,
                 n_buckets: int) -> dict[tuple[int, ...], np.ndarray]:
    """Time-series extension oracle (SSVI-B): per-bucket occurrence counts."""
    toks = np.asarray(tokens).tolist()
    buckets = np.asarray(bucket_ids).tolist()
    series: dict[tuple[int, ...], np.ndarray] = defaultdict(
        lambda: np.zeros(n_buckets, dtype=np.int64))
    start = 0
    for i in range(len(toks) + 1):
        if i == len(toks) or toks[i] == 0:
            doc = toks[start:i]
            bks = buckets[start:i]
            for b in range(len(doc)):
                for e in range(b, min(b + sigma, len(doc))):
                    series[tuple(doc[b:e + 1])][bks[b]] += 1
            start = i + 1
    return {g: s for g, s in series.items() if int(s.sum()) >= tau}


def ngram_document_frequencies(tokens, sigma: int, tau: int
                               ) -> dict[tuple[int, ...], int]:
    """df(s) = number of documents containing s (the frequent-sequence-mining
    'support' of SSII); filtered by df >= tau."""
    df: Counter = Counter()
    for doc in documents_from_stream(tokens):
        seen = set()
        n = len(doc)
        for b in range(n):
            for e in range(b, min(b + sigma, n)):
                seen.add(tuple(doc[b:e + 1]))
        for g in seen:
            df[g] += 1
    return {g: c for g, c in df.items() if c >= tau}


def ngram_postings(tokens, sigma: int, tau: int
                   ) -> dict[tuple[int, ...], dict[int, int]]:
    """Inverted index (SSVI-B): for each frequent n-gram, doc id -> in-doc count."""
    cnt = ngram_counts(tokens, sigma, tau)
    post: dict[tuple[int, ...], dict[int, int]] = {g: {} for g in cnt}
    for did, doc in enumerate(documents_from_stream(tokens)):
        n = len(doc)
        for b in range(n):
            for e in range(b, min(b + sigma, n)):
                g = tuple(doc[b:e + 1])
                if g in post:
                    post[g][did] = post[g].get(did, 0) + 1
    return post


def maximal_ngrams(stats: dict[tuple[int, ...], int]) -> dict[tuple[int, ...], int]:
    """r maximal iff no frequent s with r a *contiguous subsequence* of s (SSVI-A)."""
    grams = list(stats)
    frequent = set(grams)

    def has_frequent_super(r):
        lr = len(r)
        for s in frequent:
            if len(s) <= lr or s == r:
                continue
            for j in range(len(s) - lr + 1):
                if s[j:j + lr] == r:
                    return True
        return False

    return {g: c for g, c in stats.items() if not has_frequent_super(g)}


def closed_ngrams(stats: dict[tuple[int, ...], int]) -> dict[tuple[int, ...], int]:
    """r closed iff no frequent s (contiguous supersequence) with cf(s) == cf(r)."""
    def has_equal_super(r, c):
        lr = len(r)
        for s, cs in stats.items():
            if len(s) <= lr or cs != c:
                continue
            for j in range(len(s) - lr + 1):
                if s[j:j + lr] == r:
                    return True
        return False

    return {g: c for g, c in stats.items() if not has_equal_super(g, c)}


def expected_map_records(tokens, sigma: int, method: str) -> int:
    """Closed-form record counts from the paper's per-method analyses."""
    docs = documents_from_stream(tokens)
    if method == "suffix_sigma":
        return sum(len(d) for d in docs)                      # one per token (SSIV)
    if method == "naive":
        return sum(
            sum(min(sigma, len(d) - b) for b in range(len(d))) for d in docs
        )                                                     # every n-gram occurrence
    raise ValueError(method)
