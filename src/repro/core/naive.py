"""NAIVE (Algorithm 1): word counting extended to all n-grams up to sigma.

The map phase emits *every* n-gram occurrence -- O(|d| * sigma) records of O(sigma)
bytes per document, the paper's worst case and the reason the method drowns in
shuffle traffic for large sigma (Figs 4-5).  The reduce phase is a plain
count-per-distinct-gram.  Partitioning hashes the whole gram (any reducer may count
any gram -- no locality requirement, unlike SUFFIX-sigma).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle as shf
from repro.pipeline import plan as plan_mod
from .common import count_exact_grams, gram_hash
from .stats import NGramConfig, NGramStats
from .suffix_sigma import suffix_windows


def _explode(tokens: jax.Array, sigma: int, vocab_size: int):
    """Map emit: all (position, length<=sigma) n-grams.  [N*sigma, W] records."""
    n = tokens.shape[0]
    windows, _ = suffix_windows(tokens, sigma)                     # [N, sigma]
    lmask = jnp.tril(jnp.ones((sigma, sigma), jnp.int32))          # [len, sigma]
    grams = windows[:, None, :] * lmask[None, :, :]                # [N, len, sigma]
    valid = windows != 0           # windows are PAD-masked, so col l != 0 <=> len > l
    grams = (grams * valid[:, :, None]).reshape(n * sigma, sigma)
    lanes = packing.pack_terms(grams, vocab_size=vocab_size)
    w = valid.reshape(-1).astype(jnp.uint32)
    return jnp.concatenate([lanes, w[:, None]], axis=1), valid.reshape(-1)


def _plan_emit(tok_ext, aux_ext, n_live, cfg: NGramConfig, carry, k):
    """Map emit: every (position, length<=sigma) n-gram of the window.  Row
    ``i`` belongs to position ``i // sigma``; halo positions emit nothing."""
    records, valid = _explode(tok_ext, cfg.sigma, cfg.vocab_size)
    pos_ok = (jnp.arange(records.shape[0]) // cfg.sigma) < n_live
    valid = valid & pos_ok
    records = records * valid[:, None].astype(records.dtype)
    return records, valid, {}


def plan(cfg: NGramConfig) -> plan_mod.JobPlan:
    """NAIVE as a :class:`JobPlan`: one job, exploded emit (the paper's
    worst-case record volume), whole-gram hash partitioning, exact count."""
    return plan_mod.JobPlan(
        name="naive",
        map=plan_mod.MapStage(_plan_emit),
        shuffle=plan_mod.ShuffleStage("gram"),
        sort=plan_mod.SortStage(),
        reduce=plan_mod.ReduceStage("exact"),
    )


def _distributed(tokens_p, cfg: NGramConfig, mesh, axis_name, capacity):
    n_parts = mesh.shape[axis_name]
    n_l = packing.n_lanes(cfg.sigma, cfg.vocab_size)

    def job(tok):
        tok = tok[0]
        if cfg.sigma > 1:
            perm = [(i, (i - 1) % n_parts) for i in range(n_parts)]
            halo = jax.lax.ppermute(tok[: cfg.sigma - 1], axis_name, perm)
            is_last = jax.lax.axis_index(axis_name) == n_parts - 1
            halo = jnp.where(is_last, jnp.zeros_like(halo), halo)
            tok_ext = jnp.concatenate([tok, halo])
        else:
            tok_ext = tok
        records, valid = _explode(tok_ext, cfg.sigma, cfg.vocab_size)
        pos_ok = (jnp.arange(records.shape[0]) // cfg.sigma) < tok.shape[0]
        valid = valid & pos_ok
        records = records * valid[:, None].astype(records.dtype)
        map_rec = jnp.sum(valid)
        key = gram_hash(records[:, :n_l])
        local, overflow = shf.shuffle(records, key, valid, axis_name=axis_name,
                                      n_parts=n_parts, capacity=capacity)
        terms, flags, counts = count_exact_grams(
            local, sigma=cfg.sigma, vocab_size=cfg.vocab_size)
        stats = jnp.stack([jax.lax.psum(map_rec, axis_name), overflow])
        return terms[None], flags[None], counts[None], stats[None]

    from jax.sharding import PartitionSpec as P
    fn = jax.jit(jax.shard_map(job, mesh=mesh, in_specs=(P(axis_name, None),),
                               out_specs=(P(axis_name),) * 4, check_vma=False))
    return fn(tokens_p)


def run(tokens, cfg: NGramConfig, mesh=None, axis_name: str = "data") -> NGramStats:
    tokens = jnp.asarray(tokens, jnp.int32)
    if mesh is None or mesh.size == 1:
        from repro.pipeline.executor import run_plan
        return run_plan(tokens, cfg, plan=plan(cfg))

    n_parts = mesh.shape[axis_name]
    n = tokens.shape[0]
    n_local = -(-n // n_parts)
    tokens_p = jnp.pad(tokens, (0, n_local * n_parts - n)).reshape(n_parts, n_local)
    capacity = max(8, int(cfg.capacity_factor * n_local * cfg.sigma / n_parts) + 1)
    for attempt in range(6):
        terms, flags, counts, stats = _distributed(tokens_p, cfg, mesh, axis_name,
                                                   capacity)
        stats_np = np.asarray(stats)
        if int(stats_np[:, 1].max()) == 0:
            break
        capacity *= 2
    else:
        raise RuntimeError("naive shuffle overflow persisted")
    rec_bytes = packing.record_bytes(cfg.sigma, cfg.vocab_size)
    counters = {"map_records": int(stats_np[0, 0]),
                "shuffle_records": int(stats_np[0, 0]),
                "shuffle_bytes": int(stats_np[0, 0]) * rec_bytes,
                "jobs": 1, "overflow": 0, "capacity": capacity, "retries": attempt}
    terms, flags, counts = np.asarray(terms), np.asarray(flags), np.asarray(counts)
    out = None
    for p in range(n_parts):
        part = NGramStats.from_dense(terms[p], flags[p], counts[p], cfg.tau,
                                     counters if p == 0 else {})
        out = part if out is None else out.merged_with(part)
    return out
