"""APRIORI-SCAN (Algorithm 2): one distributed scan of the corpus per gram length.

The k-th job emits only those k-grams whose two constituent (k-1)-grams were output
(frequent) by job k-1 -- candidate pruning via the APRIORI principle.  The paper keeps
the previous job's output in a per-node dictionary (distributed cache / BerkeleyDB);
our TPU analogue is a sorted uint32 hash array broadcast to all devices with binary
search lookups (``common.membership_hashes``).  Hash collisions can only admit extra
candidates, which the exact re-count of job k then filters -- output equality with the
oracle is preserved, only pruning power degrades (negligibly at 2^-32).

Termination matches the paper: after sigma jobs or when a job produces no output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle as shf
from repro.pipeline import plan as plan_mod
from .common import count_exact_grams, gram_hash, kgram_records, member, membership_hashes
from .stats import NGramConfig, NGramStats, add_counters
from .suffix_sigma import suffix_windows


def _candidates(tokens: jax.Array, k: int, cfg: NGramConfig,
                freq_hashes: jax.Array | None):
    """Candidate k-gram records at every position (pruned by the (k-1) dictionary)."""
    sigma, vocab = cfg.sigma, cfg.vocab_size
    if k == 1 or freq_hashes is None:
        return kgram_records(tokens, k, sigma, vocab)
    windows, _ = suffix_windows(tokens, sigma)
    km1 = jnp.arange(sigma) < (k - 1)
    prefix = windows * km1[None, :].astype(windows.dtype)                 # d[b..b+k-2]
    suffix_w = jnp.roll(windows, -1, axis=0) * km1[None, :].astype(windows.dtype)
    pref_ok = member(freq_hashes,
                     gram_hash(packing.pack_terms(prefix, vocab_size=vocab)))
    suff_ok = member(freq_hashes,
                     gram_hash(packing.pack_terms(suffix_w, vocab_size=vocab)))
    mask = pref_ok & suff_ok
    return kgram_records(tokens, k, sigma, vocab, weight_mask=mask)


def _plan_emit(tok_ext, aux_ext, n_live, cfg: NGramConfig, carry, k):
    """Round-k map emit: candidate k-grams pruned by the (k-1) dictionary.

    The pre-live-mask records/valid (whole window, halo included) ride along
    in ``emit_extras`` for the wave-mode carry, which needs exactly them.
    """
    records, valid = _candidates(tok_ext, k, cfg, carry)
    pos_ok = jnp.arange(records.shape[0]) < n_live
    live_valid = valid & pos_ok
    live_records = records * live_valid[:, None].astype(records.dtype)
    return live_records, live_valid, {"window_records": records,
                                      "window_valid": valid}


def _update_carry(cfg: NGramConfig, tau_eff, k, tok_ext, stats_k,
                  reduce_extras, emit_extras, carry):
    """Next round's dictionary (the Hadoop distributed-cache analogue).

    ``tau_eff == 1`` is the wave regime: every k-gram of the window (halo
    included) is "frequent", and the dictionary must cover the halo or the
    candidate test at wave-boundary positions would prune real occurrences --
    so it is built from the emit's own window records (at tau=1 the candidate
    mask admits every valid position, so they are exactly the window's
    k-grams; no second emit).  Otherwise (the monolithic job) it is the
    hashes of this round's frequent output, as in the paper.
    """
    if tau_eff == 1:
        n_l = packing.n_lanes(cfg.sigma, cfg.vocab_size)
        return membership_hashes(emit_extras["window_records"][:, :n_l],
                                 emit_extras["window_valid"])
    freq_lane = packing.pack_terms(jnp.asarray(stats_k.grams),
                                   vocab_size=cfg.vocab_size)
    return membership_hashes(freq_lane, jnp.asarray(stats_k.lengths == k))


def plan(cfg: NGramConfig) -> plan_mod.JobPlan:
    """APRIORI-SCAN as a :class:`JobPlan`: sigma chained jobs, candidate emit
    pruned by the previous round's dictionary carry, whole-gram counting."""
    return plan_mod.JobPlan(
        name="apriori_scan",
        map=plan_mod.MapStage(_plan_emit),
        shuffle=plan_mod.ShuffleStage("gram"),
        sort=plan_mod.SortStage(),
        reduce=plan_mod.ReduceStage("exact"),
        rounds=cfg.sigma,
        stop_on_empty=True,
        update_carry=_update_carry,
    )


def run(tokens, cfg: NGramConfig, mesh=None, axis_name: str = "data") -> NGramStats:
    tokens = jnp.asarray(tokens, jnp.int32)
    if mesh is not None and mesh.size > 1:
        return _run_distributed(tokens, cfg, mesh, axis_name)
    from repro.pipeline.executor import run_plan
    return run_plan(tokens, cfg, plan=plan(cfg))


def _run_distributed(tokens, cfg: NGramConfig, mesh, axis_name) -> NGramStats:
    n_parts = mesh.shape[axis_name]
    n = tokens.shape[0]
    n_local = -(-n // n_parts)
    tokens_p = jnp.pad(tokens, (0, n_local * n_parts - n)).reshape(n_parts, n_local)
    n_l = packing.n_lanes(cfg.sigma, cfg.vocab_size)
    rec_width = packing.record_bytes(cfg.sigma, cfg.vocab_size)

    def stage_fn(k, capacity, dict_size):
        def job(tok, freq):
            tok = tok[0]
            freq = freq if dict_size else None  # replicated dictionary (dist. cache)
            if cfg.sigma > 1:
                perm = [(i, (i - 1) % n_parts) for i in range(n_parts)]
                halo = jax.lax.ppermute(tok[: cfg.sigma - 1], axis_name, perm)
                is_last = jax.lax.axis_index(axis_name) == n_parts - 1
                halo = jnp.where(is_last, jnp.zeros_like(halo), halo)
                tok_ext = jnp.concatenate([tok, halo])
            else:
                tok_ext = tok
            records, valid = _candidates(tok_ext, k, cfg, freq)
            pos_ok = jnp.arange(records.shape[0]) < tok.shape[0]
            valid = valid & pos_ok
            records = records * valid[:, None].astype(records.dtype)
            n_cand = jnp.sum(valid)
            key = gram_hash(records[:, :n_l])
            local, overflow = shf.shuffle(records, key, valid, axis_name=axis_name,
                                          n_parts=n_parts, capacity=capacity)
            terms, flags, counts = count_exact_grams(
                local, sigma=cfg.sigma, vocab_size=cfg.vocab_size)
            stats = jnp.stack([jax.lax.psum(n_cand, axis_name), overflow])
            return terms[None], flags[None], counts[None], stats[None]
        return job

    from jax.sharding import PartitionSpec as P
    counters: dict[str, float] = {"jobs": 0, "map_records": 0, "shuffle_records": 0,
                                  "shuffle_bytes": 0, "overflow": 0}
    out = None
    freq_hashes_host = None
    for k in range(1, cfg.sigma + 1):
        capacity = max(8, int(cfg.capacity_factor * n_local / n_parts) + 1)
        dict_size = 0 if freq_hashes_host is None else freq_hashes_host.shape[0]
        freq_arg = (jnp.zeros((1,), jnp.uint32) if dict_size == 0
                    else jnp.asarray(freq_hashes_host))
        for attempt in range(6):
            job = stage_fn(k, capacity, dict_size)
            fn = jax.jit(jax.shard_map(
                job, mesh=mesh, in_specs=(P(axis_name, None), P()),
                out_specs=(P(axis_name),) * 4, check_vma=False))
            terms, flags, counts, stats = fn(tokens_p, freq_arg)
            stats_np = np.asarray(stats)
            if int(stats_np[:, 1].max()) == 0:
                break
            capacity *= 2
        else:
            raise RuntimeError("apriori_scan shuffle overflow persisted")
        n_cand = int(stats_np[0, 0])
        add_counters(counters, jobs=1, map_records=n_cand, shuffle_records=n_cand,
                     shuffle_bytes=n_cand * rec_width)
        terms, flags, counts = np.asarray(terms), np.asarray(flags), np.asarray(counts)
        stage = None
        for p in range(n_parts):
            part = NGramStats.from_dense(terms[p], flags[p], counts[p], cfg.tau)
            stage = part if stage is None else stage.merged_with(part)
        out = stage if out is None else out.merged_with(stage)
        if len(stage) == 0:
            break
        freq_lane = packing.pack_terms(jnp.asarray(stage.grams),
                                       vocab_size=cfg.vocab_size)
        # dictionary replicated to every node -- Hadoop distributed-cache analogue
        freq_hashes_host = np.asarray(
            membership_hashes(freq_lane, jnp.asarray(stage.lengths == k)))
    out.counters = counters
    return out
