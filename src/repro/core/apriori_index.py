"""APRIORI-INDEX (Algorithm 3): incremental inverted index with posting-list joins.

Phase 1 (k <= K): build positional occurrence information for frequent k-grams by
direct counting.  Phase 2 (k > K): a frequent (k)-gram occurrence at position p exists
iff frequent (k-1)-gram occurrences exist at p *and* p+1 -- which is exactly the
paper's Reducer-#2 join of the posting lists of the two constituent (k-1)-grams that
share a (k-2)-infix (position p lies in the joined list iff m occurs at p and n at
p+1).  SPADE-style, the join runs on the index, never rescanning the corpus.

TPU adaptation (DESIGN.md SS2): posting lists with positions become a boolean
occurrence mask over token positions (static shape), and the join becomes a shifted
AND of masks plus an exact re-count of the surviving grams.  Per-position run totals
are scattered back through the sort permutation (``count_exact_grams`` with
positions), giving each position the collection frequency of its gram -- the
"posting list with frequencies" of the paper.

Counters account posting-list volume the way the paper does: each iteration k > K
ships one record per surviving occurrence (O(cf(s)) bytes per frequent s).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle as shf
from repro.pipeline import plan as plan_mod
from .common import count_exact_grams, gram_hash, kgram_records
from .stats import NGramConfig, NGramStats, add_counters


def _join_mask(cfg: NGramConfig, k: int, occ):
    """Phase-2 posting-list join: a k-gram occurs at p iff frequent
    (k-1)-grams occur at p and p+1; phase 1 (k <= K) has no precondition."""
    if k <= min(cfg.apriori_index_k, cfg.sigma) or occ is None:
        return None
    nxt = jnp.concatenate([occ[1:], jnp.zeros((1,), bool)])
    return occ & nxt


def _plan_emit(tok_ext, aux_ext, n_live, cfg: NGramConfig, carry, k):
    """Round-k map emit: k-grams at positions allowed by the occurrence mask.

    ``window_valid`` (the *unmasked* join-passing positions over the whole
    extended window, halo included) rides along for the wave-mode carry.
    """
    mask = _join_mask(cfg, k, carry)
    records, valid = kgram_records(tok_ext, k, cfg.sigma, cfg.vocab_size,
                                   weight_mask=mask, with_positions=True)
    pos_ok = jnp.arange(records.shape[0]) < n_live
    live_valid = valid & pos_ok
    # mask lanes + weight but KEEP the position meta lane: zeroed positions
    # would collide every invalid row onto index 0 in the reducer's
    # totals-at-pos scatter, whose duplicate-index winner is unspecified
    records = jnp.concatenate(
        [records[:, :-1] * live_valid[:, None].astype(records.dtype),
         records[:, -1:]], axis=1)
    return records, live_valid, {"window_valid": valid}


def _update_carry(cfg: NGramConfig, tau_eff, k, tok_ext, stats_k,
                  reduce_extras, emit_extras, carry):
    """Occurrence mask of frequent k-grams for the next round's join.

    ``tau_eff == 1`` is the wave regime: "frequent" means "occurs", which the
    emit already knows for every window position including the halo --
    counts-based occupancy would be blind to halo positions and prune real
    occurrences at wave boundaries.  Otherwise the paper's rule: positions
    whose gram's collection frequency reaches tau (the per-position run
    totals shipped back through the sort permutation).
    """
    if tau_eff == 1:
        return emit_extras["window_valid"]
    return jnp.asarray(np.asarray(reduce_extras["totals_pos"]) >= tau_eff)


def plan(cfg: NGramConfig) -> plan_mod.JobPlan:
    """APRIORI-INDEX as a :class:`JobPlan`: sigma chained jobs, occurrence-mask
    carry (the posting-list join), exact counting with position payloads."""
    return plan_mod.JobPlan(
        name="apriori_index",
        map=plan_mod.MapStage(_plan_emit, n_meta=1),
        shuffle=plan_mod.ShuffleStage("gram"),
        sort=plan_mod.SortStage(),
        reduce=plan_mod.ReduceStage("exact", with_positions=True),
        rounds=cfg.sigma,
        stop_on_empty=True,
        update_carry=_update_carry,
    )


def run(tokens, cfg: NGramConfig, mesh=None, axis_name: str = "data") -> NGramStats:
    tokens = jnp.asarray(tokens, jnp.int32)
    if mesh is not None and mesh.size > 1:
        return _run_distributed(tokens, cfg, mesh, axis_name)
    from repro.pipeline.executor import run_plan
    return run_plan(tokens, cfg, plan=plan(cfg))


def _run_distributed(tokens, cfg: NGramConfig, mesh, axis_name) -> NGramStats:
    """Distributed variant: positions sharded contiguously over the mesh axis, so the
    p+1 join is local except for a single boundary element exchanged by ppermute; the
    gram re-count shuffles by gram hash like the other methods."""
    n_parts = mesh.shape[axis_name]
    n = tokens.shape[0]
    n_local = -(-n // n_parts)
    tokens_p = jnp.pad(tokens, (0, n_local * n_parts - n)).reshape(n_parts, n_local)
    n_l = packing.n_lanes(cfg.sigma, cfg.vocab_size)
    rec_width = packing.record_bytes(cfg.sigma, cfg.vocab_size, n_meta=1)

    def stage_fn(k, capacity, joined):
        def job(tok, occ):
            tok, occ = tok[0], occ[0]
            perm = [(i, (i - 1) % n_parts) for i in range(n_parts)]
            is_last = jax.lax.axis_index(axis_name) == n_parts - 1
            if cfg.sigma > 1:
                halo = jax.lax.ppermute(tok[: cfg.sigma - 1], axis_name, perm)
                halo = jnp.where(is_last, jnp.zeros_like(halo), halo)
                tok_ext = jnp.concatenate([tok, halo])
            else:
                tok_ext = tok
            if joined:
                occ_next = jax.lax.ppermute(occ[:1], axis_name, perm)
                occ_next = jnp.where(is_last, jnp.zeros_like(occ_next), occ_next)
                nxt = jnp.concatenate([occ[1:], occ_next])
                mask = occ & nxt
            else:
                mask = None
            records, valid = kgram_records(tok_ext, k, cfg.sigma, cfg.vocab_size,
                                           weight_mask=(None if mask is None else
                                                        jnp.pad(mask, (0, cfg.sigma - 1))
                                                        if cfg.sigma > 1 else mask),
                                           with_positions=True)
            pos_ok = jnp.arange(records.shape[0]) < tok.shape[0]
            valid = valid & pos_ok
            records = records * valid[:, None].astype(records.dtype)
            n_rec = jnp.sum(valid)
            # re-count by gram: shuffle occurrences to the gram's reducer, count,
            # then ship totals back to the home shard of each position.
            key = gram_hash(records[:, :n_l])
            local, overflow = shf.shuffle(records, key, valid, axis_name=axis_name,
                                          n_parts=n_parts, capacity=capacity)
            terms, flags, counts, totals_pos_global = count_exact_grams(
                local, sigma=cfg.sigma, vocab_size=cfg.vocab_size,
                with_positions=True)
            # totals_pos_global is indexed by *global* position but lives on the
            # reducer shard; scatter-add back: every shard contributes its counted
            # occurrences, summed across shards via psum of a sharded one-hot write.
            my_totals = jnp.zeros((n_parts * n_local,), jnp.int32)
            pos = local[:, n_l + 1].astype(jnp.int32)
            w = (local[:, n_l] > 0)
            seg_tot = _row_totals(local, n_l)
            my_totals = my_totals.at[jnp.where(w, pos, n_parts * n_local)].set(
                seg_tot, mode="drop")
            my_totals = jax.lax.psum(my_totals, axis_name)
            shard = jax.lax.axis_index(axis_name)
            occ_out = jax.lax.dynamic_slice(my_totals, (shard * n_local,), (n_local,))
            stats = jnp.stack([jax.lax.psum(n_rec, axis_name), overflow])
            return (terms[None], flags[None], counts[None],
                    (occ_out >= cfg.tau)[None], stats[None])
        return job

    def _row_totals(local, n_l):
        # run totals aligned to `local` row order (recomputed from a sort -- cheap
        # next to the shuffle), used to ship per-position counts home.
        from repro.mapreduce import sort as srt
        rec = srt.sort_records(local, n_keys=n_l)
        lanes = rec[:, :n_l]
        first = jnp.any(lanes != jnp.roll(lanes, 1, axis=0), axis=1).at[0].set(True)
        seg = jnp.maximum(jnp.cumsum(first.astype(jnp.int32)) - 1, 0)
        totals = jax.ops.segment_sum(rec[:, n_l].astype(jnp.int32), seg,
                                     num_segments=rec.shape[0])[seg]
        pos_sorted = rec[:, n_l + 1].astype(jnp.int32)
        w_sorted = rec[:, n_l] > 0
        buf = jnp.zeros((n_parts * n_local,), jnp.int32)
        buf = buf.at[jnp.where(w_sorted, pos_sorted, n_parts * n_local)].set(
            totals, mode="drop")
        return buf[local[:, n_l + 1].astype(jnp.int32)]

    from jax.sharding import PartitionSpec as P
    counters: dict[str, float] = {"jobs": 0, "map_records": 0, "shuffle_records": 0,
                                  "shuffle_bytes": 0, "overflow": 0}
    out = None
    K = min(cfg.apriori_index_k, cfg.sigma)
    occ_p = jnp.zeros((n_parts, n_local), bool)
    for k in range(1, cfg.sigma + 1):
        capacity = max(8, int(cfg.capacity_factor * n_local / n_parts) + 1)
        for attempt in range(6):
            fn = jax.jit(jax.shard_map(
                stage_fn(k, capacity, joined=k > K), mesh=mesh,
                in_specs=(P(axis_name, None), P(axis_name, None)),
                out_specs=(P(axis_name),) * 5, check_vma=False))
            terms, flags, counts, occ_new, stats = fn(tokens_p, occ_p)
            stats_np = np.asarray(stats)
            if int(stats_np[:, 1].max()) == 0:
                break
            capacity *= 2
        else:
            raise RuntimeError("apriori_index shuffle overflow persisted")
        n_rec = int(stats_np[0, 0])
        add_counters(counters, jobs=1, map_records=n_rec, shuffle_records=n_rec,
                     shuffle_bytes=n_rec * rec_width)
        terms, flags, counts = np.asarray(terms), np.asarray(flags), np.asarray(counts)
        st = None
        for p in range(n_parts):
            part = NGramStats.from_dense(terms[p], flags[p], counts[p], cfg.tau)
            st = part if st is None else st.merged_with(part)
        out = st if out is None else out.merged_with(st)
        occ_p = occ_new
        if len(st) == 0:
            break
    out.counters = counters
    return out
