"""Shared machinery for the baseline methods (NAIVE, APRIORI-SCAN, APRIORI-INDEX).

All three count *whole grams* (full-row equality runs after the sort), unlike
SUFFIX-sigma which counts every prefix of every suffix.  The helpers here provide
exact whole-gram counting with optional position payloads (APRIORI-INDEX joins on
positions), plus the record hashing used to partition grams across reducers.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.mapreduce import pack as packing
from repro.mapreduce.shuffle import fold_hash as gram_hash  # noqa: F401  (one fold hash)
from repro.pipeline import stages


@partial(jax.jit, static_argnames=("sigma", "vocab_size", "with_positions"))
def count_exact_grams(records: jax.Array, *, sigma: int, vocab_size: int,
                      with_positions: bool = False):
    """Sort + count identical grams in ``records`` = [N, lanes | weight | (pos)].

    The fused sort+reduce the distributed whole-gram paths call; the stage
    bodies live in ``repro.pipeline.stages`` (shared with the wave executor).
    """
    rec = stages.sort_stage(records,
                            n_keys=packing.n_lanes(sigma, vocab_size))
    return stages.reduce_exact(rec, sigma=sigma, vocab_size=vocab_size,
                               with_positions=with_positions)


def kgram_records(tokens: jax.Array, k: int, sigma: int, vocab_size: int,
                  weight_mask: jax.Array | None = None,
                  with_positions: bool = False) -> tuple[jax.Array, jax.Array]:
    """Records for the k-grams starting at every position (padded to sigma lanes).

    weight_mask: optional bool [N] further restricting which positions emit.
    Returns (records, valid).
    """
    from .suffix_sigma import suffix_windows
    windows, _ = suffix_windows(tokens, sigma)
    kmask = jnp.arange(sigma) < k
    kgram = windows * kmask[None, :].astype(windows.dtype)
    valid = windows[:, k - 1] != 0                  # full k tokens present
    if weight_mask is not None:
        valid = valid & weight_mask
    kgram = kgram * valid[:, None].astype(kgram.dtype)
    lanes = packing.pack_terms(kgram, vocab_size=vocab_size)
    cols = [lanes, valid.astype(jnp.uint32)[:, None]]
    if with_positions:
        cols.append(jnp.arange(tokens.shape[0], dtype=jnp.uint32)[:, None])
    return jnp.concatenate(cols, axis=1), valid


def membership_hashes(lanes: jax.Array, valid: jax.Array) -> jax.Array:
    """Sorted uint32 hash set of the valid grams -- the APRIORI 'dictionary'.

    Hash collisions only ever *weaken pruning* (extra candidates), never drop a
    frequent gram: the final tau filter recounts exactly (see apriori_scan.py).
    This replaces the paper's BerkeleyDB / distributed-cache dictionary with a
    TPU-friendly sorted array + binary search.
    """
    h = gram_hash(lanes)
    h = jnp.where(valid, h, jnp.uint32(0xFFFFFFFF))
    return jnp.sort(h)


def member(sorted_hashes: jax.Array, queries: jax.Array) -> jax.Array:
    idx = jnp.searchsorted(sorted_hashes, queries)
    idx = jnp.minimum(idx, sorted_hashes.shape[0] - 1)
    return sorted_hashes[idx] == queries
