"""Shared machinery for the baseline methods (NAIVE, APRIORI-SCAN, APRIORI-INDEX).

All three count *whole grams* (full-row equality runs after the sort), unlike
SUFFIX-sigma which counts every prefix of every suffix.  The helpers here provide
exact whole-gram counting with optional position payloads (APRIORI-INDEX joins on
positions), plus the record hashing used to partition grams across reducers.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle, sort


def gram_hash(lanes: jax.Array) -> jax.Array:
    """Order-sensitive fold hash of the packed lanes -> uint32 partition key."""
    h = jnp.zeros(lanes.shape[:-1], jnp.uint32)
    for i in range(lanes.shape[-1]):
        h = shuffle.hash_u32(h ^ lanes[..., i] + jnp.uint32(0x9E3779B9))
    return h


@partial(jax.jit, static_argnames=("sigma", "vocab_size", "with_positions"))
def count_exact_grams(records: jax.Array, *, sigma: int, vocab_size: int,
                      with_positions: bool = False):
    """Count identical grams in ``records`` = [N, n_lanes | weight | (pos)].

    Returns (terms [N, sigma], flags [N, sigma], counts [N, sigma]) shaped like the
    SUFFIX-sigma reducer output so ``NGramStats.from_dense`` applies; flags mark the
    first row of each run at the row's own gram length.  If ``with_positions``, also
    returns per-original-position run totals [N] (scattered back through the sort
    permutation) for the APRIORI-INDEX posting-list join.
    """
    n, _ = records.shape
    n_l = packing.n_lanes(sigma, vocab_size)
    rec = sort.sort_records(records, n_keys=n_l)
    lanes = rec[:, :n_l]
    weight = rec[:, n_l].astype(jnp.int32)
    terms = packing.unpack_terms(lanes, vocab_size=vocab_size, sigma=sigma)

    first = jnp.any(lanes != jnp.roll(lanes, 1, axis=0), axis=1).at[0].set(True)
    seg = jnp.maximum(jnp.cumsum(first.astype(jnp.int32)) - 1, 0)
    totals = jax.ops.segment_sum(weight, seg, num_segments=n)[seg]

    length = jnp.sum(terms != 0, axis=1)                       # gram length per row
    valid_row = (length > 0) & (weight >= 0)
    pos_in_row = jnp.maximum(length - 1, 0)
    row_flags = first & valid_row & (totals > 0)
    flags = (jax.nn.one_hot(pos_in_row, sigma, dtype=jnp.int32)
             * row_flags[:, None].astype(jnp.int32)).astype(bool)
    counts = flags * totals[:, None]

    if not with_positions:
        return terms, flags, counts
    orig_pos = rec[:, n_l + 1].astype(jnp.int32)
    totals_at_pos = jnp.zeros((n,), jnp.int32).at[orig_pos].set(totals, mode="drop")
    return terms, flags, counts, totals_at_pos


def kgram_records(tokens: jax.Array, k: int, sigma: int, vocab_size: int,
                  weight_mask: jax.Array | None = None,
                  with_positions: bool = False) -> tuple[jax.Array, jax.Array]:
    """Records for the k-grams starting at every position (padded to sigma lanes).

    weight_mask: optional bool [N] further restricting which positions emit.
    Returns (records, valid).
    """
    from .suffix_sigma import suffix_windows
    windows, _ = suffix_windows(tokens, sigma)
    kmask = jnp.arange(sigma) < k
    kgram = windows * kmask[None, :].astype(windows.dtype)
    valid = windows[:, k - 1] != 0                  # full k tokens present
    if weight_mask is not None:
        valid = valid & weight_mask
    kgram = kgram * valid[:, None].astype(kgram.dtype)
    lanes = packing.pack_terms(kgram, vocab_size=vocab_size)
    cols = [lanes, valid.astype(jnp.uint32)[:, None]]
    if with_positions:
        cols.append(jnp.arange(tokens.shape[0], dtype=jnp.uint32)[:, None])
    return jnp.concatenate(cols, axis=1), valid


def membership_hashes(lanes: jax.Array, valid: jax.Array) -> jax.Array:
    """Sorted uint32 hash set of the valid grams -- the APRIORI 'dictionary'.

    Hash collisions only ever *weaken pruning* (extra candidates), never drop a
    frequent gram: the final tau filter recounts exactly (see apriori_scan.py).
    This replaces the paper's BerkeleyDB / distributed-cache dictionary with a
    TPU-friendly sorted array + binary search.
    """
    h = gram_hash(lanes)
    h = jnp.where(valid, h, jnp.uint32(0xFFFFFFFF))
    return jnp.sort(h)


def member(sorted_hashes: jax.Array, queries: jax.Array) -> jax.Array:
    idx = jnp.searchsorted(sorted_hashes, queries)
    idx = jnp.minimum(idx, sorted_hashes.shape[0] - 1)
    return sorted_hashes[idx] == queries
