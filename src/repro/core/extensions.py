"""SSVI extensions of SUFFIX-sigma: maximal / closed n-grams, time-series aggregation.

Maximality needs only one-term extensions (the paper's two-stage scheme): r is
maximal iff no frequent r||<x> (right extension) and no frequent <y>||r (left
extension) -- any longer frequent supersequence implies a frequent one-term extension
by the APRIORI principle.  Stage 1 filters right extensions on the forward grams
("prefix-maximal"), stage 2 filters left extensions by re-running the same filter on
the *reversed* survivors (the paper's post-filtering job, SSVI-A).  Closedness is the
same with the extra cf-equality condition; the completeness argument chains equal
counts through intermediate extensions (cf monotone under subsequence).

The filter itself reuses the job's sort + run machinery: after sorting, the strings
extending r form the run of r's own prefix, so "a frequent extension exists" ==
"r's run at level |r| holds a longer row" (closed: "... with equal cf").

Document-frequency aggregation is intentionally NOT provided for SUFFIX-sigma: a
prefix-level *distinct*-doc count cannot be derived from one lexicographic sort pass
(distinct (prefix,doc) pairs are non-contiguous for prefixes shorter than the sort
key); it needs one pass per length -- the paper glosses over this ("can easily be
modified") and we document the gap instead of hiding it.  The implemented
beyond-counting instance is the paper's own concrete one: n-gram time series (SSVI-B),
via bucketed weights in the main job (``NGramConfig.n_buckets``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import sort
from .stats import NGramStats


def _prefix_extension_filter(grams: np.ndarray, lengths: np.ndarray,
                             counts: np.ndarray, closed: bool) -> np.ndarray:
    """Keep mask over rows: False where some other row extends the row's gram to the
    right (closed: with equal count).  Rows must be distinct grams."""
    m, sigma = grams.shape
    if m == 0:
        return np.zeros((0,), bool)
    vocab = int(grams.max()) if grams.size else 1
    lanes = packing.pack_terms(jnp.asarray(grams), vocab_size=max(1, vocab))
    keys, payload = sort.sort_with_payload(
        lanes, [jnp.asarray(lengths, jnp.int32), jnp.asarray(counts, jnp.int32),
                jnp.arange(m, dtype=jnp.int32)])
    terms = packing.unpack_terms(keys, vocab_size=max(1, vocab), sigma=sigma)
    lens_s, counts_s, orig = payload

    prev = jnp.roll(terms, 1, axis=0)
    eq = (terms == prev).astype(jnp.int32)
    lcp = jnp.sum(jnp.cumprod(eq, axis=1), axis=1).at[0].set(0)

    keep = jnp.ones((m,), bool)
    for level in range(1, sigma + 1):
        at_level = lens_s == level
        # runs of the level-prefix among rows with length >= level
        valid = lens_s >= level
        new_run = valid & ((lcp < level) | (jnp.arange(m) == 0))
        seg = jnp.maximum(jnp.cumsum(new_run.astype(jnp.int32)) - 1, 0)
        longer = valid & (lens_s > level)
        if closed:
            own = jnp.where(at_level, counts_s, -1)
            run_own = jax.ops.segment_max(own, seg, num_segments=m)  # cf of r itself
            hit = longer & (counts_s == run_own[seg])
        else:
            hit = longer
        run_hit = jax.ops.segment_max(hit.astype(jnp.int32), seg, num_segments=m)
        keep = keep & ~(at_level & (run_hit[seg] > 0) & valid)
    out = np.ones((m,), bool)
    out[np.asarray(orig)] = np.asarray(keep)
    return out


def _reverse_grams(grams: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    rev = np.zeros_like(grams)
    for i, l in enumerate(lengths):
        rev[i, :l] = grams[i, :l][::-1]
    return rev


def filter_stats(stats: NGramStats, mode: str) -> NGramStats:
    """Restrict job output to maximal or closed n-grams (mode in {max, closed})."""
    closed = mode == "closed"
    grams, lengths = stats.grams, stats.lengths
    counts = stats.counts.sum(axis=-1) if stats.counts.ndim == 2 else stats.counts
    keep1 = _prefix_extension_filter(grams, lengths, counts, closed)
    g1, l1, c1 = grams[keep1], lengths[keep1], stats.counts[keep1]
    flat1 = counts[keep1]
    rev = _reverse_grams(g1, l1)
    keep2 = _prefix_extension_filter(rev, l1, flat1, closed)
    counters = dict(stats.counters)
    counters["post_filter_jobs"] = 1  # the paper's extra MapReduce job
    return NGramStats(g1[keep2], l1[keep2], c1[keep2], counters)
