"""SUFFIX-sigma (Algorithm 4 of the paper) as a single distributed JAX job.

Phases (one MapReduce job, like the paper):

  map      -- per token position emit the sigma-truncated suffix (bit-packed lanes)
              with weight 1; optional map-side combine merges equal suffixes.
  shuffle  -- partition by hash(first term) -> all_to_all (repro.mapreduce.shuffle).
  sort     -- lexicographic multi-key sort of the packed lanes.
  reduce   -- the paper's two-stack streaming aggregation, re-expressed data-parallel:
              LCP boundaries between adjacent sorted suffixes delimit the runs of every
              distinct prefix; run totals are segmented sums of the weights.  This is
              exact: the stack state at row i in Algorithm 4 is precisely the common
              prefix of rows i-1 and i, and a "pop + emit" happens exactly at an LCP
              drop -- i.e. at a run boundary.

The reducer never needs the reverse-lexicographic trick: that ordering exists so a
*streaming* reducer can emit early with O(sigma) state; the data-parallel reducer
instead processes a whole sorted block at once with O(block * sigma) VMEM state and
emits everything at the end of the block, which is the natural TPU formulation
(DESIGN.md SS2).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle
from repro.pipeline import plan as plan_mod
from repro.pipeline import stages
from .stats import NGramConfig, NGramStats, add_counters

# --------------------------------------------------------------------------- map
@partial(jax.jit, static_argnames=("sigma",))
def suffix_windows(tokens: jax.Array, sigma: int) -> tuple[jax.Array, jax.Array]:
    """All sigma-truncated suffixes of a PAD-separated token stream.

    Returns (windows [N, sigma] int32 masked after the first PAD, valid [N] bool).
    """
    n = tokens.shape[0]
    padded = jnp.concatenate([tokens, jnp.zeros((sigma,), tokens.dtype)])
    idx = jnp.arange(n)[:, None] + jnp.arange(sigma)[None, :]
    w = padded[idx]
    keep = jnp.cumprod((w != 0).astype(jnp.int32), axis=1)
    return (w * keep).astype(jnp.int32), tokens != 0


def make_records(tokens: jax.Array, *, sigma: int, vocab_size: int,
                 bucket_ids: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Map emit: [N, W] uint32 records = packed lanes | weight | (bucket)."""
    windows, valid = suffix_windows(tokens, sigma)
    lanes = packing.pack_terms(windows, vocab_size=vocab_size)
    weight = valid.astype(jnp.uint32)
    cols = [lanes, weight[:, None]]
    if bucket_ids is not None:
        cols.append(bucket_ids.astype(jnp.uint32)[:, None])
    return jnp.concatenate(cols, axis=1), valid


# ------------------------------------------------------------------------ reduce
@partial(jax.jit, static_argnames=("sigma", "vocab_size", "n_buckets", "use_kernels"))
def reduce_block(records: jax.Array, *, sigma: int, vocab_size: int,
                 n_buckets: int = 0, use_kernels: bool = False):
    """Sort + count one reducer block (the fused form the distributed path
    calls; stage bodies live in ``pipeline.stages``).

    records: [N, W] = lanes | weight | (bucket).  Returns
    (terms [N, sigma], flags [N, sigma], counts [N, sigma] or [N, sigma, B]).
    """
    rec = stages.sort_stage(records, n_keys=packing.n_lanes(sigma, vocab_size))
    return stages.reduce_suffix(rec, sigma=sigma, vocab_size=vocab_size,
                                n_buckets=n_buckets, use_kernels=use_kernels)


# --------------------------------------------------------------------- job plan
def _plan_emit(tok_ext, aux_ext, n_live, cfg: NGramConfig, carry, k):
    """Map emit over one (possibly halo-extended) token window."""
    records, valid = make_records(tok_ext, sigma=cfg.sigma,
                                  vocab_size=cfg.lane_vocab,
                                  bucket_ids=aux_ext)
    pos_ok = jnp.arange(records.shape[0]) < n_live
    records = records * pos_ok[:, None].astype(records.dtype)
    valid = valid & pos_ok
    return records, valid, {}


def plan(cfg: NGramConfig) -> plan_mod.JobPlan:
    """SUFFIX-sigma as a :class:`JobPlan`: one job, suffix emit, optional
    combiner, lead-term partitioning, LCP-run reducer."""
    return plan_mod.JobPlan(
        name="suffix_sigma",
        map=plan_mod.MapStage(_plan_emit),
        combine=plan_mod.CombineStage(cfg.combine_route) if cfg.combine else None,
        shuffle=plan_mod.ShuffleStage("lead"),
        sort=plan_mod.SortStage(),
        reduce=plan_mod.ReduceStage("suffix"),
        lane_vocab=cfg.lane_vocab,
    )


# ------------------------------------------------------------------- distributed
def build_distributed_job(cfg: NGramConfig, mesh, axis_name: str, capacity: int,
                          has_bucket: bool = False):
    """Construct the (un-jitted) shard_map SUFFIX-sigma job for a mesh axis.

    Returned fn: (tokens [P, n_local], buckets [P, n_local] or dummy) ->
    (terms, flags, counts, stats) -- all sharded [P, ...].  Exposed separately so
    the dry-run can lower/compile the job on the production mesh (configs/paper.py).
    """
    n_parts = mesh.shape[axis_name]
    n_l = packing.n_lanes(cfg.sigma, cfg.lane_vocab)

    def job(tok, bkt):
        tok = tok[0]  # [n_local]
        # --- halo: suffixes near the shard end need the right neighbor's tokens.
        halo_src = tok[: cfg.sigma - 1] if cfg.sigma > 1 else tok[:0]
        if cfg.sigma > 1:
            perm = [(i, (i - 1) % n_parts) for i in range(n_parts)]
            halo = jax.lax.ppermute(halo_src, axis_name, perm)
            is_last = jax.lax.axis_index(axis_name) == n_parts - 1
            halo = jnp.where(is_last, jnp.zeros_like(halo), halo)
            tok_ext = jnp.concatenate([tok, halo])
        else:
            tok_ext = tok
        bucket = bkt[0] if has_bucket else None
        if bucket is not None and cfg.sigma > 1:
            bucket = jnp.concatenate([bucket, jnp.zeros((cfg.sigma - 1,), bucket.dtype)])
        records, valid = make_records(tok_ext, sigma=cfg.sigma,
                                      vocab_size=cfg.lane_vocab, bucket_ids=bucket)
        # halo positions belong to the neighbor: mask them out
        pos_ok = jnp.arange(records.shape[0]) < tok.shape[0]
        records = records * pos_ok[:, None].astype(records.dtype)
        valid = valid & pos_ok
        map_rec = jnp.sum(valid)
        if cfg.combine:
            records = stages.combine(records, n_l, has_bucket,
                                     route=cfg.combine_route,
                                     use_kernels=cfg.use_kernels)
        w = records[:, n_l]
        lead = packing.lead_term(records[:, 0], vocab_size=cfg.lane_vocab)
        local_rec, overflow = shuffle.shuffle(
            records, lead, w > 0, axis_name=axis_name, n_parts=n_parts,
            capacity=capacity)
        shuf_rec = jax.lax.psum(jnp.sum(local_rec[:, n_l] > 0), axis_name)
        terms, flags, counts = reduce_block(
            local_rec, sigma=cfg.sigma, vocab_size=cfg.lane_vocab,
            n_buckets=cfg.n_buckets, use_kernels=cfg.use_kernels)
        stats = jnp.stack([jax.lax.psum(map_rec, axis_name), shuf_rec, overflow])
        return terms[None], flags[None], counts[None], stats[None]

    from jax.sharding import PartitionSpec as P
    in_specs = (P(axis_name, None), P(axis_name, None) if has_bucket else P())
    out_specs = (P(axis_name), P(axis_name), P(axis_name), P(axis_name))
    return jax.shard_map(job, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_vma=False)


def _distributed(tokens_sharded: jax.Array, cfg: NGramConfig, mesh, axis_name: str,
                 bucket_sharded, capacity: int):
    """Run one distributed SUFFIX-sigma job (tokens_sharded: [P, n_local])."""
    has_bucket = bucket_sharded is not None
    fn = jax.jit(build_distributed_job(cfg, mesh, axis_name, capacity, has_bucket))
    bkt_arg = bucket_sharded if has_bucket else jnp.zeros((1, 1), jnp.uint32)
    return fn(tokens_sharded, bkt_arg)


# --------------------------------------------------------- two-phase sigma split
def sigma_split(tokens, cfg: NGramConfig, sigma_head: int = 16,
                survivor_frac: float = 1 / 64) -> "NGramStats":
    """Beyond-paper optimization (EXPERIMENTS.md SSPerf H3): split a large-sigma
    job into

      phase A: plain SUFFIX-sigma at sigma_head -- handles every gram of length
               <= sigma_head with (sigma_head+1)-lane records instead of
               (sigma+1)-lane ones (the sort bytes scale with the lane count);
      phase B: only positions whose length-sigma_head head gram is frequent
               (APRIORI: any frequent longer gram's occurrences all pass this
               filter) emit full sigma-truncated suffixes; their count is tiny at
               analytics-scale tau (the paper's Fig. 2 tail), so the wide-record
               sort shrinks by ~1/survivor rate.

    Exact: phase A counts lengths <= sigma_head; phase B counts lengths in
    (sigma_head, sigma] -- every occurrence of a frequent long gram survives the
    head filter, and partition-by-first-term still routes all evidence of a gram
    to one reducer.  survivor_frac only sizes buffers (validated by an overflow
    counter upstream).
    """
    tokens = jnp.asarray(tokens, jnp.int32)
    if sigma_head >= cfg.sigma:
        return run(tokens, cfg)
    cfg_a = dataclasses.replace(cfg, sigma=sigma_head)
    stats_a = run(tokens, cfg_a)

    # frequent head set (the APRIORI dictionary, as in apriori_scan)
    from .common import gram_hash, member, membership_hashes
    full_len = stats_a.lengths == sigma_head
    heads = jnp.asarray(stats_a.grams[full_len])
    if heads.shape[0] == 0:
        return stats_a
    head_pad = jnp.zeros((heads.shape[0], cfg.sigma), jnp.int32
                         ).at[:, :sigma_head].set(heads[:, :sigma_head])
    dict_hashes = membership_hashes(
        packing.pack_terms(head_pad, vocab_size=cfg.vocab_size),
        jnp.ones((heads.shape[0],), bool))

    # phase B: mask positions by head membership, count lengths > sigma_head
    windows, valid = suffix_windows(tokens, cfg.sigma)
    head_mask = jnp.arange(cfg.sigma) < sigma_head
    head_grams = windows * head_mask[None, :].astype(windows.dtype)
    has_full_head = windows[:, sigma_head - 1] != 0 if sigma_head > 1 \
        else windows[:, 0] != 0
    h = gram_hash(packing.pack_terms(head_grams, vocab_size=cfg.vocab_size))
    eligible = valid & has_full_head & member(dict_hashes, h)

    # compact survivor POSITIONS first (single-lane sort), then build the wide
    # records only for them -- the wide-record sort shrinks by 1/survivor_frac,
    # which is the whole point (EXPERIMENTS.md SSPerf H3 napkin math).
    n_b = max(64, int(tokens.shape[0] * survivor_frac))
    pos = jnp.argsort(~eligible, stable=True)[:n_b]
    ok = eligible[pos]
    padded = jnp.concatenate([tokens, jnp.zeros((cfg.sigma,), tokens.dtype)])
    win_b = padded[pos[:, None] + jnp.arange(cfg.sigma)[None, :]]
    keep = jnp.cumprod((win_b != 0).astype(jnp.int32), axis=1)
    win_b = (win_b * keep) * ok[:, None].astype(win_b.dtype)
    lanes_b = packing.pack_terms(win_b.astype(jnp.int32), vocab_size=cfg.vocab_size)
    records = jnp.concatenate([lanes_b, ok.astype(jnp.uint32)[:, None]], axis=1)
    terms, flags, counts = reduce_block(
        records, sigma=cfg.sigma, vocab_size=cfg.vocab_size,
        use_kernels=cfg.use_kernels)
    # keep only lengths > sigma_head (phase A owns the rest)
    flags = np.array(flags)
    flags[:, :sigma_head] = False
    stats_b = NGramStats.from_dense(np.asarray(terms), flags, np.asarray(counts),
                                    cfg.tau)
    # one blocking device round trip for the survivor counter, reused for
    # both the overflow check and the counter bookkeeping below
    n_eligible = int(jnp.sum(eligible))
    dropped = n_eligible - n_b
    stats_a = NGramStats(
        np.pad(stats_a.grams, ((0, 0), (0, cfg.sigma - sigma_head))),
        stats_a.lengths, stats_a.counts, stats_a.counters)
    out = stats_a.merged_with(stats_b)
    add_counters(out.counters, phase_b_records=n_eligible,
                 phase_b_overflow=max(0, dropped))
    if dropped > 0:
        # survivor buffer too small -- rerun exact (counters expose the retry)
        return sigma_split(tokens, cfg, sigma_head,
                           survivor_frac=min(1.0, survivor_frac * 4))
    return out


def run(tokens, cfg: NGramConfig, mesh=None, axis_name: str = "data",
        bucket_ids=None) -> NGramStats:
    """Run a SUFFIX-sigma job.  ``tokens``: 1-D int32, PAD(0)-separated documents."""
    tokens = jnp.asarray(tokens, jnp.int32)
    bkt = None if bucket_ids is None else jnp.asarray(bucket_ids, jnp.uint32)
    if mesh is None or mesh.size == 1:
        from repro.pipeline.executor import run_plan
        return run_plan(tokens, cfg, bucket_ids=bkt, plan=plan(cfg))

    n_parts = mesh.shape[axis_name]
    n = tokens.shape[0]
    n_local = -(-n // n_parts)
    pad = n_local * n_parts - n
    tokens_p = jnp.pad(tokens, (0, pad)).reshape(n_parts, n_local)
    bkt_p = (jnp.pad(bkt, (0, pad)).reshape(n_parts, n_local)
             if bkt is not None else None)

    capacity = max(8, int(cfg.capacity_factor * n_local / n_parts) + 1)
    for attempt in range(6):  # overflow -> double capacity and re-run (see shuffle.py)
        terms, flags, counts, stats = _distributed(
            tokens_p, cfg, mesh, axis_name, bkt_p, capacity)
        stats_np = np.asarray(stats)
        overflow = int(stats_np[:, 2].max())
        if overflow == 0:
            break
        capacity *= 2
    else:
        raise RuntimeError(f"shuffle overflow persisted at capacity {capacity}")

    rec_bytes = packing.record_bytes(cfg.sigma, cfg.lane_vocab,
                                     n_meta=1 if bkt is not None else 0)
    counters = {
        "map_records": int(stats_np[0, 0]),
        "shuffle_records": int(stats_np[0, 1]),
        "shuffle_bytes": int(stats_np[0, 1]) * rec_bytes,
        "jobs": 1,
        "overflow": overflow,
        "capacity": capacity,
        "retries": attempt,
    }
    out = None
    terms, flags, counts = np.asarray(terms), np.asarray(flags), np.asarray(counts)
    for p in range(n_parts):
        part = NGramStats.from_dense(terms[p], flags[p], counts[p], cfg.tau,
                                     counters if p == 0 else {})
        out = part if out is None else out.merged_with(part)
    return out
