"""The paper's contribution: n-gram statistics methods on the MapReduce-on-JAX
substrate.  ``run_job`` dispatches on ``NGramConfig.method``."""
from __future__ import annotations

from . import (aggregations, apriori_index, apriori_scan, extensions, naive,
               oracle, suffix_sigma)
from .extensions import filter_stats as extensions_filter
from .stats import NGramConfig, NGramStats

METHODS = {
    "suffix_sigma": suffix_sigma.run,
    "naive": naive.run,
    "apriori_scan": apriori_scan.run,
    "apriori_index": apriori_index.run,
}

# method name -> JobPlan builder (cfg -> JobPlan); the declarative form the
# wave executor (repro.pipeline) interprets
PLANS = {
    "suffix_sigma": suffix_sigma.plan,
    "naive": naive.plan,
    "apriori_scan": apriori_scan.plan,
    "apriori_index": apriori_index.plan,
}


def run_job(tokens, cfg: NGramConfig, mesh=None, axis_name: str = "data",
            **kw) -> NGramStats:
    try:
        fn = METHODS[cfg.method]
    except KeyError:
        raise ValueError(f"unknown method {cfg.method!r}; options: {sorted(METHODS)}")
    return fn(tokens, cfg, mesh=mesh, axis_name=axis_name, **kw)


__all__ = ["NGramConfig", "NGramStats", "run_job", "METHODS", "PLANS", "oracle",
           "suffix_sigma", "naive", "apriori_scan", "apriori_index",
           "extensions", "extensions_filter"]
