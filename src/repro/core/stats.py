"""Containers for n-gram statistics jobs and their outputs.

``NGramStats`` mirrors what a Hadoop job leaves in HDFS (the (n-gram, cf) pairs) plus
the counters the paper reports for every experiment: MAP_OUTPUT_RECORDS and
MAP_OUTPUT_BYTES analogues, measured *exactly* by the pipelines.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import numpy as np

# Any vocab with >= 17 id bits packs one term per 32-bit lane; this is the
# canonical "packing off" value ``NGramConfig.pack_vocab`` resolves to.
UNPACKED_VOCAB = 1 << 30


@dataclass(frozen=True)
class NGramConfig:
    """Problem statement of the paper (SSIII): report every n-gram s with
    cf(s) >= tau and |s| <= sigma.

    Token-id convention (reserved id 0): term ids are ``1..vocab_size``;
    **id 0 is the PAD / document separator** and is never counted as a term
    -- every phase (window masking, lane packing, record validity) treats a
    zero as "no token here".  A tokenizer that emits 0 for a real word must
    remap to ``1..vocab_size`` first, or its counts are silently wrong.
    :meth:`validate_tokens` enforces the representable range loudly: an id
    above ``vocab_size`` would overflow its bit-packed lane field and
    fabricate grams, an id below 0 would alias through the uint32 casts.
    """

    sigma: int
    tau: int
    vocab_size: int
    method: str = "suffix_sigma"
    # --- implementation knobs -------------------------------------------------
    capacity_factor: float = 1.25   # shuffle buffer head-room per (src, dst) pair
    combine: bool = True            # map-side pre-aggregation (Hadoop combiner)
    combine_route: str = "sort"     # "sort" (run-merge) | "hash" (slot kernel)
    pack: bool = True               # bit-pack term lanes (SSV sequence encoding)
    # Explicit override of the vocabulary the lane packer sees (>0 wins); 0
    # (default) derives it per ``pack``: ``vocab_size`` when packing, else
    # ``UNPACKED_VOCAB`` -- a vocab large enough that ``pack.terms_per_lane``
    # is 1, i.e. one term per 32-bit sort lane (the SSV sequence-encoding
    # ablation: more sort passes, more shuffled bytes).  Every phase reads the
    # derived ``lane_vocab`` property, which stays consistent under
    # ``dataclasses.replace`` (nothing is baked in at construction).
    pack_vocab: int = 0
    split_docs: bool = True         # split documents at infrequent terms (SSV)
    apriori_index_k: int = 4        # K of APRIORI-INDEX (paper's calibrated value)
    n_buckets: int = 0              # >0: aggregate per-bucket time series (SSVI-B)
    use_kernels: bool = False       # route reducer through Pallas kernels (interpret on CPU)

    def __post_init__(self):
        if self.sigma < 1:
            raise ValueError("sigma must be >= 1")
        if self.tau < 1:
            raise ValueError("tau must be >= 1")
        if self.combine_route not in ("sort", "hash"):
            raise ValueError(f"unknown combine_route {self.combine_route!r}")
        if self.pack_vocab and not self.pack_vocab >= self.vocab_size:
            # a packer vocab below vocab_size would overlap term bit fields
            # and silently fabricate grams
            raise ValueError(
                f"pack_vocab {self.pack_vocab} must be 0 (derive) or >= "
                f"vocab_size {self.vocab_size}")

    @property
    def lane_vocab(self) -> int:
        """Effective vocabulary for lane packing (see ``pack_vocab``)."""
        if self.pack_vocab:
            return self.pack_vocab
        return self.vocab_size if self.pack else max(self.vocab_size,
                                                     UNPACKED_VOCAB)

    def validate_tokens(self, tokens) -> None:
        """Refuse a corpus that violates the reserved-id-0 convention's range.

        Token ids must lie in ``[0, vocab_size]`` -- 0 is the PAD / document
        separator (see the class docstring), ``1..vocab_size`` are terms.
        Out-of-range ids would not fail downstream: an id past ``vocab_size``
        overflows its packed lane bit field and *fabricates* grams, a
        negative id wraps through the uint32 casts -- both silently
        miscount, so the wave executor checks here instead.
        """
        t = np.asarray(tokens)
        if t.size == 0:
            return
        lo, hi = int(t.min()), int(t.max())
        if lo < 0 or hi > self.vocab_size:
            raise ValueError(
                f"token ids must lie in [0, {self.vocab_size}] (0 is the "
                "reserved PAD/document separator and is never counted as a "
                f"term; remap a tokenizer that uses 0 for a real word); got "
                f"ids in [{lo}, {hi}]")


@dataclass
class NGramStats:
    """Dense job output.

    grams   : [R, sigma] int32, right-padded with PAD(0)
    lengths : [R] int32
    counts  : [R] int64 collection frequencies (or [R, B] bucketed series)
    counters: exact shuffle/record accounting per phase
    """

    grams: np.ndarray
    lengths: np.ndarray
    counts: np.ndarray
    counters: dict[str, float] = field(default_factory=dict)

    def __len__(self) -> int:
        return int(self.grams.shape[0])

    def to_dict(self) -> dict[tuple[int, ...], int]:
        out: dict[tuple[int, ...], int] = {}
        for g, l, c in zip(self.grams, self.lengths, self.counts):
            key = tuple(int(x) for x in g[: int(l)])
            val = int(c.sum()) if np.ndim(c) else int(c)
            prev = out.get(key)
            out[key] = val if prev is None else prev + val
        return out

    def to_series_dict(self) -> dict[tuple[int, ...], np.ndarray]:
        assert self.counts.ndim == 2, "job was not run with n_buckets > 0"
        return {
            tuple(int(x) for x in g[: int(l)]): c.copy()
            for g, l, c in zip(self.grams, self.lengths, self.counts)
        }

    @staticmethod
    def from_dense(sorted_terms: np.ndarray, flags: np.ndarray, counts: np.ndarray,
                   tau: int, counters: dict[str, float] | None = None) -> "NGramStats":
        """Extract (gram, count) rows from the dense reducer output.

        sorted_terms: [N, sigma]; flags: [N, sigma] boundary flags; counts: [N, sigma]
        (or [N, sigma, B]) run totals at boundary positions.
        """
        total = counts.sum(axis=-1) if counts.ndim == 3 else counts
        keep = flags & (total >= tau)
        rows, lens0 = np.nonzero(keep)
        sigma = sorted_terms.shape[1]
        lengths = (lens0 + 1).astype(np.int32)
        keep_pos = np.arange(sigma, dtype=np.int32)[None, :] < lengths[:, None]
        grams = sorted_terms[rows].astype(np.int32) * keep_pos
        cvals = counts[rows, lens0].astype(np.int64)
        return NGramStats(grams, lengths, cvals, dict(counters or {}))

    def merged_with(self, other: "NGramStats") -> "NGramStats":
        counters = dict(self.counters)
        for k, v in other.counters.items():
            counters[k] = counters.get(k, 0) + v
        return NGramStats(
            np.concatenate([self.grams, other.grams], axis=0),
            np.concatenate([self.lengths, other.lengths], axis=0),
            np.concatenate([self.counts, other.counts], axis=0),
            counters,
        )


def add_counters(dst: dict[str, float], **kv: float) -> dict[str, float]:
    for k, v in kv.items():
        dst[k] = dst.get(k, 0) + float(v)
    return dst
