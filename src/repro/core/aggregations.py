"""Aggregations beyond occurrence counting (SSII / SSVI-B).

Document frequency (df): the frequent-sequence-mining notion of support.  The
paper notes every method "can easily be modified" to produce df; concretely that
is a per-(gram, document) dedup before counting -- for the whole-gram methods
(NAIVE-style) a map-side dedup does it in one job, implemented here.  For
SUFFIX-sigma the prefix-level distinct-doc count is NOT derivable from one
lexicographic pass (distinct (prefix, doc) pairs are non-contiguous below the
full sort key) -- see extensions.py for the documented gap; ``df_suffix_lengths``
provides the per-length multi-pass variant (sigma passes, each exact).

Inverted index: SUFFIX-sigma's sorted runs *are* posting lists -- each frequent
gram's run holds exactly the (doc, multiplicity) evidence; ``postings`` extracts
them (host side) from a doc-id-tagged job.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import sort
from .common import count_exact_grams
from .stats import NGramConfig, NGramStats
from .suffix_sigma import suffix_windows


def doc_ids_from_stream(tokens) -> np.ndarray:
    """Dense document id per token position (empty documents -- consecutive
    separators -- don't consume ids, matching the oracle's doc enumeration)."""
    toks = np.asarray(tokens)
    raw = np.concatenate([[0], np.cumsum(toks == 0)[:-1]])
    live = np.unique(raw[toks != 0]) if (toks != 0).any() else np.asarray([0])
    return np.searchsorted(live, raw).astype(np.int32)


def document_frequencies(tokens, cfg: NGramConfig) -> NGramStats:
    """df for all n-grams <= sigma: one job, map-side (gram, doc) dedup.

    Map emits every (gram, doc) pair once (dedup via sort on [lanes | doc]);
    reduce counts distinct docs per gram -- weight 1 per surviving pair."""
    tokens = jnp.asarray(tokens, jnp.int32)
    dids = jnp.asarray(doc_ids_from_stream(tokens), jnp.uint32)
    windows, _ = suffix_windows(tokens, cfg.sigma)
    n, sigma = windows.shape
    lmask = jnp.tril(jnp.ones((sigma, sigma), jnp.int32))
    grams = (windows[:, None, :] * lmask[None]).reshape(n * sigma, sigma)
    valid = (windows != 0).reshape(-1)
    grams = grams * valid[:, None]
    lanes = packing.pack_terms(grams, vocab_size=cfg.vocab_size)
    doc = jnp.repeat(dids, sigma)
    rec = jnp.concatenate([lanes, doc[:, None],
                           valid.astype(jnp.uint32)[:, None]], axis=1)
    n_l = lanes.shape[1]
    rec = sort.sort_records(rec, n_keys=n_l + 1)          # sort by (gram, doc)
    keys = rec[:, : n_l + 1]
    first = jnp.any(keys != jnp.roll(keys, 1, axis=0), axis=1).at[0].set(True)
    w = jnp.where(first & (rec[:, -1] > 0), jnp.uint32(1), jnp.uint32(0))
    rec = rec.at[:, -1].set(w)                             # dedup: one per (g, d)
    dedup = jnp.concatenate([rec[:, :n_l], rec[:, -1:]], axis=1)
    terms, flags, counts = count_exact_grams(dedup, sigma=cfg.sigma,
                                             vocab_size=cfg.vocab_size)
    return NGramStats.from_dense(np.asarray(terms), np.asarray(flags),
                                 np.asarray(counts), cfg.tau,
                                 {"map_records": int(valid.sum()), "jobs": 1})


def df_suffix_lengths(tokens, cfg: NGramConfig) -> NGramStats:
    """SUFFIX-sigma-flavoured df: one narrow pass per length (sigma jobs), each
    an exact distinct-doc count for that length -- the honest multi-pass cost of
    df under suffix partitioning (extensions.py explains why one pass can't)."""
    out: NGramStats | None = None
    import dataclasses
    for l in range(1, cfg.sigma + 1):
        c = dataclasses.replace(cfg, sigma=l)
        st = document_frequencies(tokens, c)
        keep = st.lengths == l
        part = NGramStats(
            np.pad(st.grams[keep], ((0, 0), (0, cfg.sigma - l))),
            st.lengths[keep], st.counts[keep],
            {"jobs": 1} if out is None else {})
        out = part if out is None else out.merged_with(part)
    out.counters["jobs"] = cfg.sigma
    return out


def postings(tokens, cfg: NGramConfig) -> dict[tuple[int, ...], dict[int, int]]:
    """Inverted index from SUFFIX-sigma's sorted runs: doc->count per frequent
    gram.  Host-side extraction over the (suffix, doc) sorted block."""
    tokens = jnp.asarray(tokens, jnp.int32)
    dids = jnp.asarray(doc_ids_from_stream(tokens), jnp.uint32)
    windows, valid = suffix_windows(tokens, cfg.sigma)
    lanes = packing.pack_terms(windows, vocab_size=cfg.vocab_size)
    rec = jnp.concatenate([lanes, dids[:, None],
                           valid.astype(jnp.uint32)[:, None]], axis=1)
    n_l = lanes.shape[1]
    rec = sort.sort_records(rec, n_keys=n_l + 1)
    terms = np.asarray(packing.unpack_terms(rec[:, :n_l],
                                            vocab_size=cfg.vocab_size,
                                            sigma=cfg.sigma))
    docs = np.asarray(rec[:, n_l])
    w = np.asarray(rec[:, n_l + 1])
    # host scan: runs of each prefix are contiguous; accumulate doc multisets
    from collections import Counter, defaultdict
    acc: dict[tuple[int, ...], Counter] = defaultdict(Counter)
    for row, doc, weight in zip(terms, docs, w):
        if weight == 0:
            continue
        for l in range(1, cfg.sigma + 1):
            if row[l - 1] == 0:
                break
            acc[tuple(int(t) for t in row[:l])][int(doc)] += 1
    return {g: dict(c) for g, c in acc.items()
            if sum(c.values()) >= cfg.tau}
