"""Fused hash + partition-id + histogram kernel -- the shuffle partitioner.

Computes each record's reducer (multiplicative hash of the lead term mod P) and the
per-partition record histogram in one pass.  The histogram is what sizes the
all_to_all capacity check; fusing it with the hash avoids a second HBM pass and a
one-hot materialization ([N, P] ints in XLA's unfused form).

Each grid block writes its own histogram row; the caller sums rows (a [nb, P]
reduction -- negligible next to the [N] pass).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(n_parts: int):
    def kernel(keys_ref, valid_ref, part_ref, hist_ref):
        k = keys_ref[...].astype(jnp.uint32)
        h = k * jnp.uint32(2654435761)
        h = h ^ (h >> 15)
        h = h * jnp.uint32(2246822519)
        h = h ^ (h >> 13)
        p = (h % jnp.uint32(n_parts)).astype(jnp.int32)
        p = jnp.where(valid_ref[...], p, n_parts)
        part_ref[...] = p
        # iota, not arange (arange would become a captured constant -- rejected)
        ids = jax.lax.broadcasted_iota(jnp.int32, (n_parts,), 0)
        hist_ref[...] = jnp.sum((p[:, None] == ids[None, :]).astype(jnp.int32),
                                axis=0, keepdims=True)

    return kernel


@partial(jax.jit, static_argnames=("n_parts", "block", "interpret"))
def hash_partition(keys: jax.Array, valid: jax.Array, *, n_parts: int,
                   block: int = 4096, interpret: bool = True
                   ) -> tuple[jax.Array, jax.Array]:
    """(partition ids [N] int32 -- n_parts marks invalid, histogram [n_parts])."""
    n = keys.shape[0]
    nb = -(-n // block)
    n_pad = nb * block
    k = jnp.pad(keys.astype(jnp.uint32), (0, n_pad - n))
    v = jnp.pad(valid, (0, n_pad - n))  # padding rows invalid -> drop bucket

    part, hist = pl.pallas_call(
        _make_kernel(n_parts),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1, n_parts), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((nb, n_parts), jnp.int32),
        ],
        interpret=interpret,
    )(k, v)
    return part[:n], jnp.sum(hist, axis=0)
