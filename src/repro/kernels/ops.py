"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in interpret mode; on TPU they compile to
Mosaic.  ``INTERPRET`` flips automatically from the backend.
"""
from __future__ import annotations

import jax

from .block_decode import block_decode as _block_decode
from .block_expand import block_expand as _block_expand
from .bsearch import bsearch as _bsearch
from .hash_combine import hash_combine as _hash_combine
from .hash_partition import hash_partition as _hash_partition
from .lcp_boundary import lcp_boundary as _lcp_boundary
from .merge_path import merge_path as _merge_path
from .suffix_pack import suffix_pack as _suffix_pack

INTERPRET = jax.default_backend() != "tpu"


def lcp_boundary(sorted_terms, *, block_rows: int = 512):
    return _lcp_boundary(sorted_terms, block_rows=block_rows, interpret=INTERPRET)


def bsearch(lanes, queries, lo, hi, *, upper: bool = False,
            steps: int | None = None, block: int = 1024):
    return _bsearch(lanes, queries, lo, hi, upper=upper, steps=steps,
                    block=block, interpret=INTERPRET)


def suffix_pack(tokens, *, sigma: int, vocab_size: int, block: int = 1024):
    return _suffix_pack(tokens, sigma=sigma, vocab_size=vocab_size, block=block,
                        interpret=INTERPRET)


def hash_partition(keys, valid, *, n_parts: int, block: int = 4096):
    return _hash_partition(keys, valid, n_parts=n_parts, block=block,
                           interpret=INTERPRET)


def hash_combine(keys, weights, *, block: int = 256):
    return _hash_combine(keys, weights, block=block, interpret=INTERPRET)


def merge_path(a_keys, b_keys, a_vals, b_vals, *, block: int = 1024):
    return _merge_path(a_keys, b_keys, a_vals, b_vals, block=block,
                       interpret=INTERPRET)


def block_decode(lcps, payload, block_base, sec_starts, blk, q_terms, q_len, *,
                 term_bits: int, lcp_width: int, block_size: int, len_off: int,
                 qblock: int = 256):
    return _block_decode(lcps, payload, block_base, sec_starts, blk, q_terms,
                         q_len, term_bits=term_bits, lcp_width=lcp_width,
                         block_size=block_size, len_off=len_off, qblock=qblock,
                         interpret=INTERPRET)


def block_expand(lcps, payload, block_base, sec_starts, blk, *, sigma: int,
                 term_bits: int, lcp_width: int, block_size: int, len_off: int,
                 bblock: int = 256):
    return _block_expand(lcps, payload, block_base, sec_starts, blk,
                         sigma=sigma, term_bits=term_bits, lcp_width=lcp_width,
                         block_size=block_size, len_off=len_off, bblock=bblock,
                         interpret=INTERPRET)
