"""Pallas TPU kernels for the SUFFIX-sigma hot spots (validated in interpret mode
on CPU; see each module's docstring for the VMEM tiling rationale):

  lcp_boundary   -- reducer inner loop (LCP + per-length boundary flags)
  suffix_pack    -- map emit (windowed gather + bit pack, fused)
  hash_partition -- shuffle partitioner (hash + histogram, fused)
  hash_combine   -- sort-free map-side combiner (block-local hash slots)
  bsearch        -- index serving inner loop (batched lexicographic bounds)
  block_decode   -- compressed-index in-block decode + rank
  merge_path     -- stable two-way merge of sorted segments (LSM compaction)
"""
from . import ops, ref
from .block_decode import block_decode
from .bsearch import bsearch
from .hash_combine import hash_combine
from .hash_partition import hash_partition
from .lcp_boundary import lcp_boundary
from .merge_path import merge_path
from .suffix_pack import suffix_pack

__all__ = ["ops", "ref", "lcp_boundary", "suffix_pack", "hash_partition",
           "hash_combine", "bsearch", "block_decode", "merge_path"]
