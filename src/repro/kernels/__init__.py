"""Pallas TPU kernels for the SUFFIX-sigma hot spots (validated in interpret mode
on CPU; see each module's docstring for the VMEM tiling rationale):

  lcp_boundary   -- reducer inner loop (LCP + per-length boundary flags)
  suffix_pack    -- map emit (windowed gather + bit pack, fused)
  hash_partition -- shuffle partitioner (hash + histogram, fused)
  bsearch        -- index serving inner loop (batched lexicographic bounds)
"""
from . import ops, ref
from .bsearch import bsearch
from .hash_partition import hash_partition
from .lcp_boundary import lcp_boundary
from .suffix_pack import suffix_pack

__all__ = ["ops", "ref", "lcp_boundary", "suffix_pack", "hash_partition",
           "bsearch"]
