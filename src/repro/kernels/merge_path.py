"""Merge-path kernel: stable two-way merge of sorted packed-lane runs.

The generational index (``repro.index.merge``) turns "refresh the index" from a
full re-sort into a merge of already-sorted immutable segments.  XLA has no
merge primitive -- the fallback re-sorts the concatenation (O((M+N) log(M+N))
sort passes per lane) -- but two sorted runs admit the classic GPU *Merge Path*
decomposition (Green et al.): output position d corresponds to one point on the
monotone staircase path through the (A, B) comparison grid, and that point is
findable by a log2(min(M, N))-step binary search along the diagonal i + j = d,
independently per output element.  The kernel runs one such fixed-trip search
for every output row of its block in lockstep (branchless, no divergence) and
gathers the winning row -- gather-based, scatter-free, which is also the cheap
direction on CPU.

Tie-break is stable with A first: among equal keys every A row precedes every
B row, so merging (older-segment, newer-segment) keeps duplicate grams adjacent
and in generation order for the downstream run-fold.

TPU mapping: output rows tile the grid; both input runs ride whole as block
inputs (same VMEM-residency contract as ``bsearch``: an index segment is
(1+L)*4 bytes/row -- shard over the mesh before a segment outgrows VMEM).  The
per-step probes are VMEM dynamic takes along the row axis; lexicographic
compares are uint32 VPU ops.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bsearch import search_steps


def _lex_gt(x: jax.Array, y: jax.Array) -> jax.Array:
    """Row-wise lexicographic x > y over trailing lane axis -> [...] bool."""
    eq = x == y
    b = x.shape[:-1]
    prefix_eq = jnp.concatenate(
        [jnp.ones(b + (1,), jnp.bool_),
         jnp.cumprod(eq[..., :-1].astype(jnp.int32), axis=-1).astype(bool)],
        axis=-1)
    return jnp.any(prefix_eq & (x > y), axis=-1)


def _make_kernel(m: int, n: int, steps: int):
    def kernel(a_ref, b_ref, av_ref, bv_ref, keys_ref, vals_ref):
        a = a_ref[...]                               # [M, K]
        b = b_ref[...]                               # [N, K]
        blk = keys_ref.shape[0]
        # global output positions of this block's rows
        d = (jax.lax.broadcasted_iota(jnp.int32, (blk,), 0)
             + pl.program_id(0) * blk)

        # diagonal search: smallest i in [max(0, d-N), min(d, M)] such that
        # A[i] > B[d-1-i] (out-of-range A -> +inf, out-of-range B -> -inf);
        # monotone in i, so a fixed-trip bracket search finds it
        lo = jnp.maximum(d - n, 0)
        hi = jnp.minimum(d, m)

        def body(_, state):
            lo_c, hi_c = state
            i = jax.lax.div(lo_c + hi_c, 2)
            j = d - 1 - i
            a_row = jnp.take(a, jnp.clip(i, 0, m - 1), axis=0)
            b_row = jnp.take(b, jnp.clip(j, 0, n - 1), axis=0)
            # predicate G(i): the (i+1)-th A row does NOT belong in the first d
            g = (i >= m) | (j < 0) | _lex_gt(a_row, b_row)
            open_ = lo_c < hi_c
            lo_c = jnp.where(open_ & ~g, i + 1, lo_c)
            hi_c = jnp.where(open_ & g, i, hi_c)
            return lo_c, hi_c

        i, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
        j = d - i
        a_row = jnp.take(a, jnp.clip(i, 0, m - 1), axis=0)
        b_row = jnp.take(b, jnp.clip(j, 0, n - 1), axis=0)
        # stable A-first: take A unless exhausted or B's row is strictly smaller
        take_a = (i < m) & ((j >= n) | ~_lex_gt(a_row, b_row))
        keys_ref[...] = jnp.where(take_a[:, None], a_row, b_row)
        vals_ref[...] = jnp.where(take_a,
                                  jnp.take(av_ref[...], jnp.clip(i, 0, m - 1)),
                                  jnp.take(bv_ref[...], jnp.clip(j, 0, n - 1)))

    return kernel


@partial(jax.jit, static_argnames=("block", "interpret"))
def merge_path(a_keys: jax.Array, b_keys: jax.Array, a_vals: jax.Array,
               b_vals: jax.Array, *, block: int = 1024,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Stable merge of two sorted runs -> (keys [M+N, K], vals [M+N]).

    a_keys/b_keys : [M, K] / [N, K] uint32, rows sorted lexicographically
    a_vals/b_vals : [M] / [N] payload rows riding along (counts)
    Ties keep every A row before every B row (generation order).
    """
    m, k = a_keys.shape
    n = b_keys.shape[0]
    if m == 0:
        return b_keys, b_vals
    if n == 0:
        return a_keys, a_vals
    out = m + n
    steps = search_steps(min(m, n) + 1)
    nb = max(1, -(-out // block))

    keys, vals = pl.pallas_call(
        _make_kernel(m, n, steps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * block, k), a_keys.dtype),
            jax.ShapeDtypeStruct((nb * block,), a_vals.dtype),
        ],
        interpret=interpret,
    )(a_keys, b_keys, a_vals, b_vals)
    return keys[:out], vals[:out]
