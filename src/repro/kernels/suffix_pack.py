"""Fused suffix-window + bit-pack kernel -- the SUFFIX-sigma map emit.

The map phase turns a token block [B] into packed suffix lanes [B, n_lanes]:
window gather (sigma shifted copies), PAD masking (cumulative AND after the first
separator), and most-significant-first bit packing.  Unfused, XLA materializes the
[B, sigma] window matrix in HBM (sigma x write amplification); the kernel keeps the
window in VREGs and writes only the packed lanes (e.g. sigma=5 packed into 2 lanes:
2.5x less HBM traffic on the hot path).

Halo handling: windows starting near the block end read into the next block, so the
kernel gets the *next* token block as a second ref (index_map i -> i+1, with the
caller appending one all-PAD block so the clamp at the last block is harmless).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.mapreduce import pack as packing


def _make_kernel(sigma: int, vocab_size: int, block: int):
    bits = packing.bits_for_vocab(vocab_size)
    per = packing.terms_per_lane(vocab_size)
    lanes = packing.n_lanes(sigma, vocab_size)

    def kernel(cur_ref, nxt_ref, out_ref):
        cur = cur_ref[...]
        nxt = nxt_ref[...]
        both = jnp.concatenate([cur, nxt])
        alive = jnp.ones((block,), jnp.uint32)
        acc = [jnp.zeros((block,), jnp.uint32) for _ in range(lanes)]
        for j in range(sigma):
            tok = jax.lax.dynamic_slice(both, (j,), (block,)).astype(jnp.uint32)
            alive = alive * (tok != 0).astype(jnp.uint32)  # mask after first PAD
            tok = tok * alive
            lane, slot = divmod(j, per)
            acc[lane] = acc[lane] + (tok << jnp.uint32(bits * (per - 1 - slot)))
        out_ref[...] = jnp.stack(acc, axis=1)

    return kernel


@partial(jax.jit, static_argnames=("sigma", "vocab_size", "block", "interpret"))
def suffix_pack(tokens: jax.Array, *, sigma: int, vocab_size: int, block: int = 1024,
                interpret: bool = True) -> jax.Array:
    """Packed sigma-truncated suffixes [N, n_lanes] of a PAD-separated stream."""
    n = tokens.shape[0]
    nb = -(-n // block)
    n_pad = nb * block
    # one extra all-PAD block so the last block's `next` ref stays in bounds
    toks = jnp.pad(tokens.astype(jnp.int32), (0, n_pad - n + block))
    lanes = packing.n_lanes(sigma, vocab_size)
    if sigma > block:
        raise ValueError("sigma must not exceed the block size")

    out = pl.pallas_call(
        _make_kernel(sigma, vocab_size, block),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i + 1,)),
        ],
        out_specs=pl.BlockSpec((block, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, lanes), jnp.uint32),
        interpret=interpret,
    )(toks, toks)
    return out[:n]
