"""Front-coded block decode + in-block rank kernel -- the compressed-index
serving inner loop.

After the head binary search picks each query's candidate block (see
``repro.index.compress``), the query needs the number of block rows whose
(length, terms) key sorts strictly below / equal to its own -- that rank, plus
``block * block_size``, is the global lower/upper bound position.  XLA's unfused
form materializes a [Q, block, sigma] decoded tensor in HBM; the kernel instead
walks the block's front-coding chain once per query tile entirely in VMEM,
reconstructing each row from the packed lcp / suffix-term streams and folding
the lexicographic comparison into the same pass, so only the two rank counters
ever leave the core.

TPU mapping: query tiles ride the grid; the compressed streams (a few bits per
row -- the whole point) ride in full as block inputs.  The per-row suffix fetch
is a clamped dynamic take on the payload words with two-word bit extraction;
the chain itself is a ``fori_loop`` over ``block_size`` rows with the previous
decoded row as carry (front coding is inherently sequential per block, but every
query in the tile walks its own block in lockstep on the VPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(*, sigma: int, term_bits: int, lcp_width: int,
                 block_size: int, len_off: int):
    # masks stay python ints (weak scalars): a jnp constant here would be
    # captured by the traced kernel, which pallas_call rejects
    per_word = 32 // lcp_width
    lcp_mask = (1 << lcp_width) - 1
    term_mask = (1 << term_bits) - 1

    def kernel(lcps_ref, payload_ref, base_ref, sec_ref, blk_ref, qt_ref,
               qlen_ref, lt_ref, eq_ref):
        lcps = lcps_ref[...]
        payload = payload_ref[...]
        nw = payload.shape[0]
        sec = sec_ref[...]                            # [sigma+1] int32
        blk = blk_ref[...]                            # [B] int32
        qt = qt_ref[...]                              # [B, S] int32
        qlen = qlen_ref[...]                          # [B] int32
        b = blk.shape[0]
        base = jnp.take(base_ref[...], blk).astype(jnp.int32)   # [B]
        # iota, not arange: arange traces to a materialized constant, which
        # pallas_call rejects ("captures constants ... pass them as inputs")
        jota = jax.lax.broadcasted_iota(jnp.int32, (sigma,), 0)

        def body(r, state):
            prev, ns_off, cnt_lt, cnt_eq = state
            g = blk * block_size + r                               # [B]
            lw = jnp.take(lcps, g // per_word)
            lcp = ((lw >> ((g % per_word) * lcp_width).astype(jnp.uint32))
                   & lcp_mask).astype(jnp.int32)
            row_len = jnp.sum((g[:, None] >= sec[None, :]).astype(jnp.int32),
                              axis=1)                              # [B]
            store_len = jnp.clip(row_len - len_off, 0, sigma)
            lcp = jnp.minimum(lcp, store_len)
            tpos = (base + ns_off)[:, None] + (jota[None, :] - lcp[:, None])
            bitp = tpos.astype(jnp.uint32) * term_bits
            w_lo = jnp.clip((bitp >> 5).astype(jnp.int32), 0, nw - 1)
            sh = bitp & 31
            lo = jnp.take(payload, w_lo) >> sh
            hi = jnp.where(
                sh > 0,
                jnp.take(payload, jnp.clip(w_lo + 1, 0, nw - 1))
                << ((32 - sh) & 31),
                0)
            stored = ((lo | hi) & term_mask).astype(jnp.int32)
            cur = jnp.where(jota[None, :] < lcp[:, None], prev,
                            jnp.where(jota[None, :] < store_len[:, None],
                                      stored, 0))
            # lexicographic (row_len, terms) vs (q_len, q_terms)
            eq = cur == qt
            prefix_eq = jnp.concatenate(
                [jnp.ones((b, 1), jnp.bool_),
                 jnp.cumprod(eq[:, :-1].astype(jnp.int32), axis=1).astype(bool)],
                axis=1)
            t_lt = jnp.any(prefix_eq & (cur < qt), axis=1)
            t_eq = jnp.all(eq, axis=1)
            len_eq = row_len == qlen
            is_lt = (row_len < qlen) | (len_eq & t_lt)
            is_eq = len_eq & t_eq
            return (cur, ns_off + store_len - lcp,
                    cnt_lt + is_lt.astype(jnp.int32),
                    cnt_eq + is_eq.astype(jnp.int32))

        init = (jnp.zeros((b, sigma), jnp.int32), jnp.zeros((b,), jnp.int32),
                jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32))
        _, _, cnt_lt, cnt_eq = jax.lax.fori_loop(0, block_size, body, init)
        lt_ref[...] = cnt_lt
        eq_ref[...] = cnt_eq

    return kernel


@partial(jax.jit, static_argnames=("term_bits", "lcp_width", "block_size",
                                   "len_off", "qblock", "interpret"))
def block_decode(lcps: jax.Array, payload: jax.Array, block_base: jax.Array,
                 sec_starts: jax.Array, blk: jax.Array, q_terms: jax.Array,
                 q_len: jax.Array, *, term_bits: int, lcp_width: int,
                 block_size: int, len_off: int, qblock: int = 256,
                 interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(cnt_lt [Q], cnt_eq [Q]) int32: per query, how many rows of its candidate
    block sort strictly below / compare equal to the query key.

    lcps       : packed lcp stream, ``lcp_width`` bits/row (word-aligned widths)
    payload    : packed suffix-term stream, ``term_bits`` bits/term
    block_base : [nb+1] uint32 cumulative suffix-term count at block starts
    sec_starts : [sigma+1] int32 decoded section starts (row-length key)
    blk        : [Q] int32 candidate block per query (0 <= blk < nb)
    q_terms    : [Q, sigma] int32 query terms; q_len: [Q] int32 query length key
    len_off    : 0 = point view, 1 = continuation (prefix) view
    """
    q, sigma = q_terms.shape
    nb = -(-q // qblock)
    q_pad = nb * qblock
    blk_p = jnp.pad(blk.astype(jnp.int32), (0, q_pad - q))
    qt_p = jnp.pad(q_terms.astype(jnp.int32), ((0, q_pad - q), (0, 0)))
    qlen_p = jnp.pad(q_len.astype(jnp.int32), (0, q_pad - q))
    sec = sec_starts.astype(jnp.int32)
    n_sec = sec.shape[0]
    w1, w2, w3 = lcps.shape[0], payload.shape[0], block_base.shape[0]

    cnt_lt, cnt_eq = pl.pallas_call(
        _make_kernel(sigma=sigma, term_bits=term_bits, lcp_width=lcp_width,
                     block_size=block_size, len_off=len_off),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((w1,), lambda i: (0,)),
            pl.BlockSpec((w2,), lambda i: (0,)),
            pl.BlockSpec((w3,), lambda i: (0,)),
            pl.BlockSpec((n_sec,), lambda i: (0,)),
            pl.BlockSpec((qblock,), lambda i: (i,)),
            pl.BlockSpec((qblock, sigma), lambda i: (i, 0)),
            pl.BlockSpec((qblock,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((qblock,), lambda i: (i,)),
                   pl.BlockSpec((qblock,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((q_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((q_pad,), jnp.int32)],
        interpret=interpret,
    )(lcps, payload, block_base, sec, blk_p, qt_p, qlen_p)
    return cnt_lt[:q], cnt_eq[:q]
