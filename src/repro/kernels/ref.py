"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mapreduce import pack as packing
from repro.mapreduce.shuffle import hash_u32


def lcp_boundary_ref(sorted_terms: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(lcp [N], flags [N, L]) of a lexicographically sorted int32 matrix."""
    prev = jnp.roll(sorted_terms, 1, axis=0)
    eq = (sorted_terms == prev).astype(jnp.int32)
    lcp = jnp.sum(jnp.cumprod(eq, axis=1), axis=1).at[0].set(0)
    n, length = sorted_terms.shape
    lengths = jnp.arange(1, length + 1, dtype=jnp.int32)
    flags = (lcp[:, None] < lengths[None, :]) & (sorted_terms != 0)
    return lcp.astype(jnp.int32), flags


def suffix_pack_ref(tokens: jax.Array, *, sigma: int, vocab_size: int) -> jax.Array:
    """Packed sigma-truncated suffix lanes [N, n_lanes] of a PAD-separated stream."""
    n = tokens.shape[0]
    padded = jnp.concatenate([tokens, jnp.zeros((sigma,), tokens.dtype)])
    idx = jnp.arange(n)[:, None] + jnp.arange(sigma)[None, :]
    w = padded[idx]
    keep = jnp.cumprod((w != 0).astype(jnp.int32), axis=1)
    return packing.pack_terms((w * keep).astype(jnp.int32), vocab_size=vocab_size)


def bsearch_ref(lanes: jax.Array, queries: jax.Array, lo: jax.Array,
                hi: jax.Array, *, upper: bool = False,
                steps: int | None = None) -> jax.Array:
    """Batched lexicographic lower/upper bound on sorted packed lanes [R, L].

    Fixed-iteration branchless search, vmapped over queries; semantics match
    ``repro.kernels.bsearch.bsearch`` (its allclose target and the default
    ``use_kernels=False`` serving path)."""
    if steps is None:
        from .bsearch import search_steps
        steps = search_steps(lanes.shape[0])

    def one(q, lo_i, hi_i):
        def body(_, state):
            lo_c, hi_c = state
            mid = (lo_c + hi_c) // 2
            row = lanes[mid]
            eq = row == q
            prefix_eq = jnp.concatenate(
                [jnp.ones((1,), jnp.bool_),
                 jnp.cumprod(eq[:-1].astype(jnp.int32)).astype(bool)])
            go_right = jnp.any(prefix_eq & (row < q))
            if upper:
                go_right = go_right | jnp.all(eq)
            open_ = lo_c < hi_c
            lo_c = jnp.where(open_ & go_right, mid + 1, lo_c)
            hi_c = jnp.where(open_ & ~go_right, mid, hi_c)
            return lo_c, hi_c

        out, _ = jax.lax.fori_loop(0, steps, body,
                                   (lo_i.astype(jnp.int32),
                                    hi_i.astype(jnp.int32)))
        return out

    return jax.vmap(one)(queries, lo, hi)


def block_expand_ref(lcps: jax.Array, payload: jax.Array, block_base: jax.Array,
                     sec_starts: jax.Array, blk: jax.Array, *, term_bits: int,
                     lcp_width: int, block_size: int,
                     len_off: int) -> jax.Array:
    """Decoded term matrix [B, block_size, sigma] int32 of the requested blocks.

    Semantics match ``repro.kernels.block_expand.block_expand`` (its allclose
    target and the ``use_kernels=False`` chunked-decode path).  Decode is the
    parallel form of the coding chain: lane j of row r comes from the last row
    p <= r whose stored span covers j.  When row id and term value pack into an
    int32 together, one running max over ``(row << term_bits) | value`` resolves
    the provider AND fetches its value (rows past a provider's span contribute
    the provider's explicit 0, so the zero-fill rides along); otherwise the
    provider index is cummax'd alone and gathered.  Both equal the sequential
    prev-row substitution the kernel runs.
    """
    from repro.kernels.bitpack import extract_bits

    b, sigma = block_size, sec_starts.shape[0] - 1
    g = blk.astype(jnp.int32)[:, None] * b + jnp.arange(b, dtype=jnp.int32)
    lcp = extract_bits(lcps, g, lcp_width).astype(jnp.int32)        # [Q, B]
    row_len = jnp.sum((g[..., None] >= sec_starts[None, None, :])
                      .astype(jnp.int32), axis=-1)                  # [Q, B]
    store_len = jnp.clip(row_len - len_off, 0, sigma)
    lcp = jnp.minimum(lcp, store_len)
    # no forced reset at row 0: a head lane with lcp > 0 decodes as 0 (negative
    # provider here, the zero-initialized prev carry in the kernel) -- the
    # builder always writes lcp 0 at block heads, so the case only arises in
    # fuzzed streams
    ns = store_len - lcp
    off_in = jnp.concatenate(
        [jnp.zeros((g.shape[0], 1), jnp.int32),
         jnp.cumsum(ns, axis=1)[:, :-1]], axis=1)
    base = block_base[blk].astype(jnp.int32)
    j = jnp.arange(sigma, dtype=jnp.int32)
    tpos = base[:, None, None] + off_in[..., None] + (j - lcp[..., None])
    # gathers dominate on CPU: when a row's suffix span fits a small static word
    # window, fetch the window once per row and mux lanes out of it arithmetically
    # instead of issuing two word gathers per (row, lane)
    # lane words sit up to ((S-1)*tb + 31) >> 5 words past the row's first word
    # (worst case: the row starts at bit 31 of its word)
    span_words = ((sigma - 1) * term_bits + 31) // 32 + 1
    if span_words <= 6:
        nw = payload.shape[0]
        row_bit0 = (base[:, None] + off_in).astype(jnp.uint32) * term_bits
        w0 = (row_bit0 >> 5).astype(jnp.int32)                      # [Q, B]
        win = jnp.stack([jnp.take(payload, jnp.clip(w0 + t, 0, nw - 1))
                         for t in range(span_words + 1)], axis=-1)  # [Q,B,W+1]
        bitp = jnp.maximum(tpos, 0).astype(jnp.uint32) * term_bits
        rel = (bitp >> 5).astype(jnp.int32) - w0[..., None]
        lo_w = hi_w = jnp.zeros(bitp.shape, jnp.uint32)
        for t in range(span_words):
            lo_w = jnp.where(rel == t, win[..., t:t + 1], lo_w)
            hi_w = jnp.where(rel == t, win[..., t + 1:t + 2], hi_w)
        sh = bitp & 31
        stored = ((lo_w >> sh)
                  | jnp.where(sh > 0, hi_w << ((32 - sh) & 31), 0)) \
            & jnp.uint32((1 << term_bits) - 1)
        stored = stored.astype(jnp.int32)
    else:
        stored = extract_bits(payload, tpos, term_bits).astype(jnp.int32)
    valid_store = (j >= lcp[..., None]) & (j < store_len[..., None])
    aligned = jnp.where(valid_store, stored, 0)                     # [Q, B, S]
    covers = lcp[..., None] <= j
    r_id = jnp.arange(b, dtype=jnp.int32)[None, :, None]
    if b.bit_length() + term_bits <= 31:
        kv = jnp.where(covers, (r_id << term_bits) | aligned, -1)
        run = jax.lax.cummax(kv, axis=1)
        # run < 0 == no provider yet (fuzzed streams only): decode 0, not mask
        decoded = jnp.where(run < 0, 0, run & ((1 << term_bits) - 1))
    else:  # row id and value don't co-pack: cummax the provider, then gather
        prov = jax.lax.cummax(jnp.where(covers, r_id, -1), axis=1)
        decoded = jnp.where(
            prov >= 0,
            jnp.take_along_axis(aligned, jnp.maximum(prov, 0), axis=1), 0)
    return decoded


def block_decode_ref(lcps: jax.Array, payload: jax.Array, block_base: jax.Array,
                     sec_starts: jax.Array, blk: jax.Array, q_terms: jax.Array,
                     q_len: jax.Array, *, term_bits: int, lcp_width: int,
                     block_size: int, len_off: int) -> tuple[jax.Array, jax.Array]:
    """(cnt_lt [Q], cnt_eq [Q]): front-coded block decode + in-block rank.

    Semantics match ``repro.kernels.block_decode.block_decode`` (its allclose
    target and the ``use_kernels=False`` compressed-serving path).  The decode
    half is ``block_expand_ref``; this adds the per-query lexicographic
    (row_len, terms) rank against the decoded candidate block.
    """
    b, sigma = block_size, q_terms.shape[1]
    decoded = block_expand_ref(lcps, payload, block_base, sec_starts, blk,
                               term_bits=term_bits, lcp_width=lcp_width,
                               block_size=b, len_off=len_off)
    g = blk.astype(jnp.int32)[:, None] * b + jnp.arange(b, dtype=jnp.int32)
    row_len = jnp.sum((g[..., None] >= sec_starts[None, None, :])
                      .astype(jnp.int32), axis=-1)                  # [Q, B]
    qt = q_terms.astype(jnp.int32)[:, None, :]
    eq = decoded == qt
    prefix_eq = jnp.concatenate(
        [jnp.ones(eq[..., :1].shape, jnp.bool_),
         jnp.cumprod(eq[..., :-1].astype(jnp.int32), axis=-1).astype(bool)],
        axis=-1)
    t_lt = jnp.any(prefix_eq & (decoded < qt), axis=-1)
    t_eq = jnp.all(eq, axis=-1)
    len_eq = row_len == q_len.astype(jnp.int32)[:, None]
    is_lt = (row_len < q_len[:, None]) | (len_eq & t_lt)
    is_eq = len_eq & t_eq
    return (jnp.sum(is_lt.astype(jnp.int32), axis=1),
            jnp.sum(is_eq.astype(jnp.int32), axis=1))


def merge_path_ref(a_keys: jax.Array, b_keys: jax.Array, a_vals: jax.Array,
                   b_vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(keys [M+N, K], vals [M+N]): stable two-way merge of sorted key matrices.

    Rows compare lexicographically (uint32 lanes); on ties every A row precedes
    every B row.  Semantics match ``repro.kernels.merge_path.merge_path`` (its
    allclose target and the ``use_kernels=False`` merge route).  The ref takes
    the rank-and-scatter route -- each A row's output slot is its index plus the
    count of strictly-smaller B rows, each B row's its index plus the count of
    less-or-equal A rows -- deliberately a different algorithm from the kernel's
    diagonal (merge-path) search, so the differential test cross-checks two
    derivations of the same permutation.
    """
    m, n = a_keys.shape[0], b_keys.shape[0]
    zeros_m = jnp.zeros((m,), jnp.int32)
    zeros_n = jnp.zeros((n,), jnp.int32)
    pos_a = jnp.arange(m, dtype=jnp.int32) + bsearch_ref(
        b_keys, a_keys, zeros_m, zeros_m + n, upper=False)
    pos_b = jnp.arange(n, dtype=jnp.int32) + bsearch_ref(
        a_keys, b_keys, zeros_n, zeros_n + m, upper=True)
    keys = jnp.zeros((m + n, a_keys.shape[1]), a_keys.dtype)
    keys = keys.at[pos_a].set(a_keys).at[pos_b].set(b_keys)
    vals = jnp.zeros((m + n,), a_vals.dtype)
    vals = vals.at[pos_a].set(a_vals).at[pos_b].set(b_vals)
    return keys, vals


def hash_combine_ref(keys: jax.Array, weights: jax.Array, *,
                     block: int = 256) -> jax.Array:
    """Redistributed weights [N] of the block-local hash-slot combiner.

    Semantics match ``repro.kernels.hash_combine.hash_combine`` (its allclose
    target and the ``use_kernels=False`` combine route).  Deliberately the
    scatter/gather derivation -- ``.at[].min`` slot winners, row gathers,
    ``segment_sum`` folds -- where the kernel uses dense one-hot planes, so
    the differential test cross-checks two formulations of the same table.
    """
    from repro.mapreduce.shuffle import fold_hash

    n, n_keys = keys.shape
    nb = max(1, -(-n // block))
    n_pad = nb * block
    k = jnp.pad(keys.astype(jnp.uint32), ((0, n_pad - n), (0, 0)))
    w = jnp.pad(weights.astype(jnp.uint32), (0, n_pad - n))
    n_slots = 2 * block
    ids = jnp.arange(block, dtype=jnp.int32)

    def one(kb, wb):
        slot = (fold_hash(kb) % jnp.uint32(n_slots)).astype(jnp.int32)
        winner = jnp.full((n_slots,), block, jnp.int32).at[slot].min(ids)
        rep = winner[slot]
        match = jnp.all(kb[rep] == kb, axis=1)
        contrib = jnp.where(match, wb, jnp.uint32(0))
        totals = jax.ops.segment_sum(contrib, rep, num_segments=block)
        return jnp.where(rep == ids, totals,
                         jnp.where(match, jnp.uint32(0), wb))

    out = jax.vmap(one)(k.reshape(nb, block, n_keys), w.reshape(nb, block))
    return out.reshape(-1)[:n]


def hash_partition_ref(keys: jax.Array, valid: jax.Array,
                       n_parts: int) -> tuple[jax.Array, jax.Array]:
    """(partition ids [N] with n_parts for invalid, histogram [n_parts])."""
    p = (hash_u32(keys) % jnp.uint32(n_parts)).astype(jnp.int32)
    p = jnp.where(valid, p, n_parts)
    hist = jnp.sum(jax.nn.one_hot(p, n_parts + 1, dtype=jnp.int32), axis=0)[:n_parts]
    return p, hist
