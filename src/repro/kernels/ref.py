"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mapreduce import pack as packing
from repro.mapreduce.shuffle import hash_u32


def lcp_boundary_ref(sorted_terms: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(lcp [N], flags [N, L]) of a lexicographically sorted int32 matrix."""
    prev = jnp.roll(sorted_terms, 1, axis=0)
    eq = (sorted_terms == prev).astype(jnp.int32)
    lcp = jnp.sum(jnp.cumprod(eq, axis=1), axis=1).at[0].set(0)
    n, length = sorted_terms.shape
    lengths = jnp.arange(1, length + 1, dtype=jnp.int32)
    flags = (lcp[:, None] < lengths[None, :]) & (sorted_terms != 0)
    return lcp.astype(jnp.int32), flags


def suffix_pack_ref(tokens: jax.Array, *, sigma: int, vocab_size: int) -> jax.Array:
    """Packed sigma-truncated suffix lanes [N, n_lanes] of a PAD-separated stream."""
    n = tokens.shape[0]
    padded = jnp.concatenate([tokens, jnp.zeros((sigma,), tokens.dtype)])
    idx = jnp.arange(n)[:, None] + jnp.arange(sigma)[None, :]
    w = padded[idx]
    keep = jnp.cumprod((w != 0).astype(jnp.int32), axis=1)
    return packing.pack_terms((w * keep).astype(jnp.int32), vocab_size=vocab_size)


def bsearch_ref(lanes: jax.Array, queries: jax.Array, lo: jax.Array,
                hi: jax.Array, *, upper: bool = False,
                steps: int | None = None) -> jax.Array:
    """Batched lexicographic lower/upper bound on sorted packed lanes [R, L].

    Fixed-iteration branchless search, vmapped over queries; semantics match
    ``repro.kernels.bsearch.bsearch`` (its allclose target and the default
    ``use_kernels=False`` serving path)."""
    if steps is None:
        from .bsearch import search_steps
        steps = search_steps(lanes.shape[0])

    def one(q, lo_i, hi_i):
        def body(_, state):
            lo_c, hi_c = state
            mid = (lo_c + hi_c) // 2
            row = lanes[mid]
            eq = row == q
            prefix_eq = jnp.concatenate(
                [jnp.ones((1,), jnp.bool_),
                 jnp.cumprod(eq[:-1].astype(jnp.int32)).astype(bool)])
            go_right = jnp.any(prefix_eq & (row < q))
            if upper:
                go_right = go_right | jnp.all(eq)
            open_ = lo_c < hi_c
            lo_c = jnp.where(open_ & go_right, mid + 1, lo_c)
            hi_c = jnp.where(open_ & ~go_right, mid, hi_c)
            return lo_c, hi_c

        out, _ = jax.lax.fori_loop(0, steps, body,
                                   (lo_i.astype(jnp.int32),
                                    hi_i.astype(jnp.int32)))
        return out

    return jax.vmap(one)(queries, lo, hi)


def hash_partition_ref(keys: jax.Array, valid: jax.Array,
                       n_parts: int) -> tuple[jax.Array, jax.Array]:
    """(partition ids [N] with n_parts for invalid, histogram [n_parts])."""
    p = (hash_u32(keys) % jnp.uint32(n_parts)).astype(jnp.int32)
    p = jnp.where(valid, p, n_parts)
    hist = jnp.sum(jax.nn.one_hot(p, n_parts + 1, dtype=jnp.int32), axis=0)[:n_parts]
    return p, hist
