"""Sort-free map-side combiner: block-local hash-slot duplicate collapse.

The paper's combiner (and ``stages.combine_sort``) pre-aggregates map output
by *sorting* it -- ``n_lanes`` full passes over the record buffer before the
shuffle even starts.  Lemire & Kaser's one-pass hashing observation is that a
combiner doesn't need an order, only coincidence: hash each record into a
slot table and fold weights when the keys collide *equal*.  A Hadoop combiner
is best-effort by contract (the reducer re-aggregates exactly), so a lossy
slot table is sound: rows that lose their slot to a different key simply keep
their weight and ride the shuffle uncombined.

Kernel shape: one grid block of ``block`` records owns a ``2 * block``-slot
table in VMEM.  Everything is branch-free VPU work over dense [B, S] / [B, B]
one-hot planes (TPU has no fast scatter; coincidence detection as masked
min-reductions is the native formulation):

  slot      = fold_hash(keys) mod S           (the shuffle's own record hash)
  winner[s] = min row index hashing to s      ([B, S] masked min)
  rep[i]    = winner[slot[i]]                 ([B, S] masked min -- a gather)
  match[i]  = keys[i] == keys[rep[i]]         (K passes over a [B, B] one-hot)
  out[i]    = rep==i ? sum of matching weights : match ? 0 : w[i]

Weight is conserved per key by construction; row order never changes, so the
caller's record layout (weight lane in place) survives.  Combining is local
to a block -- cross-block duplicates survive to the reducer, which is exactly
the contract the sort combiner's buffer boundary has too.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(n_keys: int, block: int, n_slots: int):
    def kernel(keys_ref, w_ref, out_ref):
        keys = keys_ref[...].astype(jnp.uint32)        # [B, K]
        w = w_ref[...].astype(jnp.uint32)              # [B]
        # mapreduce.shuffle.fold_hash, inlined with kernel-local constants
        # (module-level jnp scalars would be captured consts -- rejected)
        h = jnp.zeros((block,), jnp.uint32)
        for k in range(n_keys):
            h = h ^ keys[:, k] + jnp.uint32(0x9E3779B9)
            h = h * jnp.uint32(2654435761)
            h = h ^ (h >> 15)
            h = h * jnp.uint32(2246822519)
            h = h ^ (h >> 13)
        slot = (h % jnp.uint32(n_slots)).astype(jnp.int32)
        # iota, not arange (arange would become a captured constant -- rejected)
        ids = jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
        slot_ids = jax.lax.broadcasted_iota(jnp.int32, (block, n_slots), 1)
        hit = slot[:, None] == slot_ids                # [B, S]
        # min row index per slot; empty slots hold B (harmless: nothing reads them)
        winner = jnp.min(jnp.where(hit, ids[:, None], block), axis=0)   # [S]
        # rep[i] = winner[slot[i]] as a masked min (gather-free)
        rep = jnp.min(jnp.where(hit, winner[None, :], block), axis=1)   # [B]
        # match[i] = keys[i] == keys[rep[i]]; K masked [B, B] passes keep VMEM
        # at O(B^2), independent of the lane count
        eq_rep = rep[:, None] == ids[None, :]          # [B, B] one-hot rows
        match = jnp.ones((block,), jnp.bool_)
        for k in range(n_keys):
            rep_k = jnp.sum(jnp.where(eq_rep, keys[None, :, k],
                                      jnp.uint32(0)), axis=1)
            match = match & (rep_k == keys[:, k])
        contrib = jnp.where(match, w, jnp.uint32(0))
        totals = jnp.sum(jnp.where(eq_rep, contrib[:, None],
                                   jnp.uint32(0)), axis=0)              # [B]
        out_ref[...] = jnp.where(rep == ids, totals,
                                 jnp.where(match, jnp.uint32(0), w))

    return kernel


@partial(jax.jit, static_argnames=("block", "interpret"))
def hash_combine(keys: jax.Array, weights: jax.Array, *, block: int = 256,
                 interpret: bool = True) -> jax.Array:
    """Redistributed weights [N] uint32: per ``block`` of rows, rows whose key
    equals their hash-slot winner's key donate their weight to the winner;
    slot losers keep theirs.  Row order is unchanged; per-key weight totals
    are exactly preserved."""
    n, n_keys = keys.shape
    nb = max(1, -(-n // block))
    n_pad = nb * block
    # pad rows sit at the block tail with zero weight: min-index winners mean
    # they can never absorb a real row's weight
    k = jnp.pad(keys.astype(jnp.uint32), ((0, n_pad - n), (0, 0)))
    w = jnp.pad(weights.astype(jnp.uint32), (0, n_pad - n))

    out = pl.pallas_call(
        _make_kernel(n_keys, block, 2 * block),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block, n_keys), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(k, w)
    return out[:n]
