"""Fused LCP + boundary-flag kernel -- the SUFFIX-sigma reducer inner loop.

For a sorted [N, L] term matrix the reducer needs, per row, the longest common
prefix with the previous row and per-length boundary flags.  XLA emits this as
roll + compare + cumprod + reduce + broadcast-compare (5 HBM-bound passes over the
matrix); the kernel reads each row block once into VMEM and produces both outputs in
a single pass -- the classic memory-bound fusion case (arithmetic intensity ~1 flop/B).

TPU mapping: rows tile the grid; L (<= sigma, e.g. 5..100) rides in lanes.  The
previous-row halo is passed as a second, pre-shifted input ref (Pallas BlockSpecs are
block-aligned; a one-row halo would force element offsets), which costs one extra HBM
read of the matrix but keeps every block independent.  Block rows default to 512 so a
block of sigma=100 int32 terms is ~200 KiB -- comfortably inside the ~16 MiB VMEM
budget with double buffering.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cur_ref, prev_ref, lcp_ref, flags_ref):
    cur = cur_ref[...]
    prev = prev_ref[...]
    eq = (cur == prev).astype(jnp.int32)
    lcp = jnp.sum(jnp.cumprod(eq, axis=1), axis=1).astype(jnp.int32)
    length = cur.shape[1]
    # iota, not arange: arange traces to a materialized constant, which
    # pallas_call rejects ("captures constants ... pass them as inputs")
    lengths = jax.lax.broadcasted_iota(jnp.int32, (length,), 0) + 1
    lcp_ref[...] = lcp
    flags_ref[...] = (lcp[:, None] < lengths[None, :]) & (cur != 0)


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def lcp_boundary(sorted_terms: jax.Array, *, block_rows: int = 512,
                 interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(lcp [N] int32, flags [N, L] bool).  Row 0 gets lcp 0 (no predecessor)."""
    n, length = sorted_terms.shape
    nb = -(-n // block_rows)
    n_pad = nb * block_rows
    st = jnp.pad(sorted_terms, ((0, n_pad - n), (0, 0)))
    # pre-shifted previous-row matrix; row 0's "previous" is a sentinel that cannot
    # match any real row (forces lcp 0 without an in-kernel special case).
    prev = jnp.concatenate(
        [jnp.full((1, length), -2147483648, st.dtype), st[:-1]], axis=0)

    lcp, flags = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, length), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, length), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, length), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, length), jnp.bool_),
        ],
        interpret=interpret,
    )(st, prev)
    return lcp[:n], flags[:n]
