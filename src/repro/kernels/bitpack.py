"""Fixed-width bit streams over uint32 words -- the compressed index's substrate.

A stream stores n values of a common ``width`` (< 32 bits) back to back,
LSB-first: bit b of the stream lives in word ``b >> 5`` at in-word position
``b & 31``, and value i occupies stream bits [i*width, (i+1)*width).  Packing is
host-side numpy (build time); extraction is pure jnp (branchless two-word fetch,
safe for any traced index), so the same helper serves the jitted query path, the
kernel oracles, and -- because it is plain jnp on values -- the Pallas kernels
themselves.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def words_for(n_values: int, width: int) -> int:
    return -(-(n_values * width) // 32)


def pack_bits(values: np.ndarray, width: int,
              n_words: int | None = None) -> np.ndarray:
    """Pack ``values`` (uint, each < 2**width) into a uint32 word stream.

    ``n_words`` pads the stream (sharded builds pass a common capacity so shard
    streams stack); the pad is zeros and is never addressed by real indices.
    """
    values = np.asarray(values, np.uint64)
    n = values.shape[0]
    if width < 0 or width > 32:
        raise ValueError(f"width must be in [0, 32], got {width}")
    if width and n and int(values.max()) >> width:
        raise ValueError(f"value {int(values.max())} overflows width {width}")
    if n * width >= 1 << 32:
        # extract_bits (and the block_decode kernel) compute bit positions in
        # uint32; past 2^32 bits they would wrap and read garbage silently --
        # refuse loudly instead (shard the index first, serve.py does anyway)
        raise ValueError(f"stream of {n}x{width} bits exceeds the uint32 "
                         "bit-address space; shard the index instead")
    need = words_for(n, width)
    nw = need if n_words is None else n_words
    if nw < need:
        raise ValueError(f"n_words={nw} < required {need}")
    words = np.zeros((nw,), np.uint32)
    if width == 0 or n == 0:
        return words
    bitpos = np.arange(n, dtype=np.uint64) * np.uint64(width)
    # width <= 32, so each value straddles at most two words: scatter the
    # in-word part, then the spill into the next word for the lanes whose
    # shifted value actually carries past bit 31.  (values < 2**32 and
    # shift <= 31 keep the product inside uint64.)
    w = (bitpos >> np.uint64(5)).astype(np.int64)
    shifted = values << (bitpos & np.uint64(31))
    np.bitwise_or.at(words, w,
                     (shifted & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    spill = shifted >> np.uint64(32)
    lanes = np.nonzero(spill)[0]
    if lanes.size:
        np.bitwise_or.at(words, w[lanes] + 1, spill[lanes].astype(np.uint32))
    return words


def extract_bits(words: jnp.ndarray, idx: jnp.ndarray, width: int) -> jnp.ndarray:
    """Values [*idx.shape] uint32 at stream positions ``idx`` (any int shape).

    Out-of-range / negative indices (masked lanes upstream) read garbage but
    never fault: word fetches are clamped into the stream.
    """
    if width == 0:
        return jnp.zeros(idx.shape, jnp.uint32)
    nw = words.shape[0]
    bitp = idx.astype(jnp.uint32) * jnp.uint32(width)
    w_lo = jnp.clip((bitp >> 5).astype(jnp.int32), 0, nw - 1)
    w_hi = jnp.clip(w_lo + 1, 0, nw - 1)
    sh = bitp & jnp.uint32(31)
    lo = jnp.take(words, w_lo) >> sh
    # (32 - sh) & 31 keeps the shift in range; the sh==0 lane is masked anyway
    hi = jnp.where(sh > 0,
                   jnp.take(words, w_hi) << ((jnp.uint32(32) - sh) & jnp.uint32(31)),
                   jnp.uint32(0))
    mask = jnp.uint32(0xFFFFFFFF if width == 32 else (1 << width) - 1)
    return (lo | hi) & mask
