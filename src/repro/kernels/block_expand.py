"""Batched front-coded block decode kernel -- the compressed-merge inner loop.

Compressed-native merge (see ``repro.index.merge``) streams a compressed
segment back into packed lanes a chunk of blocks at a time.  XLA's unfused
decode materializes wide intermediate tensors per chunk; the kernel instead
walks each block's front-coding chain once entirely in VMEM, reconstructing
every row from the packed lcp / suffix-term streams, so only the decoded
[block, sigma] tiles leave the core.

TPU mapping: block batches ride the grid; the compressed streams (a few bits
per row -- the whole point) ride in full as block inputs.  The per-row suffix
fetch is a clamped dynamic take on the payload words with two-word bit
extraction; the chain is a python loop over the static ``block_size`` rows
with the previous decoded row as carry (front coding is inherently sequential
per block, but every block in the tile decodes in lockstep on the VPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(*, sigma: int, term_bits: int, lcp_width: int,
                 block_size: int, len_off: int):
    # masks stay python ints (weak scalars): a jnp constant here would be
    # captured by the traced kernel, which pallas_call rejects
    per_word = 32 // lcp_width
    lcp_mask = (1 << lcp_width) - 1
    term_mask = (1 << term_bits) - 1

    def kernel(lcps_ref, payload_ref, base_ref, sec_ref, blk_ref, out_ref):
        lcps = lcps_ref[...]
        payload = payload_ref[...]
        nw = payload.shape[0]
        sec = sec_ref[...]                            # [sigma+1] int32
        blk = blk_ref[...]                            # [B] int32
        b = blk.shape[0]
        base = jnp.take(base_ref[...], blk).astype(jnp.int32)   # [B]
        # iota, not arange: arange traces to a materialized constant, which
        # pallas_call rejects ("captures constants ... pass them as inputs")
        jota = jax.lax.broadcasted_iota(jnp.int32, (sigma,), 0)

        prev = jnp.zeros((b, sigma), jnp.int32)
        ns_off = jnp.zeros((b,), jnp.int32)
        # python loop, not fori_loop: each row writes a static out slice, and
        # block_size is small (4..16), so unrolling beats a carried write
        for r in range(block_size):
            g = blk * block_size + r                               # [B]
            lw = jnp.take(lcps, g // per_word)
            lcp = ((lw >> ((g % per_word) * lcp_width).astype(jnp.uint32))
                   & lcp_mask).astype(jnp.int32)
            row_len = jnp.sum((g[:, None] >= sec[None, :]).astype(jnp.int32),
                              axis=1)                              # [B]
            store_len = jnp.clip(row_len - len_off, 0, sigma)
            lcp = jnp.minimum(lcp, store_len)
            tpos = (base + ns_off)[:, None] + (jota[None, :] - lcp[:, None])
            bitp = tpos.astype(jnp.uint32) * term_bits
            w_lo = jnp.clip((bitp >> 5).astype(jnp.int32), 0, nw - 1)
            sh = bitp & 31
            lo = jnp.take(payload, w_lo) >> sh
            hi = jnp.where(
                sh > 0,
                jnp.take(payload, jnp.clip(w_lo + 1, 0, nw - 1))
                << ((32 - sh) & 31),
                0)
            stored = ((lo | hi) & term_mask).astype(jnp.int32)
            cur = jnp.where(jota[None, :] < lcp[:, None], prev,
                            jnp.where(jota[None, :] < store_len[:, None],
                                      stored, 0))
            out_ref[:, r * sigma:(r + 1) * sigma] = cur
            prev = cur
            ns_off = ns_off + store_len - lcp

    return kernel


@partial(jax.jit, static_argnames=("sigma", "term_bits", "lcp_width",
                                   "block_size", "len_off", "bblock",
                                   "interpret"))
def block_expand(lcps: jax.Array, payload: jax.Array, block_base: jax.Array,
                 sec_starts: jax.Array, blk: jax.Array, *, sigma: int,
                 term_bits: int, lcp_width: int, block_size: int, len_off: int,
                 bblock: int = 256, interpret: bool = True) -> jax.Array:
    """Decoded term matrix [B, block_size, sigma] int32 of the requested blocks.

    lcps       : packed lcp stream, ``lcp_width`` bits/row (word-aligned widths)
    payload    : packed suffix-term stream, ``term_bits`` bits/term
    block_base : [nb+1] uint32 cumulative suffix-term count at block starts
    sec_starts : [sigma+1] int32 decoded section starts (row-length key)
    blk        : [B] int32 block ids to decode (0 <= blk < nb)
    len_off    : 0 = point view, 1 = continuation (prefix) view
    """
    (b,) = blk.shape
    nb = -(-b // bblock)
    b_pad = nb * bblock
    blk_p = jnp.pad(blk.astype(jnp.int32), (0, b_pad - b))
    sec = sec_starts.astype(jnp.int32)
    n_sec = sec.shape[0]
    w1, w2, w3 = lcps.shape[0], payload.shape[0], block_base.shape[0]

    out = pl.pallas_call(
        _make_kernel(sigma=sigma, term_bits=term_bits, lcp_width=lcp_width,
                     block_size=block_size, len_off=len_off),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((w1,), lambda i: (0,)),
            pl.BlockSpec((w2,), lambda i: (0,)),
            pl.BlockSpec((w3,), lambda i: (0,)),
            pl.BlockSpec((n_sec,), lambda i: (0,)),
            pl.BlockSpec((bblock,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((bblock, block_size * sigma), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b_pad, block_size * sigma), jnp.int32)],
        interpret=interpret,
    )(lcps, payload, block_base, sec, blk_p)[0]
    return out[:b].reshape(b, block_size, sigma)
