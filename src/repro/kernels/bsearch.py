"""Batched lexicographic binary-search kernel -- the index-serving inner loop.

Point lookups and continuation-range queries both reduce to lower/upper-bound
searches of a query's packed lanes against the sorted index lanes (see
``repro.index``).  XLA's unfused form re-reads the probed index rows from HBM on
every one of the ~log2(R) steps *per query*; the kernel instead pins the index
lanes in VMEM once per query block and runs all queries of the block in lockstep
through a fixed-iteration, branchless search (every query does exactly ``steps``
probes, so there is no divergence -- the fanout table upstream makes the extra
probes cheap by shrinking every [lo, hi) to a bucket).

TPU mapping: queries tile the grid; the index lanes ride in full as block input
(VMEM residency is the design constraint: an index shard is L*4 bytes/row, so
~1M rows of sigma<=16 packed grams fit the ~16 MiB budget -- beyond that, shard
over the mesh first, which ``repro.index.serve`` does anyway).  The per-step row
gather is a VMEM dynamic take along the row axis; comparisons are uint32 VPU ops.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def search_steps(n_rows: int) -> int:
    """Fixed iteration count covering any [lo, hi) bracket within n_rows rows."""
    return max(1, math.ceil(math.log2(max(n_rows, 2)))) + 1


def _make_kernel(steps: int, upper: bool):
    def kernel(lanes_ref, q_ref, lo_ref, hi_ref, pos_ref):
        lanes = lanes_ref[...]                       # [R, L] (whole index shard)
        q = q_ref[...]                               # [B, L]
        b = q.shape[0]

        def body(_, state):
            lo, hi = state
            mid = jax.lax.div(lo + hi, 2)
            rows = jnp.take(lanes, mid, axis=0)      # [B, L]
            eq = rows == q
            # lexicographic rows<q: first differing lane decides
            prefix_eq = jnp.concatenate(
                [jnp.ones((b, 1), jnp.bool_),
                 jnp.cumprod(eq[:, :-1].astype(jnp.int32), axis=1).astype(bool)],
                axis=1)
            go_right = jnp.any(prefix_eq & (rows < q), axis=1)
            if upper:
                go_right = go_right | jnp.all(eq, axis=1)
            open_ = lo < hi
            lo = jnp.where(open_ & go_right, mid + 1, lo)
            hi = jnp.where(open_ & ~go_right, mid, hi)
            return lo, hi

        lo, _ = jax.lax.fori_loop(0, steps, body, (lo_ref[...], hi_ref[...]))
        pos_ref[...] = lo

    return kernel


@partial(jax.jit, static_argnames=("upper", "steps", "block", "interpret"))
def bsearch(lanes: jax.Array, queries: jax.Array, lo: jax.Array, hi: jax.Array,
            *, upper: bool = False, steps: int | None = None, block: int = 1024,
            interpret: bool = True) -> jax.Array:
    """Positions [Q] int32 of the lower (or upper) bound of each query.

    lanes   : [R, L] uint32, rows sorted lexicographically (lane-major)
    queries : [Q, L] uint32 packed query lanes
    lo, hi  : [Q] int32 per-query search brackets, 0 <= lo <= hi <= R
    upper   : False -> first row >= query; True -> first row > query
    """
    r, n_l = lanes.shape
    q = queries.shape[0]
    if steps is None:
        steps = search_steps(r)
    nb = -(-q // block)
    q_pad = nb * block
    qs = jnp.pad(queries, ((0, q_pad - q), (0, 0)))
    lo_p = jnp.pad(lo.astype(jnp.int32), (0, q_pad - q))
    hi_p = jnp.pad(hi.astype(jnp.int32), (0, q_pad - q))

    pos = pl.pallas_call(
        _make_kernel(steps, upper),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((r, n_l), lambda i: (0, 0)),
            pl.BlockSpec((block, n_l), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q_pad,), jnp.int32),
        interpret=interpret,
    )(lanes, qs, lo_p, hi_p)
    return pos[:q]
