"""Synthetic recsys batch generators (Criteo-like CTR, behavior sequences,
retrieval pairs).  Deterministic in (seed, step) like the LM loader."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CTRBatchGen:
    """n_sparse categorical fields with per-field vocab + 13 dense features."""
    field_vocabs: tuple[int, ...]
    n_dense: int = 13
    seed: int = 0

    def batch_at(self, step: int, batch: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        ids = np.stack([rng.zipf(1.2, batch) % v for v in self.field_vocabs], 1)
        return {
            "sparse_ids": ids.astype(np.int32),
            "dense": rng.standard_normal((batch, self.n_dense)).astype(np.float32),
            "labels": (rng.random(batch) < 0.03).astype(np.float32),
        }


@dataclass
class BehaviorSeqGen:
    """User behavior sequences + target item (BST)."""
    item_vocab: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int, batch: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        return {
            "history": (rng.zipf(1.3, (batch, self.seq_len)) % self.item_vocab
                        ).astype(np.int32),
            "target": (rng.zipf(1.3, batch) % self.item_vocab).astype(np.int32),
            "labels": (rng.random(batch) < 0.05).astype(np.float32),
        }


@dataclass
class RetrievalGen:
    """(user features, positive item id) pairs for in-batch sampled softmax."""
    item_vocab: int
    user_feat: int
    seed: int = 0

    def batch_at(self, step: int, batch: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        return {
            "user": rng.standard_normal((batch, self.user_feat)).astype(np.float32),
            "pos_item": (rng.zipf(1.3, batch) % self.item_vocab).astype(np.int32),
        }
