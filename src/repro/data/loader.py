"""Sharded, deterministic batch iterators.

Determinism contract (fault tolerance): batch at step s is a pure function of
(seed, step) so a restarted run replays the identical stream without coordination --
the checkpoint stores only the step counter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class LMBatchLoader:
    """Causal-LM batches from a token stream: inputs [B, S], labels shifted by 1."""
    tokens: np.ndarray
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        n = self.tokens.shape[0] - self.seq_len - 1
        starts = rng.integers(0, max(1, n), self.global_batch)
        idx = starts[:, None] + np.arange(self.seq_len + 1)[None, :]
        window = self.tokens[idx % self.tokens.shape[0]]
        return {"tokens": window[:, :-1].astype(np.int32),
                "labels": window[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class SyntheticLMLoader:
    """Shape-only loader for dry runs / perf smoke: random ids, zero host IO."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        t = rng.integers(1, self.vocab_size, (self.global_batch, self.seq_len + 1))
        return {"tokens": t[:, :-1].astype(np.int32),
                "labels": t[:, 1:].astype(np.int32)}
