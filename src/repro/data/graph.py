"""Graph containers + a real neighbor sampler (minibatch_lg needs fanout 15-10).

JAX message passing is segment_sum over an edge index (no native sparse SpMM for our
purposes -- see kernel taxonomy SSGNN); samplers therefore return fixed-size padded
edge lists with a validity mask so the train step stays static-shaped.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    """edge_index: [2, E] int32 (src, dst); features [N, F]; labels [N]."""
    edge_index: np.ndarray
    features: np.ndarray
    labels: np.ndarray
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return self.edge_index.shape[1]


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int = 16,
                 seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    # power-law-ish degree: preferential attachment approximation
    dst = rng.integers(0, n_nodes, n_edges)
    src = (rng.zipf(1.6, n_edges) - 1) % n_nodes
    edge_index = np.stack([src, dst]).astype(np.int32)
    feats = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return Graph(edge_index, feats, labels, n_nodes)


def batched_molecules(n_graphs: int, nodes_per: int, edges_per: int, d_feat: int,
                      seed: int = 0) -> Graph:
    """Disjoint union of small graphs (the `molecule` shape)."""
    rng = np.random.default_rng(seed)
    srcs, dsts, feats, labels = [], [], [], []
    for g in range(n_graphs):
        off = g * nodes_per
        srcs.append(rng.integers(0, nodes_per, edges_per) + off)
        dsts.append(rng.integers(0, nodes_per, edges_per) + off)
        feats.append(rng.standard_normal((nodes_per, d_feat)).astype(np.float32))
        labels.append(rng.integers(0, 2, nodes_per))
    edge_index = np.stack([np.concatenate(srcs), np.concatenate(dsts)]).astype(np.int32)
    return Graph(edge_index, np.concatenate(feats),
                 np.concatenate(labels).astype(np.int32), n_graphs * nodes_per)


def partition_edges_by_dst(graph: Graph, n_parts: int, pad_factor: float = 1.2
                           ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Partition edges so part p holds exactly the edges whose dst lies in node
    range p (range-sharded nodes), each part padded to a common capacity.

    Returns (edge_src [n_parts*cap], edge_dst [n_parts*cap], edge_mask) ready for
    the dst-partitioned shard_map message passing (models/gnn.py): every scatter
    is then shard-local.  Capacity absorbs degree skew; overflowing edges are
    dropped with a warning counter (real pipelines re-balance ranges instead).
    """
    src, dst = graph.edge_index
    n_local = -(-graph.n_nodes // n_parts)
    owner = dst // n_local
    order = np.argsort(owner, kind="stable")
    src, dst, owner = src[order], dst[order], owner[order]
    counts = np.bincount(owner, minlength=n_parts)
    cap = int(counts.mean() * pad_factor) + 1
    out_src = np.zeros(n_parts * cap, np.int32)
    out_dst = np.zeros(n_parts * cap, np.int32)
    mask = np.zeros(n_parts * cap, bool)
    start = 0
    for p in range(n_parts):
        take = min(int(counts[p]), cap)
        out_src[p * cap: p * cap + take] = src[start: start + take]
        out_dst[p * cap: p * cap + take] = dst[start: start + take]
        out_dst[p * cap + take: (p + 1) * cap] = p * n_local  # in-range padding
        mask[p * cap: p * cap + take] = True
        start += int(counts[p])
    return out_src, out_dst, mask


class CSRNeighborTable:
    """CSR adjacency for O(1) uniform neighbor sampling."""

    def __init__(self, graph: Graph):
        src, dst = graph.edge_index
        order = np.argsort(dst, kind="stable")
        self.sorted_src = src[order]
        self.indptr = np.zeros(graph.n_nodes + 1, np.int64)
        np.add.at(self.indptr, dst + 1, 1)
        self.indptr = np.cumsum(self.indptr)

    def sample(self, nodes: np.ndarray, fanout: int, rng) -> tuple[np.ndarray, np.ndarray]:
        """For each node draw `fanout` neighbors (with replacement; isolated nodes
        yield self-loops).  Returns (neighbors [len(nodes)*fanout], mask)."""
        lo = self.indptr[nodes]
        hi = self.indptr[nodes + 1]
        deg = (hi - lo)
        draw = rng.integers(0, np.maximum(deg, 1)[:, None], (nodes.size, fanout))
        nbr = self.sorted_src[np.minimum(lo[:, None] + draw, len(self.sorted_src) - 1)]
        has = (deg > 0)[:, None]
        nbr = np.where(has, nbr, nodes[:, None])  # self-loop fallback
        return nbr.reshape(-1).astype(np.int32), np.broadcast_to(has, nbr.shape).reshape(-1)


@dataclass
class SampledSubgraph:
    """Fixed-size k-hop sampled subgraph (layer-wise, GraphSAGE style)."""
    node_ids: np.ndarray       # [n_sub] global ids (padded with 0)
    features: np.ndarray       # [n_sub, F]
    labels: np.ndarray         # [n_seeds]
    edge_src: np.ndarray       # [n_sub_edges] local indices
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    n_seeds: int


def sample_subgraph(graph: Graph, table: CSRNeighborTable, seeds: np.ndarray,
                    fanouts: tuple[int, ...], seed: int = 0) -> SampledSubgraph:
    """Layer-wise sampling: frontier_0 = seeds; frontier_{l+1} = fanout[l] neighbors
    of frontier_l.  Local edges connect each sampled neighbor to its anchor."""
    rng = np.random.default_rng(seed)
    frontiers = [seeds.astype(np.int32)]
    srcs, dsts, masks = [], [], []
    offset = 0
    for fo in fanouts:
        anchors = frontiers[-1]
        nbr, mask = table.sample(anchors, fo, rng)
        next_off = offset + anchors.size
        local_dst = np.repeat(np.arange(anchors.size), fo) + offset
        local_src = np.arange(nbr.size) + next_off
        srcs.append(local_src)
        dsts.append(local_dst)
        masks.append(mask)
        frontiers.append(nbr)
        offset = next_off
    node_ids = np.concatenate(frontiers)
    return SampledSubgraph(
        node_ids=node_ids,
        features=graph.features[node_ids],
        labels=graph.labels[seeds],
        edge_src=np.concatenate(srcs).astype(np.int32),
        edge_dst=np.concatenate(dsts).astype(np.int32),
        edge_mask=np.concatenate(masks),
        n_seeds=seeds.size,
    )
