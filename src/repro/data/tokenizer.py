"""SSV 'Sequence Encoding': term dictionary with ids in descending collection
frequency (better packing: frequent terms get small ids), encode/decode."""
from __future__ import annotations

from collections import Counter

import numpy as np


class TermDictionary:
    def __init__(self, terms_by_freq: list[str]):
        self.id_to_term = [None] + list(terms_by_freq)       # id 0 = PAD/separator
        self.term_to_id = {t: i for i, t in enumerate(self.id_to_term) if t}

    @property
    def vocab_size(self) -> int:
        return len(self.id_to_term) - 1

    @classmethod
    def build(cls, documents: list[list[str]]) -> "TermDictionary":
        cnt = Counter(t for doc in documents for t in doc)
        return cls([t for t, _ in cnt.most_common()])

    def encode(self, documents: list[list[str]]) -> np.ndarray:
        out: list[int] = []
        for doc in documents:
            out.extend(self.term_to_id[t] for t in doc)
            out.append(0)
        return np.asarray(out, np.int32)

    def decode_gram(self, ids) -> tuple[str, ...]:
        return tuple(self.id_to_term[int(i)] for i in ids if int(i) != 0)


def sentences(text: str) -> list[list[str]]:
    """Whitespace tokenizer with '.'/'?'/'!' sentence boundaries (the paper uses
    OpenNLP; boundaries are n-gram barriers either way)."""
    docs: list[list[str]] = []
    cur: list[str] = []
    for raw in text.split():
        term = raw.strip(",;:\"'()[]").lower()
        end = raw and raw[-1] in ".?!"
        if term.strip(".?!"):
            cur.append(term.strip(".?!"))
        if end and cur:
            docs.append(cur)
            cur = []
    if cur:
        docs.append(cur)
    return docs
