from . import corpus, graph, loader, recsys, tokenizer

__all__ = ["corpus", "graph", "loader", "recsys", "tokenizer"]
