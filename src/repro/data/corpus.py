"""Corpora for the n-gram jobs: synthetic generators shaped like the paper's
datasets, plus the SSV pre-processing passes (sequence encoding is in tokenizer.py;
document splitting at infrequent terms lives here).

Token-stream convention everywhere: 1-D int32, term ids 1..V, PAD(0) separates
documents/sentences (the paper uses sentence boundaries as n-gram barriers)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp


@dataclass(frozen=True)
class CorpusProfile:
    """Scaled-down profiles of the paper's datasets (Table I)."""
    name: str
    vocab_size: int
    zipf_a: float
    mean_sentence_len: float
    std_sentence_len: float


# NYT: clean longitudinal news corpus; CW: noisy web corpus with heavier tail and
# more repeated boilerplate (modelled by a flatter Zipf + duplicated segments).
NYT = CorpusProfile("nyt", vocab_size=20_000, zipf_a=1.2, mean_sentence_len=18.96,
                    std_sentence_len=14.05)
CW = CorpusProfile("cw", vocab_size=60_000, zipf_a=1.05, mean_sentence_len=17.02,
                   std_sentence_len=17.56)
PROFILES = {"nyt": NYT, "cw": CW}


def zipf_corpus(n_tokens: int, profile: CorpusProfile = NYT, seed: int = 0,
                duplicate_frac: float = 0.0, with_years: bool = False,
                n_years: int = 21):
    """Zipf-distributed token stream with sentence separators.

    duplicate_frac > 0 re-injects copied segments (quotations / boilerplate -- the
    long frequent n-grams of Fig. 2).  with_years attaches a year bucket per token
    (document granularity) for the time-series extension.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, profile.vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-profile.zipf_a)
    probs /= probs.sum()
    toks = rng.choice(profile.vocab_size, size=n_tokens, p=probs).astype(np.int32) + 1

    # a small pool of "quotations" (idioms / boilerplate): repeated verbatim, they
    # create the long high-cf n-grams of the paper's Fig. 2
    pool = [rng.choice(profile.vocab_size,
                       size=rng.integers(8, 25), p=probs).astype(np.int32) + 1
            for _ in range(12)]

    # sentence separators at lognormal-ish intervals matching the profile moments
    out = []
    years = []
    i = 0
    year = 0
    while i < n_tokens:
        l = max(1, int(rng.normal(profile.mean_sentence_len, profile.std_sentence_len)))
        seg = toks[i:i + l]
        if duplicate_frac > 0 and rng.random() < duplicate_frac:
            seg = pool[rng.integers(0, len(pool))]
        out.append(seg)
        years.append(np.full(len(seg) + 1, year % n_years, np.int32))
        year += 1
        i += l
    stream = np.concatenate([np.concatenate([s, [0]]) for s in out]).astype(np.int32)
    if with_years:
        return stream, np.concatenate(years)[: stream.size]
    return stream


def unigram_counts(tokens, vocab_size: int) -> np.ndarray:
    return np.bincount(np.asarray(tokens), minlength=vocab_size + 1)


def split_at_infrequent(tokens, tau: int, vocab_size: int):
    """SSV 'Document Splits': replace terms with cf < tau by separators.

    Safe by the APRIORI principle -- no frequent n-gram contains an infrequent term.
    Returns (tokens', n_removed).  All methods benefit; large sigma especially."""
    toks = np.asarray(tokens)
    counts = unigram_counts(toks, vocab_size)
    infrequent = counts < tau
    infrequent[0] = False
    mask = infrequent[toks]
    out = np.where(mask, 0, toks).astype(np.int32)
    return out, int(mask.sum())


def scale_sample(tokens, frac: float, seed: int = 0) -> np.ndarray:
    """Random document subset at `frac` of the corpus (Fig. 6 scaling)."""
    docs = np.split(np.asarray(tokens), np.nonzero(np.asarray(tokens) == 0)[0] + 1)
    docs = [d for d in docs if d.size]
    rng = np.random.default_rng(seed)
    keep = rng.random(len(docs)) < frac
    kept = [d for d, k in zip(docs, keep) if k]
    if not kept:
        kept = docs[:1]
    return np.concatenate(kept).astype(np.int32)
