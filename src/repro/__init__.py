"""Computing n-Gram Statistics in MapReduce -- jax/pallas reproduction.

Importing the package installs small compatibility shims for older jax
releases (see ``repro._compat``) so every subpackage can target the modern
``jax.shard_map`` / ``AxisType`` API unconditionally.
"""
from . import _compat

_compat.install()
