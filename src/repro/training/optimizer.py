"""AdamW with warmup+cosine schedule and global-norm clipping (built here; no
optax in the environment).  Moments are fp32 regardless of param dtype; updates are
computed in fp32 and cast back -- the standard mixed-precision recipe."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def schedule(step, cfg: OptimizerConfig):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_frac
                         + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptimizerConfig):
    step = state["step"] + 1
    lr = schedule(step, cfg)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {"m": jax.tree.unflatten(treedef, [o[1] for o in out]),
                 "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gn}
