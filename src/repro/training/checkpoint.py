"""Sharded, atomic, resharding-capable checkpoints (orbax-lite, built here).

Layout:  <dir>/step_00000042/
            manifest.json          tree structure, per-leaf dtype/shape/shard files
            <leaf-path>.s<k>.npy   one file per addressable shard (parallel IO at
                                   fleet scale; on this single host k covers all)
         <dir>/LATEST              committed step pointer (atomic rename commit)

Restore reassembles leaves on host and ``device_put``s with the *target* sharding,
so a checkpoint written on one mesh restores onto any other (elastic scaling /
failover to a different slice topology).  Writes go to a temp dir first and are
renamed into place -- a crashed save can never corrupt the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name.replace("'", ""), leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extras: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extras or {}))
            self._thread.start()
        else:
            self._write(step, host_tree, extras or {})

    def _write(self, step: int, host_tree, extras: dict):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extras": extras, "leaves": {}}
        for name, leaf in _leaf_paths(host_tree):
            fname = name.replace("/", "__") + ".s0.npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"][name] = {
                "files": [fname], "dtype": str(leaf.dtype), "shape": list(leaf.shape)}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic commit
        with open(self.dir / ".LATEST_tmp", "w") as f:
            f.write(str(step))
        os.rename(self.dir / ".LATEST_tmp", self.dir / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")]

    def latest_step(self) -> int | None:
        f = self.dir / "LATEST"
        if not f.exists():
            return None
        return int(f.read_text())

    def restore(self, step: int, target_tree, shardings=None):
        """target_tree: pytree of arrays or ShapeDtypeStructs defining structure.
        shardings: matching pytree of NamedSharding (or None -> default device)."""
        self.wait()
        d = self.dir / f"step_{step:08d}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        names = dict(_leaf_paths(target_tree))
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (path, leaf), sh in zip(flat, shard_flat):
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path).replace("'", "")
            info = manifest["leaves"][name]
            arr = np.load(d / info["files"][0], mmap_mode="r")
            arr = np.asarray(arr)
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.device_put(arr.astype(info["dtype"])))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extras"]
