"""Fault tolerance for long training runs: step retry from checkpoint, straggler
detection, elastic re-meshing.

On a real fleet the failure signal is an XLA/runtime error or a missed heartbeat;
here failures are injected (tests) or surfaced as exceptions.  Recovery invariants:

  * data loader is a pure function of (seed, step) -> restart replays exactly;
  * checkpoints are atomic (checkpoint.py) -> a crash mid-save is invisible;
  * restore reshards -> the surviving device set may differ from the failed one.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable

log = logging.getLogger("repro.fault")


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps, once each."""

    def __init__(self, fail_at: set[int] | None = None):
        self.fail_at = set(fail_at or ())
        self.failed: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.failed:
            self.failed.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class StragglerDetector:
    """EWMA step-time monitor.  On a fleet, flagged steps trigger backup-task
    dispatch (MapReduce speculative execution -- the paper's substrate does exactly
    this for slow reducers); here we record and expose the events."""
    alpha: float = 0.9
    threshold: float = 3.0
    ewma: float | None = None
    events: list[tuple[int, float, float]] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.threshold * self.ewma
        if is_straggler:
            self.events.append((step, dt, self.ewma))
            log.warning("straggler: step %d took %.3fs (ewma %.3fs)", step, dt,
                        self.ewma)
        self.ewma = self.alpha * self.ewma + (1 - self.alpha) * dt
        return is_straggler


def run_with_recovery(*, n_steps: int, step_fn: Callable, state, batch_fn: Callable,
                      ckpt, ckpt_every: int = 10, max_retries: int = 5,
                      injector: FailureInjector | None = None,
                      straggler: StragglerDetector | None = None,
                      on_restore: Callable | None = None):
    """Generic recovering driver.

    step_fn(state, batch) -> (state, metrics);  state is any pytree.
    batch_fn(step) -> batch (deterministic).
    Returns (state, history, n_restarts).
    """
    step = 0
    if ckpt.latest_step() is not None:
        state, extras = ckpt.restore(ckpt.latest_step(), state)
        step = extras.get("next_step", 0)
    history = []
    retries = 0
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if injector is not None:
                injector.maybe_fail(step)
            state, metrics = step_fn(state, batch_fn(step))
            dt = time.perf_counter() - t0
            if straggler is not None:
                straggler.observe(step, dt)
            history.append(metrics)
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(step, state, extras={"next_step": step})
        except Exception as e:  # noqa: BLE001 -- any device failure
            retries += 1
            if retries > max_retries:
                raise
            log.warning("step %d failed (%s); restoring from checkpoint", step, e)
            last = ckpt.latest_step()
            if last is None:
                step = 0  # no checkpoint yet: replay from scratch (loader is pure)
                continue
            state, extras = ckpt.restore(last, state)
            step = extras.get("next_step", 0)
            if on_restore is not None:
                state = on_restore(state)
    ckpt.wait()
    return state, history, retries


def elastic_remesh(make_step_fn: Callable, make_mesh_fn: Callable, state, ckpt,
                   shardings_fn: Callable):
    """Elastic scaling: rebuild the mesh from the currently live device set,
    reshard the latest checkpoint onto it, and return a re-jitted step.

    make_mesh_fn() reads jax.devices() -- after a failure the runtime exposes the
    surviving set; shardings_fn(mesh) maps state -> NamedShardings on the new mesh.
    """
    mesh = make_mesh_fn()
    shardings = shardings_fn(mesh)
    last = ckpt.latest_step()
    if last is not None:
        state, _ = ckpt.restore(last, state, shardings=shardings)
    return make_step_fn(mesh), state, mesh
