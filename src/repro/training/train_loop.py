"""Jitted train-step factories: plain, microbatch-accumulated, and
compressed-DP variants.

Microbatch accumulation serves two purposes at scale: (a) activation memory, and
(b) communication overlap -- the gradient psum of microbatch i overlaps the compute
of microbatch i+1 under XLA's latency-hiding scheduler because accumulation breaks
the dependency between the full batch and a single end-of-step all-reduce.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from .optimizer import OptimizerConfig, apply_updates, init_state


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig):
    """loss_fn(params, batch) -> (loss, metrics)."""

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                           batch)
        params, opt_state, opt_metrics = apply_updates(params, grads, opt_state,
                                                       opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return step


def make_train_step_accum(loss_fn: Callable, opt_cfg: OptimizerConfig,
                          n_micro: int):
    """Gradient accumulation over ``n_micro`` microbatches (batch dim split)."""

    def step(params, opt_state, batch):
        def micro(i):
            mb = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro, 0),
                batch)
            return jax.value_and_grad(loss_fn, has_aux=True)(params, mb)

        def body(carry, i):
            acc, loss_acc = carry
            (loss, _), grads = micro(i)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.float32(0)),
                                            jnp.arange(n_micro))
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        params, opt_state, opt_metrics = apply_updates(params, grads, opt_state,
                                                       opt_cfg)
        return params, opt_state, {"loss": loss_sum / n_micro, **opt_metrics}

    return step


def make_train_step_accum_unrolled(loss_fn: Callable, opt_cfg: OptimizerConfig,
                                   n_micro: int):
    """Statically-unrolled gradient accumulation.

    vs the lax.scan variant: (a) XLA cost_analysis counts every microbatch (scan
    bodies are counted once -- DESIGN.md SS5), (b) buffer liveness frees each
    microbatch's activations before the next starts, dividing the remat-carry
    footprint by n_micro (the MoE train cells' memory fix, SSPerf H1 iter 3),
    (c) each microbatch's gradient psum can overlap the next microbatch's compute
    under the latency-hiding scheduler.
    """

    def step(params, opt_state, batch):
        grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        loss_sum = jnp.float32(0)
        for i in range(n_micro):
            mb = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro, 0),
                batch)
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            grads = jax.tree.map(jnp.add, grads, g)
            loss_sum = loss_sum + loss
            # sequence the microbatches: without this barrier the fwd passes are
            # data-independent and XLA schedules them concurrently, keeping every
            # microbatch's remat carries live simultaneously (measured: no memory
            # win without it -- SSPerf H1 iter 3).
            params, grads = jax.lax.optimization_barrier((params, grads))
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        params, opt_state, opt_metrics = apply_updates(params, grads, opt_state,
                                                       opt_cfg)
        return params, opt_state, {"loss": loss_sum / n_micro, **opt_metrics}

    return step


def eval_shape_state(init_params_fn, opt_cfg: OptimizerConfig):
    """ShapeDtypeStructs of (params, opt_state) without allocating -- dry-run input."""
    params_shapes = jax.eval_shape(init_params_fn)
    state_shapes = jax.eval_shape(init_state, params_shapes)
    return params_shapes, state_shapes
