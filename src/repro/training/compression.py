"""Gradient compression for the slow (DCN / pod) axis: int8 all-reduce with
per-tensor scales and stochastic rounding.

Intra-pod ICI is fast enough for bf16/fp32 reductions; the cross-pod data-parallel
all-reduce rides DCN at ~1/8 the bandwidth, so quantizing that hop 4x (fp32->int8)
moves the collective roofline term down proportionally.  Stochastic rounding keeps
the quantization unbiased (E[q] = g), which is what makes compressed SGD converge.

Used inside shard_map over the 'pod' axis:  grads are reduced in int8 across pods,
then averaged.  psum of int8 values is exact in int32 accumulation up to 2^23 pods.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(tree, axis_name: str, key):
    """Unbiased int8 all-reduce-mean of a gradient pytree over ``axis_name``."""
    n = jax.lax.psum(1, axis_name)
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        q, scale = _quantize(g.astype(jnp.float32), k)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # every pod contributed with its own scale; use the max scale for decode
        # (scales are near-identical across pods for averaged grads) -- we psum the
        # scaled values instead for exactness:
        s_all = jax.lax.pmax(scale, axis_name)
        out.append(acc.astype(jnp.float32) * s_all / n)
    return jax.tree.unflatten(treedef, out)


def compressed_psum_exact_scale(tree, axis_name: str, key):
    """Variant that all-gathers per-pod scales (tiny) for exact per-source decode:
    dequantize-then-reduce semantics at int8 wire cost + one scalar allgather."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    n = jax.lax.psum(1, axis_name)
    out = []
    for g, k in zip(leaves, keys):
        q, scale = _quantize(g.astype(jnp.float32), k)
        # scale-normalized reduce: send q * (scale / s_ref) quantized at a shared
        # reference scale, where s_ref = pmax(scale)
        s_ref = jax.lax.pmax(scale, axis_name)
        q2 = jnp.clip(jnp.round(q.astype(jnp.float32) * (scale / s_ref)),
                      -127, 127).astype(jnp.int32)
        acc = jax.lax.psum(q2, axis_name)
        out.append(acc.astype(jnp.float32) * s_ref / n)
    return jax.tree.unflatten(treedef, out)
