from . import checkpoint, compression, fault_tolerance, optimizer, train_loop

__all__ = ["checkpoint", "compression", "fault_tolerance", "optimizer", "train_loop"]
