"""Arch registry + cell builder: every (architecture x input-shape) pair becomes a
``Cell`` that the dry-run lowers and compiles on the production mesh.

Sharding policy (single place, applied per arch):
  * LM params: FSDP over `data` (d_model dim), TP over `model` (head / ff / vocab
    dims) -- Megatron + ZeRO-3 hybrid.  KV projections are replicated over `model`
    when n_kv doesn't divide the axis (standard GQA-TP fallback).
  * MoE experts: expert dim over `model` (EP).
  * Batch: over ('pod', 'data') -- pod-level DP rides DCN.
  * GNN: nodes + edges over `data`; model replicated (it is tiny).
  * RecSys: embedding tables row-sharded over `model`; batch over ('pod', 'data').

Non-divisible dims fall back to replication (``shard_if``) so every cell lowers on
both the 16x16 and 2x16x16 meshes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------- registry
_REGISTRY: dict[str, "ArchDef"] = {}


@dataclass
class ShapeDef:
    name: str
    kind: str                      # train | prefill | decode | forward | serve
    dims: dict[str, int]
    skip_reason: str | None = None


@dataclass
class ArchDef:
    name: str
    family: str                    # lm | gnn | recsys | ngram
    make: Callable[[], Any]                    # full config object
    make_reduced: Callable[[], Any]            # CPU-smoke config object
    shapes: dict[str, ShapeDef]
    build_cell: Callable[..., "Cell"]          # (arch_cfg, shape, mesh) -> Cell
    notes: str = ""


@dataclass
class Cell:
    """Everything the dry-run needs for one (arch x shape x mesh)."""
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    args: tuple                                # ShapeDtypeStructs / abstract pytrees
    in_shardings: Any
    out_shardings: Any = None                  # set to alias donated buffers
    donate_argnums: tuple = ()
    # scan-body probe for the cost_analysis trip-count correction (DESIGN.md SS5):
    scan_probe: tuple | None = None            # (fn, args, in_shardings, extra_trips)
    model_flops: float = 0.0
    notes: str = ""


def register(arch: ArchDef):
    _REGISTRY[arch.name] = arch
    return arch


def get(name: str) -> ArchDef:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> list[str]:
    return sorted(_REGISTRY)


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in all_archs() for s in _REGISTRY[a].shapes]


# ------------------------------------------------------------------ shard helpers
def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard_if(mesh, dim_size: int, axis) -> str | tuple | None:
    """Return the axis spec if dim_size is divisible by the axis extent, else None
    (replicate)."""
    names = axis if isinstance(axis, tuple) else (axis,)
    extent = 1
    for n in names:
        if n not in mesh.axis_names:
            return None
        extent *= mesh.shape[n]
    if dim_size % extent != 0:
        return None
    return axis


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------- LM sharding + specs
def lm_param_pspecs(cfg, mesh):
    """PartitionSpec pytree matching transformer.init_params structure."""
    a = cfg.attn
    dshard = shard_if(mesh, cfg.d_model, "data")
    tp_q = shard_if(mesh, a.h_eff * a.d_head, "model")
    tp_kv = shard_if(mesh, a.kv_eff, "model") and "model"  # replicate if kv % tp

    if a.kind == "gqa":
        attn = {
            "wq": P(None, dshard, tp_q),
            "wk": P(None, dshard, "model" if tp_kv else None),
            "wv": P(None, dshard, "model" if tp_kv else None),
            "wo": P(None, tp_q, dshard),
        }
    else:
        qd = a.h_eff * (a.d_nope + a.d_rope)
        od = a.h_eff * a.d_v
        attn = {
            "wdq": P(None, dshard, None),
            "wuq": P(None, None, shard_if(mesh, qd, "model")),
            "wdkv": P(None, dshard, None),
            "wukv": P(None, None, shard_if(mesh, a.h_eff * (a.d_nope + a.d_v),
                                           "model")),
            "wkr": P(None, dshard, None),
            "wo": P(None, shard_if(mesh, od, "model"), dshard),
        }
    if cfg.moe is not None:
        # layouts match moe_ffn_sharded's shard_map in_specs exactly (no layer-
        # entry resharding): EP when E divides tp, else per-expert ff TP
        # (mixtral E=8 on tp=16 -- replicating experts would replicate the FLOPs
        # 16x, measured in SSPerf H1).
        m = cfg.moe
        ep = shard_if(mesh, m.n_experts, "model")
        # d_model dim additionally FSDP-sharded over `data` (ZeRO-3): the
        # shard_map entry all-gathers it per layer, trading ~200 MB/layer of
        # ICI for the 8 GB/device fp32 grad+moment blowup of resident expert
        # weights (SSPerf H1 iter 3 -- measured).
        if ep:
            ffn = {"router": P(None, None, None),
                   "wg": P(None, ep, dshard, None),
                   "wu": P(None, ep, dshard, None),
                   "wo": P(None, ep, None, dshard)}
        else:
            ff_ax = shard_if(mesh, m.d_ff_expert, "model")
            ffn = {"router": P(None, None, None),
                   "wg": P(None, None, dshard, ff_ax),
                   "wu": P(None, None, dshard, ff_ax),
                   "wo": P(None, None, ff_ax, dshard)}
        if m.n_shared:
            ffs = m.d_ff_shared or m.d_ff_expert * m.n_shared
            ffn.update({"sg": P(None, None, shard_if(mesh, ffs, "model")),
                        "su": P(None, None, shard_if(mesh, ffs, "model")),
                        "so": P(None, shard_if(mesh, ffs, "model"), None)})
    else:
        ffn = {"wg": P(None, dshard, shard_if(mesh, cfg.d_ff, "model")),
               "wu": P(None, dshard, shard_if(mesh, cfg.d_ff, "model")),
               "wo": P(None, shard_if(mesh, cfg.d_ff, "model"), dshard)}
    layers = {"ln1": P(None, None), "ln2": P(None, None), "ffn": ffn}
    layers.update(attn)
    return {
        "embed": P(shard_if(mesh, cfg.vocab_size, "model"), dshard),
        "layers": layers,
        "final_norm": P(None),
        "lm_head": P(dshard, shard_if(mesh, cfg.vocab_size, "model")),
    }


def layer_pspecs(full_pspecs):
    """Drop the leading L axis of the stacked layer specs (for the body probe)."""
    return jax.tree.map(lambda s: P(*s[1:]), full_pspecs["layers"],
                        is_leaf=lambda x: isinstance(x, P))


def opt_pspecs(param_pspecs):
    return {"m": param_pspecs, "v": param_pspecs, "step": P()}


def lm_batch_pspec(mesh, batch: int):
    dp = dp_axes(mesh)
    b = shard_if(mesh, batch, dp if len(dp) > 1 else dp[0])
    return P(b, None)


def cache_pspecs(cfg, mesh, batch: int, t: int):
    """Decode-cache sharding: batch over DP if divisible, else cache length over
    `data` (context-parallel decode), else replicate."""
    a = cfg.attn
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    b_ax = shard_if(mesh, batch, dp)
    t_ax = None if b_ax else shard_if(mesh, t, "data")
    if a.kind == "mla":
        return {"ckv": P(None, b_ax, t_ax, None), "kr": P(None, b_ax, t_ax, None)}
    kv_ax = shard_if(mesh, a.kv_eff, "model") and "model"
    return {"k": P(None, b_ax, t_ax, kv_ax, None),
            "v": P(None, b_ax, t_ax, kv_ax, None)}


def lm_model_flops(cfg, kind: str, batch: int, seq: int, cache: int = 0) -> float:
    """Analytic MODEL_FLOPS: 6ND train / 2ND serve (+ attention terms)."""
    a = cfg.attn
    if a.kind == "gqa":
        attn_p = cfg.d_model * (a.n_heads + 2 * a.n_kv) * a.d_head \
                 + a.n_heads * a.d_head * cfg.d_model
    else:
        attn_p = (cfg.d_model * a.q_lora + a.q_lora * a.n_heads * (a.d_nope + a.d_rope)
                  + cfg.d_model * a.kv_lora
                  + a.kv_lora * a.n_heads * (a.d_nope + a.d_v)
                  + cfg.d_model * a.d_rope + a.n_heads * a.d_v * cfg.d_model)
    if cfg.moe is not None:
        m = cfg.moe
        ffn_p = m.top_k * 3 * cfg.d_model * m.d_ff_expert
        if m.n_shared:
            ffn_p += 3 * cfg.d_model * (m.d_ff_shared or m.d_ff_expert * m.n_shared)
        ffn_p += cfg.d_model * m.n_experts
    else:
        ffn_p = 3 * cfg.d_model * cfg.d_ff
    n_active = cfg.n_layers * (attn_p + ffn_p) + 2 * cfg.vocab_size * cfg.d_model
    tokens = batch * seq
    if kind == "train":
        dense = 6 * n_active * tokens
        # causal attention: fwd 4*H*dh*S^2/2 per layer per sequence; x3 for bwd
        win = min(seq, a.window) if a.window else seq
        attn = 12 * cfg.n_layers * a.n_heads * a.d_head * batch * seq * win / 2
        return dense + attn
    if kind == "prefill":
        win = min(seq, a.window) if a.window else seq
        return (2 * n_active * tokens
                + 4 * cfg.n_layers * a.n_heads * a.d_head * batch * seq * win / 2)
    if kind == "decode":
        return (2 * n_active * batch
                + 4 * cfg.n_layers * a.n_heads * a.d_head * batch * cache)
    raise ValueError(kind)


# ------------------------------------------------------------------- LM cells
def build_lm_cell(cfg, shape: ShapeDef, mesh) -> Cell:
    from repro.models import transformer as tf
    from repro.training.optimizer import OptimizerConfig, init_state
    from repro.training.train_loop import make_train_step

    b = shape.dims["global_batch"]
    s = shape.dims["seq_len"]
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    act_axes = shard_if(mesh, b, dp)     # None when batch can't shard (e.g. B=1)
    cfg = dataclasses.replace(cfg, shard_activations=act_axes)
    if cfg.moe is not None:              # distributed MoE (shard_map sort dispatch)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, mesh=mesh, dp_axes=act_axes))
    # transparent head padding when n_heads doesn't divide the tensor axis
    # (phi3 / minicpm3: 40 heads on tp=16 -> 48, masked pads; SSPerf notes)
    tp_size = mesh.shape.get("model", 1)
    a = cfg.attn
    if a.n_heads % tp_size:
        g = a.n_heads // a.n_kv
        import math
        step_h = math.lcm(tp_size, g)
        h_pad = -(-a.n_heads // step_h) * step_h
        cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(a, pad_heads_to=h_pad))
    pspecs = lm_param_pspecs(cfg, mesh)
    params_sh = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))

    if shape.kind == "train":
        from repro.training.train_loop import make_train_step_accum
        opt_sh = jax.eval_shape(init_state, params_sh)
        batch_sds = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        # microbatch (lax.scan accumulation) so the remat carries
        # ((b/dp) * s * d * 2B * L) fit HBM -- scan forces sequential buffer reuse
        # where the unrolled variant measured NO reuse on XLA:CPU (SSPerf H1 it.3)
        dp_size = 1
        for a in dp_axes(mesh):
            dp_size *= mesh.shape[a]
        carry_bytes = (b // max(dp_size, 1)) * s * cfg.d_model * 2 * cfg.n_layers
        n_micro = 1
        while (carry_bytes / n_micro > 2 * 2 ** 30 and n_micro < 8
               and (b // (n_micro * 2)) % dp_size == 0):
            n_micro *= 2
        if n_micro > 1:
            step = make_train_step_accum(
                lambda p, bt: tf.loss_fn(p, bt, cfg), OptimizerConfig(), n_micro)
        else:
            step = make_train_step(lambda p, bt: tf.loss_fn(p, bt, cfg),
                                   OptimizerConfig())
        bspec = {"tokens": lm_batch_pspec(mesh, b), "labels": lm_batch_pspec(mesh, b)}
        in_sh = (named(mesh, pspecs), named(mesh, opt_pspecs(pspecs)),
                 named(mesh, bspec))
        probe = _lm_layer_probe(cfg, mesh, pspecs, b // n_micro, s, train=True)
        # nested scans each counted once by cost_analysis: the full program holds
        # one microbatch-scan whose body holds one layer-scan body -> add
        # (n_micro * L - 1) layer-body costs
        probe = probe[:3] + (n_micro * cfg.n_layers - 1,)
        metric_sh = {k: NamedSharding(mesh, P()) for k in
                     ("loss", "lr", "grad_norm")}
        if n_micro == 1:
            metric_sh.update(ce=NamedSharding(mesh, P()),
                             aux=NamedSharding(mesh, P()))
        out_sh = (in_sh[0], in_sh[1], metric_sh)   # alias donated params/opt
        return Cell(cfg.name, shape.name, "train", step,
                    (params_sh, opt_sh, batch_sds), in_sh, out_shardings=out_sh,
                    donate_argnums=(0, 1), scan_probe=probe,
                    model_flops=lm_model_flops(cfg, "train", b, s),
                    notes=f"n_micro={n_micro}")

    if shape.kind == "prefill":
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
        fn = lambda p, t: tf.prefill(p, t, cfg, max_seq=s)
        in_sh = (named(mesh, pspecs), named(mesh, lm_batch_pspec(mesh, b)))
        probe = _lm_layer_probe(cfg, mesh, pspecs, b, s, train=False)
        return Cell(cfg.name, shape.name, "prefill", fn, (params_sh, toks), in_sh,
                    scan_probe=probe,
                    model_flops=lm_model_flops(cfg, "prefill", b, s))

    # decode: one new token against a cache of seq_len
    t = tf.cache_len(cfg, s)
    cache_sh = jax.eval_shape(lambda: tf.init_cache(cfg, b, s))
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    fn = lambda p, c, tk: tf.decode_step(p, c, tk, jnp.int32(s - 1), cfg)
    cspec = cache_pspecs(cfg, mesh, b, t)
    in_sh = (named(mesh, pspecs), named(mesh, cspec),
             named(mesh, P(shard_if(mesh, b, dp_axes(mesh)
                                    if len(dp_axes(mesh)) > 1 else "data"))))
    # NOTE: forcing out_shardings here to alias the donated cache was measured to
    # BACKFIRE (phi3 decode temp 31.6 -> 120 GB: GSPMD inserted full reshards of
    # the updated cache to satisfy the pinned output layout) -- left unset, XLA
    # picks the update-in-place layout.  SSPerf refuted-hypothesis log.
    return Cell(cfg.name, shape.name, "decode", fn, (params_sh, cache_sh, tok),
                in_sh, donate_argnums=(1,),
                model_flops=lm_model_flops(cfg, "decode", b, s, cache=t))


def _lm_layer_probe(cfg, mesh, pspecs, b, s, train: bool):
    """Single-layer (scan body) cost probe: compiled separately, added (L-1)x."""
    from repro.models import transformer as tf

    one = dataclasses.replace(cfg, n_layers=1)
    params_sh = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), one))
    one_pspecs = lm_param_pspecs(one, mesh)
    x = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                             cfg.dtype if hasattr(cfg, "dtype") else jnp.bfloat16)
    xspec = P(lm_batch_pspec(mesh, b)[0], None, None)

    if train:
        def body_loss(layer_params, xin):
            def f(lp, xi):
                h, aux, _ = _apply_single_layer(lp, xi, one)
                return jnp.sum(h.astype(jnp.float32)) + aux
            if cfg.remat:  # match the rematerialized scan body's bwd recompute
                f = jax.checkpoint(f)
            return jax.grad(f)(layer_params, xin)
        fn = body_loss
    else:
        def fwd(layer_params, xin):
            h, aux, _ = _apply_single_layer(layer_params, xin, one)
            return h
        fn = fwd
    in_sh = (named(mesh, one_pspecs["layers"]), NamedSharding(mesh, xspec))
    layer_sh = params_sh["layers"]
    return (fn, (layer_sh, x), in_sh, cfg.n_layers - 1)


def _apply_single_layer(stacked_layer_params, x, cfg1):
    from repro.models import transformer as tf
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    pl = jax.tree.map(lambda v: v[0], stacked_layer_params)
    h, cache = tf._attn_block(pl, tf.rms_norm(x, pl["ln1"], cfg1.norm_eps),
                              positions, cfg1, False)
    x = x + h
    h, aux = tf._ffn_block(pl, tf.rms_norm(x, pl["ln2"], cfg1.norm_eps), cfg1)
    return x + h, aux, cache
