"""bst [arXiv:1905.06874]: Behavior Sequence Transformer -- embed_dim 32, seq 20,
1 transformer block, 8 heads, MLP 1024-512-256."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import recsys as R
from .base import ArchDef, ShapeDef, register, shard_if
from .recsys_common import SHAPES, dp_spec, make_recsys_cell

FULL = R.BSTConfig(item_vocab=4_000_000, embed_dim=32, seq_len=20, n_blocks=1,
                   n_heads=8, mlp_dims=(1024, 512, 256))
REDUCED = R.BSTConfig(item_vocab=500, embed_dim=8, seq_len=6, n_blocks=1,
                      n_heads=2, mlp_dims=(32, 16))


def _flops(cfg: R.BSTConfig, batch: int) -> float:
    d, s = cfg.embed_dim, cfg.seq_len + 1
    attn = cfg.n_blocks * (4 * s * d * d + 2 * s * s * d + 8 * s * d * d)
    dims = (s * d,) + cfg.mlp_dims + (1,)
    m = sum(2 * a * b for a, b in zip(dims, dims[1:]))
    return float(batch * (attn + m))


def build_cell(cfg_factory, shape: ShapeDef, mesh):
    cfg = FULL
    params_sh = jax.eval_shape(lambda: R.bst_init(jax.random.PRNGKey(0), cfg))
    pspec = jax.tree.map(lambda _: P(), params_sh)
    pspec["item_embed"] = P(shard_if(mesh, cfg.item_vocab, "model"), None)
    pspec["mlp"] = [(P(None, shard_if(mesh, w.shape[1], "model")), P(None))
                    for (w, b) in params_sh["mlp"]]
    b = shape.dims.get("n_candidates", shape.dims["batch"])
    dp = dp_spec(mesh)
    batch_sds = {"history": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
                 "target": jax.ShapeDtypeStruct((b,), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b,), jnp.float32)}
    bspec = {"history": P(dp, None), "target": P(dp), "labels": P(dp)}
    if shape.name == "retrieval_cand":  # one user, 1M candidate targets
        batch_sds.pop("labels"), bspec.pop("labels")
        fwd = lambda p, bt: R.bst_forward(p, {**bt, "labels": None}, cfg)
    else:
        fwd = lambda p, bt: R.bst_forward(p, bt, cfg)
    return make_recsys_cell(
        name="bst", shape=shape, mesh=mesh, params_sh=params_sh, pspec=pspec,
        loss=lambda p, bt: R.bst_loss(p, bt, cfg), forward=fwd,
        batch_sds=batch_sds, batch_spec=bspec, model_flops=_flops(cfg, b))


register(ArchDef(
    name="bst", family="recsys",
    make=lambda: FULL, make_reduced=lambda: REDUCED,
    shapes=SHAPES, build_cell=build_cell,
    notes="user-behavior sequences ARE token sequences: SUFFIX-sigma computes their "
          "n-gram statistics unchanged (DESIGN.md SSArch-applicability)",
))
