"""Shared shapes + cell builder scaffolding for the four recsys architectures.

Embedding tables are row-sharded over `model` (the vocab dimension); batches shard
over ('pod', 'data').  serve_* shapes lower a pure forward (no optimizer state);
retrieval_cand scores one query against 1M candidates (batched dot / full item-tower
sweep -- never a loop)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .base import Cell, ShapeDef, dp_axes, named, shard_if

SHAPES = {
    "train_batch": ShapeDef("train_batch", "train", {"batch": 65_536}),
    "serve_p99": ShapeDef("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeDef("serve_bulk", "serve", {"batch": 262_144}),
    "retrieval_cand": ShapeDef("retrieval_cand", "serve",
                               {"batch": 1, "n_candidates": 1_000_000}),
}


def dp_spec(mesh):
    dp = dp_axes(mesh)
    return dp if len(dp) > 1 else dp[0]


def make_recsys_cell(*, name: str, shape: ShapeDef, mesh, params_sh, pspec,
                     loss, forward, batch_sds, batch_spec,
                     model_flops: float, notes: str = "") -> Cell:
    from repro.training.optimizer import OptimizerConfig, init_state
    from repro.training.train_loop import make_train_step

    if shape.kind == "train":
        opt_sh = jax.eval_shape(init_state, params_sh)
        step = make_train_step(loss, OptimizerConfig())
        in_sh = (named(mesh, pspec),
                 named(mesh, {"m": pspec, "v": pspec, "step": P()}),
                 named(mesh, batch_spec))
        return Cell(name, shape.name, "train", step, (params_sh, opt_sh, batch_sds),
                    in_sh, donate_argnums=(0, 1), model_flops=3 * model_flops,
                    notes=notes)
    in_sh = (named(mesh, pspec), named(mesh, batch_spec))
    return Cell(name, shape.name, "serve", forward, (params_sh, batch_sds), in_sh,
                model_flops=model_flops, notes=notes)
