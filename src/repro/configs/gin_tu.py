"""gin-tu [arXiv:1810.00826]: 5-layer GIN, d_hidden 64, sum aggregator,
learnable eps.  Four graph regimes; message passing = segment_sum over the edge
index (JAX sparse is BCOO-only -- the scatter IS the implementation).

Sharding: edges over `data` (padded to mesh-divisible counts), node states
replicated for the small graphs and psum-combined partial scatters for the large
ones (GSPMD inserts the all-reduce)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.gnn import GINConfig, init_params, loss_fn
from .base import ArchDef, Cell, ShapeDef, dp_axes, named, register, shard_if

SHAPES = {
    # Cora: full-batch node classification
    "full_graph_sm": ShapeDef("full_graph_sm", "train",
                              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
                               "n_classes": 7}),
    # Reddit with layer sampling, fanout 15-10 from 1024 seeds
    "minibatch_lg": ShapeDef("minibatch_lg", "train",
                             {"n_nodes": 232_965, "n_edges": 114_615_892,
                              "batch_nodes": 1024, "fanout": (15, 10),
                              "d_feat": 602, "n_classes": 41}),
    # ogbn-products full batch
    "ogb_products": ShapeDef("ogb_products", "train",
                             {"n_nodes": 2_449_029, "n_edges": 61_859_140,
                              "d_feat": 100, "n_classes": 47}),
    # batched small molecules
    "molecule": ShapeDef("molecule", "train",
                         {"n_nodes": 30, "n_edges": 64, "batch": 128,
                          "d_feat": 16, "n_classes": 2}),
}


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def sampled_sizes(dims) -> tuple[int, int]:
    """(n_sub_nodes, n_sub_edges) of the layer-sampled subgraph."""
    n = dims["batch_nodes"]
    nodes, edges = n, 0
    frontier = n
    for fo in dims["fanout"]:
        edges += frontier * fo
        frontier *= fo
        nodes += frontier
    return nodes, edges


def build_cell(cfg_factory, shape: ShapeDef, mesh) -> Cell:
    from repro.training.optimizer import OptimizerConfig, init_state
    from repro.training.train_loop import make_train_step

    d = shape.dims
    mult = 1
    for a in dp_axes(mesh):
        mult *= mesh.shape[a]
    mult = max(mult, 16) * 16  # divisible on both meshes

    if shape.name == "minibatch_lg":
        n_nodes, n_edges = sampled_sizes(d)
    elif shape.name == "molecule":
        n_nodes, n_edges = d["n_nodes"] * d["batch"], d["n_edges"] * d["batch"]
    else:
        n_nodes, n_edges = d["n_nodes"], d["n_edges"]
    n_nodes_p, n_edges_p = _pad_to(n_nodes, mult), _pad_to(n_edges, mult)

    cfg = GINConfig("gin-tu", n_layers=5, d_hidden=64, d_feat=d["d_feat"],
                    n_classes=d["n_classes"], comm_dtype=jnp.bfloat16)
    params_sh = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    opt_sh = jax.eval_shape(init_state, params_sh)
    batch_sds = {
        "features": jax.ShapeDtypeStruct((n_nodes_p, d["d_feat"]), jnp.float32),
        "edge_src": jax.ShapeDtypeStruct((n_edges_p,), jnp.int32),
        "edge_dst": jax.ShapeDtypeStruct((n_edges_p,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((n_edges_p,), jnp.bool_),
        "labels": jax.ShapeDtypeStruct((n_nodes_p,), jnp.int32),
        "label_mask": jax.ShapeDtypeStruct((n_nodes_p,), jnp.bool_),
    }
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    bspec = {
        "features": P(dp, None), "edge_src": P(dp), "edge_dst": P(dp),
        "edge_mask": P(dp), "labels": P(dp), "label_mask": P(dp),
    }
    pspec = jax.tree.map(lambda _: P(), params_sh)  # tiny model: replicated
    from repro.models.gnn import loss_fn_dst_partitioned
    step = make_train_step(
        lambda p, b: loss_fn_dst_partitioned(p, b, cfg, mesh, dp),
        OptimizerConfig())
    in_sh = (named(mesh, pspec), named(mesh, {"m": pspec, "v": pspec, "step": P()}),
             named(mesh, bspec))
    # MODEL_FLOPS: per layer 2*E*F gather-sum + 2*N*(F*H + H*H) MLPs; x3 train
    f, h = d["d_feat"], cfg.d_hidden
    fl = 0
    fin = f
    for _ in range(cfg.n_layers):
        fl += 2 * n_edges * fin + 2 * n_nodes * (fin * h + h * h)
        fin = h
    fl = 3 * (fl + 2 * n_nodes * h * d["n_classes"])
    return Cell("gin-tu", shape.name, "train", step,
                (params_sh, opt_sh, batch_sds), in_sh, donate_argnums=(0, 1),
                model_flops=float(fl),
                notes=f"padded nodes {n_nodes}->{n_nodes_p} edges {n_edges}->{n_edges_p}")


register(ArchDef(
    name="gin-tu", family="gnn",
    make=lambda: GINConfig("gin-tu", 5, 64, 1433, 7),
    make_reduced=lambda: GINConfig("gin-tu-smoke", 2, 8, 8, 3),
    shapes=SHAPES, build_cell=build_cell,
    notes="paper technique inapplicable to the model itself; shares the "
          "segment-reduce substrate (DESIGN.md SSArch-applicability)",
))
