"""Shared shape-set + registration helper for the five LM architectures."""
from __future__ import annotations

import dataclasses

from .base import ArchDef, ShapeDef, build_lm_cell, register

FULL_ATTN_SKIP = ("long_500k needs sub-quadratic attention; this arch is pure "
                  "full-attention (see DESIGN.md SSArch-applicability)")


def lm_shapes(long_ok: bool) -> dict[str, ShapeDef]:
    return {
        "train_4k": ShapeDef("train_4k", "train",
                             {"seq_len": 4096, "global_batch": 256}),
        "prefill_32k": ShapeDef("prefill_32k", "prefill",
                                {"seq_len": 32768, "global_batch": 32}),
        "decode_32k": ShapeDef("decode_32k", "decode",
                               {"seq_len": 32768, "global_batch": 128}),
        "long_500k": ShapeDef("long_500k", "decode",
                              {"seq_len": 524288, "global_batch": 1},
                              skip_reason=None if long_ok else FULL_ATTN_SKIP),
    }


def register_lm(name: str, full_cfg, reduced_cfg, long_ok: bool, notes: str = ""):
    def build(arch_cfg, shape, mesh):
        return build_lm_cell(arch_cfg, shape, mesh)

    register(ArchDef(
        name=name, family="lm",
        make=lambda: full_cfg,
        make_reduced=lambda: reduced_cfg,
        shapes=lm_shapes(long_ok),
        build_cell=build,
        notes=notes,
    ))
