"""The paper's own workload as an 11th selectable arch: ``--arch ngram-suffix-sigma``.

Shapes mirror Table I of the paper (NYT / ClueWeb09-B token counts) plus the two
use-cases of SSVII-D.  A MapReduce job has no model axis: the cell re-views the same
devices as a flat 1-D mesh (R = 256 / 512 reducers), which is exactly the paper's
reducer-count knob.  The dry-run proves the shuffle + sort + reduce pipeline lowers
and compiles at production scale; EXPERIMENTS.md SSPerf hillclimbs it.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .base import ArchDef, Cell, ShapeDef, register

SHAPES = {
    # language-model use case: sigma=5, low tau (SSVII-D a), NYT token scale
    "nyt_lm": ShapeDef("nyt_lm", "mapreduce",
                       {"n_tokens": 1_049_440_645, "vocab": 345_827, "sigma": 5}),
    # analytics use case: sigma=100 (SSVII-D b); CW 25% sample scale
    "cw_analytics": ShapeDef("cw_analytics", "mapreduce",
                             {"n_tokens": 21_404_321_682 // 4, "vocab": 979_935,
                              "sigma": 100}),
    # beyond-paper two-phase sigma split of the same workload (SSPerf H3):
    # suffix-sigma at sigma_head=16 + APRIORI-masked wide pass on the survivors
    "cw_analytics_split": ShapeDef("cw_analytics_split", "mapreduce",
                                   {"n_tokens": 21_404_321_682 // 4,
                                    "vocab": 979_935, "sigma": 100,
                                    "sigma_head": 16, "survivor_frac": 1 / 64}),
}


def flat_mesh(mesh):
    devs = mesh.devices.reshape(-1)
    return jax.sharding.Mesh(devs, ("shards",))


def build_cell(cfg_factory, shape: ShapeDef, mesh) -> Cell:
    from repro.core.stats import NGramConfig
    from repro.core.suffix_sigma import build_distributed_job
    from repro.mapreduce import pack as packing

    d = shape.dims
    fmesh = flat_mesh(mesh)
    n_parts = fmesh.shape["shards"]
    cfg = NGramConfig(sigma=d["sigma"], tau=100, vocab_size=d["vocab"])
    n_local = -(-d["n_tokens"] // n_parts)
    n_local = -(-n_local // 8) * 8
    capacity = max(8, int(cfg.capacity_factor * n_local / n_parts) + 1)
    tokens_sds = jax.ShapeDtypeStruct((n_parts, n_local), jnp.int32)
    dummy_bkt = jax.ShapeDtypeStruct((1, 1), jnp.uint32)
    n_l = packing.n_lanes(cfg.sigma, cfg.vocab_size)
    rec_bytes = packing.record_bytes(cfg.sigma, cfg.vocab_size)
    # sort-dominated job: "useful work" ~ key comparisons N * log2(n_local) * lanes
    comp = d["n_tokens"] * max(1.0, math.log2(max(n_local, 2))) * n_l

    if "sigma_head" in d:
        # two-phase: narrow job on the full stream + wide job on the survivors
        import dataclasses
        cfg_a = dataclasses.replace(cfg, sigma=d["sigma_head"])
        cap_a = max(8, int(cfg.capacity_factor * n_local / n_parts) + 1)
        n_local_b = max(64, int(n_local * d["survivor_frac"]))
        n_local_b = -(-n_local_b // 8) * 8
        cap_b = max(8, int(cfg.capacity_factor * n_local_b / n_parts) + 1)
        job_a = build_distributed_job(cfg_a, fmesh, "shards", cap_a)
        job_b = build_distributed_job(cfg, fmesh, "shards", cap_b)
        surv_sds = jax.ShapeDtypeStruct((n_parts, n_local_b), jnp.int32)

        def two_phase(tokens_p, surv_p, bkt):
            a = job_a(tokens_p, bkt)
            b = job_b(surv_p, bkt)
            return a, b

        n_l_a = packing.n_lanes(d["sigma_head"], cfg.vocab_size)
        comp2 = (d["n_tokens"] * max(1.0, math.log2(max(n_local, 2))) * n_l_a
                 + d["n_tokens"] * d["survivor_frac"]
                 * max(1.0, math.log2(max(n_local_b, 2))) * n_l)
        return Cell("ngram-suffix-sigma", shape.name, "mapreduce", two_phase,
                    (tokens_sds, surv_sds, dummy_bkt),
                    (NamedSharding(fmesh, P("shards", None)),
                     NamedSharding(fmesh, P("shards", None)),
                     NamedSharding(fmesh, P())),
                    model_flops=float(comp2),
                    notes=f"two-phase sigma {d['sigma_head']}+{d['sigma']}, "
                          f"caps {cap_a}/{cap_b}")

    job = build_distributed_job(cfg, fmesh, "shards", capacity)
    return Cell("ngram-suffix-sigma", shape.name, "mapreduce", job,
                (tokens_sds, dummy_bkt),
                (NamedSharding(fmesh, P("shards", None)),
                 NamedSharding(fmesh, P())),
                model_flops=float(comp),
                notes=f"R={n_parts} reducers, record={rec_bytes}B, cap={capacity}")


register(ArchDef(
    name="ngram-suffix-sigma", family="ngram",
    make=lambda: None, make_reduced=lambda: None,
    shapes=SHAPES, build_cell=build_cell,
    notes="the paper's contribution itself, as a dry-runnable workload",
))
