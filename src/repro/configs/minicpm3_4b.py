"""minicpm3-4b [hf:openbmb/MiniCPM3-4B]: 62L d2560 40H dense LM with MLA
(multi-head latent attention; q_lora 768, kv_lora 256, nope 64 / rope 32 / v 64).
Decode uses the absorbed latent cache.  Full attention -> long_500k skipped."""
import jax.numpy as jnp

from repro.models.transformer import AttentionConfig, LMConfig
from .lm_common import register_lm

FULL = LMConfig(
    name="minicpm3-4b",
    n_layers=62, d_model=2560, vocab_size=73_448, d_ff=6400,
    attn=AttentionConfig("mla", n_heads=40, n_kv=40, d_head=96,
                         q_lora=768, kv_lora=256, d_nope=64, d_rope=32, d_v=64),
    q_chunk=2048, dtype=jnp.bfloat16,
)

REDUCED = LMConfig(
    name="minicpm3-4b-smoke",
    n_layers=2, d_model=64, vocab_size=512, d_ff=128,
    attn=AttentionConfig("mla", n_heads=4, n_kv=4, d_head=24,
                         q_lora=32, kv_lora=16, d_nope=16, d_rope=8, d_v=16),
    dtype=jnp.float32, remat=False,
)

register_lm("minicpm3-4b", FULL, REDUCED, long_ok=False,
            notes="MLA latent cache: decode caches rank-256 ckv + rope key only")
