"""deepseek-moe-16b [arXiv:2401.06066]: 28L d2048 16H(kv16) fine-grained MoE --
64 routed experts (d_ff 1408) top-6 + 2 shared experts.  GQA full attention ->
long_500k skipped."""
import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import AttentionConfig, LMConfig
from .lm_common import register_lm

FULL = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28, d_model=2048, vocab_size=102_400, d_ff=1408,
    attn=AttentionConfig("gqa", n_heads=16, n_kv=16, d_head=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared=2, d_ff_shared=2816, capacity_factor=1.25),
    q_chunk=2048, dtype=jnp.bfloat16,
)

REDUCED = LMConfig(
    name="deepseek-moe-16b-smoke",
    n_layers=2, d_model=64, vocab_size=512, d_ff=128,
    attn=AttentionConfig("gqa", n_heads=4, n_kv=4, d_head=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=2,
                  d_ff_shared=64, capacity_factor=2.0),
    dtype=jnp.float32, remat=False,
)

register_lm("deepseek-moe-16b", FULL, REDUCED, long_ok=False,
            notes="EP dispatch shares the n-gram shuffle substrate (DESIGN.md SS4)")
