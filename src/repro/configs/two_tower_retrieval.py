"""two-tower-retrieval [YouTube, RecSys'19]: embed 256, towers 1024-512-256,
dot-product scoring, in-batch sampled softmax; retrieval_cand is the real serving
shape (1 query x 1M candidates, batched dot)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import recsys as R
from .base import ArchDef, ShapeDef, register, shard_if
from .recsys_common import SHAPES, dp_spec, make_recsys_cell

FULL = R.TwoTowerConfig(item_vocab=10_000_000, embed_dim=256, user_feat=256,
                        tower_dims=(1024, 512, 256))
REDUCED = R.TwoTowerConfig(item_vocab=500, embed_dim=16, user_feat=16,
                           tower_dims=(32, 16))


def _tower_flops(cfg, n, d_in):
    dims = (d_in,) + cfg.tower_dims
    return n * sum(2 * a * b for a, b in zip(dims, dims[1:]))


def build_cell(cfg_factory, shape: ShapeDef, mesh):
    cfg = FULL
    params_sh = jax.eval_shape(lambda: R.twotower_init(jax.random.PRNGKey(0), cfg))
    pspec = jax.tree.map(lambda _: P(), params_sh)
    pspec["item_embed"] = P(shard_if(mesh, cfg.item_vocab, "model"), None)
    dp = dp_spec(mesh)
    if shape.name == "retrieval_cand":
        n = shape.dims["n_candidates"]
        batch_sds = {"user": jax.ShapeDtypeStruct((1, cfg.user_feat), jnp.float32),
                     "candidates": jax.ShapeDtypeStruct((n,), jnp.int32)}
        bspec = {"user": P(None, None), "candidates": P(dp)}
        fl = _tower_flops(cfg, n, cfg.embed_dim) + 2 * n * cfg.tower_dims[-1]
        return make_recsys_cell(
            name="two-tower-retrieval", shape=shape, mesh=mesh, params_sh=params_sh,
            pspec=pspec, loss=None,
            forward=lambda p, bt: R.twotower_score_candidates(p, bt, cfg),
            batch_sds=batch_sds, batch_spec=bspec, model_flops=float(fl))
    b = shape.dims["batch"]
    batch_sds = {"user": jax.ShapeDtypeStruct((b, cfg.user_feat), jnp.float32),
                 "pos_item": jax.ShapeDtypeStruct((b,), jnp.int32)}
    bspec = {"user": P(dp, None), "pos_item": P(dp)}
    fl = (_tower_flops(cfg, b, cfg.user_feat) + _tower_flops(cfg, b, cfg.embed_dim)
          + 2 * b * b * cfg.tower_dims[-1])
    if shape.kind == "train":
        return make_recsys_cell(
            name="two-tower-retrieval", shape=shape, mesh=mesh, params_sh=params_sh,
            pspec=pspec, loss=lambda p, bt: R.twotower_loss(p, bt, cfg),
            forward=None, batch_sds=batch_sds, batch_spec=bspec,
            model_flops=float(fl))
    return make_recsys_cell(
        name="two-tower-retrieval", shape=shape, mesh=mesh, params_sh=params_sh,
        pspec=pspec, loss=None,
        forward=lambda p, bt: R.twotower_embed(p, bt, cfg),
        batch_sds=batch_sds, batch_spec=bspec, model_flops=float(fl))


register(ArchDef(
    name="two-tower-retrieval", family="recsys",
    make=lambda: FULL, make_reduced=lambda: REDUCED,
    shapes=SHAPES, build_cell=build_cell,
    notes="negative-sampling frequencies come from the degenerate sigma=1 "
          "SUFFIX-sigma job (distributed item counting)",
))
