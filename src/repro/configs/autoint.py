"""autoint [arXiv:1810.11921]: 39 sparse fields, embed 16, 3 self-attention
interaction layers (2 heads, d_attn 32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import recsys as R
from .base import ArchDef, ShapeDef, register, shard_if
from .recsys_common import SHAPES, dp_spec, make_recsys_cell

FULL = R.AutoIntConfig(n_sparse=39, field_vocab=1_000_000, embed_dim=16,
                       n_attn_layers=3, n_heads=2, d_attn=32)
REDUCED = R.AutoIntConfig(n_sparse=5, field_vocab=200, embed_dim=8,
                          n_attn_layers=2, d_attn=8)


def _flops(cfg: R.AutoIntConfig, batch: int) -> float:
    f = cfg.n_sparse + 1
    per_layer = 3 * 2 * f * cfg.embed_dim * cfg.d_attn + 2 * f * f * cfg.d_attn * 2
    return float(batch * (cfg.n_attn_layers * per_layer + 2 * f * cfg.d_attn))


def build_cell(cfg_factory, shape: ShapeDef, mesh):
    cfg = FULL
    params_sh = jax.eval_shape(lambda: R.autoint_init(jax.random.PRNGKey(0), cfg))
    pspec = jax.tree.map(lambda _: P(), params_sh)
    pspec["tables"] = P(None, shard_if(mesh, cfg.field_vocab, "model"), None)
    b = shape.dims.get("n_candidates", shape.dims["batch"])
    dp = dp_spec(mesh)
    batch_sds = {"sparse_ids": jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32),
                 "dense": jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32),
                 "labels": jax.ShapeDtypeStruct((b,), jnp.float32)}
    bspec = {"sparse_ids": P(dp, None), "dense": P(dp, None), "labels": P(dp)}
    return make_recsys_cell(
        name="autoint", shape=shape, mesh=mesh, params_sh=params_sh, pspec=pspec,
        loss=lambda p, bt: R.autoint_loss(p, bt, cfg),
        forward=lambda p, bt: R.autoint_forward(p, bt, cfg),
        batch_sds=batch_sds, batch_spec=bspec, model_flops=_flops(cfg, b),
        notes="retrieval_cand = offline scoring sweep of 1M rows" if
              shape.name == "retrieval_cand" else "")


register(ArchDef(
    name="autoint", family="recsys",
    make=lambda: FULL, make_reduced=lambda: REDUCED,
    shapes=SHAPES, build_cell=build_cell,
))
