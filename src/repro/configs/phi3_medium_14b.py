"""phi3-medium-14b [arXiv:2404.14219]: 40L d5120 40H (GQA kv=10) d_ff17920,
RoPE + SwiGLU.  Full attention -> long_500k skipped."""
import jax.numpy as jnp

from repro.models.transformer import AttentionConfig, LMConfig
from .lm_common import register_lm

FULL = LMConfig(
    name="phi3-medium-14b",
    n_layers=40, d_model=5120, vocab_size=100_352, d_ff=17920,
    attn=AttentionConfig("gqa", n_heads=40, n_kv=10, d_head=128),
    q_chunk=2048, dtype=jnp.bfloat16,
)

REDUCED = LMConfig(
    name="phi3-medium-14b-smoke",
    n_layers=2, d_model=64, vocab_size=512, d_ff=192,
    attn=AttentionConfig("gqa", n_heads=4, n_kv=2, d_head=16),
    dtype=jnp.float32, remat=False,
)

register_lm("phi3-medium-14b", FULL, REDUCED, long_ok=False)
