"""mixtral-8x7b [arXiv:2401.04088]: 32L d4096 32H(kv8) d_ff14336, 8 experts top-2,
sliding-window attention (4096) -> the one LM arch that RUNS long_500k (window-
bounded cache = sub-quadratic)."""
import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import AttentionConfig, LMConfig
from .lm_common import register_lm

FULL = LMConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, vocab_size=32_000, d_ff=14336,
    attn=AttentionConfig("gqa", n_heads=32, n_kv=8, d_head=128, window=4096),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336, capacity_factor=1.25),
    q_chunk=2048, dtype=jnp.bfloat16,
)

REDUCED = LMConfig(
    name="mixtral-8x7b-smoke",
    n_layers=2, d_model=64, vocab_size=512, d_ff=128,
    attn=AttentionConfig("gqa", n_heads=4, n_kv=2, d_head=16, window=8),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=2.0),
    dtype=jnp.float32, remat=False,
)

register_lm("mixtral-8x7b", FULL, REDUCED, long_ok=True,
            notes="SWA window 4096 bounds the long_500k decode cache")
