"""Importing this package registers every assigned architecture (plus the paper's
own n-gram workload) into the arch registry (configs.base)."""
from . import base
from . import (autoint, bst, deepseek_moe_16b, gin_tu, llama3_2_1b,  # noqa: F401
               minicpm3_4b, mixtral_8x7b, paper, phi3_medium_14b,
               two_tower_retrieval, xdeepfm)
from .base import all_archs, all_cells, get

ASSIGNED = [
    "deepseek-moe-16b", "mixtral-8x7b", "minicpm3-4b", "phi3-medium-14b",
    "llama3.2-1b", "gin-tu", "bst", "autoint", "two-tower-retrieval", "xdeepfm",
]

__all__ = ["base", "get", "all_archs", "all_cells", "ASSIGNED"]
