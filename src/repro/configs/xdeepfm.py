"""xdeepfm [arXiv:1803.05170]: CIN 200-200-200 + DNN 400-400 over 39 sparse fields,
embed 10."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import recsys as R
from .base import ArchDef, ShapeDef, register, shard_if
from .recsys_common import SHAPES, dp_spec, make_recsys_cell

FULL = R.XDeepFMConfig(n_sparse=39, field_vocab=1_000_000, embed_dim=10,
                       cin_layers=(200, 200, 200), mlp_dims=(400, 400))
REDUCED = R.XDeepFMConfig(n_sparse=5, field_vocab=200, embed_dim=8,
                          cin_layers=(8, 8), mlp_dims=(16,))


def _flops(cfg: R.XDeepFMConfig, batch: int) -> float:
    f, d = cfg.n_sparse, cfg.embed_dim
    cin = 0
    h_prev = f
    for h in cfg.cin_layers:
        cin += h_prev * f * d + 2 * h * h_prev * f * d   # outer product + compress
        h_prev = h
    dims = (f * d + cfg.n_dense,) + cfg.mlp_dims + (1,)
    deep = sum(2 * a * b for a, b in zip(dims, dims[1:]))
    return float(batch * (cin + deep))


def build_cell(cfg_factory, shape: ShapeDef, mesh):
    cfg = FULL
    params_sh = jax.eval_shape(lambda: R.xdeepfm_init(jax.random.PRNGKey(0), cfg))
    pspec = jax.tree.map(lambda _: P(), params_sh)
    pspec["tables"] = P(None, shard_if(mesh, cfg.field_vocab, "model"), None)
    pspec["linear"] = P(None, shard_if(mesh, cfg.field_vocab, "model"))
    b = shape.dims.get("n_candidates", shape.dims["batch"])
    dp = dp_spec(mesh)
    batch_sds = {"sparse_ids": jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32),
                 "dense": jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32),
                 "labels": jax.ShapeDtypeStruct((b,), jnp.float32)}
    bspec = {"sparse_ids": P(dp, None), "dense": P(dp, None), "labels": P(dp)}
    return make_recsys_cell(
        name="xdeepfm", shape=shape, mesh=mesh, params_sh=params_sh, pspec=pspec,
        loss=lambda p, bt: R.xdeepfm_loss(p, bt, cfg),
        forward=lambda p, bt: R.xdeepfm_forward(p, bt, cfg),
        batch_sds=batch_sds, batch_spec=bspec, model_flops=_flops(cfg, b))


register(ArchDef(
    name="xdeepfm", family="recsys",
    make=lambda: FULL, make_reduced=lambda: REDUCED,
    shapes=SHAPES, build_cell=build_cell,
))
