"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B]: 16L d2048 32H (GQA kv=8) d_ff8192,
vocab 128256.  Full attention -> long_500k skipped."""
import jax.numpy as jnp

from repro.models.transformer import AttentionConfig, LMConfig
from .lm_common import register_lm

FULL = LMConfig(
    name="llama3.2-1b",
    n_layers=16, d_model=2048, vocab_size=128_256, d_ff=8192,
    attn=AttentionConfig("gqa", n_heads=32, n_kv=8, d_head=64, rope_theta=500_000.0),
    q_chunk=2048, dtype=jnp.bfloat16,
)

REDUCED = LMConfig(
    name="llama3.2-1b-smoke",
    n_layers=2, d_model=64, vocab_size=512, d_ff=128,
    attn=AttentionConfig("gqa", n_heads=4, n_kv=2, d_head=16),
    dtype=jnp.float32, remat=False,
)

register_lm("llama3.2-1b", FULL, REDUCED, long_ok=False)
