"""n-gram statistics job launcher -- the paper's CLI.

    PYTHONPATH=src python -m repro.launch.ngram --method suffix_sigma \
        --sigma 5 --tau 10 --tokens 500000 --profile nyt

Runs the selected method on a synthetic corpus with the paper's measurement
counters (wallclock / records / bytes), optionally with maximality/closedness
post-filtering and time-series aggregation.  ``--wave-tokens`` streams the
job out of core through the wave engine; ``--devices N`` runs it distributed
on an N-way host mesh -- combined, every wave's stage pipeline shards over
the mesh (the distributed-waves path).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="suffix_sigma",
                    choices=["suffix_sigma", "naive", "apriori_scan",
                             "apriori_index"])
    ap.add_argument("--sigma", type=int, default=5)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--tokens", type=int, default=200_000)
    ap.add_argument("--profile", default="nyt", choices=["nyt", "cw"])
    ap.add_argument("--split-docs", action="store_true")
    ap.add_argument("--filter", default=None, choices=[None, "max", "closed"])
    ap.add_argument("--series", action="store_true",
                    help="aggregate per-year n-gram time series (SSVI-B)")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--wave-tokens", type=int, default=None,
                    help="out-of-core: run the job in fixed-size token waves "
                         "(repro.pipeline.WaveExecutor); output is "
                         "bit-identical to the monolithic run")
    ap.add_argument("--accumulator", default="defer",
                    choices=["defer", "tiered", "pairwise"],
                    help="wave-partial fold policy: defer = stack wave "
                         "segments and fold once, k-way, at the end (O(total) "
                         "merge rows, the default); tiered = size-tiered LSM "
                         "rungs (bounded live memory, amortized O(total log "
                         "waves)); pairwise = the one-segment baseline")
    ap.add_argument("--merge-route", default="kway",
                    choices=["kway", "merge", "sort", "device"],
                    help="segment-fold sort route: kway = galloping host "
                         "merge (default); merge = balanced-tree pairwise "
                         "merge-path; device = merge-path tree on device "
                         "with host-kway fallback for oversized tau=1 gram "
                         "sets; sort = fused re-sort")
    ap.add_argument("--no-overlap", action="store_true",
                    help="serialize the per-wave fold with wave dispatch "
                         "instead of overlapping it on the fold thread "
                         "(debugging / single-thread environments)")
    ap.add_argument("--devices", type=int, default=0,
                    help=">1: run distributed on an N-way host mesh (sets "
                         "XLA_FLAGS; with --wave-tokens, shards every wave)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="export a Chrome/Perfetto trace_event JSON of the run")
    ap.add_argument("--metrics", default=None, metavar="FILE",
                    help="append a metrics snapshot (JSONL) and print the "
                         "summary table")
    args = ap.parse_args()
    if args.devices > 1:
        from repro.launch.mesh import pin_host_device_count
        pin_host_device_count(args.devices)   # before the first backend init

    from repro.core import NGramConfig, extensions_filter, run_job
    from repro.data import corpus as corpus_mod
    from repro.obs import metrics as obs_metrics
    from repro.obs import report as obs_report

    finish_obs = obs_report.setup(args.trace, args.metrics)

    mesh = None
    if args.devices > 1:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.devices)

    prof = corpus_mod.PROFILES[args.profile]
    if args.series:
        tokens, years = corpus_mod.zipf_corpus(args.tokens, prof, seed=0,
                                               duplicate_frac=0.02, with_years=True)
    else:
        tokens = corpus_mod.zipf_corpus(args.tokens, prof, seed=0,
                                        duplicate_frac=0.02)
        years = None
    if args.split_docs:
        tokens, removed = corpus_mod.split_at_infrequent(tokens, args.tau,
                                                         prof.vocab_size)
        print(f"document splitting removed {removed} infrequent term occurrences")

    cfg = NGramConfig(sigma=args.sigma, tau=args.tau, vocab_size=prof.vocab_size,
                      method=args.method, n_buckets=21 if args.series else 0)
    t0 = time.time()
    if args.wave_tokens is not None:
        from repro.pipeline import WaveExecutor
        if args.series:
            raise SystemExit("--wave-tokens does not support --series "
                             "(bucketed counts need a single-wave job)")
        stats = WaveExecutor(cfg, wave_tokens=args.wave_tokens,
                             accumulator=args.accumulator,
                             merge_route=args.merge_route,
                             overlap=not args.no_overlap,
                             mesh=mesh).run(tokens)
    else:
        kw = {"bucket_ids": years} if args.series else {}
        stats = run_job(tokens, cfg, mesh=mesh, **kw)
    dt = time.time() - t0
    if args.filter:
        stats = extensions_filter(stats, args.filter)
    obs_metrics.get_registry().merge_job_counters(stats.counters)
    print(f"method={args.method} sigma={args.sigma} tau={args.tau} "
          f"tokens={args.tokens}: {len(stats)} n-grams in {dt:.2f}s")
    print("counters:", {k: int(v) for k, v in stats.counters.items()})
    d = stats.to_dict()
    top = sorted(d.items(), key=lambda kv: -kv[1])[: args.top]
    for g, c in top:
        print(f"  cf={c:8d}  {g}")
    finish_obs({"driver": "ngram", "method": args.method,
                "tokens": args.tokens, "wall_s": dt})


if __name__ == "__main__":
    main()
