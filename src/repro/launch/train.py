"""End-to-end training driver with checkpointing, recovery, stragglers, elastic
restart -- runs real steps on whatever devices this host has.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 200 \
        --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--reduced`` uses the arch's smoke config (CPU-feasible); omit it on a real slice
to train the full config.  The loop is the production path: deterministic loader,
atomic checkpoints, retry-on-failure, straggler log.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.loader import LMBatchLoader, SyntheticLMLoader
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import StragglerDetector, run_with_recovery
from repro.training.optimizer import OptimizerConfig, init_state
from repro.training.train_loop import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--corpus-tokens", type=int, default=200_000)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    ad = configs.get(args.arch)
    if ad.family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for gnn/recsys")
    from repro.models import transformer as tf
    cfg = ad.make_reduced() if args.reduced else ad.make()

    # real data path: synthetic Zipf corpus -> encoded stream -> LM batches
    from repro.data import corpus as corpus_mod
    prof = corpus_mod.CorpusProfile("train", cfg.vocab_size - 1, 1.1, 24, 12)
    stream = corpus_mod.zipf_corpus(args.corpus_tokens, prof, seed=0)
    stream = np.where(stream == 0, 1, stream)  # separators become a real token here
    loader = LMBatchLoader(stream, args.seq, args.batch, seed=0)

    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                              decay_steps=args.steps)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    raw_step = jax.jit(make_train_step(lambda p, b: tf.loss_fn(p, b, cfg), opt_cfg),
                       donate_argnums=(0, 1))

    def step_fn(state, batch):
        p, o, m = raw_step(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in loader.batch_at(step).items()}

    ckpt = CheckpointManager(args.ckpt_dir)
    straggler = StragglerDetector()
    t0 = time.time()
    state, history, retries = run_with_recovery(
        n_steps=args.steps, step_fn=step_fn,
        state={"params": params, "opt": init_state(params)},
        batch_fn=batch_fn, ckpt=ckpt, ckpt_every=args.ckpt_every,
        straggler=straggler)
    dt = time.time() - t0
    losses = [float(h["loss"]) for h in history]
    for i in range(0, len(losses), args.log_every):
        print(f"  step {i:5d}  loss {losses[i]:.4f}")
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"{dt:.1f}s, {retries} restarts, {len(straggler.events)} stragglers")


if __name__ == "__main__":
    main()
