import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production meshes and record memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun_results

The XLA_FLAGS line above MUST precede every other import (jax locks the device
count at first init); nothing else in the repo sets it globally.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402  (registers all archs)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import roofline  # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True) -> dict:
    ad = configs.get(arch)
    sd = ad.shapes[shape]
    rec: dict = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    if sd.skip_reason:
        rec["status"] = "skipped"
        rec["reason"] = sd.skip_reason
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = ad.build_cell(ad.make(), sd, mesh)
    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        }
        correction = None
        if cell.scan_probe is not None:
            fn, args, in_sh, trips = cell.scan_probe
            body_c = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
            correction = (body_c, trips)
        rl = roofline.analyze(compiled, chips=mesh.size,
                              model_flops=cell.model_flops, correction=correction)
        rec["roofline"] = rl.to_dict()
        rec["status"] = "ok"
        rec["kind"] = cell.kind
        rec["notes"] = cell.notes
    if verbose:
        r = rec["roofline"]
        print(f"  [{rec['mesh']}] {arch}/{shape}: compile {rec['compile_s']}s  "
              f"bottleneck={r['bottleneck']}  "
              f"t={r['step_time_s']*1e3:.2f}ms  "
              f"roofline_frac={r['roofline_fraction']:.3f}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="dryrun_results")
    ap.add_argument("--include-ngram", action="store_true",
                    help="also dry-run the paper's own n-gram pipeline cells")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(exist_ok=True)
    if args.all:
        cells = [(a, s) for a in configs.ASSIGNED for s in configs.get(a).shapes]
        if args.include_ngram:
            cells += [("ngram-suffix-sigma", s)
                      for s in configs.get("ngram-suffix-sigma").shapes]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}".replace(
                "/", "_").replace(".", "_")
            fpath = outdir / f"{tag}.json"
            if fpath.exists():
                rec = json.loads(fpath.read_text())
                print(f"  [cached] {arch}/{shape} "
                      f"{'2x16x16' if multi else '16x16'}: {rec['status']}")
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_fail += rec["status"] == "failed"
                continue
            try:
                rec = run_cell(arch, shape, multi)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "status": "failed", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"  FAILED {arch}/{shape}: {e}")
            fpath.write_text(json.dumps(rec, indent=1))
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            n_fail += rec["status"] == "failed"
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
