"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never touch jax
device state (the dry-run pins the device count via XLA_FLAGS before any jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def pin_host_device_count(n: int) -> None:
    """Force the host platform to expose ``n`` devices (the launchers'
    ``--devices`` flag).  Rewrites XLA_FLAGS -- any pre-set device-count flag
    is dropped, the rest is kept -- and must run before the first jax backend
    initialization (importing this module is safe; creating an array is not).
    """
    import os
    import re
    prev = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                  os.environ.get("XLA_FLAGS", ""))
    flag = f"--xla_force_host_platform_device_count={n}"
    os.environ["XLA_FLAGS"] = f"{prev.strip()} {flag}".strip()


def make_data_mesh(n: int):
    """1-D ``n``-way data mesh -- the shape every ``--devices N`` driver uses."""
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def make_host_mesh(model: int = 1):
    """Whatever this host actually has (tests / examples): 1-D data mesh or a
    (data, model) grid when enough local devices exist."""
    n = len(jax.devices())
    if model > 1 and n % model == 0:
        return jax.make_mesh((n // model, model), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


# ------------------------------------------------------ hardware model (v5e-like)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # B/s per chip
ICI_BW = 50e9                   # B/s per link (intra-pod)
CHIPS_PER_POD = 256
HBM_PER_CHIP = 16 * 2 ** 30
