"""n-gram query serving driver: job -> frozen index -> micro-batched QPS report.

    PYTHONPATH=src python -m repro.launch.serve_ngrams --tokens 200000 \
        --sigma 5 --tau 4 --profile nyt --batch-sizes 1,64,4096

Runs one SUFFIX-sigma job, freezes the output into the device-resident index
(``repro.index``), then drives a synthetic query stream through the batched
lookup and top-k continuation paths with fixed-size micro-batches -- the shape a
production frontend hands the device: collect queries until the batch fills (or
a deadline passes), pad the tail, launch one jitted program.  Reports QPS and
per-batch latency percentiles per batch size; ``--devices N`` serves the same
stream through the sharded ``shard_map`` path on an N-way host mesh.

``--streaming`` switches to the generational driver: the corpus arrives in
document batches, each runs through the ordinary SUFFIX-sigma map/shuffle/sort
phases into a fresh L0 segment of a :class:`~repro.index.merge.GenerationalIndex`
(size-tiered merges instead of full rebuilds), and queries keep flowing between
swaps through an LRU result cache plus double-buffered dispatch (submit batch
i+1 before materializing batch i -- jax's async dispatch does the overlap, no
``block_until_ready`` on the hot path).

``--serve HOST:PORT`` turns the process into the production frontend
(``repro.serve``): the corpus is ingested once, then the HTTP/SSE service
answers point-lookup / top-k / streaming-completion requests through the
continuous batcher and admission layer until interrupted.

This module is a thin argument-parsing shell: the serving tier itself lives in
``repro.serve`` (service, cache, batcher, admission, HTTP transport).
"""
from __future__ import annotations

import argparse
import time

_REEXPORTS = {
    # The serving tier moved to repro.serve (PR 10); these lazy re-exports
    # (PEP 562) keep every existing `from repro.launch.serve_ngrams import X`
    # working without importing jax-touching modules at module scope -- main()
    # must be able to set the --devices XLA flag before backend init.  Same
    # pattern as the PR-5 DoubleBufferedDriver move.
    "LRUQueryCache": ("repro.serve.cache", "LRUQueryCache"),
    "StreamingNGramService": ("repro.serve.service", "StreamingNGramService"),
    "microbatch_drive": ("repro.serve.service", "microbatch_drive"),
    "make_query_stream": ("repro.serve.service", "make_query_stream"),
    "DoubleBufferedDriver": ("repro.pipeline.executor", "DoubleBufferedDriver"),
}


def __getattr__(name):
    try:
        mod_name, attr = _REEXPORTS[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib
    return getattr(importlib.import_module(mod_name), attr)


def _percentiles(lat_s: list[float]) -> str:
    import numpy as np
    a = np.asarray(lat_s) * 1e3
    return (f"p50={np.percentile(a, 50):.2f}ms p99={np.percentile(a, 99):.2f}ms "
            f"max={a.max():.2f}ms")


def _build_streaming_service(args, mesh=None):
    """Corpus + config + service, shared by --streaming and --serve."""
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.serve.service import StreamingNGramService

    prof = corpus_mod.PROFILES[args.profile]
    tokens = corpus_mod.zipf_corpus(args.tokens, prof, seed=0,
                                    duplicate_frac=0.02)
    cfg = NGramConfig(sigma=args.sigma, tau=args.tau,
                      vocab_size=prof.vocab_size)
    svc = StreamingNGramService(cfg, compress=args.compress,
                                block_size=args.block_size,
                                use_kernels=args.use_kernels,
                                cache_capacity=args.cache_capacity,
                                wave_tokens=args.wave_tokens, mesh=mesh,
                                overlap=not args.no_overlap)
    return prof, tokens, svc


def run_serve(args) -> None:
    """Frontend mode: ingest once, then answer HTTP/SSE until interrupted."""
    from repro.serve.admission import AdmissionController
    from repro.serve.frontend import QueryFrontend
    from repro.serve.http import serve_http

    host, _, port = args.serve.rpartition(":")
    if not host or not port.isdigit():
        raise SystemExit(f"--serve wants HOST:PORT, got {args.serve!r}")
    _, tokens, svc = _build_streaming_service(args)
    rep = svc.ingest(tokens)
    print(f"ingested {len(tokens)} tokens -> {rep['ingested_rows']} grams "
          f"(job {rep['job_s']:.2f}s, freeze {rep['ingest_s']:.2f}s)")
    admission = AdmissionController(
        queue_budget=args.queue_budget,
        quota_rate=args.quota_rate if args.quota_rate > 0 else None)
    with QueryFrontend(svc, admission=admission,
                       deadline_s=args.deadline_ms / 1e3) as fe:
        print(f"serving on http://{host}:{port}  "
              "(POST /v1/lookup /v1/topk /v1/complete; "
              "GET /v1/system/topology /healthz)")
        serve_http(fe, host, int(port), block=True)


def run_streaming(args) -> None:
    """Generational serving loop: base build, then ingest/query interleave.

    ``--devices N`` (with ``--wave-tokens``) runs every ingest wave's stage
    pipeline sharded over an N-way host mesh -- the distributed-waves path;
    queries stay on the generational single-device fold.
    """
    import numpy as np
    from repro.index.merge import segment_to_stats
    from repro.obs import metrics as obs_metrics
    from repro.serve.service import make_query_stream

    mesh = None
    if args.devices > 1:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.devices)
    prof, tokens, svc = _build_streaming_service(args, mesh=mesh)
    nb = max(args.ingest_batches, 1)
    base, rest = np.split(tokens, [int(len(tokens) * 0.6)])
    deltas = np.array_split(rest, nb)
    rep = svc.ingest(base)
    print(f"base: {len(base)} tokens -> {rep['ingested_rows']} grams "
          f"(job {rep['job_s']:.2f}s, freeze {rep['ingest_s']:.2f}s)")

    batch = args.stream_batch
    for step, delta in enumerate(deltas):
        t0 = time.perf_counter()
        rep = svc.ingest(delta)
        t_ing = time.perf_counter() - t0
        stats = segment_to_stats(svc.gen.segments[0].to_segment())
        # fresh query stream per step (seed=step), split in two cold halves:
        # one drives the pipelined path (throughput), one the per-batch sync
        # path (latency percentiles) -- neither re-times rows the warm pass
        # just cached
        grams, lengths = make_query_stream(
            stats, n_queries=args.queries // nb, sigma=args.sigma,
            vocab_size=prof.vocab_size, miss_frac=args.miss_frac,
            seed=step)
        half = grams.shape[0] // 2
        pipe_b = [(grams[i:i + batch], lengths[i:i + batch])
                  for i in range(0, half, batch)]
        sync_b = [(grams[i:i + batch], lengths[i:i + batch])
                  for i in range(half, grams.shape[0], batch)]
        svc.lookup(*pipe_b[0])                 # compile warm only
        t0 = time.perf_counter()
        svc.lookup_pipelined(pipe_b)
        t_pipe = time.perf_counter() - t0
        lat = []
        lat_hist = obs_metrics.get_registry().histogram("serve.lookup_seconds")
        for g, ln in sync_b:
            t1 = time.perf_counter()
            svc.lookup(g, ln)
            dt = time.perf_counter() - t1
            lat.append(dt)
            lat_hist.observe(dt)
        svc.cache.publish_metrics()
        n_pipe = sum(b[0].shape[0] for b in pipe_b)
        print(f"ingest[{step}]: {len(delta):>7} tokens in {t_ing:.2f}s "
              f"({len(delta) / t_ing:,.0f} tok/s; waves={rep['waves']} "
              f"merges={rep['merges']} segments={rep['segments']}) | pipelined "
              f"{n_pipe / t_pipe:>8,.0f} qps | sync {_percentiles(lat)} "
              f"cache_hit={svc.cache.hit_rate:.0%}")
    svc.cache.publish_metrics()
    print(f"final: {svc.gen!r}, {svc.gen.nbytes / 2**20:.1f} MiB, "
          f"cache {len(svc.cache)} entries hit_rate={svc.cache.hit_rate:.0%}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=200_000)
    ap.add_argument("--sigma", type=int, default=5)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--profile", default="nyt", choices=["nyt", "cw"])
    ap.add_argument("--queries", type=int, default=20_000)
    ap.add_argument("--miss-frac", type=float, default=0.3)
    ap.add_argument("--batch-sizes", default="1,64,4096")
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0,
                    help=">1: serve through the sharded shard_map path on an "
                         "N-way host mesh (sets XLA_FLAGS; must run first)")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="serve the front-coded + Elias-Fano layout "
                         "(repro.index.compress) instead of the flat lanes")
    ap.add_argument("--block-size", type=int, default=4,
                    help="front-coding block size of the compressed layout "
                         "(larger = smaller at rest, more rows decoded per "
                         "query probe)")
    ap.add_argument("--streaming", action="store_true",
                    help="generational driver: ingest the corpus in document "
                         "batches (LSM merges, no rebuilds) with cached, "
                         "double-buffered query serving between swaps")
    ap.add_argument("--serve", default=None, metavar="HOST:PORT",
                    help="frontend mode: ingest the corpus once, then run the "
                         "HTTP/SSE service (repro.serve) with continuous "
                         "batching and admission control until interrupted")
    ap.add_argument("--deadline-ms", type=float, default=2.0,
                    help="--serve: continuous-batcher flush deadline for a "
                         "partially filled padding bucket")
    ap.add_argument("--queue-budget", type=int, default=512,
                    help="--serve: admission soft queue budget (beyond it "
                         "only interactive-priority requests are admitted; "
                         "4x is the hard shed limit)")
    ap.add_argument("--quota-rate", type=float, default=0.0,
                    help="--serve: per-tenant token-bucket refill in "
                         "requests/s (0 disables tenant quotas)")
    ap.add_argument("--ingest-batches", type=int, default=4)
    ap.add_argument("--wave-tokens", type=int, default=None,
                    help="stream each ingest through the out-of-core wave "
                         "engine (repro.pipeline) in waves of this many "
                         "tokens; bounds device memory by O(waves * sigma) "
                         "independent of corpus size")
    ap.add_argument("--no-overlap", action="store_true",
                    help="serialize each ingest's per-wave fold with wave "
                         "dispatch instead of overlapping it on the wave "
                         "engine's fold thread")
    ap.add_argument("--stream-batch", type=int, default=256,
                    help="query micro-batch size of the streaming loop")
    ap.add_argument("--cache-capacity", type=int, default=65536)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="export a Chrome/Perfetto trace_event JSON of the run")
    ap.add_argument("--metrics", default=None, metavar="FILE",
                    help="append a metrics snapshot (JSONL) and print the "
                         "summary table")
    args = ap.parse_args()
    if args.devices > 1:
        # --devices always wins; must run before the first jax backend init,
        # so it precedes both serving modes
        from repro.launch.mesh import pin_host_device_count
        pin_host_device_count(args.devices)
    from repro.obs import report as obs_report
    finish_obs = obs_report.setup(args.trace, args.metrics)
    if args.serve:
        try:
            run_serve(args)
        finally:
            finish_obs({"driver": "serve_ngrams", "mode": "serve"})
        return
    if args.streaming:
        run_streaming(args)
        finish_obs({"driver": "serve_ngrams", "mode": "streaming"})
        return

    import numpy as np
    from repro import index as index_mod
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.serve.service import make_query_stream, microbatch_drive

    prof = corpus_mod.PROFILES[args.profile]
    tokens = corpus_mod.zipf_corpus(args.tokens, prof, seed=0, duplicate_frac=0.02)
    cfg = NGramConfig(sigma=args.sigma, tau=args.tau, vocab_size=prof.vocab_size)

    t0 = time.time()
    stats = run_job(tokens, cfg)
    t_job = time.time() - t0
    from repro.obs import metrics as obs_metrics
    obs_metrics.get_registry().merge_job_counters(stats.counters)
    t0 = time.time()
    if args.devices > 1:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.devices)
        sharded = index_mod.build_sharded_index(stats, vocab_size=prof.vocab_size,
                                                mesh=mesh,
                                                compress=args.compress,
                                                block_size=args.block_size)
        idx_bytes = sharded.index.nbytes
    elif args.compress:
        idx = index_mod.build_compressed_index(stats,
                                               vocab_size=prof.vocab_size,
                                               block_size=args.block_size)
        idx_bytes = idx.nbytes
    else:
        idx = index_mod.build_index(stats, vocab_size=prof.vocab_size)
        idx_bytes = idx.nbytes
    t_build = time.time() - t0
    layout = "compressed" if args.compress else "flat"
    print(f"job: {args.tokens} tokens -> {len(stats)} frequent grams "
          f"in {t_job:.2f}s; {layout} index frozen in {t_build:.2f}s "
          f"({idx_bytes / 2**20:.1f} MiB, "
          f"{idx_bytes / max(len(stats), 1):.1f} B/gram)")

    grams, lengths = make_query_stream(stats, n_queries=args.queries,
                                       sigma=args.sigma,
                                       vocab_size=prof.vocab_size,
                                       miss_frac=args.miss_frac)

    if args.devices > 1:
        def answer_lookup(g, ln):
            return index_mod.serve_queries(sharded, g, ln,
                                           use_kernels=args.use_kernels)

        def answer_topk(g, ln):
            return index_mod.serve_queries(sharded, g, np.maximum(ln - 1, 1),
                                           mode="continuations", k=args.topk,
                                           use_kernels=args.use_kernels)
    else:
        def answer_lookup(g, ln):
            return np.asarray(index_mod.lookup(
                idx, g, ln, use_kernels=args.use_kernels))

        def answer_topk(g, ln):
            # continuations() masks the gram past the prefix length itself
            return np.asarray(index_mod.continuations(
                idx, g, np.maximum(ln - 1, 0), k=args.topk,
                use_kernels=args.use_kernels)[3])

    for mode, answer in (("lookup", answer_lookup), ("topk", answer_topk)):
        for batch in (int(b) for b in args.batch_sizes.split(",")):
            qps, lat = microbatch_drive(answer, grams, lengths, batch,
                                        hist_name=f"drive.{mode}_seconds")
            print(f"serve_{mode} batch={batch:>5} qps={qps:>10.0f} "
                  f"{_percentiles(lat)}")
    finish_obs({"driver": "serve_ngrams", "mode": "microbatch"})


if __name__ == "__main__":
    main()
