"""n-gram query serving driver: job -> frozen index -> micro-batched QPS report.

    PYTHONPATH=src python -m repro.launch.serve_ngrams --tokens 200000 \
        --sigma 5 --tau 4 --profile nyt --batch-sizes 1,64,4096

Runs one SUFFIX-sigma job, freezes the output into the device-resident index
(``repro.index``), then drives a synthetic query stream through the batched
lookup and top-k continuation paths with fixed-size micro-batches -- the shape a
production frontend hands the device: collect queries until the batch fills (or
a deadline passes), pad the tail, launch one jitted program.  Reports QPS and
per-batch latency percentiles per batch size; ``--devices N`` serves the same
stream through the sharded ``shard_map`` path on an N-way host mesh.

``--streaming`` switches to the generational driver: the corpus arrives in
document batches, each runs through the ordinary SUFFIX-sigma map/shuffle/sort
phases into a fresh L0 segment of a :class:`~repro.index.merge.GenerationalIndex`
(size-tiered merges instead of full rebuilds), and queries keep flowing between
swaps through an LRU result cache plus double-buffered dispatch (submit batch
i+1 before materializing batch i -- jax's async dispatch does the overlap, no
``block_until_ready`` on the hot path).
"""
from __future__ import annotations

import argparse
import time
from collections import OrderedDict


def _percentiles(lat_s: list[float]) -> str:
    import numpy as np
    a = np.asarray(lat_s) * 1e3
    return (f"p50={np.percentile(a, 50):.2f}ms p99={np.percentile(a, 99):.2f}ms "
            f"max={a.max():.2f}ms")


def make_query_stream(stats, *, n_queries: int, sigma: int, vocab_size: int,
                      miss_frac: float, seed: int = 0):
    """(grams [N, sigma], lengths [N]): sampled index rows + uniform-random misses.

    Hits are drawn cf-weighted (hot grams are queried more -- the serving-load
    analogue of the corpus Zipf skew the shuffle partitioner absorbs)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    grams = np.zeros((n_queries, sigma), np.int32)
    lengths = np.zeros((n_queries,), np.int32)
    n_rows = len(stats)
    is_miss = rng.random(n_queries) < miss_frac
    if n_rows:
        p = np.asarray(stats.counts, np.float64)
        p = p / p.sum()
        rows = rng.choice(n_rows, size=n_queries, p=p)
        grams = np.asarray(stats.grams)[rows].astype(np.int32)
        lengths = np.asarray(stats.lengths)[rows].astype(np.int32)
    miss_len = rng.integers(1, sigma + 1, n_queries).astype(np.int32)
    miss_g = rng.integers(1, vocab_size + 1, (n_queries, sigma)).astype(np.int32)
    miss_g *= np.arange(sigma)[None, :] < miss_len[:, None]
    grams = np.where(is_miss[:, None], miss_g, grams)
    lengths = np.where(is_miss, miss_len, lengths)
    return grams, lengths


class LRUQueryCache:
    """Host-side LRU of hot query results, keyed by (kind, gram bytes).

    Entries are tagged with the index ``generation`` they were computed
    against; a lookup under a newer generation drops the whole cache (segment
    swaps change answers wholesale, and a stale count is worse than a miss).
    Accesses tagged with an *older* generation -- an in-flight double-buffered
    batch collected after an ingest bumped the index -- are discarded, never
    installed: they must not roll the cache back to serving stale counts.
    """

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.generation = -1
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._d: OrderedDict = OrderedDict()

    def _sync(self, generation: int) -> bool:
        """Advance to ``generation`` if newer; False iff the caller is stale."""
        if generation > self.generation:
            self._d.clear()
            self.generation = generation
        return generation == self.generation

    def get(self, key, generation: int):
        if not self._sync(generation):
            self.misses += 1               # stale reader: always a miss
            return None
        v = self._d.get(key)
        if v is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def put(self, key, generation: int, value) -> None:
        if not self._sync(generation):
            return                         # stale result: drop, don't install
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._d)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def snapshot(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._d),
                "generation": self.generation, "hit_rate": self.hit_rate}

    def publish_metrics(self, reg=None) -> None:
        """Mirror lifetime cache stats into the active metrics registry."""
        if reg is None:
            from repro.obs import metrics as obs_metrics
            reg = obs_metrics.get_registry()
        if not reg:
            return
        for k in ("hits", "misses", "evictions"):
            c = reg.counter("cache." + k)
            c.add(getattr(self, k) - c.value)     # lifetime mirror, not +=
        reg.gauge("cache.entries").set(len(self._d))
        reg.gauge("cache.hit_rate").set(self.hit_rate)


def __getattr__(name):
    # The submit/collect overlap driver now lives with the wave engine (its
    # other consumer: double-buffered wave ingest).  The re-export for
    # existing users is lazy (PEP 562): importing repro.pipeline at module
    # scope would pull in jnp constants and initialize the jax backend before
    # main() can set the --devices XLA flag.
    if name == "DoubleBufferedDriver":
        from repro.pipeline.executor import DoubleBufferedDriver
        return DoubleBufferedDriver
    raise AttributeError(name)


class StreamingNGramService:
    """Generational index + query cache behind a batch lookup/completion API.

    ``ingest`` streams new document tokens through the ordinary SUFFIX-sigma
    job phases into a fresh L0 segment (``GenerationalIndex.ingest`` handles
    the size-tiered merges); queries between swaps hit the LRU cache first and
    only the residual miss rows go to the device, padded to a power-of-two
    sub-batch so the compiled-program cache stays small.
    """

    def __init__(self, cfg, *, compress: bool = False, block_size: int = 4,
                 use_kernels: bool = False, cache_capacity: int = 65536,
                 size_ratio: int = 4, route: str = "kway",
                 wave_tokens: int | None = None, mesh=None,
                 axis_name: str = "data", overlap: bool = True):
        from repro.index import GenerationalIndex
        self.cfg = cfg
        self.use_kernels = use_kernels
        self.wave_tokens = wave_tokens
        self.mesh = mesh
        self.axis_name = axis_name
        self.overlap = overlap
        self.gen = GenerationalIndex(
            sigma=cfg.sigma, vocab_size=cfg.vocab_size, compress=compress,
            block_size=block_size, size_ratio=size_ratio, route=route,
            use_kernels=use_kernels)
        self.cache = LRUQueryCache(cache_capacity)
        self._wave_ex = None

    def ingest(self, tokens) -> dict:
        """Run the job phases over a token delta and swap the new L0 in.

        With ``wave_tokens`` set, the delta streams through the wave engine
        (``repro.pipeline.WaveExecutor``) instead of one monolithic job: the
        device only ever holds one wave of job state, so a delta (or an
        initial corpus) larger than device memory ingests end to end.  A
        ``mesh`` shards the work over its devices -- each wave's stage
        pipeline when waves are on, the ordinary distributed job otherwise.
        The resulting stats are bit-identical every way.
        """
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        with obs_trace.span("svc.ingest") as sp:
            t0 = time.perf_counter()
            if self.wave_tokens is not None:
                if self._wave_ex is None:  # reuse: compiled programs carry over
                    from repro.pipeline import WaveExecutor
                    self._wave_ex = WaveExecutor(self.cfg,
                                                 wave_tokens=self.wave_tokens,
                                                 mesh=self.mesh,
                                                 axis_name=self.axis_name,
                                                 overlap=self.overlap)
                stats = self._wave_ex.run(tokens)
            else:
                from repro.core import run_job
                stats = run_job(tokens, self.cfg, mesh=self.mesh,
                                axis_name=self.axis_name)
            t_job = time.perf_counter() - t0
            obs_metrics.get_registry().merge_job_counters(stats.counters)
            t0 = time.perf_counter()
            report = self.gen.ingest(stats)
            report.update(job_s=t_job, ingest_s=time.perf_counter() - t0,
                          segments=self.gen.n_segments,
                          waves=stats.counters.get("waves", 1))
            if sp:
                sp.set(tokens=len(tokens), rows=report.get("ingested_rows"),
                       waves=report["waves"])
        return report

    def _submit_lookup(self, grams, lengths) -> dict:
        """Cache consult + async device dispatch of the miss rows.

        The returned record holds the *unmaterialized* device result; pairing
        ``_submit_lookup`` of batch i+1 with ``_collect_lookup`` of batch i is
        the double-buffered hot path (cache fill rides the collect side, one
        batch behind the device)."""
        import numpy as np
        g = np.asarray(grams, np.int32)
        ln = np.asarray(lengths, np.int32)
        gen_id = self.gen.generation
        out = np.zeros((g.shape[0],), np.uint32)
        miss = []
        keys = []
        for i in range(g.shape[0]):
            key = (int(ln[i]), g[i, :max(int(ln[i]), 0)].tobytes())
            v = self.cache.get(key, gen_id)
            if v is None:
                miss.append(i)
                keys.append(key)
            else:
                out[i] = v
        dev, pad = None, 0
        if miss:
            from repro.index.query import lookup_deferred
            m = len(miss)
            pad = max(1 << (m - 1).bit_length(), 16)
            mg = np.zeros((pad, g.shape[1]), np.int32)
            mln = np.zeros((pad,), np.int32)
            mg[:m] = g[miss]
            mln[:m] = ln[miss]
            # per-segment deferred dispatches: nothing is materialized here,
            # even with several live generations
            dev = lookup_deferred(self.gen, mg, mln,
                                  use_kernels=self.use_kernels)
        return {"out": out, "miss": miss, "keys": keys, "dev": dev,
                "pad": pad, "gen": gen_id}

    def _collect_lookup(self, rec: dict):
        if rec["dev"] is not None:
            from repro.index.query import collect_lookup
            cf = collect_lookup(rec["dev"], rec["pad"])[:len(rec["miss"])]
            rec["out"][rec["miss"]] = cf
            for key, v in zip(rec["keys"], cf):
                self.cache.put(key, rec["gen"], int(v))
        return rec["out"]

    def lookup(self, grams, lengths):
        """Point counts [B] uint32; cache hits never touch the device."""
        return self._collect_lookup(self._submit_lookup(grams, lengths))

    def lookup_pipelined(self, batches) -> list:
        """Drive (grams, lengths) batches double-buffered: batch i+1 is
        dispatched before batch i's device result is materialized, so host
        batching/cache work overlaps device execution with no
        ``block_until_ready`` anywhere."""
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        from repro.pipeline.executor import DoubleBufferedDriver
        drv = DoubleBufferedDriver(self._submit_lookup,
                                   collect=self._collect_lookup)
        reg = obs_metrics.get_registry()
        inflight = reg.gauge("serve.inflight")
        results: list = []
        with obs_trace.span("serve.pipelined") as sp:
            for g, ln in batches:
                inflight.add(1)               # one submitted, maybe one live
                res, _ = drv.submit(g, ln)
                if res is not None:
                    inflight.add(-1)
                    results.append(res)
            res, _ = drv.drain()
            inflight.set(0)
            if res is not None:
                results.append(res)
            if sp:
                sp.set(batches=len(batches))
        return results

    def continuations(self, prefixes, p_len, *, k: int = 8):
        """Top-k completion rows [B, 2+2k] uint32 (nd | total | terms | cfs)."""
        import numpy as np
        from repro.index import continuations as idx_cont
        pg = np.asarray(prefixes, np.int32)
        pl = np.asarray(p_len, np.int32)
        gen_id = self.gen.generation
        out = np.zeros((pg.shape[0], 2 + 2 * k), np.uint32)
        miss = []
        for i in range(pg.shape[0]):
            key = ("c", k, int(pl[i]), pg[i, :max(int(pl[i]), 0)].tobytes())
            v = self.cache.get(key, gen_id)
            if v is None:
                miss.append(i)
            else:
                out[i] = v
        if miss:
            m = len(miss)
            pad = max(1 << (m - 1).bit_length(), 16)
            mg = np.zeros((pad, pg.shape[1]), np.int32)
            mln = np.zeros((pad,), np.int32)
            mg[:m] = pg[miss]
            mln[:m] = pl[miss]
            nd, tot, terms, cfs = [np.asarray(x) for x in idx_cont(
                self.gen, mg, mln, k=k, use_kernels=self.use_kernels)]
            rows = np.concatenate([nd[:m, None], tot[:m, None], terms[:m],
                                   cfs[:m]], axis=1).astype(np.uint32)
            out[miss] = rows
            for j, i in enumerate(miss):
                key = ("c", k, int(pl[i]), pg[i, :max(int(pl[i]), 0)].tobytes())
                self.cache.put(key, gen_id, rows[j])
        return out


def microbatch_drive(answer, grams, lengths, batch: int, *, warmup: int = 2,
                     hist_name: str = "drive.batch_seconds"):
    """Feed the stream through ``answer`` in fixed micro-batches; (qps, lat[s]).

    Timed batches also land in the ``hist_name`` registry histogram, so the
    p50/p95/p99 the production frontend needs come out of the metrics export
    as well as the returned sample list.
    """
    import numpy as np
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    n = grams.shape[0]
    n_batches = -(-n // batch)
    pad = n_batches * batch - n
    g = np.pad(grams, ((0, pad), (0, 0)))
    ln = np.pad(lengths, (0, pad))
    for i in range(min(warmup, n_batches)):      # compile + cache warm
        answer(g[i * batch:(i + 1) * batch], ln[i * batch:(i + 1) * batch])
    hist = obs_metrics.get_registry().histogram(hist_name)
    lat = []
    with obs_trace.span("serve.drive") as sp:
        t_all = time.perf_counter()
        for i in range(n_batches):
            t0 = time.perf_counter()
            answer(g[i * batch:(i + 1) * batch], ln[i * batch:(i + 1) * batch])
            dt = time.perf_counter() - t0
            lat.append(dt)
            hist.observe(dt)
        qps = n / (time.perf_counter() - t_all)
        if sp:
            sp.set(batch=batch, n_batches=n_batches, qps=int(qps))
    return qps, lat


def run_streaming(args) -> None:
    """Generational serving loop: base build, then ingest/query interleave.

    ``--devices N`` (with ``--wave-tokens``) runs every ingest wave's stage
    pipeline sharded over an N-way host mesh -- the distributed-waves path;
    queries stay on the generational single-device fold.
    """
    import numpy as np
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.index.merge import segment_to_stats
    from repro.obs import metrics as obs_metrics

    mesh = None
    if args.devices > 1:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.devices)
    prof = corpus_mod.PROFILES[args.profile]
    tokens = corpus_mod.zipf_corpus(args.tokens, prof, seed=0,
                                    duplicate_frac=0.02)
    cfg = NGramConfig(sigma=args.sigma, tau=args.tau,
                      vocab_size=prof.vocab_size)
    svc = StreamingNGramService(cfg, compress=args.compress,
                                block_size=args.block_size,
                                use_kernels=args.use_kernels,
                                cache_capacity=args.cache_capacity,
                                wave_tokens=args.wave_tokens, mesh=mesh,
                                overlap=not args.no_overlap)
    nb = max(args.ingest_batches, 1)
    base, rest = np.split(tokens, [int(len(tokens) * 0.6)])
    deltas = np.array_split(rest, nb)
    rep = svc.ingest(base)
    print(f"base: {len(base)} tokens -> {rep['ingested_rows']} grams "
          f"(job {rep['job_s']:.2f}s, freeze {rep['ingest_s']:.2f}s)")

    batch = args.stream_batch
    for step, delta in enumerate(deltas):
        t0 = time.perf_counter()
        rep = svc.ingest(delta)
        t_ing = time.perf_counter() - t0
        stats = segment_to_stats(svc.gen.segments[0].to_segment())
        # fresh query stream per step (seed=step), split in two cold halves:
        # one drives the pipelined path (throughput), one the per-batch sync
        # path (latency percentiles) -- neither re-times rows the warm pass
        # just cached
        grams, lengths = make_query_stream(
            stats, n_queries=args.queries // nb, sigma=args.sigma,
            vocab_size=prof.vocab_size, miss_frac=args.miss_frac,
            seed=step)
        half = grams.shape[0] // 2
        pipe_b = [(grams[i:i + batch], lengths[i:i + batch])
                  for i in range(0, half, batch)]
        sync_b = [(grams[i:i + batch], lengths[i:i + batch])
                  for i in range(half, grams.shape[0], batch)]
        svc.lookup(*pipe_b[0])                 # compile warm only
        t0 = time.perf_counter()
        svc.lookup_pipelined(pipe_b)
        t_pipe = time.perf_counter() - t0
        lat = []
        lat_hist = obs_metrics.get_registry().histogram("serve.lookup_seconds")
        for g, ln in sync_b:
            t1 = time.perf_counter()
            svc.lookup(g, ln)
            dt = time.perf_counter() - t1
            lat.append(dt)
            lat_hist.observe(dt)
        svc.cache.publish_metrics()
        n_pipe = sum(b[0].shape[0] for b in pipe_b)
        print(f"ingest[{step}]: {len(delta):>7} tokens in {t_ing:.2f}s "
              f"({len(delta) / t_ing:,.0f} tok/s; waves={rep['waves']} "
              f"merges={rep['merges']} segments={rep['segments']}) | pipelined "
              f"{n_pipe / t_pipe:>8,.0f} qps | sync {_percentiles(lat)} "
              f"cache_hit={svc.cache.hit_rate:.0%}")
    svc.cache.publish_metrics()
    print(f"final: {svc.gen!r}, {svc.gen.nbytes / 2**20:.1f} MiB, "
          f"cache {len(svc.cache)} entries hit_rate={svc.cache.hit_rate:.0%}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=200_000)
    ap.add_argument("--sigma", type=int, default=5)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--profile", default="nyt", choices=["nyt", "cw"])
    ap.add_argument("--queries", type=int, default=20_000)
    ap.add_argument("--miss-frac", type=float, default=0.3)
    ap.add_argument("--batch-sizes", default="1,64,4096")
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0,
                    help=">1: serve through the sharded shard_map path on an "
                         "N-way host mesh (sets XLA_FLAGS; must run first)")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="serve the front-coded + Elias-Fano layout "
                         "(repro.index.compress) instead of the flat lanes")
    ap.add_argument("--block-size", type=int, default=4,
                    help="front-coding block size of the compressed layout "
                         "(larger = smaller at rest, more rows decoded per "
                         "query probe)")
    ap.add_argument("--streaming", action="store_true",
                    help="generational driver: ingest the corpus in document "
                         "batches (LSM merges, no rebuilds) with cached, "
                         "double-buffered query serving between swaps")
    ap.add_argument("--ingest-batches", type=int, default=4)
    ap.add_argument("--wave-tokens", type=int, default=None,
                    help="stream each ingest through the out-of-core wave "
                         "engine (repro.pipeline) in waves of this many "
                         "tokens; bounds device memory by O(waves * sigma) "
                         "independent of corpus size")
    ap.add_argument("--no-overlap", action="store_true",
                    help="serialize each ingest's per-wave fold with wave "
                         "dispatch instead of overlapping it on the wave "
                         "engine's fold thread")
    ap.add_argument("--stream-batch", type=int, default=256,
                    help="query micro-batch size of the streaming loop")
    ap.add_argument("--cache-capacity", type=int, default=65536)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="export a Chrome/Perfetto trace_event JSON of the run")
    ap.add_argument("--metrics", default=None, metavar="FILE",
                    help="append a metrics snapshot (JSONL) and print the "
                         "summary table")
    args = ap.parse_args()
    if args.devices > 1:
        # --devices always wins; must run before the first jax backend init,
        # so it precedes both serving modes
        from repro.launch.mesh import pin_host_device_count
        pin_host_device_count(args.devices)
    from repro.obs import report as obs_report
    finish_obs = obs_report.setup(args.trace, args.metrics)
    if args.streaming:
        run_streaming(args)
        finish_obs({"driver": "serve_ngrams", "mode": "streaming"})
        return

    import numpy as np
    from repro import index as index_mod
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod

    prof = corpus_mod.PROFILES[args.profile]
    tokens = corpus_mod.zipf_corpus(args.tokens, prof, seed=0, duplicate_frac=0.02)
    cfg = NGramConfig(sigma=args.sigma, tau=args.tau, vocab_size=prof.vocab_size)

    t0 = time.time()
    stats = run_job(tokens, cfg)
    t_job = time.time() - t0
    from repro.obs import metrics as obs_metrics
    obs_metrics.get_registry().merge_job_counters(stats.counters)
    t0 = time.time()
    if args.devices > 1:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.devices)
        sharded = index_mod.build_sharded_index(stats, vocab_size=prof.vocab_size,
                                                mesh=mesh,
                                                compress=args.compress,
                                                block_size=args.block_size)
        idx_bytes = sharded.index.nbytes
    elif args.compress:
        idx = index_mod.build_compressed_index(stats,
                                               vocab_size=prof.vocab_size,
                                               block_size=args.block_size)
        idx_bytes = idx.nbytes
    else:
        idx = index_mod.build_index(stats, vocab_size=prof.vocab_size)
        idx_bytes = idx.nbytes
    t_build = time.time() - t0
    layout = "compressed" if args.compress else "flat"
    print(f"job: {args.tokens} tokens -> {len(stats)} frequent grams "
          f"in {t_job:.2f}s; {layout} index frozen in {t_build:.2f}s "
          f"({idx_bytes / 2**20:.1f} MiB, "
          f"{idx_bytes / max(len(stats), 1):.1f} B/gram)")

    grams, lengths = make_query_stream(stats, n_queries=args.queries,
                                       sigma=args.sigma,
                                       vocab_size=prof.vocab_size,
                                       miss_frac=args.miss_frac)

    if args.devices > 1:
        def answer_lookup(g, ln):
            return index_mod.serve_queries(sharded, g, ln,
                                           use_kernels=args.use_kernels)

        def answer_topk(g, ln):
            return index_mod.serve_queries(sharded, g, np.maximum(ln - 1, 1),
                                           mode="continuations", k=args.topk,
                                           use_kernels=args.use_kernels)
    else:
        def answer_lookup(g, ln):
            return np.asarray(index_mod.lookup(
                idx, g, ln, use_kernels=args.use_kernels))

        def answer_topk(g, ln):
            # continuations() masks the gram past the prefix length itself
            return np.asarray(index_mod.continuations(
                idx, g, np.maximum(ln - 1, 0), k=args.topk,
                use_kernels=args.use_kernels)[3])

    for mode, answer in (("lookup", answer_lookup), ("topk", answer_topk)):
        for batch in (int(b) for b in args.batch_sizes.split(",")):
            qps, lat = microbatch_drive(answer, grams, lengths, batch,
                                        hist_name=f"drive.{mode}_seconds")
            print(f"serve_{mode} batch={batch:>5} qps={qps:>10.0f} "
                  f"{_percentiles(lat)}")
    finish_obs({"driver": "serve_ngrams", "mode": "microbatch"})


if __name__ == "__main__":
    main()
