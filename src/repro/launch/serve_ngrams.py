"""n-gram query serving driver: job -> frozen index -> micro-batched QPS report.

    PYTHONPATH=src python -m repro.launch.serve_ngrams --tokens 200000 \
        --sigma 5 --tau 4 --profile nyt --batch-sizes 1,64,4096

Runs one SUFFIX-sigma job, freezes the output into the device-resident index
(``repro.index``), then drives a synthetic query stream through the batched
lookup and top-k continuation paths with fixed-size micro-batches -- the shape a
production frontend hands the device: collect queries until the batch fills (or
a deadline passes), pad the tail, launch one jitted program.  Reports QPS and
per-batch latency percentiles per batch size; ``--devices N`` serves the same
stream through the sharded ``shard_map`` path on an N-way host mesh.
"""
from __future__ import annotations

import argparse
import os
import time


def _percentiles(lat_s: list[float]) -> str:
    import numpy as np
    a = np.asarray(lat_s) * 1e3
    return (f"p50={np.percentile(a, 50):.2f}ms p99={np.percentile(a, 99):.2f}ms "
            f"max={a.max():.2f}ms")


def make_query_stream(stats, *, n_queries: int, sigma: int, vocab_size: int,
                      miss_frac: float, seed: int = 0):
    """(grams [N, sigma], lengths [N]): sampled index rows + uniform-random misses.

    Hits are drawn cf-weighted (hot grams are queried more -- the serving-load
    analogue of the corpus Zipf skew the shuffle partitioner absorbs)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    grams = np.zeros((n_queries, sigma), np.int32)
    lengths = np.zeros((n_queries,), np.int32)
    n_rows = len(stats)
    is_miss = rng.random(n_queries) < miss_frac
    if n_rows:
        p = np.asarray(stats.counts, np.float64)
        p = p / p.sum()
        rows = rng.choice(n_rows, size=n_queries, p=p)
        grams = np.asarray(stats.grams)[rows].astype(np.int32)
        lengths = np.asarray(stats.lengths)[rows].astype(np.int32)
    miss_len = rng.integers(1, sigma + 1, n_queries).astype(np.int32)
    miss_g = rng.integers(1, vocab_size + 1, (n_queries, sigma)).astype(np.int32)
    miss_g *= np.arange(sigma)[None, :] < miss_len[:, None]
    grams = np.where(is_miss[:, None], miss_g, grams)
    lengths = np.where(is_miss, miss_len, lengths)
    return grams, lengths


def microbatch_drive(answer, grams, lengths, batch: int, *, warmup: int = 2):
    """Feed the stream through ``answer`` in fixed micro-batches; (qps, lat[s])."""
    import numpy as np
    n = grams.shape[0]
    n_batches = -(-n // batch)
    pad = n_batches * batch - n
    g = np.pad(grams, ((0, pad), (0, 0)))
    ln = np.pad(lengths, (0, pad))
    for i in range(min(warmup, n_batches)):      # compile + cache warm
        answer(g[i * batch:(i + 1) * batch], ln[i * batch:(i + 1) * batch])
    lat = []
    t_all = time.perf_counter()
    for i in range(n_batches):
        t0 = time.perf_counter()
        answer(g[i * batch:(i + 1) * batch], ln[i * batch:(i + 1) * batch])
        lat.append(time.perf_counter() - t0)
    qps = n / (time.perf_counter() - t_all)
    return qps, lat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=200_000)
    ap.add_argument("--sigma", type=int, default=5)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--profile", default="nyt", choices=["nyt", "cw"])
    ap.add_argument("--queries", type=int, default=20_000)
    ap.add_argument("--miss-frac", type=float, default=0.3)
    ap.add_argument("--batch-sizes", default="1,64,4096")
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0,
                    help=">1: serve through the sharded shard_map path on an "
                         "N-way host mesh (sets XLA_FLAGS; must run first)")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="serve the front-coded + Elias-Fano layout "
                         "(repro.index.compress) instead of the flat lanes")
    args = ap.parse_args()
    if args.devices > 1:
        # --devices always wins: drop any pre-set device-count flag, keep the
        # rest of XLA_FLAGS, and append ours
        import re
        prev = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                      os.environ.get("XLA_FLAGS", ""))
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        os.environ["XLA_FLAGS"] = f"{prev.strip()} {flag}".strip()

    import jax
    import numpy as np
    from repro import index as index_mod
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod

    prof = corpus_mod.PROFILES[args.profile]
    tokens = corpus_mod.zipf_corpus(args.tokens, prof, seed=0, duplicate_frac=0.02)
    cfg = NGramConfig(sigma=args.sigma, tau=args.tau, vocab_size=prof.vocab_size)

    t0 = time.time()
    stats = run_job(tokens, cfg)
    t_job = time.time() - t0
    t0 = time.time()
    if args.devices > 1:
        mesh = jax.make_mesh((args.devices,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sharded = index_mod.build_sharded_index(stats, vocab_size=prof.vocab_size,
                                                mesh=mesh,
                                                compress=args.compress)
        idx_bytes = sharded.index.nbytes
    elif args.compress:
        idx = index_mod.build_compressed_index(stats,
                                               vocab_size=prof.vocab_size)
        idx_bytes = idx.nbytes
    else:
        idx = index_mod.build_index(stats, vocab_size=prof.vocab_size)
        idx_bytes = idx.nbytes
    t_build = time.time() - t0
    layout = "compressed" if args.compress else "flat"
    print(f"job: {args.tokens} tokens -> {len(stats)} frequent grams "
          f"in {t_job:.2f}s; {layout} index frozen in {t_build:.2f}s "
          f"({idx_bytes / 2**20:.1f} MiB, "
          f"{idx_bytes / max(len(stats), 1):.1f} B/gram)")

    grams, lengths = make_query_stream(stats, n_queries=args.queries,
                                       sigma=args.sigma,
                                       vocab_size=prof.vocab_size,
                                       miss_frac=args.miss_frac)

    if args.devices > 1:
        def answer_lookup(g, ln):
            return index_mod.serve_queries(sharded, g, ln,
                                           use_kernels=args.use_kernels)

        def answer_topk(g, ln):
            return index_mod.serve_queries(sharded, g, np.maximum(ln - 1, 1),
                                           mode="continuations", k=args.topk,
                                           use_kernels=args.use_kernels)
    else:
        def answer_lookup(g, ln):
            return np.asarray(index_mod.lookup(
                idx, g, ln, use_kernels=args.use_kernels))

        def answer_topk(g, ln):
            # continuations() masks the gram past the prefix length itself
            return np.asarray(index_mod.continuations(
                idx, g, np.maximum(ln - 1, 0), k=args.topk,
                use_kernels=args.use_kernels)[3])

    for mode, answer in (("lookup", answer_lookup), ("topk", answer_topk)):
        for batch in (int(b) for b in args.batch_sizes.split(",")):
            qps, lat = microbatch_drive(answer, grams, lengths, batch)
            print(f"serve_{mode} batch={batch:>5} qps={qps:>10.0f} "
                  f"{_percentiles(lat)}")


if __name__ == "__main__":
    main()
