"""Roofline-term extraction from compiled dry-run artifacts.

  compute_s    = HLO_FLOPs / (chips * 197 TF/s)
  memory_s     = HLO_bytes / (chips * 819 GB/s)
  collective_s = collective operand bytes / (chips * 50 GB/s)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (per-device numbers on the
SPMD-partitioned module).  Collective bytes are NOT in cost_analysis: we parse the
compiled HLO text and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.

Scan correction (verified empirically, DESIGN.md SS5): XLA counts a while/scan body
ONCE; scanned-layer models therefore add (L-1) x the separately-compiled body cost.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.  %x = bf16[16,128,1024]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?:[a-z0-9_]+)\[[^\]]*\][^\s]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(pred|[subf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum of result-shape bytes per collective kind (result size == data moved per
    device for gather/all-to-all; for reduce ops it equals operand size)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = None
        for kind in _COLLECTIVES:
            # match op name at the assignment: "... = TYPE[SHAPE] kind("
            if f" {kind}(" in stripped or f"{kind}-start(" in stripped:
                m = kind
                break
        if m is None:
            continue
        lhs = stripped.split("=")[0:1]
        # parse the first shape on the line (the result shape)
        sm = _SHAPE_RE.search(stripped)
        if not sm:
            continue
        out[m] += _shape_bytes(sm.group(1), sm.group(2))
        out["count"] += 1
    return out


@dataclass
class Roofline:
    flops: float
    bytes_hbm: float
    bytes_collective: float
    chips: int
    model_flops: float = 0.0
    collective_detail: dict = field(default_factory=dict)

    # NOTE: cost_analysis numbers are PER-DEVICE on the SPMD-partitioned module
    # (verified: per-chip flops x chips ~ 6ND for dense LMs).  The spec's
    # "HLO_FLOPs / (chips * peak)" with global HLO_FLOPs is identical to
    # per-chip / peak, which is what we compute.
    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.bytes_collective / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound is the sum; perfectly-overlapped lower bound is
        the max.  We report the max (roofline convention)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / global HLO_FLOPs: catches remat / redundancy waste."""
        if self.flops <= 0:
            return 0.0
        return self.model_flops / max(self.flops * self.chips, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-achievable fraction of peak at the modeled step time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS_BF16)

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops, "bytes_per_chip": self.bytes_hbm,
            "collective_bytes_per_chip": self.bytes_collective,
            "chips": self.chips, "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "collective_detail": self.collective_detail,
        }


def analyze(compiled, chips: int, model_flops: float = 0.0,
            correction: tuple | None = None) -> Roofline:
    """correction: (body_compiled, extra_trips) -- adds extra_trips x the scan-body
    cost (cost_analysis counts loop bodies once)."""
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    cbytes = float(sum(v for k, v in coll.items() if k != "count"))
    if correction is not None:
        body, trips = correction
        bcost = body.cost_analysis()
        flops += trips * float(bcost.get("flops", 0.0))
        bts += trips * float(bcost.get("bytes accessed", 0.0))
        bcoll = collective_bytes(body.as_text())
        cbytes += trips * float(sum(v for k, v in bcoll.items() if k != "count"))
        coll = {k: coll.get(k, 0) + trips * bcoll.get(k, 0) for k in coll}
    return Roofline(flops=flops, bytes_hbm=bts, bytes_collective=cbytes,
                    chips=chips, model_flops=model_flops, collective_detail=coll)
