"""Serving driver: prefill a batch of prompts, then batched greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 4 --prompt-len 32 --decode-steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=32)
    args = ap.parse_args()

    ad = configs.get(args.arch)
    if ad.family != "lm":
        raise SystemExit("serve.py drives LM archs")
    from repro.models import transformer as tf
    cfg = ad.make_reduced() if args.reduced else ad.make()
    max_seq = args.prompt_len + args.decode_steps

    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 1, cfg.vocab_size)

    prefill = jax.jit(lambda p, t: tf.prefill(p, t, cfg, max_seq=max_seq))
    decode = jax.jit(lambda p, c, tk, pos: tf.decode_step(p, c, tk, pos, cfg))

    t0 = time.time()
    cache, logits = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out_tokens = [jnp.argmax(logits, -1)]
    t0 = time.time()
    for i in range(args.decode_steps - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, out_tokens[-1], pos)
        out_tokens.append(jnp.argmax(logits, -1))
    jax.block_until_ready(out_tokens[-1])
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], 1)
    tok_s = args.batch * (args.decode_steps - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms; "
          f"decode {args.decode_steps-1} steps @ {tok_s:.1f} tok/s")
    print("sample generation ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
