"""Typed job plans: an n-gram method as data over the shared stages.

A :class:`JobPlan` is the declarative form of one of the paper's algorithms:
how the map phase emits records from a token window, whether a map-side
combiner runs, what the shuffle partitions by, how many sort lanes the sort
phase keys on, and which reducer interprets the sorted runs.  Multi-job
methods (APRIORI-SCAN/-INDEX run one MapReduce job per gram length) express
the chaining as ``rounds`` plus a ``carry`` -- the state one job hands the
next (the frequent-gram dictionary, the posting-list occurrence mask).

The executor (``repro.pipeline.executor``) interprets a plan either over the
whole corpus at once (exactly the old monolithic single-device jobs) or over
fixed-size token *waves* for corpora that don't fit on the device.

Carry semantics under waves: when ``tau_eff == 1`` (the wave regime -- a gram
below tau in every wave can still be frequent globally, so per-wave partials
must keep everything) the carries must be computed from the *emit-side*
evidence over the whole extended window including the halo, never from the
counted (live-position-only) output: a frequent-gram dictionary or occurrence
mask that is blind to the halo would prune real occurrences at wave
boundaries.  ``update_carry`` receives both and picks per ``tau_eff``.

Traceability contract (async + distributed waves): under ``tau_eff == 1``,
``update_carry`` must be a pure jnp-traceable function of
``(cfg, k, tok_ext, emit_extras, carry)`` only -- ``stats_k`` may be ``None``
and ``reduce_extras`` ``{}``.  The wave executor calls it inside the round's
in-flight dispatch (no host-synced stats exist yet) and, under a mesh,
inside the ``shard_map``-traced round program, where each shard computes its
carry from its *own* extended window.  Shard-locality holds because a live
position's candidate test only ever consults window positions within
``sigma - 1`` tokens of the shard's slice -- exactly the ppermute halo the
sharded window carries.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

from repro.core.stats import NGramConfig

# map emit: (tok_ext, aux_ext, n_live, cfg, carry, k) ->
#   (records [N, W] uint32, valid [N] bool, emit_extras dict)
# Only positions < n_live may carry weight (halo positions are the next
# wave's); emit_extras carries halo-aware masks for the wave-mode carries.
EmitFn = Callable[..., tuple]

# carry update: (cfg, tau_eff, k, tok_ext, stats_k, reduce_extras,
#                emit_extras, carry) -> new carry
CarryFn = Callable[..., Any]


@dataclass(frozen=True)
class MapStage:
    emit: EmitFn
    n_meta: int = 0          # meta lanes after the weight lane (positions, ...)


@dataclass(frozen=True)
class CombineStage:
    route: str = "sort"      # "sort" | "hash" (kernels/hash_combine.py)


@dataclass(frozen=True)
class ShuffleStage:
    key: str = "gram"        # "gram" (whole-record hash) | "lead" (first term)


@dataclass(frozen=True)
class SortStage:
    pass                     # keys = the packed gram lanes (n_lanes of the plan)


@dataclass(frozen=True)
class ReduceStage:
    kind: str = "exact"      # "exact" (whole-gram) | "suffix" (every prefix)
    with_positions: bool = False


@dataclass(frozen=True)
class JobPlan:
    name: str
    map: MapStage
    shuffle: ShuffleStage
    sort: SortStage
    reduce: ReduceStage
    combine: CombineStage | None = None
    rounds: int = 1                       # jobs chained (sigma for APRIORI-*)
    stop_on_empty: bool = False           # terminate when a round emits nothing
    update_carry: CarryFn | None = None   # None: stateless rounds
    lane_vocab: int = 0                   # packer vocab (0: cfg.vocab_size)

    def effective_lane_vocab(self, cfg: NGramConfig) -> int:
        return self.lane_vocab or cfg.vocab_size


def plan_for(cfg: NGramConfig) -> JobPlan:
    """The registered :class:`JobPlan` of ``cfg.method``."""
    from repro.core import PLANS
    try:
        build = PLANS[cfg.method]
    except KeyError:
        raise ValueError(
            f"no JobPlan registered for method {cfg.method!r}; "
            f"options: {sorted(PLANS)}")
    return build(cfg)
