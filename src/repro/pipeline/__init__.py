"""Unified wave-based job engine.

``plan`` types a method as map/combine/shuffle/sort/reduce stage
descriptions; ``stages`` holds the one shared implementation of each stage;
``executor`` interprets a plan -- whole-corpus (the single-device jobs of
``repro.core`` delegate here) or over fixed-size token waves that stream
out-of-core corpora through the device and into the generational index.
"""
from . import plan, stages
from .executor import (DoubleBufferedDriver, WaveExecutor, reset_stage_cache,
                       run_plan)
from .plan import (CombineStage, JobPlan, MapStage, ReduceStage, ShuffleStage,
                   SortStage, plan_for)
from .stages import canonical_stats

__all__ = ["plan", "stages", "WaveExecutor", "run_plan", "JobPlan",
           "MapStage", "CombineStage", "ShuffleStage", "SortStage",
           "ReduceStage", "plan_for", "canonical_stats",
           "DoubleBufferedDriver", "reset_stage_cache"]
