"""Shared stage implementations of the map/combine/shuffle/sort/reduce pipeline.

Before this module each method in ``repro.core`` carried its own copy of the
post-map plumbing: SUFFIX-sigma had a sort-based combiner and an LCP reducer,
the whole-gram methods (NAIVE, APRIORI-*) had their own fused sort+count, and
each hashed partition keys its own way.  The method-specific part of an
algorithm is its *map emit* (and how rounds chain); everything after the emit
is the same MapReduce machinery, so it lives here once:

  combine -- map-side pre-aggregation (the Hadoop combiner).  Two routes:
             ``"sort"`` (sort + run-merge, exact within the buffer) and
             ``"hash"`` (the sort-free hash-slot pass of
             ``kernels/hash_combine.py`` -- Lemire & Kaser's one-pass hashing;
             best-effort per block, exact in total weight).
  shuffle -- partition-key computation (``mapreduce.shuffle.record_key``).
  sort    -- multi-key lexicographic sort of the packed lanes.
  reduce  -- ``reduce_suffix`` (LCP runs: every prefix of every suffix --
             Algorithm 4) or ``reduce_exact`` (whole-gram runs with optional
             position payloads -- Algorithms 1-3).

All functions take and return static-shape arrays, so a jitted composition
(one wave of :class:`~repro.pipeline.executor.WaveExecutor`, or a whole
single-device job) compiles once per record shape.

Reserved-id-0 convention: the validity masks here (``valid = terms != 0`` in
the reducers, weight-lane zeroing in the combiners) all read token id 0 as
"no token" -- the PAD / document-separator convention
:class:`~repro.core.stats.NGramConfig` documents and
``NGramConfig.validate_tokens`` range-checks.  Wave tail masking does NOT
lean on it: the executor passes each wave's true live count, so the
zero-padded tail past a partial final wave is excluded by position, and the
zero checks only ever encode real document boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import segment, shuffle, sort


# ------------------------------------------------------------------- combine
def combine_sort(records: jax.Array, n_lanes: int, has_bucket: bool) -> jax.Array:
    """Sort-based map-side combiner: merge records with identical keys.

    Keys = packed lanes (+ bucket lane if present, so series buckets stay
    separate).  Non-first rows of each run get weight 0 (dropped by the
    shuffle's validity mask); shapes stay static.
    """
    n_keys = n_lanes + (1 if has_bucket else 0)
    if has_bucket:  # move bucket next to lanes for sorting, weight last
        rec = jnp.concatenate(
            [records[:, :n_lanes], records[:, n_lanes + 1:],
             records[:, n_lanes:n_lanes + 1]], axis=1)
    else:
        rec = records
    rec = sort.sort_records(rec, n_keys=n_keys)
    keys = rec[:, :n_keys]
    first = jnp.any(keys != jnp.roll(keys, 1, axis=0), axis=1).at[0].set(True)
    seg = jnp.maximum(jnp.cumsum(first.astype(jnp.int32)) - 1, 0)
    wsum = jax.ops.segment_sum(rec[:, -1], seg, num_segments=rec.shape[0])
    new_w = jnp.where(first, wsum[seg], 0)
    rec = rec.at[:, -1].set(new_w)
    if has_bucket:  # restore layout lanes | weight | bucket
        rec = jnp.concatenate(
            [rec[:, :n_lanes], rec[:, -1:], rec[:, n_lanes:-1]], axis=1)
    return rec


def combine_hash(records: jax.Array, n_lanes: int, has_bucket: bool, *,
                 use_kernels: bool = False, block: int = 256) -> jax.Array:
    """Sort-free hash-slot combiner: collapse duplicate keys without a sort.

    Per block of ``block`` records, rows hash into slots; all rows whose key
    equals their slot winner's key donate their weight to the winner.  Rows
    that lose a slot to a different key keep their own weight (a Hadoop
    combiner is best-effort -- the reducer re-aggregates exactly), so the
    (key -> total weight) map is preserved and row order never changes.
    """
    n_keys = n_lanes + (1 if has_bucket else 0)
    if has_bucket:
        keys = jnp.concatenate(
            [records[:, :n_lanes], records[:, n_lanes + 1:n_lanes + 2]], axis=1)
    else:
        keys = records[:, :n_lanes]
    weights = records[:, n_lanes]
    if use_kernels:
        from repro.kernels import ops as kops
        new_w = kops.hash_combine(keys[:, :n_keys], weights, block=block)
    else:
        from repro.kernels import ref as kref
        new_w = kref.hash_combine_ref(keys[:, :n_keys], weights, block=block)
    return records.at[:, n_lanes].set(new_w)


def combine(records: jax.Array, n_lanes: int, has_bucket: bool, *,
            route: str = "sort", use_kernels: bool = False) -> jax.Array:
    if route == "sort":
        return combine_sort(records, n_lanes, has_bucket)
    if route == "hash":
        return combine_hash(records, n_lanes, has_bucket,
                            use_kernels=use_kernels)
    raise ValueError(f"unknown combine route {route!r}")


# ------------------------------------------------------------------- shuffle
def partition_keys(records: jax.Array, n_lanes: int, *, kind: str,
                   vocab_size: int) -> jax.Array:
    """Per-record shuffle key (uint32) from the packed gram lanes."""
    return shuffle.record_key(records[:, :n_lanes], kind=kind,
                              vocab_size=vocab_size)


# -------------------------------------------------------------- sort + reduce
def sort_stage(records: jax.Array, *, n_keys: int) -> jax.Array:
    """The MapReduce sort phase: lexicographic on the first ``n_keys`` lanes."""
    return sort.sort_records(records, n_keys=n_keys)


def reduce_suffix(rec: jax.Array, *, sigma: int, vocab_size: int,
                  n_buckets: int = 0, use_kernels: bool = False):
    """LCP-run reducer over a *sorted* record block (SUFFIX-sigma).

    rec: [N, W] sorted = lanes | weight | (bucket).  Returns
    (terms [N, sigma], flags [N, sigma], counts [N, sigma] or [N, sigma, B]).
    """
    n_l = packing.n_lanes(sigma, vocab_size)
    terms = packing.unpack_terms(rec[:, :n_l], vocab_size=vocab_size, sigma=sigma)
    weight = rec[:, n_l].astype(jnp.int32)
    if use_kernels:
        from repro.kernels import ops as kops
        lcp, flags = kops.lcp_boundary(terms)
    else:
        lcp = segment.lcp_lengths(terms)
        flags = segment.boundary_flags(terms, lcp)
    valid = terms != 0
    if n_buckets:
        bucket = rec[:, n_l + 1].astype(jnp.int32)
        wmat = jax.nn.one_hot(bucket, n_buckets, dtype=jnp.int32) * weight[:, None]
        counts = segment.run_counts_matrix(flags, valid, wmat,
                                           max_segments=rec.shape[0])
    else:
        counts = segment.run_counts(flags, valid, weight,
                                    max_segments=rec.shape[0])
    return terms, flags, counts


def reduce_exact(rec: jax.Array, *, sigma: int, vocab_size: int,
                 with_positions: bool = False):
    """Whole-gram reducer over a *sorted* record block (NAIVE / APRIORI-*).

    rec: [N, W] sorted = lanes | weight | (pos).  Returns (terms, flags,
    counts) shaped like :func:`reduce_suffix` so ``NGramStats.from_dense``
    applies; flags mark the first row of each run at the row's own gram
    length.  If ``with_positions``, also returns per-original-position run
    totals [N] (scattered back through the sort permutation) for the
    APRIORI-INDEX posting-list join.
    """
    n = rec.shape[0]
    n_l = packing.n_lanes(sigma, vocab_size)
    lanes = rec[:, :n_l]
    weight = rec[:, n_l].astype(jnp.int32)
    terms = packing.unpack_terms(lanes, vocab_size=vocab_size, sigma=sigma)

    first = jnp.any(lanes != jnp.roll(lanes, 1, axis=0), axis=1).at[0].set(True)
    seg = jnp.maximum(jnp.cumsum(first.astype(jnp.int32)) - 1, 0)
    totals = jax.ops.segment_sum(weight, seg, num_segments=n)[seg]

    length = jnp.sum(terms != 0, axis=1)                       # gram length per row
    valid_row = (length > 0) & (weight >= 0)
    pos_in_row = jnp.maximum(length - 1, 0)
    row_flags = first & valid_row & (totals > 0)
    flags = (jax.nn.one_hot(pos_in_row, sigma, dtype=jnp.int32)
             * row_flags[:, None].astype(jnp.int32)).astype(bool)
    counts = flags * totals[:, None]

    if not with_positions:
        return terms, flags, counts
    orig_pos = rec[:, n_l + 1].astype(jnp.int32)
    totals_at_pos = jnp.zeros((n,), jnp.int32).at[orig_pos].set(totals, mode="drop")
    return terms, flags, counts, totals_at_pos


# ------------------------------------------------- device-side segment collect
def segment_candidates(flags: jax.Array, counts: jax.Array, lanes: jax.Array,
                       masks: jax.Array, *, sigma: int, reduce_kind: str):
    """Packed segment-candidate rows straight off a reducer's dense output.

    The traceable twin of the host collect in
    ``WaveExecutor._collect_wave_segment``: a kept row of length ``l`` has
    segment key ``(l | lanes & masks[l])`` (zeroing a term slot's bit field
    == packing PAD there -- see ``mapreduce.pack.prefix_lane_masks``), so the
    candidate table is a pure elementwise function of (flags, counts, sorted
    key lanes) and folds into the fused wave program -- the host never sees
    dense reducer output, only flat ``(length | prefix lanes, count)`` rows
    with dead rows zeroed (length 0, count 0).  Shapes are static:
    ``"suffix"`` reducers may keep several lengths per row (one candidate per
    (row, length) cell), ``"exact"`` reducers keep at most one (the row's own
    gram length), so the table is [N * sigma] or [N] rows respectively.

    Candidate *order* is deliberately unspecified: within one wave every kept
    gram key is unique across rounds (rounds emit disjoint lengths; a sorted
    reducer block flags each run once), so the collector's closing stable
    byte-view sort is a pure function of the row set.
    """
    n, n_l = lanes.shape
    keep = (flags != 0) & (counts >= 1)
    if reduce_kind == "suffix":
        # [N, sigma] grid: candidate (r, l) is the length-(l+1) prefix run
        pref = jnp.stack([lanes & masks[l] for l in range(1, sigma + 1)],
                         axis=1)                              # [N, sigma, n_l]
        lens = jnp.where(keep, jnp.arange(1, sigma + 1, dtype=jnp.uint32),
                         jnp.uint32(0))
        keys = jnp.concatenate(
            [lens[..., None],
             jnp.where(keep[..., None], pref, jnp.uint32(0))], axis=-1)
        cnts = jnp.where(keep, counts, 0).astype(jnp.uint32)
        return keys.reshape(n * sigma, 1 + n_l), cnts.reshape(n * sigma)
    # exact: at most one flagged length per row -- no sigma blowup
    len_idx = jnp.argmax(keep, axis=1)                        # 0 when dead
    keep_row = jnp.any(keep, axis=1)
    length = jnp.where(keep_row, (len_idx + 1).astype(jnp.uint32),
                       jnp.uint32(0))
    pref = jnp.where(keep_row[:, None], lanes & masks[length], jnp.uint32(0))
    cnt = jnp.where(keep_row, counts[jnp.arange(n), len_idx],
                    0).astype(jnp.uint32)
    return jnp.concatenate([length[:, None], pref], axis=1), cnt


# ----------------------------------------------------------- canonical output
def canonical_stats(stats):
    """Canonical row order + dedup of a job output: sort by (length, terms
    lexicographic) and sum counts of identical grams -- exactly the order an
    :class:`~repro.index.build.IndexSegment` stores (length | packed lanes
    ascending), so a wave run folded through the segment-merge path and a
    monolithic run land on bit-identical arrays.  Host-side int64, so no
    uint32 round trip; series ([R, B]) counts are carried whole.
    """
    from repro.core.stats import NGramStats
    grams = np.asarray(stats.grams, np.int32)
    lengths = np.asarray(stats.lengths, np.int32)
    counts = np.asarray(stats.counts)
    r, sigma = grams.shape
    if r == 0:
        return NGramStats(grams, lengths,
                          counts.astype(np.int64), dict(stats.counters))
    # np.lexsort: last key is primary -> (length, g[:,0], ..., g[:,sigma-1])
    order = np.lexsort(tuple(grams[:, i] for i in range(sigma - 1, -1, -1))
                       + (lengths,))
    g_s, l_s, c_s = grams[order], lengths[order], counts[order]
    prev_diff = np.any(g_s != np.roll(g_s, 1, axis=0), axis=1) | \
        (l_s != np.roll(l_s, 1))
    prev_diff[0] = True
    starts = np.flatnonzero(prev_diff)
    summed = np.add.reduceat(c_s.astype(np.int64), starts, axis=0)
    return NGramStats(g_s[starts], l_s[starts], summed, dict(stats.counters))
