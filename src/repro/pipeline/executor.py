"""Wave-based job execution: out-of-core n-gram jobs over a shared pipeline.

The monolithic single-device jobs in ``repro.core`` hold the whole token
array (and every intermediate record buffer) on the device at once, so corpus
size is capped by HBM.  Hadoop never has that cap: it streams splits through
map -> combine -> shuffle -> sort -> reduce *across machines*.
:class:`WaveExecutor` restores the streaming shape:

  * the corpus stays host-resident; fixed-size token *waves* (plus a
    ``sigma - 1`` token halo from the next wave, exactly the ppermute halo of
    the distributed jobs) move to the device one at a time, so the device
    working set is O(wave * sigma), independent of corpus size;
  * each wave runs the method's :class:`~repro.pipeline.plan.JobPlan` through
    one jitted stage pipeline (combine -> sort -> reduce, record buffers
    donated), compiled once and reused by every wave;
  * wave dispatch is **double-buffered** (:class:`DoubleBufferedDriver`): wave
    ``i + 1``'s h2d copy and stage program are submitted before wave ``i``'s
    results are materialized, so jax's async dispatch overlaps device work
    with the host-side fold.  No per-wave host syncs ride the hot path --
    counters stay device scalars until collect time;
  * per-wave partials are produced at ``tau = 1`` -- a gram below tau in every
    wave can still be frequent globally, so nothing may be dropped early --
    and folded through the *segment merge* path (``index/merge.py``).  The
    fold is **size-tiered** (:class:`~repro.index.merge.TieredSegmentAccumulator`,
    the LSM discipline of ``GenerationalIndex``): amortized O(total log waves)
    merge work instead of the O(waves * total) of folding every wave into one
    running segment.  Either accumulator yields the same sorted segment, so
    the final output stays bit-identical to the monolithic job (canonical
    order; the global tau filter runs once at the end);
  * with a ``mesh``, every wave is **distributed**: the wave's extended
    window shards contiguously over the mesh axis and runs through a
    ``shard_map`` stage program that reuses the per-method jobs' own plumbing
    -- the ppermute sigma-1 halo between neighbor shards and the
    hash-partitioned ``all_to_all`` shuffle (``mapreduce.shuffle``) with
    counted-overflow capacity retries.  Per-wave *sharded* partials fold
    through the same segment path, so the distributed wave run is
    bit-identical to the monolithic single-device job too.

``run_streaming`` closes the loop with serving: each wave's partial goes
straight into :class:`~repro.index.merge.GenerationalIndex` ingest, so a
corpus that never fits on the device streams end to end into a queryable,
compacting index.

``run_plan`` is the one-wave degenerate case the ``repro.core`` methods now
delegate their single-device path to: whole corpus, legacy tau-per-round
semantics (APRIORI pruning at full strength), same counters as the old
monolithic code -- just one shared implementation of the stage plumbing.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle as mr_shuffle
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.pipeline import stages
from repro.pipeline.plan import JobPlan, plan_for

_SKEW_BUCKETS = 64   # nominal reducer count for the shuffle-skew counter

# jitted stage programs keyed by backend: buffer donation is decided per
# backend (a no-op with a warning on CPU), and the backend can change between
# calls (tests flip platforms, a driver may move from CPU warmup to TPU), so
# the decision must never be frozen at first call
_STAGE_CORE: dict[str, object] = {}


def reset_stage_cache() -> None:
    """Drop the jitted stage programs (tests / backend reconfiguration)."""
    _STAGE_CORE.clear()


def _stage_core(records, **kw):
    backend = jax.default_backend()
    fn = _STAGE_CORE.get(backend)
    if fn is None:
        # buffer donation is a no-op (with a warning) on CPU; donate only
        # where it helps
        donate = (0,) if backend != "cpu" else ()
        fn = partial(
            jax.jit, donate_argnums=donate,
            static_argnames=("n_lanes", "has_bucket", "combine_route",
                             "use_kernels", "sigma", "lane_vocab",
                             "shuffle_key", "reduce_kind", "with_positions",
                             "n_buckets"))(_stage_core_impl)
        _STAGE_CORE[backend] = fn
    return fn(records, **kw)


def _stage_core_impl(records, *, n_lanes: int, has_bucket: bool,
                     combine_route: str | None, use_kernels: bool, sigma: int,
                     lane_vocab: int, shuffle_key: str, reduce_kind: str,
                     with_positions: bool, n_buckets: int):
    """combine -> shuffle-key -> sort -> reduce over one wave's records.

    The single jitted program every wave reuses; ``records`` is donated, so
    the map buffer's memory is recycled for the sort.  Returns (dense reducer
    outputs, post-combine live-record count, partition histogram over
    ``_SKEW_BUCKETS`` nominal reducers -- the realized shuffle skew).
    """
    if combine_route is not None:
        records = stages.combine(records, n_lanes, has_bucket,
                                 route=combine_route, use_kernels=use_kernels)
    live = records[:, n_lanes] > 0
    shuffled = jnp.sum(live)
    key = stages.partition_keys(records, n_lanes, kind=shuffle_key,
                                vocab_size=lane_vocab)
    # the real partitioner's bucketing (hash_u32 % P, invalid -> P), so the
    # skew counter measures realized reducer load, not raw-key spread
    bucket = mr_shuffle.partition_ids(key, live, _SKEW_BUCKETS)
    hist = jnp.bincount(bucket, length=_SKEW_BUCKETS + 1)[:_SKEW_BUCKETS]
    rec = stages.sort_stage(records, n_keys=n_lanes)
    if reduce_kind == "suffix":
        dense = stages.reduce_suffix(rec, sigma=sigma, vocab_size=lane_vocab,
                                     n_buckets=n_buckets,
                                     use_kernels=use_kernels)
    else:
        dense = stages.reduce_exact(rec, sigma=sigma, vocab_size=lane_vocab,
                                    with_positions=with_positions)
    return dense, shuffled, hist


def _run_rounds(tok_ext, aux_ext, n_live: int, cfg, plan: JobPlan,
                tau_eff: int, counters: dict):
    """All of a plan's rounds over one token window -> merged ``NGramStats``.

    The *synchronous* interpreter ``run_plan`` uses: per-round host
    materialization (tau-filtered carries, ``stop_on_empty``), legacy
    monolithic counter semantics.  The wave hot path uses the async
    ``WaveExecutor._submit_wave`` / ``_collect_wave`` pair instead.
    """
    from repro.core.stats import NGramStats, add_counters

    lane_vocab = plan.effective_lane_vocab(cfg)
    n_l = packing.n_lanes(cfg.sigma, lane_vocab)
    has_bucket = aux_ext is not None
    n_meta = plan.map.n_meta + (1 if has_bucket else 0)
    rec_bytes = packing.record_bytes(cfg.sigma, lane_vocab, n_meta=n_meta)
    combine_route = plan.combine.route if plan.combine is not None else None

    out = None
    carry = None
    for k in range(1, plan.rounds + 1):
        with obs_trace.span("round.emit") as sp:
            if sp:
                sp.set(round=k)
            records, valid, emit_extras = plan.map.emit(
                tok_ext, aux_ext, n_live, cfg, carry, k)
        map_rec = int(jnp.sum(valid))
        # combine -> shuffle-key -> sort -> reduce fuse into one jitted
        # program, so the stage granularity under this span is the dispatch;
        # the device time lands in the materialize span's sync below
        with obs_trace.span("round.stages") as sp:
            if sp:
                sp.set(round=k)
            dense, shuffled, hist = _stage_core(
                records, n_lanes=n_l, has_bucket=has_bucket,
                combine_route=combine_route, use_kernels=cfg.use_kernels,
                sigma=cfg.sigma, lane_vocab=lane_vocab,
                shuffle_key=plan.shuffle.key, reduce_kind=plan.reduce.kind,
                with_positions=plan.reduce.with_positions,
                n_buckets=cfg.n_buckets)
        with obs_trace.span("round.materialize") as sp:
            if sp:
                sp.set(round=k)
            terms, flags, counts = (np.asarray(x) for x in dense[:3])
            stats_k = NGramStats.from_dense(terms, flags, counts, tau_eff)
        reduce_extras = ({"totals_pos": dense[3]}
                         if plan.reduce.with_positions else {})
        shuffled = int(shuffled)
        hist = np.asarray(hist)
        add_counters(counters, jobs=1, map_records=map_rec,
                     shuffle_records=shuffled,
                     shuffle_bytes=shuffled * rec_bytes)
        if shuffled:
            skew = float(hist.max() * _SKEW_BUCKETS / max(hist.sum(), 1))
            counters["shuffle_skew"] = max(counters.get("shuffle_skew", 0.0),
                                           skew)
        out = stats_k if out is None else out.merged_with(stats_k)
        if plan.stop_on_empty and len(stats_k) == 0:
            break
        if k < plan.rounds and plan.update_carry is not None:
            carry = plan.update_carry(cfg, tau_eff, k, tok_ext, stats_k,
                                      reduce_extras, emit_extras, carry)
    out.counters = counters
    return out


def run_plan(tokens, cfg, bucket_ids=None, plan: JobPlan | None = None):
    """One-wave (whole-corpus) plan execution -- the single-device job.

    Semantics and counters match the old per-method monolithic code (tau and
    APRIORI pruning apply per round); output rows are in canonical segment
    order (``stages.canonical_stats``), which is what the wave executor is
    bit-compared against.
    """
    plan = plan or plan_for(cfg)
    with obs_trace.span("plan.run") as sp:
        if sp:
            sp.set(method=cfg.method, rounds=plan.rounds)
        tokens = jnp.asarray(tokens, jnp.int32)
        aux = None if bucket_ids is None else jnp.asarray(bucket_ids,
                                                          jnp.uint32)
        # the full canonical counter set (obs.metrics.COUNTER_DOC), so the
        # monolithic and wave paths expose identical keys with stable types
        counters = dict.fromkeys(
            ("jobs", "map_records", "shuffle_records", "shuffle_bytes",
             "retries", "overflow"), 0)
        counters["shuffle_skew"] = 0.0
        out = _run_rounds(tokens, aux, int(tokens.shape[0]), cfg, plan,
                          cfg.tau, counters)
        out.counters = obs_metrics.normalize_counters(out.counters)
        return stages.canonical_stats(out)


class DoubleBufferedDriver:
    """Overlap host-side work with device execution.

    ``submit`` dispatches batch i+1 (``answer`` must return its result
    *unmaterialized* -- device arrays or a record holding them) and only then
    materializes batch i's via ``collect`` -- jax's async dispatch runs the new
    batch while the host reads the old one, with no ``jax.block_until_ready``
    anywhere on the hot path.  ``submit`` returns (previous batch's collected
    result, its submit-time payload); ``drain`` flushes the last in-flight
    batch.

    Shared by the serving loop (``launch/serve_ngrams.py``, where it overlaps
    query batching with device lookups) and the wave engine's ingest loop
    (where it overlaps wave i+1's h2d/compute with wave i's host-side fold).
    """

    def __init__(self, answer, collect=None):
        self._answer = answer
        self._collect = collect
        self._pending = None

    def _materialize(self, out):
        if self._collect is not None:
            return self._collect(out)
        return np.asarray(out)

    def submit(self, *args, tag=None):
        out = self._answer(*args)
        prev, self._pending = self._pending, (out, tag)
        if prev is None:
            return None, None
        return self._materialize(prev[0]), prev[1]

    def drain(self):
        if self._pending is None:
            return None, None
        (out, tag), self._pending = self._pending, None
        return self._materialize(out), tag


def _merge_wave_counters(dst: dict, src: dict) -> None:
    """Fold one wave's counters into the run totals.

    Delegates to the one shared policy (``repro.obs.metrics``): sums, except
    the documented max-merged ratio keys (``shuffle_skew``).  The canonical
    counter set and its semantics live in ``obs.metrics.COUNTER_DOC``.
    """
    obs_metrics.merge_counter_dicts(dst, src)


class WaveExecutor:
    """Run a :class:`JobPlan` over fixed-size token waves (out-of-core).

    ``wave_tokens`` bounds the device-resident working set; ``None`` (or a
    wave at least the corpus size) degenerates to one wave.  Waves execute at
    ``tau = 1`` and fold through ``index/merge.py`` segments under the
    ``accumulator`` policy (``"tiered"`` = size-tiered LSM rung stack,
    amortized O(total log waves) merge work; ``"pairwise"`` = the legacy
    fold-every-wave-into-one-segment baseline, O(waves x total));
    ``merge_route``: ``"sort"`` = one fused re-sort per fold, the fastest
    eager route on CPU; ``"merge"`` = pairwise merge-path.  :meth:`run`
    applies the global tau once at the end, so for any wave size (and either
    accumulator) the output is bit-identical to the monolithic job.

    With a ``mesh`` (size > 1), each wave's stage pipeline shards over
    ``axis_name``: contiguous token slices per shard, the distributed jobs'
    own ppermute sigma-1 halo between neighbors, and the hash-partitioned
    ``all_to_all`` shuffle with counted-overflow capacity retries.  Per-wave
    sharded partials still fold through the segment path, so the distributed
    run stays bit-identical to the single-device one.

    Memory model: device footprint is O(wave * sigma) records per stage (per
    shard when distributed); the running segments live wherever
    ``index/merge.py`` keeps them and together hold the *exact* (tau=1) gram
    set seen so far -- the unavoidable state of any exact out-of-core
    counter.  Restrictions: bucketed time series (``n_buckets``) need
    cross-wave bucket columns the segment fold does not carry, so waves
    require ``n_buckets == 0``.
    """

    def __init__(self, cfg, *, wave_tokens: int | None = None,
                 plan: JobPlan | None = None, merge_route: str = "sort",
                 accumulator: str = "tiered", mesh=None,
                 axis_name: str = "data"):
        if wave_tokens is not None and wave_tokens < 1:
            raise ValueError("wave_tokens must be >= 1")
        if cfg.n_buckets:
            raise ValueError("wave execution does not support n_buckets "
                             "(bucketed series need the bucket-carrying "
                             "single job -- run_job / run_plan)")
        if accumulator not in ("tiered", "pairwise"):
            raise ValueError(f"unknown accumulator {accumulator!r} "
                             "(options: 'tiered', 'pairwise')")
        self.cfg = cfg
        self.wave_tokens = wave_tokens
        self.plan = plan or plan_for(cfg)
        self.merge_route = merge_route
        self.accumulator = accumulator
        self.mesh = mesh
        self.axis_name = axis_name
        self._mesh_programs: dict = {}   # (k, capacity, has_carry, n_local)
        self._emit_rows_cache: dict = {}

    # --- wave iteration ------------------------------------------------------ #

    def _windows(self, tokens: np.ndarray):
        """Yield (tok_ext [wave + sigma - 1], n_live) fixed-shape windows.

        ``n_live`` is the *true* number of corpus tokens in the wave -- the
        final wave of a corpus that is not a multiple of ``wave_tokens`` gets
        a partial count, so the emit's live mask (positions ``< n_live``)
        excludes the zero-padded tail outright instead of leaning on the
        reserved-PAD convention (``NGramConfig.validate_tokens``) to mask
        phantom tail grams.
        """
        n = int(tokens.shape[0])
        wave = self.wave_tokens if self.wave_tokens is not None else n
        wave = max(1, min(wave, n) if n else 1)
        n_waves = max(1, -(-n // wave))
        halo = self.cfg.sigma - 1
        with obs_trace.span("wave.window.pad") as sp:
            if sp:
                sp.set(n_waves=n_waves, wave_tokens=wave)
            padded = np.zeros((n_waves * wave + halo,), np.int32)
            padded[:n] = np.asarray(tokens, np.int32)
        for w in range(n_waves):
            n_live = max(0, min(wave, n - w * wave))
            with obs_trace.span("wave.window.h2d") as sp:
                if sp:
                    sp.set(wave=w)
                tok_ext = jnp.asarray(padded[w * wave: (w + 1) * wave + halo])
            yield tok_ext, n_live

    # --- single-device async wave dispatch ----------------------------------- #

    def _submit_wave(self, tok_ext, n_live: int) -> dict:
        """Dispatch one wave's rounds; nothing is materialized here.

        The wave regime always runs at ``tau_eff = 1``, where carries are a
        pure traceable function of the emit-side evidence (the contract
        ``plan.py`` documents), so no round needs a host-synced ``stats_k``
        and the whole wave -- counters included -- stays in flight until
        :meth:`_collect_wave`.  ``stop_on_empty`` is skipped: an exhausted
        round chain emits empty partials that fold to nothing.
        """
        cfg, plan = self.cfg, self.plan
        with obs_trace.span("wave.submit") as sp:
            if sp:
                sp.set(n_live=n_live, rounds=plan.rounds)
            lane_vocab = plan.effective_lane_vocab(cfg)
            n_l = packing.n_lanes(cfg.sigma, lane_vocab)
            combine_route = plan.combine.route if plan.combine is not None \
                else None
            carry = None
            rounds = []
            for k in range(1, plan.rounds + 1):
                records, valid, emit_extras = plan.map.emit(
                    tok_ext, None, n_live, cfg, carry, k)
                map_rec = jnp.sum(valid)          # device scalar: deferred
                dense, shuffled, hist = _stage_core(
                    records, n_lanes=n_l, has_bucket=False,
                    combine_route=combine_route, use_kernels=cfg.use_kernels,
                    sigma=cfg.sigma, lane_vocab=lane_vocab,
                    shuffle_key=plan.shuffle.key,
                    reduce_kind=plan.reduce.kind,
                    with_positions=plan.reduce.with_positions,
                    n_buckets=cfg.n_buckets)
                rounds.append((dense[:3], map_rec, shuffled, hist))
                if k < plan.rounds and plan.update_carry is not None:
                    carry = plan.update_carry(cfg, 1, k, tok_ext, None, {},
                                              emit_extras, carry)
            rec_bytes = packing.record_bytes(cfg.sigma, lane_vocab,
                                             n_meta=plan.map.n_meta)
            return {"rounds": rounds, "rec_bytes": rec_bytes}

    def _collect_wave(self, pend: dict):
        """Materialize a submitted wave -> exact ``NGramStats`` partial.

        The ``np.asarray`` materializations here are the wave's one device
        sync: the collect span's duration is host-visible device+transfer
        time (the double-buffer's occupancy signal -- a collect much shorter
        than its submit-to-submit gap means the device was idle).
        """
        from repro.core.stats import NGramStats, add_counters

        with obs_trace.span("wave.collect") as sp:
            counters: dict = {}
            out = None
            for dense, map_rec, shuffled, hist in pend["rounds"]:
                terms, flags, counts = (np.asarray(x) for x in dense)
                stats_k = NGramStats.from_dense(terms, flags, counts, 1)
                shuffled = int(shuffled)
                hist = np.asarray(hist)
                add_counters(counters, jobs=1, map_records=int(map_rec),
                             shuffle_records=shuffled,
                             shuffle_bytes=shuffled * pend["rec_bytes"])
                if shuffled:
                    skew = float(hist.max() * _SKEW_BUCKETS
                                 / max(hist.sum(), 1))
                    counters["shuffle_skew"] = max(
                        counters.get("shuffle_skew", 0.0), skew)
                out = stats_k if out is None else out.merged_with(stats_k)
            out.counters = counters
            if sp:
                sp.set(rows=len(out), shuffle_records=counters.get(
                    "shuffle_records", 0))
            return out

    # --- distributed (mesh) wave dispatch ------------------------------------ #

    def _emit_rows(self, win_len: int, k: int) -> int:
        """Map-emit record rows for a ``win_len``-token window (shape probe)."""
        key = (win_len, k)
        rows = self._emit_rows_cache.get(key)
        if rows is None:
            shape = jax.eval_shape(
                lambda t: self.plan.map.emit(t, None, 0, self.cfg, None, k)[0],
                jax.ShapeDtypeStruct((win_len,), jnp.int32))
            rows = self._emit_rows_cache[key] = int(shape.shape[0])
        return rows

    def _mesh_program(self, k: int, capacity: int, has_carry: bool,
                      n_local: int):
        key = (k, capacity, has_carry, n_local)
        fn = self._mesh_programs.get(key)
        if fn is None:
            fn = self._mesh_programs[key] = self._build_mesh_round(
                k, capacity, has_carry, n_local)
        return fn

    def _build_mesh_round(self, k: int, capacity: int, has_carry: bool,
                          n_local: int):
        """One round's sharded stage program: the jobs' plumbing, reused.

        Each shard owns a contiguous ``n_local``-token slice of the wave's
        extended window, pulls its sigma-1 halo from the right neighbor via
        ppermute (the last shard's halo is zeros -- the window already ends
        in the wave-level halo, and nothing live reads past it), emits with a
        shard-local live count, pre-aggregates, and exchanges records through
        the hash-partitioned ``all_to_all`` shuffle so every gram's evidence
        lands on one reducer shard.  Carries stay shard-local: at
        ``tau_eff = 1`` a carry is a pure function of the shard's own
        extended window (see ``plan.py``), which covers every position the
        shard's live emits can consult.
        """
        from jax.sharding import PartitionSpec as P

        cfg, plan = self.cfg, self.plan
        mesh, axis_name = self.mesh, self.axis_name
        n_parts = mesh.shape[axis_name]
        lane_vocab = plan.effective_lane_vocab(cfg)
        n_l = packing.n_lanes(cfg.sigma, lane_vocab)
        halo = cfg.sigma - 1
        has_carry_out = plan.update_carry is not None and k < plan.rounds

        def job(tok, n_live, *maybe_carry):
            tok = tok[0]                                     # [n_local]
            if halo:
                perm = [(i, (i - 1) % n_parts) for i in range(n_parts)]
                h = jax.lax.ppermute(tok[:halo], axis_name, perm)
                is_last = jax.lax.axis_index(axis_name) == n_parts - 1
                h = jnp.where(is_last, jnp.zeros_like(h), h)
                tok_ext = jnp.concatenate([tok, h])
            else:
                tok_ext = tok
            shard = jax.lax.axis_index(axis_name)
            n_live_local = jnp.clip(n_live - shard * n_local, 0, n_local)
            carry = maybe_carry[0][0] if has_carry else None
            records, valid, emit_extras = plan.map.emit(
                tok_ext, None, n_live_local, cfg, carry, k)
            map_rec = jnp.sum(valid.astype(jnp.int32))
            if plan.combine is not None:
                records = stages.combine(records, n_l, False,
                                         route=plan.combine.route,
                                         use_kernels=cfg.use_kernels)
            live = records[:, n_l] > 0
            key = stages.partition_keys(records, n_l, kind=plan.shuffle.key,
                                        vocab_size=lane_vocab)
            skew = mr_shuffle.partition_ids(key, live, _SKEW_BUCKETS)
            hist = jax.lax.psum(
                jnp.bincount(skew, length=_SKEW_BUCKETS + 1)[:_SKEW_BUCKETS],
                axis_name)
            local, overflow = mr_shuffle.shuffle(
                records, key, live, axis_name=axis_name, n_parts=n_parts,
                capacity=capacity)
            shuf = jax.lax.psum(jnp.sum(local[:, n_l] > 0), axis_name)
            rec = stages.sort_stage(local, n_keys=n_l)
            if plan.reduce.kind == "suffix":
                terms, flags, counts = stages.reduce_suffix(
                    rec, sigma=cfg.sigma, vocab_size=lane_vocab, n_buckets=0,
                    use_kernels=cfg.use_kernels)
            else:
                # position payloads are only consumed by tau>1 carries, which
                # the wave regime never takes -- skip the scatter
                terms, flags, counts = stages.reduce_exact(
                    rec, sigma=cfg.sigma, vocab_size=lane_vocab,
                    with_positions=False)
            if has_carry_out:
                carry_out = plan.update_carry(cfg, 1, k, tok_ext, None, {},
                                              emit_extras, carry)
            else:
                carry_out = jnp.zeros((1,), jnp.uint32)
            cnt = jnp.stack([jax.lax.psum(map_rec, axis_name), shuf, overflow])
            return (terms[None], flags[None], counts[None], carry_out[None],
                    cnt[None], hist[None])

        in_specs = [P(axis_name, None), P()]
        if has_carry:
            in_specs.append(P(axis_name, None))
        return jax.jit(jax.shard_map(job, mesh=mesh, in_specs=tuple(in_specs),
                                     out_specs=(P(axis_name),) * 6,
                                     check_vma=False))

    def _iter_wave_stats_mesh(self, tokens: np.ndarray):
        """Per-wave exact partials with every wave sharded over the mesh."""
        from repro.core.stats import NGramStats, add_counters

        cfg, plan = self.cfg, self.plan
        n_parts = self.mesh.shape[self.axis_name]
        lane_vocab = plan.effective_lane_vocab(cfg)
        rec_bytes = packing.record_bytes(cfg.sigma, lane_vocab,
                                         n_meta=plan.map.n_meta)
        for tok_ext, n_live in self._windows(tokens):
            win_len = int(tok_ext.shape[0])
            # the one-hop ppermute halo pulls sigma-1 tokens from the right
            # neighbor, so a shard's slice must be at least that long --
            # tiny waves leave trailing shards all-pad (no live positions)
            n_local = max(-(-win_len // n_parts), cfg.sigma - 1, 1)
            tok_p = np.zeros((n_parts * n_local,), np.int32)
            tok_p[:win_len] = np.asarray(tok_ext)
            tok_p = jnp.asarray(tok_p.reshape(n_parts, n_local))
            n_live_dev = jnp.int32(n_live)
            counters: dict = {}
            out = None
            carry = None
            for k in range(1, plan.rounds + 1):
                rows = self._emit_rows(n_local + cfg.sigma - 1, k)
                capacity = max(8, int(cfg.capacity_factor * rows / n_parts) + 1)
                with obs_trace.span("wave.mesh.round") as sp_r:
                    for attempt in range(6):   # overflow -> double, rerun
                        fn = self._mesh_program(k, capacity, carry is not None,
                                                n_local)
                        args = (tok_p, n_live_dev) + (
                            (carry,) if carry is not None else ())
                        terms, flags, counts, carry_out, cnt, hist = fn(*args)
                        cnt_np = np.asarray(cnt)
                        if int(cnt_np[0, 2]) == 0:
                            break
                        capacity *= 2
                    else:
                        raise RuntimeError(
                            f"wave shuffle overflow persisted at capacity "
                            f"{capacity} (round {k})")
                    if sp_r:
                        sp_r.set(round=k, retries=attempt, capacity=capacity)
                if attempt:   # capacity-doubling reruns, visible like the jobs'
                    add_counters(counters, retries=attempt)
                shuf = int(cnt_np[0, 1])
                hist_np = np.asarray(hist)[0]
                add_counters(counters, jobs=1, map_records=int(cnt_np[0, 0]),
                             shuffle_records=shuf,
                             shuffle_bytes=shuf * rec_bytes)
                if shuf:
                    skew = float(hist_np.max() * _SKEW_BUCKETS
                                 / max(hist_np.sum(), 1))
                    counters["shuffle_skew"] = max(
                        counters.get("shuffle_skew", 0.0), skew)
                with obs_trace.span("wave.mesh.materialize") as sp_m:
                    terms, flags, counts = (np.asarray(terms),
                                            np.asarray(flags),
                                            np.asarray(counts))
                    stats_k = None
                    for p in range(n_parts):
                        part = NGramStats.from_dense(terms[p], flags[p],
                                                     counts[p], 1)
                        stats_k = part if stats_k is None else \
                            stats_k.merged_with(part)
                    if sp_m:
                        sp_m.set(round=k, rows=len(stats_k))
                out = stats_k if out is None else out.merged_with(stats_k)
                if plan.stop_on_empty and len(stats_k) == 0:
                    break
                if k < plan.rounds and plan.update_carry is not None:
                    carry = carry_out
            out.counters = counters
            yield out

    # --- public iteration ----------------------------------------------------- #

    def iter_wave_stats(self, tokens):
        """Per-wave exact partials (``tau = 1``) -- the streaming delta feed.

        Single-device waves are double-buffered: wave ``i + 1`` is dispatched
        before wave ``i`` is materialized, so the consumer's host-side work
        (segment folds, generational ingest) overlaps device execution.  With
        a mesh, each wave runs sharded (overflow retries force a per-wave
        sync, so mesh waves dispatch synchronously).
        """
        tokens = np.asarray(tokens, np.int32)
        self.cfg.validate_tokens(tokens)
        if self.mesh is not None and self.mesh.size > 1:
            yield from self._iter_wave_stats_mesh(tokens)
            return
        drv = DoubleBufferedDriver(self._submit_wave,
                                   collect=self._collect_wave)
        for tok_ext, n_live in self._windows(tokens):
            res, _ = drv.submit(tok_ext, n_live)
            if res is not None:
                yield res
        res, _ = drv.drain()
        if res is not None:
            yield res

    # --- whole-job execution ------------------------------------------------- #

    def run(self, tokens):
        """Execute the job over waves -> ``NGramStats`` (canonical order),
        bit-identical to the monolithic single-job run.  ``fold_rows`` in the
        counters is the total segment rows fed through ``merge_segments`` --
        the accumulator's measured merge work."""
        from repro.core.stats import NGramStats
        from repro.index.build import segment_from_stats
        from repro.index.merge import (PairwiseSegmentAccumulator,
                                       TieredSegmentAccumulator,
                                       segment_to_stats)

        with obs_trace.span("wave.run") as root:
            tokens = np.asarray(tokens, np.int32)
            if root:
                root.set(n_tokens=int(tokens.shape[0]),
                         method=self.cfg.method,
                         accumulator=self.accumulator)
            # full canonical counter set (obs.metrics.COUNTER_DOC): identical
            # keys to the monolithic run_plan, plus the wave-only
            # waves/fold_rows
            counters = dict.fromkeys(
                ("jobs", "map_records", "shuffle_records", "shuffle_bytes",
                 "retries", "overflow", "waves", "fold_rows"), 0)
            counters["shuffle_skew"] = 0.0
            acc_cls = (TieredSegmentAccumulator
                       if self.accumulator == "tiered"
                       else PairwiseSegmentAccumulator)
            acc = acc_cls(route=self.merge_route,
                          use_kernels=self.cfg.use_kernels)
            for wave_stats in self.iter_wave_stats(tokens):
                counters["waves"] += 1
                _merge_wave_counters(counters, wave_stats.counters)
                with obs_trace.span("wave.fold") as sp:
                    if sp:
                        sp.set(wave=counters["waves"] - 1,
                               rows=len(wave_stats))
                    seg = segment_from_stats(wave_stats,
                                             vocab_size=self.cfg.vocab_size)
                    acc.push(seg, n_rows=len(wave_stats))
            with obs_trace.span("wave.finalize") as sp:
                merged = segment_to_stats(acc.result())
                counters["fold_rows"] = acc.fold_rows
                keep = merged.counts >= self.cfg.tau
                out = NGramStats(merged.grams[keep], merged.lengths[keep],
                                 merged.counts[keep],
                                 obs_metrics.normalize_counters(counters))
                if sp:
                    sp.set(rows=len(out), fold_rows=acc.fold_rows)
            return out

    def run_streaming(self, tokens, *, gen=None, compress: bool = False,
                      **gen_kw):
        """Stream waves straight into a :class:`GenerationalIndex`.

        Each wave's exact partial (``tau = 1``; nothing may be dropped early)
        is frozen and ingested as a fresh L0 segment -- point/top-k answers
        over the resulting index match a from-scratch build over the full
        corpus at ``tau = 1`` exactly, while the device only ever holds one
        wave of job state plus the serving artifacts.  The wave feed is
        double-buffered, so wave ``i + 1``'s device work overlaps wave
        ``i``'s ingest/compaction.  Returns ``(index, reports)`` with one
        ingest report per wave.
        """
        from repro.index.merge import GenerationalIndex
        if gen is None:
            gen = GenerationalIndex(sigma=self.cfg.sigma,
                                    vocab_size=self.cfg.vocab_size,
                                    compress=compress,
                                    use_kernels=self.cfg.use_kernels, **gen_kw)
        reports = []
        for wave_stats in self.iter_wave_stats(tokens):
            reports.append(gen.ingest(wave_stats))
        return gen, reports
