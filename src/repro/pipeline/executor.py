"""Wave-based job execution: out-of-core n-gram jobs over a shared pipeline.

The monolithic single-device jobs in ``repro.core`` hold the whole token
array (and every intermediate record buffer) on the device at once, so corpus
size is capped by HBM.  Hadoop never has that cap: it streams splits through
map -> combine -> shuffle -> sort -> reduce.  :class:`WaveExecutor` restores
the streaming shape on a single device:

  * the corpus stays host-resident; fixed-size token *waves* (plus a
    ``sigma - 1`` token halo from the next wave, exactly the ppermute halo of
    the distributed jobs) move to the device one at a time, so the device
    working set is O(wave * sigma), independent of corpus size;
  * each wave runs the method's :class:`~repro.pipeline.plan.JobPlan` through
    one jitted stage pipeline (combine -> sort -> reduce, record buffers
    donated), compiled once and reused by every wave;
  * per-wave partials are produced at ``tau = 1`` -- a gram below tau in every
    wave can still be frequent globally, so nothing may be dropped early --
    and folded through the *segment merge* path (``index/merge.py``): the
    accumulator is a sorted :class:`~repro.index.build.IndexSegment`, never a
    host dict, so the final output is bit-identical to the monolithic job
    (canonical order; the global tau filter runs once at the end).

``run_streaming`` closes the loop with serving: each wave's partial goes
straight into :class:`~repro.index.merge.GenerationalIndex` ingest, so a
corpus that never fits on the device streams end to end into a queryable,
compacting index.

``run_plan`` is the one-wave degenerate case the ``repro.core`` methods now
delegate their single-device path to: whole corpus, legacy tau-per-round
semantics (APRIORI pruning at full strength), same counters as the old
monolithic code -- just one shared implementation of the stage plumbing.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle as mr_shuffle
from repro.pipeline import stages
from repro.pipeline.plan import JobPlan, plan_for

_SKEW_BUCKETS = 64   # nominal reducer count for the shuffle-skew counter

_STAGE_CORE = None   # jitted lazily: donation depends on the backend, and
                     # resolving the backend at import time would freeze it
                     # before callers can set XLA_FLAGS / platform config


def _stage_core(records, **kw):
    global _STAGE_CORE
    if _STAGE_CORE is None:
        # buffer donation is a no-op (with a warning) on CPU; donate only
        # where it helps
        donate = (0,) if jax.default_backend() != "cpu" else ()
        _STAGE_CORE = partial(
            jax.jit, donate_argnums=donate,
            static_argnames=("n_lanes", "has_bucket", "combine_route",
                             "use_kernels", "sigma", "lane_vocab",
                             "shuffle_key", "reduce_kind", "with_positions",
                             "n_buckets"))(_stage_core_impl)
    return _STAGE_CORE(records, **kw)


def _stage_core_impl(records, *, n_lanes: int, has_bucket: bool,
                     combine_route: str | None, use_kernels: bool, sigma: int,
                     lane_vocab: int, shuffle_key: str, reduce_kind: str,
                     with_positions: bool, n_buckets: int):
    """combine -> shuffle-key -> sort -> reduce over one wave's records.

    The single jitted program every wave reuses; ``records`` is donated, so
    the map buffer's memory is recycled for the sort.  Returns (dense reducer
    outputs, post-combine live-record count, partition histogram over
    ``_SKEW_BUCKETS`` nominal reducers -- the realized shuffle skew).
    """
    if combine_route is not None:
        records = stages.combine(records, n_lanes, has_bucket,
                                 route=combine_route, use_kernels=use_kernels)
    live = records[:, n_lanes] > 0
    shuffled = jnp.sum(live)
    key = stages.partition_keys(records, n_lanes, kind=shuffle_key,
                                vocab_size=lane_vocab)
    # the real partitioner's bucketing (hash_u32 % P, invalid -> P), so the
    # skew counter measures realized reducer load, not raw-key spread
    bucket = mr_shuffle.partition_ids(key, live, _SKEW_BUCKETS)
    hist = jnp.bincount(bucket, length=_SKEW_BUCKETS + 1)[:_SKEW_BUCKETS]
    rec = stages.sort_stage(records, n_keys=n_lanes)
    if reduce_kind == "suffix":
        dense = stages.reduce_suffix(rec, sigma=sigma, vocab_size=lane_vocab,
                                     n_buckets=n_buckets,
                                     use_kernels=use_kernels)
    else:
        dense = stages.reduce_exact(rec, sigma=sigma, vocab_size=lane_vocab,
                                    with_positions=with_positions)
    return dense, shuffled, hist


def _run_rounds(tok_ext, aux_ext, n_live: int, cfg, plan: JobPlan,
                tau_eff: int, counters: dict):
    """All of a plan's rounds over one token window -> merged ``NGramStats``."""
    from repro.core.stats import NGramStats, add_counters

    lane_vocab = plan.effective_lane_vocab(cfg)
    n_l = packing.n_lanes(cfg.sigma, lane_vocab)
    has_bucket = aux_ext is not None
    n_meta = plan.map.n_meta + (1 if has_bucket else 0)
    rec_bytes = packing.record_bytes(cfg.sigma, lane_vocab, n_meta=n_meta)
    combine_route = plan.combine.route if plan.combine is not None else None

    out = None
    carry = None
    for k in range(1, plan.rounds + 1):
        records, valid, emit_extras = plan.map.emit(
            tok_ext, aux_ext, n_live, cfg, carry, k)
        map_rec = int(jnp.sum(valid))
        dense, shuffled, hist = _stage_core(
            records, n_lanes=n_l, has_bucket=has_bucket,
            combine_route=combine_route, use_kernels=cfg.use_kernels,
            sigma=cfg.sigma, lane_vocab=lane_vocab,
            shuffle_key=plan.shuffle.key, reduce_kind=plan.reduce.kind,
            with_positions=plan.reduce.with_positions,
            n_buckets=cfg.n_buckets)
        terms, flags, counts = (np.asarray(x) for x in dense[:3])
        stats_k = NGramStats.from_dense(terms, flags, counts, tau_eff)
        reduce_extras = ({"totals_pos": dense[3]}
                         if plan.reduce.with_positions else {})
        shuffled = int(shuffled)
        hist = np.asarray(hist)
        add_counters(counters, jobs=1, map_records=map_rec,
                     shuffle_records=shuffled,
                     shuffle_bytes=shuffled * rec_bytes)
        if shuffled:
            skew = float(hist.max() * _SKEW_BUCKETS / max(hist.sum(), 1))
            counters["shuffle_skew"] = max(counters.get("shuffle_skew", 0.0),
                                           skew)
        out = stats_k if out is None else out.merged_with(stats_k)
        if plan.stop_on_empty and len(stats_k) == 0:
            break
        if k < plan.rounds and plan.update_carry is not None:
            carry = plan.update_carry(cfg, tau_eff, k, tok_ext, stats_k,
                                      reduce_extras, emit_extras, carry)
    out.counters = counters
    return out


def run_plan(tokens, cfg, bucket_ids=None, plan: JobPlan | None = None):
    """One-wave (whole-corpus) plan execution -- the single-device job.

    Semantics and counters match the old per-method monolithic code (tau and
    APRIORI pruning apply per round); output rows are in canonical segment
    order (``stages.canonical_stats``), which is what the wave executor is
    bit-compared against.
    """
    plan = plan or plan_for(cfg)
    tokens = jnp.asarray(tokens, jnp.int32)
    aux = None if bucket_ids is None else jnp.asarray(bucket_ids, jnp.uint32)
    counters = {"overflow": 0}
    out = _run_rounds(tokens, aux, int(tokens.shape[0]), cfg, plan,
                      cfg.tau, counters)
    return stages.canonical_stats(out)


class WaveExecutor:
    """Run a :class:`JobPlan` over fixed-size token waves (out-of-core).

    ``wave_tokens`` bounds the device-resident working set; ``None`` (or a
    wave at least the corpus size) degenerates to one wave.  Waves execute at
    ``tau = 1`` and fold into one sorted segment via ``index/merge.py``
    (``merge_route``: ``"sort"`` = one fused re-sort per fold, the fastest
    eager route on CPU; ``"merge"`` = pairwise merge-path); :meth:`run`
    applies the global tau once at the end, so for any wave size the output
    is bit-identical to the monolithic job.

    Memory model: device footprint is O(wave * sigma) records per stage; the
    running segment lives wherever ``index/merge.py`` keeps it and holds the
    *exact* (tau=1) gram set seen so far -- the unavoidable state of any exact
    out-of-core counter.  Restrictions: bucketed time series (``n_buckets``)
    need cross-wave bucket columns the segment fold does not carry, so waves
    require ``n_buckets == 0``.
    """

    def __init__(self, cfg, *, wave_tokens: int | None = None,
                 plan: JobPlan | None = None, merge_route: str = "sort"):
        if wave_tokens is not None and wave_tokens < 1:
            raise ValueError("wave_tokens must be >= 1")
        if cfg.n_buckets:
            raise ValueError("wave execution does not support n_buckets "
                             "(bucketed series need the bucket-carrying "
                             "single job -- run_job / run_plan)")
        self.cfg = cfg
        self.wave_tokens = wave_tokens
        self.plan = plan or plan_for(cfg)
        self.merge_route = merge_route

    # --- wave iteration ------------------------------------------------------ #

    def _windows(self, tokens: np.ndarray):
        """Yield (tok_ext [wave + sigma - 1], n_live) fixed-shape windows."""
        n = int(tokens.shape[0])
        wave = self.wave_tokens if self.wave_tokens is not None else n
        wave = max(1, min(wave, n) if n else 1)
        n_waves = max(1, -(-n // wave))
        halo = self.cfg.sigma - 1
        padded = np.zeros((n_waves * wave + halo,), np.int32)
        padded[:n] = np.asarray(tokens, np.int32)
        for w in range(n_waves):
            yield jnp.asarray(padded[w * wave: (w + 1) * wave + halo]), wave

    def iter_wave_stats(self, tokens):
        """Per-wave exact partials (``tau = 1``) -- the streaming delta feed."""
        tokens = np.asarray(tokens, np.int32)
        for tok_ext, n_live in self._windows(tokens):
            counters: dict = {}
            yield _run_rounds(tok_ext, None, n_live, self.cfg, self.plan,
                              1, counters)

    # --- whole-job execution ------------------------------------------------- #

    def run(self, tokens):
        """Execute the job over waves -> ``NGramStats`` (canonical order),
        bit-identical to the monolithic single-job run."""
        from repro.core.stats import NGramStats
        from repro.index.build import segment_from_stats
        from repro.index.merge import merge_segments, segment_to_stats

        tokens = np.asarray(tokens, np.int32)
        counters = {"overflow": 0, "waves": 0}
        acc = None
        for tok_ext, n_live in self._windows(tokens):
            counters["waves"] += 1
            wave_stats = _run_rounds(tok_ext, None, n_live, self.cfg,
                                     self.plan, 1, counters)
            seg = segment_from_stats(wave_stats,
                                     vocab_size=self.cfg.vocab_size)
            acc = seg if acc is None else merge_segments(
                [acc, seg], route=self.merge_route,
                use_kernels=self.cfg.use_kernels)
        merged = segment_to_stats(acc)
        keep = merged.counts >= self.cfg.tau
        return NGramStats(merged.grams[keep], merged.lengths[keep],
                          merged.counts[keep], counters)

    def run_streaming(self, tokens, *, gen=None, compress: bool = False,
                      **gen_kw):
        """Stream waves straight into a :class:`GenerationalIndex`.

        Each wave's exact partial (``tau = 1``; nothing may be dropped early)
        is frozen and ingested as a fresh L0 segment -- point/top-k answers
        over the resulting index match a from-scratch build over the full
        corpus at ``tau = 1`` exactly, while the device only ever holds one
        wave of job state plus the serving artifacts.  Returns
        ``(index, reports)`` with one ingest report per wave.
        """
        from repro.index.merge import GenerationalIndex
        if gen is None:
            gen = GenerationalIndex(sigma=self.cfg.sigma,
                                    vocab_size=self.cfg.vocab_size,
                                    compress=compress,
                                    use_kernels=self.cfg.use_kernels, **gen_kw)
        reports = []
        for wave_stats in self.iter_wave_stats(tokens):
            reports.append(gen.ingest(wave_stats))
        return gen, reports
