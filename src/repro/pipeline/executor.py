"""Wave-based job execution: out-of-core n-gram jobs over a shared pipeline.

The monolithic single-device jobs in ``repro.core`` hold the whole token
array (and every intermediate record buffer) on the device at once, so corpus
size is capped by HBM.  Hadoop never has that cap: it streams splits through
map -> combine -> shuffle -> sort -> reduce *across machines*.
:class:`WaveExecutor` restores the streaming shape:

  * the corpus stays host-resident; fixed-size token *waves* (plus a
    ``sigma - 1`` token halo from the next wave, exactly the ppermute halo of
    the distributed jobs) move to the device one at a time, so the device
    working set is O(wave * sigma), independent of corpus size;
  * each wave runs the method's :class:`~repro.pipeline.plan.JobPlan` as
    **one fused jitted program** (``_wave_core``): every round's map emit,
    the combine -> shuffle-key -> sort -> reduce stage chain, and the tau=1
    carry updates feeding the next round all trace into a single donated XLA
    program, compiled once per plan and reused by every wave -- a wave is a
    single dispatch, not a per-stage (or per-round) chain of them;
  * the wave loop is **device-resident with an overlapped fold**
    (``_for_each_wave``): the main thread only slices host token slabs and
    dispatches fused wave programs, while a background fold thread
    materializes each wave and folds it (accumulator merge / generational
    ingest) -- so host-side fold work overlaps the next waves' device work
    instead of serializing with it, with a bounded in-flight queue keeping
    the memory model.  No per-wave host syncs ride the feeder's hot path --
    counters stay device scalars until collect time;
  * per-wave partials are produced at ``tau = 1`` -- a gram below tau in every
    wave can still be frequent globally, so nothing may be dropped early --
    and folded through the *segment merge* path (``index/merge.py``).  The
    default fold **defers**: wave segments stack and merge once, k-way, at
    the end (:class:`~repro.index.merge.DeferredSegmentAccumulator` -- one
    stable host sort over O(total) rows, with a skewed searchsorted-splice
    fast path when one segment dominates); ``accumulator="tiered"`` keeps
    the LSM rung stack of ``GenerationalIndex`` for bounded live memory,
    ``"pairwise"`` is the re-merge-every-wave baseline.  Every accumulator
    yields the same sorted segment, so the final output stays bit-identical
    to the monolithic job (canonical order; the global tau filter runs once
    at the end);
  * with a ``mesh``, every wave is **distributed and just as fused**: the
    wave's extended window shards contiguously over the mesh axis and the
    *entire round chain* -- one ppermute sigma-1 halo pull, then every
    round's emit -> combine -> hash-partitioned ``all_to_all`` shuffle ->
    sort -> reduce, with APRIORI carries kept shard-local and
    device-resident between rounds -- traces into ONE jitted ``shard_map``
    program per wave (``_build_mesh_wave_program``), cached per
    ``(n_local, capacity scale, skew?)``.  Reduced lanes fold **on device**
    into packed segment-candidate rows (``stages.segment_candidates`` -- the
    prefix-lane-mask collect of the single-device path), so the host never
    rebuilds dense ``NGramStats`` per round/shard; shuffle overflow
    accumulates as a device scalar and is checked ONCE per wave at collect
    (the rare trip reruns the whole wave at doubled capacity), which is what
    lets mesh waves ride the same double-buffered dispatch + overlapped fold
    thread as the single-device path.  Bit-identical to the monolithic job.

``run_streaming`` closes the loop with serving: each wave's partial goes
straight into :class:`~repro.index.merge.GenerationalIndex` ingest, so a
corpus that never fits on the device streams end to end into a queryable,
compacting index.

``run_plan`` is the one-wave degenerate case the ``repro.core`` methods now
delegate their single-device path to: whole corpus, legacy tau-per-round
semantics (APRIORI pruning at full strength), same counters as the old
monolithic code -- just one shared implementation of the stage plumbing.
"""
from __future__ import annotations

import threading
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import pack as packing
from repro.mapreduce import shuffle as mr_shuffle
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.pipeline import stages
from repro.pipeline.plan import JobPlan, plan_for

_SKEW_BUCKETS = 64   # nominal reducer count for the shuffle-skew counter

# jitted stage programs keyed by backend: buffer donation is decided per
# backend (a no-op with a warning on CPU), and the backend can change between
# calls (tests flip platforms, a driver may move from CPU warmup to TPU), so
# the decision must never be frozen at first call
_STAGE_CORE: dict[str, object] = {}

# fused whole-wave programs keyed by (backend, plan, cfg): every round's
# emit -> combine -> shuffle-key -> sort -> reduce plus the tau=1 carry
# updates traced into ONE jitted program, so a wave is a single dispatch.
# Both plan (frozen JobPlan of function refs) and cfg (frozen NGramConfig)
# hash by value, so distinct WaveExecutor instances over the same job share
# the compiled program (the benchmarks build a fresh executor per rep).
_WAVE_PROGRAMS: dict[tuple, object] = {}

# in-flight single-device waves beyond the one being folded: bounds the
# device/host footprint of the overlapped fold at O(wave * sigma) times a
# small constant while still keeping the device fed during host-side folds
_WAVES_IN_FLIGHT = 2


def reset_stage_cache() -> None:
    """Drop the jitted stage programs (tests / backend reconfiguration)."""
    _STAGE_CORE.clear()
    _WAVE_PROGRAMS.clear()


def _stage_core(records, valid, **kw):
    backend = jax.default_backend()
    fn = _STAGE_CORE.get(backend)
    if fn is None:
        # buffer donation is a no-op (with a warning) on CPU; donate only
        # where it helps
        donate = (0,) if backend != "cpu" else ()
        fn = partial(
            jax.jit, donate_argnums=donate,
            static_argnames=("n_lanes", "has_bucket", "combine_route",
                             "use_kernels", "sigma", "lane_vocab",
                             "shuffle_key", "reduce_kind", "with_positions",
                             "n_buckets"))(_stage_core_impl)
        _STAGE_CORE[backend] = fn
    return fn(records, valid, **kw)


def _stage_core_impl(records, valid, *, n_lanes: int, has_bucket: bool,
                     combine_route: str | None, use_kernels: bool, sigma: int,
                     lane_vocab: int, shuffle_key: str, reduce_kind: str,
                     with_positions: bool, n_buckets: int):
    """combine -> shuffle-key -> sort -> reduce over one wave's records.

    The single jitted program every wave reuses; ``records`` is donated, so
    the map buffer's memory is recycled for the sort.  ``valid`` is the map
    emit's live mask: its sum (the ``map_records`` counter) rides the program
    as a device scalar so callers never host-sync before dispatch.  Returns
    (dense reducer outputs, map-record count, post-combine live-record count,
    partition histogram over ``_SKEW_BUCKETS`` nominal reducers -- the
    realized shuffle skew, and the sorted records' packed key lanes -- the
    direct-segment collector's raw material); all five stay device-resident
    until the caller's materialize sync.
    """
    map_rec = jnp.sum(valid)
    if combine_route is not None:
        records = stages.combine(records, n_lanes, has_bucket,
                                 route=combine_route, use_kernels=use_kernels)
    live = records[:, n_lanes] > 0
    shuffled = jnp.sum(live)
    key = stages.partition_keys(records, n_lanes, kind=shuffle_key,
                                vocab_size=lane_vocab)
    # the real partitioner's bucketing (hash_u32 % P, invalid -> P), so the
    # skew counter measures realized reducer load, not raw-key spread
    bucket = mr_shuffle.partition_ids(key, live, _SKEW_BUCKETS)
    hist = jnp.bincount(bucket, length=_SKEW_BUCKETS + 1)[:_SKEW_BUCKETS]
    rec = stages.sort_stage(records, n_keys=n_lanes)
    if reduce_kind == "suffix":
        dense = stages.reduce_suffix(rec, sigma=sigma, vocab_size=lane_vocab,
                                     n_buckets=n_buckets,
                                     use_kernels=use_kernels)
    else:
        dense = stages.reduce_exact(rec, sigma=sigma, vocab_size=lane_vocab,
                                    with_positions=with_positions)
    return dense, map_rec, shuffled, hist, rec[:, :n_lanes]


def _build_wave_program(cfg, plan: JobPlan):
    """Trace one wave's FULL round chain into a single jitted program.

    Every round's map emit, the fused stage core, and the tau=1 carry update
    feeding the next round (``plan.py``'s traceability contract: under the
    wave regime carries are pure jnp functions of the emit-side evidence)
    compile into one donated XLA program -- a wave is one dispatch, not a
    per-stage (or per-round) chain of them.  ``n_live`` is a traced scalar so
    the partial final wave reuses the same executable, and position payloads
    are skipped (``with_positions=False``): only tau>1 carries consume them,
    which the wave regime never takes.
    """
    lane_vocab = plan.effective_lane_vocab(cfg)
    n_l = packing.n_lanes(cfg.sigma, lane_vocab)
    combine_route = plan.combine.route if plan.combine is not None else None

    def wave_fn(tok_ext, n_live):
        carry = None
        rounds = []
        for k in range(1, plan.rounds + 1):
            records, valid, emit_extras = plan.map.emit(
                tok_ext, None, n_live, cfg, carry, k)
            dense, map_rec, shuffled, hist, lanes = _stage_core_impl(
                records, valid, n_lanes=n_l, has_bucket=False,
                combine_route=combine_route, use_kernels=cfg.use_kernels,
                sigma=cfg.sigma, lane_vocab=lane_vocab,
                shuffle_key=plan.shuffle.key, reduce_kind=plan.reduce.kind,
                with_positions=False, n_buckets=0)
            rounds.append((dense[:3], map_rec, shuffled, hist, lanes))
            if k < plan.rounds and plan.update_carry is not None:
                carry = plan.update_carry(cfg, 1, k, tok_ext, None, {},
                                          emit_extras, carry)
        return tuple(rounds)

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(wave_fn, donate_argnums=donate)


def _wave_core(cfg, plan: JobPlan, tok_ext, n_live: int):
    """Dispatch one wave through the cached fused program (one dispatch)."""
    key = (jax.default_backend(), plan, cfg)
    fn = _WAVE_PROGRAMS.get(key)
    if fn is None:
        fn = _WAVE_PROGRAMS[key] = _build_wave_program(cfg, plan)
    return fn(tok_ext, n_live)


def _run_rounds(tok_ext, aux_ext, n_live: int, cfg, plan: JobPlan,
                tau_eff: int, counters: dict):
    """All of a plan's rounds over one token window -> merged ``NGramStats``.

    The *synchronous* interpreter ``run_plan`` uses: per-round host
    materialization (tau-filtered carries, ``stop_on_empty``), legacy
    monolithic counter semantics.  The wave hot path uses the async
    ``WaveExecutor._submit_wave`` / ``_collect_wave`` pair instead.
    """
    from repro.core.stats import NGramStats, add_counters

    lane_vocab = plan.effective_lane_vocab(cfg)
    n_l = packing.n_lanes(cfg.sigma, lane_vocab)
    has_bucket = aux_ext is not None
    n_meta = plan.map.n_meta + (1 if has_bucket else 0)
    rec_bytes = packing.record_bytes(cfg.sigma, lane_vocab, n_meta=n_meta)
    combine_route = plan.combine.route if plan.combine is not None else None

    out = None
    carry = None
    for k in range(1, plan.rounds + 1):
        with obs_trace.span("round.emit") as sp:
            if sp:
                sp.set(round=k)
            records, valid, emit_extras = plan.map.emit(
                tok_ext, aux_ext, n_live, cfg, carry, k)
        # combine -> shuffle-key -> sort -> reduce fuse into one jitted
        # program, so the stage granularity under this span is the dispatch;
        # the device time lands in the materialize span's sync below.  The
        # map-record counter rides the program as a device scalar (read at
        # the materialize sync below) -- summing ``valid`` here would force
        # a host round trip *before* the stage dispatch.
        with obs_trace.span("round.stages") as sp:
            if sp:
                sp.set(round=k)
            dense, map_rec, shuffled, hist, _lanes = _stage_core(
                records, valid, n_lanes=n_l, has_bucket=has_bucket,
                combine_route=combine_route, use_kernels=cfg.use_kernels,
                sigma=cfg.sigma, lane_vocab=lane_vocab,
                shuffle_key=plan.shuffle.key, reduce_kind=plan.reduce.kind,
                with_positions=plan.reduce.with_positions,
                n_buckets=cfg.n_buckets)
        with obs_trace.span("round.materialize") as sp:
            if sp:
                sp.set(round=k)
            terms, flags, counts = (np.asarray(x) for x in dense[:3])
            stats_k = NGramStats.from_dense(terms, flags, counts, tau_eff)
        reduce_extras = ({"totals_pos": dense[3]}
                         if plan.reduce.with_positions else {})
        map_rec = int(map_rec)
        shuffled = int(shuffled)
        hist = np.asarray(hist)
        add_counters(counters, jobs=1, map_records=map_rec,
                     shuffle_records=shuffled,
                     shuffle_bytes=shuffled * rec_bytes)
        if shuffled:
            skew = float(hist.max() * _SKEW_BUCKETS / max(hist.sum(), 1))
            counters["shuffle_skew"] = max(counters.get("shuffle_skew", 0.0),
                                           skew)
        out = stats_k if out is None else out.merged_with(stats_k)
        if plan.stop_on_empty and len(stats_k) == 0:
            break
        if k < plan.rounds and plan.update_carry is not None:
            carry = plan.update_carry(cfg, tau_eff, k, tok_ext, stats_k,
                                      reduce_extras, emit_extras, carry)
    out.counters = counters
    return out


def run_plan(tokens, cfg, bucket_ids=None, plan: JobPlan | None = None):
    """One-wave (whole-corpus) plan execution -- the single-device job.

    Semantics and counters match the old per-method monolithic code (tau and
    APRIORI pruning apply per round); output rows are in canonical segment
    order (``stages.canonical_stats``), which is what the wave executor is
    bit-compared against.
    """
    plan = plan or plan_for(cfg)
    with obs_trace.span("plan.run") as sp:
        if sp:
            sp.set(method=cfg.method, rounds=plan.rounds)
        tokens = jnp.asarray(tokens, jnp.int32)
        aux = None if bucket_ids is None else jnp.asarray(bucket_ids,
                                                          jnp.uint32)
        # the full canonical counter set (obs.metrics.COUNTER_DOC), so the
        # monolithic and wave paths expose identical keys with stable types
        counters = dict.fromkeys(
            ("jobs", "map_records", "shuffle_records", "shuffle_bytes",
             "retries", "overflow"), 0)
        counters["shuffle_skew"] = 0.0
        out = _run_rounds(tokens, aux, int(tokens.shape[0]), cfg, plan,
                          cfg.tau, counters)
        out.counters = obs_metrics.normalize_counters(out.counters)
        return stages.canonical_stats(out)


class DoubleBufferedDriver:
    """Overlap host-side work with device execution.

    ``submit`` dispatches batch i+1 (``answer`` must return its result
    *unmaterialized* -- device arrays or a record holding them) and only then
    materializes batch i's via ``collect`` -- jax's async dispatch runs the new
    batch while the host reads the old one, with no ``jax.block_until_ready``
    anywhere on the hot path.  ``submit`` returns (previous batch's collected
    result, its submit-time payload); ``drain`` flushes the last in-flight
    batch.

    Shared by the serving loop (``launch/serve_ngrams.py``, where it overlaps
    query batching with device lookups) and the wave engine's ingest loop
    (where it overlaps wave i+1's h2d/compute with wave i's host-side fold).
    """

    def __init__(self, answer, collect=None):
        self._answer = answer
        self._collect = collect
        self._pending = None

    def _materialize(self, out):
        if self._collect is not None:
            return self._collect(out)
        return np.asarray(out)

    def submit(self, *args, tag=None):
        out = self._answer(*args)
        prev, self._pending = self._pending, (out, tag)
        if prev is None:
            return None, None
        return self._materialize(prev[0]), prev[1]

    def drain(self):
        if self._pending is None:
            return None, None
        (out, tag), self._pending = self._pending, None
        return self._materialize(out), tag


def _merge_wave_counters(dst: dict, src: dict) -> None:
    """Fold one wave's counters into the run totals.

    Delegates to the one shared policy (``repro.obs.metrics``): sums, except
    the documented max-merged ratio keys (``shuffle_skew``).  The canonical
    counter set and its semantics live in ``obs.metrics.COUNTER_DOC``.
    """
    obs_metrics.merge_counter_dicts(dst, src)


class WavePartial:
    """One collected wave: its host-frozen sorted segment + job counters.

    The unit the fold consumes (accumulator push in :meth:`WaveExecutor.run`,
    generational ingest in :meth:`WaveExecutor.run_streaming`): ``segment``
    is an unpadded host-resident :class:`~repro.index.build.IndexSegment`
    holding the wave's exact tau=1 rows in (length | packed lanes) order,
    ``n_rows`` its real row count, ``counters`` the wave's MapReduce-style
    counter dict.
    """

    __slots__ = ("segment", "n_rows", "counters")

    def __init__(self, segment, n_rows: int, counters: dict):
        self.segment = segment
        self.n_rows = n_rows
        self.counters = counters


class WaveExecutor:
    """Run a :class:`JobPlan` over fixed-size token waves (out-of-core).

    ``wave_tokens`` bounds the device-resident working set; ``None`` (or a
    wave at least the corpus size) degenerates to one wave.  Waves execute at
    ``tau = 1`` and fold through ``index/merge.py`` segments under the
    ``accumulator`` policy (``"defer"`` = stack wave partials and fold once,
    k-way, at finalize -- O(total) merge rows, the default; ``"tiered"`` =
    size-tiered LSM rung stack, amortized O(total log waves) merge work with
    log-many live rungs; ``"pairwise"`` = the legacy
    fold-every-wave-into-one-segment baseline, O(waves x total));
    ``merge_route``: ``"kway"`` = galloping host merge of the presorted
    segments; ``"sort"`` = one fused re-sort per fold; ``"merge"`` =
    balanced-tree pairwise merge-path; ``"device"`` = the merge-path tree
    as an on-device k-way sort, with the host kway fold as automatic
    fallback for oversized tau=1 gram sets
    (``index.merge.DEVICE_MERGE_MAX_ROWS``).  :meth:`run` applies the
    global tau
    once at the end, so for any wave size (and any accumulator/route) the
    output is bit-identical to the monolithic job.

    With a ``mesh`` (size > 1), each wave runs as ONE fused ``shard_map``
    dispatch over ``axis_name``: contiguous token slices per shard, the
    distributed jobs' own ppermute sigma-1 halo between neighbors (pulled
    once per wave), every round's hash-partitioned ``all_to_all`` shuffle
    with a single collect-time counted-overflow capacity retry, and the
    device-side segment-candidate collect.  Mesh waves ride the same
    double-buffered dispatch + overlapped fold thread as single-device
    waves and fold through the same segment path, so the distributed run
    stays bit-identical to the single-device one.

    Memory model: device footprint is O(wave * sigma) records per stage (per
    shard when distributed); the running segments live wherever
    ``index/merge.py`` keeps them and together hold the *exact* (tau=1) gram
    set seen so far -- the unavoidable state of any exact out-of-core
    counter.  Restrictions: bucketed time series (``n_buckets``) need
    cross-wave bucket columns the segment fold does not carry, so waves
    require ``n_buckets == 0``.
    """

    def __init__(self, cfg, *, wave_tokens: int | None = None,
                 plan: JobPlan | None = None, merge_route: str = "kway",
                 accumulator: str = "defer", mesh=None,
                 axis_name: str = "data", overlap: bool = True):
        if wave_tokens is not None and wave_tokens < 1:
            raise ValueError("wave_tokens must be >= 1")
        if cfg.n_buckets:
            raise ValueError("wave execution does not support n_buckets "
                             "(bucketed series need the bucket-carrying "
                             "single job -- run_job / run_plan)")
        if accumulator not in ("defer", "tiered", "pairwise"):
            raise ValueError(f"unknown accumulator {accumulator!r} "
                             "(options: 'defer', 'tiered', 'pairwise')")
        self.cfg = cfg
        self.wave_tokens = wave_tokens
        self.plan = plan or plan_for(cfg)
        self.merge_route = merge_route
        self.accumulator = accumulator
        self.mesh = mesh
        self.axis_name = axis_name
        # overlap: run the per-wave fold (collect + accumulator merge /
        # generational ingest) on a background thread so it overlaps the next
        # wave's device work; False serializes fold and dispatch on the main
        # thread (debugging / environments where threads are unwelcome)
        self.overlap = overlap
        self._mesh_programs: dict = {}   # (n_local, capacity scale, skew?)
        # overflow-retry capacity scale: doubles on the rare overflowed wave
        # and sticks, so later waves dispatch at the proven capacity
        self._mesh_scale = 1
        # XLA's host-device collective rendezvous is not ordered across
        # concurrently launched executions: two in-flight mesh-wave programs
        # can interleave their ppermute/all_to_all participants across device
        # threads and stall (observed as multi-second rendezvous hangs).
        # Every mesh program launch therefore waits for the previous launch
        # to finish executing, under this lock (the fold thread's retry
        # launches race the feeder's next-wave dispatch without it).  Host
        # fold work still overlaps the next wave's device execution.
        self._mesh_launch_lock = threading.Lock()
        self._mesh_last_launch = None
        self._emit_rows_cache: dict = {}
        # direct-segment collect is valid iff the record lanes' packed layout
        # is the segment layout -- i.e. the plan packs with cfg.vocab_size
        # (pack ablations / pack_vocab overrides take the stats route)
        self._direct = (self.plan.effective_lane_vocab(cfg) == cfg.vocab_size)
        self._masks = None               # prefix_lane_masks, built lazily

    # --- wave iteration ------------------------------------------------------ #

    def _windows(self, tokens: np.ndarray, *, to_device: bool = True):
        """Yield (tok_ext [wave + sigma - 1], n_live) fixed-shape windows.

        ``n_live`` is the *true* number of corpus tokens in the wave -- the
        final wave of a corpus that is not a multiple of ``wave_tokens`` gets
        a partial count, so the emit's live mask (positions ``< n_live``)
        excludes the zero-padded tail outright instead of leaning on the
        reserved-PAD convention (``NGramConfig.validate_tokens``) to mask
        phantom tail grams.  ``to_device=False`` yields host slices (the
        mesh path re-pads to the shard layout before its own h2d).
        """
        n = int(tokens.shape[0])
        wave = self.wave_tokens if self.wave_tokens is not None else n
        wave = max(1, min(wave, n) if n else 1)
        n_waves = max(1, -(-n // wave))
        halo = self.cfg.sigma - 1
        with obs_trace.span("wave.window.pad") as sp:
            if sp:
                sp.set(n_waves=n_waves, wave_tokens=wave)
            padded = np.zeros((n_waves * wave + halo,), np.int32)
            padded[:n] = np.asarray(tokens, np.int32)
        for w in range(n_waves):
            n_live = max(0, min(wave, n - w * wave))
            tok_ext = padded[w * wave: (w + 1) * wave + halo]
            if to_device:
                with obs_trace.span("wave.window.h2d") as sp:
                    if sp:
                        sp.set(wave=w)
                    tok_ext = jnp.asarray(tok_ext)
            yield tok_ext, n_live

    @property
    def _use_mesh(self) -> bool:
        return self.mesh is not None and self.mesh.size > 1

    # --- single-device async wave dispatch ----------------------------------- #

    def _submit_wave(self, tok_ext, n_live: int) -> dict:
        """Dispatch one wave as ONE fused program; nothing materializes here.

        The wave regime always runs at ``tau_eff = 1``, where carries are a
        pure traceable function of the emit-side evidence (the contract
        ``plan.py`` documents), so the *entire* round chain -- emits, stage
        pipelines, carry updates, counters -- traces into a single jitted
        donated program (``_wave_core``) and stays in flight until
        :meth:`_collect_wave`.  ``stop_on_empty`` is skipped: an exhausted
        round chain emits empty partials that fold to nothing.  With a mesh,
        the wave dispatches through the fused sharded program instead
        (:meth:`_submit_wave_mesh`) -- same async contract.
        """
        if self._use_mesh:
            return self._submit_wave_mesh(tok_ext, n_live)
        cfg, plan = self.cfg, self.plan
        with obs_trace.span("wave.submit") as sp:
            if sp:
                sp.set(n_live=n_live, rounds=plan.rounds)
            # one span == one dispatch: the fused-wave regression tests count
            # exactly one round.stages span per wave, any number of rounds
            with obs_trace.span("round.stages") as sp_s:
                if sp_s:
                    sp_s.set(fused_rounds=plan.rounds)
                rounds = _wave_core(cfg, plan, tok_ext, n_live)
            rec_bytes = packing.record_bytes(
                cfg.sigma, plan.effective_lane_vocab(cfg),
                n_meta=plan.map.n_meta)
            return {"rounds": list(rounds), "rec_bytes": rec_bytes}

    def _collect_wave(self, pend: dict):
        """Materialize a submitted wave -> exact ``NGramStats`` partial.

        The ``np.asarray`` materializations here are the wave's one device
        sync: the collect span's duration is host-visible device+transfer
        time (the double-buffer's occupancy signal -- a collect much shorter
        than its submit-to-submit gap means the device was idle).
        """
        if pend.get("mesh"):
            return self._collect_wave_mesh(pend)
        from repro.core.stats import NGramStats, add_counters

        with obs_trace.span("wave.collect") as sp:
            counters: dict = {}
            out = None
            for dense, map_rec, shuffled, hist, _lanes in pend["rounds"]:
                terms, flags, counts = (np.asarray(x) for x in dense)
                stats_k = NGramStats.from_dense(terms, flags, counts, 1)
                shuffled = int(shuffled)
                hist = np.asarray(hist)
                add_counters(counters, jobs=1, map_records=int(map_rec),
                             shuffle_records=shuffled,
                             shuffle_bytes=shuffled * pend["rec_bytes"])
                if shuffled:
                    skew = float(hist.max() * _SKEW_BUCKETS
                                 / max(hist.sum(), 1))
                    counters["shuffle_skew"] = max(
                        counters.get("shuffle_skew", 0.0), skew)
                out = stats_k if out is None else out.merged_with(stats_k)
            out.counters = counters
            if sp:
                sp.set(rows=len(out), shuffle_records=counters.get(
                    "shuffle_records", 0))
            return out

    def _prefix_masks(self) -> np.ndarray:
        masks = self._masks
        if masks is None:
            masks = self._masks = packing.prefix_lane_masks(
                self.cfg.sigma, self.cfg.vocab_size)
        return masks

    def _partial_from_stats(self, wave_stats) -> WavePartial:
        """Freeze an ``NGramStats`` wave partial (mesh / stats-route waves)."""
        from repro.index.build import segment_from_wave_stats
        seg = segment_from_wave_stats(wave_stats,
                                      vocab_size=self.cfg.vocab_size)
        return WavePartial(seg, len(wave_stats), wave_stats.counters)

    def _collect_wave_segment(self, pend: dict) -> WavePartial:
        """Materialize a submitted wave straight into a sorted host segment.

        The fold-path twin of :meth:`_collect_wave` that never leaves packed
        space: the reducer already walked the *sorted* record block, so its
        key lanes ARE the packed gram lanes in lex order, and a kept row of
        length ``l`` has segment key ``(l | lanes & prefix_mask[l])``
        (zeroing a term slot's bits == packing PAD there).  Rows come out of
        ``nonzero(keep.T)`` in (length, lane-rank) order -- segment order --
        so the closing stable byte-view argsort is a linear verification
        pass for single-round plans and a galloping merge of the per-round
        sorted runs otherwise.  Skips the stats detour entirely: no term
        unpack, no gram re-pack, no ``terms`` d2h.  Bit-identical to
        ``segment_from_wave_stats(_collect_wave(pend))`` because both
        reduce to the same (key, count) row set in the same canonical
        order; requires the lane/segment pack layouts to coincide
        (``self._direct``) -- other configs take exactly that stats route.
        """
        if pend.get("mesh"):
            return self._collect_wave_segment_mesh(pend)
        if not self._direct:
            return self._partial_from_stats(self._collect_wave(pend))
        from repro.core.stats import add_counters
        from repro.index._layout import row_bytes_view
        from repro.index.build import IndexSegment

        cfg = self.cfg
        with obs_trace.span("wave.collect") as sp:
            counters: dict = {}
            masks = self._prefix_masks()
            key_parts, cnt_parts = [], []
            for dense, map_rec, shuffled, hist, lanes in pend["rounds"]:
                flags = np.asarray(dense[1])
                counts = np.asarray(dense[2])
                lanes = np.asarray(lanes)
                shuffled = int(shuffled)
                hist = np.asarray(hist)
                add_counters(counters, jobs=1, map_records=int(map_rec),
                             shuffle_records=shuffled,
                             shuffle_bytes=shuffled * pend["rec_bytes"])
                if shuffled:
                    skew = float(hist.max() * _SKEW_BUCKETS
                                 / max(hist.sum(), 1))
                    counters["shuffle_skew"] = max(
                        counters.get("shuffle_skew", 0.0), skew)
                # from_dense's keep at the wave regime's tau = 1
                keep = (flags != 0) & (counts >= 1)
                lens0, rows = np.nonzero(keep.T)
                lengths = (lens0 + 1).astype(np.uint32)
                pref = lanes[rows] & masks[lengths]
                key_parts.append(np.concatenate(
                    [lengths[:, None], pref], axis=1).astype(np.uint32))
                cnt_parts.append(counts[rows, lens0].astype(np.uint32))
            keys = np.concatenate(key_parts, axis=0)
            cnts = np.concatenate(cnt_parts, axis=0)
            order = np.argsort(row_bytes_view(keys), kind="stable")
            seg = IndexSegment(keys=keys[order], counts=cnts[order],
                               sigma=cfg.sigma, vocab_size=cfg.vocab_size)
            if sp:
                sp.set(rows=int(keys.shape[0]), shuffle_records=counters.get(
                    "shuffle_records", 0))
            return WavePartial(seg, int(keys.shape[0]), counters)

    # --- distributed (mesh) wave dispatch ------------------------------------ #

    def _emit_rows(self, win_len: int, k: int) -> int:
        """Map-emit record rows for a ``win_len``-token window (shape probe)."""
        key = (win_len, k)
        rows = self._emit_rows_cache.get(key)
        if rows is None:
            shape = jax.eval_shape(
                lambda t: self.plan.map.emit(t, None, 0, self.cfg, None, k)[0],
                jax.ShapeDtypeStruct((win_len,), jnp.int32))
            rows = self._emit_rows_cache[key] = int(shape.shape[0])
        return rows

    def _mesh_wave_program(self, n_local: int, scale: int, with_skew: bool):
        key = (n_local, scale, with_skew)
        fn = self._mesh_programs.get(key)
        if fn is None:
            fn = self._mesh_programs[key] = self._build_mesh_wave_program(
                n_local, scale, with_skew)
        return fn

    def _build_mesh_wave_program(self, n_local: int, scale: int,
                                 with_skew: bool):
        """Trace one mesh wave's FULL round chain into ONE shard_map program.

        The distributed twin of ``_build_wave_program``: each shard owns a
        contiguous ``n_local``-token slice of the wave's extended window,
        pulls its sigma-1 halo from the right neighbor via ppermute ONCE per
        wave (the last shard's halo is zeros -- the window already ends in
        the wave-level halo, and nothing live reads past it), then every
        round's emit -> combine -> hash-partitioned ``all_to_all`` shuffle ->
        sort -> reduce -> segment-candidate collect, plus the tau=1 carry
        updates feeding the next round, trace into a single jitted
        ``shard_map`` dispatch.  Carries never cross the program boundary:
        at ``tau_eff = 1`` a carry is a pure function of the shard's own
        extended window (see ``plan.py``), so they stay shard-local,
        device-resident, and reset per wave.

        Per-round shuffle capacities are static (the emit-shape probe times
        ``capacity_factor``), multiplied by the wave-level ``scale`` the
        overflow retry doubles.  Overflow is NOT host-synced per round: each
        round's local overflow count accumulates and rides the one psum'd
        counter block ``cnt [rounds, 3] = (map_records, shuffle_records,
        overflow)``, checked once per wave at collect time.  The skew
        histogram (a second psum) is only traced when ``with_skew`` -- the
        fused program skips that collective + device work entirely when
        observability is off.

        Outputs stay sharded (leading mesh axis): per round either the flat
        packed ``(keys [P*C, 1+n_l], counts [P*C])`` candidate table
        (``self._direct`` -- the host's whole fold is concat + one stable
        byte-view sort) or the dense ``(terms, flags, counts)`` triple
        ``[P, ...]`` for the stats fallback route.
        """
        from jax.sharding import PartitionSpec as P

        cfg, plan = self.cfg, self.plan
        mesh, axis_name = self.mesh, self.axis_name
        n_parts = mesh.shape[axis_name]
        lane_vocab = plan.effective_lane_vocab(cfg)
        n_l = packing.n_lanes(cfg.sigma, lane_vocab)
        halo = cfg.sigma - 1
        direct = self._direct
        combine_route = plan.combine.route if plan.combine is not None else None
        caps = {k: scale * max(8, int(cfg.capacity_factor
                                      * self._emit_rows(n_local + halo, k)
                                      / n_parts) + 1)
                for k in range(1, plan.rounds + 1)}
        masks = jnp.asarray(self._prefix_masks()) if direct else None

        def job(tok, n_live):
            tok = tok[0]                                     # [n_local]
            if halo:
                perm = [(i, (i - 1) % n_parts) for i in range(n_parts)]
                h = jax.lax.ppermute(tok[:halo], axis_name, perm)
                is_last = jax.lax.axis_index(axis_name) == n_parts - 1
                h = jnp.where(is_last, jnp.zeros_like(h), h)
                tok_ext = jnp.concatenate([tok, h])
            else:
                tok_ext = tok
            shard = jax.lax.axis_index(axis_name)
            n_live_local = jnp.clip(n_live - shard * n_local, 0, n_local)
            carry = None
            rounds_out = []
            cnt_rows = []
            hists = []
            for k in range(1, plan.rounds + 1):
                records, valid, emit_extras = plan.map.emit(
                    tok_ext, None, n_live_local, cfg, carry, k)
                map_rec = jnp.sum(valid.astype(jnp.int32))
                if combine_route is not None:
                    records = stages.combine(records, n_l, False,
                                             route=combine_route,
                                             use_kernels=cfg.use_kernels)
                live = records[:, n_l] > 0
                key = stages.partition_keys(records, n_l,
                                            kind=plan.shuffle.key,
                                            vocab_size=lane_vocab)
                if with_skew:
                    skew = mr_shuffle.partition_ids(key, live, _SKEW_BUCKETS)
                    hists.append(jnp.bincount(
                        skew, length=_SKEW_BUCKETS + 1)[:_SKEW_BUCKETS])
                local, overflow = mr_shuffle.shuffle(
                    records, key, live, axis_name=axis_name, n_parts=n_parts,
                    capacity=caps[k], reduce_overflow=False)
                shuf = jnp.sum(local[:, n_l] > 0)
                cnt_rows.append(jnp.stack([map_rec, shuf,
                                           overflow.astype(jnp.int32)]))
                rec = stages.sort_stage(local, n_keys=n_l)
                if plan.reduce.kind == "suffix":
                    terms, flags, counts = stages.reduce_suffix(
                        rec, sigma=cfg.sigma, vocab_size=lane_vocab,
                        n_buckets=0, use_kernels=cfg.use_kernels)
                else:
                    # position payloads are only consumed by tau>1 carries,
                    # which the wave regime never takes -- skip the scatter
                    terms, flags, counts = stages.reduce_exact(
                        rec, sigma=cfg.sigma, vocab_size=lane_vocab,
                        with_positions=False)
                if direct:
                    rounds_out.append(stages.segment_candidates(
                        flags, counts, rec[:, :n_l], masks, sigma=cfg.sigma,
                        reduce_kind=plan.reduce.kind))
                else:
                    rounds_out.append((terms[None], flags[None],
                                       counts[None]))
                if k < plan.rounds and plan.update_carry is not None:
                    carry = plan.update_carry(cfg, 1, k, tok_ext, None, {},
                                              emit_extras, carry)
            # ONE collective for every per-round counter (plus one for the
            # skew histogram when observability asks for it)
            cnt = jax.lax.psum(jnp.stack(cnt_rows), axis_name)  # [rounds, 3]
            outs = [tuple(rounds_out), cnt[None]]
            if with_skew:
                outs.append(jax.lax.psum(jnp.stack(hists), axis_name)[None])
            return tuple(outs)

        per_round = (P(axis_name), P(axis_name)) if direct \
            else (P(axis_name),) * 3
        out_specs = [tuple(per_round for _ in range(plan.rounds)),
                     P(axis_name)]
        if with_skew:
            out_specs.append(P(axis_name))
        return jax.jit(jax.shard_map(
            job, mesh=mesh, in_specs=(P(axis_name, None), P()),
            out_specs=tuple(out_specs), check_vma=False))

    def _submit_wave_mesh(self, tok_host: np.ndarray, n_live: int) -> dict:
        """Dispatch one mesh wave as ONE sharded program; nothing syncs here.

        ``tok_host`` stays a host array until the padded [n_parts, n_local]
        shard layout is built (no d2h round trip through a device window).
        The retry state the collect side needs -- the padded tokens, the
        dispatch-time capacity scale, the skew flag -- rides the pend dict.
        """
        cfg, plan = self.cfg, self.plan
        n_parts = self.mesh.shape[self.axis_name]
        win_len = int(tok_host.shape[0])
        # the one-hop ppermute halo pulls sigma-1 tokens from the right
        # neighbor, so a shard's slice must be at least that long -- tiny
        # waves leave trailing shards all-pad (no live positions)
        n_local = max(-(-win_len // n_parts), cfg.sigma - 1, 1)
        tok_p = np.zeros((n_parts * n_local,), np.int32)
        tok_p[:win_len] = tok_host
        tok_p = tok_p.reshape(n_parts, n_local)
        with_skew = bool(obs_metrics.get_registry())
        scale = self._mesh_scale
        with obs_trace.span("wave.mesh.dispatch") as sp:
            if sp:
                sp.set(n_live=n_live, rounds=plan.rounds, n_local=n_local,
                       scale=scale)
            outs = self._launch_mesh_wave(n_local, scale, with_skew, tok_p,
                                          n_live)
        rec_bytes = packing.record_bytes(
            cfg.sigma, plan.effective_lane_vocab(cfg), n_meta=plan.map.n_meta)
        return {"mesh": True, "outs": outs, "tok_p": tok_p, "n_live": n_live,
                "n_local": n_local, "scale": scale, "with_skew": with_skew,
                "rec_bytes": rec_bytes}

    def _launch_mesh_wave(self, n_local: int, scale: int, with_skew: bool,
                          tok_p: np.ndarray, n_live: int):
        """Launch one fused mesh-wave program, serialized against the last.

        Collective programs launched while another is still executing can
        interleave their rendezvous participants across device threads on the
        host backend and stall for seconds (two in-flight waves = two run
        ids racing the same ppermute).  Launches therefore wait for the
        previous program to finish first; the lock covers the feeder thread
        vs the fold thread's overflow-retry launches.  Only device *launch*
        is serialized -- the host-side fold still overlaps the next wave's
        execution, which is where the 1-core overlap win actually is.
        """
        with self._mesh_launch_lock:
            if self._mesh_last_launch is not None:
                jax.block_until_ready(self._mesh_last_launch)
            fn = self._mesh_wave_program(n_local, scale, with_skew)
            outs = fn(jnp.asarray(tok_p), jnp.int32(n_live))
            self._mesh_last_launch = outs[1]
            return outs

    def _collect_wave_mesh_outs(self, pend: dict):
        """The wave's ONE host sync: read counters, retry on overflow.

        Materializing the psum'd ``cnt [rounds, 3]`` block is the only
        per-wave device round trip.  If any round overflowed its shuffle
        capacity, the WHOLE wave reruns at doubled capacity scale -- correct
        because carries are internal to the program (a rerun re-derives them
        from the same tokens) and cheap because overflow is rare and sticky:
        the doubled scale persists in ``self._mesh_scale``, so subsequent
        waves dispatch at the proven capacity and never trip again.  An
        overflowed attempt's counters never land (a rerun re-emits the same
        records; folding both would double-count) -- only the successful
        attempt's ``cnt``/hist do, while reruns stay visible via ``retries``.
        """
        outs = pend["outs"]
        retries = 0
        while True:
            cnt = np.asarray(outs[1])[0]                     # [rounds, 3]
            if int(cnt[:, 2].sum()) == 0:
                return outs, cnt, retries
            if retries >= 5:
                raise RuntimeError(
                    "wave shuffle overflow persisted at capacity scale "
                    f"{pend['scale']}")
            retries += 1
            pend["scale"] *= 2
            self._mesh_scale = max(self._mesh_scale, pend["scale"])
            with obs_trace.span("wave.mesh.retry") as sp:
                if sp:
                    sp.set(retry=retries, scale=pend["scale"])
                outs = self._launch_mesh_wave(pend["n_local"], pend["scale"],
                                              pend["with_skew"],
                                              pend["tok_p"], pend["n_live"])

    def _mesh_counters(self, cnt: np.ndarray, outs, pend: dict,
                       retries: int) -> dict:
        """Wave counters from the successful attempt's psum'd ``cnt`` block."""
        from repro.core.stats import add_counters

        counters: dict = {}
        if retries:   # capacity-doubling reruns, visible like the jobs'
            add_counters(counters, retries=retries)
        hist = np.asarray(outs[2])[0] if pend["with_skew"] else None
        for k in range(cnt.shape[0]):
            shuf = int(cnt[k, 1])
            add_counters(counters, jobs=1, map_records=int(cnt[k, 0]),
                         shuffle_records=shuf,
                         shuffle_bytes=shuf * pend["rec_bytes"])
            if hist is not None and shuf:
                skew = float(hist[k].max() * _SKEW_BUCKETS
                             / max(hist[k].sum(), 1))
                counters["shuffle_skew"] = max(
                    counters.get("shuffle_skew", 0.0), skew)
        return counters

    def _mesh_wave_stats(self, rounds_out, counters: dict):
        """Stats-route fallback fold (``pack_vocab`` overrides): from_dense
        per shard per round, merged on host -- only configs whose lane
        layout is not the segment layout pay this."""
        from repro.core.stats import NGramStats

        out = None
        for terms, flags, counts in rounds_out:
            terms, flags, counts = (np.asarray(terms), np.asarray(flags),
                                    np.asarray(counts))
            for p in range(terms.shape[0]):
                part = NGramStats.from_dense(terms[p], flags[p], counts[p], 1)
                out = part if out is None else out.merged_with(part)
        out.counters = counters
        return out

    def _collect_wave_segment_mesh(self, pend: dict) -> WavePartial:
        """Materialize a mesh wave straight into a sorted host segment.

        The sharded twin of :meth:`_collect_wave_segment`: the fused program
        already collected packed segment-candidate rows on device
        (``stages.segment_candidates``), so the host fold is concat over
        (shard, round) tables + drop dead rows + ONE stable byte-view sort.
        Within a wave every kept gram key is unique across shards (the
        shuffle routes all evidence of a gram to one reducer shard) and
        across rounds (rounds emit disjoint lengths), so the sorted row set
        -- and with it the bit-identity contract -- is independent of
        shard/round concat order.
        """
        from repro.index._layout import row_bytes_view
        from repro.index.build import IndexSegment

        with obs_trace.span("wave.mesh.collect") as sp:
            outs, cnt, retries = self._collect_wave_mesh_outs(pend)
            counters = self._mesh_counters(cnt, outs, pend, retries)
            if not self._direct:
                return self._partial_from_stats(
                    self._mesh_wave_stats(outs[0], counters))
            keys = np.concatenate([np.asarray(k) for k, _ in outs[0]], axis=0)
            cnts = np.concatenate([np.asarray(c) for _, c in outs[0]], axis=0)
            live = cnts > 0
            keys, cnts = keys[live], cnts[live]
            order = np.argsort(row_bytes_view(keys), kind="stable")
            seg = IndexSegment(keys=keys[order], counts=cnts[order],
                               sigma=self.cfg.sigma,
                               vocab_size=self.cfg.vocab_size)
            if sp:
                sp.set(rows=int(keys.shape[0]), retries=retries,
                       shuffle_records=counters.get("shuffle_records", 0))
            return WavePartial(seg, int(keys.shape[0]), counters)

    def _collect_wave_mesh(self, pend: dict):
        """Mesh collect -> ``NGramStats`` (the ``iter_wave_stats`` shape)."""
        from repro.index.merge import segment_to_stats

        part = self._collect_wave_segment_mesh(pend)
        out = segment_to_stats(part.segment)
        out.counters = dict(part.counters)
        return out

    # --- public iteration ----------------------------------------------------- #

    def iter_wave_stats(self, tokens):
        """Per-wave exact partials (``tau = 1``) -- the streaming delta feed.

        Waves are double-buffered: wave ``i + 1`` is dispatched before wave
        ``i`` is materialized, so the consumer's host-side work (segment
        folds, generational ingest) overlaps device execution.  Mesh waves
        take the same path -- the fused sharded program defers its overflow
        check to collect time, so dispatch never waits on a host sync.
        """
        tokens = np.asarray(tokens, np.int32)
        self.cfg.validate_tokens(tokens)
        drv = DoubleBufferedDriver(self._submit_wave,
                                   collect=self._collect_wave)
        for tok_ext, n_live in self._windows(tokens,
                                             to_device=not self._use_mesh):
            res, _ = drv.submit(tok_ext, n_live)
            if res is not None:
                yield res
        res, _ = drv.drain()
        if res is not None:
            yield res

    def _for_each_wave(self, tokens, consume, *, collect=None) -> None:
        """Run ``consume(collected wave)`` for every wave, in wave order.

        ``collect`` maps a submitted wave to the object ``consume`` sees
        (default :meth:`_collect_wave` -> ``NGramStats``; the fold paths
        pass :meth:`_collect_wave_segment` -> :class:`WavePartial`); both
        route mesh waves to their sharded twins via the pend dict.

        The wave-level parallel fold: the main thread stays a pure *feeder*
        -- it slices host token slabs and dispatches one fused program per
        wave (single-device or sharded) -- while a background fold thread
        materializes each wave and runs ``consume`` (the accumulator merge
        of :meth:`run`, the generational ingest of :meth:`run_streaming`).
        Host-side fold work therefore overlaps the next waves' device work
        instead of serializing with it; a bounded queue
        (``_WAVES_IN_FLIGHT``) backpressures the feeder so at most a small
        constant number of waves is ever in flight, preserving the
        O(wave * sigma) memory model.  The single FIFO fold thread keeps
        wave order, so the fold sequence -- and with it the bit-identity
        contract -- is exactly the serial path's.  Mesh overflow reruns
        happen on the fold thread too (collect-time), so even a retried
        wave never stalls the feeder.  ``overlap=False`` serializes.
        """
        collect = collect or self._collect_wave
        tokens = np.asarray(tokens, np.int32)
        self.cfg.validate_tokens(tokens)
        to_device = not self._use_mesh
        if not self.overlap:
            for tok_ext, n_live in self._windows(tokens,
                                                 to_device=to_device):
                consume(collect(self._submit_wave(tok_ext, n_live)))
            return
        import queue
        import threading

        work: queue.Queue = queue.Queue(maxsize=_WAVES_IN_FLIGHT)
        failure: list[BaseException] = []

        def fold_loop():
            while True:
                pend = work.get()
                try:
                    if pend is None:
                        return
                    if not failure:
                        consume(collect(pend))
                except BaseException as e:      # propagate to the feeder
                    failure.append(e)
                finally:
                    work.task_done()

        folder = threading.Thread(target=fold_loop, name="wave-fold",
                                  daemon=True)
        folder.start()
        try:
            for tok_ext, n_live in self._windows(tokens,
                                                 to_device=to_device):
                if failure:
                    break
                work.put(self._submit_wave(tok_ext, n_live))
        finally:
            work.put(None)
            folder.join()
        if failure:
            raise failure[0]

    # --- whole-job execution ------------------------------------------------- #

    def run(self, tokens):
        """Execute the job over waves -> ``NGramStats`` (canonical order),
        bit-identical to the monolithic single-job run.  ``fold_rows`` in the
        counters is the total segment rows fed through ``merge_segments`` --
        the accumulator's measured merge work."""
        from repro.core.stats import NGramStats
        from repro.index.merge import (DeferredSegmentAccumulator,
                                       PairwiseSegmentAccumulator,
                                       TieredSegmentAccumulator,
                                       segment_to_stats)

        with obs_trace.span("wave.run") as root:
            tokens = np.asarray(tokens, np.int32)
            if root:
                root.set(n_tokens=int(tokens.shape[0]),
                         method=self.cfg.method,
                         accumulator=self.accumulator)
            # full canonical counter set (obs.metrics.COUNTER_DOC): identical
            # keys to the monolithic run_plan, plus the wave-only
            # waves/fold_rows
            counters = dict.fromkeys(
                ("jobs", "map_records", "shuffle_records", "shuffle_bytes",
                 "retries", "overflow", "waves", "fold_rows"), 0)
            counters["shuffle_skew"] = 0.0
            acc_cls = {"defer": DeferredSegmentAccumulator,
                       "tiered": TieredSegmentAccumulator,
                       "pairwise": PairwiseSegmentAccumulator}[self.accumulator]
            acc = acc_cls(route=self.merge_route,
                          use_kernels=self.cfg.use_kernels)

            def fold(part: WavePartial):
                # runs on the fold thread: overlaps the next wave's dispatch
                counters["waves"] += 1
                _merge_wave_counters(counters, part.counters)
                with obs_trace.span("wave.fold") as sp:
                    if sp:
                        sp.set(wave=counters["waves"] - 1, rows=part.n_rows)
                    acc.push(part.segment, n_rows=part.n_rows)

            self._for_each_wave(tokens, fold,
                                collect=self._collect_wave_segment)
            with obs_trace.span("wave.finalize") as sp:
                # tau filters inside segment_to_stats, *before* the term
                # unpack, so only the monolithic-sized survivor set pays it
                out = segment_to_stats(acc.result(), min_count=self.cfg.tau)
                counters["fold_rows"] = acc.fold_rows
                out = NGramStats(out.grams, out.lengths, out.counts,
                                 obs_metrics.normalize_counters(counters))
                if sp:
                    sp.set(rows=len(out), fold_rows=acc.fold_rows)
            return out

    def run_streaming(self, tokens, *, gen=None, compress: bool = False,
                      block_size: int = 4, **gen_kw):
        """Stream waves straight into a :class:`GenerationalIndex`.

        Each wave's exact partial (``tau = 1``; nothing may be dropped early)
        is frozen and ingested as a fresh L0 segment -- point/top-k answers
        over the resulting index match a from-scratch build over the full
        corpus at ``tau = 1`` exactly, while the device only ever holds one
        wave of job state plus the serving artifacts.  The generational
        ingest (freeze + compaction) runs on the overlapped fold thread, so
        it proceeds while the device already works on the next waves.
        Returns ``(index, reports)`` with one ingest report per wave.
        """
        from repro.index.merge import GenerationalIndex
        if gen is None:
            gen = GenerationalIndex(sigma=self.cfg.sigma,
                                    vocab_size=self.cfg.vocab_size,
                                    compress=compress, block_size=block_size,
                                    use_kernels=self.cfg.use_kernels, **gen_kw)
        reports = []

        def ingest(part: WavePartial):
            # hand the bare collected segment to the LSM (an empty wave
            # ingests no segment); the query artifact materializes lazily
            # on first read
            reports.append(gen.ingest_segment(
                part.segment if part.n_rows else None, n_rows=part.n_rows))

        self._for_each_wave(tokens, ingest,
                            collect=self._collect_wave_segment)
        return gen, reports
