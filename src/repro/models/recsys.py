"""The four assigned recsys architectures on a shared embedding substrate.

EmbeddingBag is built from ``jnp.take`` + ``segment_sum`` (JAX has no native one) --
the same gather + segment-reduce primitive as the n-gram reducer.  Tables are
row-sharded over the `model` mesh axis (vocab sharding); GSPMD turns the gather into
a collective lookup.

  bst        : Behavior Sequence Transformer (arXiv:1905.06874)
  autoint    : self-attention feature interaction (arXiv:1810.11921)
  two-tower  : sampled-softmax retrieval (YouTube, RecSys'19)
  xdeepfm    : Compressed Interaction Network + DNN (arXiv:1803.05170)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .layers import rms_norm


# ----------------------------------------------------------------- substrate
def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """[V, D] table, integer ids [...]; out [..., D]."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array, segment_ids: jax.Array,
                  num_segments: int, mode: str = "sum") -> jax.Array:
    """Multi-hot bag reduce: gather rows then segment-reduce (no nn.EmbeddingBag in
    JAX -- this IS the implementation)."""
    rows = jnp.take(table, ids, axis=0)
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
        c = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), segment_ids,
                                num_segments=num_segments)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_segments)
    raise ValueError(mode)


def mlp(x, layers, act=jax.nn.relu, final_act=False):
    for i, (w, b) in enumerate(layers):
        x = jnp.einsum("...d,dh->...h", x, w) + b
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def init_mlp(key, dims, dtype):
    keys = jax.random.split(key, len(dims) - 1)
    return [(jax.random.normal(k, (dims[i], dims[i + 1]), dtype) * dims[i] ** -0.5,
             jnp.zeros((dims[i + 1],), dtype))
            for i, k in enumerate(keys)]


def bce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ------------------------------------------------------------------------ BST
@dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    item_vocab: int = 4_000_000
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: tuple = (1024, 512, 256)
    dtype: object = jnp.float32


def bst_init(key, cfg: BSTConfig):
    keys = jax.random.split(key, 8)
    d = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        k = jax.random.split(keys[2 + i], 6)
        blocks.append({
            "wq": jax.random.normal(k[0], (d, d), cfg.dtype) * d ** -0.5,
            "wk": jax.random.normal(k[1], (d, d), cfg.dtype) * d ** -0.5,
            "wv": jax.random.normal(k[2], (d, d), cfg.dtype) * d ** -0.5,
            "wo": jax.random.normal(k[3], (d, d), cfg.dtype) * d ** -0.5,
            "ff1": jax.random.normal(k[4], (d, 4 * d), cfg.dtype) * d ** -0.5,
            "ff2": jax.random.normal(k[5], (4 * d, d), cfg.dtype) * (4 * d) ** -0.5,
            "ln1": jnp.ones((d,), cfg.dtype), "ln2": jnp.ones((d,), cfg.dtype),
        })
    flat_in = (cfg.seq_len + 1) * d
    return {
        "item_embed": jax.random.normal(keys[0], (cfg.item_vocab, d), cfg.dtype) * 0.01,
        "pos_embed": jax.random.normal(keys[1], (cfg.seq_len + 1, d), cfg.dtype) * 0.01,
        "blocks": blocks,
        "mlp": init_mlp(keys[-1], (flat_in,) + cfg.mlp_dims + (1,), cfg.dtype),
    }


def bst_forward(params, batch, cfg: BSTConfig):
    hist = embedding_lookup(params["item_embed"], batch["history"])   # [B, S, d]
    tgt = embedding_lookup(params["item_embed"], batch["target"])     # [B, d]
    x = jnp.concatenate([hist, tgt[:, None]], axis=1) + params["pos_embed"][None]
    b, s, d = x.shape
    h_heads, dh = cfg.n_heads, d // cfg.n_heads
    for blk in params["blocks"]:
        hx = rms_norm(x, blk["ln1"])
        q = jnp.einsum("bsd,de->bse", hx, blk["wq"]).reshape(b, s, h_heads, dh)
        k = jnp.einsum("bsd,de->bse", hx, blk["wk"]).reshape(b, s, h_heads, dh)
        v = jnp.einsum("bsd,de->bse", hx, blk["wv"]).reshape(b, s, h_heads, dh)
        sc = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * dh ** -0.5
        p = jax.nn.softmax(sc, -1).astype(x.dtype)
        o = jnp.einsum("bhst,bthd->bshd", p, v).reshape(b, s, d)
        x = x + jnp.einsum("bsd,de->bse", o, blk["wo"])
        hx = rms_norm(x, blk["ln2"])
        x = x + jnp.einsum("bsf,fd->bsd", jax.nn.relu(
            jnp.einsum("bsd,df->bsf", hx, blk["ff1"])), blk["ff2"])
    return mlp(x.reshape(b, s * d), params["mlp"])[:, 0]


def bst_loss(params, batch, cfg: BSTConfig):
    logits = bst_forward(params, batch, cfg)
    loss = bce_loss(logits, batch["labels"])
    return loss, {"bce": loss}


# -------------------------------------------------------------------- AutoInt
@dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    field_vocab: int = 1_000_000       # per-field vocab (Criteo-scale rows total)
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    n_dense: int = 13
    dtype: object = jnp.float32


def autoint_init(key, cfg: AutoIntConfig):
    keys = jax.random.split(key, cfg.n_attn_layers + 3)
    layers = []
    d_in = cfg.embed_dim
    for i in range(cfg.n_attn_layers):
        k = jax.random.split(keys[i], 4)
        layers.append({
            "wq": jax.random.normal(k[0], (d_in, cfg.d_attn), cfg.dtype) * d_in ** -0.5,
            "wk": jax.random.normal(k[1], (d_in, cfg.d_attn), cfg.dtype) * d_in ** -0.5,
            "wv": jax.random.normal(k[2], (d_in, cfg.d_attn), cfg.dtype) * d_in ** -0.5,
            "wres": jax.random.normal(k[3], (d_in, cfg.d_attn), cfg.dtype) * d_in ** -0.5,
        })
        d_in = cfg.d_attn
    n_fields = cfg.n_sparse + 1                       # +1 dense-projection field
    return {
        "tables": jax.random.normal(keys[-3], (cfg.n_sparse, cfg.field_vocab,
                                               cfg.embed_dim), cfg.dtype) * 0.01,
        "dense_proj": jax.random.normal(keys[-2], (cfg.n_dense, cfg.embed_dim),
                                        cfg.dtype) * cfg.n_dense ** -0.5,
        "layers": layers,
        "head": jax.random.normal(keys[-1], (n_fields * d_in, 1), cfg.dtype)
                * (n_fields * d_in) ** -0.5,
    }


def autoint_forward(params, batch, cfg: AutoIntConfig):
    ids = batch["sparse_ids"]                              # [B, F]
    b = ids.shape[0]
    emb = jax.vmap(lambda t, i: jnp.take(t, i, axis=0),    # per-field table gather
                   in_axes=(0, 1), out_axes=1)(params["tables"], ids)
    dense_f = jnp.einsum("bk,kd->bd", batch["dense"], params["dense_proj"])
    x = jnp.concatenate([emb, dense_f[:, None]], axis=1)   # [B, F+1, d]
    for pl in params["layers"]:
        q = jnp.einsum("bfd,de->bfe", x, pl["wq"])
        k = jnp.einsum("bfd,de->bfe", x, pl["wk"])
        v = jnp.einsum("bfd,de->bfe", x, pl["wv"])
        sc = jnp.einsum("bfe,bge->bfg", q, k).astype(jnp.float32)
        sc *= (x.shape[-1]) ** -0.5
        p = jax.nn.softmax(sc, -1).astype(x.dtype)
        x = jax.nn.relu(jnp.einsum("bfg,bge->bfe", p, v)
                        + jnp.einsum("bfd,de->bfe", x, pl["wres"]))
    return jnp.einsum("bf,fo->bo", x.reshape(b, -1), params["head"])[:, 0]


def autoint_loss(params, batch, cfg: AutoIntConfig):
    loss = bce_loss(autoint_forward(params, batch, cfg), batch["labels"])
    return loss, {"bce": loss}


# ------------------------------------------------------------------ two-tower
@dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    item_vocab: int = 10_000_000
    embed_dim: int = 256
    user_feat: int = 256
    tower_dims: tuple = (1024, 512, 256)
    dtype: object = jnp.float32


def twotower_init(key, cfg: TwoTowerConfig):
    k = jax.random.split(key, 4)
    return {
        "item_embed": jax.random.normal(k[0], (cfg.item_vocab, cfg.embed_dim),
                                        cfg.dtype) * 0.01,
        "user_mlp": init_mlp(k[1], (cfg.user_feat,) + cfg.tower_dims, cfg.dtype),
        "item_mlp": init_mlp(k[2], (cfg.embed_dim,) + cfg.tower_dims, cfg.dtype),
    }


def twotower_embed(params, batch, cfg: TwoTowerConfig):
    u = mlp(batch["user"].astype(cfg.dtype), params["user_mlp"])
    i = mlp(embedding_lookup(params["item_embed"], batch["pos_item"]),
            params["item_mlp"])
    u = u / jnp.linalg.norm(u, axis=-1, keepdims=True).clip(1e-6)
    i = i / jnp.linalg.norm(i, axis=-1, keepdims=True).clip(1e-6)
    return u, i


def twotower_loss(params, batch, cfg: TwoTowerConfig, temp: float = 0.05):
    """In-batch sampled softmax (each row's positive vs other rows' items)."""
    u, i = twotower_embed(params, batch, cfg)
    logits = (u @ i.T).astype(jnp.float32) / temp
    labels = jnp.arange(u.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
    loss = jnp.mean(logz - gold)
    return loss, {"softmax": loss}


def twotower_score_candidates(params, batch, cfg: TwoTowerConfig):
    """retrieval_cand shape: one query [1, F] against candidate ids [N]."""
    u = mlp(batch["user"].astype(cfg.dtype), params["user_mlp"])
    c = mlp(embedding_lookup(params["item_embed"], batch["candidates"]),
            params["item_mlp"])
    u = u / jnp.linalg.norm(u, axis=-1, keepdims=True).clip(1e-6)
    c = c / jnp.linalg.norm(c, axis=-1, keepdims=True).clip(1e-6)
    return jnp.einsum("qd,nd->qn", u, c)


# -------------------------------------------------------------------- xDeepFM
@dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    field_vocab: int = 1_000_000
    embed_dim: int = 10
    cin_layers: tuple = (200, 200, 200)
    mlp_dims: tuple = (400, 400)
    n_dense: int = 13
    dtype: object = jnp.float32


def xdeepfm_init(key, cfg: XDeepFMConfig):
    keys = jax.random.split(key, len(cfg.cin_layers) + 5)
    f0 = cfg.n_sparse
    cin = []
    h_prev = f0
    for i, h in enumerate(cfg.cin_layers):
        cin.append(jax.random.normal(keys[i], (h, h_prev * f0), cfg.dtype)
                   * (h_prev * f0) ** -0.5)
        h_prev = h
    flat = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    return {
        "tables": jax.random.normal(keys[-5], (cfg.n_sparse, cfg.field_vocab,
                                               cfg.embed_dim), cfg.dtype) * 0.01,
        "linear": jax.random.normal(keys[-4], (cfg.n_sparse, cfg.field_vocab),
                                    cfg.dtype) * 0.01,
        "cin": cin,
        "cin_head": jax.random.normal(keys[-3], (sum(cfg.cin_layers), 1),
                                      cfg.dtype) * 0.05,
        "mlp": init_mlp(keys[-2], (flat,) + cfg.mlp_dims + (1,), cfg.dtype),
    }


def xdeepfm_forward(params, batch, cfg: XDeepFMConfig):
    ids = batch["sparse_ids"]                                   # [B, F]
    b = ids.shape[0]
    x0 = jax.vmap(lambda t, i: jnp.take(t, i, axis=0),
                  in_axes=(0, 1), out_axes=1)(params["tables"], ids)  # [B, F, D]
    # CIN: x^{k}_h = W^k_h . vec(x^{k-1} (outer) x^0) per embedding dim
    xs = []
    xk = x0
    for w in params["cin"]:
        z = jnp.einsum("bhd,bfd->bhfd", xk, x0)                 # [B, Hk-1, F, D]
        z = z.reshape(b, -1, cfg.embed_dim)                     # [B, Hk-1*F, D]
        xk = jnp.einsum("hm,bmd->bhd", w, z)                    # [B, Hk, D]
        xs.append(jnp.sum(xk, axis=-1))                         # sum-pool over D
    cin_logit = jnp.einsum("bh,ho->bo", jnp.concatenate(xs, -1),
                           params["cin_head"])[:, 0]
    lin = jax.vmap(lambda t, i: jnp.take(t, i, axis=0),
                   in_axes=(0, 1), out_axes=1)(params["linear"], ids)
    lin_logit = jnp.sum(lin, axis=1)
    deep_in = jnp.concatenate([x0.reshape(b, -1), batch["dense"].astype(cfg.dtype)], -1)
    deep_logit = mlp(deep_in, params["mlp"])[:, 0]
    return cin_logit + lin_logit + deep_logit


def xdeepfm_loss(params, batch, cfg: XDeepFMConfig):
    loss = bce_loss(xdeepfm_forward(params, batch, cfg), batch["labels"])
    return loss, {"bce": loss}
