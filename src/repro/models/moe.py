"""Mixture-of-Experts FFN (token-choice top-k) with expert parallelism.

Baseline dispatch is GShard-style dense one-hot einsum (t5x lineage): robust under
grad + scan + GSPMD, experts sharded over the `model` axis, capacity-factor bounded.
The combine/dispatch tensors are the FLOPs/memory overhead this formulation pays;
the sort-based dispatch (our n-gram shuffle's ``bucketize`` -- the paper's
partitioner!) is the beyond-paper optimization evaluated in EXPERIMENTS.md SSPerf.

Covers both assigned MoE archs:
  mixtral-8x7b      : 8 experts, top-2, no shared experts
  deepseek-moe-16b  : 64 fine-grained routed experts, top-6, +2 shared experts
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .layers import swiglu


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    dispatch: str = "einsum"      # einsum (GShard) | sort (bucketized, SSPerf)
    # distributed execution (set by the cell builder; None = single-device path):
    mesh: Any = None
    dp_axes: Any = None           # batch axes ('pod','data') / 'data' / None
    tp_axis: str = "model"

    def capacity(self, tokens_per_group: int) -> int:
        c = int(self.capacity_factor * tokens_per_group * self.top_k / self.n_experts)
        return max(4, -(-c // 4) * 4)


def router_topk(x, w_router, cfg: MoEConfig):
    """Returns (expert ids [T, k], gates [T, k], logits [T, E]) for tokens [T, d]."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(gates_all, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # renormalize
    return ids, gates.astype(x.dtype), logits


def load_balance_loss(logits: jax.Array, ids: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E * <fraction routed> . <mean router prob>."""
    probs = jax.nn.softmax(logits, axis=-1).mean(0)
    frac = jnp.mean(jax.nn.one_hot(ids[:, 0], n_experts, dtype=jnp.float32), axis=0)
    return n_experts * jnp.sum(frac * probs)


def _dispatch_einsum(x, ids, gates, cfg: MoEConfig, capacity):
    """GShard dense dispatch: one-hot [T, E, C] combine/dispatch tensors."""
    t = x.shape[0]
    e = cfg.n_experts
    # position of each (token, k) claim within its expert's capacity
    claims = jax.nn.one_hot(ids, e, dtype=jnp.int32)           # [T, k, E]
    pos = jnp.cumsum(claims.reshape(t * cfg.top_k, e), axis=0).reshape(
        t, cfg.top_k, e) - 1
    pos = jnp.sum(pos * claims, axis=-1)                       # [T, k]
    keep = pos < capacity
    disp = (jax.nn.one_hot(ids, e, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                             dtype=x.dtype)[..., None, :])     # [T, k, E, C+1]
    disp = disp[..., :capacity]
    combine = jnp.einsum("tkec,tk->tec", disp, gates)          # [T, E, C]
    dispatch = jnp.sum(disp, axis=1)                           # [T, E, C]
    return dispatch, combine


def _dispatch_indices(t: int, ids, gates, cfg: MoEConfig, capacity):
    """Bucketized dispatch indices (the n-gram shuffle partitioner reused as MoE
    dispatch): token index + gate per [E, C] slot; no [T, E, C] tensors.
    slot_token == t marks an empty slot."""
    e = cfg.n_experts
    flat_ids = ids.reshape(-1)                                   # [T*k]
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(sorted_ids, length=e)
    offs = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    within = jnp.arange(t * cfg.top_k) - offs[sorted_ids]
    ok = within < capacity
    slot = jnp.where(ok, sorted_ids * capacity + within, e * capacity)
    tok_of_claim = order // cfg.top_k
    slot_token = jnp.full((e * capacity + 1,), t, jnp.int32).at[slot].set(
        tok_of_claim.astype(jnp.int32), mode="drop")[:-1]        # [E*C] -> token id
    slot_gate = jnp.zeros((e * capacity + 1,), gates.dtype).at[slot].set(
        gates.reshape(-1)[order], mode="drop")[:-1]
    return slot_token, slot_gate


def _dispatch_sort(x, ids, gates, cfg: MoEConfig, capacity):
    slot_token, slot_gate = _dispatch_indices(x.shape[0], ids, gates, cfg, capacity)
    x_pad = jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)])
    expert_in = x_pad[slot_token].reshape(cfg.n_experts, capacity, x.shape[-1])
    return expert_in, slot_token, slot_gate


def moe_ffn(x: jax.Array, params: dict, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar).

    params: router [d, E]; wg/wu [E, d, ff_e]; wo [E, ff_e, d];
            (shared) sg/su [d, ff_s]; so [ff_s, d].
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    t = xt.shape[0]
    capacity = cfg.capacity(t)
    ids, gates, logits = router_topk(xt, params["router"], cfg)
    aux = load_balance_loss(logits, ids, cfg.n_experts)

    if cfg.dispatch == "einsum":
        dispatch, combine = _dispatch_einsum(xt, ids, gates, cfg, capacity)
        ein = jnp.einsum("tec,td->ecd", dispatch, xt)            # [E, C, d]
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, params["wg"]))
        h = h * jnp.einsum("ecd,edf->ecf", ein, params["wu"])
        eo = jnp.einsum("ecf,efd->ecd", h, params["wo"])         # [E, C, d]
        y = jnp.einsum("tec,ecd->td", combine, eo)
    else:
        expert_in, slot_token, slot_gate = _dispatch_sort(xt, ids, gates, cfg, capacity)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["wg"]))
        h = h * jnp.einsum("ecd,edf->ecf", expert_in, params["wu"])
        eo = jnp.einsum("ecf,efd->ecd", h, params["wo"]).reshape(-1, d)
        eo = eo * slot_gate[:, None]
        y = jnp.zeros((t + 1, d), x.dtype).at[slot_token].add(eo)[:t]

    if cfg.n_shared:
        y = y + swiglu(xt, params["sg"], params["su"], params["so"])
    return y.reshape(b, s, d), aux


def moe_ffn_sharded(x: jax.Array, params: dict, cfg: MoEConfig
                    ) -> tuple[jax.Array, jax.Array]:
    """Distributed MoE via shard_map: per-device sort-based dispatch (the n-gram
    shuffle's ``bucketize`` reused as expert dispatch) + expert/ff-sharded FFN +
    one psum over the tensor axis.

    Two expert layouts, chosen by divisibility (configs/base.py sets pspecs to
    match):
      * EP  (E %% tp == 0): each tp-rank owns E/tp experts, gathers only the
        tokens routed to them (capacity-bounded), computes, scatter-adds its
        partial [T_local, d], psum over tp.
      * ffTP (E < tp, e.g. mixtral 8 experts on tp=16): every rank holds all
        experts but only d_ff/tp of each; partial outputs psum over tp.

    vs the GShard einsum dispatch this removes the O(T*E*C*d) one-hot einsums
    entirely -- dispatch becomes O(T*k) integer work + O(E_local*C*d) gathers
    (EXPERIMENTS.md SSPerf H1).
    """
    from jax.sharding import PartitionSpec as P

    mesh = cfg.mesh
    tp = cfg.tp_axis
    tp_size = mesh.shape[tp]
    ep = cfg.n_experts % tp_size == 0
    e_local = cfg.n_experts // tp_size if ep else cfg.n_experts
    dp = cfg.dp_axes
    x_spec = P(dp, None, None)
    has_shared = cfg.n_shared > 0

    def local(xl, router, wg, wu, wo, sg, su, so):
        b_l, s, d = xl.shape
        xt = xl.reshape(b_l * s, d)
        t_l = xt.shape[0]
        capacity = cfg.capacity(t_l)
        ids, gates, logits = router_topk(xt, router, cfg)
        aux = load_balance_loss(logits, ids, cfg.n_experts)
        slot_token, slot_gate = _dispatch_indices(t_l, ids, gates, cfg, capacity)
        if ep:  # this rank gathers only its own experts' tokens
            rank = jax.lax.axis_index(tp)
            slot_token = jax.lax.dynamic_slice_in_dim(
                slot_token, rank * e_local * capacity, e_local * capacity, axis=0)
            slot_gate = jax.lax.dynamic_slice_in_dim(
                slot_gate, rank * e_local * capacity, e_local * capacity, axis=0)
        x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
        expert_in = x_pad[slot_token].reshape(e_local, capacity, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg))
        h = h * jnp.einsum("ecd,edf->ecf", expert_in, wu)
        eo = jnp.einsum("ecf,efd->ecd", h, wo).reshape(-1, d)
        eo = eo * slot_gate[:, None]
        y = jnp.zeros((t_l + 1, d), xl.dtype).at[slot_token].add(eo)[:t_l]
        if has_shared:
            y = y + swiglu(xt, sg, su, so)          # ff_s sharded over tp
        y = jax.lax.psum(y, tp)
        axes = (tp,) + ((dp,) if isinstance(dp, str) else tuple(dp or ()))
        aux = jax.lax.pmean(aux, axes)
        return y.reshape(b_l, s, d), aux

    if ep:
        w_specs = (P(tp, None, None), P(tp, None, None), P(tp, None, None))
    else:
        w_specs = (P(None, None, tp), P(None, None, tp), P(None, tp, None))
    s_specs = ((P(None, tp), P(None, tp), P(tp, None)) if has_shared
               else (P(), P(), P()))
    dummy = jnp.zeros((), x.dtype)
    args = (x, params["router"], params["wg"], params["wu"], params["wo"],
            params.get("sg", dummy), params.get("su", dummy),
            params.get("so", dummy))
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P()) + w_specs + s_specs,
        out_specs=(x_spec, P()), check_vma=False)
    return fn(*args)


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype) -> dict:
    k = jax.random.split(key, 7)
    scale = d_model ** -0.5
    p = {
        "router": jax.random.normal(k[0], (d_model, cfg.n_experts), jnp.float32) * scale,
        "wg": jax.random.normal(k[1], (cfg.n_experts, d_model, cfg.d_ff_expert), dtype) * scale,
        "wu": jax.random.normal(k[2], (cfg.n_experts, d_model, cfg.d_ff_expert), dtype) * scale,
        "wo": jax.random.normal(k[3], (cfg.n_experts, cfg.d_ff_expert, d_model), dtype)
              * cfg.d_ff_expert ** -0.5,
    }
    if cfg.n_shared:
        ffs = cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared
        p["sg"] = jax.random.normal(k[4], (d_model, ffs), dtype) * scale
        p["su"] = jax.random.normal(k[5], (d_model, ffs), dtype) * scale
        p["so"] = jax.random.normal(k[6], (ffs, d_model), dtype) * ffs ** -0.5
    return p
