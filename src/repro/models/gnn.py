"""GIN (Xu et al., arXiv:1810.00826): h' = MLP((1 + eps) h + sum_{j in N(i)} h_j).

Message passing is ``segment_sum`` over an edge index -- the same segmented
aggregation primitive as the SUFFIX-sigma reducer (DESIGN.md SS4).  Distribution:
edges sharded over the data axis, node states replicated; the scatter-add produces
partial node sums per shard that GSPMD combines with an all-reduce (exactly the
paper's shuffle-then-aggregate, with nodes as keys).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 16
    learnable_eps: bool = True
    dtype: object = jnp.float32
    # dtype of node features on the wire: with nodes sharded over `data`, every
    # layer all-gathers h for the source-side gather; bf16 halves those bytes
    # (the dominant roofline term for ogb_products -- SSPerf H2).  Aggregation
    # still accumulates in f32 after the gather.
    comm_dtype: object = jnp.float32


def init_params(key, cfg: GINConfig):
    keys = jax.random.split(key, cfg.n_layers * 2 + 1)
    layers = []
    d_in = cfg.d_feat
    for l in range(cfg.n_layers):
        k1, k2 = keys[2 * l], keys[2 * l + 1]
        layers.append({
            "w1": jax.random.normal(k1, (d_in, cfg.d_hidden), cfg.dtype) * d_in ** -0.5,
            "b1": jnp.zeros((cfg.d_hidden,), cfg.dtype),
            "w2": jax.random.normal(k2, (cfg.d_hidden, cfg.d_hidden), cfg.dtype)
                  * cfg.d_hidden ** -0.5,
            "b2": jnp.zeros((cfg.d_hidden,), cfg.dtype),
            "eps": jnp.zeros((), jnp.float32),
        })
        d_in = cfg.d_hidden
    return {"layers": layers,
            "head": jax.random.normal(keys[-1], (cfg.d_hidden, cfg.n_classes),
                                      cfg.dtype) * cfg.d_hidden ** -0.5}


def forward(params, feats, edge_src, edge_dst, edge_mask, n_nodes: int,
            cfg: GINConfig):
    """feats [N, F]; edges (src -> dst); returns logits [N, C]."""
    h = feats.astype(cfg.dtype)
    w = edge_mask.astype(cfg.dtype)[:, None] if edge_mask is not None else None
    for pl in params["layers"]:
        msg = jnp.take(h.astype(cfg.comm_dtype), edge_src, axis=0)  # gather (wire)
        msg = msg.astype(cfg.dtype)                    # accumulate in f32
        if w is not None:
            msg = msg * w
        agg = jax.ops.segment_sum(msg, edge_dst, num_segments=n_nodes)  # scatter
        z = (1.0 + pl["eps"]).astype(cfg.dtype) * h + agg
        z = jax.nn.relu(jnp.einsum("nf,fh->nh", z, pl["w1"]) + pl["b1"])
        h = jax.nn.relu(jnp.einsum("nh,hk->nk", z, pl["w2"]) + pl["b2"])
    return jnp.einsum("nh,hc->nc", h, params["head"])


def loss_fn_dst_partitioned(params, batch, cfg: GINConfig, mesh, dp):
    """Distributed message passing with dst-partitioned edges (shard_map).

    Contract: nodes are range-sharded over the dp axes and the edge arrays are
    partitioned so each device's edges target only its own dst range (the data
    pipeline's CSR ordering provides this; see graph.partition_edges_by_dst).
    Then the scatter is LOCAL and the only communication is one all-gather of the
    (comm_dtype) node features per layer -- vs the baseline GSPMD layout whose
    per-layer [N, F] fp32 all-reduce costs 2x the ring bytes of an all-gather and
    4x after bf16 (measured 178ms -> 44ms collective on ogb_products; SSPerf H2).
    """
    from jax.sharding import PartitionSpec as P

    axes = (dp,) if isinstance(dp, str) else tuple(dp)
    sizes = [mesh.shape[a] for a in axes]
    p_total = 1
    for s in sizes:
        p_total *= s

    def local(params_r, feats_l, src_l, dst_l, emask_l, labels_l, lmask_l):
        n_local = feats_l.shape[0]
        rank = jnp.int32(0)
        for a in axes:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        offset = rank * n_local
        h = feats_l.astype(cfg.dtype)
        w = emask_l.astype(cfg.dtype)[:, None]
        for pl in params_r["layers"]:
            hg = jax.lax.all_gather(h.astype(cfg.comm_dtype), axes, tiled=True)
            msg = jnp.take(hg, src_l, axis=0).astype(cfg.dtype) * w
            agg = jax.ops.segment_sum(msg, dst_l - offset, num_segments=n_local)
            z = (1.0 + pl["eps"]).astype(cfg.dtype) * h + agg
            z = jax.nn.relu(jnp.einsum("nf,fh->nh", z, pl["w1"]) + pl["b1"])
            h = jax.nn.relu(jnp.einsum("nh,hk->nk", z, pl["w2"]) + pl["b2"])
        logits = jnp.einsum("nh,hc->nc", h, params_r["head"]).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels_l[:, None], axis=1)[:, 0]
        nll = jnp.where(lmask_l, logz - gold, 0.0)
        total = jax.lax.psum(jnp.sum(nll), axes)
        count = jax.lax.psum(jnp.sum(lmask_l), axes)
        return total / jnp.maximum(count, 1)

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(dp, None), P(dp), P(dp), P(dp), P(dp), P(dp)),
        out_specs=P(), check_vma=False)
    loss = fn(params, batch["features"], batch["edge_src"], batch["edge_dst"],
              batch["edge_mask"], batch["labels"], batch["label_mask"])
    return loss, {"ce": loss}


def loss_fn(params, batch, cfg: GINConfig):
    """batch: features, edge_src, edge_dst, edge_mask, labels, label_mask."""
    logits = forward(params, batch["features"], batch["edge_src"],
                     batch["edge_dst"], batch.get("edge_mask"),
                     batch["features"].shape[0], cfg).astype(jnp.float32)
    labels = batch["labels"]
    mask = batch.get("label_mask")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    nll = logz - gold
    if mask is not None:
        nll = jnp.where(mask, nll, 0.0)
        denom = jnp.maximum(jnp.sum(mask), 1)
    else:
        denom = nll.shape[0]
    loss = jnp.sum(nll) / denom
    return loss, {"ce": loss}
