from . import gnn, layers, moe, recsys, transformer

__all__ = ["gnn", "layers", "moe", "recsys", "transformer"]
