"""Shared neural layers: norms, RoPE, attention variants (GQA / SWA / MLA),
SwiGLU.  Everything is a pure function over explicit parameter pytrees; sharding is
applied from outside via pjit in_shardings (GSPMD propagates through these ops).

Attention memory note: prefill at 32k would materialize [B, H, S, S] scores; the
``q_chunk`` knob splits queries into a statically unrolled python loop (NOT lax.scan,
so XLA cost_analysis still counts every chunk -- see DESIGN.md SS5) with exact
softmax per chunk, bounding the live score block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., S, H, d]; positions: [..., S] int32.

    The angle table is computed in f32 but cast to x.dtype BEFORE the rotation:
    otherwise the whole rotated tensor exists in f32 and XLA hoists that copy into
    the scan's saved stacks (the f32 KV-cache blowup diagnosed in EXPERIMENTS.md
    SSPerf H1 it-3 / dry-run notes)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                   # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs         # [..., S, d/2]
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _mask(q_pos, k_pos, window):
    m = k_pos[None, :] <= q_pos[:, None]                 # causal
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window    # sliding window
    return m


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  q_positions: jax.Array, k_positions: jax.Array,
                  window: int | None = None, q_chunk: int = 0) -> jax.Array:
    """Grouped-query attention.  q: [B, S, H, d]; k,v: [B, T, KV, d]; H % KV == 0.
    Returns [B, S, H, dv].  q_chunk > 0 processes queries in unrolled chunks.

    KV heads are repeated up to H (broadcast view) rather than reshaping q into a
    (KV, G) split: the single H dim stays shardable under tensor parallelism (a
    (KV, G) factorization of e.g. H=32 cannot be 16-way sharded and forces GSPMD to
    all-gather the activations -- measured as a 100+ GB/device temp blowup in the
    dry-run before this fix; see EXPERIMENTS.md SSPerf)."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                       # MLA: value dim may differ from key dim
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)       # [B, T, H, d]
        v = jnp.repeat(v, g, axis=2)
    scale = d ** -0.5

    def block(qc, qpos_c):
        scores = jnp.einsum("bshd,bthd->bhst", qc, k).astype(jnp.float32) * scale
        m = _mask(qpos_c, k_positions, window)
        scores = jnp.where(m[None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", p, v)

    if q_chunk and s > q_chunk:
        assert s % q_chunk == 0
        outs = [block(q[:, i:i + q_chunk], q_positions[i:i + q_chunk])
                for i in range(0, s, q_chunk)]
        out = jnp.concatenate(outs, axis=1)
    else:
        out = block(q, q_positions)
    return out


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     valid: jax.Array) -> jax.Array:
    """One-token decode vs a cache.  q: [B, H, d]; caches: [B, T, KV, d];
    valid: [T] or [B, T] bool marking live cache slots.  Returns [B, H, d]."""
    b, h, d = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    if g > 1:
        k_cache = jnp.repeat(k_cache, g, axis=2)
        v_cache = jnp.repeat(v_cache, g, axis=2)
    scores = jnp.einsum("bhd,bthd->bht", q, k_cache).astype(jnp.float32)
    scores *= d ** -0.5
    v_mask = valid if valid.ndim == 2 else valid[None]
    scores = jnp.where(v_mask[:, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    return jnp.einsum("bht,bthd->bhd", p, v_cache)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    gate = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    return jnp.einsum("...f,fd->...d", gate * jnp.einsum("...d,df->...f", x, w_up),
                      w_down)


def cross_entropy_loss(x_final: jax.Array, lm_head: jax.Array,
                       labels: jax.Array, n_chunks: int = 4) -> jax.Array:
    """Chunked softmax cross entropy: never materializes [B, S, V] in one piece.
    x_final: [B, S, d]; lm_head: [d, V]; labels: [B, S] int32."""
    b, s, d = x_final.shape
    n_chunks = max(1, min(n_chunks, s))
    while s % n_chunks:
        n_chunks -= 1
    cs = s // n_chunks
    total = 0.0
    for i in range(n_chunks):
        xc = x_final[:, i * cs:(i + 1) * cs]
        lc = labels[:, i * cs:(i + 1) * cs]
        logits = jnp.einsum("bsd,dv->bsv", xc, lm_head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(logz - gold)
    return total / (b * s)
