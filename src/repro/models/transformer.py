"""Causal-LM transformer covering the five assigned LM architectures.

One config, four structural switches:
  attention kind : gqa (llama3 / phi3 / deepseek / mixtral) | mla (minicpm3)
  window         : sliding-window attention (mixtral) -> bounded decode cache
  moe            : None (dense) | MoEConfig (mixtral, deepseek-moe)
  scan_layers    : lax.scan over stacked layer params (fast 512-way compiles; the
                   roofline pass compiles the body separately for the trip-count
                   correction, DESIGN.md SS5)

Decode uses per-arch KV caches: GQA ring/linear cache, SWA ring buffer bounded by the
window, MLA *absorbed* latent cache (rank-r ckv + shared rope key -- the actual
memory story of MLA).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (apply_rope, cross_entropy_loss, decode_attention,
                     gqa_attention, rms_norm, NEG_INF)
from .moe import MoEConfig, init_moe_params, moe_ffn


@dataclass(frozen=True)
class AttentionConfig:
    kind: str                    # "gqa" | "mla"
    n_heads: int
    n_kv: int
    d_head: int
    window: int | None = None
    rope_theta: float = 10_000.0
    # MLA dims (DeepSeek-V2 style):
    q_lora: int = 0
    kv_lora: int = 0
    d_nope: int = 0
    d_rope: int = 0
    d_v: int = 0
    # transparent head padding for TP (e.g. phi3's 40 heads on a 16-way axis pad
    # to 48): padded heads are *masked to zero* before the output projection, so
    # the function computed is exactly the n_heads-head model and padded params
    # receive zero gradient.  Set by the cell builder; 0 = no padding.
    pad_heads_to: int = 0

    @property
    def h_eff(self) -> int:
        return max(self.n_heads, self.pad_heads_to)

    @property
    def kv_eff(self) -> int:
        return self.h_eff // (self.n_heads // self.n_kv)

    @property
    def head_mask_needed(self) -> bool:
        return self.h_eff != self.n_heads


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    vocab_size: int
    d_ff: int
    attn: AttentionConfig
    moe: MoEConfig | None = None
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    q_chunk: int = 0             # unrolled query chunking for long prefill
    loss_chunks: int = 8
    remat: bool = True
    scan_layers: bool = True
    aux_loss_weight: float = 0.01
    # batch-dim axis names for activation sharding constraints (set by the cell
    # builder when lowering on a mesh; None on single-host runs).  Without the
    # explicit constraint GSPMD follows the FSDP weight sharding and REPLICATES the
    # batch -- a measured 100+GB/device temp blowup (EXPERIMENTS.md SSPerf).
    shard_activations: Any = None


def _constrain(x, cfg, spec_tail=(None, None)):
    if cfg.shard_activations is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(cfg.shard_activations, *spec_tail))

    @property
    def sub_quadratic(self) -> bool:
        return self.attn.window is not None


# ----------------------------------------------------------------------- params
def _init_attn(key, cfg: LMConfig):
    a, d = cfg.attn, cfg.d_model
    k = jax.random.split(key, 8)
    s = d ** -0.5
    if a.kind == "gqa":
        return {
            "wq": jax.random.normal(k[0], (d, a.h_eff * a.d_head), cfg.dtype) * s,
            "wk": jax.random.normal(k[1], (d, a.kv_eff * a.d_head), cfg.dtype) * s,
            "wv": jax.random.normal(k[2], (d, a.kv_eff * a.d_head), cfg.dtype) * s,
            "wo": jax.random.normal(k[3], (a.h_eff * a.d_head, d), cfg.dtype)
                  * (a.n_heads * a.d_head) ** -0.5,
        }
    qd, rr = a.d_nope + a.d_rope, a.kv_lora
    return {
        "wdq": jax.random.normal(k[0], (d, a.q_lora), cfg.dtype) * s,
        "wuq": jax.random.normal(k[1], (a.q_lora, a.h_eff * qd), cfg.dtype)
               * a.q_lora ** -0.5,
        "wdkv": jax.random.normal(k[2], (d, rr), cfg.dtype) * s,
        "wukv": jax.random.normal(k[3], (rr, a.h_eff * (a.d_nope + a.d_v)),
                                  cfg.dtype) * rr ** -0.5,
        "wkr": jax.random.normal(k[4], (d, a.d_rope), cfg.dtype) * s,
        "wo": jax.random.normal(k[5], (a.h_eff * a.d_v, d), cfg.dtype)
              * (a.n_heads * a.d_v) ** -0.5,
    }


def _head_mask(a: AttentionConfig, out: jax.Array) -> jax.Array:
    """Zero the padded heads' outputs: the computed function stays the exact
    n_heads model and padded parameters get zero gradient."""
    if not a.head_mask_needed:
        return out
    mask = (jnp.arange(a.h_eff) < a.n_heads).astype(out.dtype)
    return out * mask[..., :, None]


def _init_ffn(key, cfg: LMConfig):
    if cfg.moe is not None:
        return init_moe_params(key, cfg.d_model, cfg.moe, cfg.dtype)
    d, f = cfg.d_model, cfg.d_ff
    k = jax.random.split(key, 3)
    return {"wg": jax.random.normal(k[0], (d, f), cfg.dtype) * d ** -0.5,
            "wu": jax.random.normal(k[1], (d, f), cfg.dtype) * d ** -0.5,
            "wo": jax.random.normal(k[2], (f, d), cfg.dtype) * f ** -0.5}


def init_params(key, cfg: LMConfig):
    keys = jax.random.split(key, 4)

    def one_layer(k):
        ka, kf = jax.random.split(k)
        p = {"ln1": jnp.ones((cfg.d_model,), cfg.dtype),
             "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
             "ffn": _init_ffn(kf, cfg)}
        p.update(_init_attn(ka, cfg))
        return p

    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    layers = jax.vmap(one_layer)(layer_keys)        # stacked [L, ...]
    return {
        "embed": jax.random.normal(keys[1], (cfg.vocab_size, cfg.d_model),
                                   cfg.dtype) * cfg.d_model ** -0.5,
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "lm_head": jax.random.normal(keys[2], (cfg.d_model, cfg.vocab_size),
                                     cfg.dtype) * cfg.d_model ** -0.5,
    }


# ---------------------------------------------------------------------- forward
def _attn_block(pl, x, positions, cfg: LMConfig, collect_cache: bool):
    a = cfg.attn
    b, s, d = x.shape
    if a.kind == "gqa":
        q = jnp.einsum("bsd,dh->bsh", x, pl["wq"]).reshape(b, s, a.h_eff, a.d_head)
        k = jnp.einsum("bsd,dh->bsh", x, pl["wk"]).reshape(b, s, a.kv_eff, a.d_head)
        v = jnp.einsum("bsd,dh->bsh", x, pl["wv"]).reshape(b, s, a.kv_eff, a.d_head)
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
        out = gqa_attention(q, k, v, q_positions=positions, k_positions=positions,
                            window=a.window, q_chunk=cfg.q_chunk)
        out = _head_mask(a, out)
        out = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, -1), pl["wo"])
        cache = {"k": k, "v": v} if collect_cache else None
        return out, cache
    # --- MLA (non-absorbed form for train/prefill) ---
    cq = jnp.einsum("bsd,dr->bsr", x, pl["wdq"])
    q = jnp.einsum("bsr,rh->bsh", cq, pl["wuq"]).reshape(
        b, s, a.h_eff, a.d_nope + a.d_rope)
    qn, qr = q[..., : a.d_nope], q[..., a.d_nope:]
    qr = apply_rope(qr, positions, a.rope_theta)
    ckv = jnp.einsum("bsd,dr->bsr", x, pl["wdkv"])                     # latent cache
    kv = jnp.einsum("bsr,rh->bsh", ckv, pl["wukv"]).reshape(
        b, s, a.h_eff, a.d_nope + a.d_v)
    kn, v = kv[..., : a.d_nope], kv[..., a.d_nope:]
    kr = apply_rope(jnp.einsum("bsd,dr->bsr", x, pl["wkr"])[:, :, None, :],
                    positions, a.rope_theta)                            # shared head
    k = jnp.concatenate([kn, jnp.broadcast_to(kr, qn.shape[:3] + (a.d_rope,))], -1)
    q_full = jnp.concatenate([qn, qr], -1)
    out = gqa_attention(q_full, k, v, q_positions=positions, k_positions=positions,
                        window=a.window, q_chunk=cfg.q_chunk)
    out = _head_mask(a, out)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, -1), pl["wo"])
    cache = {"ckv": ckv, "kr": kr[:, :, 0, :]} if collect_cache else None
    return out, cache


def _ffn_block(pl, x, cfg: LMConfig):
    fp = pl["ffn"]
    if cfg.moe is not None:
        if cfg.moe.mesh is not None:
            from .moe import moe_ffn_sharded
            return moe_ffn_sharded(x, fp, cfg.moe)
        return moe_ffn(x, fp, cfg.moe)
    from .layers import swiglu
    return swiglu(x, fp["wg"], fp["wu"], fp["wo"]), jnp.float32(0)


def forward(params, tokens, cfg: LMConfig, collect_cache: bool = False):
    """tokens [B, S] -> (x_final [B, S, d], aux_loss, cache or None)."""
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = _constrain(jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype), cfg)

    def body(carry, pl):
        x = _constrain(carry, cfg)
        h, cache = _attn_block(pl, rms_norm(x, pl["ln1"], cfg.norm_eps), positions,
                               cfg, collect_cache)
        x = _constrain(x + h, cfg)
        h, aux = _ffn_block(pl, rms_norm(x, pl["ln2"], cfg.norm_eps), cfg)
        x = x + h
        return x, (aux, cache) if collect_cache else aux

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, ys = jax.lax.scan(body_fn, x, params["layers"])
    else:
        ys = []
        for i in range(cfg.n_layers):
            pl = jax.tree.map(lambda a: a[i], params["layers"])
            x, y = body_fn(x, pl)
            ys.append(y)
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    if collect_cache:
        aux, cache = ys
    else:
        aux, cache = ys, None
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.sum(aux), cache


def loss_fn(params, batch, cfg: LMConfig):
    x, aux, _ = forward(params, batch["tokens"], cfg)
    x = _constrain(x, cfg)
    ce = cross_entropy_loss(x, params["lm_head"], batch["labels"], cfg.loss_chunks)
    return ce + cfg.aux_loss_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------- serving
def cache_len(cfg: LMConfig, max_seq: int) -> int:
    w = cfg.attn.window
    return min(max_seq, w) if w else max_seq


def init_cache(cfg: LMConfig, batch: int, max_seq: int):
    a = cfg.attn
    t = cache_len(cfg, max_seq)
    if a.kind == "mla":
        return {"ckv": jnp.zeros((cfg.n_layers, batch, t, a.kv_lora), cfg.dtype),
                "kr": jnp.zeros((cfg.n_layers, batch, t, a.d_rope), cfg.dtype)}
    return {"k": jnp.zeros((cfg.n_layers, batch, t, a.kv_eff, a.d_head), cfg.dtype),
            "v": jnp.zeros((cfg.n_layers, batch, t, a.kv_eff, a.d_head), cfg.dtype)}


def prefill(params, tokens, cfg: LMConfig, max_seq: int):
    """tokens [B, S] -> (cache filled for S positions, last-token logits)."""
    x, _, cache = forward(params, tokens, cfg, collect_cache=True)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["lm_head"]).astype(jnp.float32)
    t = cache_len(cfg, max_seq)
    s = tokens.shape[1]

    def place(c):  # [L, B, S, ...] -> [L, B, T, ...] at ring slots (slot = pos % T)
        if s >= t:
            return jnp.roll(c[:, :, s - t:], shift=s % t, axis=2)
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, t - s)
        return jnp.pad(c, pad)

    return jax.tree.map(place, cache), logits


def decode_step(params, cache, token, pos, cfg: LMConfig):
    """One decode step.  token [B], pos scalar int32 (next position index).
    Returns (logits [B, V], updated cache)."""
    a = cfg.attn
    b = token.shape[0]
    t = cache["k"].shape[2] if a.kind == "gqa" else cache["ckv"].shape[2]
    slot = pos % t if a.window else jnp.minimum(pos, t - 1)
    idx = jnp.arange(t)
    valid = _ring_valid(t, slot, pos) if a.window else idx < pos
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(cfg.dtype)
    if b > 1:
        x = _constrain(x, cfg)
    positions = jnp.full((1,), pos, jnp.int32)

    def body(x, inp):
        pl, cl = inp
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        if a.kind == "gqa":
            q = jnp.einsum("bsd,dh->bsh", h, pl["wq"]).reshape(b, a.h_eff, a.d_head)
            k = jnp.einsum("bsd,dh->bsh", h, pl["wk"]).reshape(b, a.kv_eff, a.d_head)
            v = jnp.einsum("bsd,dh->bsh", h, pl["wv"]).reshape(b, a.kv_eff, a.d_head)
            q = apply_rope(q[:, None], positions, a.rope_theta)[:, 0]
            k = apply_rope(k[:, None], positions, a.rope_theta)[:, 0]
            kc = jax.lax.dynamic_update_index_in_dim(cl["k"], k, slot, 1)
            vc = jax.lax.dynamic_update_index_in_dim(cl["v"], v, slot, 1)
            attn = decode_attention(q, kc, vc, valid=valid | (idx == slot))
            attn = _head_mask(a, attn)
            out = jnp.einsum("bh,hd->bd", attn.reshape(b, -1), pl["wo"])
            new_cl = {"k": kc, "v": vc}
        else:  # absorbed MLA decode: attention entirely in latent space
            cq = jnp.einsum("bsd,dr->bsr", h, pl["wdq"])
            q = jnp.einsum("bsr,rh->bsh", cq, pl["wuq"]).reshape(
                b, 1, a.h_eff, a.d_nope + a.d_rope)
            qn, qr = q[..., : a.d_nope], apply_rope(q[..., a.d_nope:], positions,
                                                    a.rope_theta)
            ckv_new = jnp.einsum("bsd,dr->bsr", h, pl["wdkv"])[:, 0]
            kr_new = apply_rope(jnp.einsum("bsd,dr->bsr", h, pl["wkr"]),
                                positions, a.rope_theta)[:, 0]
            ckv = jax.lax.dynamic_update_index_in_dim(cl["ckv"], ckv_new, slot, 1)
            kr = jax.lax.dynamic_update_index_in_dim(cl["kr"], kr_new, slot, 1)
            wuk = pl["wukv"].reshape(a.kv_lora, a.h_eff, a.d_nope + a.d_v)
            q_lat = jnp.einsum("bhn,rhn->bhr", qn[:, 0], wuk[..., : a.d_nope])
            scores = (jnp.einsum("bhr,btr->bht", q_lat, ckv)
                      + jnp.einsum("bhp,btp->bht", qr[:, 0], kr)).astype(jnp.float32)
            scores *= (a.d_nope + a.d_rope) ** -0.5
            scores = jnp.where((valid | (idx == slot))[None, None], scores, NEG_INF)
            p = jax.nn.softmax(scores, axis=-1).astype(cfg.dtype)
            o_lat = jnp.einsum("bht,btr->bhr", p, ckv)
            o = jnp.einsum("bhr,rhv->bhv", o_lat, wuk[..., a.d_nope:])
            o = _head_mask(a, o)
            out = jnp.einsum("bh,hd->bd", o.reshape(b, -1), pl["wo"])
            new_cl = {"ckv": ckv, "kr": kr}
        x = x + out[:, None]
        hf, _ = _ffn_block(pl, rms_norm(x, pl["ln2"], cfg.norm_eps), cfg)
        return x + hf, new_cl

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        new_layers = []
        for i in range(cfg.n_layers):
            pl = jax.tree.map(lambda v: v[i], params["layers"])
            cl = jax.tree.map(lambda v: v[i], cache)
            x, ncl = body(x, (pl, cl))
            new_layers.append(ncl)
        new_cache = jax.tree.map(lambda *vs: jnp.stack(vs), *new_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["lm_head"]).astype(jnp.float32)
    return logits, new_cache


def _ring_valid(t, slot, pos):
    """Ring-buffer validity: slots written in the last min(pos, t) steps."""
    idx = jnp.arange(t)
    filled = jnp.minimum(pos, t)
    age = (slot - idx) % t          # 0 = current write slot, 1 = previous, ...
    return (age > 0) & (age <= filled)
