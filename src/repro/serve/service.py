"""The query service: generational index + cache behind a batch lookup API.

``StreamingNGramService`` (moved out of ``launch/serve_ngrams.py``, which
keeps lazy re-exports) owns one :class:`~repro.index.merge.GenerationalIndex`
and one :class:`~repro.serve.cache.LRUQueryCache` and exposes the three
operations every frontend layer composes:

  * ``ingest(tokens)``        -- job on the delta -> fresh L0 segment swap
  * ``lookup(grams, lengths)``-- batched point counts (cache-first)
  * ``continuations(...)``    -- batched top-k completion rows (cache-first)

plus the split ``_submit_lookup`` / ``_collect_lookup`` pair the
double-buffered paths (``lookup_pipelined`` here, the continuous batcher in
:mod:`repro.serve.batcher`) ride to overlap host work with device execution.

``microbatch_drive`` and ``make_query_stream`` are the synthetic-workload
helpers the CLI drivers and benchmarks share; they live with the service so
the launch script stays a thin argument-parsing shell.

All jax-touching imports are deferred into the methods: importing this module
must not initialize the backend (the ``--devices`` drivers set ``XLA_FLAGS``
first).
"""
from __future__ import annotations

import time

from .cache import LRUQueryCache

__all__ = ["StreamingNGramService", "microbatch_drive", "make_query_stream"]


def make_query_stream(stats, *, n_queries: int, sigma: int, vocab_size: int,
                      miss_frac: float, seed: int = 0):
    """(grams [N, sigma], lengths [N]): sampled index rows + uniform-random misses.

    Hits are drawn cf-weighted (hot grams are queried more -- the serving-load
    analogue of the corpus Zipf skew the shuffle partitioner absorbs)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    grams = np.zeros((n_queries, sigma), np.int32)
    lengths = np.zeros((n_queries,), np.int32)
    n_rows = len(stats)
    is_miss = rng.random(n_queries) < miss_frac
    if n_rows:
        p = np.asarray(stats.counts, np.float64)
        p = p / p.sum()
        rows = rng.choice(n_rows, size=n_queries, p=p)
        grams = np.asarray(stats.grams)[rows].astype(np.int32)
        lengths = np.asarray(stats.lengths)[rows].astype(np.int32)
    miss_len = rng.integers(1, sigma + 1, n_queries).astype(np.int32)
    miss_g = rng.integers(1, vocab_size + 1, (n_queries, sigma)).astype(np.int32)
    miss_g *= np.arange(sigma)[None, :] < miss_len[:, None]
    grams = np.where(is_miss[:, None], miss_g, grams)
    lengths = np.where(is_miss, miss_len, lengths)
    return grams, lengths


class StreamingNGramService:
    """Generational index + query cache behind a batch lookup/completion API.

    ``ingest`` streams new document tokens through the ordinary SUFFIX-sigma
    job phases into a fresh L0 segment (``GenerationalIndex.ingest`` handles
    the size-tiered merges); queries between swaps hit the LRU cache first and
    only the residual miss rows go to the device, padded to a power-of-two
    sub-batch so the compiled-program cache stays small.
    """

    #: cache/coalescing key of one point lookup -- shared with the frontend's
    #: in-flight duplicate coalescing, which must key identically
    @staticmethod
    def lookup_key(gram, length: int):
        return (int(length), gram[:max(int(length), 0)].tobytes())

    #: cache/coalescing key of one top-k continuation query
    @staticmethod
    def continuation_key(gram, length: int, k: int):
        return ("c", int(k), int(length), gram[:max(int(length), 0)].tobytes())

    def __init__(self, cfg, *, compress: bool = False, block_size: int = 4,
                 use_kernels: bool = False, cache_capacity: int = 65536,
                 size_ratio: int = 4, route: str = "kway",
                 wave_tokens: int | None = None, mesh=None,
                 axis_name: str = "data", overlap: bool = True):
        from repro.index import GenerationalIndex
        self.cfg = cfg
        self.use_kernels = use_kernels
        self.wave_tokens = wave_tokens
        self.mesh = mesh
        self.axis_name = axis_name
        self.overlap = overlap
        self.gen = GenerationalIndex(
            sigma=cfg.sigma, vocab_size=cfg.vocab_size, compress=compress,
            block_size=block_size, size_ratio=size_ratio, route=route,
            use_kernels=use_kernels)
        self.cache = LRUQueryCache(cache_capacity)
        self._wave_ex = None

    def ingest(self, tokens) -> dict:
        """Run the job phases over a token delta and swap the new L0 in.

        With ``wave_tokens`` set, the delta streams through the wave engine
        (``repro.pipeline.WaveExecutor``) instead of one monolithic job: the
        device only ever holds one wave of job state, so a delta (or an
        initial corpus) larger than device memory ingests end to end.  A
        ``mesh`` shards the work over its devices -- each wave's stage
        pipeline when waves are on, the ordinary distributed job otherwise.
        The resulting stats are bit-identical every way.
        """
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        with obs_trace.span("svc.ingest") as sp:
            t0 = time.perf_counter()
            if self.wave_tokens is not None:
                if self._wave_ex is None:  # reuse: compiled programs carry over
                    from repro.pipeline import WaveExecutor
                    self._wave_ex = WaveExecutor(self.cfg,
                                                 wave_tokens=self.wave_tokens,
                                                 mesh=self.mesh,
                                                 axis_name=self.axis_name,
                                                 overlap=self.overlap)
                stats = self._wave_ex.run(tokens)
            else:
                from repro.core import run_job
                stats = run_job(tokens, self.cfg, mesh=self.mesh,
                                axis_name=self.axis_name)
            t_job = time.perf_counter() - t0
            obs_metrics.get_registry().merge_job_counters(stats.counters)
            t0 = time.perf_counter()
            report = self.gen.ingest(stats)
            report.update(job_s=t_job, ingest_s=time.perf_counter() - t0,
                          segments=self.gen.n_segments,
                          waves=stats.counters.get("waves", 1))
            if sp:
                sp.set(tokens=len(tokens), rows=report.get("ingested_rows"),
                       waves=report["waves"])
        return report

    def _submit_lookup(self, grams, lengths) -> dict:
        """Cache consult + async device dispatch of the miss rows.

        The returned record holds the *unmaterialized* device result; pairing
        ``_submit_lookup`` of batch i+1 with ``_collect_lookup`` of batch i is
        the double-buffered hot path (cache fill rides the collect side, one
        batch behind the device)."""
        import numpy as np
        g = np.asarray(grams, np.int32)
        ln = np.asarray(lengths, np.int32)
        gen_id = self.gen.generation
        out = np.zeros((g.shape[0],), np.uint32)
        miss = []
        keys = []
        for i in range(g.shape[0]):
            key = self.lookup_key(g[i], int(ln[i]))
            v = self.cache.get(key, gen_id)
            if v is None:
                miss.append(i)
                keys.append(key)
            else:
                out[i] = v
        dev, pad = None, 0
        if miss:
            from repro.index.query import lookup_deferred
            m = len(miss)
            pad = max(1 << (m - 1).bit_length(), 16)
            mg = np.zeros((pad, g.shape[1]), np.int32)
            mln = np.zeros((pad,), np.int32)
            mg[:m] = g[miss]
            mln[:m] = ln[miss]
            # per-segment deferred dispatches: nothing is materialized here,
            # even with several live generations
            dev = lookup_deferred(self.gen, mg, mln,
                                  use_kernels=self.use_kernels)
        return {"out": out, "miss": miss, "keys": keys, "dev": dev,
                "pad": pad, "gen": gen_id}

    def _collect_lookup(self, rec: dict):
        if rec["dev"] is not None:
            from repro.index.query import collect_lookup
            cf = collect_lookup(rec["dev"], rec["pad"])[:len(rec["miss"])]
            rec["out"][rec["miss"]] = cf
            for key, v in zip(rec["keys"], cf):
                self.cache.put(key, rec["gen"], int(v))
        return rec["out"]

    def lookup(self, grams, lengths):
        """Point counts [B] uint32; cache hits never touch the device."""
        return self._collect_lookup(self._submit_lookup(grams, lengths))

    def lookup_pipelined(self, batches) -> list:
        """Drive (grams, lengths) batches double-buffered: batch i+1 is
        dispatched before batch i's device result is materialized, so host
        batching/cache work overlaps device execution with no
        ``block_until_ready`` anywhere."""
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        from repro.pipeline.executor import DoubleBufferedDriver
        drv = DoubleBufferedDriver(self._submit_lookup,
                                   collect=self._collect_lookup)
        reg = obs_metrics.get_registry()
        inflight = reg.gauge("serve.inflight")
        results: list = []
        with obs_trace.span("serve.pipelined") as sp:
            for g, ln in batches:
                inflight.add(1)               # one submitted, maybe one live
                res, _ = drv.submit(g, ln)
                if res is not None:
                    inflight.add(-1)
                    results.append(res)
            res, _ = drv.drain()
            inflight.set(0)
            if res is not None:
                results.append(res)
            if sp:
                sp.set(batches=len(batches))
        return results

    def continuations(self, prefixes, p_len, *, k: int = 8):
        """Top-k completion rows [B, 2+2k] uint32 (nd | total | terms | cfs)."""
        import numpy as np
        from repro.index import continuations as idx_cont
        pg = np.asarray(prefixes, np.int32)
        pl = np.asarray(p_len, np.int32)
        gen_id = self.gen.generation
        out = np.zeros((pg.shape[0], 2 + 2 * k), np.uint32)
        miss = []
        for i in range(pg.shape[0]):
            key = self.continuation_key(pg[i], int(pl[i]), k)
            v = self.cache.get(key, gen_id)
            if v is None:
                miss.append(i)
            else:
                out[i] = v
        if miss:
            m = len(miss)
            pad = max(1 << (m - 1).bit_length(), 16)
            mg = np.zeros((pad, pg.shape[1]), np.int32)
            mln = np.zeros((pad,), np.int32)
            mg[:m] = pg[miss]
            mln[:m] = pl[miss]
            nd, tot, terms, cfs = [np.asarray(x) for x in idx_cont(
                self.gen, mg, mln, k=k, use_kernels=self.use_kernels)]
            rows = np.concatenate([nd[:m, None], tot[:m, None], terms[:m],
                                   cfs[:m]], axis=1).astype(np.uint32)
            out[miss] = rows
            for j, i in enumerate(miss):
                key = self.continuation_key(pg[i], int(pl[i]), k)
                self.cache.put(key, gen_id, rows[j])
        return out


def microbatch_drive(answer, grams, lengths, batch: int, *, warmup: int = 2,
                     hist_name: str = "drive.batch_seconds"):
    """Feed the stream through ``answer`` in fixed micro-batches; (qps, lat[s]).

    Timed batches also land in the ``hist_name`` registry histogram, so the
    p50/p95/p99 the production frontend needs come out of the metrics export
    as well as the returned sample list.
    """
    import numpy as np
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    n = grams.shape[0]
    n_batches = -(-n // batch)
    pad = n_batches * batch - n
    g = np.pad(grams, ((0, pad), (0, 0)))
    ln = np.pad(lengths, (0, pad))
    for i in range(min(warmup, n_batches)):      # compile + cache warm
        answer(g[i * batch:(i + 1) * batch], ln[i * batch:(i + 1) * batch])
    hist = obs_metrics.get_registry().histogram(hist_name)
    lat = []
    with obs_trace.span("serve.drive") as sp:
        t_all = time.perf_counter()
        for i in range(n_batches):
            t0 = time.perf_counter()
            answer(g[i * batch:(i + 1) * batch], ln[i * batch:(i + 1) * batch])
            dt = time.perf_counter() - t0
            lat.append(dt)
            hist.observe(dt)
        qps = n / (time.perf_counter() - t_all)
        if sp:
            sp.set(batch=batch, n_batches=n_batches, qps=int(qps))
    return qps, lat
