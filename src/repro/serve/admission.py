"""Admission control: priority classes, tenant quotas, load shedding.

The layer in front of the batcher queue.  Policy, in verdict order:

1. **Load shedding by queue depth.**  ``queue_budget`` is the soft budget:
   past it, only the highest priority class (level 0) is admitted; past the
   ``hard_limit`` everything sheds.  Shedding keeps the queue -- and therefore
   time-to-first-byte of admitted requests -- bounded under overload: offered
   load beyond capacity turns into fast 503s, not latency collapse.
2. **Per-tenant token buckets.**  Each tenant refills at ``quota_rate``
   requests/second up to ``quota_burst``; an empty bucket is a quota
   rejection (HTTP 429), independent of system load.  Shedding is checked
   first so an overloaded system does not silently burn tenant tokens.

The controller is pure policy: it returns verdicts and never touches queues
or counters itself (the frontend owns those side effects), so every decision
path is deterministic under an injected clock.
"""
from __future__ import annotations

import time

__all__ = ["TokenBucket", "AdmissionController", "PRIORITIES",
           "ADMIT", "SHED", "QUOTA"]

#: priority classes, lower level = more important; level 0 survives the soft
#: budget (the "interactive" tier of the two-class serving convention)
PRIORITIES: dict[str, int] = {"interactive": 0, "batch": 1}

ADMIT = "admit"
SHED = "shed"
QUOTA = "quota"


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` capacity."""

    __slots__ = ("rate", "burst", "tokens", "_clock", "_last")

    def __init__(self, rate: float, burst: float, *, clock=time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError("rate and burst must be positive")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._clock = clock
        self._last = clock()

    def try_take(self, n: float = 1.0) -> bool:
        now = self._clock()
        self.tokens = min(self.burst, self.tokens + (now - self._last) * self.rate)
        self._last = now
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False


class AdmissionController:
    """Verdict machine for one frontend queue.

    ``quota_rate=None`` disables tenant quotas entirely (every tenant
    unlimited); ``hard_limit`` defaults to four soft budgets.
    """

    def __init__(self, *, queue_budget: int = 512, hard_limit: int | None = None,
                 quota_rate: float | None = None, quota_burst: float | None = None,
                 priorities: dict[str, int] | None = None, clock=time.monotonic):
        if queue_budget < 0:
            raise ValueError("queue_budget must be >= 0")
        self.queue_budget = int(queue_budget)
        self.hard_limit = int(4 * queue_budget if hard_limit is None
                              else hard_limit)
        if self.hard_limit < self.queue_budget:
            raise ValueError("hard_limit must be >= queue_budget")
        self.quota_rate = quota_rate
        self.quota_burst = quota_burst if quota_burst is not None else \
            (2 * quota_rate if quota_rate is not None else None)
        self.priorities = dict(PRIORITIES if priorities is None else priorities)
        self._clock = clock
        self._buckets: dict[str, TokenBucket] = {}

    def level(self, priority: str) -> int:
        """Numeric level of a priority class name (KeyError on unknown)."""
        return self.priorities[priority]

    def bucket(self, tenant: str) -> TokenBucket | None:
        if self.quota_rate is None:
            return None
        b = self._buckets.get(tenant)
        if b is None:
            b = self._buckets[tenant] = TokenBucket(
                self.quota_rate, self.quota_burst, clock=self._clock)
        return b

    def admit(self, *, tenant: str, level: int, queue_depth: int) -> str:
        """One verdict: :data:`ADMIT`, :data:`SHED`, or :data:`QUOTA`."""
        if queue_depth >= self.hard_limit:
            return SHED
        if queue_depth >= self.queue_budget and level > 0:
            return SHED
        b = self.bucket(tenant)
        if b is not None and not b.try_take():
            return QUOTA
        return ADMIT

    def describe(self) -> dict:
        """JSON-able config summary for the topology endpoint."""
        return {"queue_budget": self.queue_budget,
                "hard_limit": self.hard_limit,
                "quota_rate": self.quota_rate,
                "quota_burst": self.quota_burst,
                "priorities": dict(self.priorities)}
