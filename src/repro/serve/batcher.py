"""Continuous batcher: concurrent requests -> fixed-shape device batches.

The device side of the serving stack wants what ``microbatch_drive`` fakes:
fixed-shape batches arriving back to back.  Real traffic is single queries
landing on many transport threads.  The batcher sits between them:

  * requests enqueue into per-(priority, kind, k) FIFO lanes; the flush loop
    always picks the highest-priority lane with the oldest head request;
  * a flush takes up to the largest **padding bucket** of live requests and
    pads the batch up to the smallest bucket that holds them
    (:func:`select_bucket`) -- a handful of static shapes keeps the compiled
    program cache small while partial batches stay cheap;
  * a partially filled bucket flushes when its oldest request has waited
    ``deadline_s`` -- the wait is a condition-variable sleep with a computed
    timeout, never a poll loop (``stats()["wait_cycles"]`` stays O(flushes),
    regression-tested);
  * flushes ride the service's split submit/collect discipline (the same
    double-buffered contract as ``DoubleBufferedDriver`` /
    ``StreamingNGramService._submit_lookup``): batch i+1 is dispatched before
    batch i's device result is materialized, so queue drain and host delivery
    overlap device execution;
  * a cancelled (or admission-shed) request is dropped at pop time and
    **never occupies a padded slot in a live device batch** -- the batch is
    built from live requests only, and the bucket is chosen after the filter.

The batcher knows nothing about HTTP, admission, or jax: it drives an
``executor`` object with two methods::

    rec  = executor.submit(kind, k, grams, lengths)   # async dispatch
    rows = executor.collect(rec)                      # materialize [B(, R)]

``repro.serve.frontend.ServiceExecutor`` adapts ``StreamingNGramService``;
tests drive plain recording stubs.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError

__all__ = ["Request", "ContinuousBatcher", "select_bucket",
           "DEFAULT_BUCKETS", "FILL_BOUNDARIES"]

#: default padding-bucket ladder (slots per device batch)
DEFAULT_BUCKETS = (16, 64, 256)

#: fill-ratio histogram edges (fractions of the chosen bucket)
FILL_BOUNDARIES = tuple(i / 16 for i in range(1, 17))


def select_bucket(n_live: int, buckets) -> int:
    """Smallest padding bucket holding ``n_live`` rows (deterministic).

    The largest bucket caps the batch size -- the flush loop never pops more
    than ``buckets[-1]`` live requests, so the cap is always sufficient.
    """
    if n_live < 1:
        raise ValueError("a flush needs at least one live request")
    for b in buckets:
        if n_live <= b:
            return b
    return buckets[-1]


class Request:
    """One admitted query: its slot key, payload future, and coalesced riders.

    ``future`` resolves to the request's payload row (uint32 scalar for
    lookups, the packed ``[2+2k]`` continuation row for top-k).  Duplicate
    in-flight queries attach follower futures via :meth:`attach`; delivery
    fans the *same* payload object out to all of them, so coalesced answers
    are bit-identical by construction.
    """

    __slots__ = ("kind", "gram", "length", "k", "tenant", "priority", "key",
                 "future", "followers", "seq", "t_enqueue", "cancelled",
                 "_sealed", "_rlock")

    def __init__(self, kind: str, gram, length: int, *, k: int = 8,
                 tenant: str = "default", priority: int = 0, key=None):
        if kind not in ("lookup", "topk"):
            raise ValueError(f"unknown request kind {kind!r}")
        self.kind = kind
        self.gram = gram
        self.length = int(length)
        self.k = int(k)
        self.tenant = tenant
        self.priority = int(priority)
        self.key = key
        self.future: Future = Future()
        self.followers: list[Future] = []
        self.seq = -1
        self.t_enqueue = 0.0
        self.cancelled = False
        self._sealed = False
        self._rlock = threading.Lock()

    def attach(self, future: Future) -> bool:
        """Ride this request's answer; False once delivery already started."""
        with self._rlock:
            if self._sealed or self.cancelled:
                return False
            self.followers.append(future)
            return True

    def cancel(self) -> bool:
        """Drop the request before it reaches a device batch.

        Refused when followers already ride it (they still need the payload)
        or when delivery has begun.  A cancelled request is skipped at flush
        time -- it never pads a live batch.
        """
        with self._rlock:
            if self._sealed or self.followers:
                return False
            if not self.future.cancel():
                return False
            self.cancelled = True
            return True

    def deliver(self, payload=None, error: BaseException | None = None) -> None:
        with self._rlock:
            self._sealed = True
            targets = [self.future, *self.followers]
        for f in targets:
            try:
                if error is not None:
                    f.set_exception(error)
                else:
                    f.set_result(payload)
            except InvalidStateError:
                pass                      # racing cancel: nobody is waiting


class ContinuousBatcher:
    """Queue-fed flush loop coalescing requests into padded device batches.

    ``autostart=False`` skips the background thread; tests then drive
    :meth:`flush_once` / :meth:`collect_inflight` deterministically.  The
    injectable ``clock`` feeds deadlines and latency accounting.
    """

    def __init__(self, executor, *, buckets=DEFAULT_BUCKETS,
                 deadline_s: float = 2e-3, clock=time.perf_counter,
                 autostart: bool = True):
        b = tuple(sorted(int(x) for x in buckets))
        if not b or b[0] < 1 or len(set(b)) != len(b):
            raise ValueError("buckets must be distinct positive sizes")
        self.executor = executor
        self.buckets = b
        self.deadline_s = float(deadline_s)
        self.clock = clock
        self._cond = threading.Condition()
        self._lanes: dict[tuple, deque] = {}
        self._depth = 0
        self._seq = itertools.count()
        self._inflight = None            # (rec, batch, bucket) | None
        self._alive = True
        self._stats = {"batches": 0, "requests": 0, "wait_cycles": 0,
                       "cancelled_dropped": 0, "padded_slots": 0}
        self._thread = None
        if autostart:
            self._thread = threading.Thread(target=self._loop,
                                            name="repro-batcher", daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- producers

    @property
    def depth(self) -> int:
        """Queued (not yet flushed) requests -- the admission layer's signal."""
        return self._depth

    def enqueue(self, req: Request) -> None:
        from repro.obs import metrics as obs_metrics
        with self._cond:
            if not self._alive:
                raise RuntimeError("batcher is stopped")
            req.seq = next(self._seq)
            req.t_enqueue = self.clock()
            lane = (req.priority, req.kind, req.k)
            q = self._lanes.get(lane)
            if q is None:
                q = self._lanes[lane] = deque()
            q.append(req)
            self._depth += 1
            obs_metrics.get_registry().gauge("frontend.queue_depth").set(
                self._depth)
            self._cond.notify()

    def stop(self) -> None:
        """Flush every queued request, drain the in-flight batch, join."""
        with self._cond:
            self._alive = False
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        else:                            # manual mode: drain synchronously
            while self.flush_once(force=True) is not None:
                pass
            self.collect_inflight()

    def stats(self) -> dict:
        with self._cond:
            return dict(self._stats, depth=self._depth)

    # ------------------------------------------------------------ flush loop

    def _prune_and_peek(self):
        """(lane, head, n_queued) of the best lane, dropping cancelled heads.

        Best = lowest priority level, then oldest head request.  Caller holds
        the lock.
        """
        best = None
        for lane, q in self._lanes.items():
            while q and q[0].cancelled:
                q.popleft()
                self._depth -= 1
                self._stats["cancelled_dropped"] += 1
            if not q:
                continue
            cand = (lane[0], q[0].seq)
            if best is None or cand < best[0]:
                best = (cand, lane, q[0], len(q))
        if best is None:
            return None
        return best[1], best[2], best[3]

    def _pop_batch(self, lane) -> list:
        """Up to ``buckets[-1]`` live requests off one lane; caller holds lock.

        Cancelled requests are dropped here -- after this filter the batch
        holds live requests only, so no shed/cancelled slot is ever padded
        into the device batch.
        """
        from repro.obs import metrics as obs_metrics
        q = self._lanes[lane]
        batch: list = []
        while q and len(batch) < self.buckets[-1]:
            req = q.popleft()
            self._depth -= 1
            if req.cancelled:
                self._stats["cancelled_dropped"] += 1
                continue
            batch.append(req)
        obs_metrics.get_registry().gauge("frontend.queue_depth").set(
            self._depth)
        return batch

    def _next_action(self):
        """Block until there is work: ("flush", batch) | ("drain", None) | None.

        The deadline wait is ``Condition.wait(timeout)`` -- new arrivals
        notify, the timeout fires the partial-bucket flush, and nothing spins.
        """
        with self._cond:
            while True:
                choice = self._prune_and_peek()
                if choice is None:
                    if self._inflight is not None:
                        return "drain", None
                    if not self._alive:
                        return None
                    self._cond.wait()
                    continue
                lane, head, n_queued = choice
                now = self.clock()
                due = head.t_enqueue + self.deadline_s
                if (n_queued >= self.buckets[-1] or now >= due
                        or not self._alive):
                    batch = self._pop_batch(lane)
                    if not batch:        # every queued request was cancelled
                        continue
                    return "flush", batch
                if self._inflight is not None:
                    # collect the dispatched batch while this one's deadline
                    # accrues: delivery overlaps the queue fill
                    return "drain", None
                self._stats["wait_cycles"] += 1
                self._cond.wait(max(due - now, 0.0))

    def _loop(self) -> None:
        while True:
            action = self._next_action()
            if action is None:
                return
            op, batch = action
            if op == "flush":
                self._dispatch(batch)
            else:
                self.collect_inflight()

    # -------------------------------------------------------- dispatch side

    def _dispatch(self, batch: list) -> None:
        """Pad live requests into a bucket and dispatch; collect the previous
        in-flight batch afterwards (the double-buffered submit/collect order:
        device work on this batch overlaps host delivery of the last one)."""
        import numpy as np
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        kind, k = batch[0].kind, batch[0].k
        m = len(batch)
        bucket = select_bucket(m, self.buckets)
        width = int(np.asarray(batch[0].gram).shape[0])
        grams = np.zeros((bucket, width), np.int32)
        lengths = np.zeros((bucket,), np.int32)
        for i, req in enumerate(batch):
            grams[i] = req.gram
            lengths[i] = req.length
        reg = obs_metrics.get_registry()
        reg.counter("frontend.batches").add(1)
        reg.histogram("frontend.batch_fill", FILL_BOUNDARIES).observe(
            m / bucket)
        with self._cond:
            self._stats["batches"] += 1
            self._stats["requests"] += m
            self._stats["padded_slots"] += bucket - m
        with obs_trace.span("serve.flush") as sp:
            if sp:
                sp.set(kind=kind, live=m, bucket=bucket)
            try:
                rec = self.executor.submit(kind, k, grams, lengths)
            except Exception as e:       # deliver, keep the loop alive
                for req in batch:
                    req.deliver(error=e)
                return
        prev, self._inflight = self._inflight, (rec, batch)
        if prev is not None:
            self._collect(prev)

    def _collect(self, entry) -> None:
        rec, batch = entry
        try:
            rows = self.executor.collect(rec)
        except Exception as e:
            for req in batch:
                req.deliver(error=e)
            return
        for i, req in enumerate(batch):
            req.deliver(rows[i])

    def collect_inflight(self) -> None:
        """Materialize and deliver the in-flight batch, if any."""
        entry, self._inflight = self._inflight, None
        if entry is not None:
            self._collect(entry)

    # ------------------------------------------------------ manual test mode

    def flush_once(self, *, force: bool = False):
        """One deterministic flush step (manual mode): the batch popped, or
        ``None`` when nothing is due.  ``force=True`` ignores deadline/fill."""
        with self._cond:
            choice = self._prune_and_peek()
            if choice is None:
                return None
            lane, head, n_queued = choice
            due = head.t_enqueue + self.deadline_s
            if not (force or n_queued >= self.buckets[-1]
                    or self.clock() >= due):
                return None
            batch = self._pop_batch(lane)
        if not batch:
            return None
        self._dispatch(batch)
        return batch
