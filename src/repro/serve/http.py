"""Stdlib HTTP/SSE transport for the query frontend (no dependencies).

Endpoints (JSON in, JSON out; every query answer is produced by the exact
same ``StreamingNGramService`` code path a direct caller would hit, so HTTP
responses are bit-identical to in-process calls):

  POST /v1/lookup    {"gram": [ids]} or {"grams": [[ids]...], "lengths": [...]}
                     -> {"count": n} / {"counts": [...]}
  POST /v1/topk      {"prefix": [ids], "k": 8}
                     -> {"n_distinct", "total", "terms", "counts"}
  POST /v1/complete  {"prefix": [ids], "steps": 16, "k": 8}  (SSE)
                     -> data: {"step", "term", "count"} events, then [DONE];
                     greedy continuation over a sliding (sigma-1)-token window
  GET  /v1/system/topology   shard/segment discovery + frontend state
  GET  /healthz              {"status": "ok"}

Admission verdicts map onto status codes: shed -> 503 (+ Retry-After),
tenant quota -> 429.  Priority class and tenant ride the ``X-Priority`` /
``X-Tenant`` headers.  The server is a ``ThreadingHTTPServer``: each
connection blocks on its ticket future while the continuous batcher coalesces
all live requests into shared device batches.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

__all__ = ["NGramHTTPServer", "serve_http"]


class _BadRequest(Exception):
    pass


def _int_list(v, what: str) -> list[int]:
    if not isinstance(v, list) or not all(isinstance(x, int) and
                                          not isinstance(x, bool) for x in v):
        raise _BadRequest(f"{what} must be a list of ints")
    return v


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-ngram/1"
    protocol_version = "HTTP/1.1"

    def log_message(self, *args) -> None:          # tests/benchmarks: silent
        pass

    @property
    def frontend(self):
        return self.server.frontend

    # ------------------------------------------------------------- plumbing

    def _send_json(self, code: int, obj: dict, *,
                   extra_headers: dict | None = None) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(n) if n else b""
        try:
            obj = json.loads(raw or b"{}")
        except json.JSONDecodeError as e:
            raise _BadRequest(f"invalid JSON body: {e}") from None
        if not isinstance(obj, dict):
            raise _BadRequest("body must be a JSON object")
        return obj

    def _identity(self) -> tuple[str, str]:
        tenant = self.headers.get("X-Tenant", "default")
        priority = self.headers.get("X-Priority", "interactive")
        if priority not in self.frontend.admission.priorities:
            raise _BadRequest(f"unknown priority class {priority!r}")
        return tenant, priority

    def _reject(self, status: str) -> None:
        if status == "quota":
            self._send_json(429, {"error": "tenant quota exhausted"})
        else:
            self._send_json(503, {"error": "overloaded, request shed"},
                            extra_headers={"Retry-After": "1"})

    # ------------------------------------------------------------- GET side

    def do_GET(self) -> None:
        if self.path == "/healthz":
            self._send_json(200, {"status": "ok"})
        elif self.path == "/v1/system/topology":
            self._send_json(200, self.frontend.topology())
        else:
            self._send_json(404, {"error": f"unknown path {self.path}"})

    # ------------------------------------------------------------ POST side

    def do_POST(self) -> None:
        try:
            body = self._read_body()
            tenant, priority = self._identity()
            if self.path == "/v1/lookup":
                self._lookup(body, tenant, priority)
            elif self.path == "/v1/topk":
                self._topk(body, tenant, priority)
            elif self.path == "/v1/complete":
                self._complete(body, tenant, priority)
            else:
                self._send_json(404, {"error": f"unknown path {self.path}"})
        except _BadRequest as e:
            self._send_json(400, {"error": str(e)})
        except BrokenPipeError:                    # client went away mid-SSE
            pass

    def _lookup(self, body: dict, tenant: str, priority: str) -> None:
        fe = self.frontend
        if "grams" in body:
            grams = [_int_list(g, "grams[i]") for g in body["grams"]]
            lengths = body.get("lengths")
            if lengths is not None:
                _int_list(lengths, "lengths")
                if len(lengths) != len(grams):
                    raise _BadRequest("lengths must match grams")
            statuses, payloads = fe.call_many(
                "lookup", [self._pad(g) for g in grams],
                lengths if lengths is not None else [len(g) for g in grams],
                tenant=tenant, priority=priority)
            bad = next((s for s in statuses if s in ("shed", "quota")), None)
            if bad:
                self._reject(bad)
                return
            self._send_json(200, {"counts": [int(p) for p in payloads],
                                  "generation": fe.service.gen.generation})
            return
        gram = _int_list(body.get("gram"), "gram")
        status, payload = fe.call("lookup", gram, tenant=tenant,
                                  priority=priority)
        if status in ("shed", "quota"):
            self._reject(status)
            return
        self._send_json(200, {"count": int(payload),
                              "generation": fe.service.gen.generation})

    def _pad(self, gram: list[int]) -> list[int]:
        # fixed sigma-width row so a mixed-length client batch stacks; the
        # true length rides separately (lengths beyond sigma are exact misses)
        sigma = self.frontend.sigma
        return (gram + [0] * sigma)[:sigma]

    def _topk(self, body: dict, tenant: str, priority: str) -> None:
        fe = self.frontend
        prefix = _int_list(body.get("prefix", []), "prefix")
        k = body.get("k", 8)
        if not isinstance(k, int) or not 1 <= k <= 64:
            raise _BadRequest("k must be an int in [1, 64]")
        status, row = fe.call("topk", prefix, len(prefix), k=k, tenant=tenant,
                              priority=priority)
        if status in ("shed", "quota"):
            self._reject(status)
            return
        self._send_json(200, self._topk_json(row, k, fe))

    @staticmethod
    def _topk_json(row, k: int, fe) -> dict:
        return {"n_distinct": int(row[0]), "total": int(row[1]),
                "terms": [int(t) for t in row[2:2 + k]],
                "counts": [int(c) for c in row[2 + k:2 + 2 * k]],
                "generation": fe.service.gen.generation}

    def _complete(self, body: dict, tenant: str, priority: str) -> None:
        """Greedy streaming completion over SSE: one top-1 query per step.

        The prefix window slides over the last sigma-1 emitted tokens, so
        arbitrarily long completions stream from a fixed-sigma index; each
        step is an ordinary admitted/coalesced/shed frontend request, so an
        overload mid-stream ends the stream with an SSE error event instead
        of stalling the connection.
        """
        fe = self.frontend
        prefix = list(_int_list(body.get("prefix", []), "prefix"))
        steps = body.get("steps", 16)
        k = body.get("k", 8)
        if not isinstance(steps, int) or not 1 <= steps <= 512:
            raise _BadRequest("steps must be an int in [1, 512]")
        if not isinstance(k, int) or not 1 <= k <= 64:
            raise _BadRequest("k must be an int in [1, 64]")
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()

        def event(obj) -> None:
            self.wfile.write(b"data: " + json.dumps(obj).encode() + b"\n\n")
            self.wfile.flush()

        window = fe.sigma - 1
        for step in range(steps):
            ctx = prefix[-window:] if window else []
            status, row = fe.call("topk", ctx, len(ctx), k=k, tenant=tenant,
                                  priority=priority)
            if status in ("shed", "quota"):
                event({"error": status})
                break
            term, count = int(row[2]), int(row[2 + k])
            if count == 0:
                break
            event({"step": step, "term": term, "count": count})
            prefix.append(term)
        self.wfile.write(b"data: [DONE]\n\n")
        self.wfile.flush()
        self.close_connection = True


class NGramHTTPServer(ThreadingHTTPServer):
    """Threaded HTTP server bound to one :class:`QueryFrontend`."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, address, frontend):
        self.frontend = frontend
        super().__init__(address, _Handler)


def serve_http(frontend, host: str = "127.0.0.1", port: int = 8080, *,
               block: bool = True) -> NGramHTTPServer:
    """Start serving; ``block=False`` runs the accept loop on a daemon thread
    and returns the server (``.server_address`` holds the bound port when 0
    was requested; call ``.shutdown()`` to stop)."""
    srv = NGramHTTPServer((host, port), frontend)
    if block:
        try:
            srv.serve_forever()
        except KeyboardInterrupt:                   # pragma: no cover
            pass
        finally:
            srv.server_close()
        return srv
    t = threading.Thread(target=srv.serve_forever, name="repro-http",
                         daemon=True)
    t.start()
    return srv
