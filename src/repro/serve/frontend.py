"""QueryFrontend: admission + duplicate coalescing + batcher on one service.

The request-level API every transport shares (the HTTP handler, the open-loop
benchmark driver, the tests):

    frontend = QueryFrontend(service)
    ticket = frontend.submit("lookup", gram, length, tenant="t0",
                             priority="interactive")
    if ticket.admitted:
        payload = ticket.future.result()

``submit`` is non-blocking: it runs the admission verdict, coalesces
duplicate in-flight queries (keyed exactly like the LRU cache, plus the index
generation so an ingest swap never welds new queries onto stale answers), and
enqueues into the continuous batcher.  ``call`` / ``call_many`` are the
blocking conveniences that also record the ``serve.request`` span and the
time-to-first-byte histogram.

Observability (all under the active registry; names in
``repro.obs.metrics.COUNTER_DOC``):

  counters   frontend.requests / frontend.shed / frontend.quota_rejected /
             frontend.coalesced / frontend.batches
  gauge      frontend.queue_depth
  histograms frontend.batch_fill, frontend.ttfb_seconds
  spans      serve.request (transport thread) over serve.flush ->
             the service's device dispatch (batcher thread)
"""
from __future__ import annotations

import time
from concurrent.futures import Future

from .admission import ADMIT, QUOTA, SHED, AdmissionController
from .batcher import ContinuousBatcher, Request

__all__ = ["QueryFrontend", "ServiceExecutor", "Ticket"]


class ServiceExecutor:
    """Adapt ``StreamingNGramService`` to the batcher's submit/collect pair.

    Lookups ride the service's double-buffered split (``_submit_lookup``
    dispatches asynchronously, ``_collect_lookup`` materializes one batch
    later); top-k goes through ``continuations`` (cache-first, synchronous
    dispatch) and materializes at collect time.
    """

    def __init__(self, service):
        self.service = service

    def submit(self, kind: str, k: int, grams, lengths):
        if kind == "lookup":
            return "lookup", self.service._submit_lookup(grams, lengths)
        return "topk", self.service.continuations(grams, lengths, k=k)

    def collect(self, rec):
        tag, payload = rec
        if tag == "lookup":
            return self.service._collect_lookup(payload)
        return payload


class Ticket:
    """Outcome of one ``submit``: the admission status + payload future."""

    __slots__ = ("status", "future", "request")

    def __init__(self, status: str, future: Future | None, request):
        self.status = status
        self.future = future
        self.request = request

    @property
    def admitted(self) -> bool:
        return self.future is not None


class QueryFrontend:
    """The serving tier in front of one :class:`StreamingNGramService`."""

    def __init__(self, service, *, admission: AdmissionController | None = None,
                 buckets=None, deadline_s: float = 2e-3,
                 clock=time.perf_counter, autostart: bool = True,
                 executor=None):
        import threading
        self.service = service
        self.sigma = int(service.cfg.sigma)
        self.clock = clock
        self.admission = admission if admission is not None else \
            AdmissionController()
        kw = {} if buckets is None else {"buckets": buckets}
        self.batcher = ContinuousBatcher(
            executor if executor is not None else ServiceExecutor(service),
            deadline_s=deadline_s, clock=clock, autostart=autostart, **kw)
        self._lock = threading.Lock()
        self._inflight_keys: dict = {}

    # ------------------------------------------------------------ submission

    def _normalize(self, kind: str, gram, length: int | None, k: int):
        """Gram row [sigma] int32 + clamped length; None = trivially empty."""
        import numpy as np
        g = np.asarray(gram, np.int32).reshape(-1)
        n = int(g.shape[0]) if length is None else int(length)
        row = np.zeros((self.sigma,), np.int32)
        if n > (self.sigma if kind == "lookup" else self.sigma - 1):
            return None, n                # longer than the index holds: miss
        row[:n] = g[:n]
        row[n:] = 0
        return row, n

    def _trivial_payload(self, kind: str, k: int):
        import numpy as np
        if kind == "lookup":
            return np.uint32(0)
        return np.zeros((2 + 2 * k,), np.uint32)

    def submit(self, kind: str, gram, length: int | None = None, *, k: int = 8,
               tenant: str = "default", priority: str = "interactive") -> Ticket:
        """Admission verdict + (if admitted) an enqueued request ticket.

        ``status``: "admitted" | "coalesced" | "shed" | "quota".  Shed and
        quota tickets carry no future -- the caller maps them to 503/429.
        """
        from repro.obs import metrics as obs_metrics
        reg = obs_metrics.get_registry()
        reg.counter("frontend.requests").add(1)
        level = self.admission.level(priority)
        row, n = self._normalize(kind, gram, length, k)
        if row is None:                   # out-of-range length: exact miss
            f: Future = Future()
            f.set_result(self._trivial_payload(kind, k))
            return Ticket("admitted", f, None)
        svc = self.service
        gen_id = svc.gen.generation
        key = (gen_id, svc.lookup_key(row, n)) if kind == "lookup" else \
            (gen_id, svc.continuation_key(row, n, k))
        with self._lock:
            primary = self._inflight_keys.get(key)
            if primary is not None:
                f = Future()
                if primary.attach(f):
                    reg.counter("frontend.coalesced").add(1)
                    return Ticket("coalesced", f, primary)
        verdict = self.admission.admit(tenant=tenant, level=level,
                                       queue_depth=self.batcher.depth)
        if verdict == QUOTA:
            reg.counter("frontend.quota_rejected").add(1)
            return Ticket("quota", None, None)
        if verdict == SHED:
            reg.counter("frontend.shed").add(1)
            return Ticket("shed", None, None)
        assert verdict == ADMIT
        req = Request(kind, row, n, k=k, tenant=tenant, priority=level,
                      key=key)
        with self._lock:
            self._inflight_keys[key] = req
        req.future.add_done_callback(
            lambda _f, key=key, req=req: self._forget(key, req))
        self.batcher.enqueue(req)
        return Ticket("admitted", req.future, req)

    def _forget(self, key, req) -> None:
        with self._lock:
            if self._inflight_keys.get(key) is req:
                del self._inflight_keys[key]

    # ------------------------------------------------------- blocking helpers

    def call(self, kind: str, gram, length: int | None = None, *, k: int = 8,
             tenant: str = "default", priority: str = "interactive",
             timeout: float | None = 30.0):
        """Blocking one-query path: (status, payload | None).

        Wraps the whole request in a ``serve.request`` span and records
        time-to-first-byte (admission -> payload available) into
        ``frontend.ttfb_seconds``.
        """
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        with obs_trace.span("serve.request") as sp:
            t0 = self.clock()
            ticket = self.submit(kind, gram, length, k=k, tenant=tenant,
                                 priority=priority)
            if sp:
                sp.set(kind=kind, status=ticket.status, tenant=tenant)
            if not ticket.admitted:
                return ticket.status, None
            payload = ticket.future.result(timeout)
            obs_metrics.get_registry().histogram(
                "frontend.ttfb_seconds").observe(self.clock() - t0)
        return ticket.status, payload

    def call_many(self, kind: str, grams, lengths=None, *, k: int = 8,
                  tenant: str = "default", priority: str = "interactive",
                  timeout: float | None = 30.0):
        """Submit a client-side batch, then gather: (statuses, payloads).

        Rows that shed or hit quota report their status with a ``None``
        payload; admitted rows resolve in submission order.  The rows coalesce
        into device batches with every other in-flight request -- a client
        batch holds no special scheduling power.
        """
        import numpy as np
        grams = np.asarray(grams, np.int32)
        if lengths is None:
            lengths = [None] * grams.shape[0]
        tickets = [self.submit(kind, g, ln, k=k, tenant=tenant,
                               priority=priority)
                   for g, ln in zip(grams, lengths)]
        payloads = [t.future.result(timeout) if t.admitted else None
                    for t in tickets]
        return [t.status for t in tickets], payloads

    # ------------------------------------------------------------- lifecycle

    def topology(self) -> dict:
        """Shard/segment discovery + live frontend state (the HTTP endpoint)."""
        from repro.index.serve import describe_topology
        svc = self.service
        info = {
            "service": {
                "sigma": self.sigma,
                "vocab_size": int(svc.cfg.vocab_size),
                "generation": int(svc.gen.generation),
            },
            "index": describe_topology(svc.gen),
            "cache": svc.cache.snapshot(),
            "batcher": dict(self.batcher.stats(),
                            buckets=list(self.batcher.buckets),
                            deadline_s=self.batcher.deadline_s),
            "admission": self.admission.describe(),
        }
        try:
            import jax
            info["devices"] = {"backend": jax.default_backend(),
                               "count": jax.device_count()}
        except Exception:                            # pragma: no cover
            info["devices"] = {"backend": "unavailable", "count": 0}
        return info

    def close(self) -> None:
        self.batcher.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
