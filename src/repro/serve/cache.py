"""Generation-keyed host-side LRU of hot query results.

Moved out of ``launch/serve_ngrams.py`` (which keeps a lazy re-export): the
cache is a serving-tier concern, shared by the direct service API, the
continuous batcher, and the HTTP frontend.  It has no jax dependency at all.
"""
from __future__ import annotations

from collections import OrderedDict

__all__ = ["LRUQueryCache"]


class LRUQueryCache:
    """Host-side LRU of hot query results, keyed by (kind, gram bytes).

    Entries are tagged with the index ``generation`` they were computed
    against; a lookup under a newer generation drops the whole cache (segment
    swaps change answers wholesale, and a stale count is worse than a miss).
    Accesses tagged with an *older* generation -- an in-flight double-buffered
    batch collected after an ingest bumped the index -- are discarded, never
    installed: they must not roll the cache back to serving stale counts.
    """

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.generation = -1
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._d: OrderedDict = OrderedDict()

    def _sync(self, generation: int) -> bool:
        """Advance to ``generation`` if newer; False iff the caller is stale."""
        if generation > self.generation:
            self._d.clear()
            self.generation = generation
        return generation == self.generation

    def get(self, key, generation: int):
        if not self._sync(generation):
            self.misses += 1               # stale reader: always a miss
            return None
        v = self._d.get(key)
        if v is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def put(self, key, generation: int, value) -> None:
        if not self._sync(generation):
            return                         # stale result: drop, don't install
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._d)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def snapshot(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._d),
                "generation": self.generation, "hit_rate": self.hit_rate}

    def publish_metrics(self, reg=None) -> None:
        """Mirror lifetime cache stats into the active metrics registry."""
        if reg is None:
            from repro.obs import metrics as obs_metrics
            reg = obs_metrics.get_registry()
        if not reg:
            return
        for k in ("hits", "misses", "evictions"):
            c = reg.counter("cache." + k)
            c.add(getattr(self, k) - c.value)     # lifetime mirror, not +=
        reg.gauge("cache.entries").set(len(self._d))
        reg.gauge("cache.hit_rate").set(self.hit_rate)
