"""Serving frontend: the production query tier in front of the index.

The stack, bottom-up (each layer usable on its own):

  * :mod:`repro.serve.cache`     -- ``LRUQueryCache``: generation-keyed host
    LRU of hot query results (moved out of ``launch/serve_ngrams.py``).
  * :mod:`repro.serve.service`   -- ``StreamingNGramService``: generational
    index + cache behind a batch lookup / top-k / ingest API, plus
    ``microbatch_drive`` and ``make_query_stream`` (the synthetic-workload
    helpers the drivers and benchmarks share).
  * :mod:`repro.serve.batcher`   -- ``ContinuousBatcher``: queue-fed
    coalescing of concurrent requests into fixed-shape device batches
    (padding buckets, deadline-based flush, double-buffered submit/collect).
  * :mod:`repro.serve.admission` -- priority classes, per-tenant token-bucket
    quotas, queue-depth load shedding.
  * :mod:`repro.serve.frontend`  -- ``QueryFrontend``: admission + in-flight
    duplicate coalescing + batcher glued onto one service.
  * :mod:`repro.serve.http`      -- stdlib HTTP/SSE transport
    (point-lookup, top-k, streaming completion, topology/health).

Everything re-exported here is lazy (PEP 562): importing ``repro.serve`` must
not initialize the jax backend, so ``--devices`` drivers can set ``XLA_FLAGS``
first -- the same contract ``launch/serve_ngrams.py`` keeps for its
re-exports.
"""
from __future__ import annotations

__all__ = [
    "LRUQueryCache", "StreamingNGramService", "microbatch_drive",
    "make_query_stream", "ContinuousBatcher", "Request", "select_bucket",
    "TokenBucket", "AdmissionController", "QueryFrontend",
    "NGramHTTPServer", "serve_http",
]

_LAZY = {
    "LRUQueryCache": ("repro.serve.cache", "LRUQueryCache"),
    "StreamingNGramService": ("repro.serve.service", "StreamingNGramService"),
    "microbatch_drive": ("repro.serve.service", "microbatch_drive"),
    "make_query_stream": ("repro.serve.service", "make_query_stream"),
    "ContinuousBatcher": ("repro.serve.batcher", "ContinuousBatcher"),
    "Request": ("repro.serve.batcher", "Request"),
    "select_bucket": ("repro.serve.batcher", "select_bucket"),
    "TokenBucket": ("repro.serve.admission", "TokenBucket"),
    "AdmissionController": ("repro.serve.admission", "AdmissionController"),
    "QueryFrontend": ("repro.serve.frontend", "QueryFrontend"),
    "NGramHTTPServer": ("repro.serve.http", "NGramHTTPServer"),
    "serve_http": ("repro.serve.http", "serve_http"),
}


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib
    return getattr(importlib.import_module(mod_name), attr)


def __dir__():
    return sorted(__all__)
