"""Reporting: JSONL sink, summary table, env metadata, schema validators.

The executor and serving drivers hand a :class:`~repro.obs.metrics
.MetricsRegistry` snapshot (plus an optional trace) to this module, which

  * appends JSON-lines records (:func:`write_jsonl`) -- one self-contained
    snapshot per line, greppable and diffable like the ``BENCH_*.json`` files;
  * renders the human summary (:func:`summary_table`) the CLIs print;
  * stamps :func:`environment_metadata` (jax version, backend, device count)
    so every recorded number says what hardware produced it;
  * validates exported artifacts against the schemas
    (:func:`validate_trace` / :func:`validate_metrics`) -- hand-rolled
    structural checks, zero dependencies, run by the CI smoke step:

        python -m repro.obs.report --validate-trace t.json \\
                                   --validate-metrics m.jsonl
"""
from __future__ import annotations

import json
import sys

__all__ = ["environment_metadata", "write_jsonl", "summary_table",
           "validate_trace", "validate_metrics", "setup"]


def setup(trace_path: str | None = None, metrics_path: str | None = None):
    """Wire the ``--trace`` / ``--metrics`` driver flags; returns ``finish``.

    Enables the tracer and/or installs a fresh registry (no-ops when both
    paths are ``None`` -- the flags-off invocation stays on the null
    singletons).  The returned ``finish(extra=None)`` exports the artifacts:
    trace JSON to ``trace_path``, one snapshot record (metrics + env + extra)
    appended to ``metrics_path`` JSONL, and prints the summary table.
    """
    from . import metrics as metrics_mod
    from . import trace as trace_mod

    tracer = trace_mod.enable_tracing() if trace_path else None
    reg = metrics_mod.MetricsRegistry() if metrics_path else None
    if reg is not None:
        metrics_mod.set_registry(reg)

    def finish(extra: dict | None = None):
        if tracer is not None:
            tracer.save(trace_path)
            print(f"trace: {trace_path} ({len(tracer.events)} spans)")
        if reg is not None:
            rec = {"env": environment_metadata(),
                   "metrics": reg.snapshot()}
            if extra:
                rec.update(extra)
            write_jsonl(metrics_path, [rec])
            table = summary_table(rec["metrics"])
            if table:
                print(table)
            print(f"metrics: {metrics_path}")
        return reg

    return finish


def environment_metadata() -> dict:
    """What produced this number: jax/backend/device facts for perf records."""
    import platform

    meta = {"python": platform.python_version(),
            "platform": platform.platform()}
    try:
        import jax
        meta.update(jax_version=jax.__version__,
                    backend=jax.default_backend(),
                    device_count=jax.device_count())
    except Exception as e:                      # pragma: no cover - no jax
        meta["jax_error"] = str(e)
    return meta


def write_jsonl(path: str, records) -> int:
    """Append records (dicts) to a JSONL file; returns the number written."""
    n = 0
    with open(path, "a") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")
            n += 1
    return n


def read_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _fmt_num(v) -> str:
    if isinstance(v, float) and not v.is_integer():
        return f"{v:,.4g}"
    return f"{int(v):,}"


def summary_table(snapshot: dict) -> str:
    """Human-readable rendering of a registry snapshot (the CLI footer)."""
    lines = []
    counters = snapshot.get("counters", {})
    gauges = snapshot.get("gauges", {})
    if counters or gauges:
        lines.append("-- counters / gauges " + "-" * 38)
        for k, v in sorted({**counters, **gauges}.items()):
            lines.append(f"  {k:<40} {_fmt_num(v):>15}")
    hists = snapshot.get("histograms", {})
    if hists:
        lines.append("-- histograms (s) " + "-" * 41)
        lines.append(f"  {'name':<28} {'n':>7} {'p50':>9} {'p95':>9} "
                     f"{'p99':>9} {'max':>9}")
        for k, h in sorted(hists.items()):
            lines.append(
                f"  {k:<28} {h['count']:>7} {h['p50']:>9.2e} "
                f"{h['p95']:>9.2e} {h['p99']:>9.2e} {h['max']:>9.2e}")
    return "\n".join(lines)


# --------------------------------------------------------------------------- #
# schema validation (structural, dependency-free; CI smoke + tests)
# --------------------------------------------------------------------------- #

def validate_trace(obj: dict) -> list[str]:
    """Errors ([] = valid) for a Chrome ``trace_event`` JSON object."""
    errs: list[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["trace must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    if not events:
        errs.append("trace has no events")
    for i, e in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        if not isinstance(e.get("name"), str) or not e.get("name"):
            errs.append(f"{where}: missing/empty 'name'")
        if e.get("ph") != "X":
            errs.append(f"{where}: 'ph' must be 'X' (complete event)")
        for k in ("ts", "dur"):
            v = e.get(k)
            if not isinstance(v, (int, float)) or v < 0:
                errs.append(f"{where}: '{k}' must be a number >= 0")
        for k in ("pid", "tid"):
            if not isinstance(e.get(k), int):
                errs.append(f"{where}: '{k}' must be an int")
        if "args" in e and not isinstance(e["args"], dict):
            errs.append(f"{where}: 'args' must be an object")
    return errs


def validate_metrics(snapshot: dict) -> list[str]:
    """Errors ([] = valid) for one ``MetricsRegistry.snapshot()`` record."""
    errs: list[str] = []
    if not isinstance(snapshot, dict):
        return ["metrics snapshot must be an object"]
    for sect in ("counters", "gauges", "histograms"):
        if sect not in snapshot:
            errs.append(f"missing section '{sect}'")
    for sect in ("counters", "gauges"):
        for k, v in snapshot.get(sect, {}).items():
            if not isinstance(v, (int, float)):
                errs.append(f"{sect}[{k}]: value must be a number")
    for k, h in snapshot.get("histograms", {}).items():
        where = f"histograms[{k}]"
        if not isinstance(h, dict):
            errs.append(f"{where}: must be an object")
            continue
        b = h.get("boundaries")
        c = h.get("counts")
        if not isinstance(b, list) or sorted(b) != b or len(set(b)) != len(b):
            errs.append(f"{where}: 'boundaries' must be strictly increasing")
        if not isinstance(c, list) or not isinstance(b, list) or \
                len(c) != len(b) + 1:
            errs.append(f"{where}: len(counts) must be len(boundaries)+1")
        elif any((not isinstance(x, int)) or x < 0 for x in c):
            errs.append(f"{where}: counts must be non-negative ints")
        elif h.get("count") != sum(c):
            errs.append(f"{where}: 'count' != sum(counts)")
        for fld in ("count", "sum", "min", "max", "p50", "p95", "p99"):
            if not isinstance(h.get(fld), (int, float)):
                errs.append(f"{where}: missing numeric '{fld}'")
    return errs


def main(argv=None) -> int:
    """CLI validator (the CI smoke step): exit 0 iff every artifact is valid."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--validate-trace", default=None,
                    help="Chrome trace_event JSON file to validate")
    ap.add_argument("--validate-metrics", default=None,
                    help="metrics JSONL file to validate (every line)")
    args = ap.parse_args(argv)
    rc = 0
    if args.validate_trace:
        with open(args.validate_trace) as f:
            obj = json.load(f)
        errs = validate_trace(obj)
        n_events = 0 if errs else len(obj["traceEvents"])
        if errs:
            rc = 1
            print(f"TRACE INVALID ({args.validate_trace}):", file=sys.stderr)
            for e in errs[:20]:
                print(f"  {e}", file=sys.stderr)
        else:
            print(f"trace ok: {args.validate_trace} ({n_events} events)")
    if args.validate_metrics:
        records = read_jsonl(args.validate_metrics)
        errs = (["metrics file has no records"] if not records else
                [f"line {i}: {e}" for i, rec in enumerate(records)
                 for e in validate_metrics(rec.get("metrics", rec))])
        if errs:
            rc = 1
            print(f"METRICS INVALID ({args.validate_metrics}):",
                  file=sys.stderr)
            for e in errs[:20]:
                print(f"  {e}", file=sys.stderr)
        else:
            print(f"metrics ok: {args.validate_metrics} "
                  f"({len(records)} records)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
