"""Typed metrics: counters, gauges, fixed-boundary histograms, counter policy.

Two things live here:

1. A :class:`MetricsRegistry` of typed instruments.  Counters accumulate,
   gauges hold the latest value, histograms bucket observations against fixed
   boundaries so p50/p95/p99 come out of bucket interpolation with **no sample
   storage** -- the serving loop can observe millions of batch latencies in
   O(buckets) memory.  ``registry.snapshot()`` is plain JSON-able data;
   ``repro.obs.report`` renders and validates it.

2. The **canonical job-counter glossary** (:data:`COUNTER_DOC`) and its merge
   policy.  ``NGramStats.counters`` stays a plain dict -- the compatibility
   view every existing caller reads -- but the names, types, and fold rules
   are now defined in exactly one place: :func:`merge_counter_dicts` is the
   shared fold (sums, except ``max``-merged keys like ``shuffle_skew``), and
   :func:`normalize_counters` pins the types (ints for summable counts, float
   for ratios) that the ad-hoc dicts used to leave to chance.

Like tracing, the disabled path is a shared null singleton
(:data:`null_registry`): instruments exist, every mutation is a no-op, no
allocation rides the hot path.
"""
from __future__ import annotations

import math

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "COUNTER_DOC", "MAX_MERGED_COUNTERS", "FLOAT_COUNTERS",
           "merge_counter_dicts", "normalize_counters",
           "get_registry", "set_registry", "null_registry",
           "default_latency_boundaries"]


# --------------------------------------------------------------------------- #
# canonical job-counter set (the paper's Hadoop-counter analogues)
# --------------------------------------------------------------------------- #

#: Every counter the job/wave/serving paths may emit, in one place.  The
#: monolithic path (``pipeline.executor.run_plan``) and the wave path
#: (``WaveExecutor.run``) emit the same names with the same meanings; keys
#: marked *wave-only* exist only where the concept does.
COUNTER_DOC: dict[str, str] = {
    "jobs": "MapReduce jobs (= stage-pipeline rounds) executed",
    "map_records": "records emitted by the map phase, pre-combine "
                   "(MAP_OUTPUT_RECORDS)",
    "shuffle_records": "records entering the shuffle, post-combine "
                       "(REDUCE_INPUT_RECORDS)",
    "shuffle_bytes": "shuffled records x packed record bytes "
                     "(MAP_OUTPUT_BYTES)",
    "shuffle_skew": "max realized reducer load / mean, over nominal "
                    "reducers (float; folds by max, not sum).  On the fused "
                    "mesh-wave path the histogram collective behind it only "
                    "runs when metrics are enabled -- disabled runs report "
                    "0.0 and skip the psum entirely",
    "retries": "capacity-doubling shuffle reruns (mesh waves rerun the WHOLE "
               "fused wave at doubled capacity scale, sharded serving reruns "
               "the query batch); 0 on paths with exact-sized buffers",
    "overflow": "records dropped for capacity (always 0 -- overflow "
                "triggers a retry instead; kept as the loud invariant)",
    "waves": "token waves executed (wave-only)",
    "fold_rows": "segment rows fed through merge_segments by the wave "
                 "accumulator -- the measured fold work (wave-only)",
    "phase_b_records": "SUFFIX-sigma phase-B survivor records (method-only)",
    "post_filter_jobs": "maximality/closedness post-filter jobs (method-only)",
    # ---- serving-frontend instruments (repro.serve; registry names, not job
    # counters -- they never ride NGramStats.counters or the merge policy).
    # Companion histograms: frontend.batch_fill (live slots / padded bucket),
    # frontend.ttfb_seconds (admission -> payload available); gauge:
    # frontend.queue_depth.  Spans: serve.request (transport thread) and
    # serve.flush (batcher thread) around the existing serve.batch device
    # dispatch.
    "frontend.requests": "queries offered to the frontend, pre-admission",
    "frontend.shed": "requests rejected by queue-depth load shedding "
                     "(HTTP 503): past the soft budget only the top "
                     "priority class is admitted, past the hard limit "
                     "nothing is",
    "frontend.quota_rejected": "requests rejected by a tenant's token "
                               "bucket (HTTP 429)",
    "frontend.coalesced": "duplicate in-flight queries welded onto an "
                          "already-admitted request's answer (same key as "
                          "the LRU cache + index generation); they occupy "
                          "no batch slot and pay no quota",
    "frontend.batches": "device batches flushed by the continuous batcher "
                        "(full bucket or deadline)",
}

#: Keys that fold by ``max`` across waves/jobs instead of summing: a ratio
#: like the shuffle skew is meaningless summed, and the conservative report
#: is the worst wave.
MAX_MERGED_COUNTERS = frozenset({"shuffle_skew"})

#: Keys whose values are ratios (kept float); everything else is a count and
#: normalizes to int.
FLOAT_COUNTERS = frozenset({"shuffle_skew"})


def merge_counter_dicts(dst: dict, src: dict) -> dict:
    """Fold ``src`` counters into ``dst`` in place (the one shared policy).

    Sums by default; :data:`MAX_MERGED_COUNTERS` keys fold by ``max``.  This
    replaces the executor paths' private folds, which silently assumed every
    non-skew value was summable.
    """
    for key, v in src.items():
        if key in MAX_MERGED_COUNTERS:
            dst[key] = max(dst.get(key, 0.0), float(v))
        else:
            dst[key] = dst.get(key, 0) + v
    return dst


def normalize_counters(counters: dict) -> dict:
    """Pin counter value types: ints for counts, floats for ratio keys.

    Device scalars, numpy ints, and ``add_counters``'s float coercion all
    leak into the ad-hoc dicts; normalizing at the merge boundary keeps
    ``NGramStats.counters`` a stable, JSON-able contract.
    """
    return {k: float(v) if k in FLOAT_COUNTERS else int(v)
            for k, v in counters.items()}


# --------------------------------------------------------------------------- #
# typed instruments
# --------------------------------------------------------------------------- #

class Counter:
    """Monotonic accumulator."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def add(self, v=1) -> None:
        self.value += v

    def snapshot(self):
        return self.value


class Gauge:
    """Latest-value instrument (queue depth, segment count, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def add(self, v=1) -> None:
        self.value += v

    def snapshot(self):
        return self.value


def default_latency_boundaries() -> tuple[float, ...]:
    """Geometric bucket edges 1us..100s (4 per decade): latency seconds."""
    return tuple(10.0 ** (-6 + i / 4) for i in range(33))


class Histogram:
    """Fixed-boundary histogram: quantiles without sample storage.

    ``boundaries`` are the B sorted bucket edges; observations land in B+1
    buckets (``(-inf, b0], (b0, b1], ..., (b_{B-1}, inf)``).  ``quantile(q)``
    walks the cumulative counts to the target bucket and interpolates
    linearly inside it, clamping the open-ended end buckets to the observed
    min/max -- so the estimate is exact to within one bucket's width
    (differentially tested against the numpy sample oracle).
    """

    __slots__ = ("name", "boundaries", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, boundaries=None):
        if boundaries is None:
            boundaries = default_latency_boundaries()
        b = tuple(float(x) for x in boundaries)
        if list(b) != sorted(b) or len(set(b)) != len(b):
            raise ValueError("histogram boundaries must be strictly increasing")
        if not b:
            raise ValueError("histogram needs at least one boundary")
        self.name = name
        self.boundaries = b
        self.counts = [0] * (len(b) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v) -> None:
        v = float(v)
        lo, hi = 0, len(self.boundaries)
        while lo < hi:                      # first boundary >= v
            mid = (lo + hi) // 2
            if self.boundaries[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c > 0:
                # bucket i spans (lo_edge, hi_edge]; clamp open ends to the
                # observed extrema so tail quantiles stay finite
                lo_edge = self.boundaries[i - 1] if i > 0 else self.min
                hi_edge = self.boundaries[i] if i < len(self.boundaries) \
                    else self.max
                lo_edge = max(lo_edge, self.min)
                hi_edge = min(hi_edge, self.max)
                frac = (target - cum) / c
                return lo_edge + (hi_edge - lo_edge) * frac
            cum += c
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {
            "boundaries": list(self.boundaries),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Named typed instruments + the job-counter compatibility bridge."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def __bool__(self) -> bool:
        return True

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, boundaries=None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, boundaries)
        return h

    def merge_job_counters(self, counters: dict, prefix: str = "job.") -> None:
        """Absorb an ``NGramStats.counters`` dict under the shared policy."""
        for k, v in normalize_counters(counters).items():
            if k in MAX_MERGED_COUNTERS:
                g = self.gauge(prefix + k)
                g.set(max(float(g.value), v))
            else:
                self.counter(prefix + k).add(v)

    @property
    def counters(self) -> dict:
        """Plain dict view of counter values (the ad-hoc-dict-shaped read)."""
        return {k: c.value for k, c in self._counters.items()}

    def snapshot(self) -> dict:
        """JSON-able state: the unit ``report.write_jsonl`` records."""
        return {
            "counters": {k: c.snapshot() for k, c in
                         sorted(self._counters.items())},
            "gauges": {k: g.snapshot() for k, g in
                       sorted(self._gauges.items())},
            "histograms": {k: h.snapshot() for k, h in
                           sorted(self._histograms.items())},
        }


class _NullInstrument:
    """Shared do-nothing counter/gauge/histogram."""

    __slots__ = ()
    name = "null"
    value = 0
    count = 0

    def add(self, v=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass


class _NullRegistry:
    """Disabled-path registry: every instrument is the shared null singleton."""

    __slots__ = ()
    _NULL = _NullInstrument()

    def __bool__(self) -> bool:
        return False

    def counter(self, name: str):
        return self._NULL

    def gauge(self, name: str):
        return self._NULL

    def histogram(self, name: str, boundaries=None):
        return self._NULL

    def merge_job_counters(self, counters: dict, prefix: str = "job.") -> None:
        pass


null_registry = _NullRegistry()

_REGISTRY = null_registry


def set_registry(reg) -> None:
    """Install the active registry (``None`` / ``null_registry`` disables)."""
    global _REGISTRY
    _REGISTRY = reg if reg is not None else null_registry


def get_registry():
    """The active registry, or the shared null singleton when disabled.

    Instrumented code calls this unconditionally; the disabled cost is one
    global read plus no-op method calls -- no allocation, no sync.
    """
    return _REGISTRY
