"""Span tracer: nested wall-clock spans -> Chrome/Perfetto ``trace_event`` JSON.

Usage (instrumented code never checks whether tracing is on):

    from repro.obs import trace

    with trace.span("wave.submit") as sp:
        if sp:                       # real span: attach args / device sync
            sp.set(wave=i)
            sp.sync(device_arrays)   # block_until_ready at span CLOSE only
        ...

``trace.span`` returns the shared :data:`NULL_SPAN` singleton while tracing is
disabled -- no allocation, no clock read, no device sync -- so the disabled
path is a true no-op (regression-tested by ``tests/test_obs.py``).  Enabled,
spans nest through a plain stack, record host ``perf_counter_ns`` intervals,
and optionally scope *device* time: arrays registered via ``sp.sync(...)`` are
``jax.block_until_ready``-ed at span close, so the span's duration covers the
device work it dispatched instead of just the async-dispatch call.

Export is the Chrome ``trace_event`` "complete event" (``ph: "X"``) format,
loadable directly in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``;
nesting is inferred from timestamps within a track, so the JSON stays flat.
"""
from __future__ import annotations

import json
import threading
import time

__all__ = ["NULL_SPAN", "Span", "Tracer", "enable_tracing", "disable_tracing",
           "get_tracer", "span", "span_coverage"]


class _NullSpan:
    """Shared do-nothing span: the disabled path's zero-cost stand-in."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def __bool__(self) -> bool:
        return False

    def set(self, **args) -> None:
        pass

    def sync(self, _x) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """One live span; closes (and optionally device-syncs) on ``__exit__``."""

    __slots__ = ("_tracer", "name", "args", "t0", "t1", "tid", "_sync")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0
        self.t1 = 0
        self.tid = threading.get_ident() & 0xFFFF
        self._sync = None

    def __bool__(self) -> bool:
        return True

    def set(self, **args) -> None:
        """Attach key/value args (rendered in the Perfetto detail pane)."""
        if self.args is None:
            self.args = {}
        self.args.update(args)

    def sync(self, x) -> None:
        """Register device values to ``block_until_ready`` at span close.

        This is the *opt-in* device-time scoping: without it a span around an
        async jax dispatch measures only the dispatch; with it the span close
        waits for the registered arrays, so the duration covers the device
        work.  The sync happens once, at ``__exit__`` -- never mid-span.
        """
        self._sync = x if self._sync is None else (self._sync, x)

    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        if self._sync is not None:
            import jax
            jax.block_until_ready(self._sync)
        self.t1 = time.perf_counter_ns()
        self._tracer._finish(self)
        return False


class Tracer:
    """Collects finished spans; exports Chrome ``trace_event`` JSON."""

    def __init__(self):
        self.events: list[dict] = []
        self._t_origin = time.perf_counter_ns()

    def span(self, name: str, **args) -> Span:
        return Span(self, name, args or None)

    def _finish(self, sp: Span) -> None:
        ev = {
            "name": sp.name,
            "ph": "X",
            "cat": "repro",
            "ts": (sp.t0 - self._t_origin) / 1e3,    # us, Chrome's unit
            "dur": (sp.t1 - sp.t0) / 1e3,
            "pid": 0,
            "tid": sp.tid,
        }
        if sp.args:
            ev["args"] = {k: _jsonable(v) for k, v in sp.args.items()}
        self.events.append(ev)

    def export(self) -> dict:
        """The Perfetto-loadable trace object (sorted by start time)."""
        return {
            "traceEvents": sorted(self.events, key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f, indent=1)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return int(v)          # numpy / device scalars
    except (TypeError, ValueError):
        return str(v)


# --------------------------------------------------------------------------- #
# module-level current tracer (the instrumented paths' single indirection)
# --------------------------------------------------------------------------- #

_TRACER: Tracer | None = None


def enable_tracing(tracer: Tracer | None = None) -> Tracer:
    """Install (and return) the active tracer; idempotent with an argument."""
    global _TRACER
    _TRACER = tracer if tracer is not None else Tracer()
    return _TRACER


def disable_tracing() -> None:
    global _TRACER
    _TRACER = None


def get_tracer() -> Tracer | None:
    return _TRACER


def span(name: str):
    """A span under the active tracer, or :data:`NULL_SPAN` when disabled.

    The disabled call is the whole hot-path cost: one global read, one
    ``is None`` check, and the shared singleton back -- no allocation.
    """
    if _TRACER is None:
        return NULL_SPAN
    return _TRACER.span(name)


# --------------------------------------------------------------------------- #
# trace analysis (acceptance checks, benchmarks)
# --------------------------------------------------------------------------- #

def span_coverage(trace_obj: dict, root_name: str,
                  child_prefixes: tuple[str, ...] | None = None) -> float:
    """Fraction of the root span's wall time covered by named child spans.

    The per-wave-tax attribution check: merge every non-root span's
    ``[ts, ts+dur)`` interval (optionally filtered to ``child_prefixes``),
    clip to the root span, and return covered/total.  A trace where this is
    low has anonymous wall time no span accounts for.
    """
    events = trace_obj["traceEvents"]
    roots = [e for e in events if e["name"] == root_name]
    if not roots:
        raise ValueError(f"no span named {root_name!r} in trace")
    root = max(roots, key=lambda e: e["dur"])
    r0, r1 = root["ts"], root["ts"] + root["dur"]
    if r1 <= r0:
        return 0.0
    ivals = []
    for e in events:
        if e is root or e["name"] == root_name:
            continue
        if child_prefixes is not None and \
                not e["name"].startswith(child_prefixes):
            continue
        lo = max(e["ts"], r0)
        hi = min(e["ts"] + e["dur"], r1)
        if hi > lo:
            ivals.append((lo, hi))
    ivals.sort()
    covered = 0.0
    cur_lo, cur_hi = None, None
    for lo, hi in ivals:
        if cur_hi is None or lo > cur_hi:
            if cur_hi is not None:
                covered += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    if cur_hi is not None:
        covered += cur_hi - cur_lo
    return covered / (r1 - r0)
