"""Observability: structured tracing, typed metrics, machine-readable reports.

The paper reads the relative costs of its methods straight off Hadoop's
built-in counters and per-phase runtimes; this package is that instrument
panel for the reproduction -- zero external dependencies, and a **true no-op
when disabled**: the hot paths (wave dispatch, serving batches) see a shared
null singleton, no added host syncs, no allocations.

  * :mod:`repro.obs.trace`   -- nested span tracer (context-manager API, host
    wall clock, opt-in device-time scoping via ``block_until_ready`` only at
    span close) exporting Chrome/Perfetto ``trace_event`` JSON;
  * :mod:`repro.obs.metrics` -- typed registry of counters, gauges and
    fixed-boundary histograms (p50/p95/p99 without sample storage), plus the
    canonical job-counter glossary and merge/normalization policy that the
    executor paths share;
  * :mod:`repro.obs.report`  -- JSONL sink, human-readable summary table,
    environment metadata stamp, and the trace/metrics schema validators the
    CI smoke step runs.
"""
from .metrics import (COUNTER_DOC, MetricsRegistry, get_registry,
                      merge_counter_dicts, normalize_counters, null_registry,
                      set_registry)
from .trace import NULL_SPAN, Tracer, disable_tracing, enable_tracing, \
    get_tracer, span, span_coverage
from .report import (environment_metadata, setup, summary_table,
                     validate_metrics, validate_trace, write_jsonl)

__all__ = [
    "COUNTER_DOC", "MetricsRegistry", "get_registry", "merge_counter_dicts",
    "normalize_counters", "null_registry", "set_registry",
    "NULL_SPAN", "Tracer", "disable_tracing", "enable_tracing", "get_tracer",
    "span", "span_coverage",
    "environment_metadata", "setup", "summary_table", "validate_metrics",
    "validate_trace", "write_jsonl",
]
