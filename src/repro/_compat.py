"""Version shims so the distributed code runs on older jax releases.

The codebase targets the modern public API (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``).
Older jax (< 0.5) ships the same functionality under experimental names; rather
than gate every call site, :func:`install` backfills the modern names once at
``repro`` import time.  On a current jax this is a no-op.
"""
from __future__ import annotations

import enum
import functools
import inspect


def install() -> None:
    import jax

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            del axis_types  # pre-AxisType jax: every axis behaves as Auto
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      check_vma=True, **kw):
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma, **kw)

        jax.shard_map = shard_map
