from . import pack, segment, shuffle, sort

__all__ = ["pack", "segment", "shuffle", "sort"]
