"""Vocab-adaptive bit packing of term-id lanes.

The paper (SS-V "Sequence Encoding") replaces textual terms by integer ids assigned in
descending collection-frequency order and varbyte-encodes them so that (a) fewer bytes
are shuffled and (b) comparisons run on integers.  On TPU the analogous win is packing
several term ids into each 32-bit sort lane, most-significant-first, so that

  * ascending lexicographic sort on the packed lanes == ascending lexicographic sort
    on the raw term sequences (PAD = 0 sorts before every real term), and
  * the number of sort passes (one per key lane in ``jax.lax.sort``) drops by the
    packing factor.

Packing is exact and invertible; ``bits_for_vocab`` chooses the lane layout from the
vocabulary size.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

PAD_ID = 0  # reserved: sorts first, marks end-of-document / end-of-suffix


def bits_for_vocab(vocab_size: int) -> int:
    """Bits per term id (ids are 1..vocab_size, 0 is PAD)."""
    if vocab_size < 1:
        raise ValueError(f"vocab_size must be >= 1, got {vocab_size}")
    return max(1, math.ceil(math.log2(vocab_size + 1)))


def terms_per_lane(vocab_size: int) -> int:
    return max(1, 32 // bits_for_vocab(vocab_size))


def n_lanes(sigma: int, vocab_size: int) -> int:
    return (sigma + terms_per_lane(vocab_size) - 1) // terms_per_lane(vocab_size)


@partial(jax.jit, static_argnames=("vocab_size",))
def pack_terms(terms: jax.Array, *, vocab_size: int) -> jax.Array:
    """Pack ``terms`` [..., sigma] (int32, PAD=0) into uint32 lanes [..., n_lanes].

    Earlier terms occupy more-significant bits so lane-major ascending order is
    lexicographic term order.
    """
    sigma = terms.shape[-1]
    bits = bits_for_vocab(vocab_size)
    per = terms_per_lane(vocab_size)
    lanes = n_lanes(sigma, vocab_size)
    pad_to = lanes * per
    t = terms.astype(jnp.uint32)
    if pad_to != sigma:
        pad_width = [(0, 0)] * (t.ndim - 1) + [(0, pad_to - sigma)]
        t = jnp.pad(t, pad_width)
    t = t.reshape(t.shape[:-1] + (lanes, per))
    shifts = jnp.arange(per - 1, -1, -1, dtype=jnp.uint32) * jnp.uint32(bits)
    return jnp.sum(t << shifts, axis=-1).astype(jnp.uint32)


def pack_terms_np(terms: np.ndarray, *, vocab_size: int) -> np.ndarray:
    """Host numpy mirror of :func:`pack_terms` -- bit-identical lanes.

    The wave fold packs each wave's (already materialized) partial on the
    host; a device dispatch per wave just to shift-and-sum integers would
    serialize with the next wave's real work.
    """
    sigma = terms.shape[-1]
    bits = bits_for_vocab(vocab_size)
    per = terms_per_lane(vocab_size)
    lanes = n_lanes(sigma, vocab_size)
    pad_to = lanes * per
    t = terms.astype(np.uint32)
    if pad_to != sigma:
        pad_width = [(0, 0)] * (t.ndim - 1) + [(0, pad_to - sigma)]
        t = np.pad(t, pad_width)
    t = t.reshape(t.shape[:-1] + (lanes, per))
    shifts = np.arange(per - 1, -1, -1, dtype=np.uint32) * np.uint32(bits)
    return (t << shifts).sum(axis=-1, dtype=np.uint32)


def prefix_lane_masks(sigma: int, vocab_size: int) -> np.ndarray:
    """AND-masks [sigma + 1, n_lanes] uint32 reducing packed lanes to prefixes.

    ``lanes & masks[l]`` zeroes the bit fields of every term slot past the
    first ``l``, which is exactly ``pack_terms`` of the length-``l`` prefix
    padded with PAD=0 -- each term occupies its own bit field, so zeroing a
    slot's bits equals packing a PAD there.  Lets a collector derive every
    prefix gram's packed key directly from the full suffix lanes, with no
    unpack -> re-pack round trip.
    """
    bits = bits_for_vocab(vocab_size)
    per = terms_per_lane(vocab_size)
    lanes = n_lanes(sigma, vocab_size)
    field = (1 << bits) - 1
    masks = np.zeros((sigma + 1, lanes), np.uint32)
    for l in range(sigma + 1):
        for j in range(lanes):
            m = 0
            for i in range(per):
                if j * per + i < l:
                    m |= field << ((per - 1 - i) * bits)
            masks[l, j] = np.uint32(m & 0xFFFFFFFF)
    return masks


@partial(jax.jit, static_argnames=("vocab_size", "sigma"))
def unpack_terms(lanes_arr: jax.Array, *, vocab_size: int, sigma: int) -> jax.Array:
    """Inverse of :func:`pack_terms` -> int32 [..., sigma]."""
    bits = bits_for_vocab(vocab_size)
    per = terms_per_lane(vocab_size)
    shifts = jnp.arange(per - 1, -1, -1, dtype=jnp.uint32) * jnp.uint32(bits)
    mask = jnp.uint32((1 << bits) - 1) if bits < 32 else jnp.uint32(0xFFFFFFFF)
    t = (lanes_arr[..., None] >> shifts) & mask
    t = t.reshape(t.shape[:-2] + (-1,))
    return t[..., :sigma].astype(jnp.int32)


def lead_term(lane0: jax.Array, *, vocab_size: int) -> jax.Array:
    """First (most significant) term id of lane 0 -- the shuffle/serving routing key.

    The packer puts earlier terms in more-significant bits, so the lead term is a
    single shift of the first lane: the same key the paper's Algorithm-4 partitioner
    hashes, and the key the serving layer routes queries by so index shards align
    with reducer outputs.
    """
    shift = (terms_per_lane(vocab_size) - 1) * bits_for_vocab(vocab_size)
    return (lane0.astype(jnp.uint32) >> jnp.uint32(shift)).astype(jnp.uint32)


def record_width(sigma: int, vocab_size: int, n_meta: int = 0) -> int:
    """Lanes per shuffle record: packed suffix + weight lane + meta lanes."""
    return n_lanes(sigma, vocab_size) + 1 + n_meta


def record_bytes(sigma: int, vocab_size: int, n_meta: int = 0) -> int:
    return 4 * record_width(sigma, vocab_size, n_meta)
