"""Segmented reductions and run-length utilities.

These are the data-parallel equivalent of the paper's streaming two-stack reducer:
on a lexicographically sorted block of suffixes, every distinct prefix occupies a
contiguous run, so "pop the stack and emit a count" becomes "detect run boundary and
segment-sum the weights".  The same primitive backs the GNN message-passing scatter
and the recsys EmbeddingBag (see DESIGN.md SS4).

Correctness note: at prefix length l, a row whose suffix is shorter than l (PAD at
position l-1) must not contribute to any length-l run, even though the cumulative
boundary count would assign it a segment id -- hence the explicit ``valid`` mask.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def segment_sum(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_max(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


@jax.jit
def lcp_lengths(sorted_terms: jax.Array) -> jax.Array:
    """Longest-common-prefix length of each row with the previous row.

    sorted_terms: [N, L] int32 (rows lexicographically sorted).  Returns [N] int32,
    row 0 gets lcp 0.  Pure-jnp reference; the fused VPU version lives in
    ``repro.kernels.lcp_boundary``.
    """
    prev = jnp.roll(sorted_terms, 1, axis=0)
    eq = (sorted_terms == prev).astype(jnp.int32)
    lcp = jnp.sum(jnp.cumprod(eq, axis=1), axis=1)
    return lcp.at[0].set(0)


@jax.jit
def boundary_flags(sorted_terms: jax.Array, lcp: jax.Array) -> jax.Array:
    """new_prefix flags [N, L]: flags[i, l-1] == True iff the length-l prefix of row i
    starts a new run (and the row actually has length >= l, i.e. no PAD at l-1)."""
    n, length = sorted_terms.shape
    lengths = jnp.arange(1, length + 1, dtype=jnp.int32)
    valid = sorted_terms != 0  # PAD-aware: suffix shorter than l contributes nothing
    return (lcp[:, None] < lengths[None, :]) & valid


@partial(jax.jit, static_argnames=("max_segments",))
def run_counts(flags: jax.Array, valid: jax.Array, weights: jax.Array,
               max_segments: int) -> jax.Array:
    """Per-(row, length) run totals.

    flags : [N, L] boundary flags (from :func:`boundary_flags`)
    valid : [N, L] row has length >= l (``sorted_terms != 0``)
    weights: [N] per-row multiplicities (0 for padding rows)

    Returns counts [N, L]: at boundary positions, the total weight of the run (the
    collection frequency of that prefix); 0 elsewhere.
    """

    def per_length(fl, va):
        seg = jnp.maximum(jnp.cumsum(fl.astype(jnp.int32)) - 1, 0)  # [N] run ids
        contrib = jnp.where(va, weights, 0)
        totals = jax.ops.segment_sum(contrib, seg, num_segments=max_segments)
        return jnp.where(fl, totals[seg], 0)

    return jax.vmap(per_length, in_axes=(1, 1), out_axes=1)(flags, valid)


@partial(jax.jit, static_argnames=("max_segments",))
def run_counts_matrix(flags: jax.Array, valid: jax.Array, weights: jax.Array,
                      max_segments: int) -> jax.Array:
    """Like :func:`run_counts` but with bucketed weights [N, B] (e.g. per-year counts
    for the time-series extension).  Returns [N, L, B]."""

    def per_length(fl, va):
        seg = jnp.maximum(jnp.cumsum(fl.astype(jnp.int32)) - 1, 0)
        contrib = jnp.where(va[:, None], weights, 0)
        totals = jax.ops.segment_sum(contrib, seg, num_segments=max_segments)
        return jnp.where(fl[:, None], totals[seg], 0)

    return jax.vmap(per_length, in_axes=(1, 1), out_axes=1)(flags, valid)
