"""Hash-bucketed all_to_all exchange -- the MapReduce shuffle on a TPU mesh.

Hadoop's shuffle hashes each key to a reducer and streams records over the network.
The TPU-native equivalent is the MoE-dispatch pattern: bucket records into a
fixed-capacity [n_parts, capacity, W] buffer and exchange with
``jax.lax.all_to_all`` over the mesh axis.  Capacity is a head-room knob
(``capacity_factor``); overflow is *counted*, never silently dropped -- the driver
retries the job with doubled capacity (the Hadoop analogue: a reducer re-run after a
spill failure).

The paper's partitioner (Algorithm 4) hashes the suffix's **first term only**, which
is the load-balance-vs-correctness trade-off SUFFIX-sigma needs: all evidence for an
n-gram lands on one reducer.  Zipf skew of lead terms is absorbed by the capacity
factor; we measure the realized skew in the benchmarks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

KNUTH = jnp.uint32(2654435761)
GOLDEN = jnp.uint32(0x9E3779B9)


def hash_u32(x: jax.Array) -> jax.Array:
    """Multiplicative hashing (Knuth) with an xorshift finalizer."""
    h = x.astype(jnp.uint32) * KNUTH
    h = h ^ (h >> 15)
    h = h * jnp.uint32(2246822519)
    return h ^ (h >> 13)


def fold_hash(lanes: jax.Array) -> jax.Array:
    """Order-sensitive fold hash of packed key lanes [..., L] -> uint32.

    The one whole-record hash of the system: the NAIVE/APRIORI partition key,
    the APRIORI membership-dictionary key, and the map-side hash combiner's
    slot key all come from here, so two phases never disagree on which rows
    are "the same gram"."""
    h = jnp.zeros(lanes.shape[:-1], jnp.uint32)
    for i in range(lanes.shape[-1]):
        h = hash_u32(h ^ lanes[..., i] + GOLDEN)
    return h


def record_key(lanes: jax.Array, *, kind: str, vocab_size: int) -> jax.Array:
    """Partition key of packed gram lanes [..., L] -- the one shuffle-key API.

    ``kind="gram"`` hashes the whole record (any reducer may count any gram --
    NAIVE/APRIORI); ``kind="lead"`` routes by the first term only (all evidence
    of an n-gram shares a reducer -- SUFFIX-sigma, and the serving layer's
    shard router)."""
    if kind == "gram":
        return fold_hash(lanes)
    if kind == "lead":
        from repro.mapreduce import pack as packing
        return packing.lead_term(lanes[..., 0], vocab_size=vocab_size)
    raise ValueError(f"unknown partition key kind {kind!r}")


def partition_ids(keys: jax.Array, valid: jax.Array, n_parts: int) -> jax.Array:
    """Reducer id per record; invalid records go to the drop bucket ``n_parts``."""
    p = (hash_u32(keys) % jnp.uint32(n_parts)).astype(jnp.int32)
    return jnp.where(valid, p, n_parts)


@partial(jax.jit, static_argnames=("n_parts", "capacity"))
def bucketize(records: jax.Array, part: jax.Array, n_parts: int,
              capacity: int) -> tuple[jax.Array, jax.Array]:
    """Scatter records [N, W] into buckets [n_parts, capacity, W].

    ``part`` in [0, n_parts] (n_parts = drop).  Returns (buffer, overflow_count).
    Empty slots are all-zero (weight lane 0 marks them invalid downstream).
    """
    n, w = records.shape
    order = jnp.argsort(part, stable=True)
    p_s = part[order]
    rec_s = records[order]
    counts = jnp.bincount(p_s, length=n_parts + 1)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    within = jnp.arange(n, dtype=jnp.int32) - offsets[p_s].astype(jnp.int32)
    ok = (within < capacity) & (p_s < n_parts)
    slot = jnp.where(ok, p_s * capacity + within, n_parts * capacity)  # OOB -> dropped
    buf = jnp.zeros((n_parts * capacity, w), records.dtype)
    buf = buf.at[slot].set(rec_s, mode="drop")
    overflow = jnp.sum((~ok) & (p_s < n_parts))
    return buf.reshape(n_parts, capacity, w), overflow


def exchange(buffer: jax.Array, axis_name: str) -> jax.Array:
    """all_to_all the bucket buffer: leading dim indexes destination before, source
    after.  Returns local records [n_parts * capacity, W]."""
    out = jax.lax.all_to_all(buffer, axis_name, split_axis=0, concat_axis=0)
    return out.reshape(-1, buffer.shape[-1])


def shuffle(records: jax.Array, keys: jax.Array, valid: jax.Array, *, axis_name: str,
            n_parts: int, capacity: int,
            reduce_overflow: bool = True) -> tuple[jax.Array, jax.Array]:
    """Full map-side shuffle step inside ``shard_map``: partition + bucket + exchange.

    Returns (local_records [n_parts*capacity, W], global_overflow scalar).
    ``reduce_overflow=False`` skips the overflow ``psum`` and returns the
    *local* overflow count instead -- the fused multi-round wave program sums
    every round's local count and runs one ``psum`` per wave, not one per
    round (the caller owns the reduction).
    """
    part = partition_ids(keys, valid, n_parts)
    buf, overflow = bucketize(records, part, n_parts, capacity)
    out = exchange(buf, axis_name)
    if reduce_overflow:
        overflow = jax.lax.psum(overflow, axis_name)
    return out, overflow
