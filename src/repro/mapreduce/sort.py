"""Packed multi-key lexicographic sort -- the MapReduce "sort by key" phase.

Hadoop sorts map outputs with a user comparator (the paper supplies a
reverse-lexicographic one so the streaming reducer can emit early).  The parallel
reducer (``repro.mapreduce.segment``) only needs *contiguity* of equal prefixes, which
any lexicographic order gives, so we use plain ascending order on the packed lanes:
``jax.lax.sort`` with ``num_keys = n_lanes`` performs a lexicographic sort in
``n_lanes`` passes -- bit packing (``repro.mapreduce.pack``) is what keeps that pass
count low (the beyond-paper optimization logged in EXPERIMENTS.md SSPerf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sort_records(records: jax.Array, n_keys: int) -> jax.Array:
    """Sort record rows [N, W] lexicographically by their first ``n_keys`` lanes.

    The remaining lanes (weight / meta) ride along.  Stable order among equal keys is
    irrelevant for counting.
    """
    n, w = records.shape
    cols = [records[:, i] for i in range(w)]
    out = jax.lax.sort(cols, num_keys=n_keys, is_stable=False)
    return jnp.stack(out, axis=1)


def sort_with_payload(keys: jax.Array, payloads: list[jax.Array]) -> tuple[jax.Array, list[jax.Array]]:
    """Sort [N, K] key matrix lexicographically, carrying payload arrays [N, ...]."""
    n, k = keys.shape
    cols = [keys[:, i] for i in range(k)]
    out = jax.lax.sort(cols + list(payloads), num_keys=k, is_stable=False)
    return jnp.stack(out[:k], axis=1), list(out[k:])
