#!/usr/bin/env bash
# One-step verify: install dev deps (best effort -- the suite degrades
# gracefully without hypothesis / pytest-cov) and run the tier-1 test command.
#
#   scripts/ci.sh            # full tier-1 suite (+ coverage gate if available)
#   scripts/ci.sh --fast     # quick tier: skips the slow corpus/property tiers
#
# The full tier includes the slow-marked 8-way mesh regressions
# (tests/test_distributed.py -- sharded serving, generational shards, and the
# distributed-wave parity test test_mesh_waves_match_single_device_and_monolithic);
# --fast skips them along with the other slow corpus/property tiers.
#
# Both tiers finish with an examples smoke step: the streaming-ingest demo
# must run end to end (job -> generational ingest -> cached queries) in
# under 60s on CPU.
#
# The coverage gate engages whenever pytest-cov is importable; the floor is
# seeded conservatively below the suite's measured coverage so it catches
# wholesale test deletion, not refactors.  Ratchet it up as coverage grows.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
    || echo "warning: dev dep install failed (offline?); continuing" >&2

EXTRA=()
if [[ "${1:-}" == "--fast" ]]; then
    shift
    EXTRA+=(-m "not slow")
fi
if python -c "import pytest_cov" 2>/dev/null; then
    EXTRA+=(--cov=repro --cov-report=term --cov-fail-under=60)
else
    echo "note: pytest-cov not installed; running without the coverage gate" >&2
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    ${EXTRA[@]+"${EXTRA[@]}"} "$@"

echo "examples smoke: streaming_ingest.py (60s budget)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 60 \
    python examples/streaming_ingest.py > /dev/null
echo "examples smoke: out_of_core.py (corpus > device budget; 60s budget)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 60 \
    python examples/out_of_core.py > /dev/null

echo "observability smoke: traced + metered wave job, then schema validation"
OBS_TMP="$(mktemp -d)"
trap 'rm -rf "$OBS_TMP"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 120 \
    python -m repro.launch.ngram --tokens 20000 --sigma 3 --tau 5 \
    --wave-tokens 4000 --trace "$OBS_TMP/trace.json" \
    --metrics "$OBS_TMP/metrics.jsonl" > /dev/null
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.obs.report \
    --validate-trace "$OBS_TMP/trace.json" \
    --validate-metrics "$OBS_TMP/metrics.jsonl"

# Serving-frontend smoke: start the HTTP server on an ephemeral port, drive
# the mixed workload from concurrent localhost clients (every request must
# come back 200), then validate the exported frontend metrics (queue-depth
# gauge, batch-fill / TTFB histograms, shed/coalesced counters) against the
# metrics schema.
echo "frontend smoke: HTTP serving stack + metrics validation"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 240 \
    python benchmarks/frontend.py --smoke \
    --metrics "$OBS_TMP/frontend_metrics.jsonl" > /dev/null
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.obs.report \
    --validate-metrics "$OBS_TMP/frontend_metrics.jsonl"

# Wave-engine perf smoke: the fused out-of-core loop must stay within a
# generous multiple of the monolithic job (the tracked target is ~1.5x at
# 8 waves on the full corpus; 3.0x here absorbs CI host noise at the
# reduced --quick corpus).  The fused mesh cell (one shard_map dispatch
# per wave, 8 emulated devices in a subprocess) measures ~4.5x monolithic
# on a 1-core host -- every device thread serializes -- so its gate is
# 6.0x.  Appends a trend row (with the gate_mesh stamp) to BENCH_waves.json.
echo "waves perf smoke: --quick, gate waves_8 <= 3.0x, waves_mesh8_8 <= 6.0x"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 900 \
    python -m benchmarks.run --waves --quick --reps 2 --gate 3.0 --gate-mesh 6.0

# Compressed-at-rest perf smoke: the front-coded layout must stay >= 2x
# smaller at rest, native compaction >= 2x over decode-and-rebuild, and the
# b4096 compressed/flat *lookup* ratio under 2.5x (tracked target is <= 2.0x;
# 2.5 absorbs CI host noise).  --gate-only skips the full cell grid so the
# gate runs at the contract's own 60k report size -- the latency contracts
# are meaningless on a tau-filtered 20k corpus whose index is ~1k rows.
echo "serving perf smoke: compressed lookup gate <= 2.5x flat"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 480 \
    python benchmarks/serving.py --gate-only --lookup-gate 2.5 > /dev/null

echo "examples smoke: OK"
