#!/usr/bin/env bash
# One-step verify: install dev deps (best effort -- the suite degrades
# gracefully without hypothesis) and run the tier-1 test command.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
    || echo "warning: dev dep install failed (offline?); continuing" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
