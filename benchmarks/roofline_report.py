"""Render the dry-run JSON results into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir dryrun_results]
"""
from __future__ import annotations

import argparse
import glob
import json
from collections import defaultdict


def load(directory: str):
    recs = [json.load(open(f)) for f in sorted(glob.glob(f"{directory}/*.json"))]
    return [r for r in recs]


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(recs, mesh: str) -> str:
    rows = ["| arch | shape | kind | compute | memory | collective | bottleneck | "
            "useful (6ND/HLO) | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"SKIPPED | — | — |")
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('kind','')} "
            f"| {fmt_s(rl['compute_s'])} | {fmt_s(rl['memory_s'])} "
            f"| {fmt_s(rl['collective_s'])} | **{rl['bottleneck']}** "
            f"| {rl['useful_fraction']:.2f} | {rl['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def memory_table(recs, mesh: str) -> str:
    rows = ["| arch | shape | args | temps | compile |", "|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        m = r["memory"]
        rows.append(f"| {r['arch']} | {r['shape']} "
                    f"| {m['argument_bytes']/2**30:.2f} GB "
                    f"| {m['temp_bytes']/2**30:.2f} GB | {r['compile_s']}s |")
    return "\n".join(rows)


def summarize(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    by_bneck = defaultdict(list)
    for r in ok:
        if r["mesh"] == "16x16":
            by_bneck[r["roofline"]["bottleneck"]].append(
                (r["arch"], r["shape"], r["roofline"]["roofline_fraction"]))
    return by_bneck


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun_results")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Single-pod (16x16 = 256 chips)\n")
    print(table(recs, "16x16"))
    print("\n## Multi-pod (2x16x16 = 512 chips) — compile proof + terms\n")
    print(table(recs, "2x16x16"))
    print("\n## Per-device memory (single-pod)\n")
    print(memory_table(recs, "16x16"))
    by = summarize(recs)
    print("\n## Bottleneck census (single-pod)\n")
    for k, v in sorted(by.items()):
        worst = sorted(v, key=lambda t: t[2])[:3]
        print(f"- **{k}**: {len(v)} cells; worst fractions: "
              + ", ".join(f"{a}/{s} ({f:.3f})" for a, s, f in worst))


if __name__ == "__main__":
    main()
