"""SSV implementation-impact ablations: sequence encoding (bit packing) and the
map-side combiner, measured on SUFFIX-sigma's exact byte/record counters."""
from __future__ import annotations

import time

from repro.core import NGramConfig, run_job
from repro.data import corpus as corpus_mod


def run(n_tokens: int = 40_000):
    toks = corpus_mod.zipf_corpus(n_tokens, corpus_mod.NYT, seed=3,
                                  duplicate_frac=0.02)
    rows = []
    for pack in (True, False):
        for combine in (True, False):
            cfg = NGramConfig(sigma=5, tau=8, vocab_size=corpus_mod.NYT.vocab_size,
                              pack=pack, combine=combine)
            run_job(toks, cfg)                     # warm
            t0 = time.perf_counter()
            st = run_job(toks, cfg)
            rows.append({
                "pack": pack, "combine": combine,
                "wall_s": time.perf_counter() - t0,
                "records": int(st.counters["shuffle_records"]),
                "bytes": int(st.counters["shuffle_bytes"]),
                "ngrams": len(st),
            })
    base = next(r for r in rows if r["pack"] and r["combine"])
    for r in rows:
        r["bytes_x"] = round(r["bytes"] / base["bytes"], 2)
    return rows
