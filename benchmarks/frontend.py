"""Serving-frontend benchmark: open-loop mixed workload against QueryFrontend.

Unlike ``benchmarks/serving.py`` (closed-loop micro-batches straight into the
index), this drives the whole serving tier -- admission control, duplicate
coalescing, the continuous batcher -- the way production traffic does:
requests arrive on an **open-loop** schedule (arrival times fixed in advance,
independent of completions, so queueing delay is *measured*, not hidden by
backpressure), mixing lookup hits, lookup misses, and top-k continuations
across two priority classes and several tenants.

Protocol:

1. measure capacity closed-loop (N worker threads calling as fast as answers
   return) -- the sustainable QPS of this host/config;
2. run one open-loop cell at ~0.6x capacity (healthy) and one at ~2.5x
   capacity (stress) against the same frontend;
3. run a **burst cell** against a small-bucket frontend: a tight-loop burst
   of cold top-k queries whose instantaneous offered rate (tens of k/s)
   exceeds the drain rate, so queue depth crosses the admission budget within
   milliseconds.  This is the admission layer's contract check: offered load
   beyond the budget must turn into load shedding -- batch-class requests
   shed first, sustained drain holds, and the *admitted* p99 stays bounded
   by ``hard_limit / drain_rate + deadline`` -- rather than latency collapse.

Every run appends an env-stamped record (cells + registry snapshot) to
``BENCH_frontend.json`` so the serving-tier trajectory is diffable run over
run.  ``--smoke`` is the CI mode: tiny corpus, an in-process HTTP server
driven by concurrent client threads over localhost, metrics exported to
JSONL for schema validation -- no BENCH write, seconds not minutes.

    PYTHONPATH=src python benchmarks/frontend.py
    PYTHONPATH=src python benchmarks/frontend.py --smoke --metrics /tmp/m.jsonl
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

BENCH_JSON = "BENCH_frontend.json"

#: workload mix: (kind, needs_hit) weights -- 60% hot lookups, 20% cold
#: lookups, 20% top-k continuations
MIX = (("lookup", True, 0.6), ("lookup", False, 0.2), ("topk", True, 0.2))
PRIORITY_MIX = (("interactive", 0.7), ("batch", 0.3))
TENANTS = ("t0", "t1", "t2", "t3")


def _setup(n_tokens: int, *, deadline_ms: float, queue_budget: int,
           sigma: int = 5, tau: int = 4):
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.serve.admission import AdmissionController
    from repro.serve.frontend import QueryFrontend
    from repro.serve.service import StreamingNGramService

    prof = corpus_mod.NYT
    tokens = corpus_mod.zipf_corpus(n_tokens, prof, seed=0,
                                    duplicate_frac=0.02)
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=prof.vocab_size)
    svc = StreamingNGramService(cfg, cache_capacity=8192)
    svc.ingest(tokens)
    fe = QueryFrontend(svc, admission=AdmissionController(
        queue_budget=queue_budget), deadline_s=deadline_ms / 1e3)
    return svc, fe


def _workload(svc, n: int, *, k: int = 8, seed: int = 1) -> list[tuple]:
    """n pre-drawn requests: (kind, gram_row, length, k, tenant, priority)."""
    from repro.index.merge import segment_to_stats

    sigma = int(svc.cfg.sigma)
    vocab = int(svc.cfg.vocab_size)
    stats = segment_to_stats(svc.gen.segments[0].to_segment())
    grams = np.asarray(stats.grams, np.int32)
    lengths = np.asarray(stats.lengths, np.int32)
    rng = np.random.default_rng(seed)
    kinds = rng.choice(len(MIX), n, p=[w for _, _, w in MIX])
    prios = rng.choice(len(PRIORITY_MIX), n,
                       p=[w for _, w in PRIORITY_MIX])
    hit_ix = rng.integers(0, len(grams), n)
    work = []
    for i in range(n):
        kind, hot, _ = MIX[kinds[i]]
        tenant = TENANTS[i % len(TENANTS)]
        priority = PRIORITY_MIX[prios[i]][0]
        if kind == "topk":
            row = grams[hit_ix[i]]
            ln = max(min(int(lengths[hit_ix[i]]) - 1, sigma - 1), 1)
        elif hot:
            row, ln = grams[hit_ix[i]], int(lengths[hit_ix[i]])
        else:                        # cold: random gram, almost surely absent
            row = rng.integers(1, vocab + 1, sigma).astype(np.int32)
            ln = sigma
        work.append((kind, row, ln, k, tenant, priority))
    return work


def _cold_topk_work(svc, n: int, *, k: int = 32, seed: int = 5) -> list[tuple]:
    """n cold top-k requests (random prefixes, unlikely cached or coalesced),
    alternating priority class -- the burst cell's worst-case traffic."""
    sigma = int(svc.cfg.sigma)
    vocab = int(svc.cfg.vocab_size)
    rng = np.random.default_rng(seed)
    prefixes = rng.integers(1, vocab + 1, (n, sigma - 1)).astype(np.int32)
    return [("topk", prefixes[i], sigma - 1, k, TENANTS[i % len(TENANTS)],
             PRIORITY_MIX[i % 2][0]) for i in range(n)]


def _call(fe, item, timeout=30.0):
    kind, row, ln, k, tenant, priority = item
    return fe.call(kind, row, ln, k=k, tenant=tenant, priority=priority,
                   timeout=timeout)


def measure_capacity(fe, work: list, *, threads: int = 8,
                     duration: float = 1.5) -> float:
    """Closed-loop sustainable QPS: N workers, each next call gated on the
    previous answer, so offered == completed and nothing sheds."""
    for item in work[:64]:                        # compile + cache warm
        _call(fe, item)
    done = [0] * threads
    t_end = time.perf_counter() + duration

    def worker(w: int) -> None:
        i = w
        while time.perf_counter() < t_end:
            _call(fe, work[i % len(work)])
            done[w] += 1
            i += threads

    ts = [threading.Thread(target=worker, args=(w,)) for w in range(threads)]
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return sum(done) / (time.perf_counter() - t0)


def open_loop(fe, work: list, *, rate: float, duration: float) -> dict:
    """One open-loop cell: submit on the fixed arrival schedule, measure
    admitted latency + verdicts.  The dispatcher never blocks on an answer
    (completions land via future callbacks), so queue growth shows up as
    latency/shedding exactly as it would for independent clients."""
    n = max(int(rate * duration), 1)
    lock = threading.Lock()
    all_done = threading.Event()
    state = {"pending": 0, "submitted_all": False, "errors": 0}
    lats: list[float] = []
    verdicts = {"admitted": 0, "coalesced": 0, "shed": 0, "quota": 0}
    shed_by_class = {"interactive": 0, "batch": 0}

    def on_done(f, t0: float) -> None:
        t1 = time.perf_counter()
        with lock:
            if f.cancelled() or f.exception() is not None:
                state["errors"] += 1
            else:
                lats.append(t1 - t0)
            state["pending"] -= 1
            if state["pending"] == 0 and state["submitted_all"]:
                all_done.set()

    t_start = time.perf_counter()
    for i in range(n):
        target = t_start + i / rate
        now = time.perf_counter()
        if target - now > 5e-4:                   # stay open-loop, not busy
            time.sleep(target - now)
        item = work[i % len(work)]
        kind, row, ln, k, tenant, priority = item
        t0 = time.perf_counter()
        ticket = fe.submit(kind, row, ln, k=k, tenant=tenant,
                           priority=priority)
        verdicts[ticket.status] += 1
        if not ticket.admitted:
            shed_by_class[priority] += 1
            continue
        with lock:
            state["pending"] += 1
        ticket.future.add_done_callback(
            lambda f, t0=t0: on_done(f, t0))
    with lock:
        state["submitted_all"] = True
        drained = state["pending"] == 0
    if not drained:
        all_done.wait(timeout=60.0)
    t_total = time.perf_counter() - t_start
    lats.sort()

    def pct(p: float) -> float:
        return lats[min(int(p * len(lats)), len(lats) - 1)] if lats else 0.0

    return {
        "offered_qps": n / t_total,
        "sustained_qps": len(lats) / t_total,
        "p50_s": pct(0.50), "p99_s": pct(0.99),
        "completed": len(lats), "errors": state["errors"],
        "verdicts": verdicts, "shed_by_class": shed_by_class,
    }


def burst_cell(fe, work: list) -> dict:
    """Tight-loop burst: submit everything as fast as Python can, then drain.

    The instantaneous offered rate (no pacing) exceeds the small-bucket
    frontend's drain rate, so queue depth crosses the soft budget (batch
    class sheds) and then the hard limit (everything sheds) within the burst
    window -- the open-loop equivalent of a traffic spike."""
    t_done: dict[int, float] = {}         # per-key setitem is GIL-atomic
    t0s = []
    tickets = []
    t_start = time.perf_counter()
    for i, item in enumerate(work):
        kind, row, ln, k, tenant, priority = item
        t0s.append(time.perf_counter())
        ticket = fe.submit(kind, row, ln, k=k, tenant=tenant,
                           priority=priority)
        tickets.append(ticket)
        if ticket.admitted:
            ticket.future.add_done_callback(
                lambda f, i=i: t_done.__setitem__(i, time.perf_counter()))
    t_submit = time.perf_counter() - t_start
    for t in tickets:
        if t.admitted:
            t.future.result(timeout=60.0)
    t_total = time.perf_counter() - t_start
    verdicts = {"admitted": 0, "coalesced": 0, "shed": 0, "quota": 0}
    shed_by_class = {"interactive": 0, "batch": 0}
    offered_by_class = {"interactive": 0, "batch": 0}
    lats, errors = [], 0
    for i, (t, item) in enumerate(zip(tickets, work)):
        priority = item[5]
        offered_by_class[priority] += 1
        verdicts[t.status] += 1
        if not t.admitted:
            shed_by_class[priority] += 1
        elif t.future.cancelled() or t.future.exception() is not None:
            errors += 1
        else:
            lats.append(t_done[i] - t0s[i])
    lats.sort()

    def pct(p: float) -> float:
        return lats[min(int(p * len(lats)), len(lats) - 1)] if lats else 0.0

    return {
        "offered_qps": len(work) / t_submit,
        "sustained_qps": len(lats) / t_total,
        "p50_s": pct(0.50), "p99_s": pct(0.99),
        "completed": len(lats), "errors": errors,
        "verdicts": verdicts, "shed_by_class": shed_by_class,
        "offered_by_class": offered_by_class,
    }


def _cell_row(name: str, res: dict) -> dict:
    v, s = res["verdicts"], res["shed_by_class"]
    return {"name": name, "us": res["p50_s"] * 1e6,
            "derived": f"offered_qps={res['offered_qps']:.0f};"
                       f"sustained_qps={res['sustained_qps']:.0f};"
                       f"p99_us={res['p99_s'] * 1e6:.0f};"
                       f"coalesced={v['coalesced']};shed={v['shed']};"
                       f"quota={v['quota']};"
                       f"shed_interactive={s['interactive']};"
                       f"shed_batch={s['batch']};errors={res['errors']}"}


def run(args) -> list[dict]:
    svc, fe = _setup(args.tokens, deadline_ms=args.deadline_ms,
                     queue_budget=args.queue_budget)
    try:
        work = _workload(svc, 4096)
        cap = measure_capacity(fe, work, threads=args.threads,
                               duration=args.duration)
        print(f"# measured closed-loop capacity: {cap:.0f} qps "
              f"({args.threads} workers)")
        rows = [{"name": "frontend_capacity", "us": 1e6 / cap,
                 "derived": f"qps={cap:.0f};threads={args.threads};"
                            f"deadline_ms={args.deadline_ms};"
                            f"queue_budget={args.queue_budget}"}]
        under = open_loop(fe, work, rate=0.6 * cap, duration=args.duration)
        rows.append(_cell_row("frontend_openloop_0.6x", under))
        over = open_loop(fe, work, rate=2.5 * cap, duration=args.duration)
        rows.append(_cell_row("frontend_openloop_2.5x", over))
        assert over["errors"] == 0 and under["errors"] == 0
    finally:
        fe.close()

    # the overload/shed contract runs against a small-bucket frontend so the
    # drain rate sits well below a tight submit loop's offered rate: queue
    # depth crosses the soft budget (batch sheds) and the hard limit
    # (everything sheds) inside the burst window
    from repro.serve.admission import AdmissionController
    from repro.serve.frontend import QueryFrontend
    fe2 = QueryFrontend(svc, admission=AdmissionController(
        queue_budget=args.queue_budget), buckets=(16,),
        deadline_s=args.deadline_ms / 1e3)
    try:
        cold = _cold_topk_work(svc, 4000)
        for item in cold[:32]:                     # compile + warm
            _call(fe2, item)
        burst = burst_cell(fe2, cold)
        rows.append(_cell_row("frontend_burst_coldtopk", burst))
        v, s, o = (burst["verdicts"], burst["shed_by_class"],
                   burst["offered_by_class"])
        shed_frac = v["shed"] / max(sum(v.values()), 1)
        drain = burst["sustained_qps"]
        p99_bound = 4 * (fe2.admission.hard_limit / max(drain, 1.0)
                         + args.deadline_ms / 1e3)
        shed_rate = {c: s[c] / max(o[c], 1) for c in s}
        print(f"# burst: offered {burst['offered_qps']:.0f} qps vs drain "
              f"{drain:.0f} qps -> shed {100 * shed_frac:.1f}% "
              f"(interactive {100 * shed_rate['interactive']:.0f}%, "
              f"batch {100 * shed_rate['batch']:.0f}%), admitted p99 "
              f"{burst['p99_s'] * 1e3:.1f}ms (bound {p99_bound * 1e3:.0f}ms)")
        assert shed_frac > 0.05, \
            f"burst shed only {100 * shed_frac:.1f}%: admission not engaging"
        assert shed_rate["batch"] >= shed_rate["interactive"], \
            "batch class must shed before interactive (soft budget)"
        assert burst["p99_s"] <= p99_bound, \
            f"admitted p99 {burst['p99_s']:.3f}s exceeds {p99_bound:.3f}s: " \
            "latency collapsed instead of shedding"
        assert burst["errors"] == 0
        return rows
    finally:
        fe2.close()


def run_smoke(metrics_path: str | None) -> None:
    """CI mode: in-process HTTP server + concurrent localhost clients.

    Exercises the full stack (HTTP -> admission -> batcher -> service) with
    real concurrency, then exports the metrics registry to JSONL for
    ``repro.obs.report --validate-metrics``.
    """
    import http.client

    from repro.obs import report as obs_report
    from repro.serve.http import serve_http

    finish = obs_report.setup(None, metrics_path)
    svc, fe = _setup(8000, deadline_ms=2.0, queue_budget=64, sigma=3, tau=2)
    srv = serve_http(fe, "127.0.0.1", 0, block=False)
    host, port = srv.server_address
    work = _workload(svc, 256, k=4)
    codes: dict[int, int] = {}
    lock = threading.Lock()

    def client(w: int) -> None:
        conn = http.client.HTTPConnection(host, port, timeout=30)
        try:
            for i in range(w, len(work), 4):
                kind, row, ln, k, tenant, priority = work[i]
                if kind == "topk":
                    path, body = "/v1/topk", {"prefix": row[:ln].tolist(),
                                              "k": k}
                else:
                    path, body = "/v1/lookup", {"gram": row[:ln].tolist()}
                conn.request("POST", path, body=json.dumps(body),
                             headers={"Content-Type": "application/json",
                                      "X-Tenant": tenant,
                                      "X-Priority": priority})
                r = conn.getresponse()
                r.read()
                with lock:
                    codes[r.status] = codes.get(r.status, 0) + 1
        finally:
            conn.close()

    ts = [threading.Thread(target=client, args=(w,)) for w in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    srv.shutdown()
    srv.server_close()
    fe.close()
    print(f"# smoke: {sum(codes.values())} HTTP requests, codes {codes}")
    assert codes.get(200, 0) == len(work), f"non-200s in smoke: {codes}"
    finish({"driver": "benchmarks.frontend", "mode": "smoke",
            "http_codes": {str(c): n for c, n in codes.items()}})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=60_000)
    ap.add_argument("--threads", type=int, default=8,
                    help="closed-loop workers for the capacity measurement")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="seconds per cell (capacity + each open-loop cell)")
    ap.add_argument("--deadline-ms", type=float, default=2.0)
    ap.add_argument("--queue-budget", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny corpus, HTTP clients over localhost, "
                         "no BENCH write")
    ap.add_argument("--metrics", default=None,
                    help="with --smoke: metrics JSONL export path")
    args = ap.parse_args()
    if args.smoke:
        run_smoke(args.metrics)
        return
    from repro.obs import metrics as obs_metrics
    from repro.obs import report as obs_report
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    rows = run(args)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.1f},{r['derived']}")
    record = {"tokens": args.tokens, "threads": args.threads,
              "duration": args.duration, "deadline_ms": args.deadline_ms,
              "queue_budget": args.queue_budget,
              "env": obs_report.environment_metadata(),
              "metrics": reg.snapshot(), "rows": rows}
    runs = []
    try:
        with open(BENCH_JSON) as f:
            prev = json.load(f)
        runs = prev["runs"] if "runs" in prev else [prev]
    except (FileNotFoundError, json.JSONDecodeError, KeyError):
        pass
    runs.append(record)
    with open(BENCH_JSON, "w") as f:
        json.dump({"runs": runs}, f, indent=2)
    print(f"# wrote {len(rows)} rows to {BENCH_JSON} "
          f"(run {len(runs)} in history)")


if __name__ == "__main__":
    main()
