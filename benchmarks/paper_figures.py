"""Benchmarks mirroring the paper's experiments (SSVII), one per figure.

The paper measures (a) wallclock, (b) MAP_OUTPUT_BYTES, (c) MAP_OUTPUT_RECORDS for
four methods over two corpora.  We reproduce the design at CPU scale on synthetic
Zipf corpora with NYT/CW-like profiles; counters are exact (not sampled), so the
record/byte claims are validated precisely and wallclock validates the trends.

  fig3_usecases   : language-model vs analytics settings
  fig4_tau        : sweep minimum collection frequency
  fig5_sigma      : sweep maximum length
  fig6_scale      : 25/50/75/100% corpus samples
  fig7_resources  : vary reducer count (simulated partitions on 1 device)
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import NGramConfig, run_job
from repro.data import corpus as corpus_mod

METHODS = ("naive", "apriori_scan", "apriori_index", "suffix_sigma")


def _run(tokens, vocab, method, sigma, tau, **kw):
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=vocab, method=method, **kw)
    run_job(tokens, cfg)                       # warmup: exclude jit compile
    t0 = time.perf_counter()
    st = run_job(tokens, cfg)
    dt = time.perf_counter() - t0
    # records = MAP_OUTPUT_RECORDS analogue (pre-combine, like Hadoop's counter);
    # bytes = what the shuffle actually transfers (post-combine).
    return {"method": method, "sigma": sigma, "tau": tau, "wall_s": dt,
            "ngrams": len(st),
            "records": int(st.counters.get("map_records", 0)),
            "bytes": int(st.counters.get("shuffle_bytes", 0)),
            "jobs": int(st.counters.get("jobs", 1))}


def corpora(n_tokens=60_000):
    nyt = corpus_mod.zipf_corpus(n_tokens, corpus_mod.NYT, seed=0,
                                 duplicate_frac=0.02)
    cw = corpus_mod.zipf_corpus(n_tokens, corpus_mod.CW, seed=1,
                                duplicate_frac=0.05)
    return {"nyt": (nyt, corpus_mod.NYT.vocab_size),
            "cw": (cw, corpus_mod.CW.vocab_size)}


def fig3_usecases(n_tokens=60_000):
    """(a) LM use case sigma=5 low tau; (b) analytics sigma=40 higher tau."""
    out = []
    for name, (toks, vocab) in corpora(n_tokens).items():
        for case, sigma, tau in (("lm", 5, 4), ("analytics", 40, 10)):
            for m in METHODS:
                if m == "naive" and sigma > 20 and len(toks) > 40_000:
                    out.append({"corpus": name, "case": case, "method": m,
                                "wall_s": float("nan"),
                                "note": "did not complete (paper: same on CW)"})
                    continue
                r = _run(toks, vocab, m, sigma, tau)
                r.update(corpus=name, case=case)
                out.append(r)
    return out


def fig4_tau(n_tokens=60_000):
    out = []
    for name, (toks, vocab) in corpora(n_tokens).items():
        for tau in (2, 4, 8, 16, 32):
            for m in METHODS:
                r = _run(toks, vocab, m, sigma=5, tau=tau)
                r.update(corpus=name)
                out.append(r)
    return out


def fig5_sigma(n_tokens=40_000):
    out = []
    for name, (toks, vocab) in corpora(n_tokens).items():
        for sigma in (1, 2, 5, 10, 25, 50):
            for m in METHODS:
                if m == "naive" and sigma >= 25:
                    continue  # quadratic blowup: the paper's missing CW datapoints
                r = _run(toks, vocab, m, sigma=sigma, tau=8)
                r.update(corpus=name)
                out.append(r)
    return out


def fig6_scale(n_tokens=80_000):
    out = []
    full = corpus_mod.zipf_corpus(n_tokens, corpus_mod.NYT, seed=0,
                                  duplicate_frac=0.02)
    for frac in (0.25, 0.5, 0.75, 1.0):
        toks = corpus_mod.scale_sample(full, frac, seed=1) if frac < 1 else full
        for m in METHODS:
            r = _run(toks, corpus_mod.NYT.vocab_size, m, sigma=5, tau=8)
            r.update(frac=frac, tokens=int(toks.size))
            out.append(r)
    return out


def fig7_resources(n_tokens=50_000):
    """Computational-resource scaling (Fig. 7): run the REAL distributed job in
    subprocesses with 1/2/4/8 XLA host devices.  Like the paper's fixed-size
    cluster with varying slot counts, all workers share one physical machine, so
    the same diminishing-returns contention the paper reports (SSVII-H) appears."""
    import subprocess, sys, textwrap, os
    out = []
    for n_dev in (1, 2, 4, 8):
        code = textwrap.dedent(f"""
            import time, numpy as np, jax
            from repro.core import run_job
            from repro.core.stats import NGramConfig
            from repro.data import corpus as corpus_mod
            toks = corpus_mod.zipf_corpus({n_tokens}, corpus_mod.NYT, seed=0)
            mesh = (jax.make_mesh(({n_dev},), ("data",),
                    axis_types=(jax.sharding.AxisType.Auto,))
                    if {n_dev} > 1 else None)
            cfg = NGramConfig(sigma=5, tau=8,
                              vocab_size=corpus_mod.NYT.vocab_size)
            st = run_job(toks, cfg, mesh=mesh)   # warmup incl. compile
            t0 = time.perf_counter()
            st = run_job(toks, cfg, mesh=mesh)
            print("RESULT", time.perf_counter() - t0, len(st))
        """)
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["PYTHONPATH"] = "src"
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, cwd="/root/repo", env=env, timeout=560)
        line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")]
        if not line:
            out.append({"method": "suffix_sigma", "R": n_dev,
                        "wall_s": float("nan"), "ngrams": -1})
            continue
        _, wall, ngrams = line[0].split()
        out.append({"method": "suffix_sigma", "R": n_dev,
                    "wall_s": float(wall), "ngrams": int(ngrams)})
    return out


def validate_claims(rows4, rows5) -> list[str]:
    """Check the paper's qualitative claims against our measurements."""
    claims = []

    def recs(rows, m, **kv):
        sel = [r for r in rows if r["method"] == m
               and all(r.get(k) == v for k, v in kv.items())]
        return sel

    # claim 1: SUFFIX-sigma's record count is constant in tau (SSVII-F)
    ss = recs(rows4, "suffix_sigma", corpus="nyt")
    consts = {r["records"] for r in ss}
    claims.append(f"suffix-sigma records constant over tau: "
                  f"{'PASS' if len(consts) == 1 else 'FAIL'} ({consts})")
    # claim 2: suffix-sigma transfers fewest records at low tau
    low = {r["method"]: r["records"] for r in rows4
           if r.get("corpus") == "nyt" and r["tau"] == 2}
    best = min(low, key=low.get)
    claims.append(f"fewest records at low tau: {best} "
                  f"({'PASS' if best == 'suffix_sigma' else 'FAIL'}) {low}")
    # claim 3: naive records grow with sigma, suffix-sigma records don't
    nv = sorted((r["sigma"], r["records"]) for r in rows5
                if r["method"] == "naive" and r.get("corpus") == "nyt")
    sx = sorted((r["sigma"], r["records"]) for r in rows5
                if r["method"] == "suffix_sigma" and r.get("corpus") == "nyt")
    ok = nv[-1][1] > 2 * nv[0][1] and sx[-1][1] <= sx[0][1] * 1.01
    claims.append(f"naive records grow with sigma, suffix-sigma flat: "
                  f"{'PASS' if ok else 'FAIL'} naive {nv[0][1]}->{nv[-1][1]}, "
                  f"suffix {sx[0][1]}->{sx[-1][1]}")
    # claim 4: apriori methods need multiple jobs, suffix-sigma exactly one
    jobs = {r["method"]: r["jobs"] for r in rows5
            if r.get("corpus") == "nyt" and r["sigma"] == 10}
    ok = jobs["suffix_sigma"] == 1 and jobs["apriori_scan"] > 1
    claims.append(f"single job for suffix-sigma vs {jobs['apriori_scan']} "
                  f"apriori jobs: {'PASS' if ok else 'FAIL'}")
    return claims
