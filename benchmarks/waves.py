"""Wave-engine benchmark: wave count vs job throughput, fold policy, mesh waves.

    PYTHONPATH=src python -m benchmarks.run --waves

Measures the out-of-core tax: the same SUFFIX-sigma job over the same corpus
at several wave sizes (1 wave == the monolithic shape), reps *interleaved*
across all wave counts (the repo's interleaved-median protocol: host-load
transients hit every cell equally) and reduced by medians.  On top of the
wave-count sweep:

  * **accumulator cells** -- the same job at ``ACC_WAVES`` waves under both
    fold policies (``pairwise`` = every wave into one running segment,
    ``tiered`` = the LSM rung stack), recording wall time *and* the measured
    merge work (``fold_rows``: segment rows fed through ``merge_segments``);
  * **streaming cell** -- waves straight into the generational index;
  * **distributed cell** -- the same job with every wave sharded over an
    8-way host mesh, run in a subprocess (the device-count XLA flag must
    precede backend init).

Every run appends to ``BENCH_waves.json`` so regressions are diffable in
review.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

BENCH_JSON = "BENCH_waves.json"
WAVE_COUNTS = (1, 2, 4, 8)
ACC_WAVES = 16          # >= 16 waves: where the tiered fold-work win shows
MESH_DEVICES = 8

_MESH_CELL = """
import json, time
import numpy as np, jax
from repro.core import NGramConfig
from repro.data import corpus as corpus_mod
from repro.pipeline import WaveExecutor
mesh = jax.make_mesh(({devices},), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
prof = corpus_mod.NYT
tokens = corpus_mod.zipf_corpus({n_tokens}, prof, seed=0, duplicate_frac=0.02)
cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)
wave = -(-len(tokens) // {n_waves})
ex = WaveExecutor(cfg, wave_tokens=wave, mesh=mesh)
ex.run(tokens)                                   # compile + cache warm
ts = []
for _ in range({reps}):
    t0 = time.perf_counter(); ex.run(tokens); ts.append(time.perf_counter() - t0)
print(json.dumps({{"us": float(np.median(ts) * 1e6), "n_tokens": len(tokens)}}))
"""


def _mesh_cell(n_tokens: int, reps: int) -> dict:
    """Time distributed waves in a subprocess (forced host device count).

    Never silently drops the cell: any failure comes back as
    ``{"skipped": reason}``, which lands in the benchmark record as an
    explicit skipped row -- ``BENCH_waves.json`` must never read as
    "covered" when the mesh cell actually died.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={MESH_DEVICES}"
    env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "")
    code = _MESH_CELL.format(devices=MESH_DEVICES, n_tokens=n_tokens,
                             n_waves=MESH_DEVICES, reps=reps)
    try:
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=1200, env=env)
    except subprocess.TimeoutExpired:
        print("mesh wave cell timed out (skipped)", file=sys.stderr)
        return {"skipped": "subprocess timeout (1200s)"}
    if r.returncode != 0:
        print(f"mesh wave cell failed (skipped):\n{r.stderr[-2000:]}",
              file=sys.stderr)
        tail = (r.stderr.strip().splitlines() or ["no stderr"])[-1]
        return {"skipped": f"subprocess exit {r.returncode}: {tail[:300]}"}
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(n_tokens: int = 60_000, *, reps: int = 3, mesh: bool = True,
        gate_mesh: float | None = None) -> list[dict]:
    from repro.core import NGramConfig, run_job
    from repro.data import corpus as corpus_mod
    from repro.pipeline import WaveExecutor

    prof = corpus_mod.NYT
    tokens = corpus_mod.zipf_corpus(n_tokens, prof, seed=0, duplicate_frac=0.02)
    n_tokens = len(tokens)              # zipf_corpus appends duplicated docs
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)

    cells: dict[object, callable] = {"mono": lambda: run_job(tokens, cfg)}
    for nw in WAVE_COUNTS:
        wave = -(-n_tokens // nw)
        cells[nw] = (lambda w=wave: WaveExecutor(cfg, wave_tokens=w)
                     .run(tokens))
    # fold-policy cells: same job, ACC_WAVES waves, both accumulators
    acc_wave = -(-n_tokens // ACC_WAVES)
    for strat in ("pairwise", "tiered"):
        cells[f"acc_{strat}"] = (
            lambda s=strat: WaveExecutor(cfg, wave_tokens=acc_wave,
                                         accumulator=s).run(tokens))
    lat: dict[object, list[float]] = {k: [] for k in cells}
    last: dict[object, object] = {}
    for k, fn in cells.items():
        last[k] = fn()                         # compile + cache warm
    for _ in range(reps):                      # interleaved: one rep per cell
        for k, fn in cells.items():
            t0 = time.perf_counter()
            last[k] = fn()
            lat[k].append(time.perf_counter() - t0)

    rows = []
    mono_us = float(np.median(lat["mono"]) * 1e6)
    rows.append({"name": "waves_monolithic", "us": mono_us,
                 "derived": f"tok_s={n_tokens / (mono_us / 1e6):.0f}"})
    for nw in WAVE_COUNTS:
        us = float(np.median(lat[nw]) * 1e6)
        rows.append({
            "name": f"waves_{nw}",
            "us": us,
            "derived": (f"tok_s={n_tokens / (us / 1e6):.0f};"
                        f"vs_mono={us / mono_us:.2f}x"),
        })
    for strat in ("pairwise", "tiered"):
        key = f"acc_{strat}"
        us = float(np.median(lat[key]) * 1e6)
        fold = int(last[key].counters["fold_rows"])
        rows.append({
            "name": f"waves_acc_{strat}_{ACC_WAVES}",
            "us": us,
            "derived": (f"fold_rows={fold};"
                        f"tok_s={n_tokens / (us / 1e6):.0f}"),
        })
    fp = int(last["acc_pairwise"].counters["fold_rows"])
    ft = int(last["acc_tiered"].counters["fold_rows"])
    rows.append({"name": f"waves_fold_work_win_{ACC_WAVES}",
                 "us": 0.0,
                 "derived": f"pairwise/tiered={fp / max(ft, 1):.2f}x"})

    # streaming cell: waves straight into the generational index
    cfg1 = NGramConfig(sigma=5, tau=1, vocab_size=prof.vocab_size)
    wave = -(-n_tokens // WAVE_COUNTS[-1])
    ex = WaveExecutor(cfg1, wave_tokens=wave)
    ex.run_streaming(tokens[: 2 * wave])       # warm
    t_s = []
    for _ in range(max(reps - 1, 1)):
        t0 = time.perf_counter()
        gen, _ = ex.run_streaming(tokens)
        t_s.append(time.perf_counter() - t0)
    us = float(np.median(t_s) * 1e6)
    rows.append({"name": f"waves_streaming_{WAVE_COUNTS[-1]}", "us": us,
                 "derived": (f"tok_s={n_tokens / (us / 1e6):.0f};"
                             f"segments={gen.n_segments}")})

    # distributed cell: every wave sharded over the host mesh (subprocess).
    # A skipped/failed cell still lands as an explicit row -- the record
    # must say WHY the mesh number is missing, never just omit it.
    mesh_name = f"waves_mesh{MESH_DEVICES}_{MESH_DEVICES}"
    mesh_row = _mesh_cell(n_tokens, max(reps - 1, 1)) if mesh \
        else {"skipped": "disabled (--no-mesh)"}
    gate_mesh_stamp = None
    if "skipped" in mesh_row:
        rows.append({"name": mesh_name, "us": 0.0,
                     "skipped": mesh_row["skipped"],
                     "derived": f"skipped={mesh_row['skipped']}"})
        if gate_mesh is not None:
            gate_mesh_stamp = {"limit": gate_mesh, "ratio": None,
                               "ok": False, "skipped": mesh_row["skipped"]}
    else:
        us = mesh_row["us"]
        ratio = us / mono_us
        rows.append({
            "name": mesh_name,
            "us": us,
            "derived": (f"tok_s={mesh_row['n_tokens'] / (us / 1e6):.0f};"
                        f"vs_mono={ratio:.2f}x"),
        })
        if gate_mesh is not None:
            gate_mesh_stamp = {"limit": gate_mesh, "ratio": round(ratio, 4),
                               "ok": ratio <= gate_mesh}

    # tracing-overhead cell: the same waves_N job with the tracer live.
    # Acceptance gates: overhead < 1.05x the untraced median, and >= 90% of
    # the root span's wall time attributed to named child spans.
    from repro.obs import trace as obs_trace
    nw = WAVE_COUNTS[-1]
    wave = -(-n_tokens // nw)
    t_tr = []
    tracer = None
    try:
        for _ in range(reps):
            tracer = obs_trace.enable_tracing()
            t0 = time.perf_counter()
            WaveExecutor(cfg, wave_tokens=wave).run(tokens)
            t_tr.append(time.perf_counter() - t0)
            obs_trace.disable_tracing()
    finally:
        obs_trace.disable_tracing()
    us = float(np.median(t_tr) * 1e6)
    base = float(np.median(lat[nw]) * 1e6)
    cov = obs_trace.span_coverage(tracer.export(), "wave.run")
    rows.append({"name": f"waves_traced_{nw}", "us": us,
                 "derived": (f"overhead={us / base:.3f}x;"
                             f"span_cov={cov:.3f}")})

    # per-run metric snapshot: the job counters of the cells review diffs
    # most (monolithic vs the deepest wave sweep), typed and env-stamped
    from repro.obs import metrics as obs_metrics
    from repro.obs import report as obs_report
    reg = obs_metrics.MetricsRegistry()
    reg.merge_job_counters(last["mono"].counters, prefix="mono.")
    reg.merge_job_counters(last[nw].counters, prefix=f"waves{nw}.")

    try:
        with open(BENCH_JSON) as f:
            prev = json.load(f).get("runs", [])
    except (FileNotFoundError, json.JSONDecodeError):
        prev = []
    record = {"n_tokens": n_tokens, "reps": reps, "rows": rows,
              "env": obs_report.environment_metadata(),
              "metrics": reg.snapshot()}
    if gate_mesh_stamp is not None:
        record["gate_mesh"] = gate_mesh_stamp
    prev.append(record)
    with open(BENCH_JSON, "w") as f:
        json.dump({"runs": prev}, f, indent=2)
    return rows
