"""Wave-engine benchmark: wave count vs job throughput.

    PYTHONPATH=src python -m benchmarks.run --waves

Measures the out-of-core tax: the same SUFFIX-sigma job over the same corpus
at several wave sizes (1 wave == the monolithic shape), reps *interleaved*
across all wave counts (the repo's interleaved-median protocol: host-load
transients hit every cell equally) and reduced by medians.  Also records the
streaming-ingest cell (waves -> GenerationalIndex).  Every run appends to
``BENCH_waves.json`` so regressions are diffable in review.
"""
from __future__ import annotations

import json
import time

import numpy as np

BENCH_JSON = "BENCH_waves.json"
WAVE_COUNTS = (1, 2, 4, 8)


def run(n_tokens: int = 60_000, *, reps: int = 3) -> list[dict]:
    from repro.core import NGramConfig, run_job
    from repro.data import corpus as corpus_mod
    from repro.pipeline import WaveExecutor

    prof = corpus_mod.NYT
    tokens = corpus_mod.zipf_corpus(n_tokens, prof, seed=0, duplicate_frac=0.02)
    n_tokens = len(tokens)              # zipf_corpus appends duplicated docs
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)

    cells: dict[object, callable] = {"mono": lambda: run_job(tokens, cfg)}
    for nw in WAVE_COUNTS:
        wave = -(-n_tokens // nw)
        cells[nw] = (lambda w=wave: WaveExecutor(cfg, wave_tokens=w)
                     .run(tokens))
    lat: dict[object, list[float]] = {k: [] for k in cells}
    for k, fn in cells.items():
        fn()                                   # compile + cache warm
    for _ in range(reps):                      # interleaved: one rep per cell
        for k, fn in cells.items():
            t0 = time.perf_counter()
            fn()
            lat[k].append(time.perf_counter() - t0)

    rows = []
    mono_us = float(np.median(lat["mono"]) * 1e6)
    rows.append({"name": "waves_monolithic", "us": mono_us,
                 "derived": f"tok_s={n_tokens / (mono_us / 1e6):.0f}"})
    for nw in WAVE_COUNTS:
        us = float(np.median(lat[nw]) * 1e6)
        rows.append({
            "name": f"waves_{nw}",
            "us": us,
            "derived": (f"tok_s={n_tokens / (us / 1e6):.0f};"
                        f"vs_mono={us / mono_us:.2f}x"),
        })

    # streaming cell: waves straight into the generational index
    cfg1 = NGramConfig(sigma=5, tau=1, vocab_size=prof.vocab_size)
    wave = -(-n_tokens // WAVE_COUNTS[-1])
    ex = WaveExecutor(cfg1, wave_tokens=wave)
    ex.run_streaming(tokens[: 2 * wave])       # warm
    t_s = []
    for _ in range(max(reps - 1, 1)):
        t0 = time.perf_counter()
        gen, _ = ex.run_streaming(tokens)
        t_s.append(time.perf_counter() - t0)
    us = float(np.median(t_s) * 1e6)
    rows.append({"name": f"waves_streaming_{WAVE_COUNTS[-1]}", "us": us,
                 "derived": (f"tok_s={n_tokens / (us / 1e6):.0f};"
                             f"segments={gen.n_segments}")})

    try:
        with open(BENCH_JSON) as f:
            prev = json.load(f).get("runs", [])
    except (FileNotFoundError, json.JSONDecodeError):
        prev = []
    prev.append({"n_tokens": n_tokens, "reps": reps, "rows": rows})
    with open(BENCH_JSON, "w") as f:
        json.dump({"runs": prev}, f, indent=2)
    return rows
