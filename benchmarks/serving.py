"""Index-serving benchmark: build->freeze->query QPS at the paper-report sizes.

One entry per (path, batch) cell so the serving subsystem shows up in the perf
trajectory next to the job-side kernels: point lookup and top-k continuation,
micro-batched at {1, 64, 4096}, plus the index freeze itself.  With
``compress=True`` (or ``--compress`` on the CLI) every cell is measured for the
front-coded + Elias-Fano layout too, and the header rows report bytes and
bytes-per-gram for both.

The compressed layout's contract -- >= 2x smaller, batch-4096 latency within 3x
of the uncompressed plan -- is checked from *interleaved* uncompressed /
compressed batches (``--compress`` on the CLI), so host-load drift hits both
sides equally instead of whichever layout happened to run last.

``--streaming`` adds the generational-index freshness cells: incremental ingest
of a 10% corpus delta (job on the delta + L0 freeze) vs a from-scratch rebuild
(job on the full corpus + full freeze), measured *interleaved* per the
host-noise protocol, plus the forced-compaction merge cost and the post-merge
query latency.  Every run writes ``BENCH_serving.json`` so the serving perf
trajectory is recorded run over run.

    PYTHONPATH=src python benchmarks/serving.py --compress --streaming
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

BATCH_SIZES = (1, 64, 4096)
CONTRACT_BATCH = 4096
BENCH_JSON = "BENCH_serving.json"


def _setup(n_tokens: int, n_queries: int, topk: int, compress: bool):
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.index import (build_index, compress_index, continuations,
                             lookup)
    from repro.launch.serve_ngrams import make_query_stream

    prof = corpus_mod.NYT
    tokens = corpus_mod.zipf_corpus(n_tokens, prof, seed=0, duplicate_frac=0.02)
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)
    stats = run_job(tokens, cfg)

    rows: list[dict] = []
    n_grams = max(len(stats), 1)
    t0 = time.perf_counter()
    idx = build_index(stats, vocab_size=prof.vocab_size)
    idx.lanes.block_until_ready()
    rows.append({"name": "index_build", "us": (time.perf_counter() - t0) * 1e6,
                 "derived": f"rows={len(stats)};bytes={idx.nbytes};"
                            f"bpg={idx.nbytes / n_grams:.2f}"})
    layouts = [("", idx)]
    if compress:
        t0 = time.perf_counter()
        cidx = compress_index(idx)
        cidx.heads.block_until_ready()
        rows.append({"name": "index_compress",
                     "us": (time.perf_counter() - t0) * 1e6,
                     "derived": f"rows={len(stats)};bytes={cidx.nbytes};"
                                f"bpg={cidx.nbytes / n_grams:.2f};"
                                f"ratio={idx.nbytes / cidx.nbytes:.2f}"})
        layouts.append(("_comp", cidx))

    grams, lengths = make_query_stream(stats, n_queries=n_queries, sigma=5,
                                       vocab_size=prof.vocab_size, miss_frac=0.3)

    def answers(ix):
        def answer_lookup(g, ln):
            return np.asarray(lookup(ix, g, ln))

        def answer_topk(g, ln):
            # continuations() masks the gram past the prefix length itself
            return np.asarray(continuations(ix, g, np.maximum(ln - 1, 0),
                                            k=topk)[3])
        return answer_lookup, answer_topk

    return rows, layouts, answers, grams, lengths


def run(n_tokens: int = 60_000, *, n_queries: int = 12_000,
        topk: int = 8, compress: bool = False,
        _ctx: tuple | None = None) -> list[dict]:
    from repro.launch.serve_ngrams import microbatch_drive

    rows, layouts, answers, grams, lengths = _ctx if _ctx is not None else \
        _setup(n_tokens, n_queries, topk, compress)
    for tag, ix in layouts:
        answer_lookup, answer_topk = answers(ix)
        for mode, answer in (("lookup", answer_lookup), ("topk", answer_topk)):
            for batch in BATCH_SIZES:
                qps, lat = microbatch_drive(answer, grams, lengths, batch)
                rows.append({
                    "name": f"serve_{mode}{tag}_b{batch}",
                    "us": float(np.median(lat) * 1e6),
                    "derived": f"qps={qps:.0f}",
                })
    return rows


def run_streaming(n_tokens: int = 60_000, *, delta_frac: float = 0.1,
                  reps: int = 5, batch: int = 4096) -> list[dict]:
    """Generational freshness cells: incremental ingest vs full rebuild.

    One rep of each, alternating (the interleaved-median protocol: host-load
    transients hit both sides equally), then medians.  The incremental path is
    job(delta) + L0 freeze + any size-ratio merges; the rebuild path is
    job(base+delta) + full freeze.
    """
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.index import GenerationalIndex, build_index, lookup
    from repro.launch.serve_ngrams import make_query_stream

    prof = corpus_mod.NYT
    n_delta = int(n_tokens * delta_frac)
    full = corpus_mod.zipf_corpus(n_tokens + n_delta, prof, seed=0,
                                  duplicate_frac=0.02)
    base, delta = full[:n_tokens], full[n_tokens:]
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)
    stats_base = run_job(base, cfg)
    base_idx = build_index(stats_base, vocab_size=prof.vocab_size)

    def incremental():
        gen = GenerationalIndex(sigma=5, vocab_size=prof.vocab_size)
        gen.levels = [base_idx]
        gen.generation = 1
        gen.ingest(run_job(delta, cfg))
        return gen

    def rebuild():
        return build_index(run_job(full, cfg), vocab_size=prof.vocab_size)

    incremental(), rebuild()                      # compile + cache warm
    t_inc, t_reb = [], []
    gen = None
    for _ in range(reps):
        t0 = time.perf_counter()
        gen = incremental()
        t_inc.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rebuild()
        t_reb.append(time.perf_counter() - t0)
    inc_us = float(np.median(t_inc) * 1e6)
    reb_us = float(np.median(t_reb) * 1e6)

    t0 = time.perf_counter()
    gen.compact_all()                             # forced merge, job-free
    t_merge = time.perf_counter() - t0

    grams, lengths = make_query_stream(stats_base, n_queries=batch, sigma=5,
                                       vocab_size=prof.vocab_size,
                                       miss_frac=0.3)
    lookup(gen, grams, lengths)                   # compile
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        np.asarray(lookup(gen, grams, lengths))
        lat.append(time.perf_counter() - t0)

    return [
        {"name": "streaming_ingest_10pct", "us": inc_us,
         "derived": f"tok_per_s={n_delta / (inc_us / 1e6):.0f};"
                    f"speedup_vs_rebuild={reb_us / inc_us:.2f}"},
        {"name": "streaming_full_rebuild", "us": reb_us,
         "derived": f"tokens={n_tokens + n_delta}"},
        {"name": "streaming_compaction", "us": t_merge * 1e6,
         "derived": f"rows={gen.n_rows};segments={gen.n_segments}"},
        {"name": f"streaming_postmerge_lookup_b{batch}",
         "us": float(np.median(lat) * 1e6),
         "derived": f"qps={batch / np.median(lat):.0f}"},
    ]


def contract_slowdown(layouts, answers, grams, lengths, *,
                      batch: int = CONTRACT_BATCH, reps: int = 9) -> float:
    """Worst compressed/uncompressed median-latency ratio over both modes,
    measured batch-interleaved so load transients cancel."""
    (_, idx), (_, cidx) = layouts
    g, ln = grams[:batch], lengths[:batch]
    worst = 0.0
    for mode_i in (0, 1):
        a_u = answers(idx)[mode_i]
        a_c = answers(cidx)[mode_i]
        a_u(g, ln), a_c(g, ln), a_u(g, ln), a_c(g, ln)     # compile + warm
        lat_u, lat_c = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            a_u(g, ln)
            lat_u.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            a_c(g, ln)
            lat_c.append(time.perf_counter() - t0)
        worst = max(worst, float(np.median(lat_c) / np.median(lat_u)))
    return worst


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=60_000)
    ap.add_argument("--queries", type=int, default=12_000)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--compress", action="store_true",
                    help="also measure the front-coded + Elias-Fano layout and "
                         "check the size/latency contract")
    ap.add_argument("--streaming", action="store_true",
                    help="also measure generational freshness: incremental "
                         "10%% ingest vs full rebuild (interleaved medians), "
                         "compaction cost, post-merge latency")
    args = ap.parse_args()
    # live registry for the drive-loop latency histograms; snapshot rides the
    # BENCH record so percentiles are diffable run over run
    from repro.obs import metrics as obs_metrics
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    ctx = _setup(args.tokens, max(args.queries, CONTRACT_BATCH), args.topk,
                 args.compress)
    rows = run(args.tokens, n_queries=args.queries, topk=args.topk,
               compress=args.compress, _ctx=ctx)
    if args.streaming:
        rows.extend(run_streaming(args.tokens))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.1f},{r['derived']}")
    from repro.obs import report as obs_report
    record = {"tokens": args.tokens, "queries": args.queries,
              "compress": args.compress, "streaming": args.streaming,
              "env": obs_report.environment_metadata(),
              "metrics": reg.snapshot(), "rows": rows}
    # append-only history: the perf *trajectory*, not just the latest run
    runs = []
    try:
        with open(BENCH_JSON) as f:
            prev = json.load(f)
        runs = prev["runs"] if "runs" in prev else [prev]
    except (FileNotFoundError, json.JSONDecodeError, KeyError):
        pass
    runs.append(record)
    with open(BENCH_JSON, "w") as f:
        json.dump({"runs": runs}, f, indent=2)
    print(f"# wrote {len(rows)} rows to {BENCH_JSON} "
          f"(run {len(runs)} in history)")
    if args.compress:
        _, layouts, answers, grams, lengths = ctx
        nb, nc = layouts[0][1].nbytes, layouts[1][1].nbytes
        ratio = nb / nc
        slowdown = contract_slowdown(layouts, answers, grams, lengths)
        print(f"# compressed layout: {nb} -> {nc} bytes "
              f"({ratio:.2f}x smaller), worst interleaved b{CONTRACT_BATCH} "
              f"median-latency slowdown {slowdown:.2f}x")
        assert ratio >= 2.0, f"compression ratio {ratio:.2f} < 2x contract"
        assert slowdown <= 3.0, f"slowdown {slowdown:.2f} > 3x contract"
    if args.streaming:
        by_name = {r["name"]: r for r in rows}
        speedup = (by_name["streaming_full_rebuild"]["us"]
                   / by_name["streaming_ingest_10pct"]["us"])
        print(f"# streaming: incremental 10% ingest {speedup:.2f}x faster "
              "than full rebuild (interleaved medians)")
        assert speedup > 1.5, \
            f"incremental ingest speedup {speedup:.2f} not measurably > 1x"


if __name__ == "__main__":
    main()
