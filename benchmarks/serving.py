"""Index-serving benchmark: build->freeze->query QPS at the paper-report sizes.

One entry per (path, batch) cell so the serving subsystem shows up in the perf
trajectory next to the job-side kernels: point lookup and top-k continuation,
micro-batched at {1, 64, 4096}, plus the index freeze itself.  With
``compress=True`` (or ``--compress`` on the CLI) every cell is measured for the
front-coded + Elias-Fano layout too, and the header rows report bytes and
bytes-per-gram for both.

The compressed layout's contract -- >= 2x smaller, batch-4096 latency within 3x
of the uncompressed plan -- is checked from *interleaved* uncompressed /
compressed batches (``--compress`` on the CLI), so host-load drift hits both
sides equally instead of whichever layout happened to run last.

``--streaming`` adds the generational-index freshness cells: incremental ingest
of a 10% corpus delta (job on the delta + L0 freeze) vs a from-scratch rebuild
(job on the full corpus + full freeze), measured *interleaved* per the
host-noise protocol, plus the forced-compaction merge cost and the post-merge
query latency.  Every run writes ``BENCH_serving.json`` so the serving perf
trajectory is recorded run over run.

    PYTHONPATH=src python benchmarks/serving.py --compress --streaming
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

BATCH_SIZES = (1, 64, 4096)
CONTRACT_BATCH = 4096
BENCH_JSON = "BENCH_serving.json"


def _setup(n_tokens: int, n_queries: int, topk: int, compress: bool):
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.index import (build_index, compress_index, continuations,
                             lookup)
    from repro.launch.serve_ngrams import make_query_stream

    prof = corpus_mod.NYT
    tokens = corpus_mod.zipf_corpus(n_tokens, prof, seed=0, duplicate_frac=0.02)
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)
    stats = run_job(tokens, cfg)

    rows: list[dict] = []
    n_grams = max(len(stats), 1)
    t0 = time.perf_counter()
    idx = build_index(stats, vocab_size=prof.vocab_size)
    idx.lanes.block_until_ready()
    rows.append({"name": "index_build", "us": (time.perf_counter() - t0) * 1e6,
                 "derived": f"rows={len(stats)};bytes={idx.nbytes};"
                            f"bpg={idx.nbytes / n_grams:.2f}"})
    layouts = [("", idx)]
    if compress:
        t0 = time.perf_counter()
        cidx = compress_index(idx)
        cidx.heads.block_until_ready()
        # bytes: resident includes the decoded query caches; at_rest is the
        # persisted artifact (streams + EF directories) -- the storage story
        rows.append({"name": "index_compress",
                     "us": (time.perf_counter() - t0) * 1e6,
                     "derived": f"rows={len(stats)};bytes={cidx.nbytes};"
                                f"bytes_at_rest={cidx.nbytes_at_rest};"
                                f"bpg={cidx.nbytes_at_rest / n_grams:.2f};"
                                f"ratio={idx.nbytes / cidx.nbytes:.2f};"
                                f"ratio_at_rest="
                                f"{idx.nbytes / cidx.nbytes_at_rest:.2f}"})
        layouts.append(("_comp", cidx))

    grams, lengths = make_query_stream(stats, n_queries=n_queries, sigma=5,
                                       vocab_size=prof.vocab_size, miss_frac=0.3)

    def answers(ix):
        def answer_lookup(g, ln):
            return np.asarray(lookup(ix, g, ln))

        def answer_topk(g, ln):
            # continuations() masks the gram past the prefix length itself
            return np.asarray(continuations(ix, g, np.maximum(ln - 1, 0),
                                            k=topk)[3])
        return answer_lookup, answer_topk

    return rows, layouts, answers, grams, lengths, stats


def run(n_tokens: int = 60_000, *, n_queries: int = 12_000,
        topk: int = 8, compress: bool = False,
        _ctx: tuple | None = None) -> list[dict]:
    from repro.launch.serve_ngrams import microbatch_drive

    rows, layouts, answers, grams, lengths, _ = _ctx if _ctx is not None else \
        _setup(n_tokens, n_queries, topk, compress)
    for tag, ix in layouts:
        answer_lookup, answer_topk = answers(ix)
        for mode, answer in (("lookup", answer_lookup), ("topk", answer_topk)):
            for batch in BATCH_SIZES:
                qps, lat = microbatch_drive(answer, grams, lengths, batch)
                rows.append({
                    "name": f"serve_{mode}{tag}_b{batch}",
                    "us": float(np.median(lat) * 1e6),
                    "derived": f"qps={qps:.0f}",
                })
    return rows


def run_streaming(n_tokens: int = 60_000, *, delta_frac: float = 0.1,
                  reps: int = 5, batch: int = 4096) -> list[dict]:
    """Generational freshness cells: incremental ingest vs full rebuild.

    One rep of each, alternating (the interleaved-median protocol: host-load
    transients hit both sides equally), then medians.  The incremental path is
    job(delta) + L0 freeze + any size-ratio merges; the rebuild path is
    job(base+delta) + full freeze.
    """
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.index import GenerationalIndex, build_index, lookup
    from repro.launch.serve_ngrams import make_query_stream

    prof = corpus_mod.NYT
    n_delta = int(n_tokens * delta_frac)
    full = corpus_mod.zipf_corpus(n_tokens + n_delta, prof, seed=0,
                                  duplicate_frac=0.02)
    base, delta = full[:n_tokens], full[n_tokens:]
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)
    stats_base = run_job(base, cfg)
    base_idx = build_index(stats_base, vocab_size=prof.vocab_size)

    def incremental():
        gen = GenerationalIndex(sigma=5, vocab_size=prof.vocab_size)
        gen.levels = [base_idx]
        gen.generation = 1
        gen.ingest(run_job(delta, cfg))
        return gen

    def rebuild():
        return build_index(run_job(full, cfg), vocab_size=prof.vocab_size)

    incremental(), rebuild()                      # compile + cache warm
    t_inc, t_reb = [], []
    gen = None
    for _ in range(reps):
        t0 = time.perf_counter()
        gen = incremental()
        t_inc.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rebuild()
        t_reb.append(time.perf_counter() - t0)
    inc_us = float(np.median(t_inc) * 1e6)
    reb_us = float(np.median(t_reb) * 1e6)

    t0 = time.perf_counter()
    gen.compact_all()                             # forced merge, job-free
    t_merge = time.perf_counter() - t0

    grams, lengths = make_query_stream(stats_base, n_queries=batch, sigma=5,
                                       vocab_size=prof.vocab_size,
                                       miss_frac=0.3)
    lookup(gen, grams, lengths)                   # compile
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        np.asarray(lookup(gen, grams, lengths))
        lat.append(time.perf_counter() - t0)

    return [
        {"name": "streaming_ingest_10pct", "us": inc_us,
         "derived": f"tok_per_s={n_delta / (inc_us / 1e6):.0f};"
                    f"speedup_vs_rebuild={reb_us / inc_us:.2f}"},
        {"name": "streaming_full_rebuild", "us": reb_us,
         "derived": f"tokens={n_tokens + n_delta}"},
        {"name": "streaming_compaction", "us": t_merge * 1e6,
         "derived": f"rows={gen.n_rows};segments={gen.n_segments}"},
        {"name": f"streaming_postmerge_lookup_b{batch}",
         "us": float(np.median(lat) * 1e6),
         "derived": f"qps={batch / np.median(lat):.0f}"},
    ]


def run_compaction(*, vocab: int, sigma: int = 5, n_rows: int = 150_000,
                   parts: int = 3, reps: int = 3) -> list[dict]:
    """Native compressed compaction vs decode-and-rebuild, interleaved.

    The native path k-way merges the frozen rungs through the streamed block
    decode (sortedness exploited, O(block batch) decoded working set); the
    baseline decodes every rung back to a full stats table and re-runs the
    whole build -- unpack, union, re-sort, pack, compress -- from scratch.
    Both produce the identical artifact (asserted), so the speedup is pure
    merge-path economics.  Inputs are synthetic sorted tables (base-V digits
    of unique ids, round-robin split into ``parts`` overlapping-range rungs)
    so the merge works O(100k) rows regardless of the corpus knob -- a
    tau-filtered demo corpus only yields a few thousand.
    """
    from repro.core.stats import NGramStats
    from repro.index import build_compressed_index, merge_indexes

    rng = np.random.default_rng(0)
    lim = min(vocab ** sigma, 2 ** 62)
    ids = np.unique(rng.integers(0, lim, n_rows * 2, dtype=np.int64))[:n_rows]
    terms = np.empty((len(ids), sigma), np.int32)
    q = ids.copy()
    for j in range(sigma):                  # unique id -> unique term row
        terms[:, j] = q % vocab + 1
        q //= vocab
    stats = [NGramStats(terms[i::parts],
                        np.full(len(terms[i::parts]), sigma, np.int32),
                        rng.integers(1, 1000,
                                     len(terms[i::parts])).astype(np.int64))
             for i in range(parts)]
    entries = [build_compressed_index(s, vocab_size=vocab) for s in stats]

    def native():
        out = merge_indexes(entries, route="kway")
        out.heads.block_until_ready()
        return out

    def decode_rebuild():
        from repro.index import segment_to_stats, stats_union
        full = stats_union(*[segment_to_stats(ix.to_segment())
                             for ix in entries])
        out = build_compressed_index(full, vocab_size=vocab,
                                     block_size=entries[0].block_size)
        out.heads.block_until_ready()
        return out

    a, b = native(), decode_rebuild()             # warm + identity check
    np.testing.assert_array_equal(np.asarray(a.heads), np.asarray(b.heads))
    t_nat, t_reb = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        native()
        t_nat.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        decode_rebuild()
        t_reb.append(time.perf_counter() - t0)
    nat_us = float(np.median(t_nat) * 1e6)
    reb_us = float(np.median(t_reb) * 1e6)
    return [
        {"name": "compaction_native_compressed", "us": nat_us,
         "derived": f"rows={a.n_rows};segments={parts};"
                    f"speedup_vs_decode_rebuild={reb_us / nat_us:.2f}"},
        {"name": "compaction_decode_rebuild", "us": reb_us,
         "derived": f"rows={b.n_rows}"},
    ]


def run_mixed_stack(ctx, *, topk: int = 8, reps: int = 7,
                    batch: int = CONTRACT_BATCH) -> list[dict]:
    """Mixed-stack cells: hot flat L0 over a frozen compressed elder (the
    generational tier policy's serving shape) vs the all-flat stack of the
    same rows, measured interleaved, plus the bytes-at-rest census."""
    from repro.index import (GenerationalIndex, build_compressed_index,
                             build_index, continuations, lookup)

    _, layouts, _, grams, lengths, stats = ctx
    vocab = layouts[0][1].vocab_size
    sigma = layouts[0][1].sigma
    from repro.core.stats import NGramStats
    cut = int(len(stats) * 0.85)            # elder 85% of rows, delta 15%
    elder = NGramStats(stats.grams[:cut], stats.lengths[:cut],
                       stats.counts[:cut])
    delta = NGramStats(stats.grams[cut:], stats.lengths[cut:],
                       stats.counts[cut:])
    mixed = GenerationalIndex(sigma=sigma, vocab_size=vocab)
    mixed.levels = [build_index(delta, vocab_size=vocab),
                    build_compressed_index(elder, vocab_size=vocab)]
    flat = GenerationalIndex(sigma=sigma, vocab_size=vocab)
    flat.levels = [mixed.levels[0], build_index(elder, vocab_size=vocab)]
    g, ln = grams[:batch], lengths[:batch]
    pl = np.maximum(ln - 1, 0)
    cells = []
    for mode, call in (
            ("lookup", lambda ix: np.asarray(lookup(ix, g, ln))),
            ("topk", lambda ix: np.asarray(
                continuations(ix, g, pl, k=topk)[3]))):
        call(mixed), call(flat), call(mixed), call(flat)   # compile + warm
        lat_m, lat_f = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            call(mixed)
            lat_m.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            call(flat)
            lat_f.append(time.perf_counter() - t0)
        m_us = float(np.median(lat_m) * 1e6)
        cells.append({"name": f"serve_{mode}_mixed_b{batch}", "us": m_us,
                      "derived": f"qps={batch / (m_us / 1e6):.0f};"
                                 f"ratio_vs_flat_stack="
                                 f"{np.median(lat_m) / np.median(lat_f):.2f}"})

    at_rest = sum(getattr(ix, "nbytes_at_rest", None) or ix.nbytes
                  for ix in mixed.levels)
    resident = sum(ix.nbytes for ix in mixed.levels)
    flat_bytes = sum(ix.nbytes for ix in flat.levels)
    cells.append({"name": "gen_bytes_at_rest", "us": 0.0,
                  "derived": f"at_rest={at_rest};resident={resident};"
                             f"flat={flat_bytes};"
                             f"ratio_vs_flat={flat_bytes / at_rest:.2f}"})
    return cells


def contract_slowdown(layouts, answers, grams, lengths, *,
                      batch: int = CONTRACT_BATCH, reps: int = 9,
                      modes: tuple = (0, 1)) -> float:
    """Worst compressed/uncompressed median-latency ratio over the given
    modes (0=lookup, 1=topk), measured batch-interleaved so load transients
    cancel."""
    (_, idx), (_, cidx) = layouts
    g, ln = grams[:batch], lengths[:batch]
    worst = 0.0
    for mode_i in modes:
        a_u = answers(idx)[mode_i]
        a_c = answers(cidx)[mode_i]
        a_u(g, ln), a_c(g, ln), a_u(g, ln), a_c(g, ln)     # compile + warm
        lat_u, lat_c = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            a_u(g, ln)
            lat_u.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            a_c(g, ln)
            lat_c.append(time.perf_counter() - t0)
        worst = max(worst, float(np.median(lat_c) / np.median(lat_u)))
    return worst


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=60_000)
    ap.add_argument("--queries", type=int, default=12_000)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--compress", action="store_true",
                    help="also measure the front-coded + Elias-Fano layout and "
                         "check the size/latency contract")
    ap.add_argument("--streaming", action="store_true",
                    help="also measure generational freshness: incremental "
                         "10%% ingest vs full rebuild (interleaved medians), "
                         "compaction cost, post-merge latency")
    ap.add_argument("--lookup-gate", type=float, default=None,
                    help="fail if the interleaved b4096 compressed/flat "
                         "*lookup* latency ratio exceeds this (CI quick gate)")
    ap.add_argument("--gate-only", action="store_true",
                    help="contract checks only (implies --compress): skip the "
                         "per-batch cell grid and the mixed-stack cells so CI "
                         "can gate at the full report size in minutes")
    args = ap.parse_args()
    if args.gate_only:
        args.compress = True
    # live registry for the drive-loop latency histograms; snapshot rides the
    # BENCH record so percentiles are diffable run over run
    from repro.obs import metrics as obs_metrics
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    ctx = _setup(args.tokens, max(args.queries, CONTRACT_BATCH), args.topk,
                 args.compress)
    rows = ctx[0] if args.gate_only else \
        run(args.tokens, n_queries=args.queries, topk=args.topk,
            compress=args.compress, _ctx=ctx)
    if args.compress:
        rows.extend(run_compaction(vocab=ctx[1][0][1].vocab_size))
        if not args.gate_only:
            rows.extend(run_mixed_stack(ctx, topk=args.topk))
    if args.streaming:
        rows.extend(run_streaming(args.tokens))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.1f},{r['derived']}")
    from repro.obs import report as obs_report
    record = {"tokens": args.tokens, "queries": args.queries,
              "compress": args.compress, "streaming": args.streaming,
              "env": obs_report.environment_metadata(),
              "metrics": reg.snapshot(), "rows": rows}
    # append-only history: the perf *trajectory*, not just the latest run
    runs = []
    try:
        with open(BENCH_JSON) as f:
            prev = json.load(f)
        runs = prev["runs"] if "runs" in prev else [prev]
    except (FileNotFoundError, json.JSONDecodeError, KeyError):
        pass
    runs.append(record)
    with open(BENCH_JSON, "w") as f:
        json.dump({"runs": runs}, f, indent=2)
    print(f"# wrote {len(rows)} rows to {BENCH_JSON} "
          f"(run {len(runs)} in history)")
    if args.compress:
        _, layouts, answers, grams, lengths, _stats = ctx
        # the size contract holds on the at-rest artifact; the resident form
        # (with decoded query caches) must still be within 2x of at-rest
        nb = layouts[0][1].nbytes
        nc = layouts[1][1].nbytes_at_rest
        ratio = nb / nc
        slowdown = contract_slowdown(layouts, answers, grams, lengths)
        print(f"# compressed layout: {nb} -> {nc} bytes at rest "
              f"({ratio:.2f}x smaller), worst interleaved b{CONTRACT_BATCH} "
              f"median-latency slowdown {slowdown:.2f}x")
        assert ratio >= 2.0, f"compression ratio {ratio:.2f} < 2x contract"
        assert layouts[1][1].nbytes <= 2 * nc, "resident caches dominate"
        assert slowdown <= 3.0, f"slowdown {slowdown:.2f} > 3x contract"
        by_name = {r["name"]: r for r in rows}
        nat = by_name["compaction_native_compressed"]["us"]
        reb = by_name["compaction_decode_rebuild"]["us"]
        print(f"# compaction: native {nat:.0f}us vs decode-and-rebuild "
              f"{reb:.0f}us ({reb / nat:.2f}x)")
        assert reb / nat >= 2.0, \
            f"native compaction speedup {reb / nat:.2f} < 2x contract"
        if args.lookup_gate is not None:
            lk = contract_slowdown(layouts, answers, grams, lengths,
                                   modes=(0,))
            print(f"# lookup gate: interleaved b{CONTRACT_BATCH} compressed/"
                  f"flat lookup ratio {lk:.2f}x (gate {args.lookup_gate}x)")
            assert lk <= args.lookup_gate, \
                f"compressed lookup ratio {lk:.2f} > {args.lookup_gate}x gate"
    if args.streaming:
        by_name = {r["name"]: r for r in rows}
        speedup = (by_name["streaming_full_rebuild"]["us"]
                   / by_name["streaming_ingest_10pct"]["us"])
        print(f"# streaming: incremental 10% ingest {speedup:.2f}x faster "
              "than full rebuild (interleaved medians)")
        assert speedup > 1.5, \
            f"incremental ingest speedup {speedup:.2f} not measurably > 1x"


if __name__ == "__main__":
    main()
