"""Index-serving benchmark: build->freeze->query QPS at the paper-report sizes.

One entry per (path, batch) cell so the serving subsystem shows up in the perf
trajectory next to the job-side kernels: point lookup and top-k continuation,
micro-batched at {1, 64, 4096}, plus the index freeze itself.  With
``compress=True`` (or ``--compress`` on the CLI) every cell is measured for the
front-coded + Elias-Fano layout too, and the header rows report bytes and
bytes-per-gram for both.

The compressed layout's contract -- >= 2x smaller, batch-4096 latency within 3x
of the uncompressed plan -- is checked from *interleaved* uncompressed /
compressed batches (``--compress`` on the CLI), so host-load drift hits both
sides equally instead of whichever layout happened to run last.

    PYTHONPATH=src python benchmarks/serving.py --compress
"""
from __future__ import annotations

import argparse
import time

import numpy as np

BATCH_SIZES = (1, 64, 4096)
CONTRACT_BATCH = 4096


def _setup(n_tokens: int, n_queries: int, topk: int, compress: bool):
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.index import (build_index, compress_index, continuations,
                             lookup)
    from repro.launch.serve_ngrams import make_query_stream

    prof = corpus_mod.NYT
    tokens = corpus_mod.zipf_corpus(n_tokens, prof, seed=0, duplicate_frac=0.02)
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)
    stats = run_job(tokens, cfg)

    rows: list[dict] = []
    n_grams = max(len(stats), 1)
    t0 = time.perf_counter()
    idx = build_index(stats, vocab_size=prof.vocab_size)
    idx.lanes.block_until_ready()
    rows.append({"name": "index_build", "us": (time.perf_counter() - t0) * 1e6,
                 "derived": f"rows={len(stats)};bytes={idx.nbytes};"
                            f"bpg={idx.nbytes / n_grams:.2f}"})
    layouts = [("", idx)]
    if compress:
        t0 = time.perf_counter()
        cidx = compress_index(idx)
        cidx.heads.block_until_ready()
        rows.append({"name": "index_compress",
                     "us": (time.perf_counter() - t0) * 1e6,
                     "derived": f"rows={len(stats)};bytes={cidx.nbytes};"
                                f"bpg={cidx.nbytes / n_grams:.2f};"
                                f"ratio={idx.nbytes / cidx.nbytes:.2f}"})
        layouts.append(("_comp", cidx))

    grams, lengths = make_query_stream(stats, n_queries=n_queries, sigma=5,
                                       vocab_size=prof.vocab_size, miss_frac=0.3)

    def answers(ix):
        def answer_lookup(g, ln):
            return np.asarray(lookup(ix, g, ln))

        def answer_topk(g, ln):
            # continuations() masks the gram past the prefix length itself
            return np.asarray(continuations(ix, g, np.maximum(ln - 1, 0),
                                            k=topk)[3])
        return answer_lookup, answer_topk

    return rows, layouts, answers, grams, lengths


def run(n_tokens: int = 60_000, *, n_queries: int = 12_000,
        topk: int = 8, compress: bool = False,
        _ctx: tuple | None = None) -> list[dict]:
    from repro.launch.serve_ngrams import microbatch_drive

    rows, layouts, answers, grams, lengths = _ctx if _ctx is not None else \
        _setup(n_tokens, n_queries, topk, compress)
    for tag, ix in layouts:
        answer_lookup, answer_topk = answers(ix)
        for mode, answer in (("lookup", answer_lookup), ("topk", answer_topk)):
            for batch in BATCH_SIZES:
                qps, lat = microbatch_drive(answer, grams, lengths, batch)
                rows.append({
                    "name": f"serve_{mode}{tag}_b{batch}",
                    "us": float(np.median(lat) * 1e6),
                    "derived": f"qps={qps:.0f}",
                })
    return rows


def contract_slowdown(layouts, answers, grams, lengths, *,
                      batch: int = CONTRACT_BATCH, reps: int = 9) -> float:
    """Worst compressed/uncompressed median-latency ratio over both modes,
    measured batch-interleaved so load transients cancel."""
    (_, idx), (_, cidx) = layouts
    g, ln = grams[:batch], lengths[:batch]
    worst = 0.0
    for mode_i in (0, 1):
        a_u = answers(idx)[mode_i]
        a_c = answers(cidx)[mode_i]
        a_u(g, ln), a_c(g, ln), a_u(g, ln), a_c(g, ln)     # compile + warm
        lat_u, lat_c = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            a_u(g, ln)
            lat_u.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            a_c(g, ln)
            lat_c.append(time.perf_counter() - t0)
        worst = max(worst, float(np.median(lat_c) / np.median(lat_u)))
    return worst


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=60_000)
    ap.add_argument("--queries", type=int, default=12_000)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--compress", action="store_true",
                    help="also measure the front-coded + Elias-Fano layout and "
                         "check the size/latency contract")
    args = ap.parse_args()
    ctx = _setup(args.tokens, max(args.queries, CONTRACT_BATCH), args.topk,
                 args.compress)
    rows = run(args.tokens, n_queries=args.queries, topk=args.topk,
               compress=args.compress, _ctx=ctx)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.1f},{r['derived']}")
    if args.compress:
        _, layouts, answers, grams, lengths = ctx
        nb, nc = layouts[0][1].nbytes, layouts[1][1].nbytes
        ratio = nb / nc
        slowdown = contract_slowdown(layouts, answers, grams, lengths)
        print(f"# compressed layout: {nb} -> {nc} bytes "
              f"({ratio:.2f}x smaller), worst interleaved b{CONTRACT_BATCH} "
              f"median-latency slowdown {slowdown:.2f}x")
        assert ratio >= 2.0, f"compression ratio {ratio:.2f} < 2x contract"
        assert slowdown <= 3.0, f"slowdown {slowdown:.2f} > 3x contract"


if __name__ == "__main__":
    main()
