"""Index-serving benchmark: build->freeze->query QPS at the paper-report sizes.

One entry per (path, batch) cell so the serving subsystem shows up in the perf
trajectory next to the job-side kernels: point lookup and top-k continuation,
micro-batched at {1, 64, 4096}, plus the index freeze itself.
"""
from __future__ import annotations

import time

import numpy as np

BATCH_SIZES = (1, 64, 4096)


def run(n_tokens: int = 60_000, *, n_queries: int = 12_000,
        topk: int = 8) -> list[dict]:
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    from repro.index import build_index, continuations, lookup
    from repro.launch.serve_ngrams import make_query_stream, microbatch_drive

    prof = corpus_mod.NYT
    tokens = corpus_mod.zipf_corpus(n_tokens, prof, seed=0, duplicate_frac=0.02)
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=prof.vocab_size)
    stats = run_job(tokens, cfg)

    rows: list[dict] = []
    t0 = time.perf_counter()
    idx = build_index(stats, vocab_size=prof.vocab_size)
    idx.lanes.block_until_ready()
    rows.append({"name": "index_build", "us": (time.perf_counter() - t0) * 1e6,
                 "derived": f"rows={len(stats)};bytes={idx.nbytes}"})

    grams, lengths = make_query_stream(stats, n_queries=n_queries, sigma=5,
                                       vocab_size=prof.vocab_size, miss_frac=0.3)

    def answer_lookup(g, ln):
        return np.asarray(lookup(idx, g, ln))

    def answer_topk(g, ln):
        # continuations() masks the gram past the prefix length itself
        return np.asarray(continuations(idx, g, np.maximum(ln - 1, 0),
                                        k=topk)[3])

    for mode, answer in (("lookup", answer_lookup), ("topk", answer_topk)):
        for batch in BATCH_SIZES:
            qps, lat = microbatch_drive(answer, grams, lengths, batch)
            rows.append({
                "name": f"serve_{mode}_b{batch}",
                "us": float(np.median(lat) * 1e6),
                "derived": f"qps={qps:.0f}",
            })
    return rows
