"""Benchmark harness: one entry per paper table/figure + kernel microbenches.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's headline
quantity) followed by the paper-claim validation block.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _csv(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def bench_kernels() -> None:
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    terms = jnp.asarray(np.sort(rng.integers(0, 50, (20_000, 5)), axis=0))
    toks = jnp.asarray(rng.integers(0, 300, 100_000).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 2 ** 31, 100_000).astype(np.uint32))
    valid = jnp.asarray(np.ones(100_000, bool))

    for name, fn in (
        ("kernel_lcp_boundary", lambda: ops.lcp_boundary(terms)),
        ("kernel_suffix_pack", lambda: ops.suffix_pack(toks, sigma=5,
                                                       vocab_size=300)),
        ("kernel_hash_partition", lambda: ops.hash_partition(keys, valid,
                                                             n_parts=64)),
    ):
        fn()  # compile (interpret mode on CPU)
        t0 = time.perf_counter()
        for _ in range(3):
            r = fn()
        [x.block_until_ready() for x in (r if isinstance(r, tuple) else (r,))]
        _csv(name, (time.perf_counter() - t0) / 3 * 1e6, "interpret-mode")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--waves", action="store_true",
                    help="only the wave-engine cells (wave count vs job "
                         "throughput, interleaved medians -> BENCH_waves.json)")
    ap.add_argument("--reps", type=int, default=3,
                    help="interleaved reps per wave cell (--waves only)")
    ap.add_argument("--no-mesh", action="store_true",
                    help="skip the slow distributed-wave subprocess cell "
                         "(--waves only; CI smokes)")
    ap.add_argument("--gate", type=float, default=None, metavar="RATIO",
                    help="fail (exit 1) if the deepest wave sweep exceeds "
                         "RATIO x the monolithic median (--waves only)")
    ap.add_argument("--gate-mesh", type=float, default=None, metavar="RATIO",
                    help="fail (exit 1) if the fused-mesh cell exceeds RATIO "
                         "x the monolithic median OR was skipped (--waves "
                         "only; ratio is stamped into BENCH_waves.json)")
    args = ap.parse_args()
    n = 20_000 if args.quick else 60_000

    if args.waves:
        from benchmarks import waves
        print("name,us_per_call,derived")
        rows = waves.run(n, reps=args.reps, mesh=not args.no_mesh,
                         gate_mesh=args.gate_mesh)
        for r in rows:
            _csv(r["name"], r["us"], r["derived"])
        failed = False
        by_name = {r["name"]: r for r in rows}
        if args.gate is not None:
            deepest = f"waves_{max(waves.WAVE_COUNTS)}"
            ratio = by_name[deepest]["us"] / by_name["waves_monolithic"]["us"]
            ok = ratio <= args.gate
            print(f"# perf gate: {deepest}/monolithic = {ratio:.2f}x "
                  f"(limit {args.gate:.2f}x) -> {'OK' if ok else 'FAIL'}")
            failed |= not ok
        if args.gate_mesh is not None:
            name = f"waves_mesh{waves.MESH_DEVICES}_{waves.MESH_DEVICES}"
            row = by_name.get(name)
            if row is None or "skipped" in row:
                why = row["skipped"] if row else "row missing"
                print(f"# mesh perf gate: {name} SKIPPED ({why}) -> FAIL")
                failed = True
            else:
                ratio = row["us"] / by_name["waves_monolithic"]["us"]
                ok = ratio <= args.gate_mesh
                print(f"# mesh perf gate: {name}/monolithic = {ratio:.2f}x "
                      f"(limit {args.gate_mesh:.2f}x) -> "
                      f"{'OK' if ok else 'FAIL'}")
                failed |= not ok
        if failed:
            sys.exit(1)
        return

    from benchmarks import paper_figures as pf

    print("name,us_per_call,derived")
    t_all = time.time()

    rows3 = pf.fig3_usecases(n)
    for r in rows3:
        if not np.isfinite(r.get("wall_s", float("nan"))):
            _csv(f"fig3_{r['corpus']}_{r['case']}_{r['method']}", -1,
                 r.get("note", "dnf"))
        else:
            _csv(f"fig3_{r['corpus']}_{r['case']}_{r['method']}",
                 r["wall_s"] * 1e6, f"records={r['records']};bytes={r['bytes']}")

    rows4 = pf.fig4_tau(n)
    for r in rows4:
        _csv(f"fig4_{r['corpus']}_tau{r['tau']}_{r['method']}", r["wall_s"] * 1e6,
             f"records={r['records']};bytes={r['bytes']}")

    rows5 = pf.fig5_sigma(max(n * 2 // 3, 10_000))
    for r in rows5:
        _csv(f"fig5_{r['corpus']}_sigma{r['sigma']}_{r['method']}",
             r["wall_s"] * 1e6, f"records={r['records']};jobs={r['jobs']}")

    rows6 = pf.fig6_scale(n)
    for r in rows6:
        _csv(f"fig6_frac{int(r['frac']*100)}_{r['method']}", r["wall_s"] * 1e6,
             f"tokens={r['tokens']};records={r['records']}")

    rows7 = pf.fig7_resources(n // 2)
    for r in rows7:
        _csv(f"fig7_R{r['R']}_{r['method']}", r["wall_s"] * 1e6,
             f"ngrams={r['ngrams']}")

    bench_kernels()

    from benchmarks import serving
    for r in serving.run(max(n // 2, 10_000),
                         n_queries=4_000 if args.quick else 12_000,
                         compress=not args.quick):
        _csv(r["name"], r["us"], r["derived"])

    from benchmarks import ablations
    for r in ablations.run(max(n // 2, 10_000)):
        _csv(f"ablation_pack{int(r['pack'])}_combine{int(r['combine'])}",
             r["wall_s"] * 1e6,
             f"bytes={r['bytes']};bytes_x={r['bytes_x']};records={r['records']}")

    print("\n# paper-claim validation")
    for c in pf.validate_claims(rows4, rows5):
        print("#", c)
    print(f"# total bench time {time.time()-t_all:.1f}s")


if __name__ == "__main__":
    main()
