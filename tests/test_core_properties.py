"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import NGramConfig, extensions_filter, oracle, run_job, suffix_sigma
from repro.data import corpus as corpus_mod
from repro.mapreduce import pack as packing

corpora = st.lists(st.integers(0, 12), min_size=1, max_size=200).map(
    lambda xs: np.asarray(xs, np.int32))


@settings(max_examples=25, deadline=None)
@given(toks=corpora, sigma=st.integers(1, 6), tau=st.integers(1, 4))
def test_suffix_sigma_equals_oracle(toks, sigma, tau):
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=12)
    assert run_job(toks, cfg).to_dict() == oracle.ngram_counts(toks, sigma, tau)


@settings(max_examples=25, deadline=None)
@given(toks=corpora, sigma=st.integers(1, 5))
def test_apriori_monotonicity(toks, sigma):
    """cf(r) >= cf(s) for every prefix r of s -- the APRIORI principle the
    methods rely on for pruning and document splitting."""
    counts = oracle.ngram_counts(toks, sigma, 1)
    for g, c in counts.items():
        for l in range(1, len(g)):
            assert counts[g[:l]] >= c


@settings(max_examples=20, deadline=None)
@given(toks=corpora, tau=st.integers(1, 4), sigma=st.integers(1, 5))
def test_document_splitting_preserves_output(toks, tau, sigma):
    """SSV: masking infrequent terms never changes the frequent n-grams."""
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=12)
    base = run_job(toks, cfg).to_dict()
    split, _ = corpus_mod.split_at_infrequent(toks, tau, 12)
    assert run_job(split, cfg).to_dict() == base


@settings(max_examples=20, deadline=None)
@given(toks=corpora, tau=st.integers(1, 3))
def test_maximal_closed_are_subsets(toks, tau):
    cfg = NGramConfig(sigma=4, tau=tau, vocab_size=12)
    stats = run_job(toks, cfg)
    full = stats.to_dict()
    mx = extensions_filter(stats, "max").to_dict()
    cl = extensions_filter(stats, "closed").to_dict()
    assert set(mx) <= set(full) and set(cl) <= set(full)
    assert set(mx) <= set(cl)  # maximal implies closed... (superset dir: closed set contains maximal)
    assert mx == oracle.maximal_ngrams(full)
    assert cl == oracle.closed_ngrams(full)


@settings(max_examples=30, deadline=None)
@given(terms=st.lists(st.lists(st.integers(0, 200), min_size=1, max_size=7),
                      min_size=1, max_size=20),
       vocab=st.integers(200, 70000))
def test_pack_unpack_roundtrip(terms, vocab):
    sigma = max(len(t) for t in terms)
    mat = np.zeros((len(terms), sigma), np.int32)
    for i, t in enumerate(terms):
        mat[i, : len(t)] = t
    lanes = packing.pack_terms(np.asarray(mat), vocab_size=vocab)
    back = packing.unpack_terms(lanes, vocab_size=vocab, sigma=sigma)
    assert np.array_equal(np.asarray(back), mat)


@settings(max_examples=20, deadline=None)
@given(rows=st.lists(st.lists(st.integers(0, 6), min_size=3, max_size=3),
                     min_size=2, max_size=40))
def test_packed_sort_is_lexicographic(rows):
    mat = np.asarray(rows, np.int32)
    lanes = packing.pack_terms(mat, vocab_size=6)
    import jax.numpy as jnp
    from repro.mapreduce import sort
    rec = jnp.concatenate([jnp.asarray(lanes),
                           jnp.zeros((mat.shape[0], 1), jnp.uint32)], axis=1)
    out = sort.sort_records(rec, n_keys=lanes.shape[1])
    back = packing.unpack_terms(out[:, :lanes.shape[1]], vocab_size=6, sigma=3)
    py = sorted(map(tuple, mat.tolist()))
    assert [tuple(r) for r in np.asarray(back).tolist()] == py


@settings(max_examples=15, deadline=None)
@given(toks=corpora, n_buckets=st.integers(1, 5))
def test_series_sums_to_counts(toks, n_buckets):
    """Time-series aggregation marginalizes to plain collection frequencies."""
    rng = np.random.default_rng(0)
    buckets = rng.integers(0, n_buckets, toks.shape[0])
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=12, n_buckets=n_buckets)
    st_ = suffix_sigma.run(toks, cfg, bucket_ids=buckets)
    plain = run_job(toks, NGramConfig(sigma=3, tau=2, vocab_size=12)).to_dict()
    assert {g: int(s.sum()) for g, s in st_.to_series_dict().items()} == plain
