"""Training runtime: optimizer behaviour, checkpoint atomicity + determinism,
failure recovery, straggler detection."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import AttentionConfig, LMConfig, init_params, loss_fn
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import (FailureInjector, StragglerDetector,
                                            run_with_recovery)
from repro.training.optimizer import (OptimizerConfig, apply_updates, global_norm,
                                      init_state, schedule)
from repro.training.train_loop import make_train_step, make_train_step_accum
from repro.data.loader import SyntheticLMLoader

CFG = LMConfig("tiny", 2, 32, 97, 64, AttentionConfig("gqa", 4, 2, 8),
               dtype=jnp.float32, remat=False)
OPT = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)


def _fresh():
    p = init_params(jax.random.PRNGKey(0), CFG)
    return p, init_state(p)


def _batch(step=0):
    loader = SyntheticLMLoader(vocab_size=97, seq_len=16, global_batch=4)
    return {k: jnp.asarray(v) for k, v in loader.batch_at(step).items()}


def test_schedule_warmup_and_decay():
    assert float(schedule(jnp.int32(0), OPT)) == 0.0
    assert float(schedule(jnp.int32(2), OPT)) == pytest.approx(OPT.peak_lr)
    assert float(schedule(jnp.int32(50), OPT)) == pytest.approx(
        OPT.peak_lr * OPT.min_lr_frac, rel=1e-3)


def test_grad_clipping():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    s = init_state(p)
    _, _, m = apply_updates(p, g, s, OPT)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_loss_decreases():
    params, opt = _fresh()
    step = jax.jit(make_train_step(lambda p, b: loss_fn(p, b, CFG), OPT))
    first = last = None
    for i in range(25):
        params, opt, m = step(params, opt, _batch(i % 3))
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first


def test_accum_matches_full_batch_grad_direction():
    params, opt = _fresh()
    astep = jax.jit(make_train_step_accum(lambda p, b: loss_fn(p, b, CFG), OPT, 2))
    p2, o2, m2 = astep(params, opt, _batch())
    assert np.isfinite(float(m2["loss"]))
    assert int(o2["step"]) == 1


def test_checkpoint_roundtrip_and_atomicity():
    params, opt = _fresh()
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, async_save=False)
        ck.save(3, {"params": params, "opt": opt}, extras={"next_step": 3})
        assert ck.latest_step() == 3
        restored, extras = ck.restore(3, {"params": params, "opt": opt})
        assert extras["next_step"] == 3
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # no stray temp dirs after commit
        assert not [p for p in os.listdir(d) if p.startswith(".tmp")]


def test_checkpoint_gc_keeps_latest():
    params, opt = _fresh()
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            ck.save(s, {"p": params["final_norm"]})
        assert sorted(ck.all_steps()) == [3, 4]


def test_recovery_bit_determinism():
    step = jax.jit(make_train_step(lambda p, b: loss_fn(p, b, CFG), OPT))
    loader = SyntheticLMLoader(vocab_size=97, seq_len=16, global_batch=4)

    def sfn(state, batch):
        p, o, m = step(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    def bfn(s):
        return {k: jnp.asarray(v) for k, v in loader.batch_at(s).items()}

    def fresh_state():
        p = init_params(jax.random.PRNGKey(0), CFG)
        return {"params": p, "opt": init_state(p)}

    with tempfile.TemporaryDirectory() as d:
        a, _, rA = run_with_recovery(
            n_steps=20, step_fn=sfn, state=fresh_state(), batch_fn=bfn,
            ckpt=CheckpointManager(d + "/a", async_save=False), ckpt_every=5,
            injector=FailureInjector({7, 13}))
        b, _, rB = run_with_recovery(
            n_steps=20, step_fn=sfn, state=fresh_state(), batch_fn=bfn,
            ckpt=CheckpointManager(d + "/b", async_save=False), ckpt_every=5)
    assert rA == 2 and rB == 0
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_straggler_detector():
    det = StragglerDetector(alpha=0.5, threshold=2.0)
    for _ in range(5):
        det.observe(0, 0.1)
    assert det.observe(6, 1.0)          # 10x slower -> flagged
    assert len(det.events) == 1


def test_failure_exhaustion_raises():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(RuntimeError):
            run_with_recovery(
                n_steps=5,
                step_fn=lambda s, b: (_ for _ in ()).throw(RuntimeError("boom")),
                state={}, batch_fn=lambda s: None,
                ckpt=CheckpointManager(d, async_save=False), max_retries=2)
