"""Aggregations beyond counting (SSII df, SSVI-B inverted index) + pack ablation."""
import numpy as np
import pytest

from repro.core import NGramConfig, aggregations, oracle, run_job


@pytest.mark.parametrize("seed", range(3))
def test_document_frequencies_match_oracle(seed):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 25, int(rng.integers(40, 250)))
    sigma, tau = int(rng.integers(1, 5)), int(rng.integers(1, 3))
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=24)
    exp = oracle.ngram_document_frequencies(toks, sigma, tau)
    assert aggregations.document_frequencies(toks, cfg).to_dict() == exp
    assert aggregations.df_suffix_lengths(toks, cfg).to_dict() == exp


def test_df_bounded_by_cf():
    rng = np.random.default_rng(7)
    toks = rng.integers(0, 12, 400)
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=11)
    cf = run_job(toks, cfg).to_dict()
    df = aggregations.document_frequencies(toks, cfg).to_dict()
    for g, d in df.items():
        assert d <= cf[g]            # df(s) <= cf(s), SSII


@pytest.mark.parametrize("seed", range(3))
def test_postings_match_oracle(seed):
    rng = np.random.default_rng(seed + 10)
    toks = rng.integers(0, 20, int(rng.integers(40, 200)))
    sigma, tau = int(rng.integers(1, 4)), int(rng.integers(1, 3))
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=19)
    assert aggregations.postings(toks, cfg) == oracle.ngram_postings(toks, sigma,
                                                                     tau)


def test_postings_marginalize_to_cf():
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 15, 300)
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=14)
    cf = run_job(toks, cfg).to_dict()
    post = aggregations.postings(toks, cfg)
    assert {g: sum(p.values()) for g, p in post.items()} == cf


def test_pack_ablation_exactness_and_bytes():
    """SSV sequence encoding: packing changes bytes, never the output."""
    rng = np.random.default_rng(4)
    toks = rng.integers(0, 60, 700)
    on = run_job(toks, NGramConfig(sigma=4, tau=2, vocab_size=59, pack=True))
    off = run_job(toks, NGramConfig(sigma=4, tau=2, vocab_size=59, pack=False))
    assert on.to_dict() == off.to_dict()
    assert off.counters["shuffle_bytes"] > on.counters["shuffle_bytes"]


def test_combiner_reduces_shuffle_volume():
    rng = np.random.default_rng(5)
    toks = rng.integers(0, 5, 2000)   # tiny vocab: heavy suffix duplication
    on = run_job(toks, NGramConfig(sigma=3, tau=1, vocab_size=4, combine=True))
    off = run_job(toks, NGramConfig(sigma=3, tau=1, vocab_size=4, combine=False))
    assert on.to_dict() == off.to_dict()
    assert on.counters["shuffle_records"] < off.counters["shuffle_records"] / 10
