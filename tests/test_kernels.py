"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # degrade to the parametrized sweeps only
    HAS_HYPOTHESIS = False

from repro.kernels import ops, ref


def lex_sorted(rng, n, l, vmax=6):
    t = rng.integers(0, vmax, (n, l)).astype(np.int32)
    return t[np.lexsort(t.T[::-1])]


@pytest.mark.parametrize("n,l", [(1, 1), (7, 3), (100, 5), (513, 8), (2048, 2),
                                 (33, 100), (512, 1)])
def test_lcp_boundary_shapes(n, l):
    rng = np.random.default_rng(n * 131 + l)
    terms = jnp.asarray(lex_sorted(rng, n, l))
    for block in (64, 512):
        lcp_k, fl_k = ops.lcp_boundary(terms, block_rows=block)
        lcp_r, fl_r = ref.lcp_boundary_ref(terms)
        np.testing.assert_array_equal(np.asarray(lcp_k), np.asarray(lcp_r))
        np.testing.assert_array_equal(np.asarray(fl_k), np.asarray(fl_r))


if HAS_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.lists(st.integers(0, 4), min_size=4, max_size=4),
                    min_size=1, max_size=120))
    def test_lcp_boundary_property(rows):
        t = np.asarray(sorted(map(tuple, rows)), np.int32).reshape(len(rows), 4)
        lcp_k, fl_k = ops.lcp_boundary(jnp.asarray(t), block_rows=32)
        lcp_r, fl_r = ref.lcp_boundary_ref(jnp.asarray(t))
        assert np.array_equal(np.asarray(lcp_k), np.asarray(lcp_r))
        assert np.array_equal(np.asarray(fl_k), np.asarray(fl_r))


@pytest.mark.parametrize("n,sigma,vocab,block", [
    (10, 3, 5, 256), (100, 5, 300, 64), (1025, 7, 70_000, 256),
    (5000, 2, 3, 1024), (64, 64, 100, 128), (1, 1, 1, 32)])
def test_suffix_pack_shapes(n, sigma, vocab, block):
    rng = np.random.default_rng(n + sigma)
    toks = jnp.asarray(rng.integers(0, vocab + 1, n).astype(np.int32))
    got = ops.suffix_pack(toks, sigma=sigma, vocab_size=vocab, block=block)
    want = ref.suffix_pack_ref(toks, sigma=sigma, vocab_size=vocab)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,parts,block", [(10, 2, 512), (1000, 8, 128),
                                           (4097, 16, 512), (5, 512, 64)])
def test_hash_partition_shapes(n, parts, block):
    rng = np.random.default_rng(n)
    keys = jnp.asarray(rng.integers(0, 2 ** 31, n).astype(np.uint32))
    valid = jnp.asarray(rng.random(n) < 0.8)
    p_k, h_k = ops.hash_partition(keys, valid, n_parts=parts, block=block)
    p_r, h_r = ref.hash_partition_ref(keys, valid, parts)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r))
    assert int(h_k.sum()) == int(valid.sum())


@pytest.mark.parametrize("r,n_l,q,block", [(1, 1, 1, 64), (100, 2, 57, 64),
                                           (1000, 1, 513, 128),
                                           (4096, 3, 2000, 1024)])
@pytest.mark.parametrize("upper", [False, True])
def test_bsearch_shapes(r, n_l, q, block, upper):
    rng = np.random.default_rng(r + q)
    lanes = np.sort(rng.integers(0, 50, (r, n_l)).astype(np.uint32), axis=0)
    lanes = lanes[np.lexsort(lanes.T[::-1])]
    queries = rng.integers(0, 55, (q, n_l)).astype(np.uint32)
    lo = rng.integers(0, r, q).astype(np.int32)
    hi = (lo + rng.integers(0, r, q)).clip(0, r).astype(np.int32)
    got = ops.bsearch(jnp.asarray(lanes), jnp.asarray(queries),
                      jnp.asarray(lo), jnp.asarray(hi), upper=upper,
                      block=block)
    want = ref.bsearch_ref(jnp.asarray(lanes), jnp.asarray(queries),
                           jnp.asarray(lo), jnp.asarray(hi), upper=upper)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # ref itself against numpy row-tuple bisection
    import bisect
    rows = [tuple(x) for x in lanes.tolist()]
    side = bisect.bisect_right if upper else bisect.bisect_left
    expect = [side(rows, tuple(qr), lo=int(l), hi=int(h))
              for qr, l, h in zip(queries.tolist(), lo, hi)]
    np.testing.assert_array_equal(np.asarray(want), expect)


def test_kernel_backed_reducer_end_to_end():
    from repro.core import NGramConfig, oracle, run_job
    rng = np.random.default_rng(9)
    toks = rng.integers(0, 40, 700)
    cfg = NGramConfig(sigma=4, tau=2, vocab_size=39, use_kernels=True)
    assert run_job(toks, cfg).to_dict() == oracle.ngram_counts(toks, 4, 2)
