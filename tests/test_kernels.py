"""One differential harness for every Pallas kernel in ``kernels/ops.py``.

Each kernel registers a case generator that draws randomized shapes, dtypes,
and payloads (scaled by the sweep index) and returns the kernel call plus its
pure-jnp ``ref`` oracle call; a single parametrized test asserts exact
agreement over the whole registry, so adding a kernel without wiring it here
shows up as a failing ``test_registry_covers_ops`` rather than silent
no-coverage.  Cross-checks against third implementations (numpy bisect for the
search, the SUFFIX-sigma job end to end) keep the oracles honest.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # degrade to the parametrized sweeps only
    HAS_HYPOTHESIS = False

from repro.kernels import ops, ref


def lex_sorted(rng, n, l, vmax=6):
    t = rng.integers(0, vmax, (n, l)).astype(np.int32)
    return t[np.lexsort(t.T[::-1])]


def _case_lcp_boundary(rng, scale):
    n = int(rng.integers(1, 40 * scale + 2))
    l = int(rng.integers(1, 100))
    terms = jnp.asarray(lex_sorted(rng, n, l, vmax=int(rng.integers(2, 9))))
    block = int(rng.choice([32, 64, 512]))
    return (lambda: ops.lcp_boundary(terms, block_rows=block),
            lambda: ref.lcp_boundary_ref(terms))


def _case_suffix_pack(rng, scale):
    n = int(rng.integers(1, 120 * scale + 2))
    sigma = int(rng.integers(1, 65))
    vocab = int(rng.choice([1, 3, 300, 70_000]))
    toks = jnp.asarray(rng.integers(0, vocab + 1, n).astype(np.int32))
    # the kernel's halo layout requires sigma <= block
    block = int(rng.choice([b for b in (32, 256, 1024) if b >= sigma]))
    return (lambda: ops.suffix_pack(toks, sigma=sigma, vocab_size=vocab,
                                    block=block),
            lambda: ref.suffix_pack_ref(toks, sigma=sigma, vocab_size=vocab))


def _case_hash_partition(rng, scale):
    n = int(rng.integers(1, 200 * scale + 2))
    parts = int(rng.choice([2, 8, 16, 512]))
    keys = jnp.asarray(rng.integers(0, 2**31, n).astype(np.uint32))
    valid = jnp.asarray(rng.random(n) < 0.8)
    block = int(rng.choice([64, 128, 512]))
    return (lambda: ops.hash_partition(keys, valid, n_parts=parts, block=block),
            lambda: ref.hash_partition_ref(keys, valid, parts))


def _case_bsearch(rng, scale):
    r = int(rng.integers(1, 200 * scale + 2))
    n_l = int(rng.integers(1, 4))
    q = int(rng.integers(1, 100 * scale + 2))
    lanes = rng.integers(0, 50, (r, n_l)).astype(np.uint32)
    lanes = lanes[np.lexsort(lanes.T[::-1])]
    queries = rng.integers(0, 55, (q, n_l)).astype(np.uint32)
    lo = rng.integers(0, r, q).astype(np.int32)
    hi = (lo + rng.integers(0, r, q)).clip(0, r).astype(np.int32)
    upper = bool(rng.integers(0, 2))
    block = int(rng.choice([64, 128, 1024]))
    args = (jnp.asarray(lanes), jnp.asarray(queries), jnp.asarray(lo),
            jnp.asarray(hi))
    return (lambda: ops.bsearch(*args, upper=upper, block=block),
            lambda: ref.bsearch_ref(*args, upper=upper))


def _case_block_decode(rng, scale):
    """Fuzzed compressed streams -- not just builder output -- hit the
    clamped-fetch and lcp-at-head corners both implementations must share.
    (Bases stay < 2**24 so bit positions cannot wrap uint32.)"""
    sigma = int(rng.integers(1, 9))
    term_bits = int(rng.integers(3, 17))
    lcp_width = 4 if sigma <= 14 else 8
    block_size = int(rng.choice([4, 8, 16]))
    nb = int(rng.integers(1, 20 * scale + 2))
    size = nb * block_size
    q = int(rng.integers(1, 80 * scale + 2))
    lcps = rng.integers(0, 2**32, -(-size * lcp_width // 32)).astype(np.uint32)
    payload = rng.integers(0, 2**32, int(rng.integers(1, 200))).astype(np.uint32)
    base = np.sort(rng.integers(0, 2**24, nb + 1)).astype(np.uint32)
    sec = np.sort(rng.integers(0, size + 1, sigma + 1)).astype(np.int32)
    blk = rng.integers(0, nb, q).astype(np.int32)
    qt = rng.integers(0, 1 << term_bits, (q, sigma)).astype(np.int32)
    ql = rng.integers(0, sigma + 2, q).astype(np.int32)
    args = (jnp.asarray(lcps), jnp.asarray(payload), jnp.asarray(base),
            jnp.asarray(sec), jnp.asarray(blk), jnp.asarray(qt),
            jnp.asarray(ql))
    kw = dict(term_bits=term_bits, lcp_width=lcp_width, block_size=block_size,
              len_off=int(rng.integers(0, 2)))
    return (lambda: ops.block_decode(*args, **kw, qblock=64),
            lambda: ref.block_decode_ref(*args, **kw))


def _case_block_expand(rng, scale):
    """Fuzzed compressed streams for the batched block decoder -- same corner
    coverage as ``_case_block_decode`` minus the query rank (bases < 2**24 so
    bit positions cannot wrap uint32)."""
    sigma = int(rng.integers(1, 9))
    term_bits = int(rng.integers(3, 17))
    lcp_width = 4 if sigma <= 14 else 8
    block_size = int(rng.choice([4, 8, 16]))
    nb = int(rng.integers(1, 20 * scale + 2))
    size = nb * block_size
    b = int(rng.integers(1, 80 * scale + 2))
    lcps = rng.integers(0, 2**32, -(-size * lcp_width // 32)).astype(np.uint32)
    payload = rng.integers(0, 2**32, int(rng.integers(1, 200))).astype(np.uint32)
    base = np.sort(rng.integers(0, 2**24, nb + 1)).astype(np.uint32)
    sec = np.sort(rng.integers(0, size + 1, sigma + 1)).astype(np.int32)
    blk = rng.integers(0, nb, b).astype(np.int32)
    args = (jnp.asarray(lcps), jnp.asarray(payload), jnp.asarray(base),
            jnp.asarray(sec), jnp.asarray(blk))
    kw = dict(term_bits=term_bits, lcp_width=lcp_width, block_size=block_size,
              len_off=int(rng.integers(0, 2)))
    return (lambda: ops.block_expand(*args, **kw, sigma=sigma, bblock=64),
            lambda: ref.block_expand_ref(*args, **kw))


def _case_merge_path(rng, scale):
    """Sorted runs with deliberate duplicates (within and across runs) so the
    stable A-first tie-break is exercised, plus empty/singleton run corners."""
    n_l = int(rng.integers(1, 4))
    vmax = int(rng.choice([3, 20, 2**31]))
    m = int(rng.integers(0, 150 * scale + 2))
    n = int(rng.integers(0, 150 * scale + 2))
    a = lex_sorted(rng, m, n_l, vmax=vmax).astype(np.uint32)
    b = lex_sorted(rng, n, n_l, vmax=vmax).astype(np.uint32)
    if m and n and rng.integers(0, 2):      # force cross-run duplicates
        take = rng.integers(0, m, min(n, 8))
        b[:len(take)] = a[take]
        b = b[np.lexsort(b.T[::-1])]
    av = rng.integers(0, 2**32, m).astype(np.uint32)
    bv = rng.integers(0, 2**32, n).astype(np.uint32)
    block = int(rng.choice([64, 256, 1024]))
    args = (jnp.asarray(a), jnp.asarray(b), jnp.asarray(av), jnp.asarray(bv))
    return (lambda: ops.merge_path(*args, block=block),
            lambda: ref.merge_path_ref(*args))


def _case_hash_combine(rng, scale):
    """Duplicate-heavy keys (small value range) so slots actually collide
    both equal (combines) and unequal (slot losers keep their weight), plus
    ragged tails that exercise the pad-rows-can't-absorb-weight invariant."""
    n = int(rng.integers(1, 300 * scale + 2))
    n_keys = int(rng.integers(1, 6))
    vmax = int(rng.choice([2, 5, 50, 2**31]))
    keys = jnp.asarray(rng.integers(0, vmax, (n, n_keys)).astype(np.uint32))
    weights = jnp.asarray(rng.integers(0, 4, n).astype(np.uint32))
    block = int(rng.choice([32, 64, 256]))
    return (lambda: ops.hash_combine(keys, weights, block=block),
            lambda: ref.hash_combine_ref(keys, weights, block=block))


KERNEL_CASES = {
    "lcp_boundary": _case_lcp_boundary,
    "suffix_pack": _case_suffix_pack,
    "hash_partition": _case_hash_partition,
    "hash_combine": _case_hash_combine,
    "bsearch": _case_bsearch,
    "block_decode": _case_block_decode,
    "block_expand": _case_block_expand,
    "merge_path": _case_merge_path,
}


def test_registry_covers_ops():
    """Every public kernel wrapper in ops.py must have a registered case."""
    import inspect
    public = {n for n, f in vars(ops).items()
              if callable(f) and not n.startswith("_")
              and inspect.getmodule(f) is ops}
    assert public == set(KERNEL_CASES), public ^ set(KERNEL_CASES)


@pytest.mark.parametrize("name", sorted(KERNEL_CASES))
@pytest.mark.parametrize("sweep", range(4))
def test_kernel_matches_ref(name, sweep):
    # crc32, not hash(): string hashing is salted per process, and the sweep
    # must draw the same cases in every run to be debuggable
    import zlib
    rng = np.random.default_rng(zlib.crc32(f"{name}/{sweep}".encode()))
    scale = [1, 1, 4, 16][sweep]
    kernel_call, ref_call = KERNEL_CASES[name](rng, scale)
    got, want = kernel_call(), ref_call()
    if not isinstance(got, tuple):
        got, want = (got,), (want,)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


if HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(name=st.sampled_from(sorted(KERNEL_CASES)),
           seed=st.integers(0, 2**31), scale=st.sampled_from([1, 2, 8]))
    def test_kernel_matches_ref_fuzzed(name, seed, scale):
        rng = np.random.default_rng(seed)
        kernel_call, ref_call = KERNEL_CASES[name](rng, scale)
        got, want = kernel_call(), ref_call()
        if not isinstance(got, tuple):
            got, want = (got,), (want,)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_bsearch_ref_against_bisect():
    """The search oracle itself vs numpy row-tuple bisection."""
    import bisect
    rng = np.random.default_rng(5)
    r, n_l, q = 500, 3, 400
    lanes = rng.integers(0, 30, (r, n_l)).astype(np.uint32)
    lanes = lanes[np.lexsort(lanes.T[::-1])]
    queries = rng.integers(0, 33, (q, n_l)).astype(np.uint32)
    lo = rng.integers(0, r, q).astype(np.int32)
    hi = (lo + rng.integers(0, r, q)).clip(0, r).astype(np.int32)
    rows = [tuple(x) for x in lanes.tolist()]
    for upper in (False, True):
        want = ref.bsearch_ref(jnp.asarray(lanes), jnp.asarray(queries),
                               jnp.asarray(lo), jnp.asarray(hi), upper=upper)
        side = bisect.bisect_right if upper else bisect.bisect_left
        expect = [side(rows, tuple(qr), lo=int(l), hi=int(h))
                  for qr, l, h in zip(queries.tolist(), lo, hi)]
        np.testing.assert_array_equal(np.asarray(want), expect)


def test_block_decode_ref_against_host_decode():
    """The rank oracle vs a decoded-matrix host count on builder output."""
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.index import build_index, compress_index
    from repro.index.compress import decode_view

    rng = np.random.default_rng(9)
    toks = rng.integers(0, 40, 3000)
    stats = run_job(toks, NGramConfig(sigma=4, tau=2, vocab_size=39))
    idx = build_index(stats, vocab_size=39)
    cidx = compress_index(idx, block_size=8)
    sec = np.asarray(idx.section_start)
    row_len = np.searchsorted(sec, np.arange(idx.size), side="right")
    full = np.concatenate([row_len[:, None], decode_view(cidx, "point")],
                          axis=1)
    q = 200
    blk = rng.integers(0, cidx.n_blocks, q).astype(np.int32)
    qt = rng.integers(0, 45, (q, 4)).astype(np.int32)
    ql = rng.integers(0, 6, q).astype(np.int32)
    lt, eq = ref.block_decode_ref(
        cidx.lcps, cidx.payload, cidx.block_base, jnp.asarray(sec),
        jnp.asarray(blk), jnp.asarray(qt), jnp.asarray(ql),
        term_bits=cidx.term_bits, lcp_width=cidx.lcp_width,
        block_size=8, len_off=0)
    for i in range(q):
        rows = full[blk[i] * 8:(blk[i] + 1) * 8]
        key = tuple(np.concatenate([[ql[i]], qt[i]]))
        assert int(lt[i]) == sum(1 for r in rows if tuple(r) < key)
        assert int(eq[i]) == sum(1 for r in rows if tuple(r) == key)


def test_block_expand_ref_against_host_decode():
    """The batched decoder oracle vs the host full-table decode on builder
    output, both views, including a shuffled / duplicated block id batch."""
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.index import build_index, compress_index
    from repro.index.compress import decode_view

    rng = np.random.default_rng(11)
    toks = rng.integers(0, 40, 3000)
    stats = run_job(toks, NGramConfig(sigma=4, tau=2, vocab_size=39))
    idx = build_index(stats, vocab_size=39)
    cidx = compress_index(idx, block_size=8)
    for view, len_off in (("point", 0), ("cont", 1)):
        if view == "point":
            streams = (cidx.lcps, cidx.payload, cidx.block_base,
                       jnp.asarray(np.asarray(idx.section_start)))
            nb = cidx.n_blocks
        else:
            streams = (cidx.cont_lcps, cidx.cont_payload, cidx.cont_block_base,
                       jnp.asarray(np.asarray(idx.section_start)))
            nb = cidx.cont_heads.shape[0]
        full = decode_view(cidx, view)
        blk = rng.permutation(np.repeat(np.arange(nb, dtype=np.int32), 2))
        got = np.asarray(ref.block_expand_ref(
            *streams, jnp.asarray(blk), term_bits=cidx.term_bits,
            lcp_width=cidx.lcp_width, block_size=8, len_off=len_off))
        want = full.reshape(nb, 8, -1)[blk]
        np.testing.assert_array_equal(got, want)


def test_hash_combine_ref_conserves_weight_per_key():
    """The combiner oracle itself vs a host Counter: per-key weight totals
    must be untouched, and rep rows of combined runs must carry the sum."""
    from collections import Counter
    rng = np.random.default_rng(7)
    n = 700
    keys = rng.integers(0, 4, (n, 2)).astype(np.uint32)
    w = rng.integers(0, 5, n).astype(np.uint32)
    out = np.asarray(ref.hash_combine_ref(jnp.asarray(keys), jnp.asarray(w),
                                          block=64))
    want, got = Counter(), Counter()
    for i in range(n):
        want[tuple(keys[i])] += int(w[i])
        got[tuple(keys[i])] += int(out[i])
    assert want == got
    assert int((out != w).sum()) > 0        # it actually combined something


def test_kernel_backed_reducer_end_to_end():
    from repro.core import NGramConfig, oracle, run_job
    rng = np.random.default_rng(9)
    toks = rng.integers(0, 40, 700)
    cfg = NGramConfig(sigma=4, tau=2, vocab_size=39, use_kernels=True)
    assert run_job(toks, cfg).to_dict() == oracle.ngram_counts(toks, 4, 2)
