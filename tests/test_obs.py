"""Observability contracts (repro.obs): quantile math, schemas, no-op cost.

Four guarantees under test:

  * **Histogram quantiles** -- the fixed-boundary estimator must track the
    numpy sample oracle to within one bucket width (it stores buckets, not
    samples; that bound is the whole design).
  * **Trace schema** -- a *real* traced 8-wave job must export Chrome
    ``trace_event`` JSON that passes ``validate_trace`` and whose named child
    spans cover >= 90% of the root span's wall time (the attribution
    acceptance bar).
  * **Counter parity** -- monolithic ``run_plan`` and ``WaveExecutor.run``
    must emit the same counter *names* with normalized types for every
    method; wave-only keys are exactly the documented ones.  (Values can
    differ legitimately: per-wave combining dedups less, apriori pruning
    weakens at tau=1.)
  * **Disabled == free** -- with tracing off, ``trace.span`` returns the
    shared null singleton and a full wave run performs zero
    ``jax.block_until_ready`` calls attributable to the tracer.
"""
import json

import numpy as np
import pytest

from repro.core import METHODS, NGramConfig, run_job
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.pipeline import WaveExecutor
from tests.test_compress import make_corpus


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends on the disabled singletons."""
    obs_trace.disable_tracing()
    obs_metrics.set_registry(None)
    yield
    obs_trace.disable_tracing()
    obs_metrics.set_registry(None)


# ------------------------------------------------------------ histograms

@pytest.mark.parametrize("dist", ["uniform", "lognormal", "bimodal"])
def test_histogram_quantiles_vs_numpy_oracle(dist):
    rng = np.random.default_rng(hash(dist) % 2**31)
    if dist == "uniform":
        xs = rng.uniform(0.0, 1.0, 5000)
    elif dist == "lognormal":
        xs = rng.lognormal(-7.0, 1.0, 5000)       # latency-shaped, ~1ms
    else:
        xs = np.concatenate([rng.uniform(1e-4, 2e-4, 2500),
                             rng.uniform(1e-2, 2e-2, 2500)])
    h = obs_metrics.Histogram("t")
    for x in xs:
        h.observe(x)
    b = np.asarray(h.boundaries)
    n = len(xs)
    for q in (0.5, 0.95, 0.99):
        est = h.quantile(q)
        # oracle bound: the order-statistic neighborhood of q (the empirical
        # CDF may jump across a mass gap, where every value in the gap is an
        # equally valid quantile), widened by the estimate's bucket width
        ref_lo = float(np.quantile(xs, max(q - 1.5 / n, 0.0)))
        ref_hi = float(np.quantile(xs, min(q + 1.5 / n, 1.0)))
        i = int(np.searchsorted(b, est))
        lo = b[i - 1] if i > 0 else float(xs.min())
        hi = b[i] if i < len(b) else float(xs.max())
        w = hi - lo
        assert ref_lo - w - 1e-12 <= est <= ref_hi + w + 1e-12, \
            f"{dist} q={q}: est={est} ref=[{ref_lo},{ref_hi}] width={w}"
    assert h.count == len(xs)
    assert h.min == pytest.approx(xs.min())
    assert h.max == pytest.approx(xs.max())
    assert h.mean == pytest.approx(xs.mean())


def test_histogram_edges():
    h = obs_metrics.Histogram("t", boundaries=[1.0, 2.0, 4.0])
    assert h.quantile(0.5) == 0.0                 # empty
    h.observe(3.0)
    assert h.quantile(0.0) <= 3.0 <= h.quantile(1.0) + 1e-12
    assert h.quantile(1.0) == pytest.approx(3.0)  # clamped to observed max
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        obs_metrics.Histogram("bad", boundaries=[2.0, 1.0])
    snap = h.snapshot()
    assert obs_report.validate_metrics(
        {"counters": {}, "gauges": {}, "histograms": {"t": snap}}) == []


# ------------------------------------------------------------ trace schema

def test_traced_eight_wave_run_schema_and_coverage(tmp_path):
    toks = make_corpus(4000, 60, "zipf", 0)
    cfg = NGramConfig(sigma=3, tau=3, vocab_size=60)
    wave = -(-len(toks) // 8)
    tracer = obs_trace.enable_tracing()
    try:
        stats = WaveExecutor(cfg, wave_tokens=wave).run(toks)
    finally:
        obs_trace.disable_tracing()
    assert stats.counters["waves"] == 8
    path = tmp_path / "trace.json"
    tracer.save(str(path))
    obj = json.loads(path.read_text())
    assert obs_report.validate_trace(obj) == []
    names = {e["name"] for e in obj["traceEvents"]}
    assert {"wave.run", "wave.submit", "wave.collect", "wave.fold",
            "wave.finalize"} <= names
    assert sum(e["name"] == "wave.submit" for e in obj["traceEvents"]) == 8
    # attribution bar: named child spans cover >= 90% of the root's wall time
    assert obs_trace.span_coverage(obj, "wave.run") >= 0.90


def test_monolithic_trace_has_per_round_spans():
    toks = make_corpus(1500, 40, "zipf", 1)
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=40)
    tracer = obs_trace.enable_tracing()
    try:
        run_job(toks, cfg)
    finally:
        obs_trace.disable_tracing()
    names = {e["name"] for e in tracer.export()["traceEvents"]}
    assert {"plan.run", "round.emit", "round.stages",
            "round.materialize"} <= names


# ------------------------------------------------------------ counter parity

@pytest.mark.parametrize("method", sorted(METHODS))
def test_counters_parity_monolithic_vs_wave(method):
    toks = make_corpus(2000, 50, "zipf", hash(method) % 2**31)
    cfg = NGramConfig(sigma=3, tau=3, vocab_size=50, method=method)
    mono = run_job(toks, cfg)
    wavy = WaveExecutor(cfg, wave_tokens=-(-len(toks) // 4)).run(toks)
    wave_only = {"waves", "fold_rows"}
    assert set(wavy.counters) - wave_only == set(mono.counters)
    # every emitted key is documented in the one canonical glossary
    for k in set(mono.counters) | set(wavy.counters):
        assert k in obs_metrics.COUNTER_DOC, f"undocumented counter {k!r}"
    # normalized types: float for ratio keys, int for counts -- on both paths
    for counters in (mono.counters, wavy.counters):
        for k, v in counters.items():
            want = float if k in obs_metrics.FLOAT_COUNTERS else int
            assert type(v) is want, f"{k}: {type(v).__name__}"


def test_merge_policy_sums_except_skew():
    dst = {"jobs": 2, "shuffle_skew": 1.5}
    obs_metrics.merge_counter_dicts(dst, {"jobs": 3, "shuffle_skew": 1.2,
                                          "retries": 1})
    assert dst == {"jobs": 5, "shuffle_skew": 1.5, "retries": 1}
    reg = obs_metrics.MetricsRegistry()
    reg.merge_job_counters({"jobs": 2, "shuffle_skew": 3.5})
    reg.merge_job_counters({"jobs": 1, "shuffle_skew": 2.0})
    assert reg.counters["job.jobs"] == 3
    assert reg.snapshot()["gauges"]["job.shuffle_skew"] == 3.5


# ------------------------------------------------------------ disabled == free

def test_disabled_tracer_is_noop_and_sync_free(monkeypatch):
    import jax
    assert obs_trace.span("anything") is obs_trace.NULL_SPAN
    sp = obs_trace.span("x")
    assert not sp and sp.set(a=1) is None and sp.sync(object()) is None

    calls = {"n": 0}
    real = jax.block_until_ready

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "block_until_ready", counting)
    toks = make_corpus(1200, 40, "zipf", 2)
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=40)
    WaveExecutor(cfg, wave_tokens=300).run(toks)
    assert calls["n"] == 0, \
        "disabled observability must not add block_until_ready syncs"


def test_null_registry_instruments_are_noops():
    reg = obs_metrics.get_registry()
    assert not reg
    reg.counter("c").add(5)
    reg.gauge("g").set(2)
    reg.histogram("h").observe(0.1)
    reg.merge_job_counters({"jobs": 1})
    assert obs_metrics.get_registry().counter("c").value == 0


# ------------------------------------------------------------ metrics export

def test_registry_snapshot_roundtrips_through_validator(tmp_path):
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    toks = make_corpus(1500, 40, "zipf", 3)
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=40)
    stats = WaveExecutor(cfg, wave_tokens=400).run(toks)
    reg.merge_job_counters(stats.counters)
    reg.histogram("lat").observe(0.002)
    snap = reg.snapshot()
    assert obs_report.validate_metrics(snap) == []
    path = tmp_path / "m.jsonl"
    obs_report.write_jsonl(str(path), [{"metrics": snap,
                                        "env": obs_report
                                        .environment_metadata()}])
    assert obs_report.main(["--validate-metrics", str(path)]) == 0
    table = obs_report.summary_table(snap)
    assert "job.waves" in table and "lat" in table


def test_validators_reject_malformed():
    assert obs_report.validate_trace({}) != []
    assert obs_report.validate_trace(
        {"traceEvents": [{"name": "a", "ph": "B", "ts": 0, "dur": 1,
                          "pid": 0, "tid": 0}]}) != []
    bad = {"counters": {"c": "nope"}, "gauges": {}, "histograms": {}}
    assert obs_report.validate_metrics(bad) != []
    bad_h = {"counters": {}, "gauges": {}, "histograms": {
        "h": {"boundaries": [2.0, 1.0], "counts": [0, 0, 0], "count": 0,
              "sum": 0.0, "min": 0.0, "max": 0.0, "p50": 0.0, "p95": 0.0,
              "p99": 0.0}}}
    assert obs_report.validate_metrics(bad_h) != []


def test_lru_cache_surfaces_evictions_and_registry():
    from repro.launch.serve_ngrams import LRUQueryCache
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    c = LRUQueryCache(capacity=2)
    for i in range(4):
        c.get(("k", i), 0)
        c.put(("k", i), 0, i)
    assert c.evictions == 2 and c.misses == 4
    assert c.get(("k", 3), 0) == 3 and c.hits == 1
    c.publish_metrics()
    snap = reg.snapshot()
    assert snap["counters"]["cache.evictions"] == 2
    assert snap["counters"]["cache.hits"] == 1
    assert snap["gauges"]["cache.entries"] == 2
    c.publish_metrics()                            # lifetime mirror, not +=
    assert reg.snapshot()["counters"]["cache.evictions"] == 2
    assert obs_report.validate_metrics(snap) == []


def test_generational_compaction_stats_in_registry():
    from repro.index import GenerationalIndex
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    toks = make_corpus(1500, 40, "zipf", 4)
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=40)
    gen = GenerationalIndex(sigma=3, vocab_size=40, size_ratio=2)
    for part in np.array_split(toks, 4):
        gen.ingest(run_job(part, cfg))
    assert gen.compaction_stats["ingests"] == 4
    snap = reg.snapshot()
    assert snap["counters"]["gen.ingests"] == 4
    assert snap["counters"]["gen.merges"] == gen.compaction_stats["merges"]
    assert snap["gauges"]["gen.segments"] == gen.n_segments
    assert snap["gauges"]["gen.rung0_rows"] == gen.levels[0].n_rows
    assert obs_report.validate_metrics(snap) == []


# ------------------------------------------------------------ compressed at rest

def test_compressed_at_rest_gauges():
    """Per-rung bytes-at-rest, the total, and the compressed-segment census
    land in the registry -- frozen rungs reporting persisted stream bytes,
    not the resident total with decoded query caches."""
    from repro.index import GenerationalIndex
    from repro.index.compress import CompressedNGramIndex
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    toks = make_corpus(3000, 40, "zipf", 5)
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=40)
    gen = GenerationalIndex(sigma=3, vocab_size=40, size_ratio=2,
                            compress=True)
    for part in np.array_split(toks, 4):
        gen.ingest(run_job(part, cfg))
    assert gen.compaction_stats["merges"] >= 1
    segs = gen.segments          # materialize: merged rungs freeze compressed
    gen._publish_metrics()       # first publish after the lazy compression
    snap = reg.snapshot()
    want_total, want_comp = 0, 0
    for i, ix in enumerate(segs):
        b = getattr(ix, "nbytes_at_rest", None) or ix.nbytes
        assert snap["gauges"][f"gen.rung{i}_bytes_at_rest"] == b
        want_total += b
        want_comp += isinstance(ix, CompressedNGramIndex)
    assert snap["gauges"]["gen.bytes_at_rest"] == want_total
    assert snap["gauges"]["gen.compressed_segments"] == want_comp >= 1
    frozen = next(ix for ix in segs if isinstance(ix, CompressedNGramIndex))
    assert frozen.nbytes_at_rest < frozen.nbytes
    assert obs_report.validate_metrics(snap) == []


def test_streamed_decode_work_counters():
    """to_segment() and the compressed-native merge attribute their decode
    work to the registry: exactly the rows/block batches actually decoded."""
    from repro.index import build_compressed_index, merge_indexes
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=40)
    ca, cb = (build_compressed_index(
        run_job(make_corpus(1500, 40, "zipf", s), cfg), vocab_size=40)
        for s in (6, 7))
    nb = lambda ix: -(-ix.n_rows // ix.block_size)
    ca.to_segment()
    snap = reg.snapshot()
    assert snap["counters"]["compress.rows_decoded"] == ca.n_rows
    assert snap["counters"]["merge.blocks_decoded"] == nb(ca)
    merge_indexes([ca, cb], route="kway")
    snap = reg.snapshot()
    assert snap["counters"]["compress.rows_decoded"] == 2 * ca.n_rows + cb.n_rows
    assert snap["counters"]["merge.blocks_decoded"] == 2 * nb(ca) + nb(cb)
    assert obs_report.validate_metrics(snap) == []


def test_merge_span_records_layout_mix():
    """merge.segments spans carry the compressed/flat input mix."""
    from repro.index import build_compressed_index, merge_indexes
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=40)
    cixs = [build_compressed_index(
        run_job(make_corpus(800, 40, "zipf", s), cfg), vocab_size=40)
        for s in (8, 9)]
    tracer = obs_trace.enable_tracing()
    try:
        merge_indexes(cixs, route="kway")
    finally:
        obs_trace.disable_tracing()
    evs = [e for e in tracer.export()["traceEvents"]
           if e["name"] == "merge.segments"]
    assert evs and evs[-1]["args"]["n_compressed"] == 2
    assert evs[-1]["args"]["n_flat"] == 0
