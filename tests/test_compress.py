"""Differential parity harness for the compressed index layout.

The compressed layout (``repro.index.compress``) is a pure re-encoding, so the
bar is *bit-exact* agreement -- no tolerance -- along three axes:

  structural : Elias-Fano select / decode_all round-trip every encoded value;
               front-coded blocks decode back to the exact term matrices
  functional : ``lookup`` / ``continuations`` answers on the compressed index
               == uncompressed index == pure-Python oracle, over hit-heavy,
               miss-heavy, malformed, duplicate, and empty-prefix batches
  kernel     : the Pallas ``block_decode`` route agrees with the jnp ref route
               on every one of those batches (per-kernel randomized sweeps live
               in test_kernels.py)

Corpus generation is hypothesis-driven where available (vocab 2..5k, zipf and
uniform token sources) and degrades to the same generator over fixed
parametrized draws without it.  The >=100k-token acceptance corpus runs in the
slow tier (``-m "not slow"`` skips it).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import oracle, run_job
from repro.core.stats import NGramConfig, NGramStats
from repro.data import corpus as corpus_mod
from repro.index import build_index, compress_index, continuations, lookup
from repro.index.compress import EliasFano, decode_view
from repro.mapreduce import pack as packing


def grams_matrix(gram_tuples, sigma):
    g = np.zeros((len(gram_tuples), sigma), np.int32)
    ln = np.zeros(len(gram_tuples), np.int32)
    for i, t in enumerate(gram_tuples):
        g[i, : len(t)] = t
        ln[i] = len(t)
    return g, ln


def make_corpus(n_tokens: int, vocab: int, dist: str, seed: int) -> np.ndarray:
    """Token stream with PAD separators; zipf or uniform term source."""
    rng = np.random.default_rng(seed)
    if dist == "zipf":
        p = np.arange(1, vocab + 1, dtype=np.float64) ** -1.3
        p /= p.sum()
        toks = rng.choice(vocab, size=n_tokens, p=p).astype(np.int32) + 1
    else:
        toks = rng.integers(1, vocab + 1, n_tokens).astype(np.int32)
    toks[rng.random(n_tokens) < 0.05] = 0            # sentence separators
    return toks


def query_batches(exp, idx, rng):
    """(grams, lengths, expected) triples covering the paper-worthy mixes."""
    sigma, vocab = idx.sigma, idx.vocab_size
    batches = []
    gram_tuples = sorted(exp)
    if gram_tuples:
        g, ln = grams_matrix(gram_tuples, sigma)
        batches.append((g, ln, np.array([exp[t] for t in gram_tuples])))
    # miss-heavy + malformed (len 0, len > sigma, out-of-vocab, PAD inside)
    n = 600
    lnm = rng.integers(0, sigma + 3, n).astype(np.int32)
    gm = rng.integers(0, vocab + 4, (n, sigma)).astype(np.int32)
    gm *= np.arange(sigma)[None, :] < lnm[:, None]
    gm[: n // 10, 0] = 0                              # PAD at the lead
    want = np.array([
        exp.get(tuple(int(x) for x in r[:l]), 0)
        if 1 <= l <= sigma and all(1 <= int(x) <= vocab for x in r[:l]) else 0
        for r, l in zip(gm, lnm)])
    batches.append((gm, lnm, want))
    # duplicate-query batch: same rows repeated, answers must repeat too
    if gram_tuples:
        picks = rng.choice(len(gram_tuples), 40)
        dup = [gram_tuples[i] for i in picks] * 3
        g, ln = grams_matrix(dup, sigma)
        batches.append((g, ln, np.array([exp[t] for t in dup])))
    return batches


def assert_index_parity(exp, idx, cidx, seed=0, k=8):
    """The whole differential contract for one (corpus, layout) pair."""
    rng = np.random.default_rng(seed)
    for g, ln, want in query_batches(exp, idx, rng):
        got_u = np.asarray(lookup(idx, g, ln))
        np.testing.assert_array_equal(got_u, want)
        for uk in (False, True):
            got_c = np.asarray(lookup(cidx, g, ln, use_kernels=uk))
            np.testing.assert_array_equal(got_c, want)

    # continuations: empty prefix + real prefixes + junk prefixes, duplicated
    sigma = idx.sigma
    pool = sorted({t[:-1] for t in exp if len(t) >= 2})
    picks = [pool[i] for i in rng.choice(len(pool), min(30, len(pool)))] \
        if pool else []
    prefixes = [(), ()] + picks + [(idx.vocab_size + 2,)] + picks[:5]
    pg, pl = grams_matrix(prefixes, sigma)
    res_u = [np.asarray(x) for x in continuations(idx, pg, pl, k=k)]
    for uk in (False, True):
        res_c = [np.asarray(x) for x in continuations(cidx, pg, pl, k=k,
                                                      use_kernels=uk)]
        for a, b in zip(res_u, res_c):
            np.testing.assert_array_equal(a, b)
    # and the uncompressed reference itself against the oracle
    for i, p in enumerate(prefixes):
        ext = {t[-1]: c for t, c in exp.items()
               if len(t) == len(p) + 1 and t[: len(p)] == p}
        assert res_u[0][i] == len(ext)
        assert res_u[1][i] == sum(ext.values())
        got = [int(c) for c in res_u[3][i] if c > 0]
        assert got == sorted(ext.values(), reverse=True)[:k]


def assert_structural(idx, cidx):
    """Lossless re-encoding: EF values and term matrices round-trip exactly."""
    import jax.numpy as jnp
    for ef, want in (
        (cidx.ef_section, np.asarray(idx.section_start)),
        (cidx.ef_cont_fanout, np.asarray(idx.cont_fanout).reshape(-1)),
        (cidx.ef_cumsum, np.asarray(idx.cont_cumsum)),
    ):
        idxs = jnp.arange(ef.n)
        np.testing.assert_array_equal(np.asarray(ef.select(idxs)),
                                      want.astype(np.uint32))
        np.testing.assert_array_equal(np.asarray(ef.decode_all()),
                                      want.astype(np.uint32))
    # the decoded query caches are pure functions of the same structures
    np.testing.assert_array_equal(np.asarray(cidx.sec_cache),
                                  np.asarray(idx.section_start, np.int32))
    np.testing.assert_array_equal(np.asarray(cidx.cumsum_cache),
                                  np.asarray(idx.cont_cumsum, np.uint32))
    np.testing.assert_array_equal(
        np.asarray(cidx.fan_cache, np.int64),
        np.asarray(idx.fanout, np.int64).reshape(-1) // cidx.block_size)
    np.testing.assert_array_equal(
        np.asarray(cidx.cont_fan_cache, np.int64),
        np.asarray(idx.cont_fanout, np.int64).reshape(-1) // cidx.block_size)
    sigma, vocab = idx.sigma, idx.vocab_size
    sec = np.asarray(idx.section_start)
    row_len = np.searchsorted(sec, np.arange(idx.size), side="right")
    for view, lanes, off in (("point", idx.lanes, 0),
                             ("cont", idx.cont_prefix, 1)):
        terms = np.asarray(packing.unpack_terms(
            lanes, vocab_size=vocab, sigma=sigma))
        keep = np.arange(sigma)[None, :] < np.clip(row_len - off, 0,
                                                   sigma)[:, None]
        np.testing.assert_array_equal(decode_view(cidx, view),
                                      np.where(keep, terms, 0))


# --------------------------------------------------------------------------- #
# fast tier: small corpora, every dist/vocab/block-size corner
# --------------------------------------------------------------------------- #

CORPUS_DRAWS = [  # (vocab, dist, sigma, tau, block_size, seed)
    (5, "uniform", 3, 2, 4, 0),
    (40, "zipf", 5, 2, 4, 1),
    (40, "zipf", 5, 2, 16, 1),      # same corpus, different block geometry
    (700, "uniform", 4, 3, 8, 2),
    (5000, "zipf", 4, 2, 4, 3),
]


@pytest.mark.parametrize("vocab,dist,sigma,tau,block,seed", CORPUS_DRAWS)
def test_parity_generated_corpora(vocab, dist, sigma, tau, block, seed):
    toks = make_corpus(5000, vocab, dist, seed)
    stats = run_job(toks, NGramConfig(sigma=sigma, tau=tau, vocab_size=vocab))
    exp = oracle.ngram_counts(toks, sigma, tau)
    idx = build_index(stats, vocab_size=vocab)
    cidx = compress_index(idx, block_size=block)
    assert_structural(idx, cidx)
    assert_index_parity(exp, idx, cidx, seed=seed)


def test_empty_and_tiny_compressed_index():
    empty = NGramStats(np.zeros((0, 3), np.int32), np.zeros(0, np.int32),
                       np.zeros(0, np.int64))
    idx = build_index(empty, vocab_size=10)
    cidx = compress_index(idx)
    assert_structural(idx, cidx)
    assert_index_parity({}, idx, cidx)
    one = NGramStats(np.array([[5, 0, 0]], np.int32), np.array([1], np.int32),
                     np.array([7], np.int64))
    idx1 = build_index(one, vocab_size=10)
    cidx1 = compress_index(idx1)
    assert_index_parity({(5,): 7}, idx1, cidx1)


def test_huge_counts_need_full_width():
    """A single cf >= 2^31 forces count_width=32; the packer must take it."""
    big = np.uint32(2**31 + 5)
    stats = NGramStats(np.array([[5, 0, 0], [6, 0, 0]], np.int32),
                       np.array([1, 1], np.int32),
                       np.array([int(big), 7], np.int64))
    idx = build_index(stats, vocab_size=10)
    cidx = compress_index(idx)
    assert cidx.count_width == 32
    assert_index_parity({(5,): int(big), (6,): 7}, idx, cidx)


def test_elias_fano_adversarial_sequences():
    rng = np.random.default_rng(0)
    seqs = [
        np.zeros(5, np.int64),                        # all equal (all zeros)
        np.full(7, 1000, np.int64),                   # all equal, large
        np.arange(100, dtype=np.int64),               # dense
        np.sort(rng.integers(0, 2**31 - 1, 1000)),    # sparse, huge universe
        np.repeat(rng.integers(0, 50, 20).cumsum(), rng.integers(1, 5, 20)),
    ]
    import jax.numpy as jnp
    for s in seqs:
        for universe in (None, int(s.max()) * 2 + 10):
            ef = EliasFano.encode(s, universe=universe)
            np.testing.assert_array_equal(
                np.asarray(ef.select(jnp.arange(ef.n))), s.astype(np.uint32))
            np.testing.assert_array_equal(
                np.asarray(ef.decode_all()), s.astype(np.uint32))
    with pytest.raises(ValueError):
        EliasFano.encode(np.array([3, 2, 1]))
    with pytest.raises(ValueError):
        EliasFano.encode(np.array([], np.int64))


if HAS_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(vocab=st.integers(2, 5000),
           dist=st.sampled_from(["zipf", "uniform"]),
           sigma=st.integers(1, 6), tau=st.integers(1, 4),
           block=st.sampled_from([4, 8, 16]),
           seed=st.integers(0, 2**16))
    def test_parity_hypothesis(vocab, dist, sigma, tau, block, seed):
        toks = make_corpus(2500, vocab, dist, seed)
        stats = run_job(toks, NGramConfig(sigma=sigma, tau=tau,
                                          vocab_size=vocab))
        exp = oracle.ngram_counts(toks, sigma, tau)
        idx = build_index(stats, vocab_size=vocab)
        cidx = compress_index(idx, block_size=block)
        assert_index_parity(exp, idx, cidx, seed=seed)


# --------------------------------------------------------------------------- #
# slow tier: acceptance-sized corpus + the size contract
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def big_corpus_index():
    """>=100k tokens through job -> uncompressed + compressed index."""
    prof = corpus_mod.NYT
    toks = corpus_mod.zipf_corpus(110_000, prof, seed=7, duplicate_frac=0.05)
    sigma, tau = 4, 4
    stats = run_job(toks, NGramConfig(sigma=sigma, tau=tau,
                                      vocab_size=prof.vocab_size))
    exp = oracle.ngram_counts(toks, sigma, tau)
    idx = build_index(stats, vocab_size=prof.vocab_size)
    return exp, idx, compress_index(idx)


@pytest.mark.slow
def test_big_corpus_parity(big_corpus_index):
    exp, idx, cidx = big_corpus_index
    assert_structural(idx, cidx)
    assert_index_parity(exp, idx, cidx, seed=11)


@pytest.mark.slow
def test_compression_ratio_contract(big_corpus_index):
    """The acceptance bar: >= 2x smaller on a zipf corpus at default settings.

    The contract is on the *at-rest* artifact (streams + EF directories); the
    resident footprint additionally carries the decoded query caches, which
    must stay bounded relative to the at-rest bytes."""
    _, idx, cidx = big_corpus_index
    assert cidx.size == idx.size
    assert idx.nbytes / cidx.nbytes_at_rest >= 2.0, \
        (idx.nbytes, cidx.nbytes_at_rest)
    assert cidx.nbytes_at_rest < cidx.nbytes <= 2 * cidx.nbytes_at_rest, \
        (cidx.nbytes, cidx.nbytes_at_rest)
