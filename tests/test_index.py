"""Index build->query round trips against the pure-Python oracle."""
import numpy as np
import pytest

from repro.core import oracle, run_job
from repro.core.stats import NGramConfig, NGramStats
from repro.data import corpus as corpus_mod
from repro.index import build_index, continuations, lookup


def grams_matrix(gram_tuples, sigma):
    g = np.zeros((len(gram_tuples), sigma), np.int32)
    ln = np.zeros(len(gram_tuples), np.int32)
    for i, t in enumerate(gram_tuples):
        g[i, : len(t)] = t
        ln[i] = len(t)
    return g, ln


def check_continuations(exp, idx, prefixes, k, **kw):
    sigma = idx.sigma
    pg, pl = grams_matrix(prefixes, sigma)
    nd, total, terms, counts = [np.asarray(x) for x in
                                continuations(idx, pg, pl, k=k, **kw)]
    for i, p in enumerate(prefixes):
        ext = {g[-1]: c for g, c in exp.items()
               if len(g) == len(p) + 1 and g[: len(p)] == p}
        assert nd[i] == len(ext), p
        assert total[i] == sum(ext.values()), p
        got = [int(c) for c in counts[i] if c > 0]
        assert got == sorted(ext.values(), reverse=True)[:k], p
        for t, c in zip(terms[i], counts[i]):     # pairs are real (term, cf) rows
            if c > 0:
                assert ext[int(t)] == int(c), p


@pytest.fixture(scope="module")
def corpus_index():
    """Acceptance-sized fixture: >= 100k tokens through job -> index."""
    prof = corpus_mod.NYT
    toks = corpus_mod.zipf_corpus(120_000, prof, seed=3, duplicate_frac=0.05)
    sigma, tau = 4, 4
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=prof.vocab_size)
    stats = run_job(toks, cfg)
    exp = oracle.ngram_counts(toks, sigma, tau)
    idx = build_index(stats, vocab_size=prof.vocab_size)
    return exp, idx


def test_every_oracle_gram_round_trips(corpus_index):
    exp, idx = corpus_index
    gram_tuples = sorted(exp)
    g, ln = grams_matrix(gram_tuples, idx.sigma)
    got = np.asarray(lookup(idx, g, ln))
    want = np.array([exp[t] for t in gram_tuples])
    np.testing.assert_array_equal(got, want)


def test_miss_heavy_batch(corpus_index):
    exp, idx = corpus_index
    rng = np.random.default_rng(0)
    n = 5000
    ln = rng.integers(1, idx.sigma + 1, n).astype(np.int32)
    g = rng.integers(1, idx.vocab_size + 1, (n, idx.sigma)).astype(np.int32)
    g *= np.arange(idx.sigma)[None, :] < ln[:, None]
    got = np.asarray(lookup(idx, g, ln))
    want = np.array([exp.get(tuple(int(x) for x in r[: l]), 0)
                     for r, l in zip(g, ln)])
    assert (want > 0).mean() < 0.5          # the batch really is miss-heavy
    np.testing.assert_array_equal(got, want)


def test_topk_continuations_match_oracle(corpus_index):
    exp, idx = corpus_index
    rng = np.random.default_rng(1)
    # prefixes of real frequent grams (dense continuation sets) + empty prefix
    pool = [g[:-1] for g in exp if len(g) >= 2]
    prefixes = [()] + [pool[i] for i in rng.choice(len(pool), 40)]
    check_continuations(exp, idx, prefixes, k=8)


def test_invalid_and_malformed_queries_are_misses(corpus_index):
    _, idx = corpus_index
    sigma, v = idx.sigma, idx.vocab_size
    g = np.array([
        [0] * sigma,                         # length 0
        [v + 1] + [0] * (sigma - 1),         # out-of-vocab term
        [1, 0] + [2] * (sigma - 2),          # PAD inside the gram
        [1] * sigma,                         # length beyond sigma
    ], np.int32)
    ln = np.array([0, 1, 3, sigma + 1], np.int32)
    assert np.asarray(lookup(idx, g, ln)).tolist() == [0, 0, 0, 0]


def test_kernel_path_matches_ref_path():
    rng = np.random.default_rng(7)
    toks = rng.integers(0, 50, 4000)
    cfg = NGramConfig(sigma=5, tau=2, vocab_size=49)
    stats = run_job(toks, cfg)
    exp = oracle.ngram_counts(toks, 5, 2)
    idx = build_index(stats, vocab_size=49)
    gram_tuples = sorted(exp)
    g, ln = grams_matrix(gram_tuples, 5)
    ref = np.asarray(lookup(idx, g, ln))
    ker = np.asarray(lookup(idx, g, ln, use_kernels=True))
    np.testing.assert_array_equal(ref, ker)
    np.testing.assert_array_equal(ref, [exp[t] for t in gram_tuples])
    prefixes = [(), (1,), (2, 1), gram_tuples[-1][:2]]
    check_continuations(exp, idx, prefixes, k=4, use_kernels=True)


def test_bucketed_series_counts_marginalize():
    """An index built from a time-series job serves the marginal cf."""
    from repro.core import suffix_sigma
    rng = np.random.default_rng(2)
    toks = rng.integers(0, 20, 2000)
    buckets = rng.integers(0, 3, toks.shape[0])
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=19, n_buckets=3)
    stats = suffix_sigma.run(toks, cfg, bucket_ids=buckets)
    idx = build_index(stats, vocab_size=19)
    exp = oracle.ngram_counts(toks, 3, 2)
    gram_tuples = sorted(exp)
    g, ln = grams_matrix(gram_tuples, 3)
    np.testing.assert_array_equal(np.asarray(lookup(idx, g, ln)),
                                  [exp[t] for t in gram_tuples])


def test_empty_and_tiny_index():
    empty = NGramStats(np.zeros((0, 3), np.int32), np.zeros(0, np.int32),
                       np.zeros(0, np.int64))
    idx = build_index(empty, vocab_size=10)
    g, ln = grams_matrix([(1,), (1, 2)], 3)
    assert np.asarray(lookup(idx, g, ln)).tolist() == [0, 0]
    nd, total, terms, counts = continuations(idx, g, np.zeros(2, np.int32), k=2)
    assert np.asarray(nd).tolist() == [0, 0]
    one = NGramStats(np.array([[5, 0, 0]], np.int32), np.array([1], np.int32),
                     np.array([7], np.int64))
    idx1 = build_index(one, vocab_size=10)
    g, ln = grams_matrix([(5,), (6,)], 3)
    assert np.asarray(lookup(idx1, g, ln)).tolist() == [7, 0]
