"""End-to-end behaviour tests for the paper's system."""
import subprocess
import sys

import numpy as np
import pytest


def test_end_to_end_ngram_to_lm_pipeline():
    """The paper's use case (a) compressed: SUFFIX-sigma statistics -> frequency
    vocabulary -> short LM training run that reduces loss."""
    import jax
    import jax.numpy as jnp
    from repro.core import NGramConfig, run_job
    from repro.data import corpus as corpus_mod
    from repro.data.loader import LMBatchLoader
    from repro.models.transformer import (AttentionConfig, LMConfig, init_params,
                                          loss_fn)
    from repro.training.optimizer import OptimizerConfig, init_state
    from repro.training.train_loop import make_train_step

    prof = corpus_mod.CorpusProfile("e2e", 2000, 1.2, 20, 8)
    stream = corpus_mod.zipf_corpus(30_000, prof, seed=0)
    stats = run_job(stream, NGramConfig(sigma=3, tau=5, vocab_size=prof.vocab_size))
    assert len(stats) > 50
    uni = sorted(((g[0], c) for g, c in stats.to_dict().items() if len(g) == 1),
                 key=lambda kv: -kv[1])
    remap = np.zeros(prof.vocab_size + 1, np.int32)
    for new_id, (old, _) in enumerate(uni, start=2):
        remap[old] = new_id
    encoded = np.where(remap[stream] == 0, 1, remap[stream])
    cfg = LMConfig("e2e", 2, 64, len(uni) + 2, 128,
                   AttentionConfig("gqa", 4, 2, 16), dtype=jnp.float32,
                   remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_state(params)
    step = jax.jit(make_train_step(lambda p, b: loss_fn(p, b, cfg),
                                   OptimizerConfig(peak_lr=1e-3, warmup_steps=2,
                                                   decay_steps=40)))
    loader = LMBatchLoader(encoded, 32, 4, seed=0)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def _run_cli(args):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-m", "repro.launch.ngram"] + args,
                          capture_output=True, text=True, timeout=560, env=env,
                          cwd="/root/repo")


def test_ngram_cli_runs():
    r = _run_cli(["--method", "suffix_sigma", "--sigma", "4", "--tau", "5",
                  "--tokens", "20000", "--split-docs"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "n-grams in" in r.stdout and "counters" in r.stdout


def test_methods_cli_agree():
    """All four methods via the CLI produce the same number of frequent n-grams."""
    counts = {}
    for m in ("suffix_sigma", "naive", "apriori_scan", "apriori_index"):
        r = _run_cli(["--method", m, "--sigma", "3", "--tau", "8",
                      "--tokens", "8000"])
        assert r.returncode == 0, (m, r.stderr[-1500:])
        line = [l for l in r.stdout.splitlines() if "n-grams in" in l][0]
        counts[m] = int(line.split("n-grams in")[0].split(":")[-1].strip())
    assert len(set(counts.values())) == 1, counts
