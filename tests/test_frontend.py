"""Serving-frontend suite: batcher, admission, coalescing, HTTP round trip.

The layers are tested bottom-up with deterministic drivers (recording
executors, manual flush mode, injected clocks), then the whole stack --
HTTP/SSE transport -> admission -> continuous batcher -> service -> index --
is driven over localhost and checked **bit-identical** against direct
``StreamingNGramService`` calls (the oracle the acceptance criteria names).
"""
from __future__ import annotations

import http.client
import json
import time
from concurrent.futures import Future
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serve.admission import (ADMIT, QUOTA, SHED, AdmissionController,
                                   TokenBucket)
from repro.serve.batcher import ContinuousBatcher, Request, select_bucket
from repro.serve.service import StreamingNGramService

SIGMA, VOCAB = 3, 30


# --------------------------------------------------------------------------- #
# deterministic plumbing (no jax)
# --------------------------------------------------------------------------- #

class RecordingExecutor:
    """Answers lookups as f(gram) so tests can check per-slot routing."""

    def __init__(self):
        self.batches = []          # (kind, k, grams, lengths) per flush
        self.collected = 0

    def submit(self, kind, k, grams, lengths):
        self.batches.append((kind, k, grams.copy(), lengths.copy()))
        return kind, k, grams.copy(), lengths.copy()

    def collect(self, rec):
        kind, k, g, ln = rec
        self.collected += 1
        if kind == "lookup":
            return (g[:, 0].astype(np.uint32) * 100
                    + ln.astype(np.uint32))
        rows = np.zeros((g.shape[0], 2 + 2 * k), np.uint32)
        rows[:, 0] = g[:, 0]
        return rows


def req(term: int, *, length: int = 1, kind: str = "lookup", k: int = 8,
        priority: int = 0) -> Request:
    gram = np.zeros((SIGMA,), np.int32)
    gram[0] = term
    return Request(kind, gram, length, k=k, priority=priority)


def stub_service(generation: int = 1):
    """The minimal service surface QueryFrontend needs (key fns + config)."""
    return SimpleNamespace(
        cfg=SimpleNamespace(sigma=SIGMA, vocab_size=VOCAB),
        gen=SimpleNamespace(generation=generation),
        lookup_key=StreamingNGramService.lookup_key,
        continuation_key=StreamingNGramService.continuation_key)


# ------------------------------------------------------------ bucket policy

def test_select_bucket_deterministic():
    buckets = (16, 64, 256)
    assert select_bucket(1, buckets) == 16
    assert select_bucket(16, buckets) == 16
    assert select_bucket(17, buckets) == 64
    assert select_bucket(65, buckets) == 256
    assert select_bucket(10_000, buckets) == 256   # the cap
    with pytest.raises(ValueError):
        select_bucket(0, buckets)


def test_flush_pads_to_bucket_and_zero_fills():
    ex = RecordingExecutor()
    b = ContinuousBatcher(ex, buckets=(4, 8), deadline_s=10.0, autostart=False)
    reqs = [req(t + 1) for t in range(3)]
    for r in reqs:
        b.enqueue(r)
    batch = b.flush_once(force=True)
    b.collect_inflight()
    assert [r.seq for r in batch] == [0, 1, 2]
    kind, _, g, ln = ex.batches[0]
    assert kind == "lookup" and g.shape == (4, SIGMA)    # 3 live -> bucket 4
    np.testing.assert_array_equal(g[:3, 0], [1, 2, 3])
    np.testing.assert_array_equal(g[3], 0)               # pad slot is zeros
    assert ln[3] == 0
    assert [r.future.result(0) for r in reqs] == [101, 201, 301]
    assert b.stats()["padded_slots"] == 1


def test_full_bucket_caps_flush_size():
    ex = RecordingExecutor()
    b = ContinuousBatcher(ex, buckets=(2, 4), deadline_s=10.0, autostart=False)
    for t in range(6):
        b.enqueue(req(t + 1))
    assert b.flush_once() is not None      # 6 queued >= cap 4: due immediately
    assert ex.batches[0][2].shape[0] == 4
    assert b.depth == 2


# -------------------------------------------------------- deadline semantics

def test_deadline_flush_without_busy_wait():
    """A partial bucket flushes at the deadline off a condition-variable wait:
    wall time reaches the deadline while the loop wakes O(1) times, and the
    stats prove no poll loop spun."""
    ex = RecordingExecutor()
    b = ContinuousBatcher(ex, buckets=(4, 8), deadline_s=0.05)
    try:
        t0 = time.perf_counter()
        reqs = [req(t + 1) for t in range(3)]
        for r in reqs:
            b.enqueue(r)
        vals = [r.future.result(timeout=5.0) for r in reqs]
        elapsed = time.perf_counter() - t0
        assert vals == [101, 201, 301]
        assert 0.02 <= elapsed <= 2.0        # flushed by deadline, not instantly
        st = b.stats()
        assert st["batches"] == 1 and st["requests"] == 3
        assert st["wait_cycles"] <= 10        # cond.wait(timeout), not a spin
    finally:
        b.stop()


def test_stop_drains_everything():
    ex = RecordingExecutor()
    b = ContinuousBatcher(ex, buckets=(4,), deadline_s=60.0)
    reqs = [req(t + 1) for t in range(3)]
    for r in reqs:
        b.enqueue(r)
    b.stop()                                 # deadline far away: stop flushes
    assert all(r.future.done() for r in reqs)
    with pytest.raises(RuntimeError):
        b.enqueue(req(9))


# ------------------------------------------------------------ priority order

def test_priority_ordering_under_contention():
    ex = RecordingExecutor()
    b = ContinuousBatcher(ex, buckets=(8,), deadline_s=10.0, autostart=False)
    low = [req(t + 1, priority=1) for t in range(3)]
    for r in low:
        b.enqueue(r)
    high = req(7, priority=0)
    b.enqueue(high)                          # arrives last, flushes first
    first = b.flush_once(force=True)
    second = b.flush_once(force=True)
    b.collect_inflight()
    assert first == [high]
    assert second == low
    assert ex.batches[0][2][0, 0] == 7
    np.testing.assert_array_equal(ex.batches[1][2][:3, 0], [1, 2, 3])


def test_lanes_split_by_kind_and_k():
    ex = RecordingExecutor()
    b = ContinuousBatcher(ex, buckets=(8,), deadline_s=10.0, autostart=False)
    b.enqueue(req(1))
    b.enqueue(req(2, kind="topk", k=4))
    b.enqueue(req(3))
    first = b.flush_once(force=True)         # oldest head wins: lookup lane
    second = b.flush_once(force=True)
    b.collect_inflight()
    assert [r.kind for r in first] == ["lookup", "lookup"]
    assert [r.seq for r in first] == [0, 2]
    assert [r.kind for r in second] == ["topk"]
    assert ex.batches[1][1] == 4             # k rides the lane


# -------------------------------------------------- cancelled never padded in

def test_cancelled_request_never_enters_device_batch():
    ex = RecordingExecutor()
    b = ContinuousBatcher(ex, buckets=(4, 8), deadline_s=10.0, autostart=False)
    reqs = [req(t + 1) for t in range(5)]
    for r in reqs:
        b.enqueue(r)
    assert reqs[1].cancel() and reqs[4].cancel()
    batch = b.flush_once(force=True)
    b.collect_inflight()
    assert [r.seq for r in batch] == [0, 2, 3]
    _, _, g, _ = ex.batches[0]
    assert g.shape[0] == 4                   # bucket chosen AFTER the filter
    np.testing.assert_array_equal(g[:, 0], [1, 3, 4, 0])
    assert reqs[1].future.cancelled() and reqs[4].future.cancelled()
    assert b.stats()["cancelled_dropped"] == 2
    assert b.depth == 0


def test_cancel_refused_with_followers_and_after_delivery():
    r = req(1)
    rider = Future()
    assert r.attach(rider)
    assert not r.cancel()                    # a follower still needs the row
    r.deliver(np.uint32(7))
    assert rider.result(0) == 7
    assert not r.cancel()                    # sealed
    assert not r.attach(rider)               # late duplicate must re-submit


# ------------------------------------------------------------------ admission

def test_token_bucket_exhaustion_and_recovery():
    t = [0.0]
    bucket = TokenBucket(rate=2.0, burst=4.0, clock=lambda: t[0])
    assert all(bucket.try_take() for _ in range(4))
    assert not bucket.try_take()             # burst drained
    t[0] += 1.0                              # +2 tokens
    assert bucket.try_take() and bucket.try_take()
    assert not bucket.try_take()
    t[0] += 100.0                            # refill clamps at burst
    assert sum(bucket.try_take() for _ in range(10)) == 4


def test_admission_priority_shedding_tiers():
    adm = AdmissionController(queue_budget=4, hard_limit=8)
    lo, hi = adm.level("batch"), adm.level("interactive")
    assert adm.admit(tenant="t", level=lo, queue_depth=3) == ADMIT
    assert adm.admit(tenant="t", level=lo, queue_depth=4) == SHED
    assert adm.admit(tenant="t", level=hi, queue_depth=4) == ADMIT
    assert adm.admit(tenant="t", level=hi, queue_depth=8) == SHED
    with pytest.raises(KeyError):
        adm.level("vip")


def test_admission_quota_is_per_tenant_and_recovers():
    t = [0.0]
    adm = AdmissionController(queue_budget=64, quota_rate=1.0, quota_burst=2.0,
                              clock=lambda: t[0])
    assert adm.admit(tenant="a", level=0, queue_depth=0) == ADMIT
    assert adm.admit(tenant="a", level=0, queue_depth=0) == ADMIT
    assert adm.admit(tenant="a", level=0, queue_depth=0) == QUOTA
    assert adm.admit(tenant="b", level=0, queue_depth=0) == ADMIT  # own bucket
    t[0] += 1.0
    assert adm.admit(tenant="a", level=0, queue_depth=0) == ADMIT  # recovered
    assert adm.admit(tenant="a", level=0, queue_depth=0) == QUOTA


# ---------------------------------------------------------------- frontend

def make_frontend(**admission_kw):
    from repro.serve.frontend import QueryFrontend
    return QueryFrontend(stub_service(), executor=RecordingExecutor(),
                         admission=AdmissionController(**admission_kw),
                         deadline_s=10.0, autostart=False)


def test_frontend_shed_and_quota_tickets():
    from repro.obs import metrics as obs_metrics
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    try:
        fe = make_frontend(queue_budget=0, hard_limit=1,
                           quota_rate=1.0, quota_burst=1.0)
        t_batch = fe.submit("lookup", [5], 1, priority="batch")
        assert t_batch.status == "shed" and not t_batch.admitted
        t_hi = fe.submit("lookup", [5], 1, priority="interactive")
        assert t_hi.status == "admitted"     # level 0 survives the soft budget
        # depth now 1 >= hard_limit: even interactive sheds
        assert fe.submit("lookup", [6], 1).status == "shed"
        # shed/quota'd requests never reached the batcher queue
        assert fe.batcher.depth == 1
        fe.batcher.stop()
        assert reg.counter("frontend.shed").value == 2
    finally:
        obs_metrics.set_registry(None)


def test_frontend_quota_rejection_counter():
    from repro.obs import metrics as obs_metrics
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    try:
        fe = make_frontend(queue_budget=64, quota_rate=0.001, quota_burst=1.0)
        assert fe.submit("lookup", [1], 1, tenant="t0").status == "admitted"
        assert fe.submit("lookup", [2], 1, tenant="t0").status == "quota"
        assert fe.submit("lookup", [2], 1, tenant="t1").status == "admitted"
        fe.batcher.stop()
        assert reg.counter("frontend.quota_rejected").value == 1
    finally:
        obs_metrics.set_registry(None)


def test_duplicate_coalescing_bit_identical_payloads():
    from repro.obs import metrics as obs_metrics
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(reg)
    try:
        fe = make_frontend(queue_budget=64)
        a = fe.submit("lookup", [7, 8], 2)
        b = fe.submit("lookup", [7, 8], 2)       # identical, in flight
        c = fe.submit("lookup", [7, 9], 2)       # different gram
        assert (a.status, b.status, c.status) == \
            ("admitted", "coalesced", "admitted")
        fe.batcher.flush_once(force=True)
        fe.batcher.collect_inflight()
        pa, pb = a.future.result(0), b.future.result(0)
        assert pa == pb and pa.tobytes() == pb.tobytes()
        # the executor saw ONE slot for the duplicate pair (2 live, not 3)
        _, _, g, _ = fe.batcher.executor.batches[0]
        assert g.shape[0] == 16
        np.testing.assert_array_equal(g[:3, 0], [7, 7, 0])
        fe.batcher.stop()
        assert reg.counter("frontend.coalesced").value == 1
    finally:
        obs_metrics.set_registry(None)


def test_coalescing_key_includes_generation():
    fe = make_frontend(queue_budget=64)
    a = fe.submit("lookup", [7], 1)
    fe.service.gen.generation += 1               # ingest swapped the index
    b = fe.submit("lookup", [7], 1)
    assert a.status == "admitted" and b.status == "admitted"
    fe.batcher.stop()


def test_overlong_query_is_exact_miss_without_device():
    fe = make_frontend(queue_budget=64)
    t = fe.submit("lookup", list(range(1, SIGMA + 2)), SIGMA + 1)
    assert t.status == "admitted" and int(t.future.result(0)) == 0
    row = fe.submit("topk", list(range(1, SIGMA + 1)), SIGMA, k=4)
    np.testing.assert_array_equal(row.future.result(0),
                                  np.zeros(2 + 8, np.uint32))
    assert fe.batcher.depth == 0                 # nothing queued
    fe.batcher.stop()


# --------------------------------------------------------------------------- #
# end to end over localhost HTTP, vs the direct-call oracle
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def served():
    from repro.core.stats import NGramConfig
    from repro.serve.frontend import QueryFrontend
    from repro.serve.http import serve_http

    rng = np.random.default_rng(7)
    tokens = rng.integers(1, VOCAB + 1, 1500).astype(np.int32)
    cfg = NGramConfig(sigma=SIGMA, tau=1, vocab_size=VOCAB)
    svc = StreamingNGramService(cfg, cache_capacity=4096)
    svc.ingest(tokens)
    fe = QueryFrontend(svc, deadline_s=0.002)
    srv = serve_http(fe, "127.0.0.1", 0, block=False)
    try:
        yield svc, fe, srv.server_address
    finally:
        srv.shutdown()
        srv.server_close()
        fe.close()


def _post(addr, path, body, headers=None):
    conn = http.client.HTTPConnection(*addr, timeout=30)
    try:
        conn.request("POST", path, body=json.dumps(body),
                     headers={"Content-Type": "application/json",
                              **(headers or {})})
        r = conn.getresponse()
        return r.status, json.loads(r.read())
    finally:
        conn.close()


def _get(addr, path):
    conn = http.client.HTTPConnection(*addr, timeout=30)
    try:
        conn.request("GET", path)
        r = conn.getresponse()
        return r.status, json.loads(r.read())
    finally:
        conn.close()


def test_http_lookup_matches_direct_calls(served):
    from repro.index.merge import segment_to_stats
    svc, _, addr = served
    stats = segment_to_stats(svc.gen.segments[0].to_segment())
    grams = np.asarray(stats.grams)[:40].astype(np.int32)
    lengths = np.asarray(stats.lengths)[:40].astype(np.int32)
    direct = svc.lookup(grams, lengths)
    # single-gram endpoint
    for i in range(0, 8):
        status, body = _post(addr, "/v1/lookup",
                             {"gram": grams[i, :lengths[i]].tolist()})
        assert status == 200
        assert body["count"] == int(direct[i])
    # batch endpoint, mixed with misses
    miss = [[29, 29, 29], [0]]
    status, body = _post(addr, "/v1/lookup", {
        "grams": [grams[i, :lengths[i]].tolist() for i in range(40)] + miss})
    assert status == 200
    assert body["counts"][:40] == [int(c) for c in direct]
    g_miss = np.zeros((2, SIGMA), np.int32)
    g_miss[0] = miss[0]
    g_miss[1, 0] = 0
    d_miss = svc.lookup(g_miss, np.array([3, 1], np.int32))
    assert body["counts"][40:] == [int(c) for c in d_miss]


def test_http_topk_matches_direct_calls(served):
    svc, _, addr = served
    for term in (1, 2, 5, 11, VOCAB):
        pg = np.zeros((1, SIGMA), np.int32)
        pg[0, 0] = term
        row = svc.continuations(pg, np.array([1], np.int32), k=4)[0]
        status, body = _post(addr, "/v1/topk", {"prefix": [term], "k": 4})
        assert status == 200
        assert body["n_distinct"] == int(row[0])
        assert body["total"] == int(row[1])
        assert body["terms"] == [int(t) for t in row[2:6]]
        assert body["counts"] == [int(c) for c in row[6:10]]


def test_http_sse_completion_matches_greedy_oracle(served):
    svc, _, addr = served
    prefix, steps, k = [3], 6, 4
    # direct-call greedy oracle
    want = []
    ctx = list(prefix)
    for _ in range(steps):
        w = ctx[-(SIGMA - 1):]
        pg = np.zeros((1, SIGMA), np.int32)
        pg[0, :len(w)] = w
        row = svc.continuations(pg, np.array([len(w)], np.int32), k=k)[0]
        term, count = int(row[2]), int(row[2 + k])
        if count == 0:
            break
        want.append((term, count))
        ctx.append(term)
    conn = http.client.HTTPConnection(*addr, timeout=30)
    try:
        conn.request("POST", "/v1/complete",
                     body=json.dumps({"prefix": prefix, "steps": steps,
                                      "k": k}))
        r = conn.getresponse()
        assert r.status == 200
        assert r.getheader("Content-Type") == "text/event-stream"
        raw = r.read().decode()
    finally:
        conn.close()
    events = [ln[6:] for ln in raw.split("\n") if ln.startswith("data: ")]
    assert events[-1] == "[DONE]"
    got = [(e["term"], e["count"]) for e in map(json.loads, events[:-1])]
    assert got == want


def test_http_topology_and_health(served):
    svc, fe, addr = served
    status, body = _get(addr, "/healthz")
    assert status == 200 and body == {"status": "ok"}
    status, topo = _get(addr, "/v1/system/topology")
    assert status == 200
    assert topo["service"]["generation"] == svc.gen.generation
    assert topo["index"]["kind"] == "generational"
    assert topo["index"]["n_segments"] == svc.gen.n_segments
    assert [s["rows"] for s in topo["index"]["segments"]] == \
        [ix.n_rows for ix in svc.gen.segments]
    assert topo["admission"]["queue_budget"] == fe.admission.queue_budget
    assert topo["batcher"]["buckets"] == list(fe.batcher.buckets)
    json.dumps(topo)                              # fully serializable


def test_http_error_paths(served):
    _, _, addr = served
    assert _get(addr, "/nope")[0] == 404
    assert _post(addr, "/v1/lookup", {"gram": "abc"})[0] == 400
    assert _post(addr, "/v1/lookup", {"gram": [1]},
                 headers={"X-Priority": "vip"})[0] == 400
    assert _post(addr, "/v1/topk", {"prefix": [1], "k": 0})[0] == 400


def test_http_shed_maps_to_503():
    from repro.serve.frontend import QueryFrontend
    from repro.serve.http import serve_http
    fe = QueryFrontend(stub_service(), executor=RecordingExecutor(),
                       admission=AdmissionController(queue_budget=0,
                                                     hard_limit=0),
                       deadline_s=10.0, autostart=False)
    srv = serve_http(fe, "127.0.0.1", 0, block=False)
    try:
        status, body = _post(srv.server_address, "/v1/lookup", {"gram": [1]})
        assert status == 503 and "shed" in body["error"]
    finally:
        srv.shutdown()
        srv.server_close()
        fe.batcher.stop()


def test_request_and_flush_spans_recorded(served):
    from repro.obs import trace as obs_trace
    _, _, addr = served
    tracer = obs_trace.enable_tracing()
    try:
        status, _ = _post(addr, "/v1/lookup", {"gram": [2, 4]})
        assert status == 200
    finally:
        obs_trace.disable_tracing()
    names = {e["name"] for e in tracer.export()["traceEvents"]}
    assert "serve.request" in names       # transport thread
    assert "serve.flush" in names         # batcher thread, same tracer


def test_launch_reexports_still_work():
    """The PR-5/PR-10 compatibility contract: every old import path holds."""
    from repro.launch import serve_ngrams as mod
    from repro.serve.cache import LRUQueryCache as new_cache
    assert mod.LRUQueryCache is new_cache
    assert mod.StreamingNGramService is StreamingNGramService
    from repro.serve.service import microbatch_drive, make_query_stream
    assert mod.microbatch_drive is microbatch_drive
    assert mod.make_query_stream is make_query_stream
    from repro.pipeline.executor import DoubleBufferedDriver
    assert mod.DoubleBufferedDriver is DoubleBufferedDriver
    with pytest.raises(AttributeError):
        mod.not_a_thing
