"""Multi-device behaviour, run in subprocesses so the main pytest process keeps its
single CPU device (the dry-run is the only place that pins 512)."""
import json
import subprocess
import sys
import textwrap

import pytest

PY = sys.executable


def run_with_devices(code: str, n: int = 8, timeout: int = 560) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    env["PYTHONPATH"] = "src"
    r = subprocess.run([PY, "-c", textwrap.dedent(code)], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd="/root/repo")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_distributed_methods_match_oracle():
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core import run_job, oracle
        from repro.core.stats import NGramConfig
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 60, 900)
        exp = oracle.ngram_counts(toks, 4, 2)
        for m in ("suffix_sigma", "naive", "apriori_scan", "apriori_index"):
            cfg = NGramConfig(sigma=4, tau=2, vocab_size=59, method=m)
            got = run_job(toks, cfg, mesh=mesh).to_dict()
            assert got == exp, m
        print("OK")
    """)
    assert "OK" in out


def test_shuffle_overflow_retry_and_counters():
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core import suffix_sigma, oracle
        from repro.core.stats import NGramConfig
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(1)
        # heavy skew: tiny vocab concentrates lead terms -> forces capacity retry
        # (combine=False: the map-side combiner would dedupe the tiny-vocab
        # suffixes down to a handful of records and dodge the overflow)
        toks = rng.integers(0, 3, 4000)
        cfg = NGramConfig(sigma=3, tau=1, vocab_size=2, capacity_factor=0.05,
                          combine=False)
        st = suffix_sigma.run(toks, cfg, mesh=mesh)
        assert st.to_dict() == oracle.ngram_counts(toks, 3, 1)
        assert st.counters["retries"] >= 1     # capacity doubled at least once
        assert st.counters["overflow"] == 0    # final run clean
        print("OK retries=", st.counters["retries"])
    """)
    assert "OK" in out


def test_checkpoint_resharding_across_meshes():
    out = run_with_devices("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.checkpoint import CheckpointManager
        m8 = jax.make_mesh((8,), ("data",),
                           axis_types=(jax.sharding.AxisType.Auto,))
        m24 = jax.make_mesh((2, 4), ("data", "model"),
                            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        x = jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16)
        xs = jax.device_put(x, NamedSharding(m8, P("data", None)))
        with tempfile.TemporaryDirectory() as d:
            ck = CheckpointManager(d, async_save=False)
            ck.save(1, {"w": xs})
            # restore onto a DIFFERENT mesh/sharding (elastic scaling path)
            tgt = jax.ShapeDtypeStruct((64, 16), jnp.float32)
            restored, _ = ck.restore(
                1, {"w": tgt},
                shardings={"w": NamedSharding(m24, P("model", "data"))})
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
            assert restored["w"].sharding.mesh.shape == {"data": 2, "model": 4}
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_unbiased():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.training.compression import compressed_psum_exact_scale
        mesh = jax.make_mesh((4,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256)),
                        jnp.float32)

        def f(gs, key):
            return compressed_psum_exact_scale({"g": gs[0]}, "pod", key)["g"]

        fn = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(P("pod", None), P()),
                                   out_specs=P(), check_vma=False))
        # average over many rounding keys -> unbiased estimate of the true mean
        acc = 0
        n = 50
        for i in range(n):
            out = fn(g, jax.random.PRNGKey(i))
            acc = acc + np.asarray(out)
        approx = acc / n
        true = np.asarray(g).mean(0)
        err = np.abs(approx - true).max()
        scale = np.abs(np.asarray(g)).max() / 127
        assert err < 3 * scale / np.sqrt(n) + 1e-6, (err, scale)
        print("OK err=", err)
    """)
    assert "OK" in out


def test_moe_sharded_matches_local():
    """shard_map MoE (sort dispatch + EP/ffTP) == single-device moe_ffn."""
    out = run_with_devices("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.models.moe import MoEConfig, init_moe_params, moe_ffn, moe_ffn_sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        for n_exp, shared in ((8, 0), (4, 2)):   # EP (8%4==0) and EP+shared
            cfg = MoEConfig(n_exp, 2, 32, n_shared=shared, d_ff_shared=24,
                            capacity_factor=float(n_exp),  # drop-free
                            mesh=mesh, dp_axes="data")
            params = init_moe_params(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16), jnp.float32)
            with mesh:
                y_sh, aux_sh = jax.jit(lambda xx, pp: moe_ffn_sharded(xx, pp, cfg))(x, params)
            cfg0 = dataclasses.replace(cfg, mesh=None)
            y0, aux0 = moe_ffn(x, params, dataclasses.replace(cfg0, dispatch="sort"))
            err = float(jnp.max(jnp.abs(y_sh - y0)))
            assert err < 1e-4, (n_exp, shared, err)
        print("OK")
    """)
    assert "OK" in out


def test_gnn_dst_partitioned_matches_local():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models import gnn
        from repro.data import graph as gdata
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        cfg = gnn.GINConfig("t", n_layers=3, d_hidden=16, d_feat=8, n_classes=4,
                            comm_dtype=jnp.float32)
        n_nodes = 64
        g = gdata.random_graph(n_nodes, 400, 8, 4, seed=0)
        params = gnn.init_params(jax.random.PRNGKey(0), cfg)
        src, dst, emask = gdata.partition_edges_by_dst(g, 4, pad_factor=4.0)
        batch = {"features": jnp.asarray(g.features),
                 "edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst),
                 "edge_mask": jnp.asarray(emask),
                 "labels": jnp.asarray(g.labels),
                 "label_mask": jnp.ones((n_nodes,), bool)}
        with mesh:
            loss_d, _ = jax.jit(lambda p, b: gnn.loss_fn_dst_partitioned(
                p, b, cfg, mesh, "data"))(params, batch)
        loss_l, _ = gnn.loss_fn(params, batch, cfg)
        assert abs(float(loss_d) - float(loss_l)) < 1e-4, (float(loss_d), float(loss_l))
        print("OK", float(loss_d))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_index_serving_matches_oracle():
    """>=100k-token corpus: every oracle gram answered through the mesh-sharded
    index (hash-routed all_to_all round trip), plus a miss-heavy batch and
    top-k continuations."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core import run_job, oracle
        from repro.core.stats import NGramConfig
        from repro.data import corpus as corpus_mod
        from repro.index import build_sharded_index, serve_queries
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        prof = corpus_mod.NYT
        toks = corpus_mod.zipf_corpus(110_000, prof, seed=11, duplicate_frac=0.05)
        sigma, tau = 4, 4
        stats = run_job(toks, NGramConfig(sigma=sigma, tau=tau,
                                          vocab_size=prof.vocab_size))
        exp = oracle.ngram_counts(toks, sigma, tau)
        sh = build_sharded_index(stats, vocab_size=prof.vocab_size, mesh=mesh)

        gram_tuples = sorted(exp)
        g = np.zeros((len(gram_tuples), sigma), np.int32)
        ln = np.zeros(len(gram_tuples), np.int32)
        for i, t in enumerate(gram_tuples):
            g[i, :len(t)] = t; ln[i] = len(t)
        got = serve_queries(sh, g, ln)
        assert (got == np.array([exp[t] for t in gram_tuples])).all()

        rng = np.random.default_rng(0)
        lm = rng.integers(1, sigma + 1, 4000).astype(np.int32)
        gm = rng.integers(1, prof.vocab_size + 1, (4000, sigma)).astype(np.int32)
        gm *= np.arange(sigma)[None, :] < lm[:, None]
        gotm = serve_queries(sh, gm, lm)
        wantm = np.array([exp.get(tuple(int(x) for x in r[:l]), 0)
                          for r, l in zip(gm, lm)])
        assert (wantm > 0).mean() < 0.5       # miss-heavy
        assert (gotm == wantm).all()

        k = 8
        pool = [t[:-1] for t in gram_tuples if len(t) >= 2]
        prefixes = [pool[i] for i in rng.choice(len(pool), 30)]
        pg = np.zeros((len(prefixes), sigma), np.int32)
        pl = np.zeros(len(prefixes), np.int32)
        for i, t in enumerate(prefixes):
            pg[i, :len(t)] = t; pl[i] = len(t)
        res = serve_queries(sh, pg, pl, mode="continuations", k=k)
        for i, p in enumerate(prefixes):
            ext = {t[-1]: c for t, c in exp.items()
                   if len(t) == len(p) + 1 and t[:len(p)] == p}
            assert res[i, 0] == len(ext) and res[i, 1] == sum(ext.values())
            cnts = res[i, 2 + k:]
            assert [c for c in cnts if c > 0] == sorted(ext.values(),
                                                        reverse=True)[:k]
            for t_, c_ in zip(res[i, 2:2 + k], cnts):
                if c_ > 0:
                    assert ext[int(t_)] == int(c_)
        print("OK", len(gram_tuples))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_empty_prefix_matches_single_device():
    """ROADMAP gap closed: len-0 (unigram top-k) prefixes through the sharded
    path -- per-shard top-k gathered and merged on the host -- must agree with
    the single-device answer on an 8-way mesh, for both layouts, mixed into a
    batch with ordinary prefixes."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core import run_job, oracle
        from repro.core.stats import NGramConfig
        from repro.data import corpus as corpus_mod
        from repro.index import (build_index, build_sharded_index,
                                 continuations, serve_queries)
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        prof = corpus_mod.NYT
        toks = corpus_mod.zipf_corpus(60_000, prof, seed=13, duplicate_frac=0.05)
        sigma, tau, k = 4, 4, 8
        stats = run_job(toks, NGramConfig(sigma=sigma, tau=tau,
                                          vocab_size=prof.vocab_size))
        exp = oracle.ngram_counts(toks, sigma, tau)
        idx = build_index(stats, vocab_size=prof.vocab_size)

        gram_tuples = sorted(exp)
        pool = [t[:-1] for t in gram_tuples if len(t) >= 2]
        rng = np.random.default_rng(0)
        # empty prefixes interleaved with real ones (the mixed-batch path)
        prefixes = [(), pool[0], (), pool[1]] + \\
            [pool[i] for i in rng.choice(len(pool), 12)] + [()]
        pg = np.zeros((len(prefixes), sigma), np.int32)
        pl = np.zeros(len(prefixes), np.int32)
        for i, t in enumerate(prefixes):
            pg[i, :len(t)] = t; pl[i] = len(t)
        nd, tot, terms, counts = [np.asarray(x) for x in
                                  continuations(idx, pg, pl, k=k)]
        for compress in (False, True):
            sh = build_sharded_index(stats, vocab_size=prof.vocab_size,
                                     mesh=mesh, compress=compress)
            res = serve_queries(sh, pg, pl, mode="continuations", k=k)
            assert (res[:, 0] == nd).all(), compress
            assert (res[:, 1] == tot).all(), compress
            assert (res[:, 2 + k:] == counts).all(), compress   # cf descending
            # term ids may reorder inside equal-count ties; the (term -> cf)
            # mapping must still be real
            for i, p in enumerate(prefixes):
                ext = {t[-1]: c for t, c in exp.items()
                       if len(t) == len(p) + 1 and t[:len(p)] == p}
                for t_, c_ in zip(res[i, 2:2 + k], res[i, 2 + k:]):
                    if c_ > 0:
                        assert ext[int(t_)] == int(c_), (compress, i)
        print("OK", len(prefixes))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_compressed_index_matches_oracle():
    """Acceptance: the compressed layout answers bit-identically through the
    8-way hash-routed all_to_all path -- every oracle gram plus a miss-heavy
    batch, ref and kernel routes."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core import run_job, oracle
        from repro.core.stats import NGramConfig
        from repro.data import corpus as corpus_mod
        from repro.index import build_sharded_index, serve_queries
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        prof = corpus_mod.NYT
        toks = corpus_mod.zipf_corpus(110_000, prof, seed=17, duplicate_frac=0.05)
        sigma, tau = 4, 4
        stats = run_job(toks, NGramConfig(sigma=sigma, tau=tau,
                                          vocab_size=prof.vocab_size))
        exp = oracle.ngram_counts(toks, sigma, tau)
        sh_u = build_sharded_index(stats, vocab_size=prof.vocab_size, mesh=mesh)
        sh_c = build_sharded_index(stats, vocab_size=prof.vocab_size, mesh=mesh,
                                   compress=True)
        # the size contract holds on the at-rest artifact (the decoded query
        # caches are resident-only acceleration state, not stored bytes)
        assert sh_c.index.nbytes_at_rest * 2 <= sh_u.index.nbytes

        gram_tuples = sorted(exp)
        g = np.zeros((len(gram_tuples), sigma), np.int32)
        ln = np.zeros(len(gram_tuples), np.int32)
        for i, t in enumerate(gram_tuples):
            g[i, :len(t)] = t; ln[i] = len(t)
        want = np.array([exp[t] for t in gram_tuples])

        rng = np.random.default_rng(0)
        lm = rng.integers(1, sigma + 1, 4000).astype(np.int32)
        gm = rng.integers(1, prof.vocab_size + 1, (4000, sigma)).astype(np.int32)
        gm *= np.arange(sigma)[None, :] < lm[:, None]
        wantm = np.array([exp.get(tuple(int(x) for x in r[:l]), 0)
                          for r, l in zip(gm, lm)])
        assert (wantm > 0).mean() < 0.5       # really miss-heavy
        for uk in (False, True):
            assert (serve_queries(sh_c, g, ln, use_kernels=uk) == want).all(), uk
            assert (serve_queries(sh_c, gm, lm, use_kernels=uk) == wantm).all(), uk
        print("OK", len(gram_tuples))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_generational_matches_single_device():
    """Acceptance: a GenerationalIndex grown through >=3 ingests (with a
    compaction) serves bit-identically through the 8-way sharded path -- point
    lookups summed across per-segment shard stacks, continuation candidate
    sets folded on the host -- for both layouts."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core import run_job
        from repro.core.stats import NGramConfig
        from repro.index import (GenerationalIndex, build_index, continuations,
                                 lookup, serve_queries, shard_generational,
                                 stats_union)
        from tests.test_compress import make_corpus
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        vocab, sigma, k = 40, 4, 8
        cfg = NGramConfig(sigma=sigma, tau=1, vocab_size=vocab)
        slices = [make_corpus(n, vocab, "zipf", 40 + i)
                  for i, n in enumerate((5000, 1100, 1100, 1100))]
        all_stats = [run_job(t, cfg) for t in slices]
        for compress in (False, True):
            gen = GenerationalIndex(sigma=sigma, vocab_size=vocab,
                                    compress=compress)
            merges = sum(gen.ingest(s)["merges"] for s in all_stats)
            assert merges >= 1 and gen.n_segments >= 2, (merges, gen)
            sh = shard_generational(gen, mesh=mesh)
            assert sh.n_segments == gen.n_segments

            union = stats_union(*all_stats)
            exp = union.to_dict()
            target = build_index(union, vocab_size=vocab)
            gram_tuples = sorted(exp)
            g = np.zeros((len(gram_tuples), sigma), np.int32)
            ln = np.zeros(len(gram_tuples), np.int32)
            for i, t in enumerate(gram_tuples):
                g[i, :len(t)] = t; ln[i] = len(t)
            got = serve_queries(sh, g, ln)
            assert (got == np.asarray(lookup(target, g, ln))).all(), compress
            assert (got == [exp[t] for t in gram_tuples]).all(), compress

            rng = np.random.default_rng(0)
            lm = rng.integers(1, sigma + 1, 2000).astype(np.int32)
            gm = rng.integers(1, vocab + 1, (2000, sigma)).astype(np.int32)
            gm *= np.arange(sigma)[None, :] < lm[:, None]
            assert (serve_queries(sh, gm, lm)
                    == np.asarray(lookup(target, gm, lm))).all(), compress

            pool = [t[:-1] for t in gram_tuples if len(t) >= 2]
            prefixes = [(), pool[0], ()] + \\
                [pool[i] for i in rng.choice(len(pool), 12)]
            pg = np.zeros((len(prefixes), sigma), np.int32)
            pl = np.zeros(len(prefixes), np.int32)
            for i, t in enumerate(prefixes):
                pg[i, :len(t)] = t; pl[i] = len(t)
            res = serve_queries(sh, pg, pl, mode="continuations", k=k)
            nd, tot, terms, cfs = [np.asarray(x) for x in
                                   continuations(target, pg, pl, k=k)]
            assert (res[:, 0] == nd).all(), compress
            assert (res[:, 1] == tot).all(), compress
            assert (res[:, 2:2 + k] == terms).all(), compress
            assert (res[:, 2 + k:] == cfs).all(), compress
        print("OK", len(gram_tuples))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_mesh_waves_match_single_device_and_monolithic():
    """Distributed waves: every wave running as one fused shard_map dispatch
    over an 8-way mesh (ppermute halo + all_to_all shuffle + device-side
    segment collect) must be bit-identical to BOTH the single-device wave
    run and the monolithic job -- all four methods, each across the partial-
    final-wave, wave-smaller-than-mesh, and one-wave degenerate shapes."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core import run_job
        from repro.core.stats import NGramConfig
        from repro.pipeline import WaveExecutor
        from tests.test_compress import make_corpus
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))

        def check(toks, mono, cfg, wave):
            single = WaveExecutor(cfg, wave_tokens=wave).run(toks)
            dist = WaveExecutor(cfg, wave_tokens=wave, mesh=mesh).run(toks)
            for got in (single, dist):
                assert np.array_equal(got.grams, mono.grams), cfg.method
                assert np.array_equal(got.lengths, mono.lengths), cfg.method
                assert np.array_equal(got.counts, mono.counts), cfg.method
            assert dist.counters["waves"] == single.counters["waves"]
            return dist

        toks = make_corpus(400, 23, "zipf", seed=7)
        for m in ("suffix_sigma", "naive", "apriori_scan", "apriori_index"):
            cfg = NGramConfig(sigma=4, tau=2, vocab_size=23, method=m,
                              apriori_index_k=2)
            mono = run_job(toks, cfg)
            d = check(toks, mono, cfg, 97)    # partial final wave included
            assert d.counters["waves"] == -(-len(toks) // 97)
            check(toks, mono, cfg, 5)         # wave smaller than the mesh
            check(toks, mono, cfg, len(toks) + 5)   # one-wave degenerate
        print("OK")
    """)
    assert "OK" in out


def test_fused_mesh_one_dispatch_per_wave():
    """The fused mesh-wave program really is ONE sharded dispatch per wave:
    a traced 8-wave multi-round run emits exactly one ``wave.mesh.dispatch``
    span per wave (rounds fused inside the shard_map program, not looped on
    the host), one collect per wave, no overflow retries -- the mesh twin of
    ``test_fused_wave_one_stage_dispatch_per_wave``."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core.stats import NGramConfig
        from repro.pipeline import WaveExecutor
        from repro.pipeline.plan import plan_for
        from repro.obs import trace as obs_trace
        from tests.test_compress import make_corpus
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        toks = make_corpus(400, 23, "zipf", seed=5)
        n_waves = 8
        wave = -(-len(toks) // n_waves)
        cfg = NGramConfig(sigma=4, tau=2, vocab_size=23,
                          method="apriori_scan")
        assert plan_for(cfg).rounds > 1
        ex = WaveExecutor(cfg, wave_tokens=wave, mesh=mesh)
        ex.run(toks)                   # warm the per-shape program cache
        tracer = obs_trace.enable_tracing()
        try:
            ex.run(toks)
        finally:
            obs_trace.disable_tracing()
        names = [e["name"] for e in tracer.events]
        assert names.count("wave.mesh.dispatch") == n_waves, names
        assert names.count("wave.mesh.collect") == n_waves
        assert names.count("wave.mesh.retry") == 0
        assert names.count("wave.fold") == n_waves
        assert names.count("wave.run") == 1
        print("OK")
    """)
    assert "OK" in out


def test_mesh_skew_histogram_gated_by_metrics():
    """The per-round skew histogram (a psum'd bincount) must stay out of the
    fused mesh program when metrics are off: disabled runs report
    ``shuffle_skew == 0.0`` (the collective never runs), enabled runs
    measure a real skew -- and the gram set plus every additive counter is
    identical either way (observability must not change results)."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core.stats import NGramConfig
        from repro.pipeline import WaveExecutor
        from repro.obs import metrics as obs_metrics
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(3)
        toks = rng.integers(1, 40, 800).astype(np.int32)
        cfg = NGramConfig(sigma=3, tau=1, vocab_size=64)
        off = WaveExecutor(cfg, wave_tokens=200, mesh=mesh).run(toks)
        assert off.counters["shuffle_skew"] == 0.0   # psum skipped outright
        obs_metrics.set_registry(obs_metrics.MetricsRegistry())
        try:
            on = WaveExecutor(cfg, wave_tokens=200, mesh=mesh).run(toks)
        finally:
            obs_metrics.set_registry(None)
        assert on.counters["shuffle_skew"] > 0.0
        assert on.to_dict() == off.to_dict()
        for k in ("jobs", "map_records", "shuffle_records", "shuffle_bytes",
                  "waves", "retries"):
            assert on.counters[k] == off.counters[k], k
        print("OK skew=", on.counters["shuffle_skew"])
    """)
    assert "OK" in out


def test_shard_generational_incremental_reuse():
    """A small delta over a big base must not re-shard untouched elder rungs:
    their shard stacks are reused by level identity (same objects), only the
    new L0 pays a build, and the refreshed stack still answers exactly.  Runs
    in-process on a 1-device mesh -- identity reuse is mesh-width independent."""
    import numpy as np
    import jax
    from repro.core import run_job
    from repro.core.stats import NGramConfig
    from repro.index import (GenerationalIndex, build_index, lookup,
                             serve_queries, shard_generational, stats_union)
    from tests.test_compress import make_corpus

    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    vocab, sigma = 40, 4
    cfg = NGramConfig(sigma=sigma, tau=1, vocab_size=vocab)
    base = [run_job(make_corpus(n, vocab, "zipf", 60 + i), cfg)
            for i, n in enumerate((4000, 900, 900))]
    gen = GenerationalIndex(sigma=sigma, vocab_size=vocab, compress=True)
    for s in base:
        gen.ingest(s)
    sh1 = shard_generational(gen, mesh=mesh)
    assert sh1.n_segments == gen.n_segments

    delta = run_job(make_corpus(120, vocab, "zipf", 99), cfg)
    assert gen.ingest(delta)["merges"] == 0    # small delta: no compaction
    sh2 = shard_generational(gen, mesh=mesh, prev=sh1)
    assert sh2.n_segments == sh1.n_segments + 1
    # elder stacks reused verbatim; only the new L0 was built
    assert all(a is b for a, b in zip(sh2.shards[1:], sh1.shards))
    assert all(sh2.shards[0] is not s for s in sh1.shards)
    assert sh2.level_ids[1:] == sh1.level_ids

    union = stats_union(*base, delta)
    target = build_index(union, vocab_size=vocab)
    exp = union.to_dict()
    gram_tuples = sorted(exp)[:600]
    g = np.zeros((len(gram_tuples), sigma), np.int32)
    ln = np.zeros(len(gram_tuples), np.int32)
    for i, t in enumerate(gram_tuples):
        g[i, :len(t)] = t
        ln[i] = len(t)
    got = serve_queries(sh2, g, ln)
    np.testing.assert_array_equal(got, np.asarray(lookup(target, g, ln)))

    # a layout change invalidates the whole cache: nothing may be reused
    sh3 = shard_generational(gen, mesh=mesh, prev=sh2, block_size=8)
    assert all(a is not b for a in sh3.shards for b in sh2.shards)


def test_sigma_split_exact():
    """Two-phase sigma split (SSPerf H3) is exact vs the single job."""
    import numpy as np
    from repro.core import suffix_sigma
    from repro.core.stats import NGramConfig
    from repro.data import corpus as corpus_mod
    toks = corpus_mod.zipf_corpus(3000, corpus_mod.NYT, seed=5, duplicate_frac=0.3)
    cfg = NGramConfig(sigma=20, tau=2, vocab_size=corpus_mod.NYT.vocab_size)
    full = suffix_sigma.run(toks, cfg).to_dict()
    assert suffix_sigma.sigma_split(toks, cfg, 6, 1 / 8).to_dict() == full
    # undersized survivor buffer recovers via retry
    assert suffix_sigma.sigma_split(toks, cfg, 4, 1 / 512).to_dict() == full


def test_moe_sort_dispatch_under_mesh():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.transformer import AttentionConfig, LMConfig, init_params, loss_fn
        from repro.models.moe import MoEConfig
        import dataclasses
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = LMConfig("m", 2, 32, 97, 64, AttentionConfig("gqa", 8, 4, 4),
                       moe=MoEConfig(8, 2, 32, capacity_factor=8.0),
                       dtype=jnp.float32, remat=False,
                       shard_activations="data")
        params = init_params(jax.random.PRNGKey(0), cfg)
        from repro.configs.base import lm_param_pspecs, named
        pspecs = lm_param_pspecs(cfg, mesh)
        params = jax.device_put(params, named(mesh, pspecs))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 1, 97)
        batch = {"tokens": jax.device_put(toks, NamedSharding(mesh, P("data", None))),
                 "labels": jax.device_put(toks, NamedSharding(mesh, P("data", None)))}
        with mesh:
            loss, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
        # compare against single-device value
        cfg0 = dataclasses.replace(cfg, shard_activations=None)
        p0 = jax.device_put(params, jax.devices()[0])
        loss0, _ = loss_fn(p0, jax.device_put(batch, jax.devices()[0]), cfg0)
        assert abs(float(loss) - float(loss0)) < 1e-4, (float(loss), float(loss0))
        print("OK", float(loss))
    """)
    assert "OK" in out


def test_mesh_wave_capacity_retry_counters_not_double_counted():
    """Mesh-wave capacity retries rerun the whole round program, so a naive
    fold of every attempt's stats would double-count map/shuffle records.
    Regression: only the successful attempt's stats may land -- the tight-
    and ample-capacity runs must agree on every additive counter (and on the
    output), differing only in ``retries``."""
    out = run_with_devices("""
        import dataclasses, numpy as np, jax
        from repro.core import run_job
        from repro.core.stats import NGramConfig
        from repro.pipeline import WaveExecutor
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(1)
        # heavy skew: tiny vocab concentrates lead terms; combine=False keeps
        # the duplicate records that actually overflow the (src, dst) buckets
        toks = rng.integers(0, 3, 2400)
        ample_cfg = NGramConfig(sigma=3, tau=1, vocab_size=2, combine=False,
                                capacity_factor=50.0)
        tight_cfg = dataclasses.replace(ample_cfg, capacity_factor=0.05)
        ample = WaveExecutor(ample_cfg, wave_tokens=600, mesh=mesh).run(toks)
        tight = WaveExecutor(tight_cfg, wave_tokens=600, mesh=mesh).run(toks)
        assert ample.counters.get("retries", 0) == 0
        assert tight.counters["retries"] >= 1
        assert tight.counters["overflow"] == 0     # final attempts clean
        for k in ("jobs", "map_records", "shuffle_records", "shuffle_bytes",
                  "waves", "fold_rows"):
            assert tight.counters[k] == ample.counters[k], k
        assert tight.to_dict() == ample.to_dict()
        assert tight.to_dict() == run_job(toks, ample_cfg).to_dict()
        print("OK retries=", tight.counters["retries"])
    """)
    assert "OK" in out
