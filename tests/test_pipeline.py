"""Wave-vs-monolithic parity for the unified job engine (repro.pipeline).

The contract is the acceptance bar of the engine: for every method and every
wave size, ``WaveExecutor.run`` must be **bit-identical** (grams / lengths /
counts leaf-exact) to the monolithic single-job run -- per-wave partials are
kept at tau=1 and folded through the segment-merge path, so nothing may be
lost or reordered at wave boundaries (the halo + emit-side-carry machinery
under test).  On top: ``run_streaming`` over waves must answer point and
top-k queries exactly like a from-scratch generational build over the full
corpus, the hash-slot combiner route must not change any job output, and the
engine's restrictions (bucketed series) must refuse loudly.

Corpus generation is hypothesis-driven where available and degrades to the
same generator over fixed parametrized draws without it (repo convention).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import METHODS, NGramConfig, oracle, run_job
from repro.pipeline import WaveExecutor, canonical_stats, plan_for
from tests.test_compress import make_corpus


def assert_stats_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got.grams), np.asarray(want.grams))
    np.testing.assert_array_equal(np.asarray(got.lengths),
                                  np.asarray(want.lengths))
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(want.counts))


def check_wave_parity(toks, cfg, wave):
    mono = run_job(toks, cfg)
    got = WaveExecutor(cfg, wave_tokens=wave).run(toks)
    assert_stats_equal(got, mono)
    # and the engine really ran out-of-core when asked to
    if wave is not None and wave < len(toks):
        assert got.counters["waves"] == -(-len(toks) // wave)
    return got


def doc_wave(toks) -> int:
    """A wave of roughly one document (the PAD-separated unit)."""
    bounds = np.flatnonzero(np.asarray(toks) == 0)
    if bounds.size == 0:
        return max(1, len(toks) // 4)
    return max(1, int(np.median(np.diff(np.concatenate([[0], bounds])))))


# ------------------------------------------------------ parametrized parity
@pytest.mark.parametrize("method", sorted(METHODS))
@pytest.mark.parametrize("wave", ["corpus", "doc", 17])
def test_wave_parity(method, wave):
    rng = np.random.default_rng(hash(method) % 2**31)
    toks = make_corpus(400, 23, "zipf", seed=7)
    cfg = NGramConfig(sigma=4, tau=2, vocab_size=23, method=method,
                      apriori_index_k=2)
    w = {"corpus": len(toks) + 5, "doc": doc_wave(toks)}.get(wave, wave)
    check_wave_parity(toks, cfg, w)


@pytest.mark.parametrize("method", sorted(METHODS))
def test_wave_parity_single_token_waves(method):
    """wave=1: every token is its own wave -- maximal boundary stress."""
    toks = make_corpus(60, 9, "uniform", seed=3)
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=9, method=method,
                      apriori_index_k=1)
    got = check_wave_parity(toks, cfg, 1)
    assert got.to_dict() == oracle.ngram_counts(toks, 3, 2)


def test_wave_parity_sigma_exceeds_wave():
    """Halo longer than the wave itself (sigma - 1 > wave) must still be
    exact -- suffixes span several wave boundaries."""
    toks = make_corpus(120, 7, "zipf", seed=11)
    cfg = NGramConfig(sigma=6, tau=1, vocab_size=7)
    check_wave_parity(toks, cfg, 3)


@pytest.mark.parametrize("tail", [1, 2, 16])
def test_wave_parity_corpus_not_multiple_of_wave(tail):
    """The final partial wave carries a true live count (not the full wave):
    a corpus of k*wave + tail tokens must stay bit-identical, down to a
    single-token final wave."""
    wave = 64
    toks = np.asarray(make_corpus(400, 19, "zipf", seed=21))[: 5 * wave + tail]
    assert len(toks) % wave == tail
    cfg = NGramConfig(sigma=4, tau=2, vocab_size=19)
    got = check_wave_parity(toks, cfg, wave)
    assert got.counters["waves"] == 6


def test_wave_halo_spans_corpus_tail():
    """A halo reaching past the end of the corpus (the final wave's halo is
    all padding) must neither truncate nor fabricate tail grams."""
    wave = 7
    toks = np.asarray(make_corpus(200, 11, "zipf", seed=23))
    toks = toks[: (len(toks) // wave) * wave + 1]   # 1 live token + 4-pad halo
    cfg = NGramConfig(sigma=5, tau=1, vocab_size=11)
    got = check_wave_parity(toks, cfg, wave)
    assert got.to_dict() == oracle.ngram_counts(toks, 5, 1)


def test_wave_empty_corpus():
    """Zero tokens: one empty wave, empty output, and a queryable (empty)
    streaming index -- no crashes anywhere on the path."""
    from repro.index import lookup

    empty = np.zeros((0,), np.int32)
    for method in ("suffix_sigma", "naive"):
        cfg = NGramConfig(sigma=3, tau=1, vocab_size=9, method=method)
        got = WaveExecutor(cfg, wave_tokens=8).run(empty)
        assert len(got) == 0
        assert got.counters["waves"] == 1
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=9)
    gen, reports = WaveExecutor(cfg, wave_tokens=8).run_streaming(empty)
    assert len(reports) == 1 and gen.generation == 1
    assert gen.n_segments == 0      # empty deltas must not pile up segments
    g = np.asarray([[1, 2, 0]], np.int32)
    assert np.asarray(lookup(gen, g, np.asarray([2], np.int32)))[0] == 0


# ------------------------------------------------------------ wave accumulator
def test_accumulator_parity_and_fold_work_win():
    """Both fold policies are bit-identical to the monolithic job; the tiered
    rung stack does measurably less merge work at >= 16 waves."""
    toks = make_corpus(2500, 50, "zipf", seed=31)
    cfg = NGramConfig(sigma=4, tau=2, vocab_size=50)
    wave = -(-len(toks) // 16)
    mono = run_job(toks, cfg)
    tiered = WaveExecutor(cfg, wave_tokens=wave).run(toks)
    pairwise = WaveExecutor(cfg, wave_tokens=wave,
                            accumulator="pairwise").run(toks)
    assert_stats_equal(tiered, mono)
    assert_stats_equal(pairwise, mono)
    assert tiered.counters["fold_rows"] < pairwise.counters["fold_rows"]


def test_accumulator_rejects_unknown_policy():
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=9)
    with pytest.raises(ValueError, match="accumulator"):
        WaveExecutor(cfg, wave_tokens=8, accumulator="nope")


def test_merge_route_device_matches_monolithic():
    """The on-device k-way fold route (``merge_route="device"``, the mesh
    accumulator's default lever) is bit-identical to the monolithic job and
    to the host k-way default, across both fold policies."""
    toks = make_corpus(2000, 40, "zipf", seed=41)
    cfg = NGramConfig(sigma=4, tau=2, vocab_size=40)
    wave = -(-len(toks) // 8)
    mono = run_job(toks, cfg)
    for acc in ("defer", "tiered"):
        got = WaveExecutor(cfg, wave_tokens=wave, accumulator=acc,
                           merge_route="device").run(toks)
        assert_stats_equal(got, mono)


def test_segment_accumulators_match_merge_oracle():
    """Unit level: pushing per-wave segments through either accumulator gives
    the segment a one-shot merge of everything would."""
    from repro.index import (PairwiseSegmentAccumulator,
                             TieredSegmentAccumulator, merge_segments,
                             segment_from_stats, segment_to_stats)

    cfg = NGramConfig(sigma=3, tau=1, vocab_size=15)
    segs = []
    for seed in range(6):
        stats = run_job(make_corpus(150, 15, "zipf", seed=seed), cfg)
        segs.append(segment_from_stats(stats, vocab_size=15))
    want = segment_to_stats(merge_segments(segs, route="sort"))
    for acc in (TieredSegmentAccumulator(route="sort", size_ratio=2),
                PairwiseSegmentAccumulator(route="sort")):
        for s in segs:
            acc.push(s)
        got = segment_to_stats(acc.result())
        assert_stats_equal(got, want)
        assert acc.fold_rows > 0
    with pytest.raises(ValueError):
        TieredSegmentAccumulator().result()


# -------------------------------------------------------- reserved-id-0 guard
def test_validate_tokens_rejects_out_of_range_ids():
    """Ids past vocab_size would overflow their packed lane field and
    fabricate grams; negative ids alias through the uint32 casts.  Both must
    fail loudly at the wave-engine door (PAD id 0 stays legal)."""
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=9)
    cfg.validate_tokens(np.asarray([0, 1, 9, 0, 3]))        # in range: fine
    with pytest.raises(ValueError, match="reserved PAD"):
        cfg.validate_tokens(np.asarray([1, 10, 2]))
    with pytest.raises(ValueError, match="reserved PAD"):
        cfg.validate_tokens(np.asarray([-1, 2, 3]))
    ex = WaveExecutor(cfg, wave_tokens=4)
    with pytest.raises(ValueError, match="token ids"):
        ex.run(np.asarray([1, 2, 10]))
    with pytest.raises(ValueError, match="token ids"):
        ex.run_streaming(np.asarray([1, -2, 3]))


# ------------------------------------------------------------- stage cache
def test_stage_cache_keyed_by_backend_with_reset(monkeypatch):
    """The jitted stage program's donation choice depends on the backend, so
    the cache must key by it (never freeze the first caller's backend) and be
    resettable for tests/reconfiguration."""
    from repro.pipeline import executor, reset_stage_cache

    toks = make_corpus(60, 9, "uniform", seed=1)
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=9)
    run_job(toks, cfg)
    real = jax_backend = executor.jax.default_backend()
    assert jax_backend in executor._STAGE_CORE
    cpu_fn = executor._STAGE_CORE[real]
    monkeypatch.setattr(executor.jax, "default_backend", lambda: "faketpu")
    # a "new backend" must get its own program, not reuse the frozen one
    run_job(toks, cfg)
    assert "faketpu" in executor._STAGE_CORE
    assert executor._STAGE_CORE["faketpu"] is not cpu_fn
    assert executor._STAGE_CORE[real] is cpu_fn    # old entry untouched
    reset_stage_cache()
    assert executor._STAGE_CORE == {}
    monkeypatch.undo()
    assert_stats_equal(run_job(toks, cfg),
                       WaveExecutor(cfg, wave_tokens=13).run(toks))


def test_generational_ingest_skips_empty_delta():
    """An empty delta bumps the generation (cache invalidation) but must not
    insert an all-sentinel segment that every later query pays for."""
    from repro.core.stats import NGramStats
    from repro.index import GenerationalIndex

    gen = GenerationalIndex(sigma=3, vocab_size=9)
    stats = run_job(make_corpus(200, 9, "zipf", seed=2),
                    NGramConfig(sigma=3, tau=1, vocab_size=9))
    gen.ingest(stats)
    n_seg, g0 = gen.n_segments, gen.generation
    empty = NGramStats(np.zeros((0, 3), np.int32), np.zeros((0,), np.int32),
                       np.zeros((0,), np.int64))
    rep = gen.ingest(empty)
    assert rep["ingested_rows"] == 0 and rep["merges"] == 0
    assert gen.n_segments == n_seg
    assert gen.generation == g0 + 1


# ----------------------------------------------------- randomized corpora
def _parity_draw(method, vocab, dist, sigma, tau, wave_frac, seed):
    toks = make_corpus(350, vocab, dist, seed)
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=vocab, method=method,
                      combine=bool(seed % 2), apriori_index_k=1 + seed % 3)
    wave = max(1, int(len(toks) * wave_frac))
    check_wave_parity(toks, cfg, wave)


FALLBACK_DRAWS = [
    ("suffix_sigma", 50, "zipf", 5, 1, 0.31, 0),
    ("naive", 11, "uniform", 3, 2, 0.09, 1),
    ("apriori_scan", 200, "zipf", 4, 3, 0.5, 2),
    ("apriori_index", 30, "uniform", 5, 2, 0.13, 3),
]


@pytest.mark.parametrize("draw", FALLBACK_DRAWS,
                         ids=[d[0] for d in FALLBACK_DRAWS])
def test_wave_parity_fixed_draws(draw):
    _parity_draw(*draw)


if HAS_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(method=st.sampled_from(sorted(METHODS)),
           vocab=st.integers(5, 500),
           dist=st.sampled_from(["zipf", "uniform"]),
           sigma=st.integers(1, 6), tau=st.integers(1, 4),
           wave_frac=st.floats(0.02, 1.2), seed=st.integers(0, 2**20))
    def test_wave_parity_hypothesis(method, vocab, dist, sigma, tau,
                                    wave_frac, seed):
        _parity_draw(method, vocab, dist, sigma, tau, wave_frac, seed)


# ------------------------------------------------------- streaming serving
def test_streaming_ingest_equals_batch_build():
    """Waves -> GenerationalIndex must answer point + top-k queries exactly
    like a from-scratch generational build over the whole corpus."""
    from repro.index import continuations, generational_from_stats, lookup

    rng = np.random.default_rng(5)
    toks = make_corpus(3000, 40, "zipf", seed=5)
    cfg = NGramConfig(sigma=4, tau=1, vocab_size=40)
    gen, reports = WaveExecutor(cfg, wave_tokens=512).run_streaming(toks)
    assert len(reports) == -(-len(toks) // 512)
    assert gen.generation == len(reports)

    stats = run_job(toks, cfg)
    want = generational_from_stats(stats, vocab_size=40)

    q = 96
    grams = np.zeros((q, 4), np.int32)
    lengths = np.zeros((q,), np.int32)
    rows = rng.choice(len(stats), q - 16)
    grams[: q - 16] = stats.grams[rows]
    lengths[: q - 16] = stats.lengths[rows]
    grams[q - 16:] = rng.integers(1, 46, (16, 4))      # misses / OOV
    lengths[q - 16:] = rng.integers(1, 5, 16)

    np.testing.assert_array_equal(np.asarray(lookup(gen, grams, lengths)),
                                  np.asarray(lookup(want, grams, lengths)))
    p_len = np.maximum(lengths - 1, 0)
    got_c = continuations(gen, grams, p_len, k=6)
    want_c = continuations(want, grams, p_len, k=6)
    for g, w in zip(got_c, want_c):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_streaming_service_wave_ingest_matches_monolithic():
    """serve_ngrams' service with wave_tokens set serves identical counts."""
    from repro.launch.serve_ngrams import StreamingNGramService

    toks = make_corpus(1200, 25, "zipf", seed=9)
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=25)
    a = StreamingNGramService(cfg, cache_capacity=64)
    b = StreamingNGramService(cfg, cache_capacity=64, wave_tokens=200)
    ra = a.ingest(toks)
    rb = b.ingest(toks)
    assert ra["ingested_rows"] == rb["ingested_rows"]
    assert rb["waves"] == -(-len(toks) // 200) and ra["waves"] == 1
    stats = run_job(toks, cfg)
    g = np.asarray(stats.grams)[:64]
    ln = np.asarray(stats.lengths)[:64]
    np.testing.assert_array_equal(a.lookup(g, ln), b.lookup(g, ln))


# --------------------------------------------------------- engine contract
def test_run_job_output_is_canonical():
    """Single-device jobs now emit canonical (segment-ordered, deduped) rows;
    canonical_stats must be a fixed point of their output."""
    toks = make_corpus(500, 15, "zipf", seed=1)
    for method in METHODS:
        stats = run_job(toks, NGramConfig(sigma=3, tau=2, vocab_size=15,
                                          method=method))
        assert_stats_equal(canonical_stats(stats), stats)


def test_hash_combine_route_preserves_output():
    """The sort-free combiner may only *redistribute* weights -- job output
    (and the oracle) must be untouched, kernel and jnp routes alike."""
    toks = make_corpus(600, 18, "zipf", seed=2)
    want = oracle.ngram_counts(toks, 4, 2)
    for use_kernels in (False, True):
        cfg = NGramConfig(sigma=4, tau=2, vocab_size=18,
                          combine_route="hash", use_kernels=use_kernels)
        assert run_job(toks, cfg).to_dict() == want
        got = WaveExecutor(cfg, wave_tokens=150).run(toks)
        assert got.to_dict() == want


def test_hash_combine_actually_combines():
    """On a duplicate-heavy stream the hash route must shrink the shuffle
    (the whole point of a combiner), not just pass records through."""
    toks = np.asarray([1, 2, 3] * 200, np.int32)
    on = run_job(toks, NGramConfig(sigma=3, tau=1, vocab_size=3,
                                   combine_route="hash"))
    off = run_job(toks, NGramConfig(sigma=3, tau=1, vocab_size=3,
                                    combine=False))
    assert on.counters["shuffle_records"] < off.counters["shuffle_records"]
    assert on.to_dict() == off.to_dict()


def test_plan_registry_covers_methods():
    for method in METHODS:
        plan = plan_for(NGramConfig(sigma=3, tau=1, vocab_size=9,
                                    method=method))
        assert plan.name == method
    with pytest.raises(ValueError):
        plan_for(NGramConfig(sigma=3, tau=1, vocab_size=9, method="nope"))


def test_wave_rejects_buckets():
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=9, n_buckets=4)
    with pytest.raises(ValueError, match="n_buckets"):
        WaveExecutor(cfg, wave_tokens=8)
    with pytest.raises(ValueError, match="n_buckets"):
        WaveExecutor(cfg)               # one-wave mode can't carry buckets either


@pytest.mark.slow
def test_wave_parity_acceptance_scale():
    """Acceptance-sized corpus (>=30k tokens, zipf skew, 6 waves): the
    bit-identity contract and the streaming path at a size where padding /
    capacity-rounding bugs would actually bite."""
    from repro.index import generational_from_stats, lookup

    toks = make_corpus(30_000, 2_000, "zipf", seed=13)
    cfg = NGramConfig(sigma=5, tau=4, vocab_size=2_000)
    wave = -(-len(toks) // 6)
    got = check_wave_parity(toks, cfg, wave)
    assert got.counters["waves"] == 6

    cfg1 = NGramConfig(sigma=5, tau=1, vocab_size=2_000)
    gen, _ = WaveExecutor(cfg1, wave_tokens=wave).run_streaming(toks)
    want = generational_from_stats(run_job(toks, cfg1), vocab_size=2_000)
    stats = run_job(toks, cfg1)
    rng = np.random.default_rng(13)
    rows = rng.choice(len(stats), 256)
    g = np.asarray(stats.grams)[rows]
    ln = np.asarray(stats.lengths)[rows]
    np.testing.assert_array_equal(np.asarray(lookup(gen, g, ln)),
                                  np.asarray(lookup(want, g, ln)))


def test_suffix_map_record_invariant_across_waves():
    """SSIV: one record per token occurrence, wave-split or not."""
    toks = make_corpus(500, 20, "uniform", seed=8)
    n_tok = int((np.asarray(toks) != 0).sum())
    cfg = NGramConfig(sigma=4, tau=1, vocab_size=20, combine=False)
    got = WaveExecutor(cfg, wave_tokens=97).run(toks)
    assert got.counters["map_records"] == n_tok
    assert got.counters["shuffle_records"] == n_tok


# ------------------------------------------------------------ fused dispatch
def test_fused_wave_one_stage_dispatch_per_wave():
    """The whole-wave program really is ONE dispatch: a traced 8-wave run
    emits exactly one ``round.stages`` span per wave even for a multi-round
    plan (the rounds are fused inside the program, not looped on the host),
    and every wave passes through exactly one collect and one fold."""
    from repro.obs import trace as obs_trace

    toks = make_corpus(400, 23, "zipf", seed=5)
    n_waves = 8
    wave = -(-len(toks) // n_waves)
    cfg = NGramConfig(sigma=4, tau=2, vocab_size=23, method="apriori_scan")
    assert plan_for(cfg).rounds > 1
    WaveExecutor(cfg, wave_tokens=wave).run(toks)   # warm the program caches
    tracer = obs_trace.enable_tracing()
    try:
        WaveExecutor(cfg, wave_tokens=wave).run(toks)
    finally:
        obs_trace.disable_tracing()
    names = [e["name"] for e in tracer.events]
    assert names.count("round.stages") == n_waves
    assert names.count("wave.collect") == n_waves
    assert names.count("wave.fold") == n_waves
    assert names.count("wave.run") == 1


def test_direct_segment_collect_matches_stats_route():
    """The packed-lane collect (``_collect_wave_segment``: keys built as
    ``lanes & prefix_mask[len]`` straight off the sorted records) must
    produce the exact segment of the stats detour
    (``segment_from_wave_stats(_collect_wave(...))``) -- per wave, every
    method."""
    from repro.index.build import segment_from_wave_stats

    toks = make_corpus(300, 23, "zipf", seed=9)
    for method in sorted(METHODS):
        cfg = NGramConfig(sigma=4, tau=2, vocab_size=23, method=method,
                          apriori_index_k=2)
        ex = WaveExecutor(cfg, wave_tokens=61)
        assert ex._direct
        for tok_ext, n_live in ex._windows(np.asarray(toks, np.int32)):
            pend = ex._submit_wave(tok_ext, n_live)
            part = ex._collect_wave_segment(pend)
            want = segment_from_wave_stats(ex._collect_wave(pend),
                                           vocab_size=cfg.vocab_size)
            assert part.n_rows == want.n_rows, method
            np.testing.assert_array_equal(np.asarray(part.segment.keys),
                                          np.asarray(want.keys))
            np.testing.assert_array_equal(np.asarray(part.segment.counts),
                                          np.asarray(want.counts))


def test_wave_parity_unpacked_lane_fallback():
    """``pack=False`` packs lanes with a vocabulary other than the segment's,
    so the direct-segment collect must disable itself and route through the
    stats collect -- still bit-identical to the monolithic job."""
    toks = make_corpus(200, 11, "zipf", seed=13)
    cfg = NGramConfig(sigma=3, tau=2, vocab_size=11, pack=False)
    assert not WaveExecutor(cfg, wave_tokens=37)._direct
    check_wave_parity(toks, cfg, 37)


def test_overlap_off_matches_overlap_on():
    """The background fold thread is a scheduling choice, not a semantic one:
    overlap on/off must agree bit-for-bit on stats, counters, and the
    streaming ingest reports."""
    toks = make_corpus(300, 19, "zipf", seed=17)
    cfg = NGramConfig(sigma=4, tau=2, vocab_size=19)
    on = WaveExecutor(cfg, wave_tokens=41).run(toks)
    off = WaveExecutor(cfg, wave_tokens=41, overlap=False).run(toks)
    assert_stats_equal(on, off)
    assert on.counters == off.counters
    cfg1 = NGramConfig(sigma=4, tau=1, vocab_size=19)
    g_on, r_on = WaveExecutor(cfg1, wave_tokens=41).run_streaming(toks)
    g_off, r_off = WaveExecutor(cfg1, wave_tokens=41,
                                overlap=False).run_streaming(toks)
    assert r_on == r_off
    assert g_on.generation == g_off.generation
