"""Data pipeline tests: corpora, tokenizer round-trips, loaders, samplers."""
import numpy as np

from repro.data import corpus, graph, loader, recsys, tokenizer


def test_zipf_corpus_profile():
    toks = corpus.zipf_corpus(20_000, corpus.NYT, seed=0)
    assert toks.dtype == np.int32
    assert (toks >= 0).all() and toks.max() <= corpus.NYT.vocab_size
    # mean sentence length near the NYT profile
    lens = np.diff(np.nonzero(toks == 0)[0])
    assert 8 < lens.mean() < 30


def test_corpus_years_alignment():
    toks, years = corpus.zipf_corpus(5_000, corpus.NYT, seed=1, with_years=True)
    assert toks.shape == years.shape


def test_split_at_infrequent_is_apriori_safe():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 40, 2000).astype(np.int32)
    out, removed = corpus.split_at_infrequent(toks, tau=10, vocab_size=39)
    counts = np.bincount(toks, minlength=41)
    assert removed == int(sum(c for t, c in enumerate(counts) if t > 0 and c < 10))
    assert ((out == 0) | (np.bincount(out, minlength=41)[out] >= 10)).all()


def test_scale_sample_fraction():
    toks = corpus.zipf_corpus(50_000, corpus.NYT, seed=2)
    half = corpus.scale_sample(toks, 0.5, seed=0)
    assert 0.3 < half.size / toks.size < 0.7


def test_tokenizer_roundtrip():
    docs = tokenizer.sentences("The cat sat. The cat ran! A dog barked?")
    d = tokenizer.TermDictionary.build(docs)
    enc = d.encode(docs)
    assert enc[enc != 0].min() >= 1
    # frequency order: 'the'/'cat' get the smallest ids
    assert d.term_to_id["the"] <= 2 and d.term_to_id["cat"] <= 3
    back = d.decode_gram(enc[: len(docs[0])])
    assert list(back) == docs[0]


def test_lm_loader_determinism():
    toks = np.arange(1, 10_001, dtype=np.int32)
    l = loader.LMBatchLoader(toks, seq_len=16, global_batch=4, seed=7)
    a, b = l.batch_at(5), l.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_neighbor_sampler_validity():
    g = graph.random_graph(200, 1500, 8, seed=0)
    table = graph.CSRNeighborTable(g)
    rng = np.random.default_rng(0)
    nodes = np.arange(50)
    nbr, mask = table.sample(nodes, 7, rng)
    assert nbr.shape == (350,)
    src, dst = g.edge_index
    # every masked-true neighbor is a genuine in-neighbor of its anchor
    for i in range(0, 350, 29):
        anchor = nodes[i // 7]
        if mask[i]:
            assert ((dst == anchor) & (src == nbr[i])).any()
        else:
            assert nbr[i] == anchor  # self-loop fallback


def test_subgraph_shapes_and_fanout():
    g = graph.random_graph(500, 4000, 8, seed=1)
    table = graph.CSRNeighborTable(g)
    sub = graph.sample_subgraph(g, table, np.arange(32), (15, 10), seed=0)
    assert sub.features.shape[0] == 32 + 32 * 15 + 32 * 15 * 10
    assert sub.edge_src.shape == sub.edge_dst.shape
    assert sub.edge_src.max() < sub.features.shape[0]
    assert sub.labels.shape == (32,)


def test_recsys_generators_deterministic():
    gen = recsys.CTRBatchGen((100, 200, 300))
    a, b = gen.batch_at(3, 16), gen.batch_at(3, 16)
    np.testing.assert_array_equal(a["sparse_ids"], b["sparse_ids"])
    assert a["sparse_ids"].shape == (16, 3)
    assert (a["sparse_ids"] < np.asarray([100, 200, 300])).all()
